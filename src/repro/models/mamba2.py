"""Mamba2 (SSD) block: chunked parallel scan for train/prefill, O(1)
recurrent state for decode.

State-space recurrence per head (head_dim P, state N, scalar A per head):
    S_t = exp(dt_t * A) * S_t-1 + dt_t * B_t x_t^T     (S in R^{N x P})
    y_t = C_t^T S_t + D * x_t

Chunked form (chunk Q): intra-chunk pairwise decays are exp(cum_t - cum_s)
<= 1 (numerically safe), inter-chunk states carried by a lax.scan. Heads
are sharded over 'model' (the grouped gated-RMSNorm is per-head, so TP
needs no cross-device norm reduction — this mirrors the reference Mamba2
TP layout). B/C are group-shared (G=1) and replicated.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.nn import ParamSpec, rms_norm
from repro.models import unroll as U

__all__ = ["Mamba2Config", "mamba2_param_specs", "mamba2", "init_mamba_cache",
           "mamba2_decode"]


@dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64           # N
    head_dim: int = 64          # P
    expand: int = 2
    conv_width: int = 4
    chunk: int = 64
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def mamba2_param_specs(c: Mamba2Config) -> dict:
    d, h, p, n, cw = c.d_model, c.n_heads, c.head_dim, c.d_state, c.conv_width
    return {
        "w_z": ParamSpec((d, h, p), ("embed", "heads", "head_dim"), c.dtype),
        "w_x": ParamSpec((d, h, p), ("embed", "heads", "head_dim"), c.dtype),
        "w_b": ParamSpec((d, n), ("embed", "state"), c.dtype),
        "w_c": ParamSpec((d, n), ("embed", "state"), c.dtype),
        "w_dt": ParamSpec((d, h), ("embed", "heads"), c.dtype),
        "dt_bias": ParamSpec((h,), ("heads",), "float32", init="zeros"),
        "a_log": ParamSpec((h,), ("heads",), "float32", init="zeros"),
        "d_skip": ParamSpec((h,), ("heads",), "float32", init="ones"),
        "conv_x": ParamSpec((cw, h, p), ("conv", "heads", "head_dim"), c.dtype,
                            init="normal", scale=0.5),
        "conv_b": ParamSpec((cw, n), ("conv", "state"), c.dtype,
                            init="normal", scale=0.5),
        "conv_c": ParamSpec((cw, n), ("conv", "state"), c.dtype,
                            init="normal", scale=0.5),
        "norm_w": ParamSpec((h, p), ("heads", "head_dim"), c.dtype, init="ones"),
        "w_out": ParamSpec((h, p, d), ("heads", "head_dim", "embed"), c.dtype),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv along axis 1. x [B,S,...]; w [CW, ...].

    state: [B, CW-1, ...] tail of the previous segment (decode/prefill
    carry); returns (y, new_state)."""
    cw = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1) + x.shape[2:], x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(cw))
    new_state = xp[:, x.shape[1]:]
    return jax.nn.silu(y), new_state


def _ssd_chunked(xdt, a, b, cmat, s0, chunk):
    """Chunked SSD core.

    xdt [B,S,H,P] (x * dt), a [B,S,H] (dt*A, negative), b/cmat [B,S,N],
    s0 [B,H,N,P] initial state. Returns (y [B,S,H,P], s_final).
    """
    bsz, s, h, p = xdt.shape
    n = b.shape[-1]
    q = min(chunk, s)
    s_orig = s
    pad = (-s) % q
    if pad:  # padded steps: decay a=0 (identity) and zero inputs -> no-op
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        s += pad
    nc = s // q
    xdt = xdt.astype(jnp.float32).reshape(bsz, nc, q, h, p)
    a = a.astype(jnp.float32).reshape(bsz, nc, q, h)
    b = b.astype(jnp.float32).reshape(bsz, nc, q, n)
    cmat = cmat.astype(jnp.float32).reshape(bsz, nc, q, n)

    cum = jnp.cumsum(a, axis=2)                       # [B,nc,Q,H] inclusive
    # intra-chunk: scores[t,s] = (C_t . B_s) * exp(cum_t - cum_s), s <= t
    cb = jnp.einsum("bctn,bcsn->bcts", cmat, b)       # [B,nc,Q,Q]
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # [B,nc,t,s,H]
    tri = jnp.tril(jnp.ones((q, q), bool))
    scores = jnp.where(tri[None, None, :, :, None], cb[..., None] * decay, 0.0)
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", scores, xdt)

    # chunk summaries: state contribution of chunk c (before inter decay)
    dec_end = jnp.exp(cum[:, :, -1:, :] - cum)        # [B,nc,Q,H]
    s_loc = jnp.einsum("bcqn,bcqh,bcqhp->bchnp", b, dec_end, xdt)
    dec_chunk = jnp.exp(cum[:, :, -1, :])             # [B,nc,H]

    def step(s_prev, xs):
        sl, dc = xs                                    # [B,H,N,P], [B,H]
        s_new = dc[:, :, None, None] * s_prev + sl
        return s_new, s_prev

    dec_t = jnp.moveaxis(dec_chunk, 1, 0)
    sl_t = jnp.moveaxis(s_loc, 1, 0)
    s_final, s_prevs = U.scan(step, s0.astype(jnp.float32), (sl_t, dec_t))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)              # [B,nc,H,N,P]

    # inter-chunk: y_t += exp(cum_t) * C_t . S_prev
    dec_in = jnp.exp(cum)                              # [B,nc,Q,H]
    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", cmat, dec_in, s_prevs)
    y = (y_intra + y_inter).reshape(bsz, s, h, p)[:, :s_orig]
    return y, s_final


def mamba2(params, x, c: Mamba2Config, rules=None, state=None,
           conv_state=None, mode: str = "train"):
    """x [B,S,d] -> (y [B,S,d], (ssm_state, conv_states) if caching)."""
    bsz, s, _ = x.shape
    h, p, n = c.n_heads, c.head_dim, c.d_state

    z = jnp.einsum("bsd,dhp->bshp", x, params["w_z"])
    xs = jnp.einsum("bsd,dhp->bshp", x, params["w_x"])
    bmat = jnp.einsum("bsd,dn->bsn", x, params["w_b"])
    cmat = jnp.einsum("bsd,dn->bsn", x, params["w_c"])
    dt = jnp.einsum("bsd,dh->bsh", x, params["w_dt"]).astype(jnp.float32)
    if rules is not None:
        xs = rules.shard(xs, "batch", "seq", "heads", "head_dim")
        z = rules.shard(z, "batch", "seq", "heads", "head_dim")

    cs = conv_state or {}
    xs, cs_x = _causal_conv(xs, params["conv_x"], cs.get("x"))
    bmat, cs_b = _causal_conv(bmat, params["conv_b"], cs.get("b"))
    cmat, cs_c = _causal_conv(cmat, params["conv_c"], cs.get("c"))

    dt = jax.nn.softplus(dt + params["dt_bias"])
    a = -jnp.exp(params["a_log"]) * dt                  # [B,S,H]
    xdt = xs.astype(jnp.float32) * dt[..., None]

    if state is None:
        state = jnp.zeros((bsz, h, n, p), jnp.float32)
    y, s_final = _ssd_chunked(xdt, a, bmat, cmat, state, c.chunk)
    y = y + params["d_skip"][None, None, :, None] * xs.astype(jnp.float32)

    # gated per-head RMSNorm (TP-local)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(jnp.dtype(c.dtype)), params["norm_w"], c.norm_eps)
    out = jnp.einsum("bshp,hpd->bsd", y, params["w_out"])
    if rules is not None:
        out = rules.shard(out, "batch", "seq_res", "embed")
    if mode == "train":
        return out, None
    return out, {"ssm": s_final, "conv": {"x": cs_x, "b": cs_b, "c": cs_c}}


def init_mamba_cache(batch: int, c: Mamba2Config, rules=None):
    h, p, n, cw = c.n_heads, c.head_dim, c.d_state, c.conv_width
    dt = jnp.dtype(c.dtype)
    cache = {
        "ssm": jnp.zeros((batch, h, n, p), jnp.float32),
        "conv": {
            "x": jnp.zeros((batch, cw - 1, h, p), dt),
            "b": jnp.zeros((batch, cw - 1, n), dt),
            "c": jnp.zeros((batch, cw - 1, n), dt),
        },
    }
    if rules is not None:
        cache["ssm"] = rules.shard(cache["ssm"], "batch", "heads", "state", "head_dim")
        cache["conv"]["x"] = rules.shard(cache["conv"]["x"], "batch", "conv", "heads", "head_dim")
    return cache


def mamba2_decode(params, x, c: Mamba2Config, cache, rules=None):
    """Single-token decode. x [B,1,d]. Returns (y [B,1,d], new_cache)."""
    out, new = mamba2(params, x, c, rules=rules, state=cache["ssm"],
                      conv_state=cache["conv"], mode="decode")
    return out, new
