"""LSH tables over coded random projections (paper §1.1) — compat shim.

Historically this module owned a host-side Python-dict index probing one
query at a time. The search path now lives in ``repro.ann``: a
device-resident ``AnnEngine`` over bit-packed codes with batched
band-hash candidate generation and packed-collision re-ranking.
``LSHIndex`` survives as a thin wrapper preserving the original
one-query-at-a-time API (build / candidates / query) for existing
callers; new code should use ``repro.ann.AnnEngine.search`` directly and
get the batched, multi-probe, multi-device paths.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

from repro.ann.bands import BandSpec
from repro.ann.engine import AnnEngine
from repro.core.sketch import CodedRandomProjection

__all__ = ["LSHIndex"]


@dataclass
class LSHIndex:
    """L banded hash tables over coded projections (engine-backed)."""
    sketcher: CodedRandomProjection
    n_tables: int = 8
    band_width: int = 8

    def __post_init__(self):
        need = self.n_tables * self.band_width
        if need > self.sketcher.cfg.k:
            raise ValueError(f"need n_tables*band_width <= k, "
                             f"{need} > {self.sketcher.cfg.k}")
        self._engine = None

    @property
    def engine(self) -> AnnEngine:
        if self._engine is None:
            raise RuntimeError("index not built; call build(corpus) first")
        return self._engine

    def build(self, x):
        """Index a corpus x [n, D]."""
        self._engine = AnnEngine.build(
            self.sketcher, x,
            BandSpec(n_tables=self.n_tables, band_width=self.band_width))
        return self

    def candidates(self, q_codes: np.ndarray):
        """Union of bucket members across tables for one query code row."""
        counts = self.engine.band_match_counts(
            jnp.asarray(q_codes)[None, :])[0]
        return [int(i) for i in np.flatnonzero(np.asarray(counts) > 0)]

    def query(self, x_query, top: int = 10):
        """x_query [D] -> list[(corpus_idx, rho_hat)] sorted by similarity."""
        q_codes = self.engine.encode_queries(jnp.asarray(x_query)[None, :])[0]
        cand = self.candidates(np.asarray(q_codes))
        if not cand:
            return []
        _, rho = self.engine.rerank(q_codes, jnp.asarray(cand))
        order = jnp.argsort(-rho)[:top]
        return [(cand[int(i)], float(rho[i])) for i in order]
