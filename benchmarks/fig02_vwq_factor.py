"""Fig 2: the variance factor V_{w,q} x 4/d^2 — paper: min 7.6797 at
w/sqrt(d) = 1.6476."""
import numpy as np
import jax.numpy as jnp

from repro.core import variance as V
from benchmarks._util import timed, write_csv


def run(quick: bool = True):
    ws = np.linspace(0.5, 8.0, 1500)

    def curve():
        return np.asarray([float(V.variance_factor_offset(jnp.asarray(0.0), w))
                           for w in ws])

    vals, us = timed(curve, repeat=1)
    i = int(np.argmin(vals))
    write_csv("fig02_vwq_factor", ["w_over_sqrt_d", "V_wq_times_4_over_d2"],
              [[w / np.sqrt(2.0), v] for w, v in zip(ws, vals)])
    return [("fig02_min", us,
             f"min={vals[i]:.4f}@{ws[i]/np.sqrt(2):.4f};paper=7.6797@1.6476")]
