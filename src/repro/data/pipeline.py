"""Deterministic, resumable synthetic token pipeline.

Batches are a pure function of (seed, step), so:
* resume-at-step-N needs no state beyond the step counter (fault
  tolerance: a restarted job regenerates exactly the stream it would
  have seen);
* every host computes its own shard locally — nothing is broadcast
  (the same counter-based-PRNG trick as the sketch module's projection
  blocks).

The generator is a Zipf-ish unigram mixture with a Markov flavor so the
loss actually decreases during the e2e example (pure uniform tokens have
no learnable structure).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["DataConfig", "TokenPipeline"]


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 1024
    seq_len: int = 256
    global_batch: int = 8
    n_codebooks: int = 1
    seed: int = 1234
    n_states: int = 32          # hidden Markov states (learnable structure)


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        key = jax.random.PRNGKey(cfg.seed)
        # fixed HMM: transition [S,S] and per-state emission logits [S,V]
        k1, k2 = jax.random.split(key)
        self._trans = jax.random.dirichlet(
            k1, jnp.ones((cfg.n_states,)) * 0.5, (cfg.n_states,))
        self._emit_logits = jax.random.normal(
            k2, (cfg.n_states, cfg.vocab_size)) * 2.0

    def batch_at(self, step: int):
        """[B, S] (or [B, S, C]) int32 tokens for global step ``step``."""
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed ^ 0x5EED), step)
        shape_c = (cfg.n_codebooks,) if cfg.n_codebooks > 1 else ()

        def gen_one(k):
            ks, ke = jax.random.split(k)
            s0 = jax.random.randint(ks, shape_c, 0, cfg.n_states)

            def walk(state, kk):
                k1, k2 = jax.random.split(kk)
                nxt = jax.random.categorical(k1, jnp.log(self._trans[state] + 1e-9))
                tok = jax.random.categorical(k2, self._emit_logits[nxt])
                return nxt, tok
            _, toks = jax.lax.scan(walk, s0, jax.random.split(ke, cfg.seq_len))
            return toks  # [S] or [S, C]

        keys = jax.random.split(key, cfg.global_batch)
        return jax.vmap(gen_one)(keys).astype(jnp.int32)

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
