"""Mutable ANN engine: batched search over the segment log.

The serving twin of ``ann.AnnEngine`` for a corpus that changes under
traffic: same query path (fused project→code→pack via the shared
``QueryCoder``, same ``SearchConfig`` knobs, same chunking), but the
corpus side is a ``SegmentLogStore``. Each segment is searched with the
*masked* streaming top-k kernel (tombstones skipped on device), local
rows are swapped for external ids, and the per-segment lists are fused
by ``ann.engine.merge_topk`` — segments are ordered by log position, so
the merged tie-break is identical to one search over a fresh immutable
store of the live rows. That equivalence is the subsystem's contract:
mutate however you like, search never tells the difference.

LSH mode mirrors ``AnnEngine``'s banded retrieval per segment: coarse
matching-band scores against the segment's resident band hashes, the
validity mask folded into the candidate filter, full packed collision
re-rank, then the same cross-segment merge.

Scored search (``scored=True``) also runs per segment. The default
path is the single-pass fused masked kernel
(``kernels.fused_scored``): each segment is streamed once, the top-m
live candidates by collision count are selected and LUT-scored
entirely in-VMEM, and the cross-segment merge compares calibrated
float scores — the same merge, float sentinel instead of -1. With
``fused=False`` the legacy two-stage path runs instead (masked coarse
top-m, then the LUT re-rank kernel over gathered candidates); both
paths return bit-identical results — ``tests/test_kernel_conformance``
holds them to it.
"""
from __future__ import annotations

import time as _time

import numpy as np
import jax.numpy as jnp

from repro.ann.bands import BandSpec, probe_hashes
from repro.ann.engine import (QueryCoder, SearchConfig, _coarse_band_scores,
                              lut_rerank_stage, merge_topk,
                              resolve_query_tables, rho_scored,
                              run_chunked)
from repro.rank.tables import RankTables, build_rank_tables
from repro.core import packing as _packing
from repro.core.sketch import CodedRandomProjection
from repro.index.compaction import CompactionPolicy, compact
from repro.index.segment_log import SegmentLogStore
from repro.index.snapshot import restore_index, save_index
from repro.kernels import ops as _ops
from repro.kernels import ref as _ref
from repro.obs import default_flight_recorder, deep_tracing_active, span

__all__ = ["MutableAnnEngine"]


class MutableAnnEngine:
    """In-place mutable index: add/delete/upsert/compact + batched search.

    Returned ids are *external* item ids (stable across upserts, seals,
    compaction and restarts), not store rows. ``generation`` increments
    on every mutation — the serving layer keys result-cache validity on
    it.
    """

    mutable = True

    def __init__(self, sketcher: CodedRandomProjection, *,
                 band_spec: BandSpec = BandSpec(), tail_rows: int = 1024,
                 impl: str = "auto", store: SegmentLogStore = None,
                 rank_tables: RankTables = None):
        self.sketcher = sketcher
        self._rank_tables = rank_tables
        if store is None:
            store = SegmentLogStore(sketcher.cfg.k, sketcher.spec.bits,
                                    band_spec=band_spec,
                                    tail_rows=tail_rows, impl=impl)
        if (store.k, store.bits) != (sketcher.cfg.k, sketcher.spec.bits):
            raise ValueError(
                f"store k/bits {(store.k, store.bits)} != sketcher "
                f"{(sketcher.cfg.k, sketcher.spec.bits)}")
        self.store = store
        self.band_spec = store.band_spec
        self._coder = QueryCoder(sketcher)
        self.quality = None       # obs.quality.QualityMonitors, if attached

    # -- mutation ------------------------------------------------------------
    @property
    def generation(self) -> int:
        """Monotone mutation counter (result-cache invalidation key)."""
        return self.store.generation

    @property
    def n(self) -> int:
        """Live (non-tombstoned) rows."""
        return self.store.n_live

    def add(self, x, ids=None) -> np.ndarray:
        """Encode vectors x float [m, D] and append (O(batch) donated
        tail write, never O(corpus)); returns external ids int64 [m].
        Encoding runs through the shared ``repro.encode`` encoder — the
        same numerics as queries and ``ingest``."""
        return self.store.add_codes(self.encoder.encode_codes(x), ids=ids)

    def add_codes(self, codes, ids=None) -> np.ndarray:
        """Append pre-encoded int codes [m, k]; returns external ids
        int64 [m] (see ``SegmentLogStore.add_codes`` for id rules)."""
        return self.store.add_codes(codes, ids=ids)

    def add_words(self, words, ids=None) -> np.ndarray:
        """Append already-packed uint32 rows [m, W] (fused-ingest path);
        returns external ids int64 [m]."""
        return self.store.add_words(words, ids=ids)

    @property
    def encoder(self):
        """The shared ``repro.encode.StreamingEncoder`` behind the query
        coder — also the bulk-ingest encoder (one R cache, one seed)."""
        return self._coder._encoder

    def ingest(self, x, ids=None, *, chunk_rows: int = 2048,
               impl: str = "auto") -> np.ndarray:
        """Bulk-load raw vectors (dense [m, D] or ``encode.CsrMatrix``)
        through the fused project→code→pack pipeline straight into the
        segment log — no [m, k] f32/int32 intermediates, O(batch) tail
        writes; returns the external ids int64 [m]."""
        from repro.encode.pipeline import IngestPipeline
        return IngestPipeline(self.encoder, self.store,
                              chunk_rows=chunk_rows, impl=impl).ingest(
                                  x, ids=ids)

    def delete(self, ids, strict: bool = True) -> int:
        """Tombstone external ids (1-bit mask write, zero recompiles);
        returns rows killed. Unknown ids raise iff ``strict``."""
        return self.store.delete(ids, strict=strict)

    def upsert(self, ids, x) -> np.ndarray:
        """Replace-or-insert vectors x float [m, D] under stable
        external ids int [m]; returns the ids (same shared-encoder
        numerics as ``add``/``ingest``/queries)."""
        return self.store.upsert_codes(ids, self.encoder.encode_codes(x))

    def upsert_codes(self, ids, codes) -> np.ndarray:
        """Replace-or-insert pre-encoded int codes [m, k] under stable
        external ids int [m]; returns the ids."""
        return self.store.upsert_codes(ids, codes)

    def compact(self, policy: CompactionPolicy = CompactionPolicy()) -> dict:
        """Size-tiered compaction (drops tombstones, preserves result
        order bit-exactly); returns the compaction report dict."""
        return compact(self.store, policy)

    # -- durability ----------------------------------------------------------
    def save(self, directory: str, step: int, keep: int = 3) -> str:
        """Atomic snapshot of the store under ``directory`` at ``step``
        (keeping ``keep`` newest); returns the snapshot path."""
        return save_index(self.store, directory, step, keep=keep)

    @classmethod
    def restore(cls, sketcher: CodedRandomProjection, directory: str,
                step: int = None) -> "MutableAnnEngine":
        """Engine over a restored store (latest snapshot, or ``step``)."""
        return cls(sketcher, store=restore_index(directory, step))

    # -- search --------------------------------------------------------------
    @property
    def rank_tables(self) -> RankTables:
        """LUT scoring tables for scored search, built lazily from the
        sketcher's (scheme, k) on first use (pass ``rank_tables`` to
        ``__init__`` to override, e.g. for bf16-quantized tables)."""
        if self._rank_tables is None:
            self._rank_tables = build_rank_tables(self.sketcher)
        return self._rank_tables

    def encode_queries(self, x, impl: str = "auto"):
        """x float [Q, D] -> int32 codes [Q, k] (fused proj+code)."""
        return self._coder.encode(x, impl=impl)

    # -- quality audit hooks -------------------------------------------------
    def attach_quality(self, monitors) -> "MutableAnnEngine":
        """Attach an ``obs.quality.QualityMonitors`` bundle: every search
        gets a budgeted chance (its ``sample_rate``) of feeding one
        query-candidate batch to the collision monitor, and the bundle's
        shadow reservoir subscribes to the store's delete events so its
        ground truth stays tombstone-aware. Returns self."""
        self.quality = monitors
        self.store.add_listener(monitors.on_store_event)
        return self

    def codes_for_ids(self, ids):
        """int32 codes [m, k] of live *external* ids (the small per-id
        gather the quality audit re-scores against)."""
        return self.store.take_codes(ids)

    def search(self, queries, top_k: int = 10, *, mode: str = "exact",
               min_bands: int = 1, n_probes: int = 0, chunk_q: int = 256,
               impl: str = "auto", scored: bool = False,
               rerank_m: int = 0, fused: bool = True,
               table_dtype: str = "auto"):
        """queries float [Q, D] -> (ids int32 [Q, top_k], rho_hat
        float32 [Q, top_k]); ids are external item ids, -1 marks empty
        slots. ``scored=True`` LUT-scores each segment's coarse top-m
        (m = ``rerank_m``, 0 = auto) — single-pass fused masked kernel
        by default, two-stage rerank with ``fused=False`` — and returns
        rho_hat calibrated from the non-linear scores. ``table_dtype``
        picks the query-table storage (see ``SearchConfig``)."""
        cfg = SearchConfig(top_k=top_k, mode=mode, min_bands=min_bands,
                           n_probes=n_probes, chunk_q=chunk_q, impl=impl,
                           scored=scored, rerank_m=rerank_m, fused=fused,
                           table_dtype=table_dtype)
        return self.search_codes(self.encode_queries(queries, impl=impl),
                                 cfg)

    def search_codes(self, q_codes, cfg: SearchConfig):
        """Search pre-encoded queries [Q, k] across all segments."""
        if cfg.mode not in ("exact", "lsh"):
            raise ValueError(f"unknown mode {cfg.mode!r}")
        if cfg.mode == "lsh" and self.band_spec is None:
            raise ValueError("store built without band_spec: lsh "
                             "retrieval unavailable")
        if cfg.table_dtype == "int8" and not cfg.use_fused():
            raise ValueError("table_dtype='int8' requires the fused "
                             "scored path (scored=True, fused=True, "
                             "mode='exact')")
        q = q_codes.shape[0]
        if q == 0 or self.store.n_live == 0:
            return (jnp.full((q, cfg.top_k), -1, jnp.int32),
                    jnp.full((q, cfg.top_k), -1.0, jnp.float32))
        t0 = _time.perf_counter()
        out = run_chunked(q_codes, cfg, self._search_chunk)
        default_flight_recorder().record(
            "index.search", t0, _time.perf_counter(), batch=int(q),
            generation=self.generation, outcome=cfg.mode,
            synced=deep_tracing_active())
        if self.quality is not None:
            self.quality.observe_search(q_codes, out[0], self.codes_for_ids)
        return out

    def _search_chunk(self, q_codes, cfg: SearchConfig):
        """One padded query chunk across all segments: per-segment
        (masked) top-k or scored two-stage, then the cross-segment
        merge. Returns (ids int32 [c, top_k], rho float32 [c, top_k])."""
        k = self.sketcher.cfg.k
        bits = self.store.bits
        q_words = _ops.pack_codes(q_codes, bits, impl=cfg.impl)
        qh = (probe_hashes(q_codes, self.band_spec, cfg.n_probes)
              if cfg.mode == "lsh" else None)
        # the per-query LUTs are segment-independent: build once per
        # chunk, not once per segment (this loop runs eagerly)
        fused = cfg.scored and cfg.use_fused()
        q_tables = scales = None
        if fused:
            q_tables, scales = resolve_query_tables(
                self.rank_tables, q_codes, cfg.table_dtype)
        elif cfg.scored:
            q_tables = self.rank_tables.query_tables(q_codes)
        vals_l, ids_l = [], []
        # the span syncs below only block under a *deep* tracer
        # (profiling); with no tracer, or a shallow per-request
        # RequestTrace, the eager segment loop keeps its async pipeline
        for i, seg in enumerate(self.store.segments()):
            if seg.live == 0:
                continue
            if fused:
                m = cfg.resolve_m(seg.cap)
                with span("search.fused", segment=i, rows=seg.cap,
                          m=m, top_k=cfg.top_k) as sp:
                    vals, rows = _ops.fused_scored_topk_masked(
                        q_words, q_tables, seg.words, seg.valid_dev(),
                        bits, k, m, cfg.top_k, scales=scales,
                        impl=cfg.impl)
                    sp.sync(vals)
                ext = jnp.take(seg.ids_dev(),
                               jnp.clip(rows, 0, seg.cap - 1), axis=0)
                ids_l.append(jnp.where(rows < 0, -1, ext))
                vals_l.append(vals)
                continue
            top = cfg.resolve_m(seg.cap) if cfg.scored else cfg.top_k
            with span("search.coarse", mode=cfg.mode, segment=i,
                      rows=seg.cap) as sp:
                if cfg.mode == "exact":
                    vals, rows = _ops.packed_topk_masked(
                        q_words, seg.words, seg.valid_dev(), bits, k,
                        top, impl=cfg.impl)
                else:
                    counts = _ops.packed_collision_counts(
                        q_words, seg.words, bits, k, impl=cfg.impl)
                    coarse = _coarse_band_scores(qh, seg.hashes)
                    live = _packing.unpack_bitmask(seg.valid_dev(), seg.cap)
                    counts = jnp.where(live[None, :]
                                       & (coarse >= cfg.min_bands),
                                       counts, -1)
                    vals, rows = _ref.topk_stable_ref(counts, top)
                sp.sync(rows)
            if cfg.scored:
                with span("search.rerank", segment=i,
                          top_k=cfg.top_k) as sp:
                    rows, vals = lut_rerank_stage(
                        self.rank_tables, q_codes, rows, seg.words,
                        cfg.top_k, impl=cfg.impl, q_tables=q_tables)
                    sp.sync(vals)
            ext = jnp.take(seg.ids_dev(),
                           jnp.clip(rows, 0, seg.cap - 1), axis=0)
            ids_l.append(jnp.where(rows < 0, -1, ext))
            vals_l.append(vals)
        vals, ids = merge_topk(vals_l, ids_l, cfg.top_k)
        if cfg.scored:
            return ids, rho_scored(self.rank_tables, ids, vals)
        return ids, self._rho(vals)

    def _rho(self, counts):
        """Collision counts -> rho_hat (paper estimator); empty slots
        (count < 0) surface as rho = -1."""
        rho = self.sketcher._estimator(counts / self.sketcher.cfg.k)
        return jnp.where(counts < 0, -1.0, rho)
