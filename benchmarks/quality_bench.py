"""Quality-monitoring benchmark: what statistical health costs, and
whether its estimates are honest.

Three measurements, written to ``BENCH_quality.json`` (repo root):

  * **overhead** — end-to-end QPS of the exact-search serving hot path
    (submit→flush, cache disabled) with the quality bundle attached at
    its default sampling rate vs. without it. Acceptance: <= 3% QPS
    overhead — a health layer that taxes the hot path gets turned off.
  * **shadow** — the reservoir-restricted shadow-recall protocol
    (``repro.obs.shadow``) on a 131k-row corpus: the *sampled* monitor
    estimate vs. the exhaustively-measured recall of the same protocol
    over every query (the quantity the estimator is unbiased for).
    Acceptance: the exhaustive truth falls inside the sampled
    estimate's Wilson 95% interval. The engine's full-corpus recall@10
    vs. exact cosine is reported alongside for context — the
    reservoir-restricted number estimates ranking fidelity on a
    uniform corpus sample, not full-corpus recall (see ARCHITECTURE's
    statistical-observability section).
  * **drift** — detection latency: a Page-Hinkley detector over the
    per-batch collision fraction of a synthetic fixed-rho stream;
    batches-to-fire after an injected rho shift, with the false-alarm
    count over the stationary prefix. Acceptance: fires after the
    shift, zero false alarms while stationary.
"""
import json
import os
import sys
import time

import numpy as np

if __package__ in (None, ""):      # direct `python benchmarks/quality_bench.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

from benchmarks._util import write_csv
from repro.core.sketch import CodedRandomProjection, SketchConfig
from repro.index import MutableAnnEngine
from repro.obs import (CollisionMonitor, DriftMonitor, MetricsRegistry,
                       PageHinkley, QualityConfig, RecallMonitor,
                       ShadowReservoir, no_tracing, synthetic_code_pairs)
from repro.serve import AnnService, AnnServiceConfig

K = 256
SCHEME, W = "2bit", 0.75


def _interleaved_qps(svc_a, svc_b, queries, repeat):
    """Median submit-all+flush QPS for two services, rounds interleaved
    A/B/A/B so machine drift cancels (flush's host transfer = sync)."""
    nq = queries.shape[0]

    def _round(svc):
        t0 = time.perf_counter()
        for x in queries:
            svc.submit(x)
        svc.flush()
        return time.perf_counter() - t0

    for svc in (svc_a, svc_b):           # warm every jit + bucket
        _round(svc)
        _round(svc)
    ts_a, ts_b = [], []
    for _ in range(repeat):
        ts_a.append(_round(svc_a))
        ts_b.append(_round(svc_b))
    # best-of-N: the minimum is the run least disturbed by machine
    # noise, so a *systematic* per-query overhead survives while jitter
    # (which only ever adds time) cancels
    return nq / float(np.min(ts_a)), nq / float(np.min(ts_b))


def _crp(d):
    return CodedRandomProjection(SketchConfig(k=K, scheme=SCHEME, w=W), d)


def _overhead(d, n, nq, repeat, rng):
    """Serving QPS with the quality bundle off vs. on (default rate)."""
    corpus = rng.standard_normal((n, d)).astype(np.float32)
    queries = corpus[:nq] + 0.1 * rng.standard_normal(
        (nq, d)).astype(np.float32)
    cfg = AnnServiceConfig(top_k=10, mode="exact", cache_size=0,
                           buckets=(nq,))
    qcfg = QualityConfig()            # default sampling rate (~1%)
    with no_tracing():
        eng_off = MutableAnnEngine(_crp(d), tail_rows=4096)
        svc_off = AnnService(eng_off, cfg,
                             registry=MetricsRegistry(enabled=True))
        svc_off.bulk_load(corpus)
        eng_on = MutableAnnEngine(_crp(d), tail_rows=4096)
        svc_on = AnnService(eng_on, cfg,
                            registry=MetricsRegistry(enabled=True),
                            quality=qcfg)
        svc_on.bulk_load(corpus)
        qps_off, qps_on = _interleaved_qps(svc_off, svc_on, queries, repeat)
    return {"qps_quality_off": qps_off, "qps_quality_on": qps_on,
            "overhead_frac": 1.0 - qps_on / qps_off,
            "sample_rate": qcfg.sample_rate,
            "sampled_events": int(
                svc_on.registry.counter("quality.sampled").value)}


def _shadow(d, n, nq, reservoir_rows, rng):
    """Sampled shadow-recall estimate vs. exhaustive protocol truth on
    an ``n``-row corpus, plus full-corpus engine recall for context."""
    # unit-norm rows: the coded quantizer's cell widths (w) are
    # calibrated against unit-variance projections, and cosine truth
    # only makes the rho audit meaningful on the unit sphere
    corpus = rng.standard_normal((n, d)).astype(np.float32)
    corpus /= np.linalg.norm(corpus, axis=1, keepdims=True)
    crp = _crp(d)
    eng = MutableAnnEngine(crp, tail_rows=4096)
    ext_ids = eng.ingest(corpus, chunk_rows=8192)
    row_of = {int(e): i for i, e in enumerate(ext_ids)}
    queries = corpus[rng.integers(0, n, nq)] + 0.25 / np.sqrt(
        d) * rng.standard_normal((nq, d)).astype(np.float32)
    queries /= np.linalg.norm(queries, axis=1, keepdims=True)

    res = ShadowReservoir(cap=reservoir_rows, seed=0,
                          registry=MetricsRegistry(enabled=True))
    res.offer(np.arange(n), corpus)      # uniform sample of the corpus
    rows = res.rows()
    codes = np.asarray(crp.encode(rows), np.int32)
    q_codes = np.asarray(crp.encode(queries), np.int32)

    # exhaustive truth of the reservoir-restricted protocol: every query
    norms = np.maximum(np.linalg.norm(rows, axis=1), 1e-30)
    hits_all = 0
    for qi in range(nq):
        qv = queries[qi]
        cos = (rows @ (qv / np.linalg.norm(qv))) / norms
        gt = np.argsort(-cos, kind="stable")[:10]
        frac = np.mean(codes == q_codes[qi][None, :], axis=1)
        got = np.argsort(-frac, kind="stable")[:10]
        hits_all += len(set(gt.tolist()) & set(got.tolist()))
    truth = hits_all / (10 * nq)

    # the monitor's sampled estimate: a random half of the queries
    mon = RecallMonitor(res, top_k=10,
                        registry=MetricsRegistry(enabled=True))
    for qi in rng.choice(nq, size=nq // 2, replace=False):
        mon.observe_query(queries[qi], crp.encode, crp._estimator,
                          q_codes=q_codes[qi])
    rep = mon.report()

    # context: the serving engine's full-corpus recall vs. exact cosine
    n_eval = min(64, nq)
    ids, _ = eng.search(queries[:n_eval], 10, mode="exact", chunk_q=64)
    ids = np.asarray(ids)
    cnorm = np.maximum(np.linalg.norm(corpus, axis=1), 1e-30)
    full_hits = 0
    for qi in range(n_eval):
        qv = queries[qi]
        cos = (corpus @ (qv / np.linalg.norm(qv))) / cnorm
        gt = set(np.argsort(-cos, kind="stable")[:10].tolist())
        got = {row_of[int(i)] for i in ids[qi] if int(i) >= 0}
        full_hits += len(gt & got)
    return {"corpus": n, "reservoir_rows": len(res), "queries": nq,
            "queries_sampled": nq // 2,
            "true_recall_protocol": truth,
            "shadow_recall": rep["recall"],
            "wilson_lo": rep["recall_lo"], "wilson_hi": rep["recall_hi"],
            "within_interval": bool(
                rep["recall_lo"] <= truth <= rep["recall_hi"]),
            "rho_err_mean": rep["rho_err_mean"],
            "rho_err_std": rep["rho_err_std"],
            "rho_std_theory": rep["rho_std_theory"],
            "full_corpus_recall": full_hits / (10 * n_eval)}


def _drift(rho0=0.5, rho1=0.65, batches=150, batch_pairs=64):
    """Batches-to-fire after an injected rho shift; false alarms on the
    stationary prefix (per-batch collision fraction under Page-Hinkley)."""
    from repro.core.schemes import CodeSpec
    spec = CodeSpec(SCHEME, W)
    mon = CollisionMonitor(spec, K, registry=MetricsRegistry(enabled=True))
    dm = DriftMonitor(registry=MetricsRegistry(enabled=True))
    dm.watch("collision_p", PageHinkley(delta=0.005, threshold=0.1,
                                        min_samples=10))
    false_alarms = 0
    for i in range(batches):
        st = mon.observe_pairs(*synthetic_code_pairs(
            spec, K, rho0, batch_pairs, seed=1000 + i))
        false_alarms += dm.update("collision_p", st["p_batch"])
    fired_at = None
    for i in range(100):
        st = mon.observe_pairs(*synthetic_code_pairs(
            spec, K, rho1, batch_pairs, seed=5000 + i))
        if dm.update("collision_p", st["p_batch"]):
            fired_at = i + 1
            break
    return {"rho0": rho0, "rho1": rho1,
            "stationary_batches": batches, "batch_pairs": batch_pairs,
            "false_alarms": false_alarms,
            "batches_to_fire": fired_at}


def _bench(quick: bool):
    rng = np.random.default_rng(0)
    overhead = _overhead(d=64, n=8192 if quick else 65536, nq=64,
                         repeat=5 if quick else 9, rng=rng)
    shadow = _shadow(d=64, n=16384 if quick else 131072,
                     nq=128 if quick else 256,
                     reservoir_rows=2048 if quick else 4096, rng=rng)
    drift = _drift(batches=60 if quick else 150)
    ok = (overhead["overhead_frac"] <= 0.03
          and shadow["within_interval"]
          and drift["false_alarms"] == 0
          and drift["batches_to_fire"] is not None)
    return {"overhead": overhead, "shadow": shadow, "drift": drift,
            "k": K, "scheme": SCHEME, "acceptance_pass": ok,
            "timing": "best-of-N interleaved, device-synced flush"}


def _rows(r):
    o, s, d = r["overhead"], r["shadow"], r["drift"]
    return [
        ("quality_serve_on", 1e6 / o["qps_quality_on"],
         f"qps={o['qps_quality_on']:.0f} "
         f"overhead={100 * o['overhead_frac']:.2f}%"),
        ("quality_serve_off", 1e6 / o["qps_quality_off"],
         f"qps={o['qps_quality_off']:.0f}"),
        ("quality_shadow_recall", 0.0,
         f"est={s['shadow_recall']:.3f} "
         f"truth={s['true_recall_protocol']:.3f} "
         f"wilson=[{s['wilson_lo']:.3f},{s['wilson_hi']:.3f}] "
         f"in={s['within_interval']}"),
        ("quality_drift_latency", 0.0,
         f"fired_at={d['batches_to_fire']} "
         f"false_alarms={d['false_alarms']}"),
    ]


def run(quick: bool = True):
    """run.py contract: (name, us_per_call, derived) rows."""
    r = _bench(quick)
    rows = _rows(r)
    write_csv("quality_bench", ["name", "us_per_call", "derived"], rows)
    return rows


def main():
    quick = "--quick" in sys.argv[1:]
    r = _bench(quick)
    write_csv("quality_bench", ["name", "us_per_call", "derived"], _rows(r))
    if not quick:
        with open(os.path.join(_ROOT, "BENCH_quality.json"), "w") as f:
            json.dump(r, f, indent=1)
    print("BENCH " + json.dumps(r))
    o, s, d = r["overhead"], r["shadow"], r["drift"]
    print(f"\noverhead: {100 * o['overhead_frac']:.2f}% at sample_rate="
          f"{o['sample_rate']} ({o['qps_quality_on']:.0f} vs "
          f"{o['qps_quality_off']:.0f} qps)")
    print(f"shadow: est {s['shadow_recall']:.3f} in "
          f"[{s['wilson_lo']:.3f}, {s['wilson_hi']:.3f}] vs truth "
          f"{s['true_recall_protocol']:.3f} (full-corpus "
          f"{s['full_corpus_recall']:.3f})")
    print(f"drift: fired {d['batches_to_fire']} batches after shift, "
          f"{d['false_alarms']} false alarms in "
          f"{d['stationary_batches']} stationary batches")
    print("acceptance: " + ("PASS" if r["acceptance_pass"] else "FAIL"))
    if not r["acceptance_pass"]:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
