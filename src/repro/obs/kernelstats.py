"""Per-kernel-family dispatch stats + modeled FLOPs/HBM-bytes roofline.

``kernels/ops.py`` is the single chokepoint every Pallas kernel (and its
jnp oracle) dispatches through; this module is its flight recorder. Each
dispatch records, per kernel family: invocation count, how many of those
were under a jit trace (a traced call compiles into an executable and
then reruns without re-dispatching — the counters are *dispatch* counts,
not device launches), output-element counts, and analytically modeled
FLOPs and HBM bytes from the call's static shapes — the same
bytes-per-row accounting ``benchmarks/encode_bench.py`` used to do by
hand, now computed once at the dispatch layer.

``roofline_table`` folds the accumulated totals against a hardware model
(``repro.launch.roofline.HW``) into a live roofline: arithmetic
intensity, modeled compute/memory time, and which wall each family sits
against. ``tests/test_obs.py`` cross-checks the byte models against the
actual array shapes the ``kernels/ref.py`` oracles consume and produce.
"""
from __future__ import annotations

from repro.obs.registry import default_registry

__all__ = ["KernelStats", "model", "record", "get_kernel_stats",
           "set_kernel_stats", "roofline_table", "MODELS"]


def _mask_bytes(n: int) -> int:
    """Bytes of a packed row-validity bitmask over ``n`` rows."""
    return 4 * ((n + 31) // 32)


def _m_coded_project(m, d, k, **_):
    return m * k, 2 * m * d * k, 4 * (m * d + d * k + m * k)


def _m_encode_fused(m, d, k, w, **_):
    return m * k, 2 * m * d * k, 4 * (m * d + d * k + m * w)


def _m_code_pack(m, k, w, **_):
    return m * k, m * k, 4 * (m * k + m * w)


def _m_pack_codes(m, k, w, **_):
    return m * k, m * k, 4 * (m * k + m * w)


def _m_collision_counts(q, n, k, **_):
    return q * n, q * n * k, 4 * (q * k + n * k + q * n)


def _m_packed_collision_counts(q, n, w, **_):
    # XOR + popcount-fold + accumulate per word pair ~ 3 word ops
    return q * n, 3 * q * n * w, 4 * (q * w + n * w + q * n)


def _m_packed_topk(q, n, w, top_k, **_):
    return q * n, 3 * q * n * w, 4 * (q * w + n * w + 2 * q * top_k)


def _m_packed_topk_masked(q, n, w, top_k, **_):
    e, f, b = _m_packed_topk(q, n, w, top_k)
    return e, f, b + _mask_bytes(n)


def _m_packed_lut_topk(q, n, w, t, k, top_k, **_):
    # one table lookup + add per code field
    return q * n, 2 * q * n * k, 4 * (q * t + n * w + 2 * q * top_k)


def _m_packed_lut_topk_masked(q, n, w, t, k, top_k, **_):
    e, f, b = _m_packed_lut_topk(q, n, w, t, k, top_k)
    return e, f, b + _mask_bytes(n)


def _m_packed_lut_rerank(q, c, w, t, k, top_k, **_):
    return (q * c, 2 * q * c * k,
            4 * (q * t + q * c * w + 2 * q * top_k) + q * c)


def _m_fused_scored_topk(q, n, w, t, k, top_k, **_):
    # two corpus sweeps: counts twice (~3 word ops each), the k+1-bin
    # exceedance histogram in sweep A, LUT select+add per field in B
    return (q * top_k, q * n * (6 * w + 3 * k + 1),
            4 * (q * w + q * t + 2 * n * w + 2 * q * top_k))


def _m_fused_scored_topk_masked(q, n, w, t, k, top_k, **_):
    e, f, b = _m_fused_scored_topk(q, n, w, t, k, top_k)
    return e, f, b + 2 * _mask_bytes(n)


def _m_packed_linear_fwd(c, n, w, t, k, **_):
    return c * n, 2 * c * n * k, 4 * (c * t + n * w + c * n)


def _m_packed_linear_fwd_masked(c, n, w, t, k, **_):
    e, f, b = _m_packed_linear_fwd(c, n, w, t, k)
    return e, f, b + _mask_bytes(n)


def _m_packed_linear_bwd(c, n, w, t, k, **_):
    return c * n, 2 * c * n * k, 4 * (c * n + n * w + c * t)


def _m_packed_linear_bwd_masked(c, n, w, t, k, **_):
    e, f, b = _m_packed_linear_bwd(c, n, w, t, k)
    return e, f, b + _mask_bytes(n)


# family -> fn(**dims) -> (elements, flops, hbm_bytes); dims are the
# static shape parameters ops.py extracts at dispatch
MODELS = {
    "coded_project": _m_coded_project,
    "encode_fused": _m_encode_fused,
    "code_pack": _m_code_pack,
    "pack_codes": _m_pack_codes,
    "collision_counts": _m_collision_counts,
    "packed_collision_counts": _m_packed_collision_counts,
    "packed_topk": _m_packed_topk,
    "packed_topk_masked": _m_packed_topk_masked,
    "packed_lut_topk": _m_packed_lut_topk,
    "packed_lut_topk_masked": _m_packed_lut_topk_masked,
    "packed_lut_rerank": _m_packed_lut_rerank,
    "fused_scored_topk": _m_fused_scored_topk,
    "fused_scored_topk_masked": _m_fused_scored_topk_masked,
    "packed_linear_fwd": _m_packed_linear_fwd,
    "packed_linear_fwd_masked": _m_packed_linear_fwd_masked,
    "packed_linear_bwd": _m_packed_linear_bwd,
    "packed_linear_bwd_masked": _m_packed_linear_bwd_masked,
}


def model(family: str, **dims):
    """(elements, flops, hbm_bytes) modeled for one dispatch of
    ``family`` at the given static dims; KeyError on unknown family."""
    return MODELS[family](**dims)


class KernelStats:
    """Accumulated per-family dispatch totals (a plain host dict)."""

    __slots__ = ("families",)

    def __init__(self):
        self.families: dict[str, dict] = {}

    def record(self, family: str, traced: bool = False, **dims):
        """Fold one dispatch of ``family`` at ``dims`` into the totals."""
        elements, flops, hbm = model(family, **dims)
        f = self.families.get(family)
        if f is None:
            f = self.families[family] = {
                "calls": 0, "traced_calls": 0, "elements": 0,
                "flops": 0, "hbm_bytes": 0}
        f["calls"] += 1
        f["traced_calls"] += 1 if traced else 0
        f["elements"] += elements
        f["flops"] += flops
        f["hbm_bytes"] += hbm

    def reset(self):
        """Drop all accumulated totals."""
        self.families.clear()

    def snapshot(self) -> dict:
        """Copy of the per-family totals."""
        return {k: dict(v) for k, v in self.families.items()}

    def roofline_table(self, hw=None) -> dict:
        """Per-family roofline terms against a hardware model.

        Adds to each family's totals: arithmetic ``intensity``
        (FLOPs/byte), modeled ``t_compute_s`` / ``t_memory_s``, the
        binding wall (``bound``), the modeled wall time ``t_model_s``
        (max of the two) and modeled ``elements_per_s`` at that wall.
        ``hw`` defaults to ``repro.launch.roofline.HW()`` (TPU v5e).
        """
        if hw is None:
            from repro.launch.roofline import HW
            hw = HW()
        out = {}
        for fam, f in self.families.items():
            t_c = f["flops"] / hw.peak_flops
            t_m = f["hbm_bytes"] / hw.hbm_bw
            t = max(t_c, t_m)
            out[fam] = dict(
                f, intensity=f["flops"] / max(f["hbm_bytes"], 1),
                t_compute_s=t_c, t_memory_s=t_m, t_model_s=t,
                bound="compute" if t_c >= t_m else "memory",
                elements_per_s=f["elements"] / t if t else 0.0)
        return out


_DEFAULT = KernelStats()


def get_kernel_stats() -> KernelStats:
    """The process-global kernel-stat accumulator."""
    return _DEFAULT


def set_kernel_stats(ks: KernelStats) -> KernelStats:
    """Swap the process-global accumulator; returns the previous one."""
    global _DEFAULT
    prev = _DEFAULT
    _DEFAULT = ks
    return prev


def record(family: str, traced: bool = False, **dims):
    """Record one dispatch into the global accumulator — the hook
    ``kernels/ops.py`` calls — and append a point event to the flight
    recorder (so the per-request story includes which kernels fired and
    in what order). No-op while the default metrics registry is
    disabled (the one switch that silences all of repro.obs)."""
    if default_registry().enabled:
        _DEFAULT.record(family, traced=traced, **dims)
        _flight().record_kernel(family, traced)


def _flight():
    # late-bound so a set_flight_recorder swap is always respected;
    # imported lazily to keep module import order flexible
    from repro.obs.events import default_flight_recorder
    global _flight
    _flight = default_flight_recorder
    return default_flight_recorder()


def roofline_table(hw=None) -> dict:
    """Roofline view of the global accumulator (see the method)."""
    return _DEFAULT.roofline_table(hw)
