"""Cross-run bench history: append headline numbers to BENCH_history.jsonl.

Every ``benchmarks/run.py`` target emits ``(name, us_per_call, derived)``
rows; this module turns that transient CSV into a durable trajectory.
``append_history`` writes ONE JSON line per module run — timestamp, git
revision, quick/full flag, and the ``us_per_call`` of every row — to
``BENCH_history.jsonl`` at the repo root. The file is append-only and
line-oriented (concurrent runs interleave whole lines, partial tails
are skipped on read), so the history survives crashes and merges
trivially in CI artifact uploads.

``scripts/check_perf.py`` reads the per-metric series back (via
``load_history``/``series``) and runs the ``repro.obs.drift`` CUSUM
change-point check over them — the empty bench trajectory becomes a
regression gate.
"""
import json
import os
import subprocess
import time

HISTORY_FILE = "BENCH_history.jsonl"


def _git_rev(root: str) -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=root,
            capture_output=True, text=True, timeout=10)
        return out.stdout.strip() if out.returncode == 0 else ""
    except Exception:
        return ""


def history_path(root: str = None) -> str:
    """The history file path (default: repo root, next to BENCH_*.json)."""
    if root is None:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    return os.path.join(root, HISTORY_FILE)


def append_history(module: str, rows, root: str = None,
                   quick: bool = True, path: str = None) -> str:
    """Append one history line for ``module``'s bench rows.

    ``rows`` is the ``run(quick)`` return — ``(name, us_per_call,
    derived)`` triples; only finite ``us_per_call`` values are kept
    (derived strings stay in the per-run CSVs). Returns the path.
    """
    path = path or history_path(root)
    metrics = {}
    for name, us, _derived in rows:
        try:
            us = float(us)
        except (TypeError, ValueError):
            continue
        if us == us and us not in (float("inf"), float("-inf")):
            metrics[str(name)] = us
    rec = {"ts": time.strftime("%Y-%m-%dT%H:%M:%S", time.gmtime()),
           "git": _git_rev(os.path.dirname(path)),
           "module": module.rsplit(".", 1)[-1],
           "quick": bool(quick),
           "metrics": metrics}
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")
    return path


def load_history(path: str = None, root: str = None) -> list:
    """Every parseable record in the history file, append order.
    Partial/corrupt lines (a crashed writer's tail) are skipped."""
    path = path or history_path(root)
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return out


def series(records: list, metric: str, quick: bool = None) -> list:
    """The per-run values of one metric name, append order. ``quick``
    filters to quick-only / full-only runs (None = both; quick and full
    runs use different problem sizes, so a gate should never mix them)."""
    out = []
    for r in records:
        if quick is not None and bool(r.get("quick")) != quick:
            continue
        v = r.get("metrics", {}).get(metric)
        if v is not None:
            out.append(float(v))
    return out


def metric_names(records: list) -> list:
    """Every metric name seen in the history, first-seen order."""
    seen = {}
    for r in records:
        for name in r.get("metrics", {}):
            seen.setdefault(name, None)
    return list(seen)
