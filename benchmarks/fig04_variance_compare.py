"""Fig 4: V_w vs V_{w,q} over w at fixed rho — h_w dominates for w > 2."""
import numpy as np
import jax.numpy as jnp

from repro.core import variance as V
from benchmarks._util import timed, write_csv

RHOS = [0.0, 0.25, 0.5, 0.75, 0.9, 0.99]


def run(quick: bool = True):
    ws = np.geomspace(0.1, 10.0, 60)
    rho = jnp.asarray(RHOS)

    def grid():
        return [(w, np.asarray(V.variance_factor_uniform(rho, float(w))),
                 np.asarray(V.variance_factor_offset(rho, float(w))))
                for w in ws]

    table, us = timed(grid, repeat=1)
    rows, wins = [], 0
    total = 0
    for w, vw, vq in table:
        for r, a, b in zip(RHOS, vw, vq):
            rows.append([w, r, float(a), float(b)])
            if w > 2:
                total += 1
                wins += a < b
    write_csv("fig04_variance_compare", ["w", "rho", "V_w", "V_wq"], rows)
    return [("fig04_dominance", us,
             f"h_w_beats_h_wq_for_w>2:{wins}/{total}")]
