"""ShardingRules resolution logic (pure logic — no devices needed)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (ShardingRules, make_abstract_mesh,
                                     zero_shard_spec)


@pytest.fixture(scope="module")
def mesh():
    # AbstractMesh: resolution logic without real devices
    return make_abstract_mesh((4, 2), ("data", "model"))


def test_pspec_resolution(mesh):
    r = ShardingRules(mesh)
    assert r.pspec("batch", "seq", "embed") == P("data", None, None)
    assert r.pspec("batch", None, "mlp") == P("data", None, "model")


def test_duplicate_physical_axis_dropped(mesh):
    r = ShardingRules(mesh).with_overrides(seq="model")
    # heads also wants 'model'; second use must drop it
    spec = r.pspec("batch", "seq", "heads")
    assert spec == P("data", "model", None)


def test_ragged_dim_falls_back():
    mesh4 = make_abstract_mesh((2, 4), ("data", "model"))
    r = ShardingRules(mesh4)
    axes = r._divisible_axes((14, 64), ("heads", "head_dim"))  # 14 % 4 != 0
    assert axes == (None, "head_dim")
    axes = r._divisible_axes((16, 64), ("heads", "head_dim"))
    assert axes == ("heads", "head_dim")


def test_dp_expansion_multipod():
    mesh3 = make_abstract_mesh((2, 4, 2), ("pod", "data", "model"))
    r = ShardingRules(mesh3)
    assert r.pspec("batch") == P(("pod", "data"))


def test_zero_shard_spec(mesh):
    r = ShardingRules(mesh)
    # first divisible unsharded dim gets 'data' (4)
    out = zero_shard_spec(r, P(None, "model"), (8, 6))
    assert out == P("data", "model")
    # start=1 skips the stacked-layers dim
    out = zero_shard_spec(r, P(None, None, "model"), (8, 12, 6), start=1)
    assert out == P(None, "data", "model")
    # nothing divisible -> unchanged
    out = zero_shard_spec(r, P(None,), (7,))
    assert out == P(None,)


def test_overrides():
    r = ShardingRules(None).with_overrides(seq_kv="data")
    assert r.mapping["seq_kv"] == "data"
    assert r.shard(jax.numpy.zeros((2, 2)), "batch", "seq") is not None
