"""Optimal bin-width selection (paper Figs. 5, 8).

For a fixed similarity rho each scheme has a variance-minimizing bin
width w*(rho). The paper's headline findings reproduced here:

* h_w: for rho < ~0.56 the optimum w exceeds 6 (so the 1-bit sign code
  suffices); for high rho the optimum w is small (< 1).
* h_{w,q}: the optimum w stays ~1-2 everywhere (so it always needs more
  bits than h_w).
* h_{w,2}: optimum w is large for rho in ~[0.2, 0.62] (1 bit suffices
  there) and ~0.75-1 at high rho — the paper's recommended operating
  point.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.variance import variance_factor

__all__ = ["optimal_w", "default_w_grid"]


def default_w_grid(w_min: float = 0.05, w_max: float = 12.0, n: int = 240):
    return np.geomspace(w_min, w_max, n)


def optimal_w(rho, scheme: str, w_grid=None):
    """Grid-minimize V(rho, w) over w for each rho.

    rho: array [R]. Returns (w_star [R], v_star [R]).
    Static-w functions force a Python loop over the grid; each call is
    vectorized over rho so this is cheap.
    """
    if w_grid is None:
        w_grid = default_w_grid()
    rho = jnp.asarray(rho)
    vs = jnp.stack([variance_factor(rho, float(w), scheme) for w in w_grid],
                   axis=-1)  # [R, W]
    idx = jnp.argmin(vs, axis=-1)
    w_star = jnp.asarray(np.asarray(w_grid))[idx]
    v_star = jnp.take_along_axis(vs, idx[..., None], axis=-1)[..., 0]
    return w_star, v_star
