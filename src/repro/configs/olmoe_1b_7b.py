"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE 64e top-8 (no gate renorm). [arXiv:2409.02060; hf]"""
from dataclasses import replace

from repro.models.lm import ModelConfig

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1024,
        vocab_size=50304, rope_theta=10000.0, qk_norm=True,
        n_experts=64, n_experts_per_token=8, moe_d_ff=1024,
        renorm_gates=False, tie_embeddings=False, norm_eps=1e-5,
    )


def smoke_config() -> ModelConfig:
    # capacity_factor=8 -> no token dropping, so prefill/decode agree exactly
    return replace(config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                   d_ff=64, vocab_size=256, n_experts=8,
                   n_experts_per_token=2, moe_d_ff=64, capacity_factor=8.0,
                   loss_chunk=16, chunk_kv=32, chunk_q=16)
