"""Asymptotic estimator variances (paper Thms 2-4, Eq. 20).

For every scheme the rho-estimator inverts the monotone collision curve,
so by the delta method  Var(rho_hat) = V / k + O(1/k^2)  with
V = P (1 - P) / (dP/drho)^2.  We implement the analytic dP/drho from the
paper's appendices and expose both V and dP/drho (the latter is verified
against numerical differentiation of ``probabilities`` in the tests).
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.core.probabilities import (
    ZMAX, phi, collision_prob_2bit, collision_prob_offset,
    collision_prob_sign, collision_prob_uniform, _clip_rho,
)

__all__ = [
    "dP_drho_uniform", "dP_drho_offset", "dP_drho_2bit", "dP_drho_sign",
    "variance_factor_uniform", "variance_factor_offset",
    "variance_factor_2bit", "variance_factor_sign", "variance_factor",
    "dP_drho",
]


def dP_drho_uniform(rho, w: float):
    """Appendix C:  dP_w/drho = (1/(pi s)) sum_i [ e^{-(i+1)^2 w^2/(1+rho)}
    + e^{-i^2 w^2/(1+rho)} - 2 e^{-w^2/(2(1-rho^2))} e^{-i(i+1) w^2/(1+rho)} ].
    """
    w = float(w)
    n_terms = max(2, int(math.ceil(ZMAX / w)) + 1)
    rho = _clip_rho(rho)
    r = rho[..., None]
    s2 = 1.0 - r * r
    i = jnp.arange(n_terms, dtype=rho.dtype)
    w2 = w * w
    term = (jnp.exp(-((i + 1.0) ** 2) * w2 / (1.0 + r))
            + jnp.exp(-(i ** 2) * w2 / (1.0 + r))
            - 2.0 * jnp.exp(-w2 / (2.0 * s2)) * jnp.exp(-i * (i + 1.0) * w2 / (1.0 + r)))
    return jnp.sum(term, axis=-1) / (math.pi * jnp.sqrt(1.0 - rho * rho))


def dP_drho_offset(rho, w: float):
    """From Appendix B:  dP_{w,q}/drho = 2 (1/sqrt(2 pi) - phi(r)) / (r d),
    with r = w/sqrt(d), d = 2(1-rho)."""
    w = float(w)
    rho = _clip_rho(rho)
    d = jnp.maximum(2.0 * (1.0 - rho), 1e-24)
    r = w / jnp.sqrt(d)
    return 2.0 * (1.0 / math.sqrt(2.0 * math.pi) - phi(r)) / (r * d)


def dP_drho_2bit(rho, w: float):
    """Appendix D:  dP_{w,2}/drho = (1/(pi s)) [1 - 2 e^{-w^2/(2 s^2)}
    + 2 e^{-w^2/(1+rho)}],  s = sqrt(1-rho^2)."""
    w = float(w)
    rho = _clip_rho(rho)
    s2 = 1.0 - rho * rho
    w2 = w * w
    bracket = 1.0 - 2.0 * jnp.exp(-w2 / (2.0 * s2)) + 2.0 * jnp.exp(-w2 / (1.0 + rho))
    return bracket / (math.pi * jnp.sqrt(s2))


def dP_drho_sign(rho, w: float = 0.0):
    """dP_1/drho = 1 / (pi sqrt(1 - rho^2))."""
    rho = _clip_rho(rho)
    return 1.0 / (math.pi * jnp.sqrt(1.0 - rho * rho))


def _v(p, dp):
    return p * (1.0 - p) / jnp.maximum(dp * dp, 1e-30)


def variance_factor_uniform(rho, w: float):
    """V_w (Thm 3)."""
    return _v(collision_prob_uniform(rho, w), dP_drho_uniform(rho, w))


def variance_factor_offset(rho, w: float):
    """V_{w,q} (Thm 2, Eq. 13)."""
    return _v(collision_prob_offset(rho, w), dP_drho_offset(rho, w))


def variance_factor_2bit(rho, w: float):
    """V_{w,2} (Thm 4, Eq. 18)."""
    return _v(collision_prob_2bit(rho, w), dP_drho_2bit(rho, w))


def variance_factor_sign(rho, w: float = 0.0):
    """V_1 (Eq. 20) = pi^2 (1-rho^2) P_1 (1-P_1)."""
    rho = _clip_rho(rho)
    p = collision_prob_sign(rho)
    return math.pi ** 2 * (1.0 - rho * rho) * p * (1.0 - p)


_VAR = {
    "uniform": variance_factor_uniform,
    "offset": variance_factor_offset,
    "2bit": variance_factor_2bit,
    "sign": variance_factor_sign,
}
_DP = {
    "uniform": dP_drho_uniform,
    "offset": dP_drho_offset,
    "2bit": dP_drho_2bit,
    "sign": dP_drho_sign,
}


def variance_factor(rho, w: float, scheme: str):
    """Leading variance constant V for Var(rho_hat) ~ V/k."""
    return _VAR[scheme](rho, w)


def dP_drho(rho, w: float, scheme: str):
    return _DP[scheme](rho, w)
