"""Paper section 6: train linear classifiers on coded projections — on the
*packed* codes (repro.learn; the one-hot matrix is never materialized),
with the dense ``expand_codes`` path as a correctness column: both train
the same objective, and their accuracies agree to float rounding.

    PYTHONPATH=src python examples/svm_coded_features.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.sketch import CodedRandomProjection, SketchConfig
from repro.core.svm import SVMConfig, expand_codes, svm_accuracy, train_linear_svm
from repro.learn import LearnConfig, feature_spec_for, fit_words


def make_data(key, n, d, sep=0.35):
    mu = jax.random.normal(key, (d,)) * sep / np.sqrt(d) * 40
    y = jnp.where(jax.random.uniform(jax.random.fold_in(key, 1), (n,)) < 0.5,
                  1.0, -1.0)
    x = jax.random.normal(jax.random.fold_in(key, 2), (n, d)) + y[:, None] * mu
    x = x / jnp.linalg.norm(x, axis=1, keepdims=True)
    return x, y


def main():
    d = 8192
    (x, y) = make_data(jax.random.PRNGKey(0), 1200, d)
    xtr, ytr, xte, yte = x[:600], y[:600], x[600:], y[600:]
    steps = 300

    print(f"{'features':24s} {'k':>4s} {'bytes/row':>9s} "
          f"{'packed acc':>10s} {'dense acc':>9s}")
    for k in (16, 64, 256):
        proj = CodedRandomProjection(SketchConfig(k=k, scheme="sign"), d)
        ztr, zte = proj.project(xtr), proj.project(xte)
        ztr = ztr / jnp.linalg.norm(ztr, axis=1, keepdims=True)
        zte = zte / jnp.linalg.norm(zte, axis=1, keepdims=True)
        w_, b_ = train_linear_svm(ztr, ytr, SVMConfig(c=1.0, steps=steps))
        acc0 = float(svm_accuracy(w_, b_, zte, yte))
        print(f"{'orig projections':24s} {k:4d} {4 * k:9d} "
              f"{'—':>10s} {acc0:9.4f}")

        for scheme, w in (("2bit", 0.75), ("uniform", 0.75), ("sign", 0.0),
                          ("offset", 2.0)):
            crp = CodedRandomProjection(
                SketchConfig(k=k, scheme=scheme, w=max(w, 1e-3)), d)
            ctr, cte = crp.encode(xtr), crp.encode(xte)

            # packed path: code -> pack -> train -> classify; the fused
            # kernels gather/scatter weight tables over the uint32 words
            model = fit_words(crp.pack(ctr), ytr,
                              feature_spec_for(crp.spec, k),
                              LearnConfig(c=1.0, steps=steps))
            acc_p = model.accuracy(crp.pack(cte), np.asarray(yte))

            # dense comparison column: explicit one-hot + dense solver
            ftr = expand_codes(ctr, crp.spec)
            fte = expand_codes(cte, crp.spec)
            w_, b_ = train_linear_svm(ftr, ytr, SVMConfig(c=1.0, steps=steps))
            acc_d = float(svm_accuracy(w_, b_, fte, yte))

            label = f"{scheme} w={w}"
            print(f"{label:24s} {k:4d} {crp.bytes_per_vector():9d} "
                  f"{acc_p:10.4f} {acc_d:9.4f}")
        print()


if __name__ == "__main__":
    main()
