"""Shadow ground truth: reservoir-retained raw rows + exact re-scoring.

Coded search throws the raw f32 rows away by design — that is the whole
point of the paper's b-bit codes — which means a served index cannot
measure its own recall: there is nothing exact left to compare against.
This module keeps a *capped, seeded reservoir* of raw rows at ingest
(Algorithm R, so every live row is retained with equal probability
regardless of arrival order) and re-scores sampled shadow queries by
exact cosine against it, yielding an unbiased online recall@k and a
rho-estimation-error series without retaining the corpus.

The protocol is reservoir-restricted and exactly paired: for one
sampled query, the ground truth is the exact-cosine top-k *among the
reservoir rows*, and the system answer is the coded ranking (collision
fraction, the engines' exact-mode score) over the *same* reservoir rows
encoded under the engine's own sketcher. Restricting both sides to the
reservoir keeps the comparison unbiased for per-candidate ranking
fidelity — each reservoir row is a uniform draw from the live corpus —
while costing O(reservoir) per sampled query instead of O(corpus).
Per-slot hits are Bernoulli trials, summarised with Wilson score
intervals (well-behaved at recall near 1.0, where the Wald interval
collapses); the same sampled pairs feed a Welford series of
``rho_hat - rho_true`` against the estimator's asymptotic std — the
paper's variance claim (Figs 6-7), audited online.

Invariants the reservoir maintains (tested in ``tests/test_quality.py``):

  * at most ``cap`` rows, each with its external id, live at all times;
  * tombstone-aware: ``remove`` (wired to the segment log's delete
    events) drops rows immediately — a deleted row can never appear in
    ground truth; compaction is a no-op (external ids are stable);
  * upsert-aware: re-offering an existing id replaces its row in place;
  * ``version`` bumps on any membership change, so cached encodings
    (``RecallMonitor``) invalidate exactly when needed.
"""
from __future__ import annotations

import math

import numpy as np
import jax.numpy as jnp

from repro.obs.quality import Welford
from repro.obs.registry import MetricsRegistry, default_registry

__all__ = ["wilson_interval", "ShadowReservoir", "RecallMonitor"]


def wilson_interval(successes: int, trials: int, z: float = 1.96):
    """Wilson score interval for a Bernoulli rate: (lo, hi) at the given
    normal quantile (1.96 = 95%). Returns (nan, nan) with no trials."""
    if trials <= 0:
        return (math.nan, math.nan)
    p = successes / trials
    z2 = z * z
    denom = 1.0 + z2 / trials
    center = (p + z2 / (2 * trials)) / denom
    half = (z / denom) * math.sqrt(
        p * (1 - p) / trials + z2 / (4 * trials * trials))
    return (max(0.0, center - half), min(1.0, center + half))


class ShadowReservoir:
    """Seeded Algorithm-R reservoir of raw f32 rows keyed by external id.

    ``offer`` streams candidate rows in (ingest hook), ``remove`` drops
    deleted ids (segment-log listener), ``rows()``/``ids()`` expose the
    current members. Eviction is uniform over everything offered so
    far, so the reservoir is an unbiased sample of the live corpus as
    long as deletes are uncorrelated with reservoir membership — and
    deletes *remove* rows here rather than biasing them.
    """

    def __init__(self, cap: int = 1024, seed: int = 0,
                 registry: MetricsRegistry = None):
        self.cap = int(cap)
        self.rng = np.random.default_rng(seed)
        self.registry = registry if registry is not None \
            else default_registry()
        self.n_seen = 0
        self.version = 0
        self._ids: list[int] = []
        self._rows: list[np.ndarray] = []
        self._slot: dict[int, int] = {}
        self._g_rows = self.registry.gauge("quality.reservoir.rows")
        self._g_seen = self.registry.gauge("quality.reservoir.seen")

    def __len__(self) -> int:
        return len(self._ids)

    def offer(self, ids, rows):
        """Offer a batch of (id, raw f32 row) pairs; each survives with
        probability cap/n_seen (Algorithm R), existing ids are replaced
        in place (upsert semantics, does not consume a slot draw)."""
        ids = np.asarray(ids, np.int64).ravel()
        rows = np.asarray(rows, np.float32)
        changed = False
        for i, ext in enumerate(ids):
            ext = int(ext)
            slot = self._slot.get(ext)
            if slot is not None:                 # upsert: replace in place
                self._rows[slot] = rows[i].copy()
                changed = True
                continue
            self.n_seen += 1
            if len(self._ids) < self.cap:
                self._slot[ext] = len(self._ids)
                self._ids.append(ext)
                self._rows.append(rows[i].copy())
                changed = True
            else:
                j = int(self.rng.integers(self.n_seen))
                if j < self.cap:
                    del self._slot[self._ids[j]]
                    self._slot[ext] = j
                    self._ids[j] = ext
                    self._rows[j] = rows[i].copy()
                    changed = True
        if changed:
            self.version += 1
            self._g_rows.set(len(self._ids))
            self._g_seen.set(self.n_seen)

    def remove(self, ids):
        """Drop any of ``ids`` currently retained (tombstone hook; a
        missing id is a no-op). Swap-with-last keeps storage dense."""
        changed = False
        for ext in np.asarray(ids, np.int64).ravel():
            slot = self._slot.pop(int(ext), None)
            if slot is None:
                continue
            last = len(self._ids) - 1
            if slot != last:
                self._ids[slot] = self._ids[last]
                self._rows[slot] = self._rows[last]
                self._slot[self._ids[slot]] = slot
            self._ids.pop()
            self._rows.pop()
            changed = True
        if changed:
            self.version += 1
            self._g_rows.set(len(self._ids))

    def ids(self) -> np.ndarray:
        """Current member ids, int64 [R]."""
        return np.asarray(self._ids, np.int64)

    def rows(self) -> np.ndarray:
        """Current raw rows, f32 [R, d] (empty [0, 0] when empty)."""
        if not self._rows:
            return np.zeros((0, 0), np.float32)
        return np.stack(self._rows)


class RecallMonitor:
    """Online recall@k + rho-error from shadow queries vs the reservoir.

    ``observe_query`` runs the reservoir-restricted protocol (module
    docstring) for one raw query; hits accumulate as Bernoulli trials
    → ``report()`` gives the running recall estimate with its Wilson
    95% interval, plus Welford moments of ``rho_hat - rho_true`` over
    the ground-truth pairs and the estimator's predicted asymptotic
    std at the observed rho (the Fig 6-7 audit). Reservoir codes are
    cached per reservoir version and re-encoded through the engine's
    own ``encode_fn`` only when membership changes.
    """

    def __init__(self, reservoir: ShadowReservoir, top_k: int = 10,
                 registry: MetricsRegistry = None,
                 name: str = "quality.shadow"):
        self.reservoir = reservoir
        self.top_k = int(top_k)
        self.name = name
        self.registry = registry if registry is not None \
            else default_registry()
        self.successes = 0
        self.trials = 0
        self.queries = 0
        self.rho_err = Welford()
        self._asym_std = Welford()
        self._codes = None
        self._codes_version = -1

    def _reservoir_codes(self, encode_fn) -> np.ndarray:
        """Reservoir rows under the engine's encoder, [R, k] int32,
        cached until the reservoir version moves."""
        if self._codes_version != self.reservoir.version:
            rows = self.reservoir.rows()
            self._codes = np.asarray(encode_fn(jnp.asarray(rows)), np.int32)
            self._codes_version = self.reservoir.version
        return self._codes

    def observe_query(self, q_raw, encode_fn, estimator,
                      q_codes=None):
        """One shadow check: exact-cosine top-k vs coded top-k over the
        reservoir for raw query ``q_raw`` [d]. ``encode_fn(x[m, d]) ->
        codes [m, k]`` is the engine's query encoder; ``estimator`` the
        engine's ``CollisionEstimator`` (rho from collision fraction).
        Returns this query's recall@k, or None if the reservoir is too
        small (< 4k rows) to make the trial meaningful."""
        rows = self.reservoir.rows()
        k = self.top_k
        if rows.shape[0] < 4 * k:
            return None
        q = np.asarray(q_raw, np.float32).ravel()
        codes = self._reservoir_codes(encode_fn)
        if q_codes is None:
            q_codes = np.asarray(
                encode_fn(jnp.asarray(q[None, :])), np.int32)[0]
        else:
            q_codes = np.asarray(q_codes, np.int32).ravel()

        # ground truth: exact cosine over the reservoir
        qn = q / max(float(np.linalg.norm(q)), 1e-30)
        norms = np.maximum(np.linalg.norm(rows, axis=1), 1e-30)
        cos = (rows @ qn) / norms
        gt = np.argsort(-cos, kind="stable")[:k]

        # system answer: coded collision-fraction ranking, same rows
        frac = np.mean(codes == q_codes[None, :], axis=1)
        got = np.argsort(-frac, kind="stable")[:k]

        hits = len(set(gt.tolist()) & set(got.tolist()))
        self.successes += hits
        self.trials += k
        self.queries += 1

        # rho audit over the ground-truth pairs: coded estimate vs the
        # exact cosine, spread vs the estimator's asymptotic std
        rho_true = np.clip(cos[gt], -1.0, 1.0)
        rho_hat = np.asarray(estimator(jnp.asarray(frac[gt],
                                                   jnp.float32)), np.float64)
        err = rho_hat - rho_true
        self.rho_err.push_many(err)
        k_proj = codes.shape[1]
        for r in np.clip(rho_true, 0.0, 0.999):
            self._asym_std.push(float(estimator.asymptotic_std(float(r),
                                                               k_proj)))

        reg = self.registry
        recall = self.successes / self.trials
        lo, hi = wilson_interval(self.successes, self.trials)
        reg.gauge(f"{self.name}.recall").set(recall)
        reg.gauge(f"{self.name}.recall_lo").set(lo)
        reg.gauge(f"{self.name}.recall_hi").set(hi)
        reg.gauge(f"{self.name}.trials").set(self.trials)
        reg.gauge(f"{self.name}.rho_err_mean").set(self.rho_err.mean)
        if self.rho_err.n > 1:
            reg.gauge(f"{self.name}.rho_err_std").set(self.rho_err.std)
            reg.gauge(f"{self.name}.rho_std_theory").set(self._asym_std.mean)
        reg.counter(f"{self.name}.queries").inc()
        return hits / k

    def report(self) -> dict:
        """Running shadow health: recall@k with Wilson 95% bounds,
        trial counts, and the rho-error moments vs theory."""
        lo, hi = wilson_interval(self.successes, self.trials)
        return {
            "top_k": self.top_k,
            "queries": self.queries,
            "trials": self.trials,
            "recall": (self.successes / self.trials
                       if self.trials else math.nan),
            "recall_lo": lo, "recall_hi": hi,
            "reservoir_rows": len(self.reservoir),
            "rho_err_mean": self.rho_err.mean if self.rho_err.n else math.nan,
            "rho_err_std": self.rho_err.std,
            "rho_std_theory": (self._asym_std.mean
                               if self._asym_std.n else math.nan),
        }
