"""Benchmark harness: one module per paper figure/table + system benches.

Prints ``name,us_per_call,derived`` CSV per row (scaffold contract) and
writes detailed tables to benchmarks/out/*.csv.

``--profile`` records every module's run under a ``repro.obs.Tracer``
and writes one Chrome-trace/Perfetto JSON per module next to the BENCH
files (repo root, ``TRACE_<module>.json``) — load at
https://ui.perfetto.dev for the flame view.

Every module's headline ``us_per_call`` numbers are also appended to
``BENCH_history.jsonl`` at the repo root (``benchmarks/history.py``) —
the cross-run trajectory ``scripts/check_perf.py`` regression-gates.
``--no-history`` skips the append (ad-hoc local runs).

``python benchmarks/run.py lint`` runs the docs/docstring lint
(``scripts/check_docs.py``) instead of the benchmarks.
"""
import argparse
import importlib
import os
import sys
import traceback

if __package__ in (None, ""):          # direct `python benchmarks/run.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

MODULES = [
    "benchmarks.fig01_collision",
    "benchmarks.fig02_vwq_factor",
    "benchmarks.fig03_vw_rho0",
    "benchmarks.fig04_variance_compare",
    "benchmarks.fig05_optimal_w",
    "benchmarks.fig06_p2bit",
    "benchmarks.fig07_v2bit",
    "benchmarks.fig09_onebit_ratios",
    "benchmarks.fig11_svm",
    "benchmarks.kernel_bench",
    "benchmarks.grad_compression_bench",
    "benchmarks.ann_bench",
    "benchmarks.encode_bench",
    "benchmarks.ingest_bench",
    "benchmarks.rank_bench",
    "benchmarks.learn_bench",
    "benchmarks.obs_bench",
    "benchmarks.quality_bench",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("cmd", nargs="?", default="bench",
                    choices=("bench", "lint"),
                    help="bench (default) or lint (docs/docstring checks)")
    ap.add_argument("--full", action="store_true", help="bigger sizes")
    ap.add_argument("--only", default="", help="substring filter")
    ap.add_argument("--profile", action="store_true",
                    help="trace each module; write TRACE_<module>.json "
                         "(Perfetto) next to the BENCH files")
    ap.add_argument("--no-history", action="store_true",
                    help="don't append headline numbers to "
                         "BENCH_history.jsonl")
    args = ap.parse_args()
    if args.cmd == "lint":
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        sys.path.insert(0, os.path.join(root, "scripts"))
        import check_docs
        raise SystemExit(check_docs.main())
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    print("name,us_per_call,derived")
    failed = 0
    for modname in MODULES:
        if args.only and args.only not in modname:
            continue
        try:
            mod = importlib.import_module(modname)
            if args.profile:
                from repro.obs import Tracer
                short = modname.rsplit(".", 1)[-1]
                with Tracer() as tr:
                    rows = mod.run(quick=not args.full)
                path = tr.dump(os.path.join(root, f"TRACE_{short}.json"))
                print(f"# trace: {path} ({len(tr.events)} events)",
                      file=sys.stderr, flush=True)
            else:
                rows = mod.run(quick=not args.full)
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}", flush=True)
            if not args.no_history:
                from benchmarks import history as _history
                try:
                    _history.append_history(modname, rows, root,
                                            quick=not args.full)
                except OSError as e:      # read-only checkout etc.
                    print(f"# history append failed: {e}",
                          file=sys.stderr)
        except Exception:
            failed += 1
            print(f"{modname},NaN,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
