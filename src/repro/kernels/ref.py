"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; kernels must match them bit-for-bit (integer
outputs) across the shape/dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import packing as _packing
from repro.core import schemes as _schemes
from repro.core.schemes import CodeSpec

__all__ = ["coded_project_ref", "pack_codes_ref", "collision_counts_ref"]


def coded_project_ref(x, r, spec: CodeSpec, q=None):
    """x [M, D] @ r [D, K] -> int32 codes [M, K] under ``spec``.

    The matmul accumulates in float32 regardless of input dtype (matches
    the kernel's MXU accumulator).
    """
    z = jnp.dot(x, r, preferred_element_type=jnp.float32)
    return _schemes.encode(z, spec, q)


def pack_codes_ref(codes, bits: int):
    """codes int [M, K] -> uint32 words [M, ceil(K/(32/bits))]."""
    return _packing.pack_codes(codes, bits)


def collision_counts_ref(codes_q, codes_db):
    """codes_q [Q, K], codes_db [N, K] -> int32 [Q, N] match counts."""
    eq = (codes_q[:, None, :] == codes_db[None, :, :])
    return jnp.sum(eq, axis=-1).astype(jnp.int32)
