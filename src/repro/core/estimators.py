"""Similarity estimators from empirical collision fractions (paper §3).

The collision probability P(rho; scheme, w) is strictly increasing in rho
for every scheme, so rho_hat = P^{-1}(P_hat). Following the paper we
tabulate P on a dense rho grid and invert by monotone interpolation
("we can tabulate P_w for each rho, for example at a precision of 1e-3").

Also provides the closed-form inversion for the sign scheme and a
batched maximum-likelihood refinement (paper §7 'future work' — included
as a beyond-paper extension) that uses the full contingency table of the
2-bit scheme rather than only the diagonal collision count.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.probabilities import collision_prob, q_region
from repro.core.schemes import CodeSpec
from repro.core.variance import variance_factor

__all__ = ["CollisionEstimator", "rho_from_sign_collision", "region_bounds",
           "cell_probs", "MleRhoEstimator", "mle_rho_2bit"]


def rho_from_sign_collision(p_hat):
    """Closed-form inverse of P_1 = 1 - acos(rho)/pi."""
    p = jnp.clip(p_hat, 0.5, 1.0)
    return jnp.cos(math.pi * (1.0 - p))


@dataclass
class CollisionEstimator:
    """rho_hat = P^{-1}(P_hat) by table inversion.

    Builds a (rho, P) table once (host side, float64-safe under x64) and
    estimates with jnp.interp — fully jittable / vmappable.
    """
    scheme: str
    w: float = 1.0
    grid_size: int = 4096
    rho_max: float = 0.99995
    _rho_grid: np.ndarray = field(init=False, repr=False)
    _p_grid: np.ndarray = field(init=False, repr=False)

    def __post_init__(self):
        rho = np.linspace(0.0, self.rho_max, self.grid_size)
        p = np.asarray(collision_prob(jnp.asarray(rho), self.w, self.scheme))
        # enforce strict monotonicity for interp (numerics can plateau at tails)
        p = np.maximum.accumulate(p)
        eps = 1e-12 * np.arange(self.grid_size)
        self._rho_grid = rho
        self._p_grid = p + eps

    def __call__(self, p_hat):
        """Map empirical collision fraction(s) to rho_hat(s)."""
        p_hat = jnp.asarray(p_hat)
        return jnp.interp(p_hat, jnp.asarray(self._p_grid),
                          jnp.asarray(self._rho_grid))

    def estimate(self, codes_a, codes_b):
        """Estimate rho from two code arrays [..., k]."""
        p_hat = jnp.mean((codes_a == codes_b).astype(jnp.float32), axis=-1)
        return self(p_hat)

    def asymptotic_std(self, rho, k: int):
        """Predicted std of rho_hat: sqrt(V/k) (Thms 2-4)."""
        return jnp.sqrt(variance_factor(jnp.asarray(rho), self.w, self.scheme) / k)


def region_bounds(spec: CodeSpec):
    """Code-region boundaries [(lo_0, hi_0), ...] of a coding scheme.

    Region c is the half-open interval of projected values that encode to
    code c (``schemes.encode``), truncated at |z| = ZMAX (tail mass
    < 1e-18). Supported: 'sign', '2bit', 'uniform'. The 'offset' scheme
    draws a random offset per projection, so its regions differ across
    the k projections — raise rather than pretend one table covers them.
    """
    from repro.core.probabilities import ZMAX

    if spec.scheme == "sign":
        return [(-ZMAX, 0.0), (0.0, ZMAX)]
    if spec.scheme == "2bit":
        w = spec.w
        return [(-ZMAX, -w), (-w, 0.0), (0.0, w), (w, ZMAX)]
    if spec.scheme == "uniform":
        n_side = spec.n_bins_side
        out = []
        for c in range(2 * n_side):
            v = c - n_side
            lo = -ZMAX if c == 0 else v * spec.w
            hi = ZMAX if c == 2 * n_side - 1 else (v + 1) * spec.w
            out.append((lo, min(hi, ZMAX)))
        return out
    raise ValueError(
        f"no shared code regions for scheme {spec.scheme!r} (the offset "
        f"scheme's regions are per-projection); use sign/2bit/uniform")


def cell_probs(rho, spec: CodeSpec, order: int = 64):
    """Contingency-cell probabilities Pr(code(x)=a, code(y)=b | rho).

    rho: array [...]; returns [..., n, n] with n = spec.n_codes. Cells
    are intersections of the scheme's code regions (``region_bounds``);
    each is a Lemma 1-style quadrature over the generalized rectangle
    Pr(x in [a,b], y in [c,d]) under the bivariate normal with
    correlation rho. Rows/cols follow code order, so ``cell[..., a, b]``
    matches ``codes_a == a, codes_b == b``.
    """
    from repro.core.probabilities import Phi, phi
    from repro.core._quad import interval_nodes

    bounds = region_bounds(spec)
    rho = jnp.clip(jnp.asarray(rho), 0.0, 1.0 - 1e-7)
    r = rho[..., None]
    sd = jnp.sqrt(1.0 - r * r)
    rows = []
    for (a, b) in bounds:
        row = []
        z, wz = interval_nodes(a, b, order)
        for (c, d) in bounds:
            inner = Phi((d - r * z) / sd) - Phi((c - r * z) / sd)
            row.append(jnp.sum(phi(z) * inner * wz, axis=-1))
        rows.append(jnp.stack(row, axis=-1))
    return jnp.stack(rows, axis=-2)  # [..., n, n]


@dataclass
class MleRhoEstimator:
    """Non-linear maximum-likelihood estimator over the full contingency
    table of a coding scheme, inverted numerically on a rho grid.

    The collision estimator (§3) uses only the diagonal of the code
    contingency table; the follow-up 1602.06577 shows the full table
    carries most of what the 2-bit codes know about rho. This estimator
    tabulates log cell probabilities on a dense rho grid once (host
    side) and maximizes sum_cells count * log p_cell(rho) by grid argmax
    — fully jittable, batched over leading axes, and monotone in the
    data by the monotone-likelihood-ratio structure of the cell family.

    Counts may be fractional (expected counts work as well as observed
    ones); ``estimate`` builds them from raw code arrays.
    """
    spec: CodeSpec
    grid_size: int = 512
    rho_max: float = 0.99995
    _rho_grid: jax.Array = field(init=False, repr=False)
    _logp_t: jax.Array = field(init=False, repr=False)

    def __post_init__(self):
        n = self.spec.n_codes
        rho = np.linspace(0.0, self.rho_max, self.grid_size)
        probs = np.asarray(cell_probs(jnp.asarray(rho), self.spec))
        logp = np.log(np.maximum(probs, 1e-30)).reshape(
            self.grid_size, n * n)
        # device-resident once; from_counts never re-uploads the table
        self._rho_grid = jnp.asarray(rho, jnp.float32)
        self._logp_t = jnp.asarray(logp.T, jnp.float32)  # [n*n, G]

    @property
    def n_codes(self) -> int:
        return self.spec.n_codes

    def from_counts(self, counts):
        """Cell counts [..., n*n] (row-major (a, b), float or int) ->
        rho_hat float [...] by grid argmax of the log-likelihood."""
        counts = jnp.asarray(counts, jnp.float32)
        ll = counts @ self._logp_t  # [..., G]
        return self._rho_grid[jnp.argmax(ll, axis=-1)]

    def cell_counts(self, codes_a, codes_b):
        """int codes [..., k] pairs -> int32 cell counts [..., n*n]."""
        n = self.n_codes
        k = codes_a.shape[-1]
        cell = codes_a * n + codes_b  # [..., k] in [0, n*n)
        return jax.vmap(lambda c: jnp.bincount(c, length=n * n),
                        in_axes=0)(cell.reshape(-1, k)).reshape(
            codes_a.shape[:-1] + (n * n,))

    def estimate(self, codes_a, codes_b):
        """MLE rho_hat [...] from two int code arrays [..., k]."""
        return self.from_counts(self.cell_counts(codes_a, codes_b))


@functools.lru_cache(maxsize=8)
def _mle_2bit_estimator(w: float, grid_size: int) -> MleRhoEstimator:
    """Cached 2-bit estimator per (w, grid_size): the grid quadrature
    builds once, repeated ``mle_rho_2bit`` calls reuse it."""
    return MleRhoEstimator(CodeSpec("2bit", w), grid_size=grid_size)


def mle_rho_2bit(codes_a, codes_b, w: float, grid_size: int = 512):
    """Beyond-paper MLE (paper §7): maximize the 4x4 contingency-table
    likelihood of the 2-bit codes over a rho grid.

    codes_a/b: int32 [..., k] in {0,1,2,3}. Returns rho_hat [...].
    (Thin wrapper over a cached ``MleRhoEstimator`` with a 2-bit spec.)
    """
    return _mle_2bit_estimator(float(w), grid_size).estimate(codes_a,
                                                             codes_b)
