"""Beyond-paper table: coded-sketch gradient compression — wire bytes per
sync and reconstruction error per scheme (the paper's coding economics
applied to DP gradient synchronization; see EXPERIMENTS.md section Perf).
"""
import jax
import jax.numpy as jnp

from repro.core.gradient_compression import GradCompressionConfig, GradCompressor
from benchmarks._util import timed, write_csv


def run(quick: bool = True):
    g_dim = 1 << 20 if quick else 1 << 24  # ~1M/16M-param gradient
    tpl = {"g": jnp.zeros((g_dim,))}
    g = {"g": jax.random.normal(jax.random.PRNGKey(0), (g_dim,))}
    rows, out = [], []
    for scheme, w, bits in (("sign", 0.0, 1), ("2bit", 0.75, 2),
                            ("uniform", 0.75, 4), ("offset", 0.75, 4)):
        for rate in (4, 8, 16):
            cfg = GradCompressionConfig(scheme=scheme, w=max(w, 1e-3),
                                        rate=rate, chunk=4096)
            comp = GradCompressor(cfg, tpl)

            def sync():
                return comp.sync_local(g, comp.init_ef(tpl))[0]

            _, us = timed(sync, repeat=1)
            flat = comp._flatten(g)
            codes, scales = comp.encode(flat)
            err = float(jnp.linalg.norm(comp.decode(codes, scales) - flat)
                        / jnp.linalg.norm(flat))
            ratio = comp.fp32_bytes() / comp.wire_bytes()
            rows.append([scheme, rate, comp.wire_bytes(), ratio, err, us])
    write_csv("grad_compression", ["scheme", "rate", "wire_bytes",
                                   "fp32_over_wire", "rel_err", "us"], rows)
    best = min(rows, key=lambda r: r[4])
    out.append(("grad_compression", best[5],
                f"best_relerr={best[4]:.3f}@{best[0]}r{best[1]};"
                f"wire_ratio_up_to={max(r[3] for r in rows):.0f}x"))
    return out
