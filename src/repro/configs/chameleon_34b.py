"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 — early-fusion, VQ image tokens in the unified vocab,
qk-norm. Modality frontend is a stub: input_specs supplies token ids
(text + pre-tokenized VQ image codes). [arXiv:2405.09818; unverified]"""
from dataclasses import replace

from repro.models.lm import ModelConfig

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", family="vlm",
        n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
        vocab_size=65536, qk_norm=True, rope_theta=10000.0,
        tie_embeddings=False, norm_eps=1e-5,
    )


def smoke_config() -> ModelConfig:
    return replace(config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                   d_ff=128, vocab_size=256, loss_chunk=16, chunk_kv=32,
                   chunk_q=16)
