"""Observability overhead benchmark: what the measuring layer costs.

An observability layer that taxes the hot path gets turned off, and an
unmeasured system drifts; this bench keeps ``repro.obs`` honest on both
counts. Measured:

  * end-to-end QPS of the exact-search serving hot path
    (``serve.AnnService`` submit→flush, cache disabled so every query
    does device work) in four configurations: everything off, metrics
    only, the production default (metrics + flight recorder + tail
    sampler), and the full health layer on top (SLO engine ticking per
    flush; the known-answer canary probe is timed separately — one
    probe is a full 1-query corpus pass — and amortized at its
    documented ``PROBE_HZ`` cadence rather than jammed into the short
    timed window). Acceptance: metrics <= 3% QPS overhead, the flight
    layer <= 1% on top of metrics, the health layer (tick + amortized
    probe) <= 2% on top of flight+metrics;
  * microbenchmarks of the primitives: counter ``inc``, histogram
    ``observe`` (precomputed-edge bisect — the <= ~400 ns fast path),
    disabled-registry no-op metrics, a ``span(...)`` enter/exit with no
    tracer installed, and the flight-recorder ring append (the
    <= ~500 ns O(1) slot write);
  * a real trace artifact: one full service cycle — bulk_load ingest →
    batched search → classify → delete → compact — recorded under a
    ``Tracer`` and dumped as Chrome-trace/Perfetto JSON next to the
    BENCH files (load it at https://ui.perfetto.dev).

Wall-clock numbers are median-of-N with ``block_until_ready`` (the
serving flush syncs via its own host transfer).

``BENCH_obs.json`` (repo root) records the QPS triple, both overhead
fractions, the primitive costs and the trace path. ``--quick`` runs the
same acceptance gates on a small corpus without rewriting the JSON —
the mode CI uses on every push.
"""
import json
import os
import sys
import time

import numpy as np
import jax

if __package__ in (None, ""):            # direct `python benchmarks/obs_bench.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

from benchmarks._util import write_csv
from repro.ann import AnnEngine, BandSpec
from repro.core.sketch import CodedRandomProjection, SketchConfig
from repro.index import MutableAnnEngine
from repro.learn import LearnConfig, fit_log
from repro.obs import (CanaryProber, FlightRecorder, MetricsRegistry,
                       ProbeConfig, ShadowReservoir, TailSampler,
                       Tracer, no_tracing, set_default_registry,
                       set_flight_recorder, span)
from repro.serve import AnnService, AnnServiceConfig

K = 64

#: documented canary cadence the probe cost is amortized against — one
#: known-answer probe per second (the slo-gate drills use the same
#: order of magnitude; probing every few batches would spend a full
#: corpus pass per probe and dominate the serving budget)
PROBE_HZ = 1.0


def _interleaved_qps(setups, queries, repeat):
    """Median submit-all+flush QPS per configuration, with rounds
    interleaved A,B,C,A,B,C,... instead of AAA,BBB,CCC — slow machine
    drift (thermal, cache, background load) then lands on every config
    equally instead of biasing whichever ran last. Each setup is
    (service, registry, flight_recorder); the globals are swapped in
    before each round so engine/kernel-level instrumentation follows
    the config under test. The flush's host transfer of results is the
    device sync."""
    nq = queries.shape[0]
    ts = [[] for _ in setups]
    for svc, reg, fr in setups:           # warm every jit + bucket
        set_default_registry(reg)
        set_flight_recorder(fr)
        for x in queries:
            svc.submit(x)
        svc.flush()
    k = len(setups)
    for r in range(repeat):
        # rotate the within-cycle order each cycle: no config always
        # runs first (or last), so position effects — cache state left
        # by the previous config, periodic background work — average
        # out instead of biasing one config
        for j in range(k):
            i = (j + r) % k
            svc, reg, fr = setups[i]
            set_default_registry(reg)
            set_flight_recorder(fr)
            t0 = time.perf_counter()
            for x in queries:
                svc.submit(x)
            svc.flush()
            ts[i].append(time.perf_counter() - t0)
    return [nq / float(np.median(t)) for t in ts], ts


def _paired_overhead(t_slow, t_fast):
    """Fractional slowdown of config ``t_slow`` over ``t_fast`` as the
    median of per-cycle ratios — each pair ran back-to-back inside one
    interleave cycle, so machine-level drift common to the cycle
    cancels out of the ratio."""
    return float(np.median([a / b for a, b in zip(t_slow, t_fast)])) - 1.0


def _ns_per(fn, n=50_000, best_of=3):
    """Best-of-``best_of`` ns/call: the minimum over repeated timed
    loops is the standard noise-robust microbench estimator (anything
    above the minimum is scheduler/cache interference, not the code)."""
    fn()                                  # touch once outside the timer
    best = float("inf")
    for _ in range(best_of):
        t0 = time.perf_counter()
        for _ in range(n):
            fn()
        best = min(best, time.perf_counter() - t0)
    return 1e9 * best / n


def _trace_cycle(d, rows, path):
    """Record one full service cycle — bulk_load → two search rounds →
    upsert → classify → delete → compact → post-compact search, all
    through ``serve.AnnService`` — and dump the Chrome trace; returns
    (path, n_events)."""
    crp = CodedRandomProjection(SketchConfig(k=K, scheme="2bit", w=0.75), d)
    eng = MutableAnnEngine(crp, tail_rows=256)
    svc = AnnService(eng, AnnServiceConfig(top_k=10, mode="exact",
                                           cache_size=16, buckets=(32,)))
    rng = np.random.default_rng(0)
    x = rng.standard_normal((rows, d)).astype(np.float32)
    with Tracer() as tr:
        ids = svc.bulk_load(x, chunk_rows=256)
        for q in x[:32]:
            svc.submit(q)
        svc.flush()
        for q in x[32:64]:                # distinct round: no cache hits
            svc.submit(q)
        svc.flush()
        svc.upsert(ids[:16], x[:16] + 0.01)
        model = fit_log(eng.store,
                        lambda i: np.where(np.asarray(i) % 2 == 0, 1, -1),
                        crp, LearnConfig(steps=4))
        svc.set_classifier(model)
        svc.classify(x[:32])
        svc.classify(x[64:96])
        svc.delete(ids[: rows // 3])
        svc.compact()
        for q in x[64:80]:                # search the compacted store
            svc.submit(q)
        svc.flush()
    tr.dump(path)
    return path, len(tr.events)


def _bench(d, n, nq, repeat):
    rng = np.random.default_rng(0)
    corpus = rng.standard_normal((n, d)).astype(np.float32)
    queries = corpus[:nq] + 0.1 * rng.standard_normal(
        (nq, d)).astype(np.float32)
    crp = CodedRandomProjection(SketchConfig(k=K, scheme="2bit", w=0.75), d)
    engine = AnnEngine.build(crp, corpus, BandSpec(n_tables=8, band_width=4))
    # bucket 1 exists so the health config's single-row canary probes
    # pad to 1, not nq; query rounds still run at the nq bucket in
    # every config, so the ladder pairs stay apples-to-apples
    cfg = AnnServiceConfig(top_k=10, mode="exact", cache_size=0,
                           buckets=(1, nq))

    def _off_service(reg):
        return AnnService(engine, cfg, registry=reg,
                          flight=FlightRecorder(enabled=False),
                          sampler=TailSampler(enabled=False))

    # four-point ladder, rounds interleaved across configs: any tracer
    # the harness installed (run.py --profile) is suspended so the
    # pairs isolate exactly one knob
    prev_reg = set_default_registry(MetricsRegistry(enabled=True))
    prev_fr = set_flight_recorder(FlightRecorder(enabled=True))
    try:
        with no_tracing():
            reg_health = MetricsRegistry(enabled=True)
            reg_flight = MetricsRegistry(enabled=True)
            reg_metrics = MetricsRegistry(enabled=True)
            reg_none = MetricsRegistry(enabled=False)
            # full health layer: flight config + SLO engine ticking
            # per flush; the canary probe is timed separately below and
            # amortized at the documented cadence (PROBE_HZ) — a probe
            # fires ~1/s in production, far sparser than the bench's
            # timed rounds, so folding one into a 15-round window would
            # either never sample it or wildly oversample it
            svc_health = AnnService(engine, cfg, registry=reg_health,
                                    slo=True)
            resv = ShadowReservoir(cap=min(n, 512))
            resv.offer(np.arange(len(corpus)), corpus)
            prober = CanaryProber(
                svc_health, slo=svc_health.slo, reservoir=resv,
                registry=reg_health,
                cfg=ProbeConfig(n_probes=1, classify=False))
            prober.run_once(n=1)          # compile the bucket-1 probe
            svc_health.slo.mark_steady()  # ...then arm never-recompile
            setups = [
                (svc_health, reg_health, FlightRecorder(enabled=True)),
                # production default: metrics + flight ring + sampler
                (AnnService(engine, cfg, registry=reg_flight),
                 reg_flight, FlightRecorder(enabled=True)),
                # metrics only (flight off): the pre-flight baseline
                (_off_service(reg_metrics), reg_metrics,
                 FlightRecorder(enabled=False)),
                # everything off
                (_off_service(reg_none), reg_none,
                 FlightRecorder(enabled=False)),
            ]
            (qps_health, qps_flight, qps_on, qps_off), \
                (t_hl, t_fl, t_on, t_off) = \
                _interleaved_qps(setups, queries, repeat)
            # per-probe cost (1 known-answer query through the real
            # endpoint, bucket 1) — amortized at PROBE_HZ below
            set_default_registry(reg_health)
            probe_ts = []
            for _ in range(max(5, repeat // 3)):
                t0 = time.perf_counter()
                prober.run_once(n=1)
                probe_ts.append(time.perf_counter() - t0)
            probe_s = float(np.median(probe_ts))
    finally:
        set_default_registry(prev_reg)
        set_flight_recorder(prev_fr)

    reg_on = MetricsRegistry(enabled=True)
    reg_off = MetricsRegistry(enabled=False)
    c_on, c_off = reg_on.counter("bench.c"), reg_off.counter("bench.c")
    h_on, h_off = reg_on.histogram("bench.h"), reg_off.histogram("bench.h")
    fr_on = FlightRecorder(capacity=4096, enabled=True)
    fr_off = FlightRecorder(capacity=4096, enabled=False)

    def _span_noop():
        with span("bench.span"):
            pass

    trace_path, trace_events = _trace_cycle(
        d, 1024, os.path.join(_ROOT, "TRACE_obs_cycle.json"))

    # the span microbench measures the NO-tracer cost — suspend any
    # tracer the harness (run.py --profile) may have installed
    with no_tracing():
        ns_span = _ns_per(_span_noop)

    overhead = _paired_overhead(t_on, t_off)
    flight_overhead = _paired_overhead(t_fl, t_on)
    # health = always-on SLO ticking (paired ladder ratio) + the canary
    # probe amortized at its documented cadence: a probe costs probe_s
    # of wall time and fires PROBE_HZ times per second, so it claims
    # probe_s * PROBE_HZ of every second
    tick_overhead = _paired_overhead(t_hl, t_fl)
    probe_amortized = probe_s * PROBE_HZ
    health_overhead = tick_overhead + probe_amortized
    return {
        "corpus": n, "queries": nq, "k": K, "bits": 2,
        "qps_health_enabled": qps_health,
        "qps_flight_enabled": qps_flight,
        "qps_metrics_enabled": qps_on,
        "qps_metrics_disabled": qps_off,
        "overhead_frac": overhead,
        "flight_overhead_frac": flight_overhead,
        "health_tick_overhead_frac": tick_overhead,
        "probe_s": probe_s,
        "probe_hz": PROBE_HZ,
        "probe_amortized_frac": probe_amortized,
        "health_overhead_frac": health_overhead,
        "ns_counter_inc": _ns_per(lambda: c_on.inc()),
        "ns_counter_inc_disabled": _ns_per(lambda: c_off.inc()),
        "ns_histogram_observe": _ns_per(lambda: h_on.observe(3e-4)),
        "ns_histogram_observe_disabled": _ns_per(
            lambda: h_off.observe(3e-4)),
        "ns_flight_record": _ns_per(
            lambda: fr_on.record("bench", 0.0, 1.0, batch=64,
                                 generation=1, synced=True)),
        "ns_flight_record_disabled": _ns_per(
            lambda: fr_off.record("bench", 0.0, 1.0)),
        "ns_span_no_tracer": ns_span,
        "trace_file": os.path.basename(trace_path),
        "trace_events": trace_events,
        "timing": "median-of-%d, device-synced flush" % repeat,
    }


def _rows(r):
    return [
        ("obs_serve_health", 1e6 / r["qps_health_enabled"],
         f"qps={r['qps_health_enabled']:.0f} "
         f"health_overhead={100 * r['health_overhead_frac']:.2f}% "
         f"(tick {100 * r['health_tick_overhead_frac']:.2f}% + "
         f"probe {1e3 * r['probe_s']:.1f}ms@{r['probe_hz']:g}Hz)"),
        ("obs_serve_flight", 1e6 / r["qps_flight_enabled"],
         f"qps={r['qps_flight_enabled']:.0f} "
         f"flight_overhead={100 * r['flight_overhead_frac']:.2f}%"),
        ("obs_serve_enabled", 1e6 / r["qps_metrics_enabled"],
         f"qps={r['qps_metrics_enabled']:.0f}"),
        ("obs_serve_disabled", 1e6 / r["qps_metrics_disabled"],
         f"qps={r['qps_metrics_disabled']:.0f} "
         f"overhead={100 * r['overhead_frac']:.2f}%"),
        ("obs_counter_inc", 1e-3 * r["ns_counter_inc"],
         f"disabled_ns={r['ns_counter_inc_disabled']:.0f}"),
        ("obs_histogram_observe", 1e-3 * r["ns_histogram_observe"],
         f"disabled_ns={r['ns_histogram_observe_disabled']:.0f}"),
        ("obs_flight_record", 1e-3 * r["ns_flight_record"],
         f"disabled_ns={r['ns_flight_record_disabled']:.0f}"),
        ("obs_span_no_tracer", 1e-3 * r["ns_span_no_tracer"],
         f"trace_events={r['trace_events']}"),
    ]


def run(quick: bool = True):
    """run.py contract: (name, us_per_call, derived) rows."""
    r = _bench(d=64, n=4096 if quick else 65536, nq=64,
               repeat=9 if quick else 21)
    rows = _rows(r)
    write_csv("obs_bench", ["name", "us_per_call", "derived"], rows)
    return rows


def _acceptance(r) -> bool:
    """The CI gates: metrics <= 3% QPS, flight layer <= 1% QPS on top,
    health layer (slo ticks + canary probe amortized at PROBE_HZ)
    <= 2% on top of flight+metrics, ring append <= 500 ns, histogram
    observe <= 400 ns."""
    checks = [
        ("metrics overhead <= 3%", r["overhead_frac"] <= 0.03),
        ("flight overhead <= 1%", r["flight_overhead_frac"] <= 0.01),
        ("health overhead <= 2%", r["health_overhead_frac"] <= 0.02),
        ("ring append <= 500 ns", r["ns_flight_record"] <= 500.0),
        ("histogram observe <= 400 ns",
         r["ns_histogram_observe"] <= 400.0),
    ]
    ok = True
    for name, passed in checks:
        print(f"  {name}: {'PASS' if passed else 'FAIL'}")
        ok = ok and passed
    return ok


def main():
    quick = "--quick" in sys.argv[1:]
    if quick:
        # CI gate mode: small corpus, same acceptance checks, no
        # BENCH_obs.json overwrite (full-size numbers stay canonical)
        r = _bench(d=64, n=8192, nq=64, repeat=15)
    else:
        r = _bench(d=64, n=65536, nq=64, repeat=21)
    rows = _rows(r)
    write_csv("obs_bench", ["name", "us_per_call", "derived"], rows)
    if quick:
        # CI quick runs feed the cross-run perf history so the
        # change-point gate (scripts/check_perf.py) accumulates the
        # min_points it needs to arm — one appended point per build
        from benchmarks import history as _history
        try:
            _history.append_history("obs_bench", rows, _ROOT, quick=True)
        except OSError as e:
            print(f"# history append failed: {e}", file=sys.stderr)
    else:
        with open(os.path.join(_ROOT, "BENCH_obs.json"), "w") as f:
            json.dump(r, f, indent=1)
    print("BENCH " + json.dumps(r))
    print(f"\nhealth layer: {r['qps_health_enabled']:.0f} qps "
          f"({100 * r['health_overhead_frac']:.2f}% over flight+metrics"
          f" = tick {100 * r['health_tick_overhead_frac']:.2f}% + "
          f"probe {1e3 * r['probe_s']:.1f}ms @ {r['probe_hz']:g}Hz)"
          f"\nflight+metrics hot path: {r['qps_flight_enabled']:.0f} qps "
          f"vs metrics-only {r['qps_metrics_enabled']:.0f} qps "
          f"({100 * r['flight_overhead_frac']:.2f}% flight overhead) "
          f"vs all-off {r['qps_metrics_disabled']:.0f} qps "
          f"({100 * r['overhead_frac']:.2f}% metrics overhead)")
    print(f"primitives: counter {r['ns_counter_inc']:.0f} ns, histogram "
          f"{r['ns_histogram_observe']:.0f} ns, flight record "
          f"{r['ns_flight_record']:.0f} ns, span(no tracer) "
          f"{r['ns_span_no_tracer']:.0f} ns")
    ok = _acceptance(r)
    print("acceptance: " + ("PASS" if ok else "FAIL"))
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
