"""Zero-dependency ops dashboard: one snapshot, two renderers.

Everything the closed-loop health layer knows — SLO budgets and burn
sparklines, the flight-ring tail, the modeled roofline, quality series,
resource gauges — collapses into one plain-dict snapshot (``gather``)
and renders two ways from it:

* ``render_text`` — a fixed-width terminal view for ``watch``-style
  operation and test assertions;
* ``render_html`` — a static, self-contained HTML page (inline CSS, no
  scripts, no external assets) for CI artifact upload — every CI run
  leaves behind the page an operator would have been looking at.

``write_dashboard`` writes the page *atomically* (tmp file + rename in
the target directory) so a crash or a concurrent artifact scrape never
observes a torn page. Rendering is strictly read-only over the
snapshot: a dashboard render never mutates a metric, ledger, or ring
(the one deliberate exception: ``gather`` refreshes resource gauges via
``ResourceMonitor.collect`` when you hand it a monitor, because
resource numbers are pull-only).

Sparklines are unicode block glyphs over each ledger's recent
fast-window burn-rate series (the ``spark`` deque the ``SloEngine``
maintains per tick) — scale is per-line max, annotated at the end, so
a flat healthy line and a spiking one read correctly side by side.
"""
from __future__ import annotations

import html as _html
import math
import os
import tempfile

from repro.obs.registry import MetricsRegistry, default_registry

__all__ = ["gather", "render_text", "render_html", "write_dashboard"]

_BLOCKS = "▁▂▃▄▅▆▇█"


def _spark(values, width: int = 32) -> str:
    """Unicode sparkline of the last ``width`` values, scaled to the
    line's own max (empty series -> empty string)."""
    vals = [v for v in list(values)[-width:]
            if isinstance(v, (int, float)) and v == v]
    if not vals:
        return ""
    hi = max(max(vals), 1e-12)
    return "".join(_BLOCKS[min(len(_BLOCKS) - 1,
                               int(v / hi * (len(_BLOCKS) - 1)))]
                   for v in vals)


def _fmt_bytes(b) -> str:
    if not isinstance(b, (int, float)) or b != b:
        return "-"
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(b) < 1024.0 or unit == "TiB":
            return f"{b:.1f}{unit}" if unit != "B" else f"{int(b)}B"
        b /= 1024.0
    return "-"


def _fmt_s(v) -> str:
    if not isinstance(v, (int, float)) or v != v:
        return "-"
    if v >= 1.0:
        return f"{v:.2f}s"
    if v >= 1e-3:
        return f"{v * 1e3:.2f}ms"
    return f"{v * 1e6:.0f}us"


def gather(registry: MetricsRegistry = None, slo=None, flight=None,
           quality=None, resources=None, kernels=None, hw=None,
           tail_n: int = 20) -> dict:
    """One read-only snapshot of everything renderable.

    Every component is optional — pass what the deployment has wired
    and the corresponding section appears; the rest stay absent. The
    snapshot is plain dicts/lists (json-serializable apart from NaN),
    so it can also ride inside an incident bundle.
    """
    reg = registry if registry is not None else default_registry()
    snap = {"registry": reg.snapshot()}
    if slo is not None:
        snap["health"] = slo.health()
    if flight is not None:
        snap["flight"] = {"tail": flight.tail(tail_n),
                          "dropped": flight.dropped}
    if quality is not None:
        snap["quality"] = quality.report()
    if resources is not None:
        snap["resources"] = resources.collect()
    if kernels is None:
        try:
            from repro.obs import kernelstats as _ks
            kernels = _ks.get_kernel_stats()
        except Exception:
            kernels = None
    if kernels is not None:
        try:
            snap["roofline"] = kernels.roofline_table(hw)
        except Exception:
            pass
    return snap


# -- section builders (shared by both renderers) -----------------------------

def _slo_rows(health: dict):
    rows = []
    for name, b in sorted(health.get("slos", {}).items()):
        rows.append({
            "name": name,
            "objective": f"{b['objective']:.4g}",
            "burn_fast": f"{b['burn_fast']:.2f}",
            "burn_short": f"{b['burn_short']:.2f}",
            "budget": f"{b['budget_remaining'] * 100:.1f}%",
            "state": ("ALERT" if b["alerting"]
                      else f"ok ({b['alarms']} past)" if b["alarms"]
                      else "ok"),
            "spark": _spark(b.get("spark", ())),
            "spark_max": (f"{max(b['spark']):.2f}" if b.get("spark")
                          else ""),
            "alerting": b["alerting"],
        })
    return rows


def _flight_rows(flight: dict):
    rows = []
    for ev in flight.get("tail", ()):
        t0, t1 = ev.get("t_start", math.nan), ev.get("t_end", math.nan)
        rows.append({
            "op": str(ev.get("op", "?")),
            "dur": _fmt_s(t1 - t0),
            "batch": str(ev.get("batch", "")),
            "outcome": str(ev.get("outcome", "")),
            "trace": format(ev.get("trace_id", 0) or 0, "x")[:16],
        })
    return rows


def _roofline_rows(roof: dict):
    rows = []
    for fam, r in sorted(roof.items()):
        rows.append({
            "family": fam,
            "calls": str(r.get("calls", "")),
            "bytes": _fmt_bytes(r.get("bytes", math.nan)),
            "intensity": (f"{r['intensity']:.2f}"
                          if isinstance(r.get("intensity"), float)
                          and r["intensity"] == r["intensity"] else "-"),
            "bound": str(r.get("bound", "")),
            "t_model": _fmt_s(r.get("t_model_s", math.nan)),
        })
    return rows


def _resource_rows(res: dict):
    rows = [{"what": f"store:{k}", "value": _fmt_bytes(v)}
            for k, v in sorted(res.get("tracked", {}).items())]
    rows.append({"what": "tracked total",
                 "value": _fmt_bytes(res.get("tracked_total"))})
    for k, v in sorted(res.get("device", {}).items()):
        rows.append({"what": f"device:{k}", "value": _fmt_bytes(v)})
    for k, v in sorted(res.get("host", {}).items()):
        rows.append({"what": f"host:{k}", "value": _fmt_bytes(v)})
    rows.append({"what": "jit compiles",
                 "value": str(res.get("jit_compiles", "-"))})
    rows.append({"what": "compiles since mark",
                 "value": str(res.get("compiles_since_mark", "-"))})
    return rows


def _latency_rows(registry_snap: dict):
    rows = []
    for name, s in sorted(registry_snap.get("histograms", {}).items()):
        if not s.get("count"):
            continue
        rows.append({"series": name, "count": str(s["count"]),
                     "p50": _fmt_s(s.get("p50")),
                     "p95": _fmt_s(s.get("p95")),
                     "p99": _fmt_s(s.get("p99")),
                     "max": _fmt_s(s.get("max"))})
    return rows


def _table_text(rows, cols, out):
    if not rows:
        return
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    out.append("  " + "  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        out.append("  " + "  ".join(
            str(r.get(c, "")).ljust(widths[c]) for c in cols))


def render_text(snap: dict) -> str:
    """Fixed-width terminal view of one ``gather`` snapshot."""
    out = []
    health = snap.get("health")
    if health is not None:
        status = health["status"].upper()
        out.append(f"== health: {status}"
                   + (f"  shed={health['shed_fraction']:.2f}"
                      if health["shed_fraction"] else ""))
        if health["alerts"]:
            out.append("  active alerts: " + ", ".join(health["alerts"]))
        rows = _slo_rows(health)
        for r in rows:
            r["burn"] = f"{r['burn_fast']}/{r['burn_short']}"
            r["sparkline"] = (f"{r['spark']} max={r['spark_max']}"
                              if r["spark"] else "")
        _table_text(rows, ("name", "objective", "burn", "budget",
                           "state", "sparkline"), out)
    rows = _latency_rows(snap.get("registry", {}))
    if rows:
        out.append("== latency")
        _table_text(rows, ("series", "count", "p50", "p95", "p99",
                           "max"), out)
    res = snap.get("resources")
    if res is not None:
        out.append("== resources")
        _table_text(_resource_rows(res), ("what", "value"), out)
    roof = snap.get("roofline")
    if roof:
        out.append("== roofline")
        _table_text(_roofline_rows(roof), ("family", "calls", "bytes",
                                           "intensity", "bound",
                                           "t_model"), out)
    q = snap.get("quality")
    if q:
        out.append("== quality")
        for k, v in sorted(q.items()):
            out.append(f"  {k}: {v}")
    fl = snap.get("flight")
    if fl is not None:
        out.append(f"== flight tail (dropped={fl.get('dropped', 0)})")
        _table_text(_flight_rows(fl), ("op", "dur", "batch",
                                       "outcome", "trace"), out)
    return "\n".join(out) + "\n"


def _table_html(rows, cols, out, classes=None):
    if not rows:
        return
    out.append("<table><tr>"
               + "".join(f"<th>{_html.escape(c)}</th>" for c in cols)
               + "</tr>")
    for r in rows:
        cls = classes(r) if classes else ""
        out.append((f'<tr class="{cls}">' if cls else "<tr>")
                   + "".join(f"<td>{_html.escape(str(r.get(c, '')))}"
                             f"</td>" for c in cols)
                   + "</tr>")
    out.append("</table>")


_CSS = """
body{font-family:ui-monospace,Menlo,Consolas,monospace;background:#111;
color:#ddd;margin:1.5em}
h1{font-size:1.2em} h2{font-size:1em;border-bottom:1px solid #333;
padding-bottom:.2em;margin-top:1.4em}
table{border-collapse:collapse;margin:.5em 0}
th,td{padding:.15em .7em;text-align:left;font-size:.85em}
th{color:#8af;border-bottom:1px solid #333}
tr:nth-child(even){background:#181818}
tr.alert td{color:#f66;font-weight:bold}
.ok{color:#6d6} .degraded{color:#f66}
.spark{color:#fa0;letter-spacing:-1px}
"""


def render_html(snap: dict) -> str:
    """Static self-contained HTML page of one ``gather`` snapshot
    (inline CSS, no scripts — safe as a CI artifact)."""
    out = ["<!doctype html><html><head><meta charset='utf-8'>"
           "<title>serving health</title>"
           f"<style>{_CSS}</style></head><body>",
           "<h1>serving health</h1>"]
    health = snap.get("health")
    if health is not None:
        cls = "degraded" if health["status"] != "ok" else "ok"
        out.append(f"<p>status: <b class='{cls}'>"
                   f"{_html.escape(health['status'])}</b>")
        if health["shed_fraction"]:
            out.append(f" · advisory shed fraction "
                       f"{health['shed_fraction']:.2f}")
        if health["alerts"]:
            out.append(" · alerts: "
                       + _html.escape(", ".join(health["alerts"])))
        out.append("</p><h2>SLO budgets</h2>")
        rows = _slo_rows(health)
        for r in rows:
            r["burn fast/short"] = f"{r['burn_fast']} / {r['burn_short']}"
            r["burn history"] = (f"{r['spark']} ≤{r['spark_max']}"
                                 if r["spark"] else "")
        _table_html(rows, ("name", "objective", "burn fast/short",
                           "budget", "state", "burn history"), out,
                    classes=lambda r: "alert" if r["alerting"] else "")
    rows = _latency_rows(snap.get("registry", {}))
    if rows:
        out.append("<h2>latency</h2>")
        _table_html(rows, ("series", "count", "p50", "p95", "p99",
                           "max"), out)
    res = snap.get("resources")
    if res is not None:
        out.append("<h2>resources</h2>")
        _table_html(_resource_rows(res), ("what", "value"), out)
    roof = snap.get("roofline")
    if roof:
        out.append("<h2>roofline (modeled)</h2>")
        _table_html(_roofline_rows(roof), ("family", "calls", "bytes",
                                           "intensity", "bound",
                                           "t_model"), out)
    q = snap.get("quality")
    if q:
        out.append("<h2>quality</h2><table>")
        for k, v in sorted(q.items()):
            out.append(f"<tr><th>{_html.escape(str(k))}</th>"
                       f"<td>{_html.escape(str(v))}</td></tr>")
        out.append("</table>")
    fl = snap.get("flight")
    if fl is not None:
        out.append(f"<h2>flight tail "
                   f"(dropped={int(fl.get('dropped', 0))})</h2>")
        _table_html(_flight_rows(fl), ("op", "dur", "batch",
                                       "outcome", "trace"), out)
    out.append("</body></html>")
    return "".join(out)


def write_dashboard(path: str, snap: dict = None, **components) -> str:
    """Render and atomically write the HTML dashboard to ``path``.

    Either pass a pre-built ``snap`` or the ``gather`` components as
    keywords (``registry=``, ``slo=``, ``flight=``, ...). The page is
    written to a temp file in the target directory then renamed — a
    reader (CI artifact scrape, browser refresh) never sees a torn
    page. Returns ``path``.
    """
    if snap is None:
        snap = gather(**components)
    page = render_html(snap)
    d = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w", encoding="utf-8") as f:
            f.write(page)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path
