"""Public jit'd wrappers for the Pallas kernels.

Dispatch policy: on TPU run the compiled kernels; elsewhere either run the
kernels in interpret mode (exact semantics, used by tests) or fall back to
the jnp oracle (fast CPU path, used by benchmarks/examples). ``impl``:
  'auto'    -> 'pallas' on TPU, 'ref' otherwise
  'pallas'  -> kernel (interpret=True off-TPU)
  'ref'     -> jnp oracle

Every dispatch also reports its family + static shape dims to
``repro.obs.kernelstats`` (invocation counts, modeled FLOPs/HBM bytes —
the live roofline). Calls made inside a jit trace are flagged ``traced``:
they dispatch once per compile, not per execution.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.packing import packed_width as _packed_width
from repro.core.schemes import CodeSpec
from repro.kernels import autotune as _autotune
from repro.kernels import ref as _ref
from repro.obs import kernelstats as _kstats
from repro.kernels.collision import collision_counts_pallas
from repro.kernels.pack_codes import pack_codes_pallas
from repro.kernels.packed_collision import (
    packed_collision_counts_pallas, packed_topk_masked_pallas,
    packed_topk_pallas)
from repro.kernels.packed_linear import (
    packed_linear_bwd_masked_pallas, packed_linear_bwd_pallas,
    packed_linear_fwd_masked_pallas, packed_linear_fwd_pallas)
from repro.kernels.packed_lut import (
    packed_lut_rerank_pallas, packed_lut_topk_masked_pallas,
    packed_lut_topk_pallas)
from repro.kernels.encode_fused import code_pack_pallas, encode_fused_pallas
from repro.kernels.fused_scored import (fused_scored_topk_masked_pallas,
                                        fused_scored_topk_pallas)
from repro.kernels.proj_code import coded_project_pallas

__all__ = ["coded_project", "encode_fused", "code_pack", "pack_codes",
           "collision_counts",
           "packed_collision_counts", "packed_topk", "packed_topk_masked",
           "packed_lut_topk", "packed_lut_topk_masked", "packed_lut_rerank",
           "fused_scored_topk", "fused_scored_topk_masked",
           "packed_linear_fwd", "packed_linear_fwd_masked",
           "packed_linear_bwd", "packed_linear_bwd_masked"]


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _rec(family: str, *arrays, **dims):
    """Report one dispatch to the kernel flight recorder (repro.obs)."""
    _kstats.record(family,
                   traced=any(isinstance(a, jax.core.Tracer)
                              for a in arrays), **dims)


def _tuned(op: str, dtype, block_kwargs: dict, **dims) -> dict:
    """Block kwargs for a pallas dispatch: explicit caller kwargs win;
    otherwise consult the autotune cache (``kernels.autotune.lookup``,
    a pure host-dict read) — cold caches return {} and the kernel
    defaults apply. Tuned knobs are numerics-safe by construction, so
    this indirection can only change timing."""
    if block_kwargs:
        return block_kwargs
    return _autotune.lookup(op, dtype, **dims)


def coded_project(x, r, spec: CodeSpec, q: Optional[jax.Array] = None,
                  impl: str = "auto", **block_kwargs):
    """Fused encode(x @ r): [M, D] x [D, K] -> int32 codes [M, K]."""
    _rec("coded_project", x, r, m=x.shape[0], d=x.shape[1], k=r.shape[1])
    if _resolve(impl) == "ref":
        return _ref.coded_project_ref(x, r, spec, q)
    kw = _tuned("coded_project", x.dtype, block_kwargs,
                m=x.shape[0], d=x.shape[1], k=r.shape[1])
    return coded_project_pallas(x, r, spec, q, interpret=_interpret(), **kw)


def encode_fused(x, r, spec: CodeSpec, q: Optional[jax.Array] = None,
                 impl: str = "auto", **block_kwargs):
    """Fused pack(encode(x @ r)): [M, D] x [D, K] -> packed uint32
    [M, ceil(K·b/32)] — the one-kernel ingest path (projections and
    int32 codes never reach HBM)."""
    _rec("encode_fused", x, r, m=x.shape[0], d=x.shape[1], k=r.shape[1],
         w=_packed_width(r.shape[1], spec.bits))
    if _resolve(impl) == "ref":
        return _ref.encode_fused_ref(x, r, spec, q)
    kw = _tuned("encode_fused", x.dtype, block_kwargs,
                m=x.shape[0], d=x.shape[1], k=r.shape[1])
    return encode_fused_pallas(x, r, spec, q, interpret=_interpret(), **kw)


def code_pack(z, spec: CodeSpec, q: Optional[jax.Array] = None,
              impl: str = "auto", **block_kwargs):
    """Fused pack(encode(z)) of pre-projected values: [M, K] float ->
    packed uint32 [M, ceil(K·b/32)] (the streaming encode finalize)."""
    _rec("code_pack", z, m=z.shape[0], k=z.shape[1],
         w=_packed_width(z.shape[1], spec.bits))
    if _resolve(impl) == "ref":
        return _ref.code_pack_ref(z, spec, q)
    kw = _tuned("code_pack", z.dtype, block_kwargs,
                m=z.shape[0], k=z.shape[1])
    return code_pack_pallas(z, spec, q, interpret=_interpret(), **kw)


def pack_codes(codes, bits: int, impl: str = "auto", **block_kwargs):
    """Pack b-bit codes into uint32 words: [M, K] -> [M, K*b/32]."""
    _rec("pack_codes", codes, m=codes.shape[0], k=codes.shape[1],
         w=_packed_width(codes.shape[1], bits))
    if _resolve(impl) == "ref":
        return _ref.pack_codes_ref(codes, bits)
    kw = _tuned("pack_codes", codes.dtype, block_kwargs,
                m=codes.shape[0], k=codes.shape[1])
    return pack_codes_pallas(codes, bits, interpret=_interpret(), **kw)


def collision_counts(codes_q, codes_db, impl: str = "auto", **block_kwargs):
    """All-pairs collision counts: [Q, K], [N, K] -> int32 [Q, N]."""
    _rec("collision_counts", codes_q, codes_db, q=codes_q.shape[0],
         n=codes_db.shape[0], k=codes_q.shape[1])
    if _resolve(impl) == "ref":
        return _ref.collision_counts_ref(codes_q, codes_db)
    kw = _tuned("collision_counts", codes_q.dtype, block_kwargs,
                q=codes_q.shape[0], n=codes_db.shape[0])
    return collision_counts_pallas(codes_q, codes_db, interpret=_interpret(),
                                   **kw)


def packed_collision_counts(words_q, words_db, bits: int, k: int,
                            impl: str = "auto", **block_kwargs):
    """All-pairs counts on packed words: [Q, W], [N, W] -> int32 [Q, N]."""
    _rec("packed_collision_counts", words_q, words_db,
         q=words_q.shape[0], n=words_db.shape[0], w=words_q.shape[1])
    if _resolve(impl) == "ref":
        return _ref.packed_collision_ref(words_q, words_db, bits, k)
    kw = _tuned("packed_collision_counts", words_q.dtype, block_kwargs,
                q=words_q.shape[0], n=words_db.shape[0], w=words_q.shape[1])
    return packed_collision_counts_pallas(words_q, words_db, bits, k,
                                          interpret=_interpret(), **kw)


def packed_topk(words_q, words_db, bits: int, k: int, top_k: int,
                impl: str = "auto", **block_kwargs):
    """Streaming top-k search on packed words -> (counts, ids) [Q, top_k]."""
    _rec("packed_topk", words_q, words_db, q=words_q.shape[0],
         n=words_db.shape[0], w=words_q.shape[1], top_k=top_k)
    if _resolve(impl) == "ref":
        return _ref.packed_topk_ref(words_q, words_db, bits, k, top_k)
    kw = _tuned("packed_topk", words_q.dtype, block_kwargs,
                q=words_q.shape[0], n=words_db.shape[0],
                w=words_q.shape[1], top_k=top_k)
    return packed_topk_pallas(words_q, words_db, bits, k, top_k,
                              interpret=_interpret(), **kw)


def packed_topk_masked(words_q, words_db, valid_words, bits: int, k: int,
                       top_k: int, impl: str = "auto", **block_kwargs):
    """Streaming top-k over live rows only (packed validity bitmask)."""
    _rec("packed_topk_masked", words_q, words_db, q=words_q.shape[0],
         n=words_db.shape[0], w=words_q.shape[1], top_k=top_k)
    if _resolve(impl) == "ref":
        return _ref.packed_topk_masked_ref(words_q, words_db, valid_words,
                                           bits, k, top_k)
    kw = _tuned("packed_topk_masked", words_q.dtype, block_kwargs,
                q=words_q.shape[0], n=words_db.shape[0],
                w=words_q.shape[1], top_k=top_k)
    return packed_topk_masked_pallas(words_q, words_db, valid_words, bits, k,
                                     top_k, interpret=_interpret(), **kw)


def packed_lut_topk(q_tables, words_db, bits: int, top_k: int,
                    impl: str = "auto", **block_kwargs):
    """LUT-scored streaming top-k: [Q, F*P] float tables x [N, W] packed
    words -> (scores f32, ids int32) [Q, top_k]."""
    _rec("packed_lut_topk", q_tables, words_db, q=q_tables.shape[0],
         n=words_db.shape[0], w=words_db.shape[1], t=q_tables.shape[1],
         k=q_tables.shape[1] >> bits, top_k=top_k)
    if _resolve(impl) == "ref":
        return _ref.packed_lut_topk_ref(q_tables, words_db, bits, top_k)
    kw = _tuned("packed_lut_topk", q_tables.dtype, block_kwargs,
                q=q_tables.shape[0], n=words_db.shape[0],
                w=words_db.shape[1], t=q_tables.shape[1], top_k=top_k)
    return packed_lut_topk_pallas(q_tables, words_db, bits, top_k,
                                  interpret=_interpret(), **kw)


def packed_lut_topk_masked(q_tables, words_db, valid_words, bits: int,
                           top_k: int, impl: str = "auto", **block_kwargs):
    """LUT-scored streaming top-k over live rows only (packed bitmask)."""
    _rec("packed_lut_topk_masked", q_tables, words_db,
         q=q_tables.shape[0], n=words_db.shape[0], w=words_db.shape[1],
         t=q_tables.shape[1], k=q_tables.shape[1] >> bits, top_k=top_k)
    if _resolve(impl) == "ref":
        return _ref.packed_lut_topk_masked_ref(q_tables, words_db,
                                               valid_words, bits, top_k)
    kw = _tuned("packed_lut_topk_masked", q_tables.dtype, block_kwargs,
                q=q_tables.shape[0], n=words_db.shape[0],
                w=words_db.shape[1], t=q_tables.shape[1], top_k=top_k)
    return packed_lut_topk_masked_pallas(q_tables, words_db, valid_words,
                                         bits, top_k,
                                         interpret=_interpret(), **kw)


def packed_linear_fwd(tables, words, bits: int, impl: str = "auto",
                      **block_kwargs):
    """Packed-linear margins: class weight tables [C, F*P] float x
    packed words [N, W] -> float32 [C, N] (repro.learn forward)."""
    _rec("packed_linear_fwd", tables, words, c=tables.shape[0],
         n=words.shape[0], w=words.shape[1], t=tables.shape[1],
         k=tables.shape[1] >> bits)
    if _resolve(impl) == "ref":
        return _ref.packed_linear_fwd_ref(tables, words, bits)
    kw = _tuned("packed_linear_fwd", tables.dtype, block_kwargs,
                c=tables.shape[0], n=words.shape[0], t=tables.shape[1])
    return packed_linear_fwd_pallas(tables, words, bits,
                                    interpret=_interpret(), **kw)


def packed_linear_fwd_masked(tables, words, valid_words, bits: int,
                             impl: str = "auto", **block_kwargs):
    """Packed-linear margins over live rows only (packed bitmask);
    tombstoned rows emit margin 0.0."""
    _rec("packed_linear_fwd_masked", tables, words, c=tables.shape[0],
         n=words.shape[0], w=words.shape[1], t=tables.shape[1],
         k=tables.shape[1] >> bits)
    if _resolve(impl) == "ref":
        return _ref.packed_linear_fwd_masked_ref(tables, words, valid_words,
                                                 bits)
    kw = _tuned("packed_linear_fwd_masked", tables.dtype, block_kwargs,
                c=tables.shape[0], n=words.shape[0], t=tables.shape[1])
    return packed_linear_fwd_masked_pallas(tables, words, valid_words, bits,
                                           interpret=_interpret(), **kw)


def packed_linear_bwd(g, words, bits: int, impl: str = "auto",
                      **block_kwargs):
    """Weight-table gradients: margin gradients [C, N] float32 x packed
    words [N, W] -> float32 [C, F*P] (repro.learn backward)."""
    _rec("packed_linear_bwd", g, words, c=g.shape[0], n=words.shape[0],
         w=words.shape[1], t=(words.shape[1] * (32 // bits)) << bits,
         k=words.shape[1] * (32 // bits))
    if _resolve(impl) == "ref":
        return _ref.packed_linear_bwd_ref(g, words, bits, **block_kwargs)
    kw = _tuned("packed_linear_bwd", g.dtype, block_kwargs,
                c=g.shape[0], n=words.shape[0], w=words.shape[1])
    return packed_linear_bwd_pallas(g, words, bits, interpret=_interpret(),
                                    **kw)


def packed_linear_bwd_masked(g, words, valid_words, bits: int,
                             impl: str = "auto", **block_kwargs):
    """Weight-table gradients over live rows only: tombstoned rows'
    contributions are zeroed on device before the scatter."""
    _rec("packed_linear_bwd_masked", g, words, c=g.shape[0],
         n=words.shape[0], w=words.shape[1],
         t=(words.shape[1] * (32 // bits)) << bits,
         k=words.shape[1] * (32 // bits))
    if _resolve(impl) == "ref":
        return _ref.packed_linear_bwd_masked_ref(g, words, valid_words,
                                                 bits, **block_kwargs)
    kw = _tuned("packed_linear_bwd_masked", g.dtype, block_kwargs,
                c=g.shape[0], n=words.shape[0], w=words.shape[1])
    return packed_linear_bwd_masked_pallas(g, words, valid_words, bits,
                                           interpret=_interpret(), **kw)


def packed_lut_rerank(q_tables, cand_words, cand_valid, bits: int,
                      top_k: int, impl: str = "auto", **block_kwargs):
    """Re-rank gathered candidates [Q, M, W] by per-query LUT scores ->
    (scores f32, candidate positions int32) [Q, top_k]."""
    _rec("packed_lut_rerank", q_tables, cand_words,
         q=q_tables.shape[0], c=cand_words.shape[1],
         w=cand_words.shape[2], t=q_tables.shape[1],
         k=q_tables.shape[1] >> bits, top_k=top_k)
    if _resolve(impl) == "ref":
        return _ref.packed_lut_rerank_ref(q_tables, cand_words, cand_valid,
                                          bits, top_k)
    kw = _tuned("packed_lut_rerank", q_tables.dtype, block_kwargs,
                q=q_tables.shape[0], m=cand_words.shape[1],
                t=q_tables.shape[1], top_k=top_k)
    return packed_lut_rerank_pallas(q_tables, cand_words, cand_valid, bits,
                                    top_k, interpret=_interpret(), **kw)


def fused_scored_topk(q_words, q_tables, words_db, bits: int, k: int,
                      rerank_m: int, top_k: int, scales=None,
                      impl: str = "auto", **block_kwargs):
    """Single-pass scored search: exact stable coarse top-``rerank_m``
    by collision count, re-ranked by per-query LUT score, in one
    streamed kernel -> (scores f32, corpus ids int32) [Q, top_k].
    ``scales`` float32 [Q, W] (powers of two) selects the int8-table
    path."""
    _rec("fused_scored_topk", q_words, q_tables, words_db,
         q=q_words.shape[0], n=words_db.shape[0], w=q_words.shape[1],
         t=q_tables.shape[1], k=q_tables.shape[1] >> bits, top_k=top_k)
    if _resolve(impl) == "ref":
        return _ref.fused_scored_topk_ref(q_words, q_tables, words_db,
                                          bits, k, rerank_m, top_k,
                                          scales=scales)
    kw = _tuned("fused_scored_topk", q_tables.dtype, block_kwargs,
                q=q_words.shape[0], n=words_db.shape[0],
                w=q_words.shape[1], t=q_tables.shape[1], top_k=top_k)
    return fused_scored_topk_pallas(q_words, q_tables, words_db, bits, k,
                                    rerank_m, top_k, scales=scales,
                                    interpret=_interpret(), **kw)


def fused_scored_topk_masked(q_words, q_tables, words_db, valid_words,
                             bits: int, k: int, rerank_m: int, top_k: int,
                             scales=None, impl: str = "auto",
                             **block_kwargs):
    """``fused_scored_topk`` over live rows only (packed row-validity
    bitmask) — the mutable-index segment path; all-dead segments return
    pure (-inf, -1) sentinels."""
    _rec("fused_scored_topk_masked", q_words, q_tables, words_db,
         q=q_words.shape[0], n=words_db.shape[0], w=q_words.shape[1],
         t=q_tables.shape[1], k=q_tables.shape[1] >> bits, top_k=top_k)
    if _resolve(impl) == "ref":
        return _ref.fused_scored_topk_masked_ref(
            q_words, q_tables, words_db, valid_words, bits, k, rerank_m,
            top_k, scales=scales)
    kw = _tuned("fused_scored_topk_masked", q_tables.dtype, block_kwargs,
                q=q_words.shape[0], n=words_db.shape[0],
                w=q_words.shape[1], t=q_tables.shape[1], top_k=top_k)
    return fused_scored_topk_masked_pallas(
        q_words, q_tables, words_db, valid_words, bits, k, rerank_m,
        top_k, scales=scales, interpret=_interpret(), **kw)
