"""One-call exporters: obs state -> JSON snapshot / Prometheus text.

``snapshot()`` folds the metrics registry (counters, gauges, histogram
summaries with derived p50/p95/p99) and the kernel dispatch stats into
one plain dict; ``dump_json`` writes it. ``to_prometheus`` renders the
registry in the Prometheus text exposition format (counters as
``_total``, histograms as cumulative ``_bucket{le=...}`` series plus
``_sum``/``_count``), so a scrape endpoint is one ``web.Response`` away.
Metric names are sanitized (dots -> underscores) for Prometheus only;
the JSON snapshot keeps the dotted names the code uses.
"""
from __future__ import annotations

import json

from repro.obs import kernelstats as _kstats
from repro.obs.registry import MetricsRegistry, default_registry

__all__ = ["snapshot", "dump_json", "to_prometheus"]


def snapshot(registry: MetricsRegistry = None, kernels=None,
             hw=None) -> dict:
    """Everything observable as one dict: registry metrics + kernel
    dispatch totals + the modeled roofline table. ``registry`` defaults
    to the process-global one, ``kernels`` to the global accumulator."""
    reg = registry if registry is not None else default_registry()
    ks = kernels if kernels is not None else _kstats.get_kernel_stats()
    out = reg.snapshot()
    out["kernels"] = ks.snapshot()
    out["roofline"] = ks.roofline_table(hw)
    return out


def dump_json(path: str, registry: MetricsRegistry = None,
              kernels=None) -> str:
    """Write ``snapshot()`` as JSON to ``path``; returns ``path``."""
    with open(path, "w") as f:
        json.dump(snapshot(registry, kernels), f, indent=1)
    return path


def _sanitize(name: str) -> str:
    return name.replace(".", "_").replace("-", "_")


def _escape_label(value) -> str:
    """Escape one label value per the OpenMetrics/Prometheus text
    exposition spec: backslash, double-quote, and newline must be
    escaped inside quoted label values (a hostile trace id must not be
    able to forge extra labels or break the exposition line)."""
    return (str(value).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def to_prometheus(registry: MetricsRegistry = None) -> str:
    """Render the registry in Prometheus text exposition format.

    Conventional series shapes: every finite bucket bound is emitted —
    empty ones included — so the cumulative ``_bucket{le=...}`` series
    is complete and monotone and keeps the *same* label set across
    scrapes (rate()/histogram_quantile() break on appearing/disappearing
    ``le`` labels); each metric carries a ``# HELP`` line (the dotted
    registry name, which is how the code refers to it) ahead of its
    ``# TYPE``. Histogram buckets holding an exemplar (a retained
    flight-recorder trace pinned via ``Histogram.exemplar``) carry an
    OpenMetrics-style annotation ``# {trace_id="..."} <value>`` — the
    link from a latency bucket back to the concrete trace that landed
    there. Label values are escaped per the OpenMetrics spec
    (backslash, double-quote, newline), so a hostile trace id cannot
    forge labels or split the exposition line.
    """
    reg = registry if registry is not None else default_registry()
    lines = []
    for name, c in sorted(reg.counters.items()):
        n = _sanitize(name)
        lines.append(f"# HELP {n}_total counter '{name}'")
        lines.append(f"# TYPE {n}_total counter")
        lines.append(f"{n}_total {c.value}")
    for name, g in sorted(reg.gauges.items()):
        n = _sanitize(name)
        lines.append(f"# HELP {n} gauge '{name}'")
        lines.append(f"# TYPE {n} gauge")
        lines.append(f"{n} {g.value}")
    for name, h in sorted(reg.histograms.items()):
        n = _sanitize(name)
        lines.append(f"# HELP {n} histogram '{name}'")
        lines.append(f"# TYPE {n} histogram")
        cum = 0
        for i, cnt in enumerate(h.counts):
            cum += cnt
            le = h.spec.bucket_bounds(i)[1]
            ex = h.exemplars.get(i)
            tail = (f' # {{trace_id="{_escape_label(ex[1])}"}} '
                    f'{ex[0]:.6g}' if ex is not None else "")
            lines.append(f'{n}_bucket{{le="{le:.6g}"}} {cum}{tail}')
        lines.append(f'{n}_bucket{{le="+Inf"}} {h.count}')
        lines.append(f"{n}_sum {h.total}")
        lines.append(f"{n}_count {h.count}")
    return "\n".join(lines) + "\n"
