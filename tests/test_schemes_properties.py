"""Hypothesis property tests on scheme/packing/estimator invariants.

Runs under real hypothesis when installed, otherwise under the seeded
sampling shim in ``_hypothesis_compat`` — never skipped either way.
"""
import numpy as np
import jax.numpy as jnp

from _hypothesis_compat import given, settings, strategies as st

from repro.core import packing as PK
from repro.core import schemes as S
from repro.core.estimators import CollisionEstimator
from repro.core.probabilities import collision_prob

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

# width=32 + no subnormals: the encoders run in f32 and XLA flushes
# denormals to zero, so generate values exactly representable there
W = st.floats(min_value=0.125, max_value=8.0, width=32)
VALS = st.lists(st.floats(min_value=-20, max_value=20,
                          allow_subnormal=False, width=32),
                min_size=1, max_size=64)


@given(VALS, W)
def test_uniform_codes_in_range(vals, w):
    spec = S.CodeSpec("uniform", w)
    codes = np.asarray(S.encode(jnp.asarray(vals), spec))
    assert codes.min() >= 0 and codes.max() < spec.n_codes
    assert spec.n_codes <= 2 ** spec.bits


@given(VALS, W, st.integers(0, 2 ** 31 - 1))
def test_offset_codes_in_range(vals, w, seed):
    import jax
    spec = S.CodeSpec("offset", w)
    q = S.sample_offsets(jax.random.PRNGKey(seed), len(vals), w)
    codes = np.asarray(S.encode(jnp.asarray(vals), spec, q))
    assert codes.min() >= 0 and codes.max() < spec.n_codes


@given(VALS, W)
def test_2bit_region_semantics(vals, w):
    codes = np.asarray(S.encode_2bit(jnp.asarray(vals), w))
    w32 = float(np.float32(w))    # encoder compares in f32 on both sides
    for v, c in zip(vals, codes):
        v = float(np.float32(v))  # (denormals -> +-0.0, ties round f32)
        want = 0 if v < -w32 else 1 if v < 0 else 2 if v < w32 else 3
        assert c == want


@given(st.integers(1, 4), st.integers(1, 200), st.integers(0, 2 ** 31 - 1))
def test_pack_unpack_roundtrip(bits_pow, k, seed):
    bits = [1, 2, 4, 8][bits_pow - 1]
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 1 << bits, size=(3, k)).astype(np.int32)
    packed = PK.pack_codes(jnp.asarray(codes), bits)
    assert packed.shape[-1] == PK.packed_width(k, bits)
    back = np.asarray(PK.unpack_codes(packed, bits, k))
    np.testing.assert_array_equal(back, codes)


@given(st.integers(1, 128), st.integers(0, 2 ** 31 - 1))
def test_1bit_match_count_equals_direct(k, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2, size=(k,)).astype(np.int32)
    b = rng.integers(0, 2, size=(k,)).astype(np.int32)
    pa = PK.pack_codes(jnp.asarray(a[None]), 1)
    pb = PK.pack_codes(jnp.asarray(b[None]), 1)
    got = int(PK.match_count_packed_1bit(pa, pb, k)[0])
    assert got == int(np.sum(a == b))


@given(st.sampled_from(["uniform", "offset", "2bit", "sign"]),
       st.floats(0.3, 4.0), st.floats(0.0, 0.99))
def test_estimator_inverts_probability(scheme, w, rho):
    est = CollisionEstimator(scheme, w, grid_size=2048)
    p = float(collision_prob(jnp.asarray(rho), w, scheme))
    rho_hat = float(est(p))
    assert abs(rho_hat - rho) < 0.01, (scheme, w, rho, rho_hat)
