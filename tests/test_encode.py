"""repro.encode: matrix-free streaming ingest, CSR inputs,
pipeline/bulk-load, and the sketch reproducibility invariants. Encode
kernel-vs-oracle bit-exactness lives in test_kernel_conformance.py."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.ann import AnnEngine, BandSpec, CodeStore
from repro.core import packing as _packing
from repro.core.schemes import CodeSpec, sample_offsets
from repro.core.sketch import (CodedRandomProjection, OFFSET_KEY_TAG,
                               SketchConfig)
from repro.encode import (CsrMatrix, IngestPipeline, StreamingEncoder,
                          encode_sharded, unit_buckets)
from repro.index import MutableAnnEngine, SegmentLogStore
from repro.kernels import ops, ref
from repro.serve.ann_service import AnnService, AnnServiceConfig

SCHEMES = [("uniform", 1.0), ("2bit", 0.75), ("sign", 1.0), ("offset", 1.0)]
SHAPES = [(8, 64, 32), (33, 700, 77), (100, 513, 128), (5, 100, 17)]


def _unpacked_mismatches(got, want, bits, k):
    ga = _packing.unpack_codes(got, bits, k)
    wa = _packing.unpack_codes(want, bits, k)
    return int(jnp.sum(ga != wa))


def test_ops_dispatch_agrees():
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (20, 130))
    r = jax.random.normal(jax.random.fold_in(key, 1), (130, 33))
    spec = CodeSpec("2bit", 0.75)
    np.testing.assert_array_equal(
        np.asarray(ops.encode_fused(x, r, spec, impl="ref")),
        np.asarray(ops.encode_fused(x, r, spec, impl="pallas",
                                    block_m=16, block_d=32)))
    z = x[:, :33]
    np.testing.assert_array_equal(
        np.asarray(ops.code_pack(z, spec, impl="ref")),
        np.asarray(ops.code_pack(z, spec, impl="pallas", block_m=16)))


# -- reproducibility invariants ----------------------------------------------

def _sparse_corpus(rng, n, d, density=0.01):
    x = np.zeros((n, d), np.float32)
    nz = rng.random((n, d)) < density
    x[nz] = rng.normal(size=int(nz.sum())).astype(np.float32)
    return x


@pytest.mark.parametrize("scheme,w", SCHEMES)
def test_streaming_paths_bit_identical(rng, scheme, w):
    """Same seed => identical packed words: oracle vs fused-kernel path
    vs forced matrix-free streaming vs CSR input, at multi-unit D."""
    d, k = 5000, 32
    crp = CodedRandomProjection(
        SketchConfig(k=k, scheme=scheme, w=w, seed=11, r_unit=2048), d)
    x = jnp.asarray(_sparse_corpus(rng, 24, d, 0.02))
    oracle = crp.sketch_oracle(x)
    fused = StreamingEncoder(crp).encode_packed(x)
    streamed = StreamingEncoder(crp, r_cap_elems=1).encode_packed(x)
    csr = StreamingEncoder(crp).encode_packed(
        CsrMatrix.from_dense(np.asarray(x)))
    for got in (fused, streamed, csr):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(oracle))
    np.testing.assert_array_equal(np.asarray(crp.sketch(x)),
                                  np.asarray(oracle))


def test_block_d_is_not_part_of_sketch_identity(rng):
    """block_d is a streaming knob only: any choice yields the same R,
    codes and packed words (generation is keyed per r_unit)."""
    d, k = 9000, 16
    x = jnp.asarray(rng.normal(size=(8, d)).astype(np.float32))
    base = None
    for block_d in (512, 4096, 16384):
        crp = CodedRandomProjection(
            SketchConfig(k=k, scheme="2bit", w=0.75, seed=2,
                         block_d=block_d), d)
        words = np.asarray(crp.sketch_oracle(x))
        if base is None:
            base = words
        else:
            np.testing.assert_array_equal(words, base)


def test_encode_sharded_matches_unsharded(rng):
    d, k = 5000, 32
    crp = CodedRandomProjection(
        SketchConfig(k=k, scheme="2bit", w=0.75, seed=4, r_unit=2048), d)
    enc = StreamingEncoder(crp)
    x = jnp.asarray(rng.normal(size=(16, d)).astype(np.float32))
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    got = encode_sharded(enc, x, mesh)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(crp.sketch_oracle(x)))


def test_offset_key_disjoint_from_unit_keys():
    """Regression: offsets used fold_in(key, 0xFFFF), colliding with
    projection unit 65535; the offset key now lives at a tag strictly
    above every admissible unit index."""
    crp = CodedRandomProjection(SketchConfig(k=8, scheme="offset", w=1.0),
                                256)
    off = np.asarray(crp.offset_key())
    # the old collision: unit 65535's key IS fold_in(key, 0xFFFF)
    old = np.asarray(jax.random.fold_in(crp._key, 0xFFFF))
    unit_65535 = np.asarray(jax.random.fold_in(crp._key, 65535))
    np.testing.assert_array_equal(old, unit_65535)
    for u in (0, 1, 65535, 2 ** 20, OFFSET_KEY_TAG - 1):
        assert not np.array_equal(
            off, np.asarray(jax.random.fold_in(crp._key, u))), u


def test_unit_key_guard_rejects_absurd_d():
    with pytest.raises(ValueError):
        CodedRandomProjection(SketchConfig(k=4, r_unit=1), OFFSET_KEY_TAG)


# -- never materialize R at large D ------------------------------------------

def test_large_d_encode_never_builds_r(rng):
    """D ≥ 1M: R would be d*k = 8.4M elements; the encoder streams it in
    r_unit slabs (capped buffer), never concatenating the full matrix."""
    d, k = 1 << 20, 8
    crp = CodedRandomProjection(
        SketchConfig(k=k, scheme="2bit", w=0.75, seed=9), d)
    enc = StreamingEncoder(crp, r_cap_elems=1 << 22)
    assert not enc.r_resident
    with pytest.raises(ValueError):
        enc.r_matrix()
    x = jnp.asarray(rng.normal(size=(2, d)).astype(np.float32))
    words = enc.encode_packed(x)
    assert enc._rmat is None           # nothing cached, nothing built
    assert enc.r_slab_elems == crp.cfg.r_unit * k
    assert enc.r_slab_elems * 256 <= d * k   # slab is >=256x below full R
    np.testing.assert_array_equal(np.asarray(words),
                                  np.asarray(crp.sketch_oracle(x)))


def test_query_coder_streams_above_cap(rng):
    """QueryCoder at large D: r_matrix refuses, encode still serves."""
    d, k = 1 << 20, 8
    crp = CodedRandomProjection(
        SketchConfig(k=k, scheme="2bit", w=0.75, seed=9), d)
    from repro.ann.engine import QueryCoder
    coder = QueryCoder(crp)
    coder._encoder.r_cap_elems = 1 << 22
    with pytest.raises(ValueError):
        coder.r_matrix()
    x = jnp.asarray(rng.normal(size=(2, d)).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(coder.encode(x)),
                                  np.asarray(crp.encode(x)))


# -- CSR container ------------------------------------------------------------

def test_csr_roundtrip_and_slicing(rng):
    x = _sparse_corpus(rng, 17, 200, 0.05)
    csr = CsrMatrix.from_dense(x)
    np.testing.assert_array_equal(csr.densify(), x)
    np.testing.assert_array_equal(csr.row_slice(3, 11).densify(), x[3:11])
    assert csr.row_slice(0, 0).nnz == 0
    units, rows, lcols, vals = unit_buckets(csr, 64)
    assert len(units) == len(rows) == len(lcols) == len(vals)
    for r, c, v in zip(rows, lcols, vals):
        assert r.shape == c.shape == v.shape
        assert r.size == 1 << (r.size - 1).bit_length()   # pow2 per unit
    assert all(0 <= u < 200 // 64 + 1 for u in units)


def test_csr_empty_rows_and_empty_matrix():
    d, k = 3000, 16
    crp = CodedRandomProjection(
        SketchConfig(k=k, scheme="2bit", w=0.75, r_unit=1024), d)
    enc = StreamingEncoder(crp, r_cap_elems=1)
    x = np.zeros((5, d), np.float32)
    x[2, 7] = 1.5                       # rows 0,1,3,4 are all-zero
    got = enc.encode_packed(CsrMatrix.from_dense(x))
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(crp.sketch_oracle(jnp.asarray(x))))
    empty = CsrMatrix.from_dense(np.zeros((3, d), np.float32))
    got0 = enc.encode_packed(empty)
    np.testing.assert_array_equal(
        np.asarray(got0),
        np.asarray(crp.sketch_oracle(jnp.zeros((3, d)))))


def test_csr_validation():
    with pytest.raises(ValueError):
        CsrMatrix(indptr=np.array([0, 1], np.int64),
                  indices=np.array([5], np.int32),
                  data=np.array([1.0], np.float32), shape=(1, 4))
    with pytest.raises(ValueError):
        CsrMatrix(indptr=np.array([0, 2], np.int64),
                  indices=np.array([0], np.int32),
                  data=np.array([1.0], np.float32), shape=(1, 4))


# -- pipeline / stores --------------------------------------------------------

def _corpus(rng, n, d):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def test_pipeline_into_code_store(rng):
    d, k = 300, 64
    crp = CodedRandomProjection(SketchConfig(k=k, scheme="2bit", w=0.75), d)
    x = _corpus(rng, 500, d)
    oracle = crp.sketch_oracle(jnp.asarray(x))
    store = CodeStore.from_words(
        jnp.zeros((0, oracle.shape[1]), jnp.uint32), k, crp.spec.bits)
    pipe = IngestPipeline(StreamingEncoder(crp), store, chunk_rows=128)
    ids = pipe.ingest(x)
    assert pipe.store.n == 500 and pipe.stats["chunks"] == 4
    np.testing.assert_array_equal(np.asarray(ids), np.arange(500))
    np.testing.assert_array_equal(np.asarray(pipe.store.words),
                                  np.asarray(oracle))


def test_pipeline_into_segment_log_matches_add_codes(rng):
    d, k = 300, 64
    crp = CodedRandomProjection(SketchConfig(k=k, scheme="2bit", w=0.75), d)
    x = _corpus(rng, 300, d)
    log_a = SegmentLogStore(k, crp.spec.bits, band_spec=BandSpec(8, 4),
                            tail_rows=128)
    log_a.add_codes(crp.encode(jnp.asarray(x)))
    log_b = SegmentLogStore(k, crp.spec.bits, band_spec=BandSpec(8, 4),
                            tail_rows=128)
    IngestPipeline(StreamingEncoder(crp), log_b, chunk_rows=100).ingest(x)
    np.testing.assert_array_equal(np.asarray(log_a.live_words()),
                                  np.asarray(log_b.live_words()))
    for sa, sb in zip(log_a.segments(), log_b.segments()):
        if sa.hashes is not None:
            np.testing.assert_array_equal(np.asarray(sa.hashes),
                                          np.asarray(sb.hashes))


def test_mutable_engine_ingest_search_matches_add(rng):
    d, k = 200, 64
    crp = CodedRandomProjection(SketchConfig(k=k, scheme="2bit", w=0.75), d)
    x = _corpus(rng, 400, d)
    eng_a = MutableAnnEngine(crp, tail_rows=128)
    eng_a.add(jnp.asarray(x))
    eng_b = MutableAnnEngine(crp, tail_rows=128)
    ids = eng_b.ingest(x, chunk_rows=150)
    assert ids.shape == (400,)
    q = jnp.asarray(x[:20])
    ids_a, rho_a = eng_a.search(q, top_k=5)
    ids_b, rho_b = eng_b.search(q, top_k=5)
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))
    np.testing.assert_allclose(np.asarray(rho_a), np.asarray(rho_b))


def test_ingest_bad_ids_is_atomic(rng):
    """A cross-chunk id clash must be rejected before ANY chunk lands —
    a mid-loop failure would leave the store partially mutated."""
    d, k = 100, 64
    crp = CodedRandomProjection(SketchConfig(k=k, scheme="2bit", w=0.75), d)
    x = _corpus(rng, 8, d)
    eng = MutableAnnEngine(crp, tail_rows=32)
    bad = np.array([0, 1, 2, 3, 0, 5, 6, 7])       # dup across chunks
    with pytest.raises(ValueError):
        eng.ingest(x, ids=bad, chunk_rows=4)
    assert eng.store.n_live == 0 and eng.generation == 0
    eng.ingest(x[:4], ids=np.arange(4), chunk_rows=4)
    with pytest.raises(ValueError):                 # clash with live ids
        eng.ingest(x[4:], ids=np.array([3, 8, 9, 10]), chunk_rows=2)
    assert eng.store.n_live == 4


def test_service_bulk_load(rng):
    d, k = 200, 64
    crp = CodedRandomProjection(SketchConfig(k=k, scheme="2bit", w=0.75), d)
    x = _corpus(rng, 300, d)
    eng = MutableAnnEngine(crp, tail_rows=128)
    svc = AnnService(eng, AnnServiceConfig(top_k=5))
    gen0 = eng.generation
    ids = svc.bulk_load(x, chunk_rows=128)
    assert ids.shape == (300,) and eng.generation > gen0
    t = svc.submit(x[7])
    res = svc.flush()
    assert int(res[t][0][0]) == 7          # self-neighbor retrieved
    # immutable engines have no mutation endpoints
    store = CodeStore.from_codes(crp.encode(jnp.asarray(x)), k,
                                 crp.spec.bits)
    svc2 = AnnService(AnnEngine(crp, store))
    with pytest.raises(TypeError):
        svc2.bulk_load(x)


def test_add_words_matches_add_codes_with_bands(rng):
    d, k = 100, 32
    crp = CodedRandomProjection(SketchConfig(k=k, scheme="2bit", w=0.75), d)
    codes = crp.encode(jnp.asarray(_corpus(rng, 64, d)))
    words = crp.pack(codes)
    log_a = SegmentLogStore(k, 2, band_spec=BandSpec(4, 4), tail_rows=32)
    log_a.add_codes(codes)
    log_b = SegmentLogStore(k, 2, band_spec=BandSpec(4, 4), tail_rows=32)
    log_b.add_words(words)
    for sa, sb in zip(log_a.segments(), log_b.segments()):
        np.testing.assert_array_equal(np.asarray(sa.words),
                                      np.asarray(sb.words))
        np.testing.assert_array_equal(np.asarray(sa.hashes),
                                      np.asarray(sb.hashes))
    with pytest.raises(ValueError):
        log_b.add_words(words[:, :-1])


def test_code_store_add_words(rng):
    d, k = 100, 32
    crp = CodedRandomProjection(SketchConfig(k=k, scheme="2bit", w=0.75), d)
    codes = crp.encode(jnp.asarray(_corpus(rng, 48, d)))
    words = crp.pack(codes)
    s = CodeStore.from_codes(codes[:16], k, 2).add_words(words[16:])
    np.testing.assert_array_equal(np.asarray(s.words), np.asarray(words))


# -- paper-scale sparse ingest (slow) ----------------------------------------

@pytest.mark.slow
def test_url_scale_sparse_ingest():
    """D = 3.2M CSR ingest (the paper's §7 URL regime): matrix-free
    streaming into a segment log — [D, k] never exists, packed words
    are chunking-invariant, and a dense single-row oracle (touched
    units only; untouched units contribute an exact float zero) pins
    bit-exactness at full scale."""
    rng = np.random.default_rng(0)
    d, k, n, nnz_row = 3_200_000, 16, 48, 24
    crp = CodedRandomProjection(
        SketchConfig(k=k, scheme="2bit", w=0.75, seed=1), d)
    cols = np.sort(rng.choice(d, size=(n, nnz_row), replace=True), axis=1)
    vals = rng.normal(size=(n, nnz_row)).astype(np.float32)
    # dedupe columns within a row (choice may repeat): keep first
    keep = np.concatenate([np.ones((n, 1), bool),
                           np.diff(cols, axis=1) != 0], axis=1)
    indptr = np.concatenate([[0], np.cumsum(keep.sum(1))]).astype(np.int64)
    csr = CsrMatrix(indptr=indptr, indices=cols[keep].astype(np.int32),
                    data=vals[keep], shape=(n, d))
    enc = StreamingEncoder(crp)
    assert not enc.r_resident          # 51.2M elements >> cap
    with pytest.raises(ValueError):
        enc.r_matrix()
    log = SegmentLogStore(k, crp.spec.bits, tail_rows=32)
    IngestPipeline(enc, log, chunk_rows=32).ingest(csr)
    got = np.asarray(log.live_words())
    assert got.shape == (n, _packing.packed_width(k, crp.spec.bits))
    # chunking invariance: a different chunk size, same packed words
    log2 = SegmentLogStore(k, crp.spec.bits, tail_rows=32)
    IngestPipeline(enc, log2, chunk_rows=16).ingest(csr)
    np.testing.assert_array_equal(got, np.asarray(log2.live_words()))
    # dense oracle for one row, eagerly unit-by-unit over touched units
    i = 0
    sl = slice(int(csr.indptr[i]), int(csr.indptr[i + 1]))
    ru = crp.cfg.r_unit
    z = jnp.zeros((1, k))
    for u in sorted(set(int(c) // ru for c in csr.indices[sl])):
        width = crp.unit_width(u)
        xe = np.zeros((1, width), np.float32)
        inu = (csr.indices[sl] // ru) == u
        xe[0, csr.indices[sl][inu] - u * ru] = csr.data[sl][inu]
        z = z + jnp.asarray(xe) @ crp._block_r(u, width)
    want = np.asarray(crp.pack(crp.encode_projected(z)))[0]
    np.testing.assert_array_equal(got[i], want)
