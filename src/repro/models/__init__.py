from repro.models.lm import (  # noqa: F401
    ModelConfig, model_param_specs, forward, lm_loss, init_caches,
    decode_step, prefill,
)
from repro.models.nn import init_params, abstract_params, param_shardings  # noqa: F401
