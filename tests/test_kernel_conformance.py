"""Cross-kernel conformance suite: every Pallas kernel family vs its
``kernels/ref.py`` oracle over one shared differential grid.

This file replaces the ad-hoc per-subsystem bit-exactness tests that
used to live in test_kernels/test_ann/test_rank/test_learn/test_encode:
one grid (all schemes x 1/2/4-bit packing x odd / non-power-of-2 shapes
x random tombstone densities x f32/bf16/int8 tables), one assertion
style (bit-exact, values AND tie-broken ids), every family held to it.
Kernels run in interpret mode with deliberately small block sizes so
row/word/query padding and multi-tile carry paths are always exercised.

The fused single-pass scored kernel gets the deepest treatment: it is
checked against its own oracle (``fused_scored_topk_ref``), against the
two-stage pipeline it replaces (``two_stage_scored_ref`` — the
coarse-top-m + LUT-re-rank semantics are the contract), and against a
block-size-invariance property (results must not depend on the tile
shape) driven through ``_hypothesis_compat``.

The quick subgrid runs by default; the full grid rides behind the
``slow`` marker (still part of tier-1 — the marker only lets a fast
iteration loop deselect it with ``-m "not slow"``).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, strategies as st

from repro.core import packing as PK
from repro.core.schemes import CodeSpec, sample_offsets
from repro.kernels import ops, ref
from repro.kernels.collision import collision_counts_pallas
from repro.kernels.encode_fused import code_pack_pallas, encode_fused_pallas
from repro.kernels.fused_scored import (fused_scored_topk_masked_pallas,
                                        fused_scored_topk_pallas)
from repro.kernels.pack_codes import pack_codes_pallas
from repro.kernels.packed_collision import (packed_collision_counts_pallas,
                                            packed_topk_masked_pallas,
                                            packed_topk_pallas)
from repro.kernels.packed_linear import (packed_linear_bwd_masked_pallas,
                                         packed_linear_bwd_pallas,
                                         packed_linear_fwd_masked_pallas,
                                         packed_linear_fwd_pallas)
from repro.kernels.packed_lut import (packed_lut_rerank_pallas,
                                      packed_lut_topk_masked_pallas,
                                      packed_lut_topk_pallas)

slow = pytest.mark.slow

# -- the shared grid ----------------------------------------------------------
# scheme, bin width -> packed field width 1/2/4 bits (CodeSpec.bits)
SCHEMES = [
    pytest.param("sign", 1.0, id="sign-1b"),
    pytest.param("2bit", 0.75, id="2bit-2b"),
    pytest.param("uniform", 1.0, marks=slow, id="uniform-4b"),
    pytest.param("offset", 1.5, marks=slow, id="offset-4b"),
]
# (q, n, k): odd / non-power-of-2 everywhere, k never divides 32/bits
SHAPES = [
    pytest.param(3, 37, 17, id="3x37x17"),
    pytest.param(5, 130, 33, id="5x130x33"),
    pytest.param(8, 130, 64, marks=slow, id="8x130x64"),
    pytest.param(2, 33, 96, marks=slow, id="2x33x96"),
]
DENSITIES = [
    pytest.param(0.0, id="all-dead"),
    pytest.param(0.35, id="sparse"),
    pytest.param(1.0, id="all-live"),
]
TABLE_DTYPES = [
    pytest.param("f32", id="f32"),
    pytest.param("bf16", id="bf16"),
    pytest.param("int8", id="int8"),
]
BITS = [1, 2, pytest.param(4, marks=slow)]


def _codes(key, shape, bits):
    return jax.random.randint(key, shape, 0, 1 << bits)


def _tables(key, q, k, bits, table_dtype):
    """Random per-query LUTs in the flat [Q, F*P] layout the kernels
    take; int8 comes with power-of-two scales (the dtype's contract)."""
    fp = PK.packed_width(k, bits) * PK.codes_per_word(bits) * (1 << bits)
    n_words = PK.packed_width(k, bits)
    t = jax.random.normal(key, (q, fp), jnp.float32)
    if table_dtype == "bf16":
        return t.astype(jnp.bfloat16), None
    if table_dtype == "int8":
        ti = jax.random.randint(key, (q, fp), -127, 128).astype(jnp.int8)
        scales = jnp.exp2(jax.random.randint(
            jax.random.fold_in(key, 1), (q, n_words), -8, 2)
            .astype(jnp.float32))
        return ti, scales
    return t, None


def _mask(key, n, density):
    flags = jax.random.bernoulli(key, density, (n,))
    return flags, PK.pack_bitmask(flags)


def _eq(got, want, label=""):
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want), label)


def _eq_pairs(got, want, label=""):
    for g, w in zip(got, want):
        _eq(g, w, label)


# -- encode path: project -> code -> pack -------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32,
                                   pytest.param(jnp.bfloat16, marks=slow)],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("scheme,w", SCHEMES)
@pytest.mark.parametrize("q,n,k", SHAPES)
def test_coded_project_conformance(scheme, w, q, n, k, dtype):
    m, d = n, max(q * 8, 24)            # reuse grid dims as [m, d, k]
    key = jax.random.PRNGKey(m * 7 + k)
    x = jax.random.normal(key, (m, d), dtype)
    r = jax.random.normal(jax.random.fold_in(key, 1), (d, k), dtype)
    off = sample_offsets(jax.random.fold_in(key, 2), k, w)
    spec = CodeSpec(scheme, w)
    got = ops.coded_project(x, r, spec, off, impl="pallas", block_m=32,
                            block_k=32, block_d=64)
    want = ref.coded_project_ref(x, r, spec, off)
    # floor() at bin boundaries can flip one ulp between accumulation
    # orders for bf16 inputs; allow a vanishing fraction there
    tol = 0 if dtype == jnp.float32 else max(2, int(0.001 * got.size))
    mism = int(jnp.sum(got != want))
    assert mism <= tol, f"{mism}/{got.size} mismatches"


@pytest.mark.parametrize("dtype", [jnp.float32,
                                   pytest.param(jnp.bfloat16, marks=slow)],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("scheme,w", SCHEMES)
@pytest.mark.parametrize("q,n,k", SHAPES)
def test_encode_fused_conformance(scheme, w, q, n, k, dtype):
    m, d = n, max(q * 8, 24)
    key = jax.random.PRNGKey(m * 13 + k)
    x = jax.random.normal(key, (m, d), dtype)
    r = jax.random.normal(jax.random.fold_in(key, 1), (d, k), dtype)
    off = sample_offsets(jax.random.fold_in(key, 2), k, w)
    spec = CodeSpec(scheme, w)
    got = encode_fused_pallas(x, r, spec, off, interpret=True,
                              block_m=32, block_d=64)
    want = ref.encode_fused_ref(x, r, spec, off)
    assert got.shape == want.shape == (m, PK.packed_width(k, spec.bits))
    if dtype == jnp.float32:
        _eq(got, want)
    else:
        cg = PK.unpack_codes(got, spec.bits, k)
        cw = PK.unpack_codes(want, spec.bits, k)
        mism = int(jnp.sum(cg != cw))
        assert mism <= max(2, int(0.001 * m * k)), mism


@pytest.mark.parametrize("scheme,w", SCHEMES)
@pytest.mark.parametrize("q,n,k", SHAPES)
def test_code_pack_conformance(scheme, w, q, n, k):
    m = n
    key = jax.random.PRNGKey(m + k)
    z = jax.random.normal(key, (m, k)) * 2.0
    off = sample_offsets(jax.random.fold_in(key, 1), k, w)
    spec = CodeSpec(scheme, w)
    _eq(code_pack_pallas(z, spec, off, interpret=True, block_m=32),
        ref.code_pack_ref(z, spec, off))


@pytest.mark.parametrize("bits", BITS + [pytest.param(8, marks=slow)])
@pytest.mark.parametrize("q,n,k", SHAPES)
def test_pack_codes_conformance(bits, q, n, k):
    m = n
    codes = _codes(jax.random.PRNGKey(bits * 31 + m), (m, k), bits)
    _eq(pack_codes_pallas(codes, bits, interpret=True, block_m=32),
        ref.pack_codes_ref(codes, bits))


# -- collision counting -------------------------------------------------------

@pytest.mark.parametrize("q,n,k", SHAPES)
def test_collision_counts_conformance(q, n, k):
    key = jax.random.PRNGKey(q * n)
    cq = _codes(key, (q, k), 2)
    cdb = _codes(jax.random.fold_in(key, 1), (n, k), 2)
    _eq(collision_counts_pallas(cq, cdb, interpret=True, block_q=32,
                                block_n=32, block_k=64),
        ref.collision_counts_ref(cq, cdb))


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("q,n,k", SHAPES)
def test_packed_collision_conformance(bits, q, n, k):
    """Packed XOR/popcount counts == unpacked oracle == packed ref,
    incl. K-padding (k never divides 32/bits on this grid)."""
    key = jax.random.PRNGKey(bits * 100 + q)
    cq, cdb = _codes(key, (q, k), bits), _codes(
        jax.random.fold_in(key, 1), (n, k), bits)
    wq, wdb = PK.pack_codes(cq, bits), PK.pack_codes(cdb, bits)
    want = ref.collision_counts_ref(cq, cdb)
    _eq(ref.packed_collision_ref(wq, wdb, bits, k), want, "ref")
    _eq(packed_collision_counts_pallas(wq, wdb, bits, k, block_q=8,
                                       block_n=16, block_w=2,
                                       interpret=True), want, "pallas")


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("q,n,k", SHAPES)
@pytest.mark.parametrize("top_k", [1, pytest.param(5, marks=slow), 50])
def test_packed_topk_conformance(bits, q, n, k, top_k):
    """Streaming top-k == full-matrix stable top-k, values AND
    tie-broken ids; top_k=50 > n=37 exercises (-1, -1) overflow."""
    key = jax.random.PRNGKey(k + top_k)
    wq = PK.pack_codes(_codes(key, (q, k), bits), bits)
    wdb = PK.pack_codes(_codes(jax.random.fold_in(key, 1), (n, k), bits),
                        bits)
    _eq_pairs(packed_topk_pallas(wq, wdb, bits, k, top_k, block_q=8,
                                 block_n=32, interpret=True),
              ref.packed_topk_ref(wq, wdb, bits, k, top_k))


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("q,n,k", SHAPES)
@pytest.mark.parametrize("density", DENSITIES)
def test_packed_topk_masked_conformance(bits, q, n, k, density):
    key = jax.random.PRNGKey(bits + int(density * 7))
    wq = PK.pack_codes(_codes(key, (q, k), bits), bits)
    wdb = PK.pack_codes(_codes(jax.random.fold_in(key, 1), (n, k), bits),
                        bits)
    flags, vwords = _mask(jax.random.fold_in(key, 9), n, density)
    got = packed_topk_masked_pallas(wq, wdb, vwords, bits, k, 8,
                                    block_q=8, block_n=32, interpret=True)
    _eq_pairs(got, ref.packed_topk_masked_ref(wq, wdb, vwords, bits, k, 8))
    dead = set(np.flatnonzero(~np.asarray(flags)))
    assert not (set(np.asarray(got[1]).ravel()) - {-1}) & dead


# -- LUT scoring --------------------------------------------------------------

@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("q,n,k", SHAPES)
@pytest.mark.parametrize("table_dtype", TABLE_DTYPES[:2])
def test_lut_topk_conformance(bits, q, n, k, table_dtype):
    key = jax.random.PRNGKey(q * k + bits)
    tab, _ = _tables(key, q, k, bits, table_dtype)
    wdb = PK.pack_codes(_codes(jax.random.fold_in(key, 1), (n, k), bits),
                        bits)
    _eq_pairs(packed_lut_topk_pallas(tab, wdb, bits, 7, interpret=True,
                                     block_q=8, block_n=32),
              ref.packed_lut_topk_ref(tab, wdb, bits, 7))


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("q,n,k", SHAPES[:2] + SHAPES[2:3])
@pytest.mark.parametrize("density", DENSITIES)
def test_lut_topk_masked_conformance(bits, q, n, k, density):
    key = jax.random.PRNGKey(bits * 5 + int(density * 7))
    tab, _ = _tables(key, q, k, bits, "f32")
    wdb = PK.pack_codes(_codes(jax.random.fold_in(key, 1), (n, k), bits),
                        bits)
    flags, vwords = _mask(jax.random.fold_in(key, 9), n, density)
    got = packed_lut_topk_masked_pallas(tab, wdb, vwords, bits, 7,
                                        interpret=True, block_q=8,
                                        block_n=32)
    _eq_pairs(got, ref.packed_lut_topk_masked_ref(tab, wdb, vwords, bits, 7))
    dead = set(np.flatnonzero(~np.asarray(flags)))
    assert not (set(np.asarray(got[1]).ravel()) - {-1}) & dead


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("table_dtype", TABLE_DTYPES[:2])
def test_lut_rerank_conformance(bits, table_dtype):
    """Candidate re-rank with random invalid (-1) slots."""
    q, n, m, k = 13, 130, 50, 33
    key = jax.random.PRNGKey(3 + bits)
    tab, _ = _tables(key, q, k, bits, table_dtype)
    wdb = PK.pack_codes(_codes(jax.random.fold_in(key, 1), (n, k), bits),
                        bits)
    cand_ids = jax.random.randint(jax.random.fold_in(key, 5), (q, m), -1, n)
    cand = jnp.take(wdb, jnp.clip(cand_ids, 0, n - 1), axis=0)
    valid = cand_ids >= 0
    _eq_pairs(packed_lut_rerank_pallas(tab, cand, valid, bits, 7,
                                       interpret=True, block_q=8,
                                       block_m=64),
              ref.packed_lut_rerank_ref(tab, cand, valid, bits, 7))


# -- packed-linear classifier kernels ----------------------------------------

@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("direction", ["fwd", "bwd"])
@pytest.mark.parametrize("density", [None] + DENSITIES)
def test_packed_linear_conformance(bits, direction, density):
    n_cls, n, k = 3, 130, 33
    key = jax.random.PRNGKey(bits * 11 + (0 if density is None
                                          else int(density * 7)))
    tab, _ = _tables(key, n_cls, k, bits, "f32")
    words = PK.pack_codes(
        _codes(jax.random.fold_in(key, 1), (n, k), bits), bits)
    g = jax.random.normal(jax.random.fold_in(key, 2), (n_cls, n))
    if density is None:
        if direction == "fwd":
            _eq(packed_linear_fwd_pallas(tab, words, bits, interpret=True,
                                         block_c=2, block_n=32),
                ref.packed_linear_fwd_ref(tab, words, bits))
        else:
            _eq(packed_linear_bwd_pallas(g, words, bits, interpret=True,
                                         block_c=2, block_n=32),
                ref.packed_linear_bwd_ref(g, words, bits, block_c=2,
                                          block_n=32))
        return
    flags, vw = _mask(jax.random.fold_in(key, 9), n, density)
    if direction == "fwd":
        got = packed_linear_fwd_masked_pallas(tab, words, vw, bits,
                                              interpret=True, block_c=2,
                                              block_n=32)
        _eq(got, ref.packed_linear_fwd_masked_ref(tab, words, vw, bits))
        assert (np.asarray(got)[:, ~np.asarray(flags)] == 0.0).all()
    else:
        got = packed_linear_bwd_masked_pallas(g, words, vw, bits,
                                              interpret=True, block_c=2,
                                              block_n=32)
        _eq(got, ref.packed_linear_bwd_masked_ref(g, words, vw, bits,
                                                  block_c=2, block_n=32))
        # masking == zeroing dead rows' gradients by hand
        g0 = jnp.where(jnp.asarray(flags)[None, :], g, 0.0)
        _eq(got, ref.packed_linear_bwd_ref(g0, words, bits, block_c=2,
                                           block_n=32))


# -- fused single-pass scored search ------------------------------------------

def _fused_problem(key, q, n, k, bits, table_dtype):
    wq = PK.pack_codes(_codes(key, (q, k), bits), bits)
    wdb = PK.pack_codes(_codes(jax.random.fold_in(key, 1), (n, k), bits),
                        bits)
    tab, scales = _tables(jax.random.fold_in(key, 2), q, k, bits,
                          table_dtype)
    return wq, wdb, tab, scales


@pytest.mark.parametrize("table_dtype", TABLE_DTYPES)
@pytest.mark.parametrize("q,n,k", SHAPES)
@pytest.mark.parametrize("bits", BITS)
def test_fused_scored_conformance(bits, q, n, k, table_dtype):
    """The single-pass kernel is bit-exact vs its oracle AND vs the
    two-stage coarse+re-rank pipeline it replaces (f32/bf16; the int8
    path has no two-stage counterpart — oracle only)."""
    m, top_k = max(5, n // 4), 7
    key = jax.random.PRNGKey(bits * 301 + q * n + k)
    wq, wdb, tab, scales = _fused_problem(key, q, n, k, bits, table_dtype)
    got = fused_scored_topk_pallas(wq, tab, wdb, bits, k, m, top_k,
                                   scales=scales, block_q=8, block_n=32,
                                   interpret=True)
    want = ref.fused_scored_topk_ref(wq, tab, wdb, bits, k, m, top_k,
                                     scales=scales)
    _eq_pairs(got, want, "kernel vs fused ref")
    if scales is None:
        _eq_pairs(want,
                  ref.two_stage_scored_ref(wq, tab, wdb, bits, k, m, top_k),
                  "fused ref vs two-stage ref")


@pytest.mark.parametrize("table_dtype", TABLE_DTYPES)
@pytest.mark.parametrize("density", DENSITIES)
@pytest.mark.parametrize("bits", BITS)
def test_fused_scored_masked_conformance(bits, density, table_dtype):
    """Masked variant under random tombstone bitmasks: kernel == oracle
    == masked two-stage; dead rows never surface."""
    q, n, k, m, top_k = 5, 130, 33, 20, 7
    key = jax.random.PRNGKey(bits * 17 + int(density * 7))
    wq, wdb, tab, scales = _fused_problem(key, q, n, k, bits, table_dtype)
    flags, vwords = _mask(jax.random.fold_in(key, 9), n, density)
    got = fused_scored_topk_masked_pallas(wq, tab, wdb, vwords, bits, k,
                                          m, top_k, scales=scales,
                                          block_q=8, block_n=32,
                                          interpret=True)
    want = ref.fused_scored_topk_masked_ref(wq, tab, wdb, vwords, bits, k,
                                            m, top_k, scales=scales)
    _eq_pairs(got, want, "kernel vs fused ref")
    if scales is None:
        _eq_pairs(want, ref.two_stage_scored_masked_ref(
            wq, tab, wdb, vwords, bits, k, m, top_k), "vs two-stage")
    dead = set(np.flatnonzero(~np.asarray(flags)))
    assert not (set(np.asarray(got[1]).ravel()) - {-1}) & dead


@pytest.mark.parametrize("case", [
    pytest.param(dict(n=9, m=50, top_k=4), id="rerank_m-gt-corpus"),
    pytest.param(dict(n=30, m=8, top_k=20), id="top_k-gt-candidates"),
    pytest.param(dict(n=1, m=1, top_k=1), id="single-row"),
    pytest.param(dict(n=40, m=40, top_k=40), id="everything-survives"),
])
def test_fused_scored_edge_cases(case):
    """Degenerate geometries: overflow slots are (-inf, -1) and the
    fused and two-stage rankings still agree slot for slot."""
    q, k, bits = 4, 33, 2
    n, m, top_k = case["n"], case["m"], case["top_k"]
    key = jax.random.PRNGKey(n * m + top_k)
    wq, wdb, tab, _ = _fused_problem(key, q, n, k, bits, "f32")
    got = fused_scored_topk_pallas(wq, tab, wdb, bits, k, m, top_k,
                                   block_q=8, block_n=32, interpret=True)
    want = ref.fused_scored_topk_ref(wq, tab, wdb, bits, k, m, top_k)
    _eq_pairs(got, want)
    _eq_pairs(want, ref.two_stage_scored_ref(wq, tab, wdb, bits, k, m,
                                             top_k))
    pad = min(n, m)
    assert (np.asarray(got[1])[:, pad:] == -1).all()
    assert np.isneginf(np.asarray(got[0])[:, pad:]).all()


def test_fused_scored_all_rows_tombstoned():
    """A fully-dead segment returns pure sentinels from both paths."""
    q, n, k, bits = 3, 64, 33, 2
    key = jax.random.PRNGKey(0)
    wq, wdb, tab, _ = _fused_problem(key, q, n, k, bits, "f32")
    vwords = PK.pack_bitmask(jnp.zeros((n,), bool))
    got = fused_scored_topk_masked_pallas(wq, tab, wdb, vwords, bits, k,
                                          16, 5, block_q=8, block_n=32,
                                          interpret=True)
    assert (np.asarray(got[1]) == -1).all()
    assert np.isneginf(np.asarray(got[0])).all()
    _eq_pairs(got, ref.two_stage_scored_masked_ref(wq, tab, wdb, vwords,
                                                   bits, k, 16, 5))


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([8, 16, 32]),        # block_q
       st.sampled_from([32, 64, 128]),      # block_n
       st.integers(min_value=1, max_value=90),        # n
       st.integers(min_value=1, max_value=40),        # m
       st.integers(min_value=0, max_value=2**31 - 1))  # seed
def test_fused_scored_block_size_invariance(block_q, block_n, n, m, seed):
    """Property: the fused result is a pure function of the inputs —
    tile shape never changes values or ids (the autotuner's license to
    sweep block sizes)."""
    q, k, bits, top_k = 3, 17, 2, 5
    key = jax.random.PRNGKey(seed)
    wq, wdb, tab, _ = _fused_problem(key, q, n, k, bits, "f32")
    got = fused_scored_topk_pallas(wq, tab, wdb, bits, k, m, top_k,
                                   block_q=block_q, block_n=block_n,
                                   interpret=True)
    _eq_pairs(got, ref.fused_scored_topk_ref(wq, tab, wdb, bits, k, m,
                                             top_k))


def test_ops_dispatch_fused_agrees():
    """ops.fused_scored_topk: ref and pallas impls agree through the
    dispatch chokepoint (and through any autotune-supplied blocks)."""
    q, n, k, bits, m, top_k = 5, 70, 33, 2, 16, 6
    key = jax.random.PRNGKey(11)
    wq, wdb, tab, _ = _fused_problem(key, q, n, k, bits, "f32")
    a = ops.fused_scored_topk(wq, tab, wdb, bits, k, m, top_k, impl="ref")
    b = ops.fused_scored_topk(wq, tab, wdb, bits, k, m, top_k,
                              impl="pallas", block_q=8, block_n=32)
    _eq_pairs(a, b)


def test_ops_dispatch_cpu_uses_ref():
    """impl='auto' resolves to the jnp oracle off-TPU (moved here from
    test_kernels.py — it is a conformance property of the dispatcher)."""
    x = jnp.ones((4, 8), jnp.float32)
    r = jnp.ones((8, 4), jnp.float32)
    out = ops.coded_project(x, r, CodeSpec("sign", 1.0))
    np.testing.assert_array_equal(np.asarray(out), 1)
