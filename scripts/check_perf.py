"""CI perf-regression gate over BENCH_history.jsonl.

Reads the cross-run bench trajectory (``benchmarks/history.py``) and
runs a change-point check on every per-metric ``us_per_call`` series:
a ``repro.obs.drift`` CUSUM over log-values — scale-free, so the same
slack/threshold work for a 3 us kernel and a 300 ms ingest. A 2x
latency jump moves log(v) by +0.69: with ``slack=0.2`` and
``threshold=0.5`` the detector fires within two regressed points,
while stationary noise at realistic bench jitter (5-10% relative)
stays far below threshold (``--selftest`` pins both properties, the
same protocol the PR 7 drift bench used).

A series is *flagged* when an up-side alarm fired AND the latest value
is still elevated above the warmup baseline (a regression that was
since fixed stops gating). Down-side alarms (improvements) are
reported, never fatal.

Gate semantics (CI runs ``--quick``): series shorter than
``--min-points`` (default 5) are report-only — the step is non-blocking
until the trajectory has enough history to judge, then flagged
regressions exit 1. A missing history file is a clean no-op under
``--quick`` (first run of a fresh clone) and an error otherwise.
"""
import argparse
import json
import math
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

from benchmarks import history as _history            # noqa: E402
from repro.obs.drift import Cusum                     # noqa: E402

SLACK = 0.2        # log-space slack: ignores <~22% drift per point
THRESHOLD = 0.5    # accumulated log-evidence to fire (2x fires in ~2)
WARMUP = 3         # points frozen into the baseline mean


def analyze(values, slack=SLACK, threshold=THRESHOLD, warmup=WARMUP,
            min_points=5) -> dict:
    """Change-point verdict for one metric series (see module docstring).

    Returns {n, baseline, last, alarms: [(index, side)], regressed,
    improved, gating} — ``regressed`` is the flag, ``gating`` whether
    the series is long enough for the flag to be fatal.
    """
    logs = [math.log(v) for v in values if v > 0]
    det = Cusum(slack=slack, threshold=threshold, warmup=warmup)
    alarms = []
    for i, x in enumerate(logs):
        if det.update(x):
            alarms.append((i, det.side))
    baseline = math.exp(det.mu0) if det.n > 0 else float("nan")
    last = values[-1] if values else float("nan")
    up = any(s == "up" for _, s in alarms)
    still_high = (len(logs) > warmup
                  and logs[-1] > det.mu0 + slack)
    return {"n": len(logs), "baseline": baseline, "last": last,
            "alarms": alarms,
            "regressed": up and still_high,
            "improved": any(s == "down" for _, s in alarms),
            "gating": len(logs) >= min_points}


def check(path=None, min_points=5, quick=False, out=sys.stdout) -> int:
    """Run the gate over one history file; returns the exit code."""
    records = _history.load_history(path)
    if not records:
        if quick:
            print("check_perf: no bench history yet (report-only)",
                  file=out)
            return 0
        print(f"check_perf: no history at "
              f"{path or _history.history_path()}", file=out)
        return 1
    failures = []
    for flavor in (True, False):          # quick/full series never mix
        for name in _history.metric_names(records):
            vals = _history.series(records, name, quick=flavor)
            if len(vals) < 2:
                continue
            v = analyze(vals, min_points=min_points)
            tag = "quick" if flavor else "full"
            status = ("REGRESSED" if v["regressed"] else
                      "improved" if v["improved"] else "ok")
            if v["regressed"] or v["improved"]:
                print(f"  [{tag}] {name}: {status} n={v['n']} "
                      f"baseline={v['baseline']:.1f}us "
                      f"last={v['last']:.1f}us "
                      f"alarms={v['alarms']}", file=out)
            if v["regressed"] and v["gating"]:
                failures.append((tag, name))
            elif v["regressed"]:
                print(f"  [{tag}] {name}: regression below "
                      f"min-points={min_points} — report-only",
                      file=out)
    n_series = len(_history.metric_names(records))
    print(f"check_perf: {len(records)} runs, {n_series} metrics, "
          f"{len(failures)} gating regression(s)", file=out)
    if failures:
        for tag, name in failures:
            print(f"check_perf: FAIL [{tag}] {name}", file=out)
        return 1
    return 0


def explain(path=None, min_points=5, out=sys.stdout) -> int:
    """Arming report: for every series, how many points exist and how
    many more are needed before the gate arms (``min_points``). This is
    the one-line answer to "why didn't the perf gate block that
    regression?" — a fresh history (CI appends one quick run per build)
    spends its first ``min_points`` builds report-only."""
    records = _history.load_history(path)
    if not records:
        print("check_perf --explain: no bench history yet — every "
              f"series needs {min_points} points to arm", file=out)
        return 0
    rows = []
    for flavor in (True, False):          # quick/full series never mix
        tag = "quick" if flavor else "full"
        for name in _history.metric_names(records):
            n = len(_history.series(records, name, quick=flavor))
            if n == 0:
                continue
            need = max(0, min_points - n)
            rows.append((tag, name, n, need))
    armed = sum(1 for *_, need in rows if need == 0)
    print(f"check_perf --explain: {len(records)} runs on record; "
          f"{armed}/{len(rows)} series armed "
          f"(min_points={min_points})", file=out)
    for tag, name, n, need in rows:
        state = ("ARMED" if need == 0
                 else f"{need} more point(s) until armed")
        print(f"  [{tag}] {name}: {n} point(s) — {state}", file=out)
    return 0


def selftest() -> int:
    """Synthetic protocol: zero false alarms on stationary series,
    guaranteed detection of an injected 2x latency jump — across seeds
    and realistic bench jitter levels (mirrors the drift bench)."""
    import numpy as np
    bad = 0
    for seed in range(20):
        rng = np.random.default_rng(seed)
        for rel in (0.02, 0.05, 0.10):
            base = float(rng.uniform(3.0, 3000.0))
            noise = rng.normal(0.0, rel, size=24)
            stationary = [base * math.exp(e) for e in noise]
            v = analyze(stationary)
            if v["regressed"] or v["alarms"]:
                print(f"selftest: FALSE ALARM seed={seed} rel={rel}: "
                      f"{v['alarms']}")
                bad += 1
            jumped = [base * math.exp(e) * (2.0 if i >= 16 else 1.0)
                      for i, e in enumerate(noise)]
            v = analyze(jumped)
            if not v["regressed"]:
                print(f"selftest: MISSED 2x jump seed={seed} rel={rel}")
                bad += 1
            shrunk = [base * math.exp(e) * (0.5 if i >= 16 else 1.0)
                      for i, e in enumerate(noise)]
            v = analyze(shrunk)
            if v["regressed"] or not v["improved"]:
                print(f"selftest: misread improvement seed={seed} "
                      f"rel={rel}")
                bad += 1
    print(f"check_perf selftest: {'FAIL' if bad else 'PASS'} "
          f"(20 seeds x 3 jitter levels x stationary/2x/0.5x)")
    return 1 if bad else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--history", default=None,
                    help="history file (default BENCH_history.jsonl "
                         "at repo root)")
    ap.add_argument("--min-points", type=int, default=5,
                    help="series length below which regressions are "
                         "report-only (default 5)")
    ap.add_argument("--quick", action="store_true",
                    help="CI mode: missing history is a clean no-op; "
                         "short series stay non-blocking")
    ap.add_argument("--selftest", action="store_true",
                    help="run the synthetic detection protocol and exit")
    ap.add_argument("--explain", action="store_true",
                    help="print per-series points-until-armed and exit")
    ap.add_argument("--json", action="store_true",
                    help="also dump per-series verdicts as JSON")
    args = ap.parse_args(argv)
    if args.selftest:
        return selftest()
    if args.explain:
        return explain(args.history, min_points=args.min_points)
    code = check(args.history, min_points=args.min_points,
                 quick=args.quick)
    if args.json:
        records = _history.load_history(args.history)
        out = {}
        for name in _history.metric_names(records):
            vals = _history.series(records, name, quick=True)
            if len(vals) >= 2:
                out[name] = analyze(vals, min_points=args.min_points)
        print(json.dumps(out, indent=1, default=str))
    return code


if __name__ == "__main__":
    raise SystemExit(main())
