"""Fig 6: P_{w,2} vs P_w — overlap for w>1, non-monotone in w, equal to
P_1 at w=0 and w->inf."""
import numpy as np
import jax.numpy as jnp

from repro.core import probabilities as P
from benchmarks._util import timed, write_csv

RHOS = [0.0, 0.25, 0.5, 0.75, 0.9, 0.99]


def run(quick: bool = True):
    ws = np.geomspace(0.05, 8.0, 50)
    rho = jnp.asarray(RHOS)

    def grid():
        return [(w, np.asarray(P.collision_prob_2bit(rho, float(w))),
                 np.asarray(P.collision_prob_uniform(rho, float(w))))
                for w in ws]

    table, us = timed(grid, repeat=1)
    rows = []
    for w, p2, pu in table:
        for r, a, b in zip(RHOS, p2, pu):
            rows.append([w, r, float(a), float(b)])
    write_csv("fig06_p2bit", ["w", "rho", "P_w2", "P_w"], rows)
    p1 = np.asarray(P.collision_prob_sign(rho))
    d0 = np.max(np.abs(np.asarray(P.collision_prob_2bit(rho, 1e-4)) - p1))
    dinf = np.max(np.abs(np.asarray(P.collision_prob_2bit(rho, 50.0)) - p1))
    return [("fig06_limits", us, f"|P_w2-P_1|@w0={d0:.1e};@winf={dinf:.1e}")]
