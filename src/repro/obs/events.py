"""Flight recorder: a preallocated ring buffer of per-request events.

Histograms (``obs.registry``) answer "what is p99?"; they cannot answer
"*which* request was slow, and what was it doing?". This module is the
forensic layer: every request-scoped operation (a serving flush batch, a
classify call, an ingest, an engine search, a kernel dispatch) appends
one structured event — op, queue/start/end timestamps, batch shape,
cache hits, store generation, outcome, trace id — into a fixed-capacity
ring of preallocated slots. Append is O(1) (one tuple build + one slot
store + one integer bump), allocation-bounded, and cheap enough to stay
on in production (``benchmarks/obs_bench.py`` pins it ≤ ~500 ns and the
whole recorder ≤ 1% serving QPS); the ring holds the last ``capacity``
events whatever the uptime, so an incident bundle (``obs.incident``)
always has the minutes-before story.

Timestamps reuse the ``sp.sync`` boundary invariant of ``obs.trace``:
an event's ``synced`` flag records whether ``t_end`` was taken after a
device sync (host transfer / ``block_until_ready``) — ``synced=False``
durations are *submission* times and are labelled as such, never
presented as execution times.

There is a process-global default recorder (on by default, the
always-on contract) plus injectable per-component instances — the same
pattern as ``MetricsRegistry``. A recorder built with ``enabled=False``
makes ``record`` a constant-time no-op.
"""
from __future__ import annotations

import time

__all__ = ["FlightRecorder", "EVENT_FIELDS", "default_flight_recorder",
           "set_flight_recorder"]

#: slot layout of one event tuple, in storage order
EVENT_FIELDS = ("seq", "op", "t_queue", "t_start", "t_end", "batch",
                "cache_hits", "generation", "outcome", "trace_id",
                "synced")


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


class FlightRecorder:
    """Fixed-slot ring of request events; O(1) append, O(capacity) read.

    ``capacity`` rounds up to a power of two (slot index is one mask).
    ``seq`` increases monotonically forever; slot ``seq & mask`` is
    overwritten on wrap, so the ring always holds the newest
    ``capacity`` events. Readers (``tail``/``snapshot``) rebuild plain
    dicts — the hot path never allocates one.
    """

    __slots__ = ("capacity", "enabled", "_mask", "_slots", "seq")

    def __init__(self, capacity: int = 4096, enabled: bool = True):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = _pow2(int(capacity))
        self._mask = self.capacity - 1
        self._slots = [None] * self.capacity
        self.seq = 0
        self.enabled = enabled

    def record(self, op: str, t_start: float, t_end: float, *,
               t_queue: float = 0.0, batch: int = 0, cache_hits: int = 0,
               generation: int = -1, outcome: str = "ok",
               trace_id: int = 0, synced: bool = False) -> int:
        """Append one event; returns its ``seq`` (-1 when disabled).

        ``t_queue``/``t_start``/``t_end`` are ``time.perf_counter``
        values (0.0 = not applicable); ``synced`` asserts ``t_end`` was
        taken after a device sync (the ``sp.sync`` boundary invariant —
        leave False for submission-time events).
        """
        if not self.enabled:
            return -1
        seq = self.seq
        self._slots[seq & self._mask] = (
            seq, op, t_queue, t_start, t_end, batch, cache_hits,
            generation, outcome, trace_id, synced)
        self.seq = seq + 1
        return seq

    def record_kernel(self, family: str, traced: bool) -> int:
        """Minimal-cost append for a kernel dispatch (the
        ``kernels/ops.py`` chokepoint, via ``obs.kernelstats``): a
        point event ``kernel.<family>``; ``outcome`` records whether
        the dispatch happened under a jit trace."""
        if not self.enabled:
            return -1
        seq = self.seq
        t = time.perf_counter()
        self._slots[seq & self._mask] = (
            seq, "kernel." + family, 0.0, t, t, 0, 0, -1,
            "traced" if traced else "ok", 0, False)
        self.seq = seq + 1
        return seq

    def __len__(self) -> int:
        """Events currently resident (≤ capacity)."""
        return min(self.seq, self.capacity)

    @property
    def wrapped(self) -> bool:
        """Whether the ring has overwritten at least one slot."""
        return self.seq > self.capacity

    @property
    def dropped(self) -> int:
        """Events overwritten by wraparound — derived from ``seq`` at
        read time so the append path carries zero drop bookkeeping."""
        return max(0, self.seq - self.capacity)

    def tail(self, n: int = None):
        """The newest ``n`` events (default: all resident) as dicts,
        oldest first — the slice an incident bundle captures."""
        have = len(self)
        n = have if n is None else min(int(n), have)
        first = self.seq - n
        return [dict(zip(EVENT_FIELDS, self._slots[s & self._mask]))
                for s in range(first, self.seq)]

    def snapshot(self):
        """Every resident event as dicts, oldest first."""
        return self.tail()

    def events(self, op: str = None):
        """Resident events filtered by exact ``op`` (oldest first)."""
        evs = self.tail()
        return evs if op is None else [e for e in evs if e["op"] == op]

    def reset(self):
        """Drop every event (slots stay preallocated)."""
        self._slots = [None] * self.capacity
        self.seq = 0


_DEFAULT = FlightRecorder(capacity=4096, enabled=True)


def default_flight_recorder() -> FlightRecorder:
    """The process-global flight recorder (on by default — the
    always-on contract; components may take injected instances)."""
    return _DEFAULT


def set_flight_recorder(fr: FlightRecorder) -> FlightRecorder:
    """Swap the process-global recorder; returns the previous one."""
    global _DEFAULT
    prev = _DEFAULT
    _DEFAULT = fr
    return prev
