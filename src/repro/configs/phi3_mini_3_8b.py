"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064 — RoPE SwiGLU MHA. [arXiv:2404.14219; unverified]"""
from dataclasses import replace

from repro.models.lm import ModelConfig

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b", family="dense",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_ff=8192,
        vocab_size=32064, rope_theta=10000.0,
        tie_embeddings=False, norm_eps=1e-5,
    )


def smoke_config() -> ModelConfig:
    return replace(config(), n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                   d_ff=128, vocab_size=256, loss_chunk=16, chunk_kv=32,
                   chunk_q=16)
