"""Bit-packing Pallas kernel: b-bit codes -> uint32 words.

Row-blocked: each grid step packs (bm, K) int32 codes into (bm, K·b/32)
uint32 words entirely in VMEM; fields are disjoint so the bitwise-or is an
integer dot with the shift vector (VPU multiply-accumulate). K is padded
to a multiple of 32/b by the wrapper (zero codes land in high bits and are
ignored by unpack).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.packing import codes_per_word

__all__ = ["pack_codes_pallas"]


def _kernel(c_ref, o_ref, *, bits: int):
    cpw = codes_per_word(bits)
    c = c_ref[...].astype(jnp.uint32)
    bm, kp = c.shape
    c = c.reshape(bm, kp // cpw, cpw)
    shifts = (jnp.arange(cpw, dtype=jnp.uint32) * jnp.uint32(bits))
    o_ref[...] = jnp.sum(c << shifts, axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("bits", "block_m", "interpret"))
def pack_codes_pallas(codes, bits: int, *, block_m: int = 256,
                      interpret: bool = False):
    """codes int32 [M, K] -> uint32 [M, ceil(K/(32/bits))]."""
    cpw = codes_per_word(bits)
    m, k = codes.shape
    kpad = (-k) % cpw
    if kpad:
        codes = jnp.pad(codes, ((0, 0), (0, kpad)))
    mpad = (-m) % block_m
    if mpad:
        codes = jnp.pad(codes, ((0, mpad), (0, 0)))
    mp, kp = codes.shape
    nw = kp // cpw
    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits),
        grid=(mp // block_m,),
        in_specs=[pl.BlockSpec((block_m, kp), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_m, nw), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, nw), jnp.uint32),
        interpret=interpret,
    )(codes)
    return out[:m]
