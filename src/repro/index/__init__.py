"""Mutable streaming index lifecycle over packed codes.

The layer between the immutable device corpus (``repro.ann.CodeStore``)
and a real online near-neighbor service: a corpus that changes under
traffic without ever invalidating a search executable.

segment_log — ``SegmentLogStore``: append-only log of content-immutable
              segments + a preallocated donated tail buffer (O(batch)
              ingest), packed tombstone bitmasks, id↔row mapping for
              deletes/upserts
compaction  — size-tiered adjacent-run rewrite: merges small segments,
              drops tombstoned rows, preserves result order bit-exactly
snapshot    — durability via ``repro.checkpoint``: atomic snapshot +
              self-describing restore (manifest-driven), ids never reused
engine      — ``MutableAnnEngine``: batched exact/LSH search across
              segments with the masked streaming top-k kernel and a
              cross-segment merge; results are bit-identical to a fresh
              immutable store of the surviving rows

(serving front-end with mutation endpoints + result cache:
``repro.serve.ann_service``; classifier training over a live segment
log — tombstones skipped on device, labels keyed by external id:
``repro.learn.fit_log``)
"""
from repro.index.compaction import (CompactionPolicy, compact,  # noqa: F401
                                    plan_compaction)
from repro.index.engine import MutableAnnEngine  # noqa: F401
from repro.index.segment_log import Segment, SegmentLogStore  # noqa: F401
from repro.index.snapshot import restore_index, save_index  # noqa: F401
