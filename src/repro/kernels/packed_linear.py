"""Fused linear-classifier kernels directly on bit-packed codes.

The paper's SVM application (§6, Figs 11–14) trains L2 linear models on
the one-hot expansion of the codes: k projections × 2^b code values per
row, exactly k ones. Materializing that [N, k·2^b] float matrix is a
32/b × 2^b blow-up over the packed words and caps training at toy sizes.
These kernels train **on the packed words themselves**: the one-hot dot
product is a per-projection weight-table gather, so the forward pass is
the ``packed_lut`` select-tree machinery with the per-query tables
replaced by one shared weight table per output class, and the backward
pass is its transpose — gradient contributions scattered back into the
[k, 2^b] weight tables.

Four kernels:

``packed_linear_fwd_pallas``
    Margins: weight tables float [C, F*P] × corpus words uint32 [N, W]
    -> float32 [C, N], streaming corpus blocks; each b-bit field selects
    one of its 2^b table entries through a branchless select tree
    (``packed_lut._lut_select``) and selections accumulate in float32 in
    (word, field) order. The one-hot feature matrix never exists.

``packed_linear_fwd_masked_pallas``
    Same with a packed row-validity bitmask (``packing.pack_bitmask``
    layout): tombstoned rows emit margin 0.0 on device. The mask is
    data, not shape — churn never recompiles.

``packed_linear_bwd_pallas``
    Gradient scatter-accumulation: upstream margin gradients float32
    [C, N] × corpus words [N, W] -> table gradients float32 [C, F*P].
    Each corpus block expands to its one-hot tile *in register*
    (branchless field compares — never in HBM) and one MXU matmul
    ``g_tile @ onehot_tile`` accumulates every per-example contribution
    into the right (field, code) table column; blocks accumulate
    sequentially in a VMEM scratch accumulator.

``packed_linear_bwd_masked_pallas``
    Same with the validity bitmask: dead rows' gradients are zeroed
    before the matmul, so tombstoned examples never touch the tables.

Bit-exactness: the jnp oracles (``ref.packed_linear_*_ref``) fix the
accumulation order — (word, field) for margins, ``block_n``-blocked
row chunks for gradients — and the kernels match them bit-for-bit.
Phantom table columns (field slots >= k from word padding, code values
>= n_codes from the power-of-two field width) are the *caller's*
responsibility: the kernels faithfully gather/scatter every field slot,
and ``repro.learn.features`` masks the phantom columns out of the
weight tables and gradients.

Padding: weight-table class rows pad with zeros (padded classes emit
garbage margins the wrapper slices off), corpus rows pad with zero
words *and* zero gradient columns, so padded rows contribute exact
zeros to every gradient sum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import bitmask_width
from repro.kernels.packed_collision import _pad
from repro.kernels.packed_lut import _accum_lut_scores

__all__ = ["packed_linear_fwd_pallas", "packed_linear_fwd_masked_pallas",
           "packed_linear_bwd_pallas", "packed_linear_bwd_masked_pallas",
           "onehot_tile"]


def _expand_valid(valid_tile, block_n: int):
    """Packed validity tile [block_n/32, 1] -> row mask [1, block_n]."""
    bitpos = jax.lax.broadcasted_iota(jnp.uint32, (block_n // 32, 32), 1)
    return ((valid_tile >> bitpos) & jnp.uint32(1)).reshape(1, block_n)


def onehot_tile(words, bits: int):
    """One-hot expand a packed tile in-register: uint32 [bn, W] ->
    float32 [bn, F*P] with F = W * 32/bits field slots and P = 2**bits
    entries per slot (the flat layout of ``rank.RankTables`` /
    ``learn.features``). Entry [n, f*P + c] is 1.0 iff field f of row n
    holds code value c — built from branchless field compares. The
    oracle's ``ref._onehot_rows`` is an independent construction of the
    same matrix (via ``packing.unpack_codes``); their equality — and
    hence kernel/oracle bit-exactness — is pinned by
    ``tests/test_learn.py``.
    """
    p = 1 << bits
    cpw = 32 // bits
    shifts = (jnp.arange(cpw, dtype=jnp.uint32) * jnp.uint32(bits))
    fields = (words[..., None] >> shifts) & jnp.uint32(p - 1)   # [bn, W, cpw]
    fields = fields.reshape(words.shape[0], -1)                  # [bn, F]
    hot = (fields[..., None] == jnp.arange(p, dtype=jnp.uint32))
    return hot.reshape(words.shape[0], -1).astype(jnp.float32)   # [bn, F*P]


# -- forward: margins ---------------------------------------------------------

def _fwd_kernel(tab_ref, db_ref, o_ref, *, bits: int, block_n: int):
    tab = tab_ref[...].astype(jnp.float32)
    o_ref[...] = _accum_lut_scores(tab, db_ref[...], bits,
                                   (tab.shape[0], block_n))


@functools.partial(
    jax.jit,
    static_argnames=("bits", "block_c", "block_n", "interpret"))
def packed_linear_fwd_pallas(tables, words, bits: int, *, block_c: int = 8,
                             block_n: int = 512, interpret: bool = False):
    """tables float [C, F*P] (class weight tables, flat ``RankTables``
    layout), words uint32 [N, W] -> margins float32 [C, N].

    margin[c, n] = sum over field slots f of tables[c, f*P + code(n, f)]
    accumulated in float32 in (word, field) order — bit-exact vs
    ``ref.packed_linear_fwd_ref``. Streams the corpus axis; the one-hot
    feature matrix never materializes.
    """
    cn, fp = tables.shape
    n, w = words.shape
    assert fp == w * (32 // bits) * (1 << bits), (tables.shape,
                                                  words.shape, bits)
    tp = _pad(tables, block_c, 0)
    dbp = _pad(words, block_n, 0)
    cm, nm = tp.shape[0], dbp.shape[0]
    grid = (cm // block_c, nm // block_n)
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, bits=bits, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_c, fp), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_c, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((cm, nm), jnp.float32),
        interpret=interpret,
    )(tp, dbp)
    return out[:cn, :n]


def _fwd_masked_kernel(tab_ref, db_ref, valid_ref, o_ref, *, bits: int,
                       block_n: int):
    tab = tab_ref[...].astype(jnp.float32)
    score = _accum_lut_scores(tab, db_ref[...], bits,
                              (tab.shape[0], block_n))
    live = _expand_valid(valid_ref[...], block_n)
    o_ref[...] = jnp.where(live != 0, score, 0.0)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "block_c", "block_n", "interpret"))
def packed_linear_fwd_masked_pallas(tables, words, valid_words, bits: int, *,
                                    block_c: int = 8, block_n: int = 512,
                                    interpret: bool = False):
    """``packed_linear_fwd_pallas`` over live rows only: ``valid_words``
    uint32 [ceil(N/32)] is the packed row-validity bitmask
    (``packing.pack_bitmask`` layout). Dead rows emit margin 0.0 —
    callers also exclude them from the loss, so the exact fill value is
    load-bearing only for bit-exactness vs
    ``ref.packed_linear_fwd_masked_ref``. The mask is data: tombstone
    churn never triggers a recompile.
    """
    cn, fp = tables.shape
    n, w = words.shape
    assert fp == w * (32 // bits) * (1 << bits), (tables.shape,
                                                  words.shape, bits)
    assert block_n % 32 == 0, block_n
    nw = bitmask_width(n)
    assert valid_words.shape == (nw,), (valid_words.shape, nw)
    tp = _pad(tables, block_c, 0)
    dbp = _pad(words, block_n, 0)
    cm, nm = tp.shape[0], dbp.shape[0]
    vw = valid_words.astype(jnp.uint32)
    if n % 32:
        vw = vw.at[-1].set(vw[-1] & jnp.uint32((1 << (n % 32)) - 1))
    vw = jnp.pad(vw, (0, nm // 32 - nw)).reshape(nm // 32, 1)
    grid = (cm // block_c, nm // block_n)
    out = pl.pallas_call(
        functools.partial(_fwd_masked_kernel, bits=bits, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_c, fp), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, w), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n // 32, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_c, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((cm, nm), jnp.float32),
        interpret=interpret,
    )(tp, dbp, vw)
    return out[:cn, :n]


# -- backward: gradient scatter-accumulation into the weight tables -----------

def _bwd_kernel(g_ref, db_ref, o_ref, acc_ref, *, bits: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    hot = onehot_tile(db_ref[...], bits)                 # [bn, F*P]
    acc_ref[...] += jnp.dot(g_ref[...], hot,
                            preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finalize():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("bits", "block_c", "block_n", "interpret"))
def packed_linear_bwd_pallas(g, words, bits: int, *, block_c: int = 8,
                             block_n: int = 512, interpret: bool = False):
    """Backward pass of ``packed_linear_fwd_pallas``: upstream margin
    gradients g float32 [C, N] × words uint32 [N, W] -> weight-table
    gradients float32 [C, F*P].

    dTables[c, f*P + v] = sum over rows n with code(n, f) == v of
    g[c, n] — each block's contributions enter through one in-register
    one-hot tile and an MXU matmul, accumulated block-sequentially in
    VMEM. Bit-exact vs ``ref.packed_linear_bwd_ref`` at the same
    ``block_n``. Padded rows carry zero gradient columns, so they
    contribute exact zeros.
    """
    cn, n = g.shape
    n2, w = words.shape
    assert n == n2, (g.shape, words.shape)
    fp = w * (32 // bits) * (1 << bits)
    gp = _pad(_pad(g.astype(jnp.float32), block_c, 0), block_n, 1)
    dbp = _pad(words, block_n, 0)
    cm, nm = gp.shape[0], dbp.shape[0]
    grid = (cm // block_c, nm // block_n)
    out = pl.pallas_call(
        functools.partial(_bwd_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_c, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_n, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_c, fp), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cm, fp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_c, fp), jnp.float32)],
        interpret=interpret,
    )(gp, dbp)
    return out[:cn]


def _bwd_masked_kernel(g_ref, db_ref, valid_ref, o_ref, acc_ref, *,
                       bits: int, block_n: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = _expand_valid(valid_ref[...], block_n)
    g = jnp.where(live != 0, g_ref[...], 0.0)
    hot = onehot_tile(db_ref[...], bits)
    acc_ref[...] += jnp.dot(g, hot, preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finalize():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("bits", "block_c", "block_n", "interpret"))
def packed_linear_bwd_masked_pallas(g, words, valid_words, bits: int, *,
                                    block_c: int = 8, block_n: int = 512,
                                    interpret: bool = False):
    """``packed_linear_bwd_pallas`` over live rows only: gradients of
    rows whose validity bit is clear are zeroed on device before the
    scatter, so tombstoned examples never move a weight. Bit-exact vs
    ``ref.packed_linear_bwd_masked_ref`` at the same ``block_n``; the
    mask is data, not shape.
    """
    cn, n = g.shape
    n2, w = words.shape
    assert n == n2, (g.shape, words.shape)
    assert block_n % 32 == 0, block_n
    nw = bitmask_width(n)
    assert valid_words.shape == (nw,), (valid_words.shape, nw)
    fp = w * (32 // bits) * (1 << bits)
    gp = _pad(_pad(g.astype(jnp.float32), block_c, 0), block_n, 1)
    dbp = _pad(words, block_n, 0)
    cm, nm = gp.shape[0], dbp.shape[0]
    vw = valid_words.astype(jnp.uint32)
    if n % 32:
        vw = vw.at[-1].set(vw[-1] & jnp.uint32((1 << (n % 32)) - 1))
    vw = jnp.pad(vw, (0, nm // 32 - nw)).reshape(nm // 32, 1)
    grid = (cm // block_c, nm // block_n)
    out = pl.pallas_call(
        functools.partial(_bwd_masked_kernel, bits=bits, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_c, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_n, w), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n // 32, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_c, fp), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cm, fp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_c, fp), jnp.float32)],
        interpret=interpret,
    )(gp, dbp, vw)
    return out[:cn]
