import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Evidence probe for the zamba2 prefill_32k compile pathology.

zamba2-1.2b train_4k compiles in ~30 s but prefill_32k did not finish in
45+ min on this 1-core CPU backend. This probe compiles the *identical*
prefill program at growing sequence lengths to show the lowering/sharding
is coherent and compile cost is a CPU-backend pass blowup in S, not a
model/sharding bug. Results land in prefill_probe.json.
"""
import json      # noqa: E402
import time      # noqa: E402
import sys       # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402

from repro import configs as C                                   # noqa: E402
from repro.launch.dryrun import SHAPES, lower_cell               # noqa: E402

ARCH = sys.argv[1] if len(sys.argv) > 1 else "zamba2-1.2b"
out = {}
for seq in (4096, 8192, 16384):
    SHAPES["prefill_32k"] = ("prefill", seq, 32)  # shrink the cell in place
    t0 = time.monotonic()
    try:
        lowered, meta = lower_cell(ARCH, "prefill_32k", False)
        t_lower = time.monotonic() - t0
        t0 = time.monotonic()
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        out[seq] = {"lower_s": round(t_lower, 1),
                    "compile_s": round(t_compile, 1),
                    "flops_per_dev": float(cost.get("flops", 0)),
                    "status": "ok"}
        del compiled, lowered
    except Exception as e:
        out[seq] = {"status": f"FAIL: {e}"}
    print(seq, out[seq], flush=True)
    json.dump(out, open(f"prefill_probe_{ARCH.replace('-', '_').replace('.', '_')}.json", "w"), indent=1)
