"""Segment-log store: the mutable ingestion path over immutable packed codes.

The PR-1 ``CodeStore`` is append-by-copy: every ``add`` concatenates the
whole corpus (O(N) HBM traffic) and changes the corpus shape, invalidating
every jit cache entry. The ``SegmentLogStore`` turns ingestion into a log:

* **Tail buffer** — a preallocated device-resident uint32 buffer of
  ``tail_rows`` rows. ``add_codes`` packs the batch and writes it with a
  *donated* ``dynamic_update_slice``, so the update is in-place: O(batch)
  bytes copied, O(corpus) never touched, and the buffer shape never
  changes so the write executable compiles once per chunk size.
* **Sealed segments** — when the tail fills it is sealed as-is (the buffer
  simply stops being written) and a fresh tail is allocated. Sealed
  segments are content-immutable; every search jit entry keyed on a
  segment shape stays valid forever.
* **Tombstones** — deletes flip one bit in a packed per-segment validity
  bitmask (host-authoritative ``np.uint32``, device copy cached until the
  next delete). Dead rows are skipped *on device* by the masked streaming
  top-k kernel (``kernels.packed_collision.packed_topk_masked_pallas``);
  the mask is data, not shape, so tombstones cost zero recompiles.
* **Upserts** — an id→(segment, row) map lets ``upsert_codes`` tombstone
  the id's current row and append the new version; external ids are
  stable across upserts, seals and compactions.

Row identity: every row carries an external id (monotonic ``next_id`` by
default). The store's *iteration order* — sealed segments in log order,
live rows in row order, then the tail — defines search tie-breaking, and
is exactly the row order of a fresh ``CodeStore`` built from
``live_codes()``: the bit-exactness contract the tests enforce.

Lifecycle ops live beside this module: ``compaction`` (size-tiered merge
that drops tombstones), ``snapshot`` (durability via ``repro.checkpoint``).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.ann.bands import BandSpec, band_hashes
from repro.core import packing as _packing
from repro.kernels import ops as _ops
from repro.obs import MetricsRegistry

__all__ = ["Segment", "SegmentLogStore"]


def _np_pack_bitmask(flags: np.ndarray) -> np.ndarray:
    """Host-side ``packing.pack_bitmask``: bool [n] -> uint32 [ceil(n/32)]."""
    packed = np.packbits(flags.astype(bool), bitorder="little")
    pad = (-packed.size) % 4
    if pad:
        packed = np.pad(packed, (0, pad))
    return packed.view(np.uint32)


def _np_unpack_bitmask(words: np.ndarray, n: int) -> np.ndarray:
    """Inverse of ``_np_pack_bitmask``: uint32 words -> bool [n]."""
    return np.unpackbits(words.view(np.uint8), bitorder="little")[:n] \
        .astype(bool)


@functools.partial(jax.jit, donate_argnums=(0,))
def _write_rows(buf, rows, start):
    """In-place (donated) row-slab write into a preallocated buffer."""
    return jax.lax.dynamic_update_slice(buf, rows, (start, 0))


class Segment:
    """One log segment: content-immutable device rows + mutable liveness.

    ``words``/``hashes`` are device arrays that never change shape; for
    the tail, rows past ``length`` are unwritten (their validity bits are
    0, so search can treat the full buffer as the segment). ``valid`` is
    the host-authoritative packed bitmask; ``valid_dev``/``ids_dev`` are
    demand-built device copies, dropped on mutation.
    """

    __slots__ = ("words", "hashes", "ids", "valid", "live", "length",
                 "_valid_dev", "_ids_dev")

    def __init__(self, words, hashes, ids, valid, live, length):
        self.words = words            # uint32 [cap, W] device
        self.hashes = hashes          # uint32 [cap, L] device | None
        self.ids = ids                # int64 [cap] host
        self.valid = valid            # uint32 [ceil(cap/32)] host bitmask
        self.live = live              # live-row count
        self.length = length          # written rows (== cap once sealed)
        self._valid_dev = None
        self._ids_dev = None

    @property
    def cap(self) -> int:
        """Row capacity of the segment's device buffer."""
        return self.words.shape[0]

    def valid_dev(self):
        """Device copy of the packed validity bitmask, uint32
        [ceil(cap/32)] (cached until the next mutation)."""
        if self._valid_dev is None:
            self._valid_dev = jnp.asarray(self.valid)
        return self._valid_dev

    def ids_dev(self):
        """Device copy of the external ids, int32 [cap] (-1 =
        unwritten slot; cached until the next mutation)."""
        if self._ids_dev is None:
            self._ids_dev = jnp.asarray(self.ids.astype(np.int32))
        return self._ids_dev

    def live_rows(self) -> np.ndarray:
        """Indices of live rows, ascending (the iteration order)."""
        return np.flatnonzero(_np_unpack_bitmask(self.valid, self.length))

    def kill_row(self, row: int):
        """Tombstone one row: clear its validity bit (host + cached
        device mask dropped) and decrement the live count."""
        self.valid[row // 32] &= np.uint32(~np.uint32(1 << (row % 32)))
        self.live -= 1
        self._valid_dev = None


def _empty_segment(cap: int, n_words: int, n_tables) -> Segment:
    return Segment(
        words=jnp.zeros((cap, n_words), jnp.uint32),
        hashes=(jnp.zeros((cap, n_tables), jnp.uint32)
                if n_tables else None),
        ids=np.full(cap, -1, np.int64),
        valid=np.zeros(_packing.bitmask_width(cap), np.uint32),
        live=0, length=0)


class SegmentLogStore:
    """Mutable corpus of packed codes: append-only segment log + tombstones.

    All mutators bump ``generation`` (result-cache invalidation hook for
    the serving layer). The store holds *codes*; vector encoding lives in
    ``repro.index.engine.MutableAnnEngine``.
    """

    def __init__(self, k: int, bits: int, *, band_spec: BandSpec = None,
                 tail_rows: int = 1024, impl: str = "auto",
                 registry: MetricsRegistry = None):
        if tail_rows % 32:
            raise ValueError(f"tail_rows must be a multiple of 32, "
                             f"got {tail_rows}")
        self.k = k
        self.bits = bits
        self.band_spec = band_spec.validate(k) if band_spec else None
        self.tail_rows = tail_rows
        self.impl = impl
        self.n_words = _packing.packed_width(k, bits)
        self.sealed: list[Segment] = []
        self.tail = self._new_tail()
        self.next_id = 0
        self.generation = 0
        self._by_id: dict[int, tuple[Segment, int]] = {}
        self._listeners: list = []
        self.registry = registry if registry is not None \
            else MetricsRegistry(enabled=True)
        self._c_appended = self.registry.counter("index.rows_appended")
        self._c_deleted = self.registry.counter("index.rows_deleted")
        self._c_seals = self.registry.counter("index.seals")
        self._g_live = self.registry.gauge("index.live_rows")
        self._g_dead = self.registry.gauge("index.dead_rows")
        self._g_livefrac = self.registry.gauge("index.live_fraction")
        self._g_segments = self.registry.gauge("index.segments")
        self._g_tail = self.registry.gauge("index.tail_fill")
        self._g_bytes = self.registry.gauge("index.resident_bytes")

    def _update_gauges(self):
        """Refresh the store-shape gauges after any mutation."""
        self._g_live.set(self.n_live)
        self._g_dead.set(self.n_rows - self.n_live)
        self._g_livefrac.set(self.n_live / self.n_rows
                             if self.n_rows else 1.0)
        self._g_segments.set(self.n_segments)
        self._g_tail.set(self.tail.length / self.tail_rows)
        self._g_bytes.set(self.nbytes)

    def _new_tail(self) -> Segment:
        return _empty_segment(
            self.tail_rows, self.n_words,
            self.band_spec.n_tables if self.band_spec else 0)

    # -- geometry ------------------------------------------------------------
    @property
    def n_live(self) -> int:
        """Live (non-tombstoned) rows across all segments."""
        return len(self._by_id)

    @property
    def n_rows(self) -> int:
        """Resident rows, live or dead (excludes unwritten tail slots)."""
        return sum(s.length for s in self.segments())

    @property
    def n_segments(self) -> int:
        """Resident segments (sealed + the tail)."""
        return len(self.sealed) + 1

    @property
    def nbytes(self) -> int:
        """Resident device bytes (words + hashes + masks), full buffers."""
        total = 0
        for s in self.segments():
            total += s.words.size * 4 + s.valid.size * 4
            if s.hashes is not None:
                total += s.hashes.size * 4
        return total

    def segments(self) -> list[Segment]:
        """Iteration order: sealed segments in log order, then the tail."""
        return self.sealed + [self.tail]

    def __contains__(self, item_id: int) -> bool:
        return int(item_id) in self._by_id

    # -- mutation listeners --------------------------------------------------
    def add_listener(self, callback) -> "SegmentLogStore":
        """Subscribe ``callback(event: str, ids)`` to membership events:
        ``"delete"`` carries the external ids just tombstoned (int64
        array), ``"compact"`` carries None (external ids survive
        compaction unchanged). The shadow reservoir of
        ``repro.obs.quality`` subscribes here to stay tombstone-aware.
        Returns self."""
        self._listeners.append(callback)
        return self

    def _notify(self, event: str, ids):
        for cb in self._listeners:
            cb(event, ids)

    def take_codes(self, ids) -> np.ndarray:
        """int32 codes [m, k] of *live* external ids int [m] (the small
        per-id gather behind the quality audit; raises KeyError on a
        dead/unknown id)."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        rows = []
        for item in ids:
            seg, row = self._by_id[int(item)]
            rows.append(seg.words[row])
        if not rows:
            return np.zeros((0, self.k), np.int32)
        words = jnp.stack(rows)
        return np.asarray(
            _packing.unpack_codes(words, self.bits, self.k), np.int32)

    # -- ingestion -----------------------------------------------------------
    def add_codes(self, codes, ids=None) -> np.ndarray:
        """Append int codes [m, k]; returns the external ids (int64 [m]).

        Auto-assigned ids continue from ``next_id``; explicit ids must
        not collide with a live id (use ``upsert_codes`` to replace).
        O(batch) device copy via the donated tail write.
        """
        shape = np.shape(codes)          # no copy/transfer, any array type
        if len(shape) != 2 or shape[1] != self.k:
            raise ValueError(f"codes {shape} != [m, {self.k}]")
        ids = self._prepare_ids(ids, shape[0])
        if shape[0] == 0:
            return ids
        codes = jnp.asarray(codes)
        words = _ops.pack_codes(codes, self.bits, impl=self.impl)
        hashes = (band_hashes(codes, self.band_spec)
                  if self.band_spec else None)
        return self._append(words, hashes, ids)

    def add_words(self, words, ids=None) -> np.ndarray:
        """Append already-packed uint32 rows [m, W] (the fused-ingest
        path, ``repro.encode``): same id rules and O(batch) donated tail
        write as ``add_codes``, but int32 codes for the batch never
        exist on device — except, with a ``band_spec``, a chunk-local
        unpack to compute the band hashes (O(batch), never O(corpus))."""
        shape = np.shape(words)          # no copy/transfer, any array type
        if len(shape) != 2 or shape[1] != self.n_words:
            raise ValueError(f"words {shape} != [m, {self.n_words}]")
        ids = self._prepare_ids(ids, shape[0])
        if shape[0] == 0:
            return ids
        words = jnp.asarray(words, jnp.uint32)
        if self.band_spec:
            hashes = band_hashes(
                _packing.unpack_codes(words, self.bits, self.k),
                self.band_spec)
        else:
            hashes = None
        return self._append(words, hashes, ids)

    def _prepare_ids(self, ids, m: int) -> np.ndarray:
        """Validate/auto-assign a batch's external ids — runs before any
        device work so bad batches are rejected for free."""
        if ids is None:
            ids = np.arange(self.next_id, self.next_id + m, dtype=np.int64)
        else:
            ids = np.asarray(ids, np.int64)
            if ids.shape != (m,):
                raise ValueError(f"ids {ids.shape} != ({m},)")
            if np.unique(ids).size != m:
                raise ValueError("duplicate ids within one batch")
            clash = [int(i) for i in ids if int(i) in self._by_id]
            if clash:
                raise ValueError(f"ids already live (upsert instead): "
                                 f"{clash[:5]}")
        if m and (ids.min() < 0 or ids.max() >= 2 ** 31 - 1):
            raise ValueError("ids must fit int32 (device id gather)")
        return ids

    def _append(self, words, hashes, ids) -> np.ndarray:
        """Shared append tail: chunked donated tail writes (ids already
        validated), seal-on-full, generation bump."""
        m = words.shape[0]
        pos = 0
        while pos < m:
            t = min(self.tail_rows - self.tail.length, m - pos)
            self._write_tail(words, hashes, ids, pos, t)
            pos += t
            if self.tail.length == self.tail_rows:
                self._seal_tail()
        self.next_id = max(self.next_id, int(ids.max()) + 1)
        self.generation += 1
        self._c_appended.inc(m)
        self._update_gauges()
        return ids

    def _write_tail(self, words, hashes, ids, pos: int, t: int):
        tail = self.tail
        start = tail.length
        # pad the chunk to a power of two when it fits, so the donated
        # write executable compiles O(log tail_rows) times, not O(sizes)
        tp = 1 << max(t - 1, 0).bit_length()
        if start + tp > self.tail_rows:
            tp = t
        chunk = jax.lax.dynamic_slice_in_dim(words, pos, t, 0)
        if tp > t:      # zero rows land on not-yet-valid slots
            chunk = jnp.pad(chunk, ((0, tp - t), (0, 0)))
        tail.words = _write_rows(tail.words, chunk, start)
        if hashes is not None:
            hc = jax.lax.dynamic_slice_in_dim(hashes, pos, t, 0)
            if tp > t:
                hc = jnp.pad(hc, ((0, tp - t), (0, 0)))
            tail.hashes = _write_rows(tail.hashes, hc, start)
        rows = np.arange(start, start + t)
        tail.ids[start:start + t] = ids[pos:pos + t]
        np.bitwise_or.at(tail.valid, rows // 32,
                         np.uint32(1) << (rows % 32).astype(np.uint32))
        self._by_id.update(
            (int(item), (tail, start + j))
            for j, item in enumerate(ids[pos:pos + t]))
        tail.live += t
        tail.length += t
        tail._valid_dev = None
        tail._ids_dev = None

    def _seal_tail(self):
        """The full tail becomes a sealed segment as-is (no copy: the id
        map keys on the Segment object, which just moves lists)."""
        self.sealed.append(self.tail)
        self.tail = self._new_tail()
        self._c_seals.inc()

    # -- deletes / upserts ---------------------------------------------------
    def delete(self, ids, strict: bool = True) -> int:
        """Tombstone external ids. Returns the number of rows killed;
        unknown ids raise (``strict``) or are ignored. Strict deletes are
        all-or-nothing: ids are validated before anything is tombstoned,
        so a raise leaves the store (and its generation) untouched."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        if strict:
            dead = [int(i) for i in ids if int(i) not in self._by_id]
            if dead:
                raise KeyError(f"ids not live: {dead[:5]}")
        killed = 0
        killed_ids = []
        for item in ids:
            loc = self._by_id.pop(int(item), None)
            if loc is None:
                continue
            seg, row = loc
            seg.kill_row(row)
            killed_ids.append(int(item))
            killed += 1
        if killed:
            self.generation += 1
            self._c_deleted.inc(killed)
            self._update_gauges()
            self._notify("delete", np.asarray(killed_ids, np.int64))
        return killed

    def upsert_codes(self, ids, codes) -> np.ndarray:
        """Replace-or-insert: tombstone each id's current row (if live),
        append the new version under the *same* external id. The batch is
        validated *before* the tombstones, so a bad upsert never loses
        the old versions."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        codes = jnp.asarray(codes)
        if codes.ndim != 2 or codes.shape != (ids.size, self.k):
            raise ValueError(f"codes {codes.shape} != [{ids.size}, "
                             f"{self.k}]")
        if np.unique(ids).size != ids.size:
            raise ValueError("duplicate ids within one batch")
        if ids.size and (ids.min() < 0 or ids.max() >= 2 ** 31 - 1):
            raise ValueError("ids must fit int32 (device id gather)")
        self.delete([i for i in ids if int(i) in self._by_id])
        return self.add_codes(codes, ids=ids)

    # -- live-row views (oracle / compaction / snapshot) ---------------------
    def live_ids(self) -> np.ndarray:
        """External ids of live rows in iteration order, int64 [n_live]."""
        out = [seg.ids[seg.live_rows()] for seg in self.segments()]
        return (np.concatenate(out) if out
                else np.zeros(0, np.int64))

    def live_words(self):
        """Packed live rows in iteration order -> uint32 [n_live, W]."""
        parts = [jnp.take(seg.words, jnp.asarray(rows), axis=0)
                 for seg in self.segments()
                 if (rows := seg.live_rows()).size]
        if not parts:
            return jnp.zeros((0, self.n_words), jnp.uint32)
        return jnp.concatenate(parts)

    def live_codes(self):
        """Unpacked live rows [n_live, k] int32 (fresh-build oracle)."""
        return _packing.unpack_codes(self.live_words(), self.bits, self.k)

    def stats(self) -> dict:
        """Operational counters: rows (live/dead), segments, tail fill,
        resident bytes, generation."""
        return {"n_live": self.n_live, "n_rows": self.n_rows,
                "n_dead": self.n_rows - self.n_live,
                "n_segments": self.n_segments,
                "tail_len": self.tail.length, "nbytes": self.nbytes,
                "generation": self.generation}
