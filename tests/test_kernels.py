"""Pallas kernels vs jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.schemes import CodeSpec, sample_offsets
from repro.kernels import ref
from repro.kernels.collision import collision_counts_pallas
from repro.kernels.pack_codes import pack_codes_pallas
from repro.kernels.proj_code import coded_project_pallas

SHAPES = [(8, 64, 32), (100, 700, 96), (128, 512, 128), (33, 1000, 17)]
DTYPES = [jnp.float32, jnp.bfloat16]
SCHEMES = [("uniform", 1.0), ("2bit", 0.75), ("sign", 1.0), ("offset", 1.0)]


@pytest.mark.parametrize("m,d,k", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
@pytest.mark.parametrize("scheme,w", SCHEMES)
def test_proj_code_matches_ref(m, d, k, dtype, scheme, w):
    key = jax.random.PRNGKey(m * 7 + k)
    x = jax.random.normal(key, (m, d), dtype)
    r = jax.random.normal(jax.random.fold_in(key, 1), (d, k), dtype)
    q = sample_offsets(jax.random.fold_in(key, 2), k, w)
    spec = CodeSpec(scheme, w)
    got = coded_project_pallas(x, r, spec, q, interpret=True,
                               block_m=32, block_k=32, block_d=64)
    want = ref.coded_project_ref(x, r, spec, q)
    mism = int(jnp.sum(got != want))
    # floor() at bin boundaries can differ by one ulp between accumulation
    # orders for bf16 inputs; allow a vanishing fraction there.
    tol = 0 if dtype == jnp.float32 else max(2, int(0.001 * got.size))
    assert mism <= tol, f"{mism}/{got.size} mismatches"


@pytest.mark.parametrize("bits", [1, 2, 4, 8])
@pytest.mark.parametrize("m,k", [(5, 17), (64, 256), (130, 100)])
def test_pack_codes_matches_ref(bits, m, k):
    codes = jax.random.randint(jax.random.PRNGKey(bits), (m, k), 0, 1 << bits)
    got = pack_codes_pallas(codes, bits, interpret=True, block_m=32)
    want = ref.pack_codes_ref(codes, bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("q,n,k", [(8, 16, 32), (33, 51, 77), (128, 64, 256)])
def test_collision_counts_matches_ref(q, n, k):
    key = jax.random.PRNGKey(q)
    cq = jax.random.randint(key, (q, k), 0, 4)
    cdb = jax.random.randint(jax.random.fold_in(key, 1), (n, k), 0, 4)
    got = collision_counts_pallas(cq, cdb, interpret=True,
                                  block_q=32, block_n=32, block_k=64)
    want = ref.collision_counts_ref(cq, cdb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ops_dispatch_cpu_uses_ref():
    from repro.kernels import ops
    x = jnp.ones((4, 8), jnp.float32)
    r = jnp.ones((8, 4), jnp.float32)
    spec = CodeSpec("sign", 1.0)
    out = ops.coded_project(x, r, spec)  # impl=auto -> ref on CPU
    np.testing.assert_array_equal(np.asarray(out), 1)
