"""Microbatching front-end for the ANN engines (serving-layer component).

Mirrors ``serve.serving``'s split between jit'd device steps and a thin
host loop: individual queries arrive via ``submit`` (a ticket comes
back), ``flush`` pads the pending queue up to the next bucket size and
runs ONE batched engine search per bucket-shaped batch. Bucketed padding
keeps the jit cache to a handful of entries regardless of traffic shape —
``warmup`` pre-compiles every bucket so the first real query never pays
compile latency.

Two engine flavors plug in unchanged: the immutable ``ann.AnnEngine``
and the mutable ``index.MutableAnnEngine``. For mutable engines the
service exposes ``add``/``delete``/``upsert``/``compact`` endpoints that
interleave with queries.

Result cache: an LRU keyed on the query's *packed code words* (identical
vectors — and any vectors that code identically — share an entry) plus
the search knobs. Entries are valid for exactly one engine
``generation``: any index mutation bumps the generation and the next
flush drops the whole cache, so a cached hit is always bit-identical to
a fresh search.

Classification: attach a trained ``repro.learn.PackedLinearModel``
(``set_classifier``) and ``classify`` runs the same fused
project→code→pack front end as search (the engine's shared
``QueryCoder``), then the packed-linear forward kernel — one service,
two workloads over one set of codes.

Observability: every endpoint reports through a ``repro.obs``
``MetricsRegistry`` (per-service instance by default; inject a shared
one via the ``registry`` field) — latency histograms (``serve.flush_s``,
``serve.search_batch_s``, ``serve.classify_s``), ticket age from
``submit`` to result (``serve.ticket_age_s``), cache hit/miss/eviction/
invalidation and warmup-compile counters, and a padding-waste gauge.
The old ad-hoc ``stats`` dict survives as a read-only compat property
derived from the counters. ``flush``/``classify`` also open tracing
spans when a ``repro.obs.Tracer`` is installed.

Flight recorder: every endpoint additionally appends a structured
event (op, queue/start/sync timestamps, batch shape, cache hits, store
generation, outcome, trace id) to an always-on ``obs.FlightRecorder``
ring, and ``flush``/``classify`` run under a ``TailSampler`` request:
each gets a shallow span chain, and the full trace is retained when the
request lands in the slow tail — keyed by *deadline-relative lateness*
(oldest ticket age minus ``cfg.deadline_s``, so "slow" means late
against the SLO, not merely large) — errors, or is flagged by a quality
monitor. Retained requests pin exemplars (their trace id) onto the
``serve.flush_s`` histogram buckets, and when an ``IncidentManager`` is
attached (``incidents`` field, or just a directory string) endpoint
errors and drift alarms dump full incident bundles.

Closed-loop health: ``slo=True`` (or an injected ``obs.slo.SloEngine``)
registers default ``SloSpec``s per endpoint — latency against
``cfg.deadline_s`` over ``serve.flush_s``/``serve.classify_s``,
availability from the ``serve.*_errors`` counters, and a quality SLO
fed by shadow recall — and ticks the engine once per flush/classify.
Burn-rate alarms ride the same wiring as drift alarms (flag the
in-flight trace, dump an incident bundle carrying the SLO state);
``service.slo.health()`` is the admission-control verdict.
``resources=True`` attaches an ``obs.resources.ResourceMonitor``
(engine store bytes tracked, jit-recompile counter armed at the end of
``warmup`` via ``mark_steady`` — the never-recompile invariant becomes
a budgeted SLO). ``probe_search``/``probe_classify`` are the canary
endpoints ``obs.probe.CanaryProber`` replays known-answer rows through:
the real serving path (cache included) with telemetry segregated under
``serve.probe.*`` and the tail sampler and quality samplers suspended.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from contextlib import contextmanager
from dataclasses import dataclass, field
from types import MappingProxyType

import numpy as np
import jax.numpy as jnp

from repro.ann.engine import SearchConfig
from repro.core import packing as _packing
from repro.kernels import ops as _ops
from repro.obs import (MetricsRegistry, TailSampler,
                       default_flight_recorder, span)

__all__ = ["AnnServiceConfig", "AnnService"]

#: shared no-op sampler for probe traffic — probes must never occupy
#: the retained-trace budget nor move the slow-tail threshold
_PROBE_SAMPLER = TailSampler(enabled=False)


@dataclass(frozen=True)
class AnnServiceConfig:
    """Static service knobs; one engine jit cache entry per bucket."""
    top_k: int = 10
    mode: str = "exact"            # exact | lsh
    min_bands: int = 1
    n_probes: int = 0
    buckets: tuple = (1, 8, 64, 256)   # padded batch shapes (ascending)
    cache_size: int = 256          # LRU result entries (0 disables)
    impl: str = "auto"
    scored: bool = False           # LUT-scored ranking (repro.rank)
    rerank_m: int = 0              # scored: coarse candidates (0 = auto)
    fused: bool = True             # single-pass fused scored kernel
    table_dtype: str = "auto"      # auto | f32 | bf16 | int8 (fused only)
    autotune_warmup: bool = False  # warmup also tunes kernel block sizes
    deadline_s: float = 0.050      # per-flush SLO; lateness keys the tail


@dataclass
class AnnService:
    """Queue + pad-to-bucket batching + result LRU over a shared engine;
    optionally also a classification endpoint over the same codes."""
    engine: object
    cfg: AnnServiceConfig = field(default_factory=AnnServiceConfig)
    classifier: object = None     # learn.PackedLinearModel (optional)
    registry: object = None       # obs.MetricsRegistry (own one if None)
    quality: object = None        # True | QualityConfig | QualityMonitors
    flight: object = None         # obs.FlightRecorder (global if None)
    sampler: object = None        # obs.TailSampler (own one if None)
    incidents: object = None      # obs.IncidentManager | directory str
    slo: object = None            # True | obs.slo.SloEngine
    resources: object = None      # True | obs.resources.ResourceMonitor

    def __post_init__(self):
        self._queue = []          # [(ticket, vector [D])]
        self._results = {}        # ticket -> (ids [top_k], rho [top_k])
        self._next_ticket = 0
        self._submit_ts = {}      # ticket -> submit wall-clock (ticket age)
        self._cache = OrderedDict()   # key -> (ids np, rho np)
        self._cache_gen = None
        if self.registry is None:
            self.registry = MetricsRegistry(enabled=True)
        reg = self.registry
        self._c_queries = reg.counter("serve.queries")
        self._c_batches = reg.counter("serve.batches")
        self._c_padded = reg.counter("serve.padded_rows")
        self._c_hits = reg.counter("serve.cache_hits")
        self._c_misses = reg.counter("serve.cache_misses")
        self._c_evict = reg.counter("serve.cache_evictions")
        self._c_inval = reg.counter("serve.cache_invalidations")
        self._c_warm = reg.counter("serve.warmup_compiles")
        self._c_classified = reg.counter("serve.classified_rows")
        self._c_flush_err = reg.counter("serve.flush_errors")
        self._c_classify_err = reg.counter("serve.classify_errors")
        self._h_flush = reg.histogram("serve.flush_s")
        self._h_batch = reg.histogram("serve.search_batch_s")
        self._h_age = reg.histogram("serve.ticket_age_s")
        self._h_classify = reg.histogram("serve.classify_s")
        self._g_pending = reg.gauge("serve.pending")
        self._g_waste = reg.gauge("serve.padding_waste")
        self._probing = False
        if self.flight is None:
            self.flight = default_flight_recorder()
        if self.sampler is None:
            self.sampler = TailSampler(registry=reg)
        if isinstance(self.incidents, str):
            from repro.obs import IncidentManager
            self.incidents = IncidentManager(
                self.incidents, flight=self.flight, sampler=self.sampler,
                registry=reg, generation_fn=lambda: getattr(
                    self.engine, "generation", 0))
        self._drift_flags = []    # series that alarmed since last request
        if self.quality is not None:
            from repro.obs.quality import QualityConfig, QualityMonitors
            if self.quality is True:
                self.quality = QualityConfig()
            if isinstance(self.quality, QualityConfig):
                self.quality = QualityMonitors(
                    self.engine.sketcher, self.quality, registry=reg)
            # the engine hook samples searches; mutable engines also
            # subscribe the shadow reservoir to store delete events
            if getattr(self.engine, "quality", None) is not self.quality:
                self.engine.attach_quality(self.quality)
            # drift alarms flag the in-flight request for trace
            # retention and (when wired) dump an incident bundle
            self.quality.on_drift(self._on_drift)
            if self.incidents is not None and \
                    getattr(self.incidents, "quality", None) is None:
                self.incidents.quality = self.quality
        if self.resources is True:
            from repro.obs.resources import ResourceMonitor
            self.resources = ResourceMonitor(registry=reg)
        if self.resources is not None:
            store = getattr(self.engine, "store", None)
            if store is not None and hasattr(store, "nbytes"):
                self.resources.track("engine.store", store)
        if self.slo is True:
            from repro.obs.slo import SloEngine
            self.slo = SloEngine(registry=reg)
        if self.slo is not None:
            from repro.obs.slo import SloSpec
            # default endpoint objectives: latency against the flush
            # deadline, availability from the error counters, quality
            # fed by shadow recall (floor 0.8) and probe verdicts
            if "search" not in self.slo.specs:
                self.slo.add(SloSpec(
                    "search", latency_hist="serve.flush_s",
                    latency_target_s=self.cfg.deadline_s,
                    error_counter="serve.flush_errors",
                    quality_min=0.8))
            if "classify" not in self.slo.specs:
                self.slo.add(SloSpec(
                    "classify", latency_hist="serve.classify_s",
                    latency_target_s=self.cfg.deadline_s,
                    error_counter="serve.classify_errors"))
            if self.resources is not None:
                self.slo.attach_resources(self.resources)
            # burn-rate alarms ride the drift wiring: flag the
            # in-flight trace for retention + dump an incident bundle
            self.slo.subscribe(self._on_drift)
            if self.incidents is not None and \
                    getattr(self.incidents, "slo", None) is None:
                self.incidents.slo = self.slo

    def _on_drift(self, series: str, value: float, detector):
        self._drift_flags.append(series)
        if self.incidents is not None:
            self.incidents.on_drift(series, value, detector)

    @property
    def stats(self):
        """Read-only view of the endpoint counters (compat shape: the
        pre-registry ad-hoc dict keys, plus the newer counters)."""
        return MappingProxyType({
            "queries": self._c_queries.value,
            "batches": self._c_batches.value,
            "padded_rows": self._c_padded.value,
            "cache_hits": self._c_hits.value,
            "cache_misses": self._c_misses.value,
            "cache_evictions": self._c_evict.value,
            "cache_invalidations": self._c_inval.value,
            "warmup_compiles": self._c_warm.value,
        })

    # -- request path --------------------------------------------------------
    def submit(self, x) -> int:
        """Enqueue one query vector [D]; returns a ticket for ``result``."""
        x = jnp.asarray(x)
        if x.ndim != 1:
            raise ValueError(f"submit takes a single vector, got {x.shape}")
        t = self._next_ticket
        self._next_ticket += 1
        self._queue.append((t, x))
        self._submit_ts[t] = time.perf_counter()
        self._g_pending.set(len(self._queue))
        return t

    def result(self, ticket: int):
        """(ids, rho) for a flushed ticket; KeyError if not flushed yet."""
        return self._results[ticket]

    def pending(self) -> int:
        return len(self._queue)

    # -- mutation endpoints (mutable engines only) ---------------------------
    def _mutable(self):
        if not getattr(self.engine, "mutable", False):
            raise TypeError("engine is immutable (ann.AnnEngine); build "
                            "the service over index.MutableAnnEngine for "
                            "add/delete/upsert")
        return self.engine

    def _mut_event(self, op: str, t0: float, batch: int = 0,
                   outcome: str = "ok"):
        """One flight event for a mutation endpoint (generation read
        *after* the mutation, so the event carries the new one)."""
        self.flight.record(op, t0, time.perf_counter(), batch=batch,
                           generation=getattr(self.engine,
                                              "generation", 0),
                           outcome=outcome)

    def add(self, x, ids=None):
        """Ingest vectors [m, D]; returns their external ids. The result
        cache invalidates on the next flush (generation bump)."""
        t0 = time.perf_counter()
        out = self._mutable().add(x, ids=ids)
        if self.quality is not None:
            self.quality.offer_rows(out, x)
        self._mut_event("serve.add", t0, batch=len(np.asarray(out)))
        return out

    def bulk_load(self, x, ids=None, chunk_rows: int = 2048):
        """Stream a whole corpus (dense [m, D] or ``encode.CsrMatrix``)
        into the index through the fused matrix-free ingest pipeline
        (``repro.encode``): chunked project→code→pack with only packed
        words written back, O(batch) tail appends. Returns the external
        ids int64 [m]; the result cache invalidates on the next flush.
        """
        t0 = time.perf_counter()
        out = self._mutable().ingest(x, ids=ids, chunk_rows=chunk_rows,
                                     impl=self.cfg.impl)
        if self.quality is not None:
            self.quality.offer_rows(out, x)
        self._mut_event("serve.bulk_load", t0, batch=len(np.asarray(out)))
        return out

    def delete(self, ids, strict: bool = True) -> int:
        """Tombstone external ids; the quality bundle's shadow reservoir
        (if attached) drops them via the store's delete listener."""
        t0 = time.perf_counter()
        n = self._mutable().delete(ids, strict=strict)
        self._mut_event("serve.delete", t0, batch=int(n))
        return n

    def upsert(self, ids, x):
        t0 = time.perf_counter()
        out = self._mutable().upsert(ids, x)
        if self.quality is not None:
            self.quality.offer_rows(out, x)
        self._mut_event("serve.upsert", t0, batch=len(np.asarray(out)))
        return out

    def compact(self, *args, **kwargs) -> dict:
        t0 = time.perf_counter()
        out = self._mutable().compact(*args, **kwargs)
        self._mut_event("serve.compact", t0,
                        batch=int(out.get("rows_dropped", 0)))
        return out

    # -- classification endpoint ---------------------------------------------
    def set_classifier(self, model) -> "AnnService":
        """Attach a trained ``learn.PackedLinearModel`` (k/bits must
        match the engine's store); returns self for chaining."""
        store = self.engine.store
        if (model.fspec.k, model.fspec.bits) != (store.k, store.bits):
            raise ValueError(
                f"classifier k/bits {(model.fspec.k, model.fspec.bits)} "
                f"!= store {(store.k, store.bits)}")
        self.classifier = model
        return self

    def classify(self, x):
        """Classify vectors x [m, D] -> (labels int [m], margins f32
        [C, m]) through the engine's shared fused query coder and the
        packed-linear forward kernel; requires ``set_classifier``.

        Batches are padded up to the service's bucket shapes (slices of
        at most the largest bucket), so classify traffic shares the
        search path's never-recompile property: one executable per
        bucket, whatever m arrives.
        """
        if self.classifier is None:
            raise TypeError("no classifier attached; call "
                            "set_classifier(model) first")
        x = jnp.asarray(x)
        if x.ndim != 2:
            raise ValueError(f"classify takes a batch [m, D], got {x.shape}")
        t0 = time.perf_counter()
        with self.sampler.request("classify", rows=int(x.shape[0])) as rq:
            with span("serve.classify", rows=int(x.shape[0])) as sp:
                try:
                    preds, margs = [], []
                    max_b = self.cfg.buckets[-1]
                    for lo in range(0, x.shape[0], max_b):
                        sub = x[lo:lo + max_b]
                        n = sub.shape[0]
                        b = self._bucket_for(n)
                        if b > n:
                            sub = jnp.pad(sub, ((0, b - n), (0, 0)))
                        codes = self.engine.encode_queries(
                            sub, impl=self.cfg.impl)
                        words = _ops.pack_codes(
                            codes, self.engine.store.bits,
                            impl=self.cfg.impl)
                        m = self.classifier.margins(
                            words, impl=self.cfg.impl)
                        preds.append(np.asarray(
                            self.classifier.predict_from_margins(m))[:n])
                        margs.append(np.asarray(sp.sync(m))[:, :n])
                    self._c_classified.inc(int(x.shape[0]))
                except Exception as e:
                    self._c_classify_err.inc()
                    if self.incidents is not None:
                        self.incidents.capture(
                            "error",
                            f"classify: {type(e).__name__}: {e}")
                    raise
        t1 = time.perf_counter()
        self._h_classify.observe(t1 - t0)
        self.flight.record("serve.classify", t0, t1,
                           batch=int(x.shape[0]),
                           generation=self._cache_gen or 0,
                           trace_id=rq.trace_id, synced=True)
        if rq.retained:
            self._h_classify.exemplar(t1 - t0, rq.trace_id)
        labels, margins = np.concatenate(preds), np.concatenate(margs, axis=1)
        qm = self.quality
        if qm is not None and qm.sample():
            qm.observe_margins(margins)     # calibration drift series
        if self.slo is not None:
            self.slo.tick()
        return labels, margins

    # -- batch execution -----------------------------------------------------
    def _bucket_for(self, n: int) -> int:
        for b in self.cfg.buckets:
            if n <= b:
                return b
        return self.cfg.buckets[-1]

    def _cache_key(self, word_row: np.ndarray):
        """Result-cache key: the query's packed code words + every knob
        that changes the search result (scored included — count-ranked
        and score-ranked results never alias)."""
        cfg = self.cfg
        return (word_row.tobytes(), cfg.top_k, cfg.mode, cfg.min_bands,
                cfg.n_probes, cfg.scored, cfg.rerank_m, cfg.fused,
                cfg.table_dtype)

    def _sync_cache_generation(self):
        gen = getattr(self.engine, "generation", 0)
        if gen != self._cache_gen:
            if self._cache_gen is not None and self._cache:
                self._c_inval.inc()
            self._cache.clear()
            self._cache_gen = gen

    def flush(self):
        """Run every pending query; returns {ticket: (ids, rho)}.

        Queries are taken in arrival order, in slices of at most the
        largest bucket; cache hits are served host-side and only misses
        are padded up to a bucket shape and searched.

        The whole flush runs as one tail-sampled request: its trace is
        retained when the oldest ticket finishes later than
        ``cfg.deadline_s`` past the current slow-quantile threshold,
        when it raises (also captured as an incident bundle when an
        ``IncidentManager`` is wired), or when a quality monitor
        flagged drift since the last request.
        """
        t_flush = time.perf_counter()
        with self.sampler.request("search",
                                  pending=len(self._queue)) as rq:
            with span("serve.flush", pending=len(self._queue)) as sp:
                try:
                    out = self._flush(sp, rq)
                except Exception as e:
                    self._c_flush_err.inc()
                    if self.incidents is not None:
                        self.incidents.capture(
                            "error", f"flush: {type(e).__name__}: {e}")
                    raise
            if self._drift_flags:
                for s in self._drift_flags:
                    rq.flag(s)
                self._drift_flags = []
        dur = time.perf_counter() - t_flush
        self._h_flush.observe(dur)
        if rq.retained:
            self._h_flush.exemplar(dur, rq.trace_id)
        self._g_pending.set(len(self._queue))
        if self.slo is not None:
            self.slo.tick()
        return out

    # -- canary-probe endpoints ----------------------------------------------
    @contextmanager
    def _probe_context(self):
        """Run one probe through the real endpoint code with its
        telemetry segregated: every per-request metric the endpoints
        touch is swapped for a ``probe.*`` twin, the tail sampler is
        replaced by a disabled one (probes never occupy the retained-
        trace budget or shift the slow-tail threshold), and quality
        sampling is suspended at both the service and the engine's
        collision hook (probes must not advance the seeded
        shadow/margin sampling streams or skew collision statistics —
        a replayed user workload still samples identically). The
        result cache and engine path are deliberately untouched: a
        probe exercises exactly what user traffic exercises, stale
        cache included."""
        reg = self.registry
        saved = (self._h_flush, self._h_batch, self._h_age,
                 self._h_classify, self._c_queries, self._c_hits,
                 self._c_misses, self._c_batches, self._c_padded,
                 self._c_classified, self._c_flush_err,
                 self._c_classify_err, self._g_waste, self.sampler,
                 self.quality)
        eng_quality = getattr(self.engine, "quality", None)
        self._h_flush = reg.histogram("serve.probe.flush_s")
        self._h_batch = reg.histogram("serve.probe.search_batch_s")
        self._h_age = reg.histogram("serve.probe.ticket_age_s")
        self._h_classify = reg.histogram("serve.probe.classify_s")
        self._c_queries = reg.counter("serve.probe.queries")
        self._c_hits = reg.counter("serve.probe.cache_hits")
        self._c_misses = reg.counter("serve.probe.cache_misses")
        self._c_batches = reg.counter("serve.probe.batches")
        self._c_padded = reg.counter("serve.probe.padded_rows")
        self._c_classified = reg.counter("serve.probe.classified_rows")
        self._c_flush_err = reg.counter("serve.probe.flush_errors")
        self._c_classify_err = reg.counter("serve.probe.classify_errors")
        self._g_waste = reg.gauge("serve.probe.padding_waste")
        self.sampler = _PROBE_SAMPLER
        self.quality = None
        if eng_quality is not None:      # engine-level collision hook
            self.engine.quality = None
        self._probing = True
        try:
            yield
        finally:
            (self._h_flush, self._h_batch, self._h_age,
             self._h_classify, self._c_queries, self._c_hits,
             self._c_misses, self._c_batches, self._c_padded,
             self._c_classified, self._c_flush_err,
             self._c_classify_err, self._g_waste, self.sampler,
             self.quality) = saved
            if eng_quality is not None:
                self.engine.quality = eng_quality
            self._probing = False

    def probe_search(self, x):
        """Known-answer canary search of ONE vector [D]; returns
        (ids, rho). The real submit→flush path runs — bucket padding,
        result cache, engine search — under ``_probe_context`` so the
        probe is invisible to user-facing metrics, the tail sampler,
        and the quality samplers (``obs.probe`` holds the prober that
        drives this and judges the answer)."""
        x = jnp.asarray(x)
        if x.ndim != 1:
            raise ValueError(f"probe_search takes one vector, "
                             f"got {x.shape}")
        saved_queue, self._queue = self._queue, []
        t0 = time.perf_counter()
        outcome = "error"
        t = None
        try:
            with self._probe_context():
                t = self.submit(x)
                out = self.flush()
            outcome = "ok"
            return out[t]
        finally:
            if t is not None:
                self._results.pop(t, None)
                self._submit_ts.pop(t, None)
            self._queue = saved_queue
            self._g_pending.set(len(self._queue))
            self.flight.record("serve.probe", t0, time.perf_counter(),
                               batch=1, generation=self._cache_gen or 0,
                               outcome=outcome)

    def probe_classify(self, x):
        """Canary classify of a batch [m, D] through the real
        ``classify`` path with probe-segregated telemetry; returns
        (labels, margins)."""
        t0 = time.perf_counter()
        outcome = "ok"
        try:
            with self._probe_context():
                return self.classify(x)
        except Exception:
            outcome = "error"
            raise
        finally:
            self.flight.record("serve.probe_classify", t0,
                               time.perf_counter(),
                               batch=int(np.asarray(x).shape[0]),
                               generation=self._cache_gen or 0,
                               outcome=outcome)

    def _flush(self, sp, rq=None):
        out = {}
        cfg = self.cfg
        self._sync_cache_generation()
        max_b = cfg.buckets[-1]
        max_age = 0.0
        trace_id = rq.trace_id if rq is not None else 0
        while self._queue:
            batch = self._queue[:max_b]
            self._queue = self._queue[max_b:]
            n = len(batch)
            # pad to the bucket BEFORE any device work, so every jit'd
            # stage (encode included) only ever sees bucket shapes
            b = self._bucket_for(n)
            x = jnp.stack([v for _, v in batch])
            if b > n:
                x = jnp.pad(x, ((0, b - n), (0, 0)))
            q_codes = self.engine.encode_queries(x, impl=cfg.impl)
            qm = self.quality
            if qm is not None and qm.sample():
                # budgeted shadow check of one real (unpadded) query:
                # exact-cosine ground truth vs the coded ranking over
                # the reservoir (obs.shadow)
                qi = int(qm.rng.integers(n))
                r = qm.shadow_check(batch[qi][1],
                                    self.engine.encode_queries,
                                    q_codes=q_codes[qi])
                if r is not None and self.slo is not None:
                    # shadow recall is the quality SLO's ground truth
                    self.slo.observe_quality("search", r)
            res = [None] * n
            miss = list(range(n))
            keys = None
            if cfg.cache_size:
                words = np.asarray(_packing.pack_codes(
                    q_codes, self.engine.store.bits))
                keys = [self._cache_key(words[i]) for i in range(n)]
                miss = []
                for i, key in enumerate(keys):
                    hit = self._cache.get(key)
                    if hit is not None:
                        self._cache.move_to_end(key)
                        res[i] = hit
                    else:
                        miss.append(i)
            if miss:
                if len(miss) == n:
                    sub, b2 = q_codes, b          # already bucket-shaped
                else:
                    # gather with a bucket-shaped index list (row 0
                    # repeated as filler) so the gather itself only ever
                    # compiles at bucket shapes
                    b2 = self._bucket_for(len(miss))
                    idx = miss + [0] * (b2 - len(miss))
                    sub = q_codes[jnp.asarray(idx)]
                t_batch = time.perf_counter()
                ids, rho = self.engine.search_codes(
                    sub, SearchConfig(top_k=cfg.top_k, mode=cfg.mode,
                                      min_bands=cfg.min_bands,
                                      n_probes=cfg.n_probes, chunk_q=b2,
                                      impl=cfg.impl, scored=cfg.scored,
                                      rerank_m=cfg.rerank_m,
                                      fused=cfg.fused,
                                      table_dtype=cfg.table_dtype))
                # host transfer is the device sync for this batch's
                # timing (np.asarray blocks on the result buffers)
                ids, rho = np.asarray(sp.sync(ids)), np.asarray(rho)
                t_done = time.perf_counter()
                self._h_batch.observe(t_done - t_batch)
                self.flight.record(
                    "serve.search", t_batch, t_done,
                    t_queue=min(self._submit_ts.get(t, t_batch)
                                for t, _ in batch),
                    batch=b2, cache_hits=n - len(miss),
                    generation=self._cache_gen or 0,
                    trace_id=trace_id, synced=True)
                for j, i in enumerate(miss):
                    res[i] = (ids[j], rho[j])
                    if cfg.cache_size:
                        self._cache[keys[i]] = res[i]
                        while len(self._cache) > cfg.cache_size:
                            self._cache.popitem(last=False)
                            self._c_evict.inc()
                self._c_batches.inc()
                self._c_padded.inc(b2 - len(miss))
                self._g_waste.set((b2 - len(miss)) / b2)
            now = time.perf_counter()
            for (t, _), r in zip(batch, res):
                self._results[t] = r
                out[t] = r
                t0 = self._submit_ts.pop(t, None)
                if t0 is not None:
                    age = now - t0
                    self._h_age.observe(age)
                    if age > max_age:
                        max_age = age
            self._c_queries.inc(n)
            self._c_hits.inc(n - len(miss))
            self._c_misses.inc(len(miss))
        if rq is not None:
            # deadline-relative lateness keys the slow-tail reservoir:
            # a flush is "slow" when its oldest ticket beat the SLO by
            # less than its peers, not merely when it was large
            rq.set_key(max_age - cfg.deadline_s)
        return out

    def warmup(self, d: int):
        """Pre-compile every bucket shape (cold-start insurance).

        With ``autotune_warmup=True`` this first runs the block-size
        sweep for the search kernel families at the engine's corpus
        shape (``kernels.autotune.tune_search_ops``) so the bucket
        compiles below already pick up tuned configs; on CPU backends
        the sweep is a safe no-op (autotune refuses to measure there).
        """
        cfg = self.cfg
        if cfg.autotune_warmup:
            from repro.kernels import autotune as _autotune
            store = self.engine.store
            dtype = {"auto": "float32", "f32": "float32",
                     "bf16": "bfloat16", "int8": "int8"}.get(
                         cfg.table_dtype, "float32")
            # CodeStore carries a words array; SegmentLogStore carries
            # the packed width directly
            n_rows = int(getattr(store, "n", 0)
                         or getattr(store, "n_rows", 0) or 0)
            w = (store.words.shape[-1] if hasattr(store, "words")
                 else store.n_words)
            _autotune.tune_search_ops(
                n=max(n_rows, 1), w=w, bits=store.bits,
                k=self.engine.sketcher.cfg.k, q=cfg.buckets[-1],
                top_k=cfg.top_k, table_dtype=dtype)
        with span("serve.warmup", buckets=len(cfg.buckets)) as sp:
            for b in cfg.buckets:
                sp.sync(self.engine.search(
                    jnp.zeros((b, d)), cfg.top_k, mode=cfg.mode,
                    min_bands=cfg.min_bands,
                    n_probes=cfg.n_probes, chunk_q=b,
                    impl=cfg.impl, scored=cfg.scored,
                    rerank_m=cfg.rerank_m, fused=cfg.fused,
                    table_dtype=cfg.table_dtype))
                self._c_warm.inc()
        # warmup compiles are free; anything after this burns the
        # never-recompile budget (obs.resources / obs.slo)
        if self.resources is not None:
            self.resources.mark()
        if self.slo is not None:
            self.slo.mark_steady()
        return self
