"""Train-step factory + preemption-safe trainer loop.

Two train-step flavors:

* ``make_train_step``   — GSPMD path: params TP-sharded (logical rules),
  optimizer state additionally ZeRO-1 sharded over DP; jit with explicit
  in/out shardings so reduce-scatter/all-gather placement is GSPMD's.
* ``make_compressed_train_step`` — shard_map pure-DP path where gradient
  synchronization goes through the paper's coded-sketch compressor
  (repro.core.gradient_compression) instead of a psum. Used for the
  collective-term study in EXPERIMENTS.md §Perf and by examples.

The Trainer handles: resume-from-latest, SIGTERM checkpoint-and-exit
(preemption), one transient-failure retry per step, step-time EMA
straggler monitor, periodic + final checkpoints.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.parallel.sharding import shard_map_unchecked
from repro.models import lm as L
from repro.models.nn import abstract_params, param_shardings, init_params
from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.parallel.sharding import ShardingRules, zero_shard_spec

__all__ = ["make_train_step", "make_compressed_train_step", "TrainState",
           "Trainer", "make_state_shardings"]


def make_state_shardings(cfg, rules: ShardingRules, master_fp32: bool = True):
    """(param_shardings, opt_shardings) — opt state gets ZeRO-1 over DP."""
    specs = L.model_param_specs(cfg)
    p_shard = param_shardings(specs, rules)
    if rules.mesh is None:
        return p_shard, None

    def zero(s):
        ps = rules.pspec_for(s.shape, s.axes)
        start = 1 if (s.axes and s.axes[0] == "layers") else 0
        return NamedSharding(rules.mesh,
                             zero_shard_spec(rules, ps, s.shape, start=start))

    from repro.models.nn import ParamSpec  # local import to avoid cycle
    z_shard = jax.tree.map(zero, specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    opt_shard = {
        "step": NamedSharding(rules.mesh, P()),
        "m": z_shard, "v": z_shard,
    }
    if master_fp32:
        opt_shard["master"] = z_shard
    return p_shard, opt_shard


def make_train_step(cfg, opt_cfg: AdamWConfig, rules: ShardingRules,
                    donate: bool = True):
    """jit'd (params, opt_state, tokens) -> (params, opt_state, metrics)."""

    def step(params, opt_state, tokens):
        if rules.mesh is not None:
            tokens = rules.shard(tokens, *(("batch", "seq", "codebooks")
                                           [:tokens.ndim]))
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: L.lm_loss(p, tokens, cfg, rules), has_aux=True)(params)
        new_params, new_opt, om = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss, **om)
        return new_params, new_opt, metrics

    if rules.mesh is None:
        return jax.jit(step, donate_argnums=(0, 1) if donate else ())

    p_shard, opt_shard = make_state_shardings(cfg, rules, opt_cfg.master_fp32)
    tok_shard = rules.sharding("batch", "seq")
    return jax.jit(
        step,
        in_shardings=(p_shard, opt_shard, tok_shard),
        out_shardings=(p_shard, opt_shard, None),
        donate_argnums=(0, 1) if donate else (),
    )


def make_compressed_train_step(cfg, opt_cfg: AdamWConfig, mesh, compressor,
                               axis: str = "data"):
    """Pure-DP shard_map step with coded-sketch gradient sync.

    params/opt replicated; tokens sharded over ``axis``; per-rank grads
    synced via compressor.sync (all-gather of codes) instead of psum.
    """

    def step(params, opt_state, ef, tokens):
        def local_loss(p, t):
            loss, _ = L.lm_loss(p, t, cfg, None)
            return loss

        loss, grads = jax.value_and_grad(local_loss)(params, tokens)
        loss = jax.lax.pmean(loss, axis)
        if compressor is None:  # plain-psum DP baseline (same code path)
            grads = jax.lax.pmean(grads, axis)
            new_ef = ef
        else:
            grads, new_ef = compressor.sync(grads, ef, axis,
                                            step=opt_state["step"])
        new_params, new_opt, om = adamw_update(params, grads, opt_state, opt_cfg)
        return new_params, new_opt, new_ef, dict(om, loss=loss)

    def wrapped(params, opt_state, ef, tokens):
        return shard_map_unchecked(
            step, mesh=mesh,
            in_specs=(P(), P(), P(), P(axis)),   # prefix specs: replicated
            out_specs=(P(), P(), P(), P()),
        )(params, opt_state, ef, tokens)

    return jax.jit(wrapped, donate_argnums=(0, 1, 2))


@dataclass
class TrainState:
    params: object
    opt_state: object
    step: int = 0
    ef: object = None     # error-feedback state (compressed path)


class Trainer:
    """Preemption-safe loop around a train step."""

    def __init__(self, step_fn: Callable, state: TrainState, pipeline,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 100,
                 keep: int = 3, log_every: int = 10, log_fn=print):
        self.step_fn = step_fn
        self.state = state
        self.pipeline = pipeline
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.keep = keep
        self.log_every = log_every
        self.log = log_fn
        self._preempted = False
        self._ema = None
        self.history = []

    def _install_sigterm(self):
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:  # not main thread
            pass

    def maybe_resume(self):
        if not self.ckpt_dir:
            return
        step = latest_step(self.ckpt_dir)
        if step is None:
            return
        tree = {"params": self.state.params, "opt": self.state.opt_state}
        if self.state.ef is not None:
            tree["ef"] = self.state.ef
        restored = restore_checkpoint(self.ckpt_dir, step, tree)
        self.state.params = restored["params"]
        self.state.opt_state = restored["opt"]
        if self.state.ef is not None:
            self.state.ef = restored["ef"]
        self.state.step = step
        self.log(f"[trainer] resumed from step {step}")

    def checkpoint(self):
        if not self.ckpt_dir:
            return
        tree = {"params": self.state.params, "opt": self.state.opt_state}
        if self.state.ef is not None:
            tree["ef"] = self.state.ef
        save_checkpoint(self.ckpt_dir, self.state.step, tree, keep=self.keep)

    def run(self, n_steps: int):
        self._install_sigterm()
        s = self.state
        while s.step < n_steps and not self._preempted:
            tokens = self.pipeline.batch_at(s.step)
            t0 = time.monotonic()
            try:
                out = self._apply(tokens)
            except Exception as e:  # one retry for transient failures
                self.log(f"[trainer] step {s.step} failed ({e!r}); retrying once")
                out = self._apply(tokens)
            self._absorb(out)
            dt = time.monotonic() - t0
            self._ema = dt if self._ema is None else 0.9 * self._ema + 0.1 * dt
            if dt > 3.0 * self._ema and s.step > 5:
                self.log(f"[trainer] straggler: step {s.step} took {dt:.2f}s "
                         f"(ema {self._ema:.2f}s)")
            s.step += 1
            if s.step % self.log_every == 0:
                m = self.history[-1]
                self.log(f"[trainer] step {s.step} loss={float(m['loss']):.4f} "
                         f"gnorm={float(m['grad_norm']):.3f} {dt * 1e3:.0f}ms")
            if self.ckpt_every and s.step % self.ckpt_every == 0:
                self.checkpoint()
        self.checkpoint()
        if self._preempted:
            self.log("[trainer] SIGTERM received: checkpointed and exiting")
        return self.history

    def _apply(self, tokens):
        s = self.state
        if s.ef is not None:
            return self.step_fn(s.params, s.opt_state, s.ef, tokens)
        return self.step_fn(s.params, s.opt_state, tokens)

    def _absorb(self, out):
        s = self.state
        if s.ef is not None:
            s.params, s.opt_state, s.ef, metrics = out
        else:
            s.params, s.opt_state, metrics = out
        self.history.append(metrics)
