"""Fig 5: optimum-w curves — V at the per-rho optimal w for h_w / h_{w,q},
and the ~0.56 threshold where h_w's optimal w exceeds 6 (1 bit suffices)."""
import numpy as np
import jax.numpy as jnp

from repro.core.optimal import optimal_w
from benchmarks._util import timed, write_csv


def run(quick: bool = True):
    rhos = np.linspace(0.01, 0.98, 40 if quick else 160)

    def curves():
        w_u, v_u = optimal_w(jnp.asarray(rhos), "uniform")
        w_q, v_q = optimal_w(jnp.asarray(rhos), "offset")
        return (np.asarray(w_u), np.asarray(v_u),
                np.asarray(w_q), np.asarray(v_q))

    (w_u, v_u, w_q, v_q), us = timed(curves, repeat=1)
    write_csv("fig05_optimal_w", ["rho", "w_star_hw", "V_star_hw",
                                  "w_star_hwq", "V_star_hwq"],
              np.stack([rhos, w_u, v_u, w_q, v_q], 1).tolist())
    # threshold: largest rho with w*(h_w) > 6
    thr = rhos[np.where(w_u > 6)[0]].max() if np.any(w_u > 6) else float("nan")
    return [("fig05_threshold", us,
             f"rho_thresh={thr:.3f};paper~0.56;"
             f"Vstar_ratio@rho0.25={v_q[np.argmin(abs(rhos-0.25))]/v_u[np.argmin(abs(rhos-0.25))]:.2f}")]
