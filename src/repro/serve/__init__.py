from repro.serve.serving import make_serve_step, generate  # noqa: F401
