"""Production mesh definition (functions only — importing this module
never touches jax device state)."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_dp_mesh", "dp_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (data, model) per pod; 2x16x16 (pod, data, model) multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=types)


def make_dp_mesh(n_devices: int | None = None):
    """Pure data-parallel mesh (gradient-compression study / examples)."""
    n = n_devices or len(jax.devices())
    return jax.make_mesh((n,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
