"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the 1 real CPU device; only launch/dryrun.py forces 512 host devices
(and tests/test_distributed.py spawns subprocesses with 8)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
