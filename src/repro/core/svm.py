"""Linear SVM on coded random projections (paper §6) — compat shim.

Historically this module owned the dense pipeline: materialize the full
[n, k * 2^b] one-hot feature matrix (``expand_codes``) and solve the
squared-hinge L2 SVM on it with full-batch Adam. Training now lives in
``repro.learn``, which never builds that matrix — margins are
per-projection weight-table gathers and gradients scatter straight back
into the packed tables (``kernels.packed_linear``), so the paper's SVM
experiments run at corpus sizes where the dense expansion cannot fit.

The original API survives here as thin wrappers over ``repro.learn``
(the same move ``core.lsh`` made for search in PR 1): ``expand_codes``
is re-exported as the parity oracle, ``train_linear_svm`` delegates to
the shared dense solver (bit-identical trajectory to the historical
code), ``svm_accuracy`` is unchanged. New code should use
``repro.learn.train_packed_linear`` / ``learn.trainer.fit_store`` and
get the packed, masked, minibatch and sharded paths.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax.numpy as jnp

from repro.learn.features import expand_codes  # noqa: F401  (compat re-export)
from repro.learn.linear import LearnConfig, train_dense_linear

__all__ = ["expand_codes", "SVMConfig", "train_linear_svm", "svm_accuracy"]


@dataclass(frozen=True)
class SVMConfig:
    """Knobs of the historical dense solver (see ``learn.LearnConfig``)."""
    c: float = 1.0           # L2 regularization tradeoff (LIBLINEAR's C)
    steps: int = 400
    lr: float = 0.1
    seed: int = 0


def train_linear_svm(x, y, cfg: SVMConfig = SVMConfig(),
                     x_val: Optional[jnp.ndarray] = None,
                     y_val: Optional[jnp.ndarray] = None):
    """Train a binary squared-hinge SVM on dense features x [n, d],
    y ±1 [n]. Returns (w, b). Delegates to
    ``learn.linear.train_dense_linear`` (same objective, same Adam +
    cosine schedule as the historical in-module solver)."""
    return train_dense_linear(
        x, y, LearnConfig(loss="sq_hinge", c=cfg.c, steps=cfg.steps,
                          lr=cfg.lr, seed=cfg.seed), x_val, y_val)


def svm_accuracy(w, b, x, y):
    """Accuracy of sign(x @ w + b) against ±1 labels (0 counts as +1)."""
    pred = jnp.sign(x @ w + b)
    pred = jnp.where(pred == 0, 1.0, pred)
    return jnp.mean((pred == y).astype(jnp.float32))
