"""MoE routing semantics (single-device path; the 8-device shard_map
parity test lives in test_distributed.py)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.models.moe import MoEConfig, moe, moe_param_specs, _moe_inner, _route
from repro.models.nn import init_params


def _setup(cap=8.0, e=8, k=2, d=16, f=8):
    c = MoEConfig(d_model=d, n_experts=e, n_per_token=k, d_ff=f,
                  capacity_factor=cap)
    params = init_params(moe_param_specs(c), seed=0)
    return c, params


def test_moe_matches_dense_reference_when_no_drop():
    # with huge capacity, gather/scatter MoE == dense per-token expert mix
    c, params = _setup(cap=16.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (30, c.d_model), jnp.float32)
    out, _ = _moe_inner(x, params, c, 1, None)

    gate, expert, tok, probs = _route(x, params["w_router"], c)
    dense = np.zeros((30, c.d_model), np.float32)
    w_g, w_u, w_d = (np.asarray(params[k2], np.float32)
                     for k2 in ("w_gate", "w_up", "w_down"))
    xn = np.asarray(x)
    for a in range(gate.shape[0]):
        e_idx, t_idx, g = int(expert[a]), int(tok[a]), float(gate[a])
        h = (xn[t_idx] @ w_g[e_idx])
        h = h / (1 + np.exp(-h)) * (xn[t_idx] @ w_u[e_idx])
        dense[t_idx] += g * (h @ w_d[e_idx])
    np.testing.assert_allclose(np.asarray(out, np.float32), dense,
                               rtol=2e-2, atol=2e-2)


def test_moe_drops_tokens_at_low_capacity():
    c, params = _setup(cap=0.25)
    x = jax.random.normal(jax.random.PRNGKey(2), (64, c.d_model), jnp.float32)
    out_low, _ = _moe_inner(x, params, c, 1, None)
    c_hi, _ = _setup(cap=16.0)
    out_hi, _ = _moe_inner(x, params, c_hi, 1, None)
    # low capacity must zero some tokens' contributions
    changed = np.mean(np.any(np.asarray(out_low) != np.asarray(out_hi), axis=-1))
    assert changed > 0.2


def test_gate_renormalization():
    c, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(3), (10, c.d_model))
    gate, _, _, _ = _route(x, params["w_router"], c)
    sums = np.asarray(gate).reshape(10, c.n_per_token).sum(1)
    np.testing.assert_allclose(sums, 1.0, atol=1e-5)


def test_aux_loss_balanced_router_is_one():
    # perfectly uniform router -> aux ~ 1 (Switch normalization)
    c, params = _setup()
    params = dict(params)
    params["w_router"] = jnp.zeros_like(params["w_router"])
    x = jax.random.normal(jax.random.PRNGKey(4), (256, c.d_model))
    _, aux = _moe_inner(x, params, c, 1, None)
    assert 0.9 < float(aux) < 1.1


def test_moe_full_layer_shapes():
    c, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 12, c.d_model),
                          jnp.bfloat16)
    out, aux = moe(params, x, c, None)
    assert out.shape == x.shape and out.dtype == x.dtype
    assert np.isfinite(float(aux))
