"""Fig 1: collision probabilities P_w vs P_{w,q} over w for selected rho,
validated against Monte-Carlo simulation."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import probabilities as P
from repro.core import schemes as S
from benchmarks._util import timed, write_csv

RHOS = [0.0, 0.25, 0.5, 0.75, 0.9, 0.99]
WS = np.round(np.geomspace(0.1, 10.0, 25), 4)


def run(quick: bool = True):
    rho = jnp.asarray(RHOS)
    rows = []

    def grid():
        return [(w, np.asarray(P.collision_prob_uniform(rho, float(w))),
                 np.asarray(P.collision_prob_offset(rho, float(w))))
                for w in WS]

    table, us = timed(grid, repeat=1)
    for w, pw, pwq in table:
        for r, a, b in zip(RHOS, pw, pwq):
            rows.append([w, r, float(a), float(b)])
    write_csv("fig01_collision", ["w", "rho", "P_w", "P_wq"], rows)

    # paper claim: at rho=0, P_w -> 0.5 while P_wq -> 1 as w grows
    pw_inf = float(P.collision_prob_uniform(jnp.asarray(0.0), 10.0))
    pwq_inf = float(P.collision_prob_offset(jnp.asarray(0.0), 10.0))

    # Monte-Carlo validation at (rho=0.5, w=1)
    key = jax.random.PRNGKey(0)
    n = 200_000 if quick else 2_000_000
    z1 = jax.random.normal(key, (n,))
    z2 = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    x, y = z1, 0.5 * z1 + np.sqrt(0.75) * z2
    mc = float(jnp.mean((S.encode_uniform(x, 1.0) == S.encode_uniform(y, 1.0))
                        .astype(jnp.float32)))
    th = float(P.collision_prob_uniform(jnp.asarray(0.5), 1.0))

    return [("fig01_grid", us, f"Pw(0,10)={pw_inf:.4f};Pwq(0,10)={pwq_inf:.4f}"),
            ("fig01_mc", 0.0, f"mc={mc:.5f};theory={th:.5f};err={abs(mc-th):.2e}")]
