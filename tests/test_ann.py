"""repro.ann subsystem: CodeStore ingestion, batched search (exact vs
LSH recall), multi-probe monotonicity, the serving front-end, and the
compat wrapper. Kernel-vs-oracle bit-exactness lives in
test_kernel_conformance.py."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.ann import AnnEngine, BandSpec, CodeStore
from repro.core import packing as PK
from repro.core.sketch import CodedRandomProjection, SketchConfig
from repro.kernels import ref
from repro.serve.ann_service import AnnService, AnnServiceConfig


def _codes(key, shape, bits):
    return jax.random.randint(key, shape, 0, 1 << bits)


@pytest.mark.parametrize("bits", [1, 2, 8])
def test_match_count_packed_rowwise(bits):
    k = 45
    key = jax.random.PRNGKey(bits)
    a = _codes(key, (12, k), bits)
    b = _codes(jax.random.fold_in(key, 1), (12, k), bits)
    got = PK.match_count_packed(PK.pack_codes(a, bits),
                                PK.pack_codes(b, bits), bits, k)
    want = jnp.sum((a == b).astype(jnp.int32), axis=-1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_topk_blocked_matches_lax_top_k():
    """The CPU-fast blocked top-k is bit-identical to stable lax.top_k
    under heavy ties and non-divisible block sizes."""
    m = jax.random.randint(jax.random.PRNGKey(0), (7, 5001), 0, 9,
                           dtype=jnp.int32)
    v1, i1 = ref.topk_blocked_ref(m, 6, block=128)
    v2, i2 = jax.lax.top_k(m, 6)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


# -- CodeStore ----------------------------------------------------------------

def test_code_store_roundtrip_add_merge():
    bits, k = 2, 50
    key = jax.random.PRNGKey(3)
    c1 = _codes(key, (20, k), bits)
    c2 = _codes(jax.random.fold_in(key, 1), (12, k), bits)
    s = CodeStore.from_codes(c1, k, bits)
    assert s.n == 20 and s.n_words == PK.packed_width(k, bits)
    np.testing.assert_array_equal(np.asarray(s.unpack()), np.asarray(c1))
    s2 = s.add(c2)
    assert s2.n == 32 and s.n == 20  # immutable: original untouched
    np.testing.assert_array_equal(np.asarray(s2.unpack()[20:]),
                                  np.asarray(c2))
    with pytest.raises(ValueError):
        s.merge(CodeStore.from_codes(c1, k, 4))


# -- engine: batched search ---------------------------------------------------

def _unit(x):
    return x / jnp.linalg.norm(x, axis=-1, keepdims=True)


@pytest.fixture(scope="module")
def small_world():
    d, n_clusters, per = 32, 60, 5
    key = jax.random.PRNGKey(7)
    centers = _unit(jax.random.normal(key, (n_clusters, d)))
    noise = _unit(jax.random.normal(jax.random.fold_in(key, 1),
                                    (n_clusters, per, d)))
    corpus = _unit(0.95 * centers[:, None, :] + np.sqrt(1 - 0.95 ** 2)
                   * noise).reshape(-1, d)
    queries = corpus[::per][:20]  # one member of each of 20 clusters
    crp = CodedRandomProjection(SketchConfig(k=128, scheme="2bit", w=0.75), d)
    engine = AnnEngine.build(crp, corpus,
                             BandSpec(n_tables=32, band_width=4))
    return engine, corpus, queries, per


def test_exact_search_is_packed_brute_force(small_world):
    engine, corpus, queries, per = small_world
    ids, rho = engine.search(queries, top_k=3, mode="exact", chunk_q=8)
    # query IS a corpus row: rank 0 must be itself at rho ~ 1
    np.testing.assert_array_equal(np.asarray(ids[:, 0]),
                                  np.arange(20) * per)
    assert float(jnp.min(rho[:, 0])) > 0.98
    # exact == oracle top-k over unpacked collision counts
    counts = ref.collision_counts_ref(engine.encode_queries(queries),
                                      engine.store.unpack())
    want_v, want_i = jax.lax.top_k(counts, 3)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(want_i))


def test_search_edge_batches(small_world):
    """Empty query batch and top_k > corpus both honor the -1-fill
    contract instead of crashing."""
    engine, corpus, queries, per = small_world
    ids, rho = engine.search(queries[:0], top_k=3)
    assert ids.shape == (0, 3) and rho.shape == (0, 3)
    big = engine.n + 5
    for mode in ("exact", "lsh"):
        ids, rho = engine.search(queries[:2], top_k=big, mode=mode)
        assert ids.shape == (2, big)
        assert (np.asarray(ids[:, engine.n:]) == -1).all()
        assert (np.asarray(rho[:, engine.n:]) == -1).all()


def test_lsh_recall_vs_exact(small_world):
    engine, corpus, queries, per = small_world
    ids_e, _ = engine.search(queries, top_k=5, mode="exact")
    ids_l, _ = engine.search(queries, top_k=5, mode="lsh", n_probes=1)
    recall = np.mean([len(set(np.asarray(a)) & set(np.asarray(b))) / 5
                      for a, b in zip(ids_l, ids_e)])
    assert recall >= 0.9, recall


def test_multiprobe_candidates_monotone(small_world):
    """Prefix-nested probes: candidate sets only grow with n_probes."""
    engine, corpus, queries, per = small_world
    q_codes = engine.encode_queries(queries)
    prev = None
    for p in (0, 1, 3, 5):
        coarse = np.asarray(engine.band_match_counts(q_codes, n_probes=p))
        if prev is not None:
            assert (coarse >= prev).all(), f"probe {p} lost candidates"
        prev = coarse


def test_incremental_add_finds_new_rows(small_world):
    engine, corpus, queries, per = small_world
    engine2 = engine.add(queries[:4])
    ids, _ = engine2.search(queries[:4], top_k=2, mode="exact")
    # the appended duplicates (ids n..n+3) tie with the originals; both
    # top-2 slots must come from {original, appended}
    for i in range(4):
        got = set(int(x) for x in np.asarray(ids[i]))
        assert got == {i * per, engine.n + i}, (i, got)


def test_search_sharded_matches_exact(small_world):
    from jax.sharding import Mesh
    engine, corpus, queries, per = small_world
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    ids_s, rho_s = engine.search_sharded(queries, mesh, top_k=4)
    ids_e, rho_e = engine.search(queries, top_k=4, mode="exact")
    np.testing.assert_array_equal(np.asarray(ids_s), np.asarray(ids_e))
    np.testing.assert_allclose(np.asarray(rho_s), np.asarray(rho_e),
                               rtol=1e-6)


# -- serving front-end --------------------------------------------------------

def test_ann_service_microbatching(small_world):
    engine, corpus, queries, per = small_world
    svc = AnnService(engine, AnnServiceConfig(top_k=3, mode="exact",
                                              buckets=(1, 4, 8)))
    tickets = [svc.submit(queries[i]) for i in range(6)]
    out = svc.flush()
    assert svc.pending() == 0 and set(out) == set(tickets)
    assert svc.stats["queries"] == 6 and svc.stats["padded_rows"] == 2
    ids_direct, _ = engine.search(queries[:6], top_k=3, mode="exact")
    for i, t in enumerate(tickets):
        ids_t, _ = svc.result(t)
        np.testing.assert_array_equal(np.asarray(ids_t),
                                      np.asarray(ids_direct[i]))
    with pytest.raises(ValueError):
        svc.submit(queries[:2])  # batch submit is one vector at a time


# -- compat wrapper -----------------------------------------------------------

def test_lsh_index_wrapper_compat(small_world):
    from repro.core.lsh import LSHIndex
    engine, corpus, queries, per = small_world
    idx = LSHIndex(engine.sketcher, n_tables=32, band_width=4).build(corpus)
    hits = idx.query(np.asarray(queries[0]), top=3)
    assert hits[0][0] == 0 and hits[0][1] > 0.98
    cand = idx.candidates(np.asarray(engine.encode_queries(
        queries[:1])[0]))
    assert 0 in cand
    with pytest.raises(ValueError):
        LSHIndex(engine.sketcher, n_tables=64, band_width=4)  # > k codes
