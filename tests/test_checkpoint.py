"""Checkpointer: atomic roundtrip, retention, resume, corruption safety."""
import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro.checkpoint import (available_steps, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree(x=1.0):
    return {"a": jnp.full((4, 3), x), "b": {"c": jnp.arange(5)},
            "step": jnp.asarray(7)}


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 10, _tree(2.5))
    out = restore_checkpoint(d, 10, _tree(0.0))
    np.testing.assert_allclose(np.asarray(out["a"]), 2.5)
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]), np.arange(5))


def test_latest_and_retention(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(d, s, _tree(float(s)), keep=3)
    assert latest_step(d) == 5
    assert available_steps(d) == [3, 4, 5]


def test_incomplete_checkpoint_ignored(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree())
    # simulate crash mid-write: directory without manifest
    os.makedirs(os.path.join(d, "step_9"))
    assert latest_step(d) == 1


def test_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree())
    bad = {"a": jnp.zeros((2, 2)), "b": {"c": jnp.arange(5)},
           "step": jnp.asarray(0)}
    with pytest.raises(ValueError):
        restore_checkpoint(d, 1, bad)


def test_missing_leaf_rejected(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, {"a": jnp.zeros(3)})
    with pytest.raises(KeyError):
        restore_checkpoint(d, 1, {"zz": jnp.zeros(3)})


def test_manifest_is_json(tmp_path):
    d = str(tmp_path)
    path = save_checkpoint(d, 2, _tree())
    with open(os.path.join(path, "manifest.json")) as f:
        m = json.load(f)
    assert m["step"] == 2 and len(m["leaves"]) == 3
