"""L2 linear classifiers trained directly on packed codes (paper §6).

The paper trains L2-regularized linear SVMs (LIBLINEAR) on the one-hot
expansion of the codes. This module keeps the objective family —

    min_W  0.5 ||W||^2 + C * sum_i loss(y_i, margin_i)

with squared-hinge (LIBLINEAR's L2R_L2LOSS primal) or logistic loss,
solved by Adam with cosine decay — but replaces the feature matrix with
the packed words themselves:

* **margins** are per-projection weight-table gathers
  (``kernels.packed_linear`` forward; the one-hot matrix never exists),
  row normalization folded in as the scalar ``fspec.scale`` pre-scale;
* **gradients** scatter per-example contributions straight back into
  the [k, 2^b] tables (fused backward kernel), multiplied by
  ``fspec.entry_mask()`` so phantom table columns (packing padding)
  never learn — with zero init they stay exactly zero, keeping packed
  L2/margins/gradients equal to the dense ``expand_codes`` path up to
  float rounding;
* **tombstones**: the masked kernel variants + a live-row mask on the
  loss terms let the same step run over a churned ``SegmentLogStore``
  segment, dead rows contributing exactly nothing.

``train_dense_linear`` is the dense twin (autodiff over an explicit
feature matrix) — the parity oracle, and the engine behind the
``core.svm`` compat wrappers. Streaming/minibatch/sharded training
lives in ``learn.trainer``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import packing as _packing
from repro.kernels import ops as _ops
from repro.learn.features import PackedFeatureSpec

__all__ = ["LearnConfig", "PackedLinearModel", "packed_margins",
           "packed_data_grads", "packed_loss_and_grads", "targets_pm",
           "adam_update", "adam_cosine_train", "full_batch_fit",
           "train_packed_linear", "train_dense_linear"]

_LOSSES = ("sq_hinge", "logistic")


@dataclass(frozen=True)
class LearnConfig:
    """Static training knobs (one jit cache entry per distinct config)."""
    loss: str = "sq_hinge"   # sq_hinge | logistic
    c: float = 1.0           # data-loss tradeoff (LIBLINEAR's C)
    steps: int = 400         # optimizer steps (full-batch or minibatch)
    lr: float = 0.1          # peak Adam lr (cosine-decayed to 0)
    batch: int = 0           # minibatch rows; 0 = full batch
    seed: int = 0            # batch-sampling seed (minibatch path)
    impl: str = "auto"       # kernel dispatch (see kernels.ops)

    def __post_init__(self):
        if self.loss not in _LOSSES:
            raise ValueError(f"loss must be one of {_LOSSES}, "
                             f"got {self.loss!r}")


def targets_pm(y, n_outputs: int):
    """Labels -> ±1 target matrix [C, n].

    n_outputs == 1 (binary): y [n] in {-1, +1} passes through as
    [1, n]. n_outputs > 1 (one-vs-rest): y [n] int class ids in
    [0, n_outputs) become +1 at the true class row, -1 elsewhere.
    """
    y = jnp.asarray(y)
    if n_outputs == 1:
        return y.astype(jnp.float32)[None, :]
    cls = jnp.arange(n_outputs)[:, None]
    return jnp.where(y[None, :] == cls, 1.0, -1.0).astype(jnp.float32)


def _loss_and_margin_grad(margins, y_pm, c: float, loss: str, live=None):
    """Data term of the objective and its margin gradient.

    margins/y_pm float32 [C, n]; ``live`` optional bool [n] — dead rows
    contribute zero loss and zero gradient. Returns (scalar loss_sum,
    g [C, n] = dloss/dmargin).
    """
    if loss == "sq_hinge":
        h = jnp.maximum(0.0, 1.0 - y_pm * margins)
        if live is not None:
            h = jnp.where(live[None, :], h, 0.0)
        return c * jnp.sum(h * h), (-2.0 * c) * (y_pm * h)
    z = -y_pm * margins
    ll = jax.nn.softplus(z)
    s = jax.nn.sigmoid(z)
    if live is not None:
        ll = jnp.where(live[None, :], ll, 0.0)
        s = jnp.where(live[None, :], s, 0.0)
    return c * jnp.sum(ll), -c * (y_pm * s)


def packed_margins(tables, bias, words, fspec: PackedFeatureSpec,
                   valid_words=None, impl: str = "auto"):
    """Model margins on packed rows: tables f32 [C, F*P], bias f32 [C],
    words uint32 [n, W] -> f32 [C, n] = scale * gather-sum + bias.

    With ``valid_words`` (packed row-validity bitmask) the masked
    forward kernel runs instead; dead rows come back as bias alone —
    meaningless, and excluded from every loss by the same mask.
    """
    if valid_words is None:
        raw = _ops.packed_linear_fwd(tables, words, fspec.bits, impl=impl)
    else:
        raw = _ops.packed_linear_fwd_masked(tables, words, valid_words,
                                            fspec.bits, impl=impl)
    return raw * fspec.scale + bias[:, None]


def packed_data_grads(params, words, y_pm, fspec: PackedFeatureSpec,
                      c: float = 1.0, loss: str = "sq_hinge",
                      valid_words=None, impl: str = "auto"):
    """Data term of the objective + its gradients on one packed block.

    params = (tables f32 [C, F*P], bias f32 [C]); y_pm ±1 targets
    [C, n] (``targets_pm``). Returns (data_loss, (dTables, dBias)): the
    per-example contributions scattered through the fused backward
    kernel, scaled by ``fspec.scale``, phantom columns masked — **no
    L2 term**, so multi-part callers (segment loops, sharded shards)
    can sum blocks and add the regularizer exactly once.
    """
    tables, bias = params
    m = packed_margins(tables, bias, words, fspec, valid_words, impl)
    live = (None if valid_words is None
            else _packing.unpack_bitmask(valid_words, words.shape[0]))
    data_loss, g = _loss_and_margin_grad(m, y_pm, c, loss, live)
    if valid_words is None:
        dt = _ops.packed_linear_bwd(g, words, fspec.bits, impl=impl)
    else:
        dt = _ops.packed_linear_bwd_masked(g, words, valid_words,
                                           fspec.bits, impl=impl)
    dt = dt * (fspec.scale * fspec.entry_mask())
    return data_loss, (dt, jnp.sum(g, axis=1))


def packed_loss_and_grads(params, words, y_pm, fspec: PackedFeatureSpec,
                          c: float = 1.0, loss: str = "sq_hinge",
                          valid_words=None, impl: str = "auto"):
    """One full objective + gradient evaluation on packed rows:
    ``packed_data_grads`` plus the L2 term (tables regularized, bias
    free — LIBLINEAR's convention). Returns (loss, (dTables, dBias))."""
    tables, bias = params
    data_loss, (dt, db) = packed_data_grads(params, words, y_pm, fspec,
                                            c, loss, valid_words, impl)
    return (0.5 * jnp.sum(tables * tables) + data_loss,
            (dt + tables, db))


def adam_update(params, m, v, g, i, steps: int, lr: float):
    """One Adam step with cosine decay — THE update rule (single source
    of truth for the full-batch scan, the minibatch per-step executable
    and the dense compat path; bit-identical to the original
    ``core.svm`` solver). ``i`` is the float32 step index (traced, so
    step counts never recompile). Returns (params, m, v).
    """
    lr_i = lr * 0.5 * (1.0 + jnp.cos(jnp.pi * i / steps))
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = jax.tree.map(lambda mm, gg: b1 * mm + (1 - b1) * gg, m, g)
    v = jax.tree.map(lambda vv, gg: b2 * vv + (1 - b2) * gg * gg, v, g)
    t = i + 1.0

    def upd(p, mm, vv):
        mh = mm / (1 - b1 ** t)
        vh = vv / (1 - b2 ** t)
        return p - lr_i * mh / (jnp.sqrt(vh) + eps)

    return jax.tree.map(upd, params, m, v), m, v


def adam_cosine_train(params, grad_fn, steps: int, lr: float):
    """Full-batch Adam with cosine decay (deterministic; the trainer
    shared by the dense and packed paths): ``adam_update`` scanned over
    ``steps``.

    params: pytree of float arrays; grad_fn(params) -> matching grads.
    """
    zeros = jax.tree.map(jnp.zeros_like, params)

    def step(carry, i):
        params, m, v = carry
        return adam_update(params, m, v, grad_fn(params), i, steps, lr), None

    (params, _, _), _ = jax.lax.scan(
        step, (params, zeros, zeros), jnp.arange(steps, dtype=jnp.float32))
    return params


@dataclass
class PackedLinearModel:
    """A trained linear classifier living in weight-table space.

    tables f32 [C, F*P] (flat ``PackedFeatureSpec`` layout, phantom
    columns zero), bias f32 [C]. C == 1 is a binary model over ±1
    labels; C > 1 is one-vs-rest over int class ids (predict = argmax).
    """
    fspec: PackedFeatureSpec
    tables: jax.Array
    bias: jax.Array
    loss: str = "sq_hinge"

    @classmethod
    def zeros(cls, fspec: PackedFeatureSpec, n_outputs: int = 1,
              loss: str = "sq_hinge") -> "PackedLinearModel":
        """Zero-initialized model (the training start point)."""
        return cls(fspec=fspec,
                   tables=jnp.zeros((n_outputs, fspec.table_width),
                                    jnp.float32),
                   bias=jnp.zeros((n_outputs,), jnp.float32), loss=loss)

    @property
    def n_outputs(self) -> int:
        """Margin rows: 1 for binary, n_classes for one-vs-rest."""
        return self.tables.shape[0]

    def margins(self, words, valid_words=None, impl: str = "auto"):
        """Packed rows [n, W] -> margins f32 [C, n] (fused forward)."""
        return packed_margins(self.tables, self.bias, words, self.fspec,
                              valid_words, impl)

    def decision(self, words, impl: str = "auto"):
        """Binary decision values f32 [n] (requires C == 1)."""
        if self.n_outputs != 1:
            raise ValueError("decision() is binary-only; use margins()")
        return self.margins(words, impl=impl)[0]

    def predict_from_margins(self, margins):
        """Margins [C, n] -> labels [n]: ±1 (binary, zero margin -> +1)
        or int class ids (one-vs-rest argmax)."""
        if self.n_outputs == 1:
            return jnp.where(margins[0] >= 0, 1, -1).astype(jnp.int32)
        return jnp.argmax(margins, axis=0).astype(jnp.int32)

    def predict(self, words, impl: str = "auto"):
        """Predicted labels [n] (``predict_from_margins`` of a fused
        forward pass)."""
        return self.predict_from_margins(self.margins(words, impl=impl))

    def accuracy(self, words, y, impl: str = "auto") -> float:
        """Mean prediction accuracy against labels ``y`` (±1 binary or
        int class ids, matching ``predict``)."""
        pred = np.asarray(self.predict(words, impl=impl))
        return float(np.mean(pred == np.asarray(y)))

    def dense_weights(self):
        """Weights in the dense ``expand_codes`` layout f32
        [C, k*n_codes] (phantom columns dropped) — parity/debug view."""
        return self.fspec.dense_from_tables(self.tables)


def full_batch_fit(words, y_pm, fspec: PackedFeatureSpec,
                   cfg: LearnConfig, valid_words=None, grad_fn=None):
    """Shared full-batch driver: zero init + the whole Adam scan under
    one donated jit (weight and optimizer buffers update in place).

    y_pm: ±1 targets [C, n] (``targets_pm``). ``grad_fn(params) ->
    grads`` overrides the default unsharded gradient — how the trainer
    plugs in the ``shard_map`` data-parallel path. Returns (tables,
    bias).
    """
    init = (jnp.zeros((y_pm.shape[0], fspec.table_width), jnp.float32),
            jnp.zeros((y_pm.shape[0],), jnp.float32))
    if grad_fn is None:
        def grad_fn(p):
            return packed_loss_and_grads(
                p, words, y_pm, fspec, c=cfg.c, loss=cfg.loss,
                valid_words=valid_words, impl=cfg.impl)[1]

    def run(params):
        return adam_cosine_train(params, grad_fn, cfg.steps, cfg.lr)

    return jax.jit(run, donate_argnums=(0,))(init)


def train_packed_linear(words, y, fspec: PackedFeatureSpec,
                        cfg: LearnConfig = LearnConfig(), *,
                        valid_words=None,
                        n_outputs: int = 1) -> PackedLinearModel:
    """Full-batch training directly on packed rows.

    words uint32 [n, W]; y ±1 [n] (binary) or int class ids
    (n_outputs > 1); ``valid_words`` optional packed validity bitmask —
    tombstoned rows contribute nothing (``full_batch_fit`` under the
    hood). Minibatch/streaming/sharded variants: ``learn.trainer``.
    """
    tables, bias = full_batch_fit(words, targets_pm(y, n_outputs), fspec,
                                  cfg, valid_words=valid_words)
    return PackedLinearModel(fspec=fspec, tables=tables, bias=bias,
                             loss=cfg.loss)


def _dense_objective(params, x, y, c: float, loss: str):
    w, b = params
    margin = y * (x @ w + b)
    if loss == "sq_hinge":
        hinge = jnp.maximum(0.0, 1.0 - margin)
        return 0.5 * jnp.sum(w * w) + c * jnp.sum(hinge * hinge)
    return 0.5 * jnp.sum(w * w) + c * jnp.sum(jax.nn.softplus(-margin))


def train_dense_linear(x, y, cfg: LearnConfig = LearnConfig(),
                       x_val: Optional[jnp.ndarray] = None,
                       y_val: Optional[jnp.ndarray] = None):
    """Dense-feature twin of ``train_packed_linear``: binary L2 linear
    classifier by autodiff over an explicit feature matrix x [n, d],
    y ±1 [n]. Returns (w [d], b). Identical optimizer trajectory to the
    packed path up to float rounding — the parity oracle, and the
    engine behind ``core.svm.train_linear_svm`` (x_val/y_val accepted
    for that signature, unused)."""
    del x_val, y_val
    n, d = x.shape
    params = (jnp.zeros((d,), jnp.float32), jnp.zeros((), jnp.float32))
    grad_obj = jax.grad(_dense_objective)

    def grad_fn(p):
        return grad_obj(p, x, y, cfg.c, cfg.loss)

    return adam_cosine_train(params, grad_fn, cfg.steps, cfg.lr)
