"""Matrix-free streaming encoder: raw vectors -> packed words, O(unit) memory.

The ingest front door of the system.  A ``StreamingEncoder`` wraps a
``core.sketch.CodedRandomProjection`` and produces the same packed
uint32 words as the oracle ``pack(encode(x))`` while never holding more
than one projection unit of R and never writing f32 projections or
int32 codes for the corpus to HBM:

* **R-resident regime** (``d * k <= r_cap_elems``): R is concatenated
  from its canonical units once, cached, and every batch runs the
  one-kernel fused path (``kernels.encode_fused``) — GEMM, coding and
  packing in a single pallas_call whose only HBM write-back is the
  packed words.
* **Matrix-free regime** (above the cap — the paper's URL scale, where
  R would be ~3.3 GB): batches stream over D unit by unit.  Each step
  regenerates one R unit from the counter-based seed *inside* the jit
  trace (it lives only as an XLA temporary) and accumulates into a
  donated [chunk, k] f32 slab — the donation makes the update in-place,
  so peak memory is O(chunk·k + unit·k) however large D grows.  The
  finalize is the fused code+pack epilogue kernel.
* **CSR regime**: sparse chunks bucket their nonzeros by unit
  (``encode.sparse``) and scatter ``vals · R[cols]`` into the same
  donated slab — O(nnz·k) work, untouched units skipped (their
  contribution is an exact float zero).

The streaming and CSR regimes accumulate in canonical unit order and so
match the ``core.sketch`` oracle (and each other, and ``encode_sharded``
at any device count) bit-for-bit at the same seed.  The fused kernel
accumulates its GEMM in ``block_d`` slabs instead; integer outputs are
bit-exact against its own oracle (``ref.encode_fused_ref``), and
cross-path agreement holds except on projections within one float ulp
of a coding bin edge — a vanishing fraction, pinned exactly at tier-1
scales/seeds (``tests/test_encode.py``) and bounded at 1e-4 of fields
in ``benchmarks/encode_bench.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.encode.sparse import CsrMatrix, unit_buckets
from repro.kernels import ops as _ops

__all__ = ["StreamingEncoder", "R_CAP_ELEMS"]

# Default R-residency cap: d * k f32 elements (64 MB) — far below one
# device's HBM, far above every query-side working set.  The paper-scale
# URL corpus (D = 3.2M, k = 256 -> 8.2e8 elements) lands two orders of
# magnitude above it and always streams.
R_CAP_ELEMS = 1 << 24


class StreamingEncoder:
    """Raw dense [n, D] / ``CsrMatrix`` input -> packed uint32 [n, W]."""

    def __init__(self, sketcher, *, r_cap_elems: int = R_CAP_ELEMS):
        self.sketcher = sketcher
        self.r_cap_elems = int(r_cap_elems)
        self._rmat = None

    # -- R residency ---------------------------------------------------------
    @property
    def r_resident(self) -> bool:
        """Whether R may be materialized (``d * k`` under the cap)."""
        s = self.sketcher
        return s.d * s.cfg.k <= self.r_cap_elems

    @property
    def r_slab_elems(self) -> int:
        """Peak R elements held by the matrix-free path: one unit."""
        s = self.sketcher
        return s.cfg.r_unit * s.cfg.k

    def r_matrix(self):
        """Materialized projection [D, k], cached; concatenated from the
        canonical units.  Raises above ``r_cap_elems`` — at that point
        the whole point is to never build this array (stream instead).
        """
        s = self.sketcher
        if not self.r_resident:
            raise ValueError(
                f"R is {s.d} x {s.cfg.k} = {s.d * s.cfg.k} elements, over "
                f"the residency cap {self.r_cap_elems}; use the streaming "
                f"encode path instead of materializing")
        if self._rmat is None:
            self._rmat = jnp.concatenate(
                [s._block_r(u, s.unit_width(u)) for u in range(s.n_units)])
        return self._rmat

    # -- streaming steps (one executable per shape, donated accumulator) -----
    @functools.partial(jax.jit, static_argnums=(0, 4), donate_argnums=1)
    def _dense_step(self, acc, x_blk, u, width: int):
        """acc [n, k] += x_blk [n, width] @ R_unit(u); u is traced data
        (one executable covers every full-width unit), acc donated."""
        r = self.sketcher._block_r(u, width)
        return acc + x_blk.astype(acc.dtype) @ r

    @functools.partial(jax.jit, static_argnums=(0, 6), donate_argnums=1)
    def _sparse_step(self, acc, rows, lcols, vals, u, width: int):
        """acc [n, k] += segment-sum of vals · R_unit(u)[lcols] over
        ``rows`` — the CSR gather projection; padding entries carry
        val 0 and scatter an exact zero."""
        r = self.sketcher._block_r(u, width)
        contrib = vals[:, None] * jnp.take(r, lcols, axis=0)
        return acc + jax.ops.segment_sum(contrib, rows,
                                         num_segments=acc.shape[0])

    def project(self, x):
        """Streaming projection x -> z [n, k] f32 without materializing
        R: dense rows stream unit-by-unit through the donated slab, CSR
        rows gather/scatter only their nonzeros."""
        s = self.sketcher
        ru = s.cfg.r_unit
        if isinstance(x, CsrMatrix):
            if x.d != s.d:
                raise ValueError(f"csr d={x.d} != sketcher d={s.d}")
            acc = jnp.zeros((x.n, s.cfg.k), jnp.dtype(s.cfg.dtype))
            if x.nnz == 0:
                return acc
            units, rows, lcols, vals = unit_buckets(x, ru)
            for i, u in enumerate(units):
                acc = self._sparse_step(
                    acc, jnp.asarray(rows[i]), jnp.asarray(lcols[i]),
                    jnp.asarray(vals[i]), jnp.int32(u), s.unit_width(u))
            return acc
        if x.ndim != 2 or x.shape[1] != s.d:
            raise ValueError(f"x {x.shape} != [n, {s.d}]")
        # host-resident inputs (np.ndarray, memmaps) are sliced on the
        # host and shipped one unit slab at a time — device memory stays
        # O(chunk·unit + chunk·k) even for dense corpora at huge D;
        # device-resident inputs slice in place
        acc = jnp.zeros((x.shape[0], s.cfg.k), jnp.dtype(s.cfg.dtype))
        for u in range(s.n_units):
            lo = u * ru
            w = s.unit_width(u)
            acc = self._dense_step(acc, jnp.asarray(x[:, lo:lo + w]),
                                   jnp.int32(u), w)
        return acc

    # -- encoding ------------------------------------------------------------
    def encode_packed(self, x, impl: str = "auto"):
        """x dense [n, D] or ``CsrMatrix`` -> packed uint32 [n, W].

        R-resident dense input takes the one-kernel fused path; all
        other regimes stream the projection in unit order (bit-identical
        to ``sketcher.sketch_oracle``) and run the fused code+pack
        epilogue.  The fused path's full-R accumulation can differ from
        the oracle on values one ulp from a bin edge (see the module
        docstring)."""
        s = self.sketcher
        if not isinstance(x, CsrMatrix) and self.r_resident:
            return _ops.encode_fused(jnp.asarray(x), self.r_matrix(),
                                     s.spec, s._offsets, impl=impl)
        return _ops.code_pack(self.project(x), s.spec, s._offsets,
                              impl=impl)

    def encode_codes(self, x, impl: str = "auto"):
        """x dense [n, D] or ``CsrMatrix`` -> int32 codes [n, k] (the
        query-side contract: engines band-hash and LUT-index unpacked
        codes).  Fused project+code kernel when R is resident, streaming
        projection + scheme encode otherwise."""
        s = self.sketcher
        if not isinstance(x, CsrMatrix) and self.r_resident:
            return _ops.coded_project(jnp.asarray(x), self.r_matrix(),
                                      s.spec, s._offsets, impl=impl)
        return s.encode_projected(self.project(x))

    @property
    def n_words(self) -> int:
        """uint32 words per packed row: ceil(k / (32/bits))."""
        from repro.core.packing import packed_width
        return packed_width(self.sketcher.cfg.k, self.sketcher.spec.bits)
