"""repro.obs flight layer: ring-buffer wraparound, tail-sampling
determinism under a fixed seed, incident round-trip through
repro.checkpoint, exemplar <-> trace-id consistency in the OpenMetrics
export, the perf-history change-point gate (scripts/check_perf.py), and
the end-to-end drift-during-churn incident path through AnnService."""
import json
import os
import sys

import numpy as np
import pytest
import jax.numpy as jnp

from repro.ann import BandSpec
from repro.core.sketch import CodedRandomProjection, SketchConfig
from repro.index import MutableAnnEngine
from repro.obs import (EVENT_FIELDS, FlightRecorder, IncidentManager,
                       MetricsRegistry, TailSampler, Tracer,
                       default_flight_recorder, deep_tracing_active,
                       set_flight_recorder, span, to_prometheus,
                       tracing_active)

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)                      # benchmarks/
sys.path.insert(0, os.path.join(_ROOT, "scripts"))   # check_perf

D, K = 16, 16
BAND = BandSpec(n_tables=4, band_width=4)


def _crp():
    return CodedRandomProjection(SketchConfig(k=K, scheme="2bit", w=0.75),
                                 D)


# -- flight-recorder ring -----------------------------------------------------

def test_ring_capacity_rounds_to_pow2_and_append_order():
    fr = FlightRecorder(capacity=100)        # rounds up to 128
    assert fr.capacity == 128
    for i in range(5):
        seq = fr.record(f"op{i}", float(i), float(i) + 0.5, batch=i)
        assert seq == i
    assert len(fr) == 5 and not fr.wrapped and fr.dropped == 0
    evs = fr.snapshot()
    assert [e["op"] for e in evs] == [f"op{i}" for i in range(5)]
    assert [e["seq"] for e in evs] == list(range(5))
    assert set(EVENT_FIELDS) == set(evs[0])


def test_ring_wraparound_keeps_newest_capacity_events():
    fr = FlightRecorder(capacity=8)
    for i in range(20):
        fr.record("op", float(i), float(i), batch=i)
    assert fr.capacity == 8 and len(fr) == 8
    assert fr.wrapped and fr.dropped == 12
    evs = fr.snapshot()
    # exactly the newest 8, oldest first, seq contiguous
    assert [e["seq"] for e in evs] == list(range(12, 20))
    assert [e["batch"] for e in evs] == list(range(12, 20))
    assert [e["batch"] for e in fr.tail(3)] == [17, 18, 19]


def test_ring_disabled_and_reset():
    fr = FlightRecorder(capacity=8, enabled=False)
    assert fr.record("op", 0.0, 1.0) == -1
    assert len(fr) == 0
    fr.enabled = True
    fr.record("op", 0.0, 1.0)
    fr.record_kernel("pack_codes", traced=True)
    evs = fr.snapshot()
    assert len(evs) == 2
    assert evs[1]["op"] == "kernel.pack_codes"
    assert evs[1]["outcome"] == "traced"
    fr.reset()
    assert len(fr) == 0 and fr.seq == 0 and fr.dropped == 0


def test_ring_events_filter_and_global_swap():
    fr = FlightRecorder(capacity=16)
    fr.record("a", 0.0, 1.0)
    fr.record("b", 0.0, 1.0)
    fr.record("a", 0.0, 1.0)
    assert len(fr.events("a")) == 2 and len(fr.events("b")) == 1
    prev = set_flight_recorder(fr)
    try:
        assert default_flight_recorder() is fr
    finally:
        set_flight_recorder(prev)
    assert default_flight_recorder() is prev


# -- tail sampler -------------------------------------------------------------

def _run_workload(sampler):
    """Deterministic mixed workload: mostly-fast requests with a slow
    tail; returns the retained (trace_id, reason) pairs."""
    keys = [0.001, 0.002, 0.001, 0.050, 0.002, 0.001] * 8
    for k in keys:
        with sampler.request("search") as rq:
            rq.set_key(k)
    return [(t["trace_id"], t["reason"])
            for t in sampler.retained_traces()]


def test_tail_sampling_deterministic_under_fixed_seed():
    a = _run_workload(TailSampler(seed=3, sample_rate=0.05,
                                  registry=MetricsRegistry()))
    b = _run_workload(TailSampler(seed=3, sample_rate=0.05,
                                  registry=MetricsRegistry()))
    assert a == b and len(a) > 0            # replay == identical decisions


def test_tail_sampler_retains_slow_tail_only():
    s = TailSampler(quantile=0.9, min_count=10, registry=MetricsRegistry())
    for i in range(40):
        with s.request("search") as rq:
            rq.set_key(1.0 if i == 30 else 0.001)
    retained = s.retained_traces()
    assert len(retained) >= 1
    assert all(t["reason"] == "slow" for t in retained)
    assert any(t["key"] == 1.0 for t in retained)
    # warmup: nothing retained before min_count past observations
    assert all(t["trace_id"] > 10 for t in retained)


def test_tail_sampler_error_and_flag_retention():
    s = TailSampler(registry=MetricsRegistry())
    with pytest.raises(RuntimeError):
        with s.request("search") as rq:
            raise RuntimeError("boom")
    assert rq.retained and rq.reason == "error"
    with s.request("search") as rq2:
        rq2.flag("collision.chi2")
    assert rq2.retained and rq2.reason == "flagged:collision.chi2"
    reasons = {t["reason"] for t in s.retained_traces()}
    assert reasons == {"error", "flagged:collision.chi2"}
    err = next(t for t in s.retained_traces() if t["reason"] == "error")
    assert err["attrs"]["error"] == "RuntimeError"


def test_tail_sampler_lru_cap_and_disabled_mode():
    s = TailSampler(max_retained=4, registry=MetricsRegistry())
    for i in range(10):
        with s.request("op") as rq:
            rq.flag("x")
    assert len(s.retained_traces()) == 4
    # newest survive
    assert [t["trace_id"] for t in s.retained_traces()] == [7, 8, 9, 10]
    off = TailSampler(enabled=False, registry=MetricsRegistry())
    with off.request("op") as rq:
        rq.set_key(100.0)
        rq.flag("y")
    assert not rq.retained and off.retained_traces() == []


def test_request_trace_is_shallow_and_stamps_trace_id():
    s = TailSampler(registry=MetricsRegistry())
    with s.request("search") as rq:
        assert tracing_active() and not deep_tracing_active()
        with span("inner") as sp:
            out = sp.sync(jnp.ones(4))     # passthrough: no block
        rq.flag("keep")
    np.testing.assert_array_equal(np.asarray(out), np.ones(4))
    (t,) = s.retained_traces()
    (ev,) = t["events"]
    assert ev["name"] == "inner"
    assert ev["args"]["trace_id"] == rq.trace_id
    assert ev["args"]["sync"] == "async"   # honest label, never blocked


def test_request_trace_forwards_to_outer_deep_tracer():
    s = TailSampler(registry=MetricsRegistry())
    with Tracer() as outer:
        with s.request("search") as rq:
            assert deep_tracing_active()   # inherits profiling depth
            with span("inner") as sp:
                sp.sync(jnp.ones(4))
    names = [e["name"] for e in outer.events]
    assert "inner" in names                # forwarded, nothing lost
    inner = next(e for e in outer.events if e["name"] == "inner")
    assert inner["args"]["trace_id"] == rq.trace_id
    assert inner["args"]["sync"] == "device"


# -- incident bundles through repro.checkpoint --------------------------------

def test_incident_roundtrip_through_checkpoint(tmp_path):
    fr = FlightRecorder(capacity=64)
    for i in range(10):
        fr.record("serve.search", float(i), float(i) + 0.5, batch=4,
                  generation=2)
    s = TailSampler(registry=MetricsRegistry())
    with s.request("search") as rq:
        rq.flag("drift")
    reg = MetricsRegistry()
    reg.counter("serve.queries").inc(7)
    mgr = IncidentManager(str(tmp_path / "inc"), flight=fr, sampler=s,
                          registry=reg, generation_fn=lambda: 2)
    path = mgr.capture("drift", "collision.chi2 drifted",
                       {"value": np.float32(1.5)})
    assert path and mgr.steps() == [1]
    b = mgr.load()
    assert b["kind"] == "drift" and b["generation"] == 2
    assert b["context"]["value"] == 1.5    # numpy scalar survives as float
    assert len(b["events"]) == 10
    assert b["events"][-1]["op"] == "serve.search"
    assert b["registry"]["counters"]["serve.queries"] == 7
    (t,) = b["traces"]
    assert t["trace_id"] == rq.trace_id
    assert t["reason"] == "flagged:drift"


def test_incident_keep_retention_and_capture_never_raises(tmp_path):
    mgr = IncidentManager(str(tmp_path / "inc"), flight=FlightRecorder(8),
                          registry=MetricsRegistry(), keep=2)
    for i in range(4):
        assert mgr.capture("error", f"boom {i}")
    assert mgr.steps() == [3, 4]           # keep=2 newest
    assert mgr.load(4)["reason"] == "boom 3"
    # a broken destination degrades to a counter, never raises
    reg = MetricsRegistry()
    bad = IncidentManager(str(tmp_path / "file"), registry=reg)
    open(tmp_path / "file", "w").write("not a directory")
    assert bad.capture("error", "x") == ""
    assert reg.counters["obs.incident.capture_errors"].value == 1


def test_incident_on_drift_callback_contract(tmp_path):
    from repro.obs.drift import Cusum
    mgr = IncidentManager(str(tmp_path / "inc"), flight=FlightRecorder(8),
                          registry=MetricsRegistry())
    det = Cusum(slack=0.1, threshold=0.5, warmup=2)
    for v in (1.0, 1.0, 4.0, 4.0, 4.0):
        det.update(v)
    assert det.alarms >= 1 and det.side == "up"
    mgr.on_drift("collision.chi2", 4.0, det)
    b = mgr.load()
    assert b["kind"] == "drift"
    assert b["context"]["series"] == "collision.chi2"
    assert b["context"]["side"] == "up"


# -- exemplars ----------------------------------------------------------------

def test_exemplar_trace_id_consistency_in_export():
    reg = MetricsRegistry()
    h = reg.histogram("serve.flush_s")
    s = TailSampler(registry=reg)
    with s.request("search") as rq:
        rq.flag("slow-tail")
    h.observe(0.2)
    h.exemplar(0.2, rq.trace_id)
    i = h.spec.bucket_index(0.2)
    v, tid = h.exemplars[i]
    assert v == 0.2 and tid == rq.trace_id
    # the exemplar's trace id points at a retained trace
    assert tid in {t["trace_id"] for t in s.retained_traces()}
    text = to_prometheus(reg)
    line = next(ln for ln in text.splitlines()
                if f'trace_id="{rq.trace_id}"' in ln)
    assert line.startswith("serve_flush_s_bucket")
    assert "# {" in line and "0.2" in line


# -- perf-history gate --------------------------------------------------------

def test_history_append_load_series(tmp_path):
    from benchmarks import history
    p = str(tmp_path / "BENCH_history.jsonl")
    rows = [("m_a", 10.0, "d"), ("m_b", 20.0, "d")]
    history.append_history("benchmarks.x_bench", rows, quick=True, path=p)
    history.append_history("benchmarks.x_bench", [("m_a", 11.0, "d")],
                           quick=True, path=p)
    history.append_history("benchmarks.x_bench", [("m_a", 99.0, "d")],
                           quick=False, path=p)
    recs = history.load_history(p)
    assert len(recs) == 3 and recs[0]["module"] == "x_bench"
    assert history.series(recs, "m_a", quick=True) == [10.0, 11.0]
    assert history.series(recs, "m_a", quick=False) == [99.0]  # never mix
    assert history.metric_names(recs) == ["m_a", "m_b"]


def test_check_perf_flags_2x_regression_no_false_alarms(tmp_path):
    import check_perf
    rng = np.random.default_rng(0)
    noise = rng.normal(0.0, 0.05, size=24)
    stationary = [100.0 * float(np.exp(e)) for e in noise]
    v = check_perf.analyze(stationary)
    assert not v["regressed"] and not v["alarms"]      # zero false alarms
    jumped = [x * (2.0 if i >= 16 else 1.0)
              for i, x in enumerate(stationary)]
    v = check_perf.analyze(jumped)
    assert v["regressed"] and v["gating"]
    assert all(s == "up" for _, s in v["alarms"])
    # an improvement is recognized, never fatal
    shrunk = [x * (0.5 if i >= 16 else 1.0)
              for i, x in enumerate(stationary)]
    v = check_perf.analyze(shrunk)
    assert v["improved"] and not v["regressed"]


def test_check_perf_gate_exit_codes(tmp_path):
    import check_perf
    from benchmarks import history
    p = str(tmp_path / "BENCH_history.jsonl")
    # synthetic trajectory: stationary metric + one that regresses 2x
    rng = np.random.default_rng(1)
    for i in range(8):
        jitter = float(np.exp(rng.normal(0.0, 0.03)))
        rows = [("flat_us", 50.0 * jitter, ""),
                ("slow_us", 10.0 * jitter * (2.0 if i >= 5 else 1.0), "")]
        history.append_history("benchmarks.y_bench", rows, quick=True,
                               path=p)
    assert check_perf.check(p, min_points=5, quick=True,
                            out=open(os.devnull, "w")) == 1
    # short series stay report-only (non-blocking)
    assert check_perf.check(p, min_points=99, quick=True,
                            out=open(os.devnull, "w")) == 0
    # missing history: clean no-op under --quick, error otherwise
    missing = str(tmp_path / "nope.jsonl")
    assert check_perf.check(missing, quick=True,
                            out=open(os.devnull, "w")) == 0
    assert check_perf.check(missing, quick=False,
                            out=open(os.devnull, "w")) == 1


# -- end to end through the service -------------------------------------------

def test_service_flush_events_and_tail_retention():
    rng = np.random.default_rng(21)
    eng = MutableAnnEngine(_crp(), band_spec=BAND, tail_rows=64)
    fr = FlightRecorder(capacity=256)
    from repro.serve import AnnService, AnnServiceConfig
    svc = AnnService(eng, AnnServiceConfig(top_k=3, buckets=(1, 4),
                                           cache_size=0),
                     flight=fr,
                     sampler=TailSampler(min_count=2, quantile=0.5,
                                         registry=MetricsRegistry()))
    svc.add(jnp.asarray(rng.normal(size=(20, D)), jnp.float32))
    for _ in range(6):
        svc.submit(jnp.asarray(rng.normal(size=(D,)), jnp.float32))
        svc.flush()
    ops = [e["op"] for e in fr.snapshot()]
    assert "serve.add" in ops
    assert ops.count("serve.search") >= 6
    ev = fr.events("serve.search")[-1]
    assert ev["synced"] is True            # post-host-transfer timestamp
    assert ev["batch"] >= 1 and ev["generation"] >= 0
    # retained flush traces pin exemplars with their trace ids
    retained = svc.sampler.retained_traces()
    if retained:
        tids = {t["trace_id"] for t in retained}
        for v, tid in svc._h_flush.exemplars.values():
            assert tid in tids


def test_forced_drift_during_churn_dumps_restorable_incident(tmp_path):
    """Acceptance path: a drift trigger mid-churn produces an incident
    bundle that restores to a readable registry + trace set."""
    rng = np.random.default_rng(23)
    eng = MutableAnnEngine(_crp(), band_spec=BAND, tail_rows=64)
    from repro.serve import AnnService, AnnServiceConfig
    svc = AnnService(eng, AnnServiceConfig(top_k=3, buckets=(1, 4),
                                           cache_size=0),
                     flight=FlightRecorder(capacity=256),
                     sampler=TailSampler(registry=MetricsRegistry()),
                     incidents=str(tmp_path / "inc"))
    ids = svc.add(jnp.asarray(rng.normal(size=(40, D)), jnp.float32))
    svc.submit(jnp.asarray(rng.normal(size=(D,)), jnp.float32))
    svc.flush()
    svc.delete(ids[:10])                   # churn
    # force a drift alarm mid-churn (the DriftMonitor callback contract)
    from repro.obs.drift import Cusum
    det = Cusum(slack=0.1, threshold=0.5, warmup=2)
    for v in (1.0, 1.0, 5.0, 5.0):
        det.update(v)
    svc._on_drift("collision.chi2", 5.0, det)
    # the alarm flags the NEXT request for trace retention
    svc.submit(jnp.asarray(rng.normal(size=(D,)), jnp.float32))
    svc.flush()
    flagged = [t for t in svc.sampler.retained_traces()
               if t["reason"].startswith("flagged:")]
    assert flagged and "collision.chi2" in flagged[0]["reason"]
    # the bundle round-trips: readable registry, events, trace set
    assert svc.incidents.steps() == [1]
    b = svc.incidents.load()
    assert b["kind"] == "drift"
    assert b["context"]["series"] == "collision.chi2"
    assert b["generation"] == eng.generation
    assert any(e["op"] == "serve.search" for e in b["events"])
    assert isinstance(b["registry"]["counters"], dict)
    json.dumps(b)                          # self-contained, serializable


def test_service_error_dumps_incident_and_retains_trace(tmp_path):
    rng = np.random.default_rng(29)
    eng = MutableAnnEngine(_crp(), band_spec=BAND, tail_rows=64)
    from repro.serve import AnnService, AnnServiceConfig
    svc = AnnService(eng, AnnServiceConfig(top_k=3, buckets=(1,),
                                           cache_size=0),
                     flight=FlightRecorder(capacity=64),
                     sampler=TailSampler(registry=MetricsRegistry()),
                     incidents=str(tmp_path / "inc"))
    svc.add(jnp.asarray(rng.normal(size=(8, D)), jnp.float32))
    svc.submit(jnp.asarray(rng.normal(size=(D,)), jnp.float32))
    svc.engine.search_codes = None         # break the engine mid-flight
    with pytest.raises(TypeError):
        svc.flush()
    (t,) = [t for t in svc.sampler.retained_traces()
            if t["reason"] == "error"]
    assert t["attrs"]["error"] == "TypeError"
    b = svc.incidents.load()
    assert b["kind"] == "error" and "flush" in b["reason"]
