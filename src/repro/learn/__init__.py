"""Linear-classifier training directly on packed codes (paper §6).

The paper's second headline application — linear SVMs on one-hot
expanded coded projections — without ever materializing the one-hot
matrix: the feature dot product is a per-projection weight-table
gather, so training runs on the same packed words the search engines
serve from.

features — ``PackedFeatureSpec``: the flat [k, 2^b] weight-table
          layout shared with ``rank.RankTables``, phantom-column
          masking, row normalization as a scalar table pre-scale,
          dense<->packed weight converters; ``expand_codes`` (the
          dense oracle path, ex-``core.svm``)
linear   — ``PackedLinearModel`` + ``train_packed_linear``: squared
          hinge / logistic objectives, margins and gradients through
          the fused ``kernels.packed_linear`` forward/backward, Adam
          with cosine decay under one donated jit
trainer  — streaming drivers: minibatch with donated weight buffers,
          batches straight off ``ann.CodeStore`` (``fit_store``) and a
          churning ``index.SegmentLogStore`` (``fit_log`` — masked
          per-segment grads, labels keyed by external id), shard_map
          data-parallel gradient all-reduce (``packed_grads_sharded``)

(dense compat wrappers: ``repro.core.svm``; serving endpoint:
``repro.serve.ann_service`` ``classify``)
"""
from repro.learn.features import (PackedFeatureSpec, expand_codes,  # noqa: F401
                                  feature_spec_for)
from repro.learn.linear import (LearnConfig, PackedLinearModel,  # noqa: F401
                                train_dense_linear, train_packed_linear)
from repro.learn.trainer import (fit_log, fit_store, fit_words,  # noqa: F401
                                 packed_grads_sharded)
