"""Coded random-projection sketches — the paper's end-to-end pipeline.

    X [n, D]  --(Gaussian projection R in blocks)-->  [n, k]
              --(b-bit coding scheme)-->              codes [n, k]
              --(bit packing)-->                      uint32 [n, k*b/32]

The projection matrix is never materialized for large D: it is generated
in fixed-width **units** from a counter-based PRNG key (``fold_in``), so
sketching a D = 3.2M-dim corpus (the paper's URL dataset) streams R in
O(unit) memory and the sketch is reproducible from the seed alone — on a
cluster every host regenerates the same R without any broadcast.

Generation is canonical: R is a pure function of ``(seed, r_unit, k,
dtype)``.  Streaming knobs (``block_d``, chunk sizes, device counts)
group *whole units* per step and therefore never change a single bit of
the sketch — the reproducibility contract ``tests/test_encode.py`` pins.

This module is the **oracle path**: plain jnp, one readable step per
stage.  The production ingest path (fused project→code→pack kernels,
CSR-sparse inputs, chunked streaming into stores) lives in
``repro.encode`` and must match these semantics bit-for-bit.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import schemes as _schemes
from repro.core import packing as _packing
from repro.core.estimators import CollisionEstimator
from repro.core.schemes import CodeSpec

__all__ = ["SketchConfig", "CodedRandomProjection", "OFFSET_KEY_TAG"]

# Key domain split: projection unit u draws from fold_in(key, u) with
# u in [0, n_units); the offset vector draws from fold_in(key, 2^32-1).
# n_units is capped strictly below the tag (a D that large is ~17.6 TB
# of f32 per row anyway), so a unit key can NEVER collide with the
# offset key.  The old scheme used fold_in(key, 0xFFFF), which collided
# with projection unit 65535 once ceil(D / unit) > 65535 — at unit 4096
# that is D > 268M, squarely in sparse-corpus territory.
OFFSET_KEY_TAG = 2 ** 32 - 1


@dataclass(frozen=True)
class SketchConfig:
    k: int = 256                    # number of projections
    scheme: str = "2bit"            # paper-recommended default (§8)
    w: float = 0.75                 # paper-recommended first bin width (§8)
    cutoff: float = 6.0
    seed: int = 0
    block_d: int = 4096             # retained for config compat: superseded
                                    # by r_unit (generation) and the encode
                                    # pipeline's chunking; never read, and
                                    # never changes the sketch bits (pinned
                                    # by tests/test_encode.py)
    dtype: str = "float32"
    r_unit: int = 4096              # canonical R generation granularity:
                                    # part of the sketch identity

    @property
    def code_spec(self) -> CodeSpec:
        return CodeSpec(scheme=self.scheme, w=self.w, cutoff=self.cutoff)


class CodedRandomProjection:
    """Sketching engine for a fixed input dimensionality D."""

    def __init__(self, cfg: SketchConfig, d: int):
        self.cfg = cfg
        self.d = int(d)
        if cfg.r_unit <= 0:
            raise ValueError(f"r_unit must be positive, got {cfg.r_unit}")
        if self.n_units >= OFFSET_KEY_TAG:
            raise ValueError(f"D={d} needs {self.n_units} projection units; "
                             f"key domain holds < {OFFSET_KEY_TAG}")
        self.spec = cfg.code_spec
        self._key = jax.random.PRNGKey(cfg.seed)
        self._offsets = None
        if cfg.scheme == "offset":
            self._offsets = _schemes.sample_offsets(
                self.offset_key(), cfg.k, cfg.w, dtype=jnp.dtype(cfg.dtype))
        self._estimator = CollisionEstimator(cfg.scheme, cfg.w)

    # -- projection ---------------------------------------------------------
    @property
    def n_units(self) -> int:
        """Number of canonical R generation units: ceil(D / r_unit)."""
        return (self.d + self.cfg.r_unit - 1) // self.cfg.r_unit

    def unit_width(self, u: int) -> int:
        """Rows of unit ``u``: r_unit except a ragged final unit."""
        return min(self.cfg.r_unit, self.d - u * self.cfg.r_unit)

    def offset_key(self):
        """PRNG key for the offset vector q — a tag fold disjoint from
        every projection-unit key (see ``OFFSET_KEY_TAG``)."""
        return jax.random.fold_in(self._key, OFFSET_KEY_TAG)

    def _block_r(self, u, width: int):
        """Regenerable Gaussian unit R[u*r_unit : u*r_unit+width, :k].

        ``u`` may be a traced int32 (``fold_in`` traces), ``width`` must
        be static.  This is the ONLY generator of projection entries —
        the fused/streamed paths in ``repro.encode`` call exactly this.
        """
        key = jax.random.fold_in(self._key, u)
        return jax.random.normal(key, (width, self.cfg.k),
                                 dtype=jnp.dtype(self.cfg.dtype))

    @functools.partial(jax.jit, static_argnums=0)
    def project(self, x):
        """x [n, D] -> [n, k], streaming R unit-by-unit over D.

        Accumulation is unit-ordered: acc += x_u @ R_u for u = 0.. — the
        float summation order every other encode path reproduces. Full
        units run under ``lax.scan`` (compile cost is O(1) in D; at the
        paper's D = 3.2M an unrolled loop would trace ~800 dots), the
        ragged tail unit as a final step.
        """
        n = x.shape[0]
        ru = self.cfg.r_unit
        n_full = self.d // ru
        acc = jnp.zeros((n, self.cfg.k), dtype=jnp.dtype(self.cfg.dtype))
        if n_full:
            xf = jnp.moveaxis(
                x[:, :n_full * ru].reshape(n, n_full, ru), 1, 0)

            def body(a, inp):
                u, xb = inp
                return a + xb.astype(a.dtype) @ self._block_r(u, ru), None

            acc, _ = jax.lax.scan(
                body, acc, (jnp.arange(n_full, dtype=jnp.int32), xf))
        if self.d % ru:
            acc = acc + x[:, n_full * ru:].astype(acc.dtype) @ \
                self._block_r(jnp.int32(n_full), self.d - n_full * ru)
        return acc

    # -- coding -------------------------------------------------------------
    def encode(self, x):
        """x [n, D] -> int32 codes [n, k]."""
        return _schemes.encode(self.project(x), self.spec, self._offsets)

    def encode_projected(self, z):
        """Pre-projected z [n, k] -> codes."""
        return _schemes.encode(z, self.spec, self._offsets)

    def pack(self, codes):
        return _packing.pack_codes(codes, self.spec.bits)

    def stream_encoder(self):
        """The per-sketcher ``repro.encode.StreamingEncoder``, built
        lazily and cached — shared by ``sketch`` and every
        ``ann.QueryCoder`` over this sketcher, so R and the streaming
        executables are cached exactly once per sketcher."""
        from repro.encode.encoder import StreamingEncoder  # lazy: no cycle
        if getattr(self, "_stream_encoder", None) is None:
            self._stream_encoder = StreamingEncoder(self)
        return self._stream_encoder

    def sketch(self, x, impl: str = "auto"):
        """x [n, D] -> packed uint32 sketch [n, k*bits/32].

        Runs the production ingest path (``repro.encode``): fused
        project→code→pack below the R-residency cap, matrix-free unit
        streaming above it. Agrees with ``pack(encode(x))`` up to
        accumulation-order ulp flips at bin edges (see
        ``StreamingEncoder.encode_packed``).
        """
        return self.stream_encoder().encode_packed(x, impl=impl)

    def sketch_oracle(self, x):
        """Reference sketch: unfused project → encode → pack in jnp.

        The semantics oracle for ``sketch`` and for everything in
        ``repro.encode`` (each intermediate is materialized — fine at
        test scale, the thing the fused path exists to avoid)."""
        return self.pack(self.encode(x))

    # -- estimation ---------------------------------------------------------
    def estimate_rho(self, codes_a, codes_b):
        """rho_hat from code arrays [..., k] (table inversion, §3)."""
        return self._estimator.estimate(codes_a, codes_b)

    def estimate_rho_packed(self, words_a, words_b):
        ca = _packing.unpack_codes(words_a, self.spec.bits, self.cfg.k)
        cb = _packing.unpack_codes(words_b, self.spec.bits, self.cfg.k)
        return self.estimate_rho(ca, cb)

    def asymptotic_std(self, rho):
        return self._estimator.asymptotic_std(rho, self.cfg.k)

    # -- storage accounting (the paper's headline economy) -------------------
    def bytes_per_vector(self) -> int:
        return 4 * _packing.packed_width(self.cfg.k, self.spec.bits)

    def fp32_bytes_per_vector(self) -> int:
        return 4 * self.cfg.k

    def with_scheme(self, scheme: str, w: Optional[float] = None):
        cfg = replace(self.cfg, scheme=scheme, w=self.cfg.w if w is None else w)
        return CodedRandomProjection(cfg, self.d)
