"""Quick numeric sanity check of core math vs paper's stated constants."""
import numpy as np
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from repro.core import probabilities as P
from repro.core import variance as V
from scipy import integrate, stats

rho = jnp.asarray([0.0, 0.25, 0.5, 0.75, 0.9, 0.99])

# 1. P_w at rho=0 vs closed series (Eq. 11)
for w in (0.5, 1.0, 2.0, 6.0):
    pw = float(P.collision_prob_uniform(jnp.asarray(0.0), w))
    i = np.arange(0, 40)
    series = 2 * np.sum((stats.norm.cdf((i + 1) * w) - stats.norm.cdf(i * w)) ** 2)
    print(f"P_w(rho=0,w={w}): quad={pw:.10f} series={series:.10f} diff={abs(pw-series):.2e}")

# 2. P_w vs scipy dblquad for rho=0.5, w=1
def joint(x, y, rho):
    s = np.sqrt(1 - rho**2)
    return np.exp(-(x*x - 2*rho*x*y + y*y) / (2*s*s)) / (2*np.pi*s)
tot = 0.0
for i in range(9):
    val, _ = integrate.dblquad(lambda y, x: joint(x, y, 0.5), i, i+1, lambda x: i, lambda x: i+1)
    tot += val
print(f"P_w(rho=0.5,w=1): ours={float(P.collision_prob_uniform(jnp.asarray(0.5),1.0)):.10f} scipy={2*tot:.10f}")

# 3. V_{w,q} minimum: 7.6797 at w/sqrt(d)=1.6476 (paper Fig 2)
d = 2.0  # rho = 0
ws = np.linspace(0.5, 8.0, 4000)
vals = np.asarray([float(V.variance_factor_offset(jnp.asarray(0.0), w)) * 4 / d**2 for w in ws])
i = np.argmin(vals)
print(f"V_wq factor min={vals[i]:.4f} at w/sqrt(d)={ws[i]/np.sqrt(d):.4f}  (paper: 7.6797 @ 1.6476)")

# 4. V_w|rho=0 -> pi^2/4 as w->inf (paper Thm 3 remark)
for w in (4.0, 8.0, 20.0):
    print(f"V_w(rho=0,w={w}) = {float(V.variance_factor_uniform(jnp.asarray(0.0), w)):.6f} (limit {np.pi**2/4:.6f})")

# 5. V_1 at rho=0: pi^2 * 1 * .5 * .5 = pi^2/4
print(f"V_1(rho=0) = {float(V.variance_factor_sign(jnp.asarray(0.0))):.6f}")

# 6. dP/drho analytic vs numeric for all schemes
eps = 1e-6
for scheme, w in (("uniform", 1.0), ("offset", 1.5), ("2bit", 0.75), ("sign", 0.0)):
    for r in (0.1, 0.5, 0.9):
        num = (float(P.collision_prob(jnp.asarray(r + eps), w, scheme))
               - float(P.collision_prob(jnp.asarray(r - eps), w, scheme))) / (2 * eps)
        ana = float(V.dP_drho(jnp.asarray(r), w, scheme))
        print(f"dP/drho {scheme:8s} w={w} rho={r}: analytic={ana:.8f} numeric={num:.8f} relerr={abs(ana-num)/max(abs(num),1e-12):.2e}")

# 7. P_{w,2} at w=0 and w->inf equals P_1
for w in (1e-6, 50.0):
    p2 = np.asarray(P.collision_prob_2bit(rho, w))
    p1 = np.asarray(P.collision_prob_sign(rho))
    print(f"P_w2(w={w}) vs P_1 max diff: {np.max(np.abs(p2-p1)):.2e}")
print("OK")
