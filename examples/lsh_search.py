"""Batched near-neighbor search with the device-resident ANN engine.

    PYTHONPATH=src python examples/lsh_search.py

Builds a packed-code ``AnnEngine`` over a corpus with planted
near-duplicates, then answers a *batch* of queries in one device call —
exact (brute-force packed collision) and LSH-banded multi-probe modes —
and shows the microbatching service front-end plus the legacy
``LSHIndex`` wrapper.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.ann import AnnEngine, BandSpec
from repro.core.lsh import LSHIndex
from repro.core.sketch import CodedRandomProjection, SketchConfig
from repro.serve import AnnService, AnnServiceConfig


def make_corpus(key, d, n):
    corpus = jax.random.normal(key, (n, d))
    corpus = corpus / jnp.linalg.norm(corpus, axis=1, keepdims=True)
    # plant 5 near-duplicates of item 0 at similarity 0.85-0.98
    u = corpus[0]
    planted = []
    for i, rho in enumerate([0.98, 0.95, 0.92, 0.9, 0.85]):
        z = jax.random.normal(jax.random.fold_in(key, i + 1), (d,))
        z = z - jnp.dot(z, u) * u
        z = z / jnp.linalg.norm(z)
        planted.append(rho * u + np.sqrt(1 - rho ** 2) * z)
    return jnp.concatenate([corpus, jnp.stack(planted)])


def main():
    d, n = 512, 2000
    key = jax.random.PRNGKey(0)
    corpus = make_corpus(key, d, n)

    crp = CodedRandomProjection(SketchConfig(k=128, scheme="2bit", w=0.75), d)
    engine = AnnEngine.build(crp, corpus,
                             BandSpec(n_tables=16, band_width=6))
    print(f"indexed {engine.n} items: {engine.store.nbytes} bytes packed "
          f"({crp.bytes_per_vector()} B/vec vs {4 * d} raw fp32)")

    # one batched call answers many queries; query 0 is the planted item
    queries = jnp.concatenate([corpus[0][None, :], corpus[100:107]])
    for mode, kw in [("exact", {}), ("lsh", dict(n_probes=2))]:
        ids, rho = engine.search(queries, top_k=8, mode=mode, **kw)
        hits = [(int(i), float(r)) for i, r in zip(ids[0], rho[0])]
        print(f"\n[{mode}] query = item 0; planted neighbors are ids >= {n}")
        print(f"{'corpus id':>9s} {'rho_hat':>8s}")
        for idx, r in hits:
            marker = (" <- planted" if idx >= n
                      else (" <- self" if idx == 0 else ""))
            print(f"{idx:9d} {r:8.4f}{marker}")
        found = sum(1 for idx, _ in hits if idx >= n)
        print(f"recall of planted near-duplicates in top-8: {found}/5")

    # microbatching service front-end: submit singles, flush one batch
    svc = AnnService(engine, AnnServiceConfig(top_k=3, mode="lsh",
                                              n_probes=1, buckets=(1, 8, 64)))
    tickets = [svc.submit(corpus[i]) for i in range(5)]
    svc.flush()
    ids0, _ = svc.result(tickets[0])
    print(f"\nservice: {dict(svc.stats)}, ticket0 top ids {np.asarray(ids0)}")

    # legacy wrapper still answers one query at a time
    index = LSHIndex(crp, n_tables=16, band_width=6).build(corpus)
    top = index.query(np.asarray(corpus[0]), top=3)
    print(f"LSHIndex compat wrapper top-3: {[(i, round(r, 4)) for i, r in top]}")


if __name__ == "__main__":
    main()
