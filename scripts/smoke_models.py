"""Shake-out: every arch's reduced config — init, loss+grad, prefill+decode.

Also checks prefill/decode consistency: logits for position t from
decode-by-decode must match the full-forward logits.
"""
import sys
import numpy as np
import jax
import jax.numpy as jnp

from repro import configs as C
from repro.models import lm as L
from repro.models.nn import init_params, count_params

ARCHS = sys.argv[1:] or C.ARCHS
B, S = 2, 32

for arch in ARCHS:
    cfg = C.get_smoke_config(arch)
    specs = L.model_param_specs(cfg)
    params = init_params(specs, seed=0)
    key = jax.random.PRNGKey(1)
    if cfg.n_codebooks > 1:
        tokens = jax.random.randint(key, (B, S, cfg.n_codebooks), 0, cfg.vocab_size)
    else:
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    loss_fn = jax.jit(lambda p, t: L.lm_loss(p, t, cfg)[0])
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    assert jnp.isfinite(gnorm), f"{arch}: grad not finite"

    # prefill + decode consistency
    last_logits, caches = jax.jit(
        lambda p, t: L.prefill(p, t, cfg, max_len=S + 4))(params, tokens[:, :S - 1])
    logits_dec, caches = jax.jit(
        lambda p, c, t: L.decode_step(p, c, t, jnp.int32(S - 1), cfg)
    )(params, caches, tokens[:, S - 1:S])
    hidden, _, _ = jax.jit(
        lambda p, t: L.forward(p, t, cfg, mode="train"))(params, tokens)
    logits_full = L.lm_logits(hidden[:, -1:], params, cfg)
    err = float(jnp.max(jnp.abs(logits_dec - logits_full)))
    scale = float(jnp.max(jnp.abs(logits_full))) + 1e-6
    print(f"{arch:24s} params={count_params(specs)/1e6:7.2f}M loss={float(loss):8.4f} "
          f"gnorm={float(gnorm):9.3f} decode_err={err:.4e} (rel {err/scale:.3e})")
    assert err / scale < 0.08, f"{arch}: prefill/decode mismatch {err} vs {scale}"
print("ALL OK")
