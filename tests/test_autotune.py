"""Autotune cache behavior: persistence round-trips, cold-cache
fallbacks, stale-entry filtering — and the one invariant everything
hangs on: a cache entry (fresh, stale, or fabricated) can change
*timing only*, never output bits.
"""
import json

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import packing as PK
from repro.kernels import autotune, ops, ref


@pytest.fixture
def iso_cache():
    """A fresh process-global cache for the test, restored after."""
    cache = autotune.AutotuneCache()
    prev = autotune.set_cache(cache)
    yield cache
    autotune.set_cache(prev)


def _fused_inputs(q=5, n=70, k=33, bits=2, seed=7):
    key = jax.random.PRNGKey(seed)
    wq = PK.pack_codes(
        jax.random.randint(key, (q, k), 0, 1 << bits), bits)
    wdb = PK.pack_codes(
        jax.random.randint(jax.random.fold_in(key, 1), (n, k), 0,
                           1 << bits), bits)
    fp = wq.shape[1] * (32 // bits) * (1 << bits)
    tab = jax.random.normal(jax.random.fold_in(key, 2), (q, fp))
    return wq, wdb, tab


# -- bucket + cache mechanics -------------------------------------------------

def test_shape_bucket_rounds_to_pow2():
    assert autotune.shape_bucket(n=100000, q=256) == "n131072-q256"
    assert autotune.shape_bucket(n=1, q=0) == "n1-q0"
    # close shapes share a bucket, far shapes never do
    assert (autotune.shape_bucket(n=70000, q=200)
            == autotune.shape_bucket(n=100000, q=256))
    assert (autotune.shape_bucket(n=70000, q=200)
            != autotune.shape_bucket(n=200000, q=200))


def test_cache_roundtrip_via_json(tmp_path, iso_cache):
    path = str(tmp_path / "tune.json")
    cfg = {"block_q": 64, "block_n": 512}
    iso_cache.put("tpu", "fused_scored_topk", "n1024-q8", "float32", cfg)
    iso_cache.save(path)
    reloaded = autotune.AutotuneCache(path)
    assert reloaded.get("tpu", "fused_scored_topk", "n1024-q8",
                        "float32") == cfg
    # the file is plain versioned JSON
    with open(path) as f:
        data = json.load(f)
    assert data["version"] == 1 and len(data["configs"]) == 1


def test_cache_miss_dimensions(iso_cache):
    cfg = {"block_q": 64}
    iso_cache.put("tpu", "packed_topk", "n1024-q8", "uint32", cfg)
    get = iso_cache.get
    assert get("tpu", "packed_topk", "n1024-q8", "uint32") == cfg
    assert get("gpu", "packed_topk", "n1024-q8", "uint32") is None
    assert get("tpu", "packed_topk", "n2048-q8", "uint32") is None
    assert get("tpu", "packed_topk", "n1024-q8", "float32") is None
    assert get("tpu", "packed_topk_masked", "n1024-q8", "uint32") is None


def test_put_rejects_non_sweepable_knobs(iso_cache):
    """Accumulation-order knobs can never enter the cache — that is the
    numerics invariant's write-side gate."""
    with pytest.raises(ValueError, match="non-sweepable"):
        iso_cache.put("tpu", "packed_linear_bwd", "c8-n1024", "float32",
                      {"block_c": 8, "block_n": 512})
    with pytest.raises(ValueError, match="non-sweepable"):
        iso_cache.put("tpu", "encode_fused", "m256", "float32",
                      {"block_d": 64})


def test_stale_entries_filtered_at_read(tmp_path, iso_cache):
    """A cache file written under an older schema (knobs that are no
    longer sweepable) is filtered to the safe subset at read time."""
    path = str(tmp_path / "stale.json")
    key = "tpu|fused_scored_topk|n1024-q8|float32"
    with open(path, "w") as f:
        json.dump({"version": 1, "configs": {
            key: {"block_q": 64, "block_d": 512, "unroll": 4}}}, f)
    cache = autotune.AutotuneCache(path)
    assert cache.get("tpu", "fused_scored_topk", "n1024-q8",
                     "float32") == {"block_q": 64}
    # nothing valid at all -> clean miss, not a crash
    with open(path, "w") as f:
        json.dump({"version": 1, "configs": {key: {"unroll": 4}}}, f)
    assert autotune.AutotuneCache(path).get(
        "tpu", "fused_scored_topk", "n1024-q8", "float32") is None


def test_candidate_configs_full_grid():
    grid = autotune.candidate_configs("fused_scored_topk")
    assert len(grid) == 9 and all(
        set(c) == {"block_q", "block_n"} for c in grid)
    assert len(autotune.candidate_configs("packed_linear_bwd")) == 3


# -- tune() measurement loop --------------------------------------------------

def test_tune_cpu_without_force_is_noop(iso_cache):
    calls = []
    out = autotune.tune("packed_topk", lambda c: calls.append(c),
                        "uint32", dict(q=8, n=64, w=4, top_k=8))
    assert out == {} and calls == [] and len(iso_cache) == 0


def test_tune_injected_measure_picks_argmin(iso_cache):
    """With a deterministic fake measure, tune picks the argmin config,
    records it, and lookup returns exactly it."""
    target = {"block_q": 64, "block_n": 512}

    def fake_measure(run, config):
        run(config)
        return 1.0 if config == target else 2.0 + config["block_q"]

    dims = dict(q=8, n=100, w=4, top_k=8)
    ran = []
    best = autotune.tune("packed_topk", ran.append, "uint32", dims,
                         measure=fake_measure)
    assert best == target
    assert len(ran) == len(autotune.candidate_configs("packed_topk"))
    assert autotune.lookup("packed_topk", "uint32", **dims) == target
    # a different bucket still cold-misses to {}
    assert autotune.lookup("packed_topk", "uint32", q=8, n=100000, w=4,
                           top_k=8) == {}


def test_tune_skips_raising_candidates(iso_cache):
    """Candidates that fail (VMEM overflow etc.) are skipped; the best
    surviving one wins. All failing -> {} and nothing cached."""
    def fragile_measure(run, config):
        if config["block_q"] > 64:
            raise RuntimeError("tile too large")
        return float(config["block_q"])

    dims = dict(q=8, n=100, w=4, top_k=8)
    best = autotune.tune("packed_topk", lambda c: None, "uint32", dims,
                         measure=fragile_measure)
    assert best["block_q"] == 64

    def all_fail(run, config):
        raise RuntimeError("no")

    assert autotune.tune("packed_topk", lambda c: None, "uint32",
                         dict(q=9, n=5000, w=4, top_k=8),
                         measure=all_fail) == {}
    assert autotune.lookup("packed_topk", "uint32", q=9, n=5000, w=4,
                           top_k=8) == {}


def test_tune_search_ops_with_injected_measure(iso_cache):
    """The service-warmup entry point tunes every search family using
    real (small) arrays, and the recorded winners flow back through
    lookup for the same dims."""
    seen = []

    def measure(run, config):
        run(config)           # must actually execute without raising
        seen.append(config)
        return float(sum(config.values()))

    out = autotune.tune_search_ops(n=128, w=3, bits=2, k=33, q=8,
                                   top_k=5, rerank_m=16,
                                   measure=measure)
    assert set(out) == {"packed_topk", "packed_topk_masked",
                        "fused_scored_topk", "fused_scored_topk_masked",
                        "packed_lut_topk"}
    for op, best in out.items():
        assert best, op       # every family found a winner
    fp = 3 * (32 // 2) * (1 << 2)
    assert autotune.lookup("fused_scored_topk", "float32", q=8, n=128,
                           w=3, t=fp, top_k=5) == out["fused_scored_topk"]


def test_tune_search_ops_cpu_default_noop(iso_cache):
    assert autotune.tune_search_ops(n=64, w=3, bits=2, k=33, q=4) == {}
    assert len(iso_cache) == 0


# -- the invariant: tuned configs change timing, never numerics ---------------

def test_tuned_config_never_changes_results(iso_cache):
    """ops picks up a cached (even adversarially odd) block config for
    the fused op and still returns bit-identical results to the oracle
    and to the untuned call."""
    wq, wdb, tab = _fused_inputs()
    bits, k, m, top_k = 2, 33, 16, 6
    fp = tab.shape[1]
    dims = dict(q=5, n=70, w=wq.shape[1], t=fp, top_k=top_k)

    base = ops.fused_scored_topk(wq, tab, wdb, bits, k, m, top_k,
                                 impl="pallas")
    want = ref.fused_scored_topk_ref(wq, tab, wdb, bits, k, m, top_k)
    for g, w_ in zip(base, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w_))

    for cfg in ({"block_q": 32, "block_n": 256},
                {"block_q": 128, "block_n": 1024}):
        autotune.record_config("fused_scored_topk", tab.dtype, dims, cfg,
                               cache=iso_cache)
        assert autotune.lookup("fused_scored_topk", tab.dtype,
                               **dims) == cfg
        tuned = ops.fused_scored_topk(wq, tab, wdb, bits, k, m, top_k,
                                      impl="pallas")
        for g, w_ in zip(tuned, base):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w_))


def test_cold_cache_identical_to_explicit_defaults(iso_cache):
    """Cold cache -> kernel defaults: bit-identical to passing the
    documented default blocks explicitly."""
    wq, wdb, tab = _fused_inputs()
    bits, k, m, top_k = 2, 33, 16, 6
    cold = ops.fused_scored_topk(wq, tab, wdb, bits, k, m, top_k,
                                 impl="pallas")
    explicit = ops.fused_scored_topk(wq, tab, wdb, bits, k, m, top_k,
                                     impl="pallas", block_q=128,
                                     block_n=512)
    for g, w_ in zip(cold, explicit):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w_))


def test_explicit_blocks_override_cache(iso_cache):
    """Caller-passed block sizes always win over a cached config (the
    dispatch contract _tuned implements)."""
    wq, wdb, tab = _fused_inputs(q=3, n=40)
    dims = dict(q=3, n=40, w=wq.shape[1], t=tab.shape[1], top_k=4)
    autotune.record_config("fused_scored_topk", tab.dtype, dims,
                           {"block_q": 128, "block_n": 1024},
                           cache=iso_cache)
    got = ops.fused_scored_topk(wq, tab, wdb, 2, 33, 8, 4,
                                impl="pallas", block_q=8, block_n=32)
    want = ref.fused_scored_topk_ref(wq, tab, wdb, 2, 33, 8, 4)
    for g, w_ in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w_))
