"""Fixed-order Gauss-Legendre quadrature helpers (host-side nodes, jnp eval).

The paper's collision probabilities (Thm 1, Thm 4, Lemma 1) are 1-D
integrals of smooth Gaussian integrands over bin intervals; fixed-order
Gauss-Legendre per interval converges spectrally and is fully jittable
(the nodes are compile-time constants).
"""
from __future__ import annotations

import functools

import numpy as np
import jax.numpy as jnp

__all__ = ["leggauss", "interval_nodes"]


@functools.lru_cache(maxsize=32)
def leggauss(order: int):
    """Cached Gauss-Legendre nodes/weights on [-1, 1] as float64 numpy."""
    x, w = np.polynomial.legendre.leggauss(order)
    return x.astype(np.float64), w.astype(np.float64)


def interval_nodes(a, b, order: int):
    """Nodes and weights for integration over [a, b].

    a, b: arrays (broadcastable) of interval endpoints.
    Returns (z, wz) with shape broadcast(a,b).shape + (order,).
    """
    x, w = leggauss(order)
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    half = 0.5 * (b - a)[..., None]
    mid = 0.5 * (b + a)[..., None]
    z = mid + half * jnp.asarray(x)
    wz = half * jnp.asarray(w)
    return z, wz
