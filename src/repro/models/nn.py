"""Minimal functional parameter system (no flax in this container).

Parameters are plain pytrees of jnp arrays. A parallel pytree of
``ParamSpec`` declares shape/dtype/init and *logical* sharding axes; specs
drive initialization (deterministic per-path keys), abstract
ShapeDtypeStructs for the dry-run, and NamedShardings via
``repro.parallel.ShardingRules``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ParamSpec", "init_params", "abstract_params", "param_shardings",
           "rms_norm", "count_params"]


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis names, len == rank
    dtype: str = "bfloat16"
    init: str = "fan_in"                      # fan_in | normal | zeros | ones | embed
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def initialize(self, key):
        dt = jnp.dtype(self.dtype)
        if self.init == "zeros":
            return jnp.zeros(self.shape, dt)
        if self.init == "ones":
            return jnp.ones(self.shape, dt)
        if self.init == "embed":
            std = self.scale
            return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(dt)
        if self.init == "normal":
            return (jax.random.normal(key, self.shape, jnp.float32)
                    * self.scale).astype(dt)
        if self.init == "fan_in":
            # truncated-normal fan-in (dim -2 is input for [in, out] matrices;
            # for stacked [L, ..., in, out] the -2 convention still holds)
            fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
            std = self.scale / math.sqrt(max(fan_in, 1))
            return (jax.random.truncated_normal(key, -2.0, 2.0, self.shape,
                                                jnp.float32) * std).astype(dt)
        raise ValueError(self.init)


def _is_spec(x):
    return isinstance(x, ParamSpec)


def init_params(specs, seed: int = 0):
    """Deterministic init: every leaf key is fold_in(root, hash(path))."""
    root = jax.random.PRNGKey(seed)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=_is_spec)
    out = []
    for path, spec in leaves:
        h = hash(jax.tree_util.keystr(path)) & 0x7FFFFFFF
        out.append(spec.initialize(jax.random.fold_in(root, h)))
    return jax.tree.unflatten(treedef, out)


def abstract_params(specs):
    """ShapeDtypeStruct pytree for dry-run lowering (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        specs, is_leaf=_is_spec)


def param_shardings(specs, rules, fsdp_threshold_bytes: float = 4e9):
    """NamedSharding pytree from logical axes via rules (ragged dims fall
    back to replication).

    If the TP-only layout leaves more than ``fsdp_threshold_bytes`` of
    parameters per device, parameters are additionally sharded over the
    data axes (FSDP): with stacked layer params as scan xs, GSPMD gathers
    one layer per scan step. Set threshold to inf to disable.
    """
    if rules is None or rules.mesh is None:
        return jax.tree.map(lambda s: None, specs, is_leaf=_is_spec)
    leaves = jax.tree.leaves(specs, is_leaf=_is_spec)
    per_dev = 0.0
    for s in leaves:
        pspec = rules.pspec_for(s.shape, s.axes)
        shard = 1
        for entry in pspec:
            flat = entry if isinstance(entry, tuple) else ((entry,) if entry else ())
            for a in flat:
                shard *= rules.mesh.shape[a]
        per_dev += np_prod(s.shape) * jnp.dtype(s.dtype).itemsize / max(shard, 1)
    if per_dev <= fsdp_threshold_bytes:
        return jax.tree.map(lambda s: rules.sharding_for(s.shape, s.axes),
                            specs, is_leaf=_is_spec)

    from jax.sharding import NamedSharding
    from repro.parallel.sharding import zero_shard_spec

    def fsdp(s):
        ps = rules.pspec_for(s.shape, s.axes)
        start = 1 if (s.axes and s.axes[0] == "layers") else 0
        return NamedSharding(rules.mesh,
                             zero_shard_spec(rules, ps, s.shape, start=start))

    return jax.tree.map(fsdp, specs, is_leaf=_is_spec)


def param_pspecs(specs, rules):
    return jax.tree.map(lambda s: rules.pspec_for(s.shape, s.axes), specs,
                        is_leaf=_is_spec)


def count_params(specs) -> int:
    return sum(int(np_prod(s.shape)) for s in jax.tree.leaves(specs, is_leaf=_is_spec))


def np_prod(shape):
    out = 1
    for s in shape:
        out *= s
    return out


def rms_norm(x, weight, eps: float = 1e-6, plus_one: bool = False):
    """RMSNorm in f32 accumulation; gemma uses (1 + w) scaling."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    w = 1.0 + w if plus_one else w
    return (xf * w).astype(dt)
