"""Device-side LSH banding: batched band hashes + multi-probe expansion.

The paper's table construction ("(2 ceil(6/w))^k buckets" amplified the
standard way) banded the k codes into L tables of m codes each. The old
``core.lsh`` hashed bands one query at a time into Python dicts; here the
whole thing is a jnp computation so a [Q, k] code batch turns into
[Q, L] uint32 bucket ids in one fused kernel, and corpus-vs-query bucket
equality is a batched compare — no host round-trip on the query path.

Multi-probe: probe p perturbs one band position by ±1 (the neighboring
quantization cell, the natural probe for the paper's floor(./w) codes)
before hashing. Probes are *prefix-nested*: the probe sequence is fixed
and ``n_probes`` selects a prefix, so the probed bucket set — and hence
the candidate set — is monotone in ``n_probes`` by construction.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

__all__ = ["BandSpec", "band_hashes", "probe_hashes"]

_MIX1 = jnp.uint32(0x9E3779B9)      # golden-ratio increment
_MIX2 = jnp.uint32(0x85EBCA6B)      # murmur3 finalizer constants
_MIX3 = jnp.uint32(0xC2B2AE35)


@dataclass(frozen=True)
class BandSpec:
    """L tables of m codes each over the first L*m of k projections."""
    n_tables: int = 8
    band_width: int = 8

    def validate(self, k: int):
        """Check L*m fits within k code positions; returns self."""
        need = self.n_tables * self.band_width
        if need > k:
            raise ValueError(
                f"need n_tables*band_width <= k, {need} > {k}")
        return self


def _mix(h):
    h = h ^ (h >> jnp.uint32(16))
    h = h * _MIX2
    h = h ^ (h >> jnp.uint32(13))
    h = h * _MIX3
    return h ^ (h >> jnp.uint32(16))


def _hash_bands(bands):
    """bands int32 [..., L, m] -> uint32 [..., L] bucket ids.

    Polynomial accumulate + murmur-style finalizer, all uint32 so it runs
    on device without x64.
    """
    h = jnp.zeros(bands.shape[:-1], jnp.uint32)
    for j in range(bands.shape[-1]):
        h = (h ^ (bands[..., j].astype(jnp.uint32) + _MIX1)) * _MIX2
        h = h ^ (h >> jnp.uint32(15))
    return _mix(h)


def band_hashes(codes, spec: BandSpec):
    """codes int32 [..., k] -> uint32 band hashes [..., L]."""
    L, m = spec.validate(codes.shape[-1]).n_tables, spec.band_width
    bands = codes[..., :L * m].reshape(codes.shape[:-1] + (L, m))
    return _hash_bands(bands)


def probe_hashes(codes, spec: BandSpec, n_probes: int = 0):
    """codes int32 [..., k] -> uint32 [..., P, L] with P = 1 + n_probes.

    Probe 0 is the unperturbed hash; probe p >= 1 bumps band position
    (p-1) // 2 mod m by +1 (p odd) or -1 (p even) in every band. The
    sequence is deterministic, so probe sets are nested prefixes.
    """
    L, m = spec.validate(codes.shape[-1]).n_tables, spec.band_width
    bands = codes[..., :L * m].reshape(codes.shape[:-1] + (L, m))
    out = [_hash_bands(bands)]
    for p in range(1, n_probes + 1):
        pos = (p - 1) // 2 % m
        delta = 1 if p % 2 == 1 else -1
        bump = jnp.zeros((m,), jnp.int32).at[pos].set(delta)
        out.append(_hash_bands(bands + bump))
    return jnp.stack(out, axis=-2)
