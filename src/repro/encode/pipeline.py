"""Chunked ingest pipeline: raw corpus -> packed words -> store, streamed.

``IngestPipeline`` is the bulk-load driver above the encoder: it walks a
host-resident corpus (dense array or ``CsrMatrix``) in fixed-size row
chunks, encodes each chunk straight to packed words (fused kernels, no
f32/int32 corpus intermediates in HBM), and appends them to a store —
either the mutable ``index.SegmentLogStore`` (donated O(batch) tail
writes, via ``add_words``) or the immutable ``ann.CodeStore`` (merge per
chunk).  Chunks are padded up to a power-of-two row count so the whole
ingest compiles O(log chunk_rows) executables regardless of corpus size.

``encode_sharded`` is the data-parallel twin: corpus rows sharded over a
mesh axis, each shard streaming the SAME canonical R units locally (the
seed regenerates R everywhere — nothing is broadcast), so the packed
words are bit-identical to a single-device encode at any device count.
"""
from __future__ import annotations

import time
from types import MappingProxyType

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.encode.encoder import StreamingEncoder
from repro.encode.sparse import CsrMatrix
from repro.kernels import ops as _ops
from repro.obs import MetricsRegistry, default_flight_recorder, span
from repro.parallel.sharding import shard_map_unchecked

__all__ = ["IngestPipeline", "encode_sharded"]


class IngestPipeline:
    """Stream a corpus into a store in encoder-sized chunks.

    ``store`` may be a ``SegmentLogStore``-like object (has
    ``add_codes``/``add_words`` with external-id support; mutated in
    place) or a ``CodeStore``-like object (has ``merge``/``from_words``;
    rebound on ``self.store`` per chunk — read it back after
    ``ingest``).  ``stats`` is a read-only view of the ``repro.obs``
    counters accumulating rows, chunks and packed bytes across calls;
    per-chunk encode latency lands in the ``encode.chunk_s`` histogram
    and each chunk opens an ``encode.chunk`` span when tracing.
    """

    def __init__(self, encoder: StreamingEncoder, store, *,
                 chunk_rows: int = 2048, impl: str = "auto",
                 registry: MetricsRegistry = None):
        if chunk_rows <= 0:
            raise ValueError(f"chunk_rows must be positive: {chunk_rows}")
        self.encoder = encoder
        self.store = store
        self.chunk_rows = int(chunk_rows)
        self.impl = impl
        self.registry = registry if registry is not None \
            else MetricsRegistry(enabled=True)
        self._c_rows = self.registry.counter("encode.rows")
        self._c_chunks = self.registry.counter("encode.chunks")
        self._c_bytes = self.registry.counter("encode.packed_bytes")
        self._h_chunk = self.registry.histogram("encode.chunk_s")

    @property
    def stats(self):
        """Read-only compat view of the ingest counters."""
        return MappingProxyType({"rows": self._c_rows.value,
                                 "chunks": self._c_chunks.value,
                                 "packed_bytes": self._c_bytes.value})

    def _encode_chunk(self, x, lo: int, hi: int):
        """Rows [lo, hi) -> packed words [hi-lo, W]; the chunk is padded
        up to a power of two (zero rows, dropped after the kernel) so
        ragged tails never compile a fresh executable."""
        m = hi - lo
        mp = min(1 << (m - 1).bit_length(), self.chunk_rows)
        if isinstance(x, CsrMatrix):
            chunk = x.row_slice(lo, hi)
            if mp > m:
                pad = np.zeros(mp - m, np.int64)
                chunk = CsrMatrix(
                    indptr=np.concatenate([chunk.indptr,
                                           pad + chunk.indptr[-1]]),
                    indices=chunk.indices, data=chunk.data,
                    shape=(mp, chunk.d))
        elif isinstance(x, jax.Array):
            chunk = x[lo:hi]
            if mp > m:
                chunk = jnp.pad(chunk, ((0, mp - m), (0, 0)))
        else:
            # host corpora stay host-side: the encoder ships unit slabs
            # to the device itself (O(chunk·unit), not O(chunk·D))
            chunk = np.asarray(x[lo:hi], np.float32)
            if mp > m:
                chunk = np.pad(chunk, ((0, mp - m), (0, 0)))
        words = self.encoder.encode_packed(chunk, impl=self.impl)
        return words[:m]

    def ingest(self, x, ids=None) -> np.ndarray:
        """Encode + append every row of ``x`` (dense [n, D] or
        ``CsrMatrix``); returns the external ids (int64 [n]; for
        ``CodeStore`` targets, the appended row positions)."""
        n = x.n if isinstance(x, CsrMatrix) else int(np.asarray(
            x.shape[0]))
        if ids is not None:
            if not hasattr(self.store, "add_codes"):
                raise ValueError(
                    "explicit ids need an id-aware store (SegmentLogStore); "
                    "CodeStore rows are addressed by position only")
            ids = np.asarray(ids, np.int64)
            if ids.shape != (n,):
                raise ValueError(f"ids {ids.shape} != ({n},)")
            # validate the WHOLE batch before the first chunk is
            # appended: a clash surfacing mid-loop would leave earlier
            # chunks permanently ingested (no rollback)
            if np.unique(ids).size != n:
                raise ValueError("duplicate ids within one ingest")
            clash = [int(i) for i in ids if i in self.store]
            if clash:
                raise ValueError(f"ids already live (upsert instead): "
                                 f"{clash[:5]}")
        out_ids = []
        t_ing = time.perf_counter()
        with span("encode.ingest", rows=n) as sp:
            for lo in range(0, n, self.chunk_rows):
                hi = min(lo + self.chunk_rows, n)
                t0 = time.perf_counter()
                with span("encode.chunk", rows=hi - lo) as csp:
                    words = csp.sync(self._encode_chunk(x, lo, hi))
                self._h_chunk.observe(time.perf_counter() - t0)
                chunk_ids = None if ids is None else ids[lo:hi]
                if hasattr(self.store, "add_codes"):        # mutable log
                    out_ids.append(np.asarray(
                        self.store.add_words(words, ids=chunk_ids)))
                else:                                       # immutable store
                    start = self.store.n
                    self.store = self.store.add_words(words)
                    out_ids.append(np.arange(start, start + (hi - lo),
                                             dtype=np.int64))
                self._c_rows.inc(hi - lo)
                self._c_chunks.inc()
                self._c_bytes.inc(int(words.size) * 4)
            sp.set(chunks=self._c_chunks.value)
        # chunk encodes round-trip to host (np words), so t_end here is
        # effectively device-synced
        default_flight_recorder().record(
            "encode.ingest", t_ing, time.perf_counter(), batch=n,
            generation=getattr(self.store, "generation", -1), synced=True)
        return (np.concatenate(out_ids) if out_ids
                else np.zeros(0, np.int64))


def encode_sharded(encoder: StreamingEncoder, x, mesh: Mesh,
                   axis: str = "data", impl: str = "auto"):
    """Data-parallel fused encode: dense x [n, D] row-sharded over
    ``mesh[axis]`` -> packed uint32 [n, W] (n must divide the axis;
    CSR corpora shard at the pipeline level instead — run one
    ``IngestPipeline`` per host over its row slice).

    Every shard regenerates the same canonical R units from the seed —
    no weight broadcast, no gather — runs the sketcher's scan
    projection over its local rows and the fused code+pack epilogue
    kernel (``kernels.encode_fused``, dispatched per ``impl``), so the
    result matches the unsharded streaming encode bit-for-bit at ANY
    device count (the reproducibility contract of ``core.sketch``)."""
    s = encoder.sketcher
    x = jnp.asarray(x)
    if x.shape[0] % mesh.shape[axis]:
        raise ValueError(f"n={x.shape[0]} not divisible by mesh axis "
                         f"{axis} ({mesh.shape[axis]})")

    def local(xs):
        # the sketcher's canonical scan-projection: every shard streams
        # the same units in the same order as the single-device oracle
        return _ops.code_pack(s.project(xs), s.spec, s._offsets,
                              impl=impl)

    fn = shard_map_unchecked(local, mesh, in_specs=(P(axis, None),),
                             out_specs=P(axis, None))
    return jax.jit(fn)(x)
