"""Single-pass fused scored search: coarse collision filter + LUT
re-rank in one kernel.

The two-stage scored path (``packed_collision`` top-m -> gather ->
``packed_lut`` re-rank) pays for its statistical win twice: the coarse
stage sorts the full [Q, N] count matrix down to m candidate ids, and
those ids round-trip through HBM to drive a gather before scoring. This
kernel streams the corpus once more instead and never materializes
either: the survivor *rule* of the stable coarse top-m is evaluated
in-VMEM per corpus tile, and survivors' LUT scores enter the running
top-k directly.

Survivor rule. Collision counts live in [-1, k] (-1 = tombstoned or
padded), so the coarse top-m by count is fully described by a threshold
and a tie quota: with A(c) = #{rows : count > c} and t the smallest
c >= 0 with A(c) < m, row n survives iff count > t, or count == t and
its id-ascending rank among the count == t ties is <= m - A(t). That is
exactly the membership of ``ref.topk_stable_ref(counts, m)`` (stable
ties -> lowest id) — but it needs only the (k+1)-bin exceedance
histogram, not a sort.

Two sweeps over the corpus stream (grid minor axis runs 0..2*NT-1; VMEM
scratch persists across the minor axis for a fixed query tile):

sweep A (j < NT)
    XOR/popcount counts per tile, accumulate A(c) for c in 0..k into a
    [bq, k+1] VMEM histogram. At the phase boundary (j == NT) the
    histogram inverts into (t, quota) with a min/max reduction — no
    gather, no sort.

sweep B (j >= NT)
    Recompute the tile's counts (cheaper than writing [Q, N] to HBM and
    reading it back), evaluate the survivor rule — id-ascending tie
    ranks come from a sequential per-query tie counter plus an in-tile
    cumsum, computed as a triangular f32 matmul (MXU-friendly; exact
    below 2^24) — LUT-score the tile, mask non-survivors to -inf, and
    merge into the running (scores, ids) top-k exactly like
    ``packed_lut``.

Scoring paths: float tables upcast to float32 at tile load and
accumulate in (word, field) order (bit-identical to
``ref.lut_scores_rowwise_ref``); int8 tables take per-(query, word)
float32 scales, sum each word's 32/b selected entries exactly in int32,
and join the float32 total as ``score += scale * float(isum)`` in word
order (bit-identical to ``ref.lut_scores_rowwise_int8_ref``). Scales
must be powers of two: the multiply is then exact, so FMA contraction —
which XLA applies or skips depending on the surrounding fusion — cannot
flip a single result bit between kernel and oracle.

Padding: padded query rows get zero words/tables/scales (their outputs
are sliced off); corpus rows past ``n_valid`` (and tombstoned rows in
the masked variant) take count -1, which the survivor rule can never
admit, so they need no separate score mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import bitmask_width
from repro.kernels.packed_collision import (_merge_running_topk,
                                            _mismatch_bits, _pad)
from repro.kernels.packed_lut import _accum_lut_scores, _init_running, \
    _lut_select

__all__ = ["fused_scored_topk_pallas", "fused_scored_topk_masked_pallas"]

_NEG_INF = float("-inf")


def _row_cumsum(x):
    """Inclusive row-wise cumsum of small non-negative int32 [bq, bn]
    via a triangular f32 matmul — one MXU op instead of a lane scan;
    exact while row sums stay below 2^24 (tile widths are far below)."""
    n = x.shape[-1]
    r = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    tri = (r <= c).astype(jnp.float32)
    return jnp.dot(x.astype(jnp.float32), tri,
                   preferred_element_type=jnp.float32).astype(jnp.int32)


def _accum_lut_scores_int8(tab, scales, words, bits: int, shape):
    """int8-table LUT scores for a corpus tile: tab int32 [bq, F*P]
    (upcast int8 entries), scales f32 [bq, W], words uint32 [bn, W] ->
    f32 ``shape``. Per word: exact int32 entry sum, then one scaled
    float32 add — the accumulation contract of
    ``ref.lut_scores_rowwise_int8_ref``."""
    p = 1 << bits
    cpw = 32 // bits
    n_words = words.shape[-1]
    score = jnp.zeros(shape, jnp.float32)
    for w in range(n_words):
        word = words[:, w][None, :]                       # [1, bn]
        isum = jnp.zeros(shape, jnp.int32)
        for f in range(cpw):
            c = (word >> jnp.uint32(f * bits)) & jnp.uint32(p - 1)
            col = (w * cpw + f) * p
            entries = [tab[:, col + i][:, None] for i in range(p)]
            isum = isum + _lut_select(c, entries)
        score = score + scales[:, w][:, None] * isum.astype(jnp.float32)
    return score


def _fused_scored_kernel(*refs, bits: int, k: int, rerank_m: int,
                         top_k: int, n_valid: int, block_n: int, nt: int,
                         has_mask: bool, has_scales: bool):
    it = iter(refs)
    q_ref, tab_ref, db_ref = next(it), next(it), next(it)
    valid_ref = next(it) if has_mask else None
    scales_ref = next(it) if has_scales else None
    ov_ref, oi_ref = next(it), next(it)
    above_ref, thr_ref, quota_ref, ties_ref = (next(it), next(it),
                                               next(it), next(it))
    vals_ref, ids_ref = next(it), next(it)

    j = pl.program_id(1)

    def tile_counts():
        q = q_ref[...]                                    # [bq, W]
        db = db_ref[...]                                  # [bn, W]
        xor = jnp.bitwise_xor(q[:, None, :], db[None, :, :])
        counts = k - jnp.sum(_mismatch_bits(xor, bits), axis=-1)
        local = jax.lax.broadcasted_iota(jnp.int32,
                                         (counts.shape[0], block_n), 1)
        gids = local + jax.lax.rem(j, nt) * block_n
        counts = jnp.where(gids < n_valid, counts, -1)
        if has_mask:
            v = valid_ref[...]                            # [bn/32, 1]
            bitpos = jax.lax.broadcasted_iota(jnp.uint32,
                                              (block_n // 32, 32), 1)
            live = ((v >> bitpos) & jnp.uint32(1)).reshape(1, block_n)
            counts = jnp.where(live != 0, counts, -1)
        return counts, gids

    @pl.when(j == 0)
    def _init_hist():
        above_ref[...] = jnp.zeros_like(above_ref)

    @pl.when(j < nt)
    def _sweep_a():
        counts, _ = tile_counts()
        cols = [jnp.sum((counts > c).astype(jnp.int32), axis=1,
                        keepdims=True) for c in range(k + 1)]
        above_ref[...] += jnp.concatenate(cols, axis=1)

    @pl.when(j == nt)
    def _invert():
        a = above_ref[...]                                # [bq, k+1]
        below = a < rerank_m          # nonempty: A(k) == 0 < rerank_m
        cidx = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
        thr_ref[...] = jnp.min(jnp.where(below, cidx, k + 1), axis=1,
                               keepdims=True)
        # A is non-increasing, so A(t) is the max over satisfied bins
        a_t = jnp.max(jnp.where(below, a, -1), axis=1, keepdims=True)
        quota_ref[...] = rerank_m - a_t
        ties_ref[...] = jnp.zeros_like(ties_ref)
        _init_running(vals_ref, ids_ref)

    @pl.when(j >= nt)
    def _sweep_b():
        counts, gids = tile_counts()
        t = thr_ref[...]                                  # [bq, 1]
        is_tie = counts == t
        tie_rank = ties_ref[...] + _row_cumsum(is_tie.astype(jnp.int32))
        surv = (counts > t) | (is_tie & (tie_rank <= quota_ref[...]))
        ties_ref[...] += jnp.sum(is_tie.astype(jnp.int32), axis=1,
                                 keepdims=True)
        db = db_ref[...]
        if has_scales:
            score = _accum_lut_scores_int8(
                tab_ref[...].astype(jnp.int32), scales_ref[...], db, bits,
                counts.shape)
        else:
            score = _accum_lut_scores(tab_ref[...].astype(jnp.float32), db,
                                      bits, counts.shape)
        score = jnp.where(surv, score, _NEG_INF)
        _merge_running_topk(vals_ref, ids_ref, score, gids, top_k)

    @pl.when(j == 2 * nt - 1)
    def _finalize():
        ov_ref[...] = vals_ref[...]
        oi_ref[...] = ids_ref[...]


def _fused_scored_call(q_words, q_tables, words_db, valid_words, scales,
                       bits, k, rerank_m, top_k, block_q, block_n,
                       interpret):
    qn, w = q_words.shape
    n = words_db.shape[0]
    fp = q_tables.shape[1]
    assert q_tables.shape[0] == qn, (q_words.shape, q_tables.shape)
    assert w == words_db.shape[1], (q_words.shape, words_db.shape)
    assert fp == w * (32 // bits) * (1 << bits), (q_tables.shape,
                                                  words_db.shape, bits)
    assert rerank_m >= 1 and top_k >= 1, (rerank_m, top_k)
    assert block_n % 32 == 0, block_n
    if scales is not None:
        assert q_tables.dtype == jnp.int8, q_tables.dtype
        assert scales.shape == (qn, w), (scales.shape, qn, w)
    if n == 0:
        return (jnp.full((qn, top_k), _NEG_INF, jnp.float32),
                jnp.full((qn, top_k), -1, jnp.int32))
    qp = _pad(q_words, block_q, 0)
    tp = _pad(q_tables, block_q, 0)
    dbp = _pad(words_db, block_n, 0)
    qm, nm = qp.shape[0], dbp.shape[0]
    nt = nm // block_n
    inputs = [qp, tp, dbp]
    in_specs = [
        pl.BlockSpec((block_q, w), lambda i, j: (i, 0)),
        pl.BlockSpec((block_q, fp), lambda i, j: (i, 0)),
        pl.BlockSpec((block_n, w), lambda i, j: (j % nt, 0)),
    ]
    if valid_words is not None:
        nw = bitmask_width(n)
        assert valid_words.shape == (nw,), (valid_words.shape, nw)
        vw = valid_words.astype(jnp.uint32)
        if n % 32:   # zero mask bits past N inside the last partial word
            vw = vw.at[-1].set(vw[-1] & jnp.uint32((1 << (n % 32)) - 1))
        vw = jnp.pad(vw, (0, nm // 32 - nw)).reshape(nm // 32, 1)
        inputs.append(vw)
        in_specs.append(
            pl.BlockSpec((block_n // 32, 1), lambda i, j: (j % nt, 0)))
    if scales is not None:
        inputs.append(_pad(scales.astype(jnp.float32), block_q, 0))
        in_specs.append(pl.BlockSpec((block_q, w), lambda i, j: (i, 0)))
    kernel = functools.partial(
        _fused_scored_kernel, bits=bits, k=k, rerank_m=rerank_m,
        top_k=top_k, n_valid=n, block_n=block_n, nt=nt,
        has_mask=valid_words is not None, has_scales=scales is not None)
    vals, ids = pl.pallas_call(
        kernel,
        grid=(qm // block_q, 2 * nt),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_q, top_k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, top_k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qm, top_k), jnp.float32),
            jax.ShapeDtypeStruct((qm, top_k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k + 1), jnp.int32),
            pltpu.VMEM((block_q, 1), jnp.int32),
            pltpu.VMEM((block_q, 1), jnp.int32),
            pltpu.VMEM((block_q, 1), jnp.int32),
            pltpu.VMEM((block_q, top_k), jnp.float32),
            pltpu.VMEM((block_q, top_k), jnp.int32),
        ],
        interpret=interpret,
    )(*inputs)
    return vals[:qn], ids[:qn]


@functools.partial(
    jax.jit,
    static_argnames=("bits", "k", "rerank_m", "top_k", "block_q",
                     "block_n", "interpret"))
def fused_scored_topk_pallas(q_words, q_tables, words_db, bits: int,
                             k: int, rerank_m: int, top_k: int, *,
                             scales=None, block_q: int = 128,
                             block_n: int = 512, interpret: bool = False):
    """Single-pass scored search: q_words uint32 [Q, W], q_tables float
    or int8 [Q, F*P], words_db uint32 [N, W] -> (scores f32 [Q, top_k],
    corpus ids int32 [Q, top_k]).

    Top-``top_k`` by LUT score over the exact stable coarse
    top-``rerank_m`` by collision count, in one streamed pass — no
    [Q, N] matrix, no candidate-id round-trip through HBM. ``scales``
    float32 [Q, W] selects the int8 table path. Bit-exact vs
    ``ref.fused_scored_topk_ref`` (scores, lowest-id ties, (-inf, -1)
    sentinel padding when candidates run out).
    """
    return _fused_scored_call(q_words, q_tables, words_db, None, scales,
                              bits, k, rerank_m, top_k, block_q, block_n,
                              interpret)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "k", "rerank_m", "top_k", "block_q",
                     "block_n", "interpret"))
def fused_scored_topk_masked_pallas(q_words, q_tables, words_db,
                                    valid_words, bits: int, k: int,
                                    rerank_m: int, top_k: int, *,
                                    scales=None, block_q: int = 128,
                                    block_n: int = 512,
                                    interpret: bool = False):
    """``fused_scored_topk_pallas`` over live rows only: ``valid_words``
    uint32 [ceil(N/32)] packed bitmask (``packing.pack_bitmask``
    layout). Tombstoned rows take count -1 before the survivor rule, so
    they can neither survive nor displace a live tie; the mask is data,
    not shape — deletes never recompile. Bit-exact vs
    ``ref.fused_scored_topk_masked_ref``.
    """
    return _fused_scored_call(q_words, q_tables, words_db, valid_words,
                              scales, bits, k, rerank_m, top_k, block_q,
                              block_n, interpret)
