"""repro.rank: non-linear estimators, LUT tables, and the scored
search paths (single-pass fused by default, two-stage as the checked
fallback). Kernel-vs-oracle bit-exactness lives in
test_kernel_conformance.py."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.ann import AnnEngine, BandSpec
from repro.core import packing as PK
from repro.core.estimators import MleRhoEstimator, cell_probs
from repro.core.schemes import CodeSpec
from repro.core.sketch import CodedRandomProjection, SketchConfig
from repro.index import MutableAnnEngine
from repro.kernels import ref
from repro.rank import build_rank_tables
from repro.serve.ann_service import AnnService, AnnServiceConfig

SPECS = [("2bit", 0.75), ("sign", 1.0), ("uniform", 1.0)]


# -- non-linear estimator -----------------------------------------------------

@pytest.mark.parametrize("scheme,w", SPECS)
def test_mle_estimator_monotone_in_rho(scheme, w):
    """The grid-inverted MLE is monotone in the true rho: feeding it the
    *expected* contingency counts of increasing rho must produce a
    non-decreasing (and accurate) rho_hat sequence."""
    spec = CodeSpec(scheme, w)
    est = MleRhoEstimator(spec, grid_size=512)
    rhos = np.linspace(0.0, 0.98, 30)
    n = spec.n_codes
    probs = np.asarray(cell_probs(jnp.asarray(rhos), spec))
    rho_hat = np.asarray(est.from_counts(256.0 * probs.reshape(30, n * n)))
    assert (np.diff(rho_hat) >= 0).all(), rho_hat
    assert np.max(np.abs(rho_hat - rhos)) < 0.01


def test_mle_estimate_from_codes():
    """Sampled correlated projections: the 2-bit MLE recovers rho."""
    rho, k = 0.8, 4096
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (k,))
    y = rho * x + np.sqrt(1 - rho ** 2) * jax.random.normal(
        jax.random.fold_in(key, 1), (k,))
    spec = CodeSpec("2bit", 0.75)
    from repro.core.schemes import encode
    est = MleRhoEstimator(spec)
    got = float(est.estimate(encode(x[None], spec), encode(y[None], spec))[0])
    assert abs(got - rho) < 0.05, got


def test_rank_tables_calibration_roundtrip():
    """rho_from_scores inverts the expected-score curve to ~1e-4."""
    spec = CodeSpec("2bit", 0.75)
    k = 128
    rt = build_rank_tables(spec, k)
    rhos = np.linspace(0.0, 0.95, 16)
    probs = np.asarray(cell_probs(jnp.asarray(rhos), spec))
    n = spec.n_codes
    g = k * np.einsum("gab,ab->g", probs, np.asarray(rt.pair)[:n, :n])
    rho_hat = np.asarray(rt.rho_from_scores(g))
    assert (np.diff(rho_hat) >= 0).all()
    np.testing.assert_allclose(rho_hat, rhos, atol=1e-3)


def test_rank_tables_reject_offset_scheme():
    with pytest.raises(ValueError):
        build_rank_tables(CodeSpec("offset", 1.0), 64)


# -- fused LUT kernels vs oracles ---------------------------------------------

# -- scored search --------------------------------------------------------

def _unit(x):
    return x / jnp.linalg.norm(x, axis=-1, keepdims=True)


@pytest.fixture(scope="module")
def scored_world():
    """Clustered corpus + queries with float32 cosine ground truth."""
    d, n_clusters, per, nq = 32, 80, 8, 24
    key = jax.random.PRNGKey(11)
    centers = _unit(jax.random.normal(key, (n_clusters, d)))
    noise = _unit(jax.random.normal(jax.random.fold_in(key, 1),
                                    (n_clusters, per, d)))
    corpus = _unit(0.92 * centers[:, None, :] + np.sqrt(1 - 0.92 ** 2)
                   * noise).reshape(-1, d)
    qn = _unit(jax.random.normal(jax.random.fold_in(key, 2), (nq, d)))
    queries = _unit(0.92 * centers[:nq] + np.sqrt(1 - 0.92 ** 2) * qn)
    crp = CodedRandomProjection(SketchConfig(k=64, scheme="2bit", w=0.75), d)
    engine = AnnEngine.build(crp, corpus, BandSpec(n_tables=8, band_width=4))
    gt = np.asarray(jnp.argsort(-(queries @ corpus.T), axis=1)[:, :10])
    return engine, corpus, queries, gt


def _recall(ids, gt):
    return float(np.mean([len(set(np.asarray(a)) & set(b)) / gt.shape[1]
                          for a, b in zip(ids, gt)]))


def test_two_stage_recall_at_least_collision_only(scored_world):
    """Against float32 cosine ground truth, LUT re-ranked recall@10 must
    be at least collision-count-only recall@10 at equal k."""
    engine, corpus, queries, gt = scored_world
    ids_plain, _ = engine.search(queries, 10, mode="exact")
    ids_scored, rho = engine.search(queries, 10, mode="exact", scored=True,
                                    rerank_m=256)
    r_plain, r_scored = _recall(ids_plain, gt), _recall(ids_scored, gt)
    assert r_scored >= r_plain, (r_scored, r_plain)
    # calibrated rho is descending per row and within [-1, 1]
    rho = np.asarray(rho)
    assert (np.diff(rho, axis=1) <= 1e-6).all()
    assert (rho <= 1.0).all() and (rho >= -1.0).all()


def test_scored_full_coverage_is_global_lut_ranking(scored_world):
    """With rerank_m >= n the coarse stage cannot truncate: two-stage
    results must equal a full-corpus LUT ranking."""
    engine, corpus, queries, gt = scored_world
    n = engine.n
    ids, _ = engine.search(queries, 6, mode="exact", scored=True,
                           rerank_m=n)
    q_codes = engine.encode_queries(queries)
    tab = engine.rank_tables.query_tables(q_codes)
    _, want = ref.packed_lut_topk_ref(tab, engine.store.words,
                                      engine.store.bits, 6)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(want))


def test_scored_mutable_matches_immutable(scored_world):
    """Single-segment mutable scored search == immutable scored search
    (same corpus, full coarse coverage)."""
    engine, corpus, queries, gt = scored_world
    crp = engine.sketcher
    m = MutableAnnEngine(crp, band_spec=BandSpec(n_tables=8, band_width=4),
                         tail_rows=1024)
    m.add(corpus)
    ids_m, rho_m = m.search(queries, 5, mode="exact", scored=True,
                            rerank_m=engine.n)
    ids_i, rho_i = engine.search(queries, 5, mode="exact", scored=True,
                                 rerank_m=engine.n)
    np.testing.assert_array_equal(np.asarray(ids_m), np.asarray(ids_i))
    np.testing.assert_allclose(np.asarray(rho_m), np.asarray(rho_i),
                               rtol=1e-6)


def test_scored_mutable_skips_tombstones(scored_world):
    """Deleted rows never appear in scored results."""
    engine, corpus, queries, gt = scored_world
    m = MutableAnnEngine(engine.sketcher,
                         band_spec=BandSpec(n_tables=8, band_width=4),
                         tail_rows=256)  # several segments
    ext = m.add(corpus)
    dead = set(int(i) for i in ext[::3])
    m.delete(sorted(dead))
    ids, _ = m.search(queries, 10, mode="exact", scored=True, rerank_m=64)
    got = set(int(x) for x in np.asarray(ids).ravel()) - {-1}
    assert not got & dead


def test_scored_edge_batches(scored_world):
    """Empty batch and top_k > corpus honor the (-1, -1) fill contract
    in scored mode too."""
    engine, corpus, queries, gt = scored_world
    ids, rho = engine.search(queries[:0], top_k=3, scored=True)
    assert ids.shape == (0, 3) and rho.shape == (0, 3)
    big = engine.n + 4
    ids, rho = engine.search(queries[:2], top_k=big, mode="exact",
                             scored=True)
    assert (np.asarray(ids[:, engine.n:]) == -1).all()
    assert (np.asarray(rho[:, engine.n:]) == -1).all()


def test_scored_lsh_mode(scored_world):
    """LSH + scored: results come from the banded candidate set and
    carry calibrated rho."""
    engine, corpus, queries, gt = scored_world
    ids, rho = engine.search(queries, 5, mode="lsh", n_probes=1,
                             scored=True, rerank_m=128)
    assert (np.asarray(ids[:, 0]) >= 0).all()
    assert _recall(ids, gt[:, :5]) > 0.2


def test_scored_sharded_matches_unsharded(scored_world):
    from jax.sharding import Mesh
    engine, corpus, queries, gt = scored_world
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    ids_s, rho_s = engine.search_sharded(queries, mesh, top_k=4,
                                         scored=True, rerank_m=256)
    ids_e, rho_e = engine.search(queries, top_k=4, mode="exact",
                                 scored=True, rerank_m=256)
    np.testing.assert_array_equal(np.asarray(ids_s), np.asarray(ids_e))
    np.testing.assert_allclose(np.asarray(rho_s), np.asarray(rho_e),
                               rtol=1e-6)


def test_service_scored_mode(scored_world):
    """The serving layer threads scored knobs through and caches on
    them: scored and unscored results never alias one cache entry."""
    engine, corpus, queries, gt = scored_world
    svc_s = AnnService(engine, AnnServiceConfig(top_k=3, scored=True,
                                                rerank_m=64,
                                                buckets=(1, 4)))
    svc_p = AnnService(engine, AnnServiceConfig(top_k=3, buckets=(1, 4)))
    t_s = [svc_s.submit(queries[i]) for i in range(4)]
    t_p = [svc_p.submit(queries[i]) for i in range(4)]
    out_s, out_p = svc_s.flush(), svc_p.flush()
    ids_direct, _ = engine.search(queries[:4], top_k=3, mode="exact",
                                  scored=True, rerank_m=64)
    for i, t in enumerate(t_s):
        np.testing.assert_array_equal(np.asarray(out_s[t][0]),
                                      np.asarray(ids_direct[i]))
    assert svc_s._cache_key(np.zeros(4)) != svc_p._cache_key(np.zeros(4))
    # cache hit on resubmission
    t2 = svc_s.submit(queries[0])
    svc_s.flush()
    assert svc_s.stats["cache_hits"] >= 1
    np.testing.assert_array_equal(np.asarray(svc_s.result(t2)[0]),
                                  np.asarray(ids_direct[0]))


def test_service_autotune_warmup_both_store_types(scored_world):
    """``autotune_warmup=True`` must survive warmup over both store
    shapes — CodeStore (words array) and SegmentLogStore (packed width
    attr) — and change nothing about the results (on CPU the sweep is
    a no-op by design)."""
    engine, corpus, queries, gt = scored_world
    svc = AnnService(engine, AnnServiceConfig(
        top_k=3, scored=True, rerank_m=64, buckets=(1, 4),
        autotune_warmup=True))
    svc.warmup(corpus.shape[1])
    t = svc.submit(queries[0])
    svc.flush()
    ids_direct, _ = engine.search(queries[:1], top_k=3, mode="exact",
                                  scored=True, rerank_m=64)
    np.testing.assert_array_equal(np.asarray(svc.result(t)[0]),
                                  np.asarray(ids_direct[0]))

    m = MutableAnnEngine(engine.sketcher, tail_rows=128)
    m.add(corpus, np.arange(corpus.shape[0]))
    svc_m = AnnService(m, AnnServiceConfig(
        top_k=3, scored=True, rerank_m=64, buckets=(1, 4),
        autotune_warmup=True))
    svc_m.warmup(corpus.shape[1])
    tm = svc_m.submit(queries[0])
    svc_m.flush()
    np.testing.assert_array_equal(np.asarray(svc_m.result(tm)[0]),
                                  np.asarray(ids_direct[0]))


def test_bf16_tables_end_to_end(scored_world):
    """bf16-quantized tables run the whole scored path and stay close
    to the f32 ranking."""
    engine, corpus, queries, gt = scored_world
    eng_bf16 = AnnEngine(engine.sketcher, engine.store,
                         BandSpec(n_tables=8, band_width=4),
                         db_band_hashes=engine.db_band_hashes,
                         rank_tables=engine.rank_tables.quantize())
    ids_b, _ = eng_bf16.search(queries, 10, mode="exact", scored=True,
                               rerank_m=256)
    ids_f, _ = engine.search(queries, 10, mode="exact", scored=True,
                             rerank_m=256)
    overlap = np.mean([len(set(np.asarray(a)) & set(np.asarray(b))) / 10
                       for a, b in zip(ids_b, ids_f)])
    assert overlap >= 0.8, overlap


# -- single-pass fused scored path (engine level) -----------------------------

def test_fused_matches_two_stage_immutable(scored_world):
    """The default fused path is bit-identical to the two-stage path it
    replaces — ids AND calibrated rho, across rerank_m regimes."""
    engine, corpus, queries, gt = scored_world
    for m in (16, 256, engine.n + 50):      # truncating / ample / m > n
        ids_f, rho_f = engine.search(queries, 10, mode="exact",
                                     scored=True, rerank_m=m, fused=True)
        ids_t, rho_t = engine.search(queries, 10, mode="exact",
                                     scored=True, rerank_m=m, fused=False)
        np.testing.assert_array_equal(np.asarray(ids_f), np.asarray(ids_t))
        np.testing.assert_array_equal(np.asarray(rho_f), np.asarray(rho_t))


def test_fused_matches_two_stage_mutable(scored_world):
    """Fused masked path == two-stage across segments with tombstones;
    segments small enough that rerank_m exceeds some live counts."""
    engine, corpus, queries, gt = scored_world
    m = MutableAnnEngine(engine.sketcher,
                         band_spec=BandSpec(n_tables=8, band_width=4),
                         tail_rows=256)
    ext = m.add(corpus)
    m.delete(sorted(int(i) for i in ext[::3]))
    for rm in (32, 300):
        ids_f, rho_f = m.search(queries, 10, mode="exact", scored=True,
                                rerank_m=rm, fused=True)
        ids_t, rho_t = m.search(queries, 10, mode="exact", scored=True,
                                rerank_m=rm, fused=False)
        np.testing.assert_array_equal(np.asarray(ids_f), np.asarray(ids_t))
        np.testing.assert_array_equal(np.asarray(rho_f), np.asarray(rho_t))


def test_fused_all_rows_tombstoned_segment(scored_world):
    """A segment whose rows are all deleted contributes nothing; with
    everything deleted the engine returns pure sentinels."""
    engine, corpus, queries, gt = scored_world
    m = MutableAnnEngine(engine.sketcher, tail_rows=128)
    ext = m.add(corpus)
    m.delete([int(i) for i in ext if int(i) < 128])  # first segment dead
    ids, _ = m.search(queries, 10, mode="exact", scored=True, rerank_m=64)
    assert not (set(np.asarray(ids).ravel().tolist()) - {-1}) & set(
        range(128))
    m.delete([int(i) for i in ext if int(i) >= 128])
    ids, rho = m.search(queries, 5, mode="exact", scored=True)
    assert (np.asarray(ids) == -1).all()
    assert (np.asarray(rho) == -1.0).all()


def test_fused_sharded_matches_unsharded(scored_world):
    from jax.sharding import Mesh
    engine, corpus, queries, gt = scored_world
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    ids_s, rho_s = engine.search_sharded(queries, mesh, top_k=4,
                                         scored=True, rerank_m=256,
                                         fused=True)
    ids_e, rho_e = engine.search(queries, top_k=4, mode="exact",
                                 scored=True, rerank_m=256, fused=True)
    np.testing.assert_array_equal(np.asarray(ids_s), np.asarray(ids_e))
    np.testing.assert_allclose(np.asarray(rho_s), np.asarray(rho_e),
                               rtol=1e-6)


def test_int8_tables_end_to_end(scored_world):
    """int8 query tables (power-of-two scales) run the fused path end
    to end and stay close to the f32 ranking; the two-stage path
    rejects them loudly."""
    engine, corpus, queries, gt = scored_world
    ids_8, rho_8 = engine.search(queries, 10, mode="exact", scored=True,
                                 rerank_m=256, table_dtype="int8")
    ids_f, _ = engine.search(queries, 10, mode="exact", scored=True,
                             rerank_m=256)
    overlap = np.mean([len(set(np.asarray(a)) & set(np.asarray(b))) / 10
                       for a, b in zip(ids_8, ids_f)])
    assert overlap >= 0.8, overlap
    rho_8 = np.asarray(rho_8)
    assert (rho_8 <= 1.0).all() and (rho_8 >= -1.0).all()
    with pytest.raises(ValueError, match="int8"):
        engine.search(queries, 10, scored=True, table_dtype="int8",
                      fused=False)


def test_int8_quantization_contract(scored_world):
    """query_tables_int8 emits power-of-two scales and reconstructs the
    f32 tables to within one quantization step."""
    engine, corpus, queries, gt = scored_world
    rt = engine.rank_tables
    q_codes = engine.encode_queries(queries[:4])
    qt, scales = rt.query_tables_int8(q_codes)
    s = np.asarray(scales)
    assert (np.exp2(np.round(np.log2(s))) == s).all()   # powers of two
    t32 = np.asarray(rt.query_tables(q_codes, dtype=jnp.float32))
    cpw_p = t32.shape[1] // s.shape[1]
    recon = (np.asarray(qt, np.float32).reshape(4, s.shape[1], cpw_p)
             * s[:, :, None]).reshape(t32.shape)
    assert np.abs(recon - t32).max() <= s.max()
