"""Backend-measured block-size autotuning for the Pallas kernel
families.

The kernels in this package take hand-picked default tile sizes; what
actually wins depends on the backend generation and the workload shape.
This module sweeps each family's *numerics-safe* knobs against real
timed calls and caches the winner, keyed on::

    (backend, op, shape-bucket, dtype)

where the shape bucket rounds every dispatch dimension up to the next
power of two — close shapes share a tuning, far shapes never do, and a
cache entry can never leak across backends.

Numerics invariant (what makes a cache safe to trust blindly): the
sweep space (``SWEEPS``) contains only knobs that provably cannot
change results — query/corpus/candidate row tiles. Streaming order is
fixed by the grid, the running top-k merges are stable, integer
accumulations are order-free, and float scores accumulate per row in a
fixed (word, field) order regardless of tiling. Knobs that ARE part of
an oracle's accumulation-order contract (``packed_linear_bwd``'s
``block_n``, ``encode_fused``/``coded_project``'s ``block_d``) are
pinned to their defaults and never swept. A stale, corrupt, or
wrong-bucket cache entry can therefore only change timing, never
output bits — ``tests/test_autotune.py`` and the block-size-invariance
properties in ``tests/test_kernel_conformance.py`` enforce exactly
this.

Lookup is pure and jit-friendly (a host dict read keyed on static
dims); measurement is explicit and offline: ``tune`` times real calls
(median of ``repeats``, ``block_until_ready``), which only makes sense
on a compiled backend — on CPU, where kernels run in interpret mode,
``tune`` refuses to measure and returns the defaults unless forced or
given an injected ``measure`` function (how the tests drive it
deterministically). ``kernels/ops.py`` consults ``lookup`` on every
dispatch whose caller passed no explicit block sizes, so engines and
services pick up tuned configs transparently; ``serve.ann_service``
can pre-tune its own search shapes at warmup.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Optional

import jax

__all__ = ["SWEEPS", "shape_bucket", "AutotuneCache", "default_cache",
           "set_cache", "lookup", "record_config", "tune",
           "tune_search_ops"]

# op -> {knob: candidate values}. ONLY numerics-safe knobs (row tiles).
# Reduction-axis tiles that fix an accumulation order (packed_linear_bwd
# block_n, encode_fused/coded_project block_d) are deliberately absent.
SWEEPS = {
    "coded_project": {"block_m": (32, 64, 128, 256)},
    "encode_fused": {"block_m": (64, 128, 256)},
    "code_pack": {"block_m": (64, 128, 256, 512)},
    "pack_codes": {"block_m": (64, 128, 256, 512)},
    "collision_counts": {"block_q": (64, 128, 256),
                         "block_n": (64, 128, 256)},
    "packed_collision_counts": {"block_q": (64, 128, 256),
                                "block_n": (64, 128, 256)},
    "packed_topk": {"block_q": (64, 128, 256),
                    "block_n": (256, 512, 1024)},
    "packed_topk_masked": {"block_q": (64, 128, 256),
                           "block_n": (256, 512, 1024)},
    "packed_lut_topk": {"block_q": (32, 64, 128),
                        "block_n": (256, 512, 1024)},
    "packed_lut_topk_masked": {"block_q": (32, 64, 128),
                               "block_n": (256, 512, 1024)},
    "packed_lut_rerank": {"block_q": (32, 64, 128),
                          "block_m": (256, 512, 1024)},
    "fused_scored_topk": {"block_q": (32, 64, 128),
                          "block_n": (256, 512, 1024)},
    "fused_scored_topk_masked": {"block_q": (32, 64, 128),
                                 "block_n": (256, 512, 1024)},
    "packed_linear_fwd": {"block_c": (8, 16, 32),
                          "block_n": (256, 512, 1024)},
    "packed_linear_fwd_masked": {"block_c": (8, 16, 32),
                                 "block_n": (256, 512, 1024)},
    "packed_linear_bwd": {"block_c": (8, 16, 32)},
    "packed_linear_bwd_masked": {"block_c": (8, 16, 32)},
}

_ENV_PATH = "REPRO_AUTOTUNE_CACHE"
_MEASURED_BACKENDS = ("tpu", "gpu")


def _backend() -> str:
    return jax.default_backend()


def _bucket_dim(v: int) -> int:
    """Next power of two >= v (0 stays 0) — the shape-bucket rounding."""
    v = int(v)
    return 0 if v <= 0 else 1 << (v - 1).bit_length()


def shape_bucket(**dims) -> str:
    """Canonical bucket string for a dispatch's static dims: each value
    rounded up to the next power of two, keys sorted — e.g.
    ``n=100000, q=256`` -> ``"n131072-q256"``."""
    return "-".join(f"{k}{_bucket_dim(v)}" for k, v in sorted(dims.items()))


def _key(backend: str, op: str, bucket: str, dtype: str) -> str:
    return f"{backend}|{op}|{bucket}|{dtype}"


class AutotuneCache:
    """(backend, op, shape-bucket, dtype) -> block-size dict, with JSON
    persistence. Entries whose knobs fall outside the op's declared
    sweep space are ignored at read time (stale-schema safety), so a
    cache file can only ever supply knobs the numerics invariant
    covers."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._configs: dict[str, dict] = {}
        if path and os.path.exists(path):
            self.load(path)

    def get(self, backend: str, op: str, bucket: str, dtype: str):
        """The cached config dict, filtered to the op's sweepable knobs;
        None on miss or when nothing valid survives the filter."""
        cfg = self._configs.get(_key(backend, op, bucket, dtype))
        if not cfg:
            return None
        allowed = SWEEPS.get(op, {})
        out = {kn: int(v) for kn, v in cfg.items() if kn in allowed}
        return out or None

    def put(self, backend: str, op: str, bucket: str, dtype: str,
            config: dict):
        """Store one winning config (knobs outside the sweep space are
        rejected loudly — they would break the numerics invariant)."""
        allowed = SWEEPS.get(op, {})
        bad = set(config) - set(allowed)
        if bad:
            raise ValueError(f"non-sweepable knobs for {op}: {sorted(bad)}")
        self._configs[_key(backend, op, bucket, dtype)] = dict(config)

    def save(self, path: Optional[str] = None) -> str:
        """Write the cache as JSON; returns the path written."""
        path = path or self.path
        assert path, "no path bound to this cache"
        tmp = f"{path}.tmp"
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(tmp, "w") as f:
            json.dump({"version": 1, "configs": self._configs}, f,
                      indent=2, sort_keys=True)
        os.replace(tmp, path)
        self.path = path
        return path

    def load(self, path: str) -> "AutotuneCache":
        """Merge entries from a JSON cache file into this cache."""
        with open(path) as f:
            data = json.load(f)
        self._configs.update(data.get("configs", {}))
        self.path = path
        return self

    def clear(self):
        """Drop every entry."""
        self._configs.clear()

    def __len__(self) -> int:
        return len(self._configs)


_CACHE: Optional[AutotuneCache] = None


def default_cache() -> AutotuneCache:
    """The process-global cache; first use loads ``$REPRO_AUTOTUNE_
    CACHE`` if the variable is set and the file exists."""
    global _CACHE
    if _CACHE is None:
        _CACHE = AutotuneCache(os.environ.get(_ENV_PATH) or None)
    return _CACHE


def set_cache(cache: Optional[AutotuneCache]) -> Optional[AutotuneCache]:
    """Swap the process-global cache (None resets to lazy default);
    returns the previous one — how tests isolate themselves."""
    global _CACHE
    prev = _CACHE
    _CACHE = cache
    return prev


def lookup(op: str, dtype, **dims) -> dict:
    """Tuned block sizes for one dispatch, or ``{}`` (use the kernel's
    defaults) on a cold cache / unknown bucket — the call ``ops.py``
    makes when a caller passed no explicit block sizes. Never measures,
    never raises."""
    cache = default_cache()
    return cache.get(_backend(), op, shape_bucket(**dims),
                     str(dtype)) or {}


def record_config(op: str, dtype, dims: dict, config: dict, *,
                  backend: Optional[str] = None,
                  cache: Optional[AutotuneCache] = None):
    """Store ``config`` for (backend, op, bucket(dims), dtype)."""
    cache = cache or default_cache()
    cache.put(backend or _backend(), op, shape_bucket(**dims),
              str(dtype), config)


def _default_measure(run: Callable[[dict], object], config: dict,
                     repeats: int) -> float:
    """Median wall-time of ``run(config)`` with device sync; one warmup
    call first so compile time never biases the vote."""
    jax.block_until_ready(run(config))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(run(config))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def candidate_configs(op: str) -> list[dict]:
    """The sweep grid for ``op`` as a list of config dicts."""
    knobs = sorted(SWEEPS[op].items())
    grids = [{}]
    for name, values in knobs:
        grids = [dict(g, **{name: v}) for g in grids for v in values]
    return grids


def tune(op: str, run: Callable[[dict], object], dtype, dims: dict, *,
         measure: Optional[Callable] = None, repeats: int = 3,
         cache: Optional[AutotuneCache] = None,
         force: bool = False) -> dict:
    """Sweep ``op``'s block-size grid by timing ``run(config)``, cache
    the winner under (backend, op, bucket(dims), dtype), return it.

    ``run`` executes the op once with the given block kwargs (adapters
    close over real arrays); candidates that raise (tile too large for
    VMEM at this shape, say) are skipped. On backends where kernels run
    in interpret mode (CPU) timing is meaningless, so without ``force``
    or an injected ``measure`` this is a no-op returning ``{}`` — safe
    to call unconditionally at service warmup."""
    if measure is None:
        if _backend() not in _MEASURED_BACKENDS and not force:
            return {}
        measure = lambda r, c: _default_measure(r, c, repeats)  # noqa: E731
    best, best_t = None, None
    for config in candidate_configs(op):
        try:
            t = measure(run, config)
        except Exception:
            continue
        if best_t is None or t < best_t:
            best, best_t = config, t
    if best is None:
        return {}
    record_config(op, dtype, dims, best, cache=cache)
    return best


def tune_search_ops(n: int, w: int, bits: int, k: int, *, q: int = 256,
                    top_k: int = 10, rerank_m: int = 256,
                    table_dtype="float32", seed: int = 0,
                    measure: Optional[Callable] = None,
                    cache: Optional[AutotuneCache] = None,
                    force: bool = False) -> dict:
    """Tune the search-family ops for one corpus shape bucket using
    synthesized representative arrays; returns {op: winning config}.

    The convenience entry point ``serve.ann_service`` warmup calls: a
    no-op (empty dict per op) off-accelerator unless forced, so it is
    always safe to invoke.
    """
    import jax.numpy as jnp

    from repro.kernels import ops as _ops

    if measure is None and _backend() not in _MEASURED_BACKENDS \
            and not force:
        return {}
    kk = jax.random.split(jax.random.PRNGKey(seed), 4)
    q_words = jax.random.bits(kk[0], (q, w), jnp.uint32)
    words_db = jax.random.bits(kk[1], (n, w), jnp.uint32)
    fp = w * (32 // bits) * (1 << bits)
    scales = None
    if str(jnp.dtype(table_dtype)) == "int8":
        # the int8 path takes quantized tables + per-word power-of-two
        # scales (the fused kernel's contract)
        tables = jax.random.randint(kk[2], (q, fp), -127, 128, jnp.int8)
        scales = jnp.full((q, w), 0.0078125, jnp.float32)  # 2**-7
    else:
        tables = jax.random.uniform(kk[2], (q, fp), jnp.float32,
                                    -1.0, 1.0).astype(table_dtype)
    valid = jnp.full(((n + 31) // 32,), 0xFFFFFFFF, jnp.uint32)
    runs = {
        "packed_topk": (
            dict(q=q, n=n, w=w, top_k=top_k), q_words.dtype,
            lambda c: _ops.packed_topk(q_words, words_db, bits, k, top_k,
                                       impl="pallas", **c)),
        "packed_topk_masked": (
            dict(q=q, n=n, w=w, top_k=top_k), q_words.dtype,
            lambda c: _ops.packed_topk_masked(q_words, words_db, valid,
                                              bits, k, top_k,
                                              impl="pallas", **c)),
        "fused_scored_topk": (
            dict(q=q, n=n, w=w, t=fp, top_k=top_k), tables.dtype,
            lambda c: _ops.fused_scored_topk(q_words, tables, words_db,
                                             bits, k, rerank_m, top_k,
                                             scales=scales,
                                             impl="pallas", **c)),
        "fused_scored_topk_masked": (
            dict(q=q, n=n, w=w, t=fp, top_k=top_k), tables.dtype,
            lambda c: _ops.fused_scored_topk_masked(
                q_words, tables, words_db, valid, bits, k, rerank_m,
                top_k, scales=scales, impl="pallas", **c)),
    }
    if scales is None:
        runs["packed_lut_topk"] = (
            dict(q=q, n=n, w=w, t=fp, top_k=top_k), tables.dtype,
            lambda c: _ops.packed_lut_topk(tables, words_db, bits, top_k,
                                           impl="pallas", **c))
    out = {}
    for op, (dims, dtype, run) in runs.items():
        out[op] = tune(op, run, dtype, dims, measure=measure, cache=cache,
                       force=force)
    return out
