"""Pallas TPU kernels for the paper's compute hot-spots.

proj_code   — fused projection GEMM + in-register coding (MXU + epilogue)
pack_codes  — b-bit field packing into uint32 words (VPU)
collision   — all-pairs code-match counting (VPU compare-accumulate)

Each has a pure-jnp oracle in ref.py and a dispatching wrapper in ops.py;
tests sweep shapes/dtypes in interpret mode against the oracles.
"""
from repro.kernels.ops import coded_project, pack_codes, collision_counts  # noqa: F401
