"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the 1 real CPU device; only launch/dryrun.py forces 512 host devices
(and tests/test_distributed.py spawns subprocesses with 8)."""
import numpy as np
import pytest


def pytest_report_header(config):
    """Surface which property-test engine this run got (real hypothesis
    or the seeded shim in ``_hypothesis_compat``) in the CI summary."""
    try:
        from _hypothesis_compat import HAVE_HYPOTHESIS
    except ImportError:
        return None
    engine = ("hypothesis" if HAVE_HYPOTHESIS
              else "seeded shim (_hypothesis_compat)")
    return f"property tests: {engine}"


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
