"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local(1024):global, 128k context, dual rope bases,
qk-norm. [hf:google/gemma-3-1b-pt; unverified]"""
from dataclasses import replace

from repro.models.lm import ModelConfig

# global layers keep a full 500k KV -> long_500k skipped (DESIGN.md)
SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b", family="dense",
        n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, d_ff=21504,
        vocab_size=262144, head_dim=128,
        layer_pattern="LLLLLG", window=1024,
        rope_theta=1_000_000.0, rope_theta_local=10_000.0,
        qk_norm=True, activation="gelu", post_norms=True, embed_scale=True,
        tie_embeddings=True, norm_eps=1e-6,
    )


def smoke_config() -> ModelConfig:
    return replace(config(), n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
                   head_dim=16, d_ff=128, vocab_size=256, window=8,
                   loss_chunk=16, chunk_kv=32, chunk_q=16)
