"""musicgen-medium [audio]: 48L d_model=1536 24H (MHA kv=24) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens, 4 codebooks with delay
pattern (handled by the data layout; the EnCodec frontend is a stub:
input_specs supplies the 4 codebook token streams). Text cross-attention
omitted per the backbone-only assignment. [arXiv:2306.05284; hf]"""
from dataclasses import replace

from repro.models.lm import ModelConfig

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", family="audio",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, d_ff=6144,
        vocab_size=2048, n_codebooks=4, tie_embeddings=False,
        rope_theta=10000.0, norm_eps=1e-5,
    )


def smoke_config() -> ModelConfig:
    return replace(config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                   d_ff=128, vocab_size=64, loss_chunk=16, chunk_kv=32,
                   chunk_q=16)
