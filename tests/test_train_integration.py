"""End-to-end: tiny LM trains (loss drops), checkpoint resume is exact,
data pipeline is deterministic/resumable, serving generates."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step
from repro.data import DataConfig, TokenPipeline
from repro.models import lm as L
from repro.models.nn import init_params
from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.serve import generate
from repro.train import Trainer, TrainState, make_train_step


def _tiny_cfg():
    return L.ModelConfig(name="tiny", n_layers=2, d_model=32, n_heads=2,
                         n_kv_heads=2, d_ff=64, vocab_size=64, loss_chunk=16,
                         chunk_kv=16, chunk_q=16, remat=False)


def test_data_pipeline_deterministic():
    p1 = TokenPipeline(DataConfig(vocab_size=64, seq_len=16, global_batch=2))
    p2 = TokenPipeline(DataConfig(vocab_size=64, seq_len=16, global_batch=2))
    np.testing.assert_array_equal(np.asarray(p1.batch_at(3)),
                                  np.asarray(p2.batch_at(3)))
    assert not np.array_equal(np.asarray(p1.batch_at(3)),
                              np.asarray(p1.batch_at(4)))


def test_train_loss_decreases_and_resumes(tmp_path):
    cfg = _tiny_cfg()
    opt_cfg = AdamWConfig(lr_peak=3e-3, warmup_steps=5, decay_steps=60,
                          weight_decay=0.0)
    pipe = TokenPipeline(DataConfig(vocab_size=64, seq_len=32, global_batch=4))
    from repro.parallel.sharding import ShardingRules
    rules = ShardingRules(None)
    step_fn = make_train_step(cfg, opt_cfg, rules)

    params = init_params(L.model_param_specs(cfg), seed=0)
    opt = init_opt_state(params, opt_cfg)
    tr = Trainer(step_fn, TrainState(params, opt), pipe,
                 ckpt_dir=str(tmp_path), ckpt_every=10, log_every=100,
                 log_fn=lambda *a: None)
    hist = tr.run(30)
    first = float(np.mean([h["loss"] for h in hist[:5]]))
    last = float(np.mean([h["loss"] for h in hist[-5:]]))
    assert last < first - 0.1, (first, last)
    assert latest_step(str(tmp_path)) == 30

    # resume: fresh trainer picks up step 30 and continues identically
    params2 = init_params(L.model_param_specs(cfg), seed=0)
    opt2 = init_opt_state(params2, opt_cfg)
    tr2 = Trainer(step_fn, TrainState(params2, opt2), pipe,
                  ckpt_dir=str(tmp_path), ckpt_every=10, log_every=100,
                  log_fn=lambda *a: None)
    tr2.maybe_resume()
    assert tr2.state.step == 30
    m_restored = tr2.state.opt_state["m"]
    m_current = tr.state.opt_state["m"]
    for a, b in zip(jax.tree.leaves(m_restored), jax.tree.leaves(m_current)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


def test_generate_shapes():
    cfg = _tiny_cfg()
    params = init_params(L.model_param_specs(cfg), seed=0)
    prompt = jax.random.randint(jax.random.PRNGKey(0), (2, 8), 0, 64)
    out = generate(params, prompt, cfg, n_tokens=5)
    assert out.shape == (2, 13)
    out_t = generate(params, prompt, cfg, n_tokens=5, temperature=1.0, seed=3)
    assert out_t.shape == (2, 13)
