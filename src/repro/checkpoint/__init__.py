from repro.checkpoint.checkpointer import (  # noqa: F401
    save_checkpoint, restore_checkpoint, read_manifest, latest_step,
    available_steps,
)
