"""Encoders for the four coding schemes (paper Eq. 4, Eq. 5, §4, §5).

All encoders map projected values x (any shape, last axis = k projections)
to small integer codes. Codes are *unsigned* int32 in [0, n_codes) so they
pack directly into b-bit fields (``repro.core.packing``) and index one-hot
feature expansions (``repro.core.svm``).

The uniform scheme uses the paper's cutoff argument (§1.1): values beyond
|x| = cutoff (default 6, tail mass 9.9e-10) are clamped, so the code needs
1 + log2(ceil(cutoff/w)) bits.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = [
    "CodeSpec", "spec_for", "encode", "encode_uniform", "encode_offset",
    "encode_2bit", "encode_sign", "sample_offsets", "collision_fraction",
]


@dataclass(frozen=True)
class CodeSpec:
    """Static description of a coding scheme instance."""
    scheme: str            # uniform | offset | 2bit | sign
    w: float               # bin width (ignored for sign)
    cutoff: float = 6.0    # clamp for uniform/offset schemes
    # derived
    @property
    def n_bins_side(self) -> int:
        if self.scheme == "uniform":
            return max(1, int(math.ceil(self.cutoff / self.w)))
        if self.scheme == "offset":
            # the random offset can push values one bin past the cutoff
            return max(1, int(math.ceil(self.cutoff / self.w)) + 1)
        if self.scheme == "2bit":
            return 2
        return 1

    @property
    def n_codes(self) -> int:
        return 2 * self.n_bins_side

    @property
    def bits(self) -> int:
        """Bits per packed code field: ceil(log2(n_codes)) rounded up to a
        32-bit-divisible field width (1/2/4/8/16)."""
        raw = max(1, int(math.ceil(math.log2(self.n_codes))))
        for b in (1, 2, 4, 8, 16):
            if raw <= b:
                return b
        raise ValueError(f"codes too wide to pack: {self.n_codes}")


def spec_for(scheme: str, w: float = 1.0, cutoff: float = 6.0) -> CodeSpec:
    return CodeSpec(scheme=scheme, w=float(w), cutoff=float(cutoff))


def encode_uniform(x, w: float, cutoff: float = 6.0):
    """h_w (Eq. 4): floor(x/w), clamped to +-cutoff, shifted to unsigned.

    Returns int32 codes in [0, 2*ceil(cutoff/w)).
    """
    n_side = max(1, int(math.ceil(cutoff / w)))
    c = jnp.floor(jnp.asarray(x) / w)
    c = jnp.clip(c, -n_side, n_side - 1)
    return (c + n_side).astype(jnp.int32)


def encode_offset(x, w: float, q, cutoff: float = 6.0):
    """h_{w,q} (Eq. 5, Datar et al.): floor((x + q)/w) with q ~ U(0, w)
    shared per-projection (broadcast on the last axis), clamped."""
    n_side = max(1, int(math.ceil(cutoff / w)) + 1)  # offset can push one bin over
    c = jnp.floor((jnp.asarray(x) + q) / w)
    c = jnp.clip(c, -n_side, n_side - 1)
    return (c + n_side).astype(jnp.int32)


def encode_2bit(x, w: float):
    """h_{w,2} (§4): regions (-inf,-w) -> 0, [-w,0) -> 1, [0,w) -> 2, [w,inf) -> 3."""
    x = jnp.asarray(x)
    return ((x >= -w).astype(jnp.int32)
            + (x >= 0.0).astype(jnp.int32)
            + (x >= w).astype(jnp.int32))


def encode_sign(x):
    """h_1 (§5): sign bit, x >= 0 -> 1 else 0."""
    return (jnp.asarray(x) >= 0.0).astype(jnp.int32)


def sample_offsets(key, k: int, w: float, dtype=jnp.float32):
    """q_j ~ Uniform(0, w), one per projection; shared by all data vectors."""
    return jax.random.uniform(key, (k,), dtype=dtype, minval=0.0, maxval=w)


def encode(x, spec: CodeSpec, q=None):
    """Dispatch encoder. ``q`` required iff scheme == 'offset'."""
    if spec.scheme == "uniform":
        return encode_uniform(x, spec.w, spec.cutoff)
    if spec.scheme == "offset":
        if q is None:
            raise ValueError("offset scheme requires offsets q (sample_offsets)")
        return encode_offset(x, spec.w, q, spec.cutoff)
    if spec.scheme == "2bit":
        return encode_2bit(x, spec.w)
    if spec.scheme == "sign":
        return encode_sign(x)
    raise ValueError(f"unknown scheme {spec.scheme!r}")


def collision_fraction(codes_a, codes_b, axis: int = -1):
    """Empirical collision probability P_hat = mean_j [a_j == b_j]."""
    return jnp.mean((codes_a == codes_b).astype(jnp.float32), axis=axis)
