"""Shared benchmark helpers: timing + CSV output."""
from __future__ import annotations

import csv
import os
import time

import jax

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


def timed(fn, *args, repeat: int = 3, **kwargs):
    """Returns (result, us_per_call) — best of `repeat` wall times."""
    fn(*args, **kwargs)  # warmup/compile
    best = float("inf")
    result = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        result = fn(*args, **kwargs)
        result = jax.block_until_ready(result) if hasattr(result, "block_until_ready") \
            else jax.tree.map(lambda x: x.block_until_ready()
                              if hasattr(x, "block_until_ready") else x, result)
        best = min(best, time.perf_counter() - t0)
    return result, best * 1e6


def write_csv(name: str, header, rows):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path
