"""Kernel-level economics (paper section 5 processing-cost claim).

CPU timings are of the jnp reference path (this container has no TPU);
the derived column reports the structural quantities that transfer:
HBM write-bytes of fused coded projection vs project-then-code, packed
storage footprint, and collision-count throughput proxy.
"""
import jax
import jax.numpy as jnp

from repro.core.schemes import CodeSpec
from repro.kernels import ref
from repro.core import packing as PK
from benchmarks._util import timed, write_csv


def run(quick: bool = True):
    m, d, k = (2048, 4096, 256) if quick else (8192, 16384, 512)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, d), jnp.float32)
    r = jax.random.normal(jax.random.fold_in(key, 1), (d, k), jnp.float32)
    spec = CodeSpec("2bit", 0.75)

    fused = jax.jit(lambda x, r: ref.coded_project_ref(x, r, spec))
    _, us_f = timed(fused, x, r)
    unfused_proj = jax.jit(lambda x, r: x @ r)
    _, us_p = timed(unfused_proj, x, r)

    codes = fused(x, r)
    packf = jax.jit(lambda c: ref.pack_codes_ref(c, 2))
    packed, us_pack = timed(packf, codes)

    q = codes[:64]
    coll = jax.jit(ref.collision_counts_ref)
    _, us_coll = timed(coll, q, codes)

    # structural bytes (TPU model): fused writes int8-scale codes instead
    # of f32 projections
    write_f32 = m * k * 4
    write_codes = m * k * 1          # int8-scale epilogue write
    write_packed = m * PK.packed_width(k, 2) * 4
    rows = [
        ["coded_project_fused", us_f, write_codes],
        ["project_only", us_p, write_f32],
        ["pack_2bit", us_pack, write_packed],
        ["collision_64xM", us_coll, 64 * m * 4],
    ]
    write_csv("kernel_bench", ["kernel", "us_per_call", "hbm_write_bytes"], rows)
    return [("kernel_fused_project", us_f,
             f"writeback_bytes {write_f32}->{write_packed} "
             f"({write_f32/write_packed:.0f}x smaller)"),
            ("kernel_collision", us_coll, f"pairs={64*m}")]
