from repro.train.train_loop import (  # noqa: F401
    make_train_step, make_compressed_train_step, TrainState, Trainer,
    make_state_shardings,
)
