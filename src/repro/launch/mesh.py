"""Production mesh definition (functions only — importing this module
never touches jax device state)."""
from __future__ import annotations

import jax

__all__ = ["make_mesh_compat", "make_production_mesh", "make_dp_mesh",
           "dp_axes"]


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` across jax versions.

    jax >= 0.5 takes ``axis_types`` (and defaults axes to Auto); 0.4.x has
    neither ``jax.sharding.AxisType`` nor the kwarg, and its meshes are
    implicitly Auto — so requesting Auto everywhere is the portable
    behavior on both.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (data, model) per pod; 2x16x16 (pod, data, model) multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_dp_mesh(n_devices: int | None = None):
    """Pure data-parallel mesh (gradient-compression study / examples)."""
    n = n_devices or len(jax.devices())
    return make_mesh_compat((n,), ("data",))


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
