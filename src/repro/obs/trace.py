"""Nestable tracing spans with device-sync-correct timing.

The timing trap this module exists to close: jax dispatch is async, so
``t1 - t0`` around a device call measures *submission*, not execution —
exactly the bug that produced a negative (clamped-to-zero) re-rank
overhead in ``BENCH_rank.json``. A span therefore closes in one of two
explicitly-labelled states:

* **device-synced** — the code inside called ``sp.sync(value)`` (a
  ``jax.block_until_ready`` that returns its argument), so the span's
  duration covers the device work that produced ``value``;
* **async** — no sync happened before close (either ``sync=False`` was
  requested, or the caller simply never synced). The span is marked
  ``"sync": "async"`` in the trace.

That labelling is the sync-boundary invariant documented in
``docs/ARCHITECTURE.md``: a span that closes without a device sync is
*always* marked async — there is no state in which an unsynced duration
masquerades as an execution time.

Tracing is globally opt-in: ``with Tracer() as tr`` installs the tracer,
and while none is installed ``span(...)`` returns a shared no-op context
manager (near-zero cost — the hot path keeps its spans). Finished traces
export to Chrome-trace / Perfetto JSON (``Tracer.dump``): load the file
in ``chrome://tracing`` or https://ui.perfetto.dev to see a whole
ingest→search→compact run as a flame view.

Two tracer depths exist. A plain ``Tracer`` is **deep**: ``sp.sync``
really blocks, so durations are execution-true — the profiling mode of
``benchmarks/run.py --profile``. A ``RequestTrace`` (installed per
request by ``TailSampler``) is **shallow**: spans are recorded with
submission timings and ``sp.sync`` never blocks, so the always-on
request span chains add no device barriers to the serving pipeline.
Shallow spans are honestly labelled ``"sync": "async"`` — the
sync-boundary invariant is never weakened, only the *blocking* is
skipped. Code that must behave differently under real profiling (the
engines' device-synced chunk paths) checks ``deep_tracing_active()``,
not ``tracing_active()``.

``TailSampler`` implements the retain-on-tail policy: every request is
*recorded* (cheap shallow chain), but the full trace is *retained* only
when the request lands in the slowest-quantile tail of past requests,
raises, or is flagged by a quality monitor. Retention decisions use
only (a) past observations and (b) one seeded RNG, so a replayed
workload retains the same trace ids.
"""
from __future__ import annotations

import json
import threading
import time
from collections import OrderedDict

import jax
import numpy as np

from .registry import Histogram, HistogramSpec, default_registry

__all__ = ["Span", "Tracer", "RequestTrace", "TailSampler", "span",
           "tracing_active", "deep_tracing_active", "active_tracer",
           "no_tracing"]

_ACTIVE: "Tracer | None" = None


def tracing_active() -> bool:
    """Whether a tracer is currently installed (spans are recording)."""
    return _ACTIVE is not None


def deep_tracing_active() -> bool:
    """Whether a *deep* tracer is installed — one whose ``sp.sync``
    really blocks. Engines use this to pick their device-synced
    per-chunk paths; a shallow ``RequestTrace`` never triggers them."""
    return _ACTIVE is not None and _ACTIVE.deep


def active_tracer() -> "Tracer | None":
    """The installed tracer, or None."""
    return _ACTIVE


class Span:
    """One live span; use via ``with span("name") as sp``.

    Call ``sp.sync(value)`` on the device results produced inside the
    span — it blocks until they are ready (so the closing timestamp is
    execution-true) and returns them. Extra attributes land in the
    Chrome-trace ``args`` via ``sp.set(key=...)`` or the ``span(...)``
    kwargs.
    """

    __slots__ = ("tracer", "name", "args", "sync_wanted", "t0", "_synced")

    def __init__(self, tracer: "Tracer", name: str, sync_wanted: bool,
                 args: dict):
        self.tracer = tracer
        self.name = name
        self.args = args
        self.sync_wanted = sync_wanted
        self.t0 = 0.0
        self._synced = False

    def sync(self, value):
        """Block until ``value`` (any pytree of arrays) is ready; marks
        the span device-synced and returns ``value``. Under a shallow
        tracer (``RequestTrace``) this is a passthrough — no block, no
        synced mark — so always-on request tracing never serialises the
        pipeline; the span stays labelled async, which is the truth."""
        if self.tracer.deep:
            jax.block_until_ready(value)
            self._synced = True
        return value

    def set(self, **attrs):
        """Attach attributes to the span's trace ``args``."""
        self.args.update(attrs)

    def __enter__(self) -> "Span":
        self.tracer._push(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter()
        self.args["sync"] = "device" if self._synced else "async"
        if exc_type is not None:
            self.args["error"] = exc_type.__name__
        self.tracer._pop(self, t1)
        return False                      # never swallow exceptions


class _NullSpan:
    """Shared no-op span returned while no tracer is installed; its
    ``sync`` is a passthrough (no block), so disabled-mode tracing adds
    neither time nor device barriers."""

    __slots__ = ()

    def sync(self, value):
        """Passthrough: no block, no recording."""
        return value

    def set(self, **attrs):
        """No-op."""

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_SPAN = _NullSpan()


def span(name: str, sync: bool = True, **attrs):
    """Open a span on the installed tracer (no-op when none is active).

    ``sync=True`` declares the span *should* close device-synced — the
    body is expected to route its device results through ``sp.sync``;
    if it never does, the span is recorded but labelled async.
    ``sync=False`` declares an async span up front (e.g. enqueue-only
    work). Returns a context manager either way.
    """
    tr = _ACTIVE
    if tr is None:
        return _NULL_SPAN
    return Span(tr, name, sync, dict(attrs))


class _NoTracing:
    """Suspends the installed tracer for the duration of a block."""

    __slots__ = ("_prev",)

    def __enter__(self):
        global _ACTIVE
        self._prev = _ACTIVE
        _ACTIVE = None
        return self

    def __exit__(self, exc_type, exc, tb):
        global _ACTIVE
        _ACTIVE = self._prev
        return False


def no_tracing() -> _NoTracing:
    """Context manager suspending span recording inside its block —
    for sections too hot to trace, or for measuring the no-tracer span
    cost itself while a tracer happens to be installed."""
    return _NoTracing()


class Tracer:
    """Span collector + Chrome-trace exporter; ``with Tracer() as tr``
    installs it globally for the duration of the block.

    Spans nest per-thread (a stack keyed on thread id); nesting in the
    exported trace is carried by timestamp containment on one track,
    which is exactly how chrome://tracing / Perfetto build flames.
    """

    #: deep tracers make ``sp.sync`` really block (execution-true
    #: durations); ``RequestTrace`` overrides this to False per instance.
    deep = True

    def __init__(self):
        self.events: list[dict] = []      # finished spans, close order
        self._stacks: dict[int, list] = {}
        self._tids: dict[int, int] = {}
        self._t0 = time.perf_counter()
        self._prev = None

    # -- span bookkeeping (called by Span) -----------------------------------
    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            tid = self._tids[ident] = len(self._tids)
        return tid

    def _push(self, sp: Span):
        self._stacks.setdefault(threading.get_ident(), []).append(sp)

    def _pop(self, sp: Span, t1: float):
        stack = self._stacks[threading.get_ident()]
        # exception-safe: unwind past any inner spans abandoned by a raise
        while stack and stack[-1] is not sp:
            stack.pop()
        if stack:
            stack.pop()
        self.events.append({
            "name": sp.name, "ts": sp.t0 - self._t0,
            "dur": t1 - sp.t0, "tid": self._tid(), "depth": len(stack),
            "args": sp.args})

    def depth(self) -> int:
        """Current nesting depth on the calling thread."""
        return len(self._stacks.get(threading.get_ident(), ()))

    # -- install / uninstall -------------------------------------------------
    def __enter__(self) -> "Tracer":
        global _ACTIVE
        self._prev = _ACTIVE
        _ACTIVE = self
        return self

    def __exit__(self, exc_type, exc, tb):
        global _ACTIVE
        _ACTIVE = self._prev
        return False

    # -- queries -------------------------------------------------------------
    def durations(self, name: str) -> list:
        """Seconds of every finished span called ``name``."""
        return [e["dur"] for e in self.events if e["name"] == name]

    def total(self, name: str) -> float:
        """Summed seconds across every finished span called ``name``."""
        return sum(self.durations(name))

    # -- export --------------------------------------------------------------
    def to_chrome(self) -> dict:
        """Chrome-trace JSON object (``traceEvents`` complete events,
        timestamps in microseconds) — loadable by chrome://tracing and
        Perfetto."""
        events = [{
            "name": e["name"], "ph": "X", "pid": 0, "tid": e["tid"],
            "ts": round(e["ts"] * 1e6, 3),
            "dur": round(e["dur"] * 1e6, 3),
            "args": e["args"],
        } for e in self.events]
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def dump(self, path: str) -> str:
        """Write the Chrome-trace JSON to ``path``; returns ``path``."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


class RequestTrace(Tracer):
    """Lightweight per-request span chain — the always-on tracer.

    Shallow by default: spans record submission timings, ``sp.sync``
    never blocks, and every span's ``args`` carry the request's
    ``trace_id`` (the id exported as an exemplar link and stamped on
    flight-recorder events). When an *outer deep* tracer is already
    installed (``run.py --profile``), the request trace inherits
    ``deep=True`` and forwards its finished spans — rebased onto the
    outer clock — so profiling sees everything and loses nothing.
    """

    def __init__(self, trace_id: int, outer: "Tracer | None" = None):
        super().__init__()
        self.trace_id = trace_id
        self._outer = outer
        self.deep = outer.deep if outer is not None else False

    def _pop(self, sp: Span, t1: float):
        sp.args["trace_id"] = self.trace_id
        super()._pop(sp, t1)
        if self._outer is not None:
            e = dict(self.events[-1])
            e["ts"] += self._t0 - self._outer._t0
            self._outer.events.append(e)


class _Request:
    """Handle for one sampled request (yielded by ``TailSampler.request``).

    Inside the block a ``RequestTrace`` is installed, so every
    ``span(...)`` down the call stack joins this request's chain. Call
    ``set_key`` to choose the tail-ranking key (e.g. deadline-relative
    lateness; defaults to wall duration), ``flag(reason)`` to force
    retention (quality monitors do). After the block, ``retained`` /
    ``reason`` say what the sampler decided.
    """

    __slots__ = ("sampler", "op", "attrs", "trace", "trace_id", "key",
                 "_flags", "_t0", "retained", "reason")

    def __init__(self, sampler: "TailSampler", op: str, attrs: dict):
        self.sampler = sampler
        self.op = op
        self.attrs = attrs
        self.trace_id = sampler._next_id()
        self.key = None
        self._flags = []
        self.retained = False
        self.reason = ""

    def set_key(self, key: float):
        """Set the tail-ranking key (higher = more worth retaining)."""
        self.key = float(key)

    def flag(self, reason: str):
        """Force retention of this request's trace (e.g. a quality
        monitor fired mid-request)."""
        self._flags.append(str(reason))

    def __enter__(self) -> "_Request":
        outer = _ACTIVE
        self.trace = RequestTrace(
            self.trace_id, outer if outer is not None and outer.deep
            else None)
        self.trace.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        dur = time.perf_counter() - self._t0
        self.trace.__exit__(exc_type, exc, tb)
        self.sampler._finish(self, dur, exc_type)
        return False                      # never swallow exceptions


class _NullRequest:
    """Shared no-op request handle (disabled ``TailSampler``)."""

    __slots__ = ()
    trace_id = 0
    retained = False
    reason = ""

    def set_key(self, key):
        """No-op."""

    def flag(self, reason):
        """No-op."""

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_REQUEST = _NullRequest()


class TailSampler:
    """Tail-based trace retention: record everything, keep the tail.

    Every ``request(...)`` gets a shallow ``RequestTrace`` (cheap, no
    device barriers). On close, the trace is **retained** only when:

    * ``slow`` — its key lands above the ``quantile`` of all *past*
      request keys (a reservoir of the slowest tail; keys default to
      wall duration, the serving layer uses deadline-relative lateness);
    * ``error`` — the block raised;
    * ``flagged`` — something called ``handle.flag(...)`` (quality
      monitors wire their drift callbacks here);
    * ``sampled`` — a seeded coin (``sample_rate``) kept it as a
      baseline exemplar of normal traffic.

    Determinism: the slow threshold is computed from past observations
    *before* the new key is recorded, trace ids are a per-sampler
    monotone counter, and the coin is a seeded ``default_rng`` — a
    replayed workload makes identical retention decisions
    (``tests/test_flight.py`` pins this). Retained traces live in an
    LRU capped at ``max_retained``; ``flight.requests`` /
    ``flight.retained`` counters land in the registry.
    """

    def __init__(self, quantile: float = 0.95, max_retained: int = 32,
                 min_count: int = 20, sample_rate: float = 0.0,
                 seed: int = 0, registry=None, enabled: bool = True):
        if not 0.0 < quantile < 1.0:
            raise ValueError(f"quantile must be in (0,1), got {quantile}")
        self.enabled = enabled
        self.quantile = float(quantile)
        self.max_retained = int(max_retained)
        self.min_count = int(min_count)
        self.sample_rate = float(sample_rate)
        self._rng = np.random.default_rng(seed)
        # past request keys; keys can be negative (early vs deadline) —
        # those clamp into bucket 0, which only sharpens the tail.
        self._keys = Histogram("flight.request_key",
                               HistogramSpec(lo=1e-6, hi=1e4))
        self.retained: "OrderedDict[int, dict]" = OrderedDict()
        self._id = 0
        reg = registry if registry is not None else default_registry()
        self._c_requests = reg.counter("flight.requests")
        self._c_retained = reg.counter("flight.retained")

    def _next_id(self) -> int:
        self._id += 1
        return self._id

    def request(self, op: str, **attrs):
        """Open a sampled request block: ``with sampler.request("search")
        as rq:``. See ``_Request`` for the handle API. A sampler built
        with ``enabled=False`` returns a shared no-op handle (no
        request trace, no retention, no counters) — the off switch the
        flight-overhead bench measures against."""
        if not self.enabled:
            return _NULL_REQUEST
        return _Request(self, op, dict(attrs))

    def threshold(self) -> float:
        """Current slow-tail key threshold (inf during warmup)."""
        if self._keys.count < self.min_count:
            return float("inf")
        return self._keys.percentile(self.quantile)

    def _finish(self, rq: _Request, dur: float, exc_type):
        key = rq.key if rq.key is not None else dur
        if exc_type is not None:
            reason = "error"
            rq.attrs["error"] = exc_type.__name__
        elif rq._flags:
            reason = "flagged:" + ",".join(rq._flags)
        elif key >= self.threshold():
            reason = "slow"
        elif self.sample_rate > 0.0 and \
                self._rng.random() < self.sample_rate:
            reason = "sampled"
        else:
            reason = ""
        self._keys.observe(key)           # after the decision: past-only
        self._c_requests.inc()
        if reason:
            self._retain(rq, reason, key, dur)
        rq.retained = bool(reason)
        rq.reason = reason

    def _retain(self, rq: _Request, reason: str, key: float, dur: float):
        self.retained[rq.trace_id] = {
            "trace_id": rq.trace_id, "op": rq.op, "reason": reason,
            "key": key, "dur": dur, "attrs": rq.attrs,
            "events": rq.trace.events}
        self._c_retained.inc()
        while len(self.retained) > self.max_retained:
            self.retained.popitem(last=False)

    def retained_traces(self) -> list:
        """Retained trace records, oldest first — what an incident
        bundle captures and ``obs.export`` links exemplars against."""
        return list(self.retained.values())
