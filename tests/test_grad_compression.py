"""Coded-sketch gradient compression: decode fidelity, error-feedback
convergence, wire-bytes accounting (the paper's economy claim)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.gradient_compression import (GradCompressionConfig,
                                             GradCompressor, code_centroids)
from repro.core.schemes import CodeSpec


def _template():
    return {"w": jnp.zeros((300, 7)), "b": jnp.zeros((13,))}


def test_centroids_are_conditional_means():
    # 1-bit: E[z | z>0] = sqrt(2/pi)
    c = code_centroids(CodeSpec("sign", 1.0))
    assert abs(c[1] - np.sqrt(2 / np.pi)) < 1e-6
    assert abs(c[0] + np.sqrt(2 / np.pi)) < 1e-6
    c2 = code_centroids(CodeSpec("2bit", 0.75))
    assert c2[0] < -0.75 and -0.75 < c2[1] < 0 < c2[2] < 0.75 < c2[3]


def test_encode_decode_reduces_error_with_rate():
    tpl = _template()
    g = jax.tree.map(lambda x: jax.random.normal(jax.random.PRNGKey(0), x.shape),
                     tpl)
    errs = {}
    for rate in (2, 8):
        cfg = GradCompressionConfig(scheme="2bit", rate=rate, chunk=512)
        comp = GradCompressor(cfg, tpl)
        flat = comp._flatten(g)
        codes, scales = comp.encode(flat)
        g_hat = comp.decode(codes, scales)
        errs[rate] = float(jnp.linalg.norm(g_hat - flat) / jnp.linalg.norm(flat))
    assert errs[2] < errs[8] <= 1.05  # more sketch dims -> better recovery


def test_wire_bytes_accounting():
    cfg = GradCompressionConfig(scheme="2bit", rate=8, chunk=1024)
    comp = GradCompressor(cfg, _template())
    # 2-bit codes on chunk/8 dims -> ~ (2/8)/32 of fp32 payload + scales
    assert comp.wire_bytes() * 30 < comp.fp32_bytes()


def test_error_feedback_converges_least_squares():
    # min ||Ax - b||^2 by compressed-gradient descent with error feedback:
    # EF-SGD must converge despite the aggressive sketch+2bit compression.
    key = jax.random.PRNGKey(1)
    a = jax.random.normal(key, (64, 32)) / 8.0
    b = jax.random.normal(jax.random.fold_in(key, 1), (64,))
    x_star, *_ = jnp.linalg.lstsq(a, b)

    tpl = {"x": jnp.zeros((32,))}
    cfg = GradCompressionConfig(scheme="2bit", w=0.75, rate=4, chunk=32)
    comp = GradCompressor(cfg, tpl)
    x = {"x": jnp.zeros((32,))}
    ef = comp.init_ef(tpl)

    def grad_fn(x):
        return jax.grad(lambda p: jnp.sum((a @ p["x"] - b) ** 2))(x)

    losses = []
    for i in range(300):
        g = grad_fn(x)
        g_hat, ef = comp.sync_local(g, ef, step=i)
        x = jax.tree.map(lambda p, gg: p - 0.05 * gg, x, g_hat)
        losses.append(float(jnp.sum((a @ x["x"] - b) ** 2)))
    # the system is overdetermined: converge to the lstsq optimum, not 0
    opt_loss = float(jnp.sum((a @ x_star - b) ** 2))
    final_gap = float(jnp.linalg.norm(x["x"] - x_star))
    base = float(jnp.linalg.norm(x_star))
    assert losses[-1] < 1.05 * opt_loss + 1e-3, (losses[-1], opt_loss)
    assert final_gap < 0.15 * base, (final_gap, base)


def test_dithered_offset_scheme_less_biased():
    # For mean estimation the dithered h_{w,q} decodes with lower bias on a
    # fixed vector than the paper-preferred (for similarity) h_w at equal w.
    tpl = {"v": jnp.zeros((4096,))}
    v = jax.random.normal(jax.random.PRNGKey(2), (4096,))
    results = {}
    for scheme in ("uniform", "offset"):
        cfg = GradCompressionConfig(scheme=scheme, w=1.0, rate=1, chunk=512)
        comp = GradCompressor(cfg, tpl)
        flat = comp._flatten({"v": v})
        codes, scales = comp.encode(flat)
        g_hat = comp.decode(codes, scales)
        results[scheme] = float(jnp.linalg.norm(g_hat - flat)
                                / jnp.linalg.norm(flat))
    # both should reconstruct reasonably at rate=1
    assert results["offset"] < 1.0 and results["uniform"] < 1.0
