"""Quality monitors: empirical-vs-theory convergence, shadow recall
with Wilson coverage, reservoir invariants, drift detection, export."""
import math

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.estimators import cell_probs
from repro.core.probabilities import collision_prob
from repro.core.schemes import CodeSpec
from repro.core.sketch import CodedRandomProjection, SketchConfig
from repro.index import MutableAnnEngine
from repro.obs import (CollisionMonitor, Cusum, DriftMonitor,
                       MarginMonitor, MetricsRegistry, PageHinkley,
                       QualityConfig, QualityMonitors, RecallMonitor,
                       ShadowReservoir, Welford, synthetic_code_pairs,
                       to_prometheus, wilson_interval)
from repro.serve import AnnService, AnnServiceConfig

K = 64


def _reg():
    return MetricsRegistry(enabled=True)


# -- Welford ------------------------------------------------------------------

def test_welford_matches_numpy():
    rng = np.random.default_rng(0)
    xs = rng.standard_normal(500) * 3.0 + 1.5
    w = Welford()
    w.push_many(xs)
    assert w.n == 500
    np.testing.assert_allclose(w.mean, xs.mean(), rtol=1e-12)
    np.testing.assert_allclose(w.var, xs.var(ddof=1), rtol=1e-10)


# -- collision monitor: convergence to theory at known rho --------------------

@pytest.mark.parametrize("scheme,w", [("sign", 1.0), ("2bit", 0.75),
                                      ("uniform", 0.75), ("offset", 1.5)])
@pytest.mark.parametrize("rho", [0.25, 0.7])
def test_cell_frequencies_converge_to_theory(scheme, w, rho):
    """The empirical cell-frequency monitor converges to
    ``core.estimators.cell_probs`` (diagonal ``collision_prob`` for the
    offset scheme) at a known synthetic rho, and its MLE recovers it."""
    spec = CodeSpec(scheme, w)
    q = np.full(K, w / 3, np.float32) if scheme == "offset" else None
    a, b = synthetic_code_pairs(spec, K, rho, 2000, seed=3, q=q)
    mon = CollisionMonitor(spec, K, registry=_reg(), min_pairs=100)
    mon.observe_pairs(a, b)
    rep = mon.report()
    assert abs(rep["rho_hat"] - rho) < 0.02
    if scheme == "offset":
        # per-projection regions: diagonal-only audit against P(rho)
        assert mon.diag_only
        p = float(collision_prob(jnp.asarray(rho), w, scheme))
        assert abs(rep["p_hat"] - p) < 0.02
    else:
        want = np.asarray(cell_probs(jnp.asarray(rho), spec),
                          np.float64).ravel()
        np.testing.assert_allclose(rep["cell_freq"], want, atol=0.02)
        # diagonal sums to the collision probability curve
        p = float(collision_prob(jnp.asarray(rho), w, scheme))
        assert abs(rep["p_hat"] - p) < 0.02
    # pooled fit at the true rho: the divergence stays at noise level
    assert rep["chi2_per_cell"] < 5.0
    # per-pair collision-fraction spread tracks the binomial prediction
    assert abs(rep["phat_std"] - rep["phat_std_theory"]) \
        < 0.5 * rep["phat_std_theory"]


def test_collision_monitor_batch_stats_and_reset():
    spec = CodeSpec("2bit", 0.75)
    mon = CollisionMonitor(spec, K, registry=_reg())
    a, b = synthetic_code_pairs(spec, K, 0.6, 300, seed=5)
    st = mon.observe_pairs(a, b)
    assert abs(st["rho_batch"] - 0.6) < 0.05
    assert 0.0 < st["p_batch"] < 1.0
    assert mon.pairs == 300
    mon.reset()
    assert mon.pairs == 0 and mon.counts.sum() == 0
    assert math.isnan(mon.report()["rho_hat"])


# -- wilson interval ----------------------------------------------------------

def test_wilson_interval_basics():
    lo, hi = wilson_interval(0, 0)
    assert math.isnan(lo) and math.isnan(hi)
    lo, hi = wilson_interval(10, 10)
    assert hi == 1.0 and 0.6 < lo < 1.0      # no Wald collapse at p=1
    lo, hi = wilson_interval(50, 100)
    assert lo < 0.5 < hi and (hi - lo) < 0.25


def test_wilson_interval_coverage():
    """95% Wilson intervals bracket the true Bernoulli rate ~95% of the
    time (seeded; binomial draws, 300 replications, n=60)."""
    rng = np.random.default_rng(7)
    for p in (0.1, 0.5, 0.9):
        cover = 0
        for _ in range(300):
            s = rng.binomial(60, p)
            lo, hi = wilson_interval(int(s), 60)
            cover += lo <= p <= hi
        assert cover >= 0.90 * 300, (p, cover)


# -- shadow reservoir invariants ----------------------------------------------

def test_reservoir_cap_upsert_and_tombstones():
    res = ShadowReservoir(cap=32, seed=0, registry=_reg())
    rng = np.random.default_rng(1)
    rows = rng.standard_normal((200, 8)).astype(np.float32)
    res.offer(np.arange(200), rows)
    assert len(res) == 32 and res.n_seen == 200
    assert set(res.ids()) <= set(range(200))
    # upsert: same id replaces in place, no slot churn
    v0 = res.version
    target = int(res.ids()[0])
    res.offer([target], np.full((1, 8), 9.0, np.float32))
    assert len(res) == 32 and res.version > v0
    slot = list(res.ids()).index(target)
    np.testing.assert_array_equal(res.rows()[slot], np.full(8, 9.0))
    # tombstones: removed ids can never appear again
    kill = res.ids()[:10]
    res.remove(kill)
    assert len(res) == 22
    assert not (set(kill) & set(res.ids()))
    res.remove([10 ** 9])                     # unknown id: no-op
    assert len(res) == 22


def test_reservoir_is_roughly_uniform():
    """Algorithm R: early and late offers are retained at similar
    rates (chi-square over thirds of the stream, seeded)."""
    counts = np.zeros(3)
    for seed in range(30):
        res = ShadowReservoir(cap=30, seed=seed, registry=_reg())
        res.offer(np.arange(300), np.zeros((300, 4), np.float32))
        ids = res.ids()
        for third in range(3):
            counts[third] += np.sum((ids >= third * 100)
                                    & (ids < (third + 1) * 100))
    frac = counts / counts.sum()
    assert np.all(np.abs(frac - 1 / 3) < 0.08), frac


# -- shadow recall vs exact ground truth --------------------------------------

def _shadow_setup(n=400, d=24, seed=2):
    rng = np.random.default_rng(seed)
    # unit-norm rows: the quantizer's cell widths assume unit-variance
    # projections, and the rho audit is only calibrated on the sphere
    x = rng.standard_normal((n, d)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    crp = CodedRandomProjection(SketchConfig(k=256, scheme="2bit", w=0.75),
                                d)
    res = ShadowReservoir(cap=n, seed=0, registry=_reg())
    res.offer(np.arange(n), x)
    return x, crp, res, rng


def test_shadow_recall_brackets_exhaustive_truth():
    """The sampled shadow estimate's Wilson 95% interval brackets the
    exhaustively-measured recall of the same protocol (reservoir = the
    whole corpus, so the protocol's ground truth is exact)."""
    x, crp, res, rng = _shadow_setup()
    mon = RecallMonitor(res, top_k=10, registry=_reg())
    queries = x[:80] + 0.3 / np.sqrt(24) * rng.standard_normal(
        (80, 24)).astype(np.float32)
    queries /= np.linalg.norm(queries, axis=1, keepdims=True)
    # exhaustive truth: same protocol, every query, computed directly
    codes = np.asarray(crp.encode(jnp.asarray(x)), np.int32)
    hits_all = 0
    for qv in queries:
        qc = np.asarray(crp.encode(jnp.asarray(qv[None, :])), np.int32)[0]
        qn = qv / np.linalg.norm(qv)
        cos = (x @ qn) / np.maximum(np.linalg.norm(x, axis=1), 1e-30)
        gt = np.argsort(-cos, kind="stable")[:10]
        frac = np.mean(codes == qc[None, :], axis=1)
        got = np.argsort(-frac, kind="stable")[:10]
        hits_all += len(set(gt.tolist()) & set(got.tolist()))
    truth = hits_all / (10 * len(queries))
    # sampled estimate: a random half of the queries through the monitor
    for qi in rng.choice(len(queries), size=40, replace=False):
        r = mon.observe_query(queries[qi], crp.encode, crp._estimator)
        assert r is not None
    rep = mon.report()
    assert rep["trials"] == 400
    assert rep["recall_lo"] <= truth <= rep["recall_hi"], (rep, truth)
    # 2-bit codes at k=256 rank 400 gaussian rows decently
    assert rep["recall"] > 0.3


def test_shadow_rho_error_tracks_asymptotic_std():
    """rho_hat - rho_true over the ground-truth pairs: near-zero mean,
    spread within a small factor of the estimator's asymptotic std."""
    x, crp, res, rng = _shadow_setup(seed=4)
    mon = RecallMonitor(res, top_k=10, registry=_reg())
    queries = x[:30] + 0.2 / np.sqrt(24) * rng.standard_normal(
        (30, 24)).astype(np.float32)
    queries /= np.linalg.norm(queries, axis=1, keepdims=True)
    for qv in queries:
        mon.observe_query(qv, crp.encode, crp._estimator)
    rep = mon.report()
    assert abs(rep["rho_err_mean"]) < 0.1
    assert rep["rho_err_std"] < 3.0 * rep["rho_std_theory"]


def test_shadow_skips_tiny_reservoir():
    res = ShadowReservoir(cap=8, seed=0, registry=_reg())
    res.offer(np.arange(8), np.zeros((8, 4), np.float32))
    crp = CodedRandomProjection(SketchConfig(k=K, scheme="2bit", w=0.75), 4)
    mon = RecallMonitor(res, top_k=10, registry=_reg())
    assert mon.observe_query(np.ones(4, np.float32), crp.encode,
                             crp._estimator) is None


# -- drift detectors ----------------------------------------------------------

def test_page_hinkley_fires_on_shift_and_stays_silent_stationary():
    """Page-Hinkley is silent over a long stationary stream and fires
    within a bounded number of batches after an injected mean shift."""
    rng = np.random.default_rng(11)
    ph = PageHinkley(delta=0.005, threshold=0.5, min_samples=10)
    for _ in range(800):
        assert not ph.update(0.5 + 0.01 * rng.standard_normal())
    assert ph.alarms == 0
    fired_at = None
    for i in range(200):
        if ph.update(0.56 + 0.01 * rng.standard_normal()):
            fired_at = i
            break
    assert fired_at is not None and fired_at < 100, fired_at
    # reset-on-fire: stat re-armed
    assert ph.stat <= 0.5 and ph.n <= 1


def test_page_hinkley_two_sided_catches_drops():
    ph = PageHinkley(delta=0.0, threshold=0.3, min_samples=5)
    fired = any(ph.update(1.0 - 0.05 * i) for i in range(40))
    assert fired


def test_cusum_warmup_baseline_and_fire():
    c = Cusum(slack=0.01, threshold=0.3, warmup=20)
    for _ in range(20):
        c.update(1.0)
    assert abs(c.mu0 - 1.0) < 1e-9
    assert not any(c.update(1.0) for _ in range(50))
    assert any(c.update(1.1) for _ in range(10))
    assert c.alarms == 1


def test_drift_monitor_gauges_and_callbacks():
    reg = _reg()
    dm = DriftMonitor(registry=reg)
    dm.watch("s", PageHinkley(delta=0.0, threshold=0.2, min_samples=3))
    events = []
    dm.subscribe(lambda series, value, det: events.append((series, value)))
    fired = False
    for i in range(50):
        fired = dm.update("s", float(i)) or fired
    assert fired and events and events[0][0] == "s"
    snap = reg.snapshot()
    assert snap["gauges"]["drift.s.stat"] >= 0.0
    assert snap["counters"]["drift.s.alarms"] >= 1
    assert dm.alarms("s") >= 1
    # NaN observations are ignored, not counted
    n0 = dm.detector("s").n
    assert not dm.update("s", float("nan"))
    assert dm.detector("s").n == n0


def test_drift_detection_survives_disabled_registry():
    dm = DriftMonitor(registry=MetricsRegistry(enabled=False))
    dm.watch("s", PageHinkley(delta=0.0, threshold=0.1, min_samples=2))
    hits = []
    dm.subscribe(lambda *a: hits.append(a))
    for i in range(20):
        dm.update("s", float(i))
    assert hits    # callbacks fire even with metrics off


# -- the bundle + serving integration -----------------------------------------

def _service(sample_rate=1.0, n=300, d=16, seed=0, enabled=True):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    crp = CodedRandomProjection(SketchConfig(k=K, scheme="2bit", w=0.75), d)
    eng = MutableAnnEngine(crp, tail_rows=128)
    reg = MetricsRegistry(enabled=enabled)
    svc = AnnService(eng, AnnServiceConfig(top_k=5, cache_size=0,
                                           buckets=(8, 32)),
                     registry=reg,
                     quality=QualityConfig(sample_rate=sample_rate,
                                           reservoir_rows=256,
                                           min_pairs=32))
    ids = svc.bulk_load(x)
    return svc, eng, x, ids, rng


def test_service_quality_end_to_end():
    svc, eng, x, ids, rng = _service()
    assert len(svc.quality.reservoir) == 256
    for i in range(40):
        svc.submit(x[i] + 0.1 * rng.standard_normal(16).astype(np.float32))
    svc.flush()
    qm = svc.quality
    assert qm.collision.pairs > 0        # engine search hook fed pairs
    assert qm.recall.queries > 0         # serving shadow hook fired
    rep = qm.report()
    assert 0.0 <= rep["shadow"]["recall"] <= 1.0
    assert np.isfinite(rep["collision"]["rho_hat"])
    # deletes keep the reservoir tombstone-aware through the store event
    kill = [int(i) for i in ids if int(i) in set(qm.reservoir.ids())][:20]
    svc.delete(kill)
    assert not (set(kill) & set(qm.reservoir.ids()))
    # gauges surface through the registry and the Prometheus endpoint
    txt = to_prometheus(svc.registry)
    assert "quality_shadow_recall" in txt
    assert "# HELP" in txt


def test_quality_disabled_registry_is_noop():
    svc, eng, x, ids, rng = _service(enabled=False)
    assert not svc.quality.sample()
    for i in range(10):
        svc.submit(x[i])
    svc.flush()
    assert svc.quality.collision.pairs == 0
    assert svc.quality.recall.queries == 0
    assert len(svc.quality.reservoir) == 0   # ingest hook no-ops too


def test_quality_zero_rate_never_samples():
    svc, eng, x, ids, rng = _service(sample_rate=0.0)
    for i in range(10):
        svc.submit(x[i])
    svc.flush()
    assert svc.quality.collision.pairs == 0


def test_margin_monitor_binary_and_ovr():
    reg = _reg()
    mm = MarginMonitor(registry=reg)
    m1 = mm.observe(np.array([[1.0, -2.0, 3.0]]))
    np.testing.assert_allclose(m1, (1.0 - 2.0 + 3.0) / 3)
    mm2 = MarginMonitor(registry=reg, name="q.m2")
    ovr = np.array([[3.0, 0.0], [1.0, -1.0], [0.0, 2.0]])
    np.testing.assert_allclose(mm2.observe(ovr), ((3 - 1) + (2 - 0)) / 2)


def test_trainer_feeds_margin_monitor():
    from repro.learn import LearnConfig, fit_log
    svc, eng, x, ids, rng = _service()
    labels = {int(i): (1 if j % 2 else -1) for j, i in enumerate(ids)}
    model = fit_log(eng.store, labels, eng.sketcher.spec,
                    LearnConfig(steps=3), quality=svc.quality)
    assert svc.quality.margins.moments.n > 0
    svc.set_classifier(model)
    svc.classify(x[:8])                  # classify hook (rate=1.0)
    assert svc.quality.margins.moments.n > 0


def test_on_drift_subscription_contract():
    crp = CodedRandomProjection(SketchConfig(k=K, scheme="2bit", w=0.75),
                                8)
    qm = QualityMonitors(crp, QualityConfig(), registry=_reg())
    got = []
    assert qm.on_drift(lambda s, v, d: got.append(s)) is qm
    det = qm.drift.watch("margin_mean",
                         PageHinkley(delta=0.0, threshold=0.1,
                                     min_samples=2))
    for i in range(20):
        qm.drift.update("margin_mean", float(i))
    assert "margin_mean" in got


# -- prometheus export (satellite: complete, monotone bucket series) ----------

def test_prometheus_emits_every_finite_bucket():
    reg = _reg()
    h = reg.histogram("t.lat")
    h.observe(1e-5)
    h.observe(0.5)
    txt = to_prometheus(reg)
    lines = [l for l in txt.splitlines() if l.startswith("t_lat_bucket")]
    assert len(lines) == h.spec.n_buckets + 1       # every bound + +Inf
    cum = [int(l.rsplit(" ", 1)[1]) for l in lines]
    assert cum == sorted(cum) and cum[-1] == 2      # cumulative, monotone
    les = [l.split('le="')[1].split('"')[0] for l in lines]
    assert les[-1] == "+Inf" and len(set(les)) == len(les)
    assert f"# HELP t_lat histogram 't.lat'" in txt
