"""Per-band lookup tables: packed codes -> calibrated similarity scores.

The ANN engines rank candidates by raw collision counts — the diagonal
of the code contingency table. The paper's 2-bit scheme carries more:
an adjacent-region disagreement ((1,2): both values near zero) is weak
evidence *against* similarity, an extreme-region disagreement ((0,3):
opposite tails) is strong evidence. The non-linear estimators of
1602.06577 exploit exactly this, and product-quantization-style
asymmetric distance tables make the exploit cheap: precompute, per
query, one float per (code position, corpus code value), and scoring a
corpus row is a pure table-lookup accumulation — the shape the fused
Pallas kernel (``kernels.packed_lut``) wants.

Construction (``build_rank_tables``):

* pair scores ``S[a, b] = log p_ab(rho_ref) - log p_ab(0)`` — the
  per-code log-likelihood ratio of "correlated at rho_ref" vs
  "independent", from the scheme's contingency-cell model
  (``core.estimators.cell_probs``). Summed over the k code positions
  this is the Neyman–Pearson optimal statistic for detecting similarity
  at rho_ref, and a monotone-likelihood-ratio family makes the ranking
  consistent across the whole rho range.
* calibration: the expected total score g(rho) = k * sum_ab p_ab(rho)
  S[a, b] is tabulated on a dense rho grid and inverted by monotone
  interpolation — ``rho_from_scores`` maps raw LUT scores to calibrated
  rho_hat exactly the way ``CollisionEstimator`` inverts P(rho).

Layout: scoring tables are *asymmetric* (query-side specialized).
``query_tables`` gathers S rows by the query's own codes into a flat
float table [Q, F*P] with F = n_words * codes_per_word field slots and
P = 2**bits entries per slot; padded field slots (k not a multiple of
32/bits) hold zeros, so padding contributes nothing. Tables quantize to
bf16 (``quantize``) at half the VMEM footprint; kernels accumulate in
float32 either way.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import packing as _packing
from repro.core.estimators import cell_probs
from repro.core.schemes import CodeSpec

__all__ = ["RankTables", "build_rank_tables"]


@dataclass(frozen=True)
class RankTables:
    """Immutable LUT bundle for one (scheme, k) search setup.

    pair: float32 [P, P] per-code-pair scores (P = 2**bits), code pairs
    beyond n_codes zero. rho_grid/score_grid: float32 [G] calibration
    table, score_grid strictly increasing (monotone-enforced).
    """
    spec: CodeSpec
    k: int
    pair: jax.Array                 # f32 [P, P]
    rho_grid: jax.Array             # f32 [G]
    score_grid: jax.Array           # f32 [G], strictly increasing
    dtype: jnp.dtype = jnp.float32  # storage dtype of query tables

    @property
    def bits(self) -> int:
        """Packed field width of the scheme (bits per code)."""
        return self.spec.bits

    @property
    def n_entries(self) -> int:
        """Entries per field slot in the flat query table (2**bits)."""
        return 1 << self.spec.bits

    @property
    def n_fields(self) -> int:
        """Field slots per row: n_words * codes_per_word (>= k)."""
        return (_packing.packed_width(self.k, self.bits)
                * _packing.codes_per_word(self.bits))

    def query_tables(self, q_codes, dtype=None):
        """Specialize the pair table to queries.

        q_codes: int32 [Q, k] -> ``self.dtype`` [Q, F*P] with
        F = ``n_fields``, P = ``n_entries``: entry [i, (w*cpw + f)*P + c]
        scores corpus code value c at code position w*cpw + f of query
        i. Padded positions (>= k) are zero. Jittable (pure gather).
        ``dtype`` overrides the bundle's storage dtype for this call.
        """
        p = self.n_entries
        t = jnp.take(self.pair, q_codes, axis=0)        # [Q, k, P]
        pad = self.n_fields - self.k
        if pad:
            t = jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        return t.reshape(t.shape[0], self.n_fields * p).astype(
            dtype if dtype is not None else self.dtype)

    def query_tables_int8(self, q_codes):
        """int8 query tables with per-(query, word) scales for the fused
        scored kernel: -> (tables int8 [Q, F*P], scales f32 [Q, W]).

        Each packed word's cpw*P table entries share one scale —
        2**ceil(log2(max_abs / 127)), i.e. the smallest *power of two*
        that fits the word's largest entry into int8. Power-of-two
        scales keep ``score += scale * int_sum`` exact in float32 (no
        rounding in the multiply), which is what makes the int8 kernel
        path bit-reproducible against ``ref.lut_scores_rowwise_int8_
        ref`` regardless of FMA contraction; all-zero words get scale
        1.0. Quantization error is at most 2x the optimal int8 step —
        the recall cost is measured in ``benchmarks/rank_bench.py``.
        """
        t32 = self.query_tables(q_codes, dtype=jnp.float32)  # [Q, F*P]
        q = t32.shape[0]
        cpw = _packing.codes_per_word(self.bits)
        n_words = self.n_fields // cpw
        per_word = t32.reshape(q, n_words, cpw * self.n_entries)
        max_abs = jnp.max(jnp.abs(per_word), axis=-1)        # [Q, W]
        scale = jnp.exp2(jnp.ceil(jnp.log2(
            jnp.maximum(max_abs, 1e-30) / 127.0)))
        scale = jnp.where(max_abs > 0, scale, 1.0).astype(jnp.float32)
        qt = per_word / scale[:, :, None]
        qt = jnp.clip(jnp.round(qt), -127, 127).astype(jnp.int8)
        return qt.reshape(q, self.n_fields * self.n_entries), scale

    def rho_from_scores(self, scores):
        """Calibrate raw LUT scores [...] (float) to rho_hat [...] by
        monotone inversion of the expected-score curve on the rho grid
        (out-of-range scores clamp to the grid ends)."""
        return jnp.interp(jnp.asarray(scores, jnp.float32),
                          self.score_grid, self.rho_grid)

    def quantize(self, dtype=jnp.bfloat16) -> "RankTables":
        """Same tables with query-table storage dtype ``dtype`` (the
        calibration grid stays float32; kernels accumulate float32)."""
        return replace(self, dtype=jnp.dtype(dtype))


def build_rank_tables(spec, k: int = None, *, rho_ref: float = 0.9,
                      grid_size: int = 512, rho_max: float = 0.99995,
                      floor: float = 1e-12,
                      dtype=jnp.float32) -> RankTables:
    """Build LUT scoring + calibration tables for one (scheme, k).

    spec: a ``CodeSpec`` (then ``k`` is required) or a
    ``CodedRandomProjection`` (spec and k taken from it). rho_ref is the
    similarity the log-likelihood-ratio scores are tuned to detect (the
    near-neighbor regime by default); ``floor`` clips cell probabilities
    before the log so impossible cells stay finite. Supports the
    'sign', '2bit' and 'uniform' schemes (the 'offset' scheme has
    per-projection regions — ``cell_probs`` raises).
    """
    if k is None:
        if isinstance(spec, CodeSpec):
            raise TypeError("k is required when passing a bare CodeSpec "
                            "(or pass a CodedRandomProjection)")
        sk = spec
        spec, k = sk.spec, sk.cfg.k
    if not isinstance(spec, CodeSpec):
        raise TypeError(f"spec must be CodeSpec or sketcher, got {spec!r}")
    n = spec.n_codes
    p_entries = 1 << spec.bits

    rho = np.linspace(0.0, rho_max, grid_size)
    probs = np.asarray(cell_probs(jnp.asarray(rho), spec), np.float64)
    probs = np.maximum(probs, floor)                     # [G, n, n]
    p_ref = np.maximum(
        np.asarray(cell_probs(jnp.asarray(rho_ref), spec), np.float64),
        floor)
    p_null = probs[0]                                    # rho=0: p_a * p_b
    pair = np.log(p_ref) - np.log(p_null)                # [n, n] LLR

    # expected total score per rho; monotone-enforce for inversion
    g = k * np.einsum("gab,ab->g", probs, pair)
    g = np.maximum.accumulate(g) + 1e-9 * np.arange(grid_size)

    full = np.zeros((p_entries, p_entries), np.float32)
    full[:n, :n] = pair.astype(np.float32)
    return RankTables(spec=spec, k=k,
                      pair=jnp.asarray(full),
                      rho_grid=jnp.asarray(rho, jnp.float32),
                      score_grid=jnp.asarray(g, jnp.float32),
                      dtype=jnp.dtype(dtype))
