"""Attention: GQA + RoPE + sliding-window + softcap, memory-bounded.

Three execution paths, all pure jnp (so the dry-run's cost analysis sees
real FLOPs; a Pallas flash kernel would hide them from cost_analysis):

* ``flash``  — blockwise online-softmax scan over KV chunks for full
  causal attention. O(S·chunk) live memory instead of O(S^2).
* ``banded`` — sliding-window layers attend over a fixed-width KV band
  gathered per query chunk: FLOPs O(S·(window+chunk)), not O(S^2).
* ``decode`` — single-position query against a (possibly ring-buffered)
  KV cache.

GQA is expressed by reshaping queries to [B, S, KV, G, D] so the HLO
never materializes repeated KV heads.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.nn import ParamSpec, rms_norm
from repro.models import unroll as U

__all__ = ["AttnConfig", "attn_param_specs", "apply_rope", "attention",
           "init_kv_cache", "flash_attention", "banded_attention"]

_NEG_INF = -2.0e38


@dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    window: Optional[int] = None        # sliding window (None = global)
    attn_softcap: Optional[float] = None
    qk_norm: bool = False
    qkv_bias: bool = False
    query_scale: Optional[float] = None  # default head_dim**-0.5
    norm_eps: float = 1e-6
    chunk_kv: int = 1024                # flash KV chunk
    chunk_q: int = 512                  # banded query chunk
    probs_bf16: bool = False            # PV matmul in bf16 (memory diet)
    dtype: str = "bfloat16"

    @property
    def groups(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def scale(self) -> float:
        return self.query_scale if self.query_scale is not None else self.head_dim ** -0.5


def attn_param_specs(c: AttnConfig) -> dict:
    d, h, k, hd = c.d_model, c.n_heads, c.n_kv_heads, c.head_dim
    specs = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim"), c.dtype),
        "wk": ParamSpec((d, k, hd), ("embed", "kv_heads", "head_dim"), c.dtype),
        "wv": ParamSpec((d, k, hd), ("embed", "kv_heads", "head_dim"), c.dtype),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed"), c.dtype),
    }
    if c.qkv_bias:
        specs["bq"] = ParamSpec((h, hd), ("heads", "head_dim"), c.dtype, init="zeros")
        specs["bk"] = ParamSpec((k, hd), ("kv_heads", "head_dim"), c.dtype, init="zeros")
        specs["bv"] = ParamSpec((k, hd), ("kv_heads", "head_dim"), c.dtype, init="zeros")
    if c.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), ("head_dim",), c.dtype, init="ones")
        specs["k_norm"] = ParamSpec((hd,), ("head_dim",), c.dtype, init="ones")
    return specs


def apply_rope(x, positions, theta: float):
    """x [..., S, H, D] with positions [S] (or [B, S] broadcast)."""
    d = x.shape[-1]
    half = d // 2
    freq = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # [S, half]
    # broadcast over head axis: [..., S, 1, half]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * cos - xf2 * sin,
                            xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


def _softcap(s, cap: Optional[float]):
    if cap is None:
        return s
    return cap * jnp.tanh(s / cap)


def _project_qkv(params, x, c: AttnConfig, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if c.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if c.qk_norm:
        q = rms_norm(q, params["q_norm"], c.norm_eps)
        k = rms_norm(k, params["k_norm"], c.norm_eps)
    q = apply_rope(q, positions, c.rope_theta)
    k = apply_rope(k, positions, c.rope_theta)
    return q, k, v


def flash_attention(q, k, v, c: AttnConfig, q_positions, kv_positions):
    """Blockwise causal attention. q [B,S,H,D]; k/v [B,T,KV,D]."""
    b, s, h, d = q.shape
    t = k.shape[1]
    kv = c.n_kv_heads
    g = c.groups
    ck = min(c.chunk_kv, t)
    pad = (-t) % ck
    if pad:  # padded KV positions get -1e9 -> masked out everywhere
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=-10 ** 9)
        t += pad
    nck = t // ck
    qg = q.reshape(b, s, kv, g, d).astype(jnp.float32) * c.scale
    kc = jnp.moveaxis(k.reshape(b, nck, ck, kv, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nck, ck, kv, d), 1, 0)
    pc = kv_positions.reshape(nck, ck)

    m0 = jnp.full((b, kv, g, s), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g, s), jnp.float32)
    a0 = jnp.zeros((b, kv, g, s, d), jnp.float32)

    @jax.checkpoint  # recompute per-chunk probs in backward: without this
    def step(carry, xs):  # scan-of-grad stacks [nck,B,KV,G,S,ck] f32 probs
        m, l, acc = carry
        kb, vb, pb = xs
        sc = jnp.einsum("bskgd,btkd->bkgst", qg, kb.astype(jnp.float32))
        sc = _softcap(sc, c.attn_softcap)
        mask = q_positions[:, None] >= pb[None, :]
        if c.window is not None:
            mask &= (q_positions[:, None] - pb[None, :]) < c.window
        sc = jnp.where(mask[None, None, None], sc, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = p.astype(jnp.bfloat16) if c.probs_bf16 else p
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", pv, vb.astype(pv.dtype)).astype(jnp.float32)
        return (m_new, l, acc), None

    (m, l, acc), _ = U.scan(step, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(b, s, h, d)
    return out.astype(q.dtype)


def banded_attention(q, k, v, c: AttnConfig, positions):
    """Sliding-window attention with O(S*(window+chunk)) FLOPs.

    Pads KV left by `window` (rounded to chunk) and, per query chunk i,
    attends to the fixed-width slab covering [i*cq - window, i*cq + cq).
    """
    b, s, h, d = q.shape
    kv, g = c.n_kv_heads, c.groups
    win = c.window
    cq = min(c.chunk_q, s)
    s_orig = s
    qpad = (-s) % cq
    if qpad:  # padded queries are garbage rows, sliced off at the end
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
        positions = jnp.pad(positions, (0, qpad))
        s += qpad
    nq = s // cq
    pad = win  # left pad; right pad matches any query padding
    kp = jnp.pad(k, ((0, 0), (pad, qpad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (pad, qpad), (0, 0), (0, 0)))
    pos_p = jnp.pad(positions[:s - qpad] if qpad else positions, (pad, qpad),
                    constant_values=-10 ** 9)
    width = win + cq
    qg = q.reshape(b, nq, cq, kv, g, d).astype(jnp.float32) * c.scale
    qpos = positions.reshape(nq, cq)

    @jax.checkpoint  # see flash_attention: keep per-chunk probs transient
    def one_chunk(i):
        start = i * cq  # in padded coords this covers [i*cq - win, i*cq + cq)
        kb = jax.lax.dynamic_slice_in_dim(kp, start, width, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(vp, start, width, axis=1)
        pb = jax.lax.dynamic_slice_in_dim(pos_p, start, width, axis=0)
        qb = qg[:, i]
        pq = qpos[i]
        sc = jnp.einsum("bskgd,btkd->bkgst", qb, kb.astype(jnp.float32))
        sc = _softcap(sc, c.attn_softcap)
        mask = (pq[:, None] >= pb[None, :]) & ((pq[:, None] - pb[None, :]) < win)
        sc = jnp.where(mask[None, None, None], sc, _NEG_INF)
        p = jax.nn.softmax(sc, axis=-1)
        pv = p.astype(jnp.bfloat16) if c.probs_bf16 else p
        ob = jnp.einsum("bkgst,btkd->bskgd", pv, vb.astype(pv.dtype))
        return ob  # [b, cq, kv, g, d]

    out = U.map_(one_chunk, jnp.arange(nq))            # [nq, b, cq, kv, g, d]
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, h, d)[:, :s_orig]
    return out.astype(q.dtype)


def init_kv_cache(batch: int, length: int, c: AttnConfig, rules=None):
    """KV cache [B, L, KV, D]; local layers pass length=window (ring)."""
    shape = (batch, length, c.n_kv_heads, c.head_dim)
    k = jnp.zeros(shape, jnp.dtype(c.dtype))
    v = jnp.zeros(shape, jnp.dtype(c.dtype))
    if rules is not None:
        k = rules.shard(k, "batch", "seq_kv", "kv_heads", "head_dim")
        v = rules.shard(v, "batch", "seq_kv", "kv_heads", "head_dim")
    return {"k": k, "v": v}


def _cache_write(cache, k_new, v_new, pos, ring: Optional[int]):
    """Insert [B, S_new, KV, D] at position pos (scalar). Ring-buffer if
    ``ring`` is the cache length for a windowed layer."""
    length = cache["k"].shape[1]
    idx = pos % ring if ring else pos
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, idx, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, idx, axis=1)
    del length
    return {"k": k, "v": v}


def decode_attention(q, cache, c: AttnConfig, pos, ring: Optional[int]):
    """q [B,1,H,D] against cache [B,L,KV,D]; pos = current position."""
    b, _, h, d = q.shape
    kv, g = c.n_kv_heads, c.groups
    length = cache["k"].shape[1]
    qg = q.reshape(b, 1, kv, g, d).astype(jnp.float32) * c.scale
    sc = jnp.einsum("bskgd,btkd->bkgst", qg, cache["k"].astype(jnp.float32))
    sc = _softcap(sc, c.attn_softcap)
    slots = jnp.arange(length)
    if ring:
        # slot holds absolute position p iff p = pos - ((idx_now - slot) mod ring)
        idx_now = pos % ring
        age = (idx_now - slots) % ring
        abs_pos = pos - age
        mask = (abs_pos >= 0) & (abs_pos <= pos) & ((pos - abs_pos) < c.window)
    else:
        mask = slots <= pos
    sc = jnp.where(mask[None, None, None, None, :], sc, _NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, cache["v"].astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


def attention(params, x, c: AttnConfig, positions, rules=None,
              cache=None, pos=None, mode: str = "train"):
    """Full attention block: qkv proj -> core -> out proj.

    mode: 'train' (no cache) | 'prefill' (write cache) | 'decode' (1 tok).
    Returns (out [B,S,d], new_cache_or_None).
    """
    q, k, v = _project_qkv(params, x, c, positions)
    if rules is not None:
        q = rules.shard(q, "batch", "seq", "heads", "head_dim")
        k = rules.shard(k, "batch", "seq", "kv_heads", "head_dim")
        v = rules.shard(v, "batch", "seq", "kv_heads", "head_dim")
    new_cache = None
    ring = c.window if (c.window is not None and cache is not None
                        and cache["k"].shape[1] == c.window) else None
    if mode == "decode":
        new_cache = _cache_write(cache, k, v, pos, ring)
        ctx = decode_attention(q, new_cache, c, pos, ring)
    else:
        if mode == "prefill":
            # positions start at 0. Ring layers keep only the last `window`
            # tokens at slots p % window: roll so slot j holds position
            # S - window + ((j - S) mod window).
            if ring:
                s_len = k.shape[1]
                if s_len >= ring:
                    kk = jnp.roll(k[:, -ring:], s_len % ring, axis=1)
                    vv = jnp.roll(v[:, -ring:], s_len % ring, axis=1)
                else:
                    kk, vv = k, v
                new_cache = _cache_write(cache, kk, vv, 0, None)
            else:
                new_cache = _cache_write(cache, k, v, 0, None)
        if c.window is not None and x.shape[1] > c.window:
            ctx = banded_attention(q, k, v, c, positions)
        else:
            ctx = flash_attention(q, k, v, c, positions, positions)
    out = jnp.einsum("bshk,hkd->bsd", ctx, params["wo"])
    if rules is not None:
        out = rules.shard(out, "batch", "seq_res", "embed")
    return out, new_cache
