"""Matrix-free fused ingestion: raw vectors -> packed codes -> stores.

The front door of the system, closing the paper's economy end-to-end:
the b-bit packed words that search/rank/learn serve from are *produced*
without ever materializing the [D, k] projection matrix (regenerated in
canonical units from the seed), the [n, k] f32 projections, or the
[n, k] int32 codes in HBM — the only corpus-sized write-back is the
packed words themselves.

encoder  — ``StreamingEncoder``: fused one-kernel encode below the
           R-residency cap (``kernels.encode_fused``), donated-slab
           unit streaming above it (D = 3.2M in O(unit) memory), CSR
           gather projection for sparse corpora; ``encode_codes`` for
           the query-side int32 contract
sparse   — ``CsrMatrix`` host CSR container + per-unit nonzero
           bucketing (``unit_buckets``)
pipeline — ``IngestPipeline``: chunked host→device bulk load straight
           into ``index.SegmentLogStore.add_words`` /
           ``ann.CodeStore``; ``encode_sharded`` shard_map
           data-parallel encode, bit-identical at any device count

(oracle semantics: ``core.sketch`` — unit-ordered accumulation,
``sketch_oracle``; serving entry: ``serve.ann_service`` ``bulk_load``)
"""
from repro.encode.encoder import R_CAP_ELEMS, StreamingEncoder  # noqa: F401
from repro.encode.pipeline import IngestPipeline, encode_sharded  # noqa: F401
from repro.encode.sparse import CsrMatrix, unit_buckets  # noqa: F401
