"""Paper section 6: train linear SVMs on one-hot-expanded coded projections
and compare schemes (synthetic stand-in for the UCI sets; offline container).

    PYTHONPATH=src python examples/svm_coded_features.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.sketch import CodedRandomProjection, SketchConfig
from repro.core.svm import SVMConfig, expand_codes, svm_accuracy, train_linear_svm


def make_data(key, n, d, sep=0.35):
    mu = jax.random.normal(key, (d,)) * sep / np.sqrt(d) * 40
    y = jnp.where(jax.random.uniform(jax.random.fold_in(key, 1), (n,)) < 0.5,
                  1.0, -1.0)
    x = jax.random.normal(jax.random.fold_in(key, 2), (n, d)) + y[:, None] * mu
    x = x / jnp.linalg.norm(x, axis=1, keepdims=True)
    return x, y


def main():
    d = 8192
    (x, y) = make_data(jax.random.PRNGKey(0), 1200, d)
    xtr, ytr, xte, yte = x[:600], y[:600], x[600:], y[600:]

    print(f"{'features':24s} {'k':>4s} {'dim':>7s} {'test acc':>9s}")
    for k in (16, 64, 256):
        proj = CodedRandomProjection(SketchConfig(k=k, scheme="sign"), d)
        ztr, zte = proj.project(xtr), proj.project(xte)
        ztr = ztr / jnp.linalg.norm(ztr, axis=1, keepdims=True)
        zte = zte / jnp.linalg.norm(zte, axis=1, keepdims=True)
        w_, b_ = train_linear_svm(ztr, ytr, SVMConfig(c=1.0, steps=300))
        print(f"{'orig projections':24s} {k:4d} {k:7d} "
              f"{float(svm_accuracy(w_, b_, zte, yte)):9.4f}")

        for scheme, w in (("2bit", 0.75), ("uniform", 0.75), ("sign", 0.0),
                          ("offset", 2.0)):
            crp = CodedRandomProjection(
                SketchConfig(k=k, scheme=scheme, w=max(w, 1e-3)), d)
            ftr = expand_codes(crp.encode(xtr), crp.spec)
            fte = expand_codes(crp.encode(xte), crp.spec)
            w_, b_ = train_linear_svm(ftr, ytr, SVMConfig(c=1.0, steps=300))
            acc = float(svm_accuracy(w_, b_, fte, yte))
            label = f"{scheme} w={w}"
            print(f"{label:24s} {k:4d} {ftr.shape[1]:7d} {acc:9.4f}")
        print()


if __name__ == "__main__":
    main()
