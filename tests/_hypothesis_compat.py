"""Hypothesis facade: the real library when installed, a seeded shim
when not.

Two tier-1 property tests (``test_schemes_properties.py`` and the slow
lifecycle sequence test in ``test_index.py``) were perpetually skipped
in environments without ``hypothesis``. This module keeps their source
written against the hypothesis API (``given`` / ``settings`` /
``strategies``) while degrading to a deterministic random-sampling
shim when the import fails: every ``@given`` test then runs
``max_examples`` seeded draws from the declared strategies (endpoints
drawn with boosted probability, since boundary values are where
encoder/packing invariants actually break) and re-raises the first
failure with the falsifying example attached.

The shim is NOT hypothesis — no shrinking, no example database, no
``assume`` — but the invariants under test are plain ∀-statements over
boxed numeric domains, where seeded sampling with endpoint bias keeps
nearly all of the bug-finding power. ``HAVE_HYPOTHESIS`` tells a test
which engine it got (surfaced in the CI summary via the test report
header in ``conftest.py``).
"""
from __future__ import annotations

import functools
import inspect

import numpy as np

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "strategies"]

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A draw rule: ``example(rng)`` produces one value."""

        __slots__ = ("_draw",)

        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _Strategies:
        """The subset of ``hypothesis.strategies`` the tests use."""

        @staticmethod
        def floats(min_value, max_value, width=64, allow_subnormal=True,
                   allow_nan=False, allow_infinity=False):
            lo, hi = float(min_value), float(max_value)

            def draw(rng):
                r = rng.random()
                if r < 0.05:
                    v = lo
                elif r < 0.10:
                    v = hi
                else:
                    v = rng.uniform(lo, hi)
                if width == 32:
                    v = float(np.float32(v))
                return float(v)

            return _Strategy(draw)

        @staticmethod
        def integers(min_value, max_value):
            lo, hi = int(min_value), int(max_value)

            def draw(rng):
                r = rng.random()
                if r < 0.05:
                    return lo
                if r < 0.10:
                    return hi
                return int(rng.integers(lo, hi + 1))

            return _Strategy(draw)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(draw)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)

            def draw(rng):
                return seq[int(rng.integers(len(seq)))]

            return _Strategy(draw)

    strategies = _Strategies()

    class settings:  # noqa: N801 — mirrors the hypothesis name
        """Shim of ``hypothesis.settings``: only ``max_examples`` is
        honored (``deadline`` etc. accepted and ignored); usable as a
        decorator and via ``register_profile``/``load_profile``."""

        _profiles = {"default": 25}
        _active = "default"

        def __init__(self, max_examples=None, **_ignored):
            self.max_examples = max_examples

        def __call__(self, fn):
            fn._shim_settings = self
            return fn

        @classmethod
        def register_profile(cls, name, max_examples=None, **_ignored):
            cls._profiles[name] = max_examples

        @classmethod
        def load_profile(cls, name):
            cls._active = name

        @classmethod
        def active_max_examples(cls) -> int:
            return cls._profiles.get(cls._active) or 25

    def given(*strats):
        """Shim of ``hypothesis.given``: run the test body over
        ``max_examples`` seeded draws (deterministic across runs);
        failures re-raise with the falsifying example attached."""

        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                s = (getattr(wrapper, "_shim_settings", None)
                     or getattr(fn, "_shim_settings", None))
                n = ((s.max_examples if s and s.max_examples else None)
                     or settings.active_max_examples())
                rng = np.random.default_rng(0xC0DE)
                for i in range(n):
                    vals = [st.example(rng) for st in strats]
                    try:
                        fn(*args, *vals, **kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example (shim draw {i}): "
                            f"{vals!r}") from e

            # pytest resolves fixture names from inspect.signature, which
            # follows __wrapped__ straight to the test's strategy params —
            # present the wrapper as the zero-arg test it actually is
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco
