"""Scored re-ranking: packed codes -> calibrated similarity estimates.

The ANN layers rank by raw collision counts (the diagonal of the code
contingency table); this subsystem ranks by the full table. ``tables``
builds product-quantization-style per-query lookup tables whose entries
are per-code-pair log-likelihood ratios from the scheme's contingency
model (``core.estimators.cell_probs``), with a monotone rho calibration
inverted on a dense grid; ``kernels.packed_lut`` fuses the lookups with
streaming top-k on device. The engines compose the two stages — coarse
packed-collision top-m, LUT re-rank to top-k — behind ``scored=True``
(``ann.AnnEngine`` / ``index.MutableAnnEngine`` / ``serve.AnnService``).
"""
from repro.rank.tables import RankTables, build_rank_tables  # noqa: F401
