"""Mixture-of-Experts FFN with shard_map expert parallelism.

Experts are sharded over the 'model' mesh axis; tokens are sharded over
(dp, model). Per device: local top-k routing -> capacity-bounded scatter
into a per-destination send buffer -> all_to_all over 'model' -> batched
expert GLU -> inverse all_to_all -> gated scatter-add combine
(GShard-style token dropping, capacity_factor configurable).

The same inner routine runs unmapped (n_model=1, no collectives) on a
single device for smoke tests, so routing semantics are identical in both
paths and testable on CPU.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.nn import ParamSpec
from repro.parallel.sharding import shard_map_unchecked

__all__ = ["MoEConfig", "moe_param_specs", "moe"]


@dataclass(frozen=True)
class MoEConfig:
    d_model: int
    n_experts: int
    n_per_token: int
    d_ff: int                      # per-expert hidden width
    capacity_factor: float = 1.25
    renorm_gates: bool = True      # qwen3 renormalizes top-k probs; olmoe not
    activation: str = "silu"
    dtype: str = "bfloat16"


def moe_param_specs(c: MoEConfig) -> dict:
    e, d, f = c.n_experts, c.d_model, c.d_ff
    return {
        "w_router": ParamSpec((d, e), ("embed", None), "float32"),
        "w_gate": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp"), c.dtype),
        "w_up": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp"), c.dtype),
        "w_down": ParamSpec((e, f, d), ("experts", "expert_mlp", "embed"), c.dtype),
    }


def _act(x, name):
    return jax.nn.silu(x) if name == "silu" else jax.nn.gelu(x, approximate=True)


def _route(x, w_router, c: MoEConfig):
    """x [T, d] -> (gates [T*k], expert [T*k], tok [T*k]) flattened."""
    t = x.shape[0]
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), w_router)
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, c.n_per_token)
    if c.renorm_gates:
        vals = vals / jnp.maximum(jnp.sum(vals, axis=-1, keepdims=True), 1e-9)
    gate = vals.reshape(-1)
    expert = idx.reshape(-1)
    tok = jnp.repeat(jnp.arange(t), c.n_per_token)
    return gate, expert, tok, probs


def _moe_inner(x, params, c: MoEConfig, n_model: int, axis_name):
    """Per-device MoE. x [T, d]; expert weights hold E/n_model local experts."""
    t, d = x.shape
    e = c.n_experts
    e_loc = e // n_model
    cap = int(max(4, math.ceil(t * c.n_per_token / e * c.capacity_factor)))

    gate, expert, tok, probs = _route(x, params["w_router"], c)
    a = gate.shape[0]  # = T * k assignments

    # position of each assignment within its expert (token-major priority)
    one_hot = (expert[:, None] == jnp.arange(e)[None, :]).astype(jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(one_hot, axis=0), expert[:, None],
                              axis=1)[:, 0] - 1
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap)  # OOB -> dropped by scatter mode='drop'
    dest = expert // e_loc
    slot = expert % e_loc

    # dispatch: send buffer [n_model, e_loc, cap, d]
    sb = jnp.zeros((n_model, e_loc, cap, d), x.dtype)
    sb = sb.at[dest, slot, pos_c].add(x[tok], mode="drop")
    if axis_name is not None:
        sb = jax.lax.all_to_all(sb, axis_name, split_axis=0, concat_axis=0,
                                tiled=True)
    # expert GLU on [e_loc, n_model*cap, d]
    xin = sb.transpose(1, 0, 2, 3).reshape(e_loc, n_model * cap, d)
    g = jnp.einsum("ecd,edf->ecf", xin, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xin, params["w_up"])
    y = jnp.einsum("ecf,efd->ecd", _act(g, c.activation) * u, params["w_down"])
    rb = y.reshape(e_loc, n_model, cap, d).transpose(1, 0, 2, 3)
    if axis_name is not None:
        rb = jax.lax.all_to_all(rb, axis_name, split_axis=0, concat_axis=0,
                                tiled=True)
    # combine: gather each assignment's value and scatter-add into tokens
    flat = (dest * e_loc + slot) * cap + pos_c
    vals = jnp.take(rb.reshape(n_model * e_loc * cap, d), jnp.minimum(flat, n_model * e_loc * cap - 1), axis=0)
    wts = (gate * keep.astype(gate.dtype)).astype(jnp.float32)
    out = jnp.zeros((t, d), jnp.float32).at[tok].add(vals.astype(jnp.float32) * wts[:, None])

    # load-balancing auxiliary loss (Switch/OLMoE style)
    me = jnp.mean(probs, axis=0)                       # mean router prob per expert
    ce = jnp.mean(one_hot.reshape(t, c.n_per_token, e).sum(1).astype(jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce) / c.n_per_token
    return out.astype(x.dtype), aux


def moe(params, x, c: MoEConfig, rules=None):
    """x [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    b, s, d = x.shape
    if rules is None or rules.mesh is None or "model" not in (rules.mesh.axis_names if rules.mesh else ()):
        out, aux = _moe_inner(x.reshape(b * s, d), params, c, 1, None)
        return out.reshape(b, s, d), aux

    mesh = rules.mesh
    n_model = mesh.shape["model"]
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    dp_spec = dp if len(dp) > 1 else dp[0]
    seq_spec = "model" if s % n_model == 0 and s > 1 else None
    x_spec = P(dp_spec if b % dp_size == 0 else None, seq_spec, None)
    param_specs = {
        "w_router": P(None, None),
        "w_gate": P("model", None, None),
        "w_up": P("model", None, None),
        "w_down": P("model", None, None),
    }

    def mapped(xb, pb):
        bb, sb_, dd = xb.shape
        # When seq is not sharded over 'model' (decode, S=1) every
        # model-rank routes the same tokens; compute is duplicated n_model
        # times but outputs are replicated-correct (negligible at S=1).
        out, aux = _moe_inner(xb.reshape(bb * sb_, dd), pb, c, n_model, "model")
        aux = jax.lax.pmean(aux, mesh.axis_names)
        return out.reshape(bb, sb_, dd), aux

    out, aux = shard_map_unchecked(
        mapped, mesh=mesh,
        in_specs=(x_spec, param_specs),
        out_specs=(x_spec, P()),
    )(x, params)
    return out, aux
