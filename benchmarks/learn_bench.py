"""Packed-code classifier training benchmark: parity and economics.

Two questions, one JSON record (``BENCH_learn.json`` at the repo root):

1. **Parity** — on a fig11 synthetic set, training on packed codes
   (``repro.learn``, fused gather/scatter kernels, no one-hot matrix)
   must reach test accuracy within 1e-3 of the dense ``expand_codes``
   path: same objective, same optimizer, different float summation
   order only.

2. **Scale** — minibatch training over a corpus whose dense one-hot
   expansion does not fit on a device: 1M rows × k=256 × 2-bit codes is
   64 MB packed but ≈4 GiB as float32 one-hot (a 64× blow-up; with
   optimizer transients the dense path busts a 16 GB part long before
   the packed working set is visible). Measured: training rows/s (the
   per-step donated update touches only O(batch) rows), full-corpus
   margin (inference) rows/s, bytes on device vs bytes the dense path
   would need.

Acceptance contract: parity |Δacc| <= 1e-3, dense one-hot bytes >= 4 GiB
while packed bytes fit in under 1/32 of that, and held-out accuracy
beats chance by a wide margin.
"""
import json
import os
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

if __package__ in (None, ""):           # direct `python benchmarks/learn_bench.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

from benchmarks._util import write_csv
from repro.core import packing as PK
from repro.core.schemes import CodeSpec, encode
from repro.core.sketch import CodedRandomProjection, SketchConfig
from repro.core.svm import SVMConfig, expand_codes, svm_accuracy, train_linear_svm
from repro.learn import LearnConfig, feature_spec_for, fit_words
from repro.learn.linear import packed_loss_and_grads, targets_pm

SPEC = CodeSpec("2bit", 0.75)


def _parity(k: int, steps: int):
    """Dense expand_codes vs packed training on a fig11 synthetic set.

    The PRNG seed is fixed (not fig11's per-process ``hash(name)``), so
    the recorded accuracies are reproducible run to run."""
    from benchmarks.fig11_svm import _make_dataset
    (xtr, ytr), (xte, yte) = _make_dataset("url_like",
                                           jax.random.PRNGKey(1105))
    crp = CodedRandomProjection(
        SketchConfig(k=k, scheme=SPEC.scheme, w=SPEC.w), xtr.shape[1])
    ctr, cte = crp.encode(xtr), crp.encode(xte)

    model = fit_words(crp.pack(ctr), ytr, feature_spec_for(crp.spec, k),
                      LearnConfig(c=1.0, steps=steps))
    acc_packed = model.accuracy(crp.pack(cte), np.asarray(yte))

    ftr, fte = expand_codes(ctr, crp.spec), expand_codes(cte, crp.spec)
    w_, b_ = train_linear_svm(ftr, ytr, SVMConfig(c=1.0, steps=steps))
    acc_dense = float(svm_accuracy(w_, b_, fte, yte))
    return {"dataset": "url_like", "n_train": int(xtr.shape[0]),
            "n_test": int(xte.shape[0]), "k": k, "steps": steps,
            "acc_packed": acc_packed, "acc_dense": acc_dense,
            "abs_diff": abs(acc_packed - acc_dense)}


def _make_packed_corpus(n: int, k: int, seed: int = 0, chunk: int = 65536):
    """Planted two-class codes, generated and packed chunk by chunk so
    the int32 code matrix never exists at full size either."""
    rng = np.random.default_rng(seed)
    mu = rng.normal(size=(k,)).astype(np.float32) * 0.25
    words = np.empty((n, PK.packed_width(k, SPEC.bits)), np.uint32)
    y = np.empty((n,), np.float32)
    for lo in range(0, n, chunk):
        m = min(chunk, n - lo)
        yc = np.where(rng.random(m) < 0.5, 1.0, -1.0).astype(np.float32)
        z = rng.normal(size=(m, k)).astype(np.float32) + yc[:, None] * mu
        words[lo:lo + m] = np.asarray(
            PK.pack_codes(encode(jnp.asarray(z), SPEC), SPEC.bits))
        y[lo:lo + m] = yc
    return jnp.asarray(words), jnp.asarray(y)


def _scale(n: int, k: int, steps: int, batch: int, n_test: int = 16384):
    fspec = feature_spec_for(SPEC, k)
    words, y = _make_packed_corpus(n + n_test, k)
    wtr, ytr = words[:n], y[:n]
    wte, yte = words[n:], y[n:]

    cfg = LearnConfig(c=1.0, steps=steps, lr=0.1, batch=batch)
    t0 = time.perf_counter()
    model = fit_words(wtr, ytr, fspec, cfg)
    jax.block_until_ready(model.tables)
    t_train = time.perf_counter() - t0

    # steady-state step throughput: time the warmed jit'd gradient
    # evaluation on a fixed batch (the per-step hot path; the end-to-end
    # t_train above additionally pays one trace+compile and host-side
    # batch sampling) — same warmed-measurement rules as inference below
    probe = jax.jit(lambda p, bw, by: packed_loss_and_grads(
        p, bw, by, fspec, c=1.0)[1])
    params = (model.tables, model.bias)
    bw, by = wtr[:batch], targets_pm(ytr, 1)[:, :batch]
    jax.block_until_ready(probe(params, bw, by))
    reps = 10
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(probe(params, bw, by))
    t_step = (time.perf_counter() - t0) / reps

    # inference: one streaming margin pass over the full corpus
    m = model.margins(wtr)
    jax.block_until_ready(m)
    t0 = time.perf_counter()
    jax.block_until_ready(model.margins(wtr))
    t_fwd = time.perf_counter() - t0

    packed_bytes = int(wtr.size * 4)
    dense_bytes = int(n) * fspec.dense_dim * 4
    return {
        "corpus": n, "k": k, "bits": SPEC.bits, "n_codes": SPEC.n_codes,
        "batch": batch, "steps": steps,
        "train_time_s": t_train,
        "train_step_s": t_step,
        "train_rows_per_s": batch / t_step,
        "infer_rows_per_s": n / t_fwd,
        "test_acc": model.accuracy(wte, np.asarray(yte)),
        "packed_bytes": packed_bytes,
        "dense_onehot_bytes": dense_bytes,
        "dense_blowup_x": dense_bytes / packed_bytes,
    }


def _rows(par, sc):
    return [
        ("learn_train_packed", 1e6 / sc["train_rows_per_s"],
         f"rows/s={sc['train_rows_per_s']:.0f} acc={sc['test_acc']:.3f} "
         f"n={sc['corpus']}"),
        ("learn_infer_packed", 1e6 / sc["infer_rows_per_s"],
         f"rows/s={sc['infer_rows_per_s']:.0f}"),
        ("learn_parity", 0.0,
         f"packed={par['acc_packed']:.4f} dense={par['acc_dense']:.4f} "
         f"|d|={par['abs_diff']:.4f}"),
        ("learn_dense_blowup", 0.0,
         f"packed_MB={sc['packed_bytes'] / 2**20:.0f} "
         f"dense_MB={sc['dense_onehot_bytes'] / 2**20:.0f} "
         f"x{sc['dense_blowup_x']:.0f}"),
    ]


def run(quick: bool = True):
    """run.py contract: (name, us_per_row, derived) rows."""
    par = _parity(k=64, steps=150 if quick else 250)
    sc = _scale(n=131072 if quick else 1 << 20, k=64 if quick else 256,
                steps=40 if quick else 100, batch=2048 if quick else 4096,
                n_test=4096 if quick else 16384)
    rows = _rows(par, sc)
    write_csv("learn_bench", ["name", "us_per_row", "derived"], rows)
    return rows


def main():
    par = _parity(k=256, steps=250)
    sc = _scale(n=1 << 20, k=256, steps=100, batch=4096)
    r = {"parity": par, "scale": sc}
    write_csv("learn_bench", ["name", "us_per_row", "derived"],
              _rows(par, sc))
    with open(os.path.join(_ROOT, "BENCH_learn.json"), "w") as f:
        json.dump(r, f, indent=1)
    print("BENCH " + json.dumps(r))
    print(f"\nparity on {par['dataset']}: packed {par['acc_packed']:.4f} "
          f"vs dense {par['acc_dense']:.4f} (|d|={par['abs_diff']:.4f})")
    print(f"scale: {sc['corpus']} rows x k={sc['k']} ({sc['bits']}-bit): "
          f"{sc['packed_bytes'] / 2**20:.0f} MB packed vs "
          f"{sc['dense_onehot_bytes'] / 2**30:.2f} GiB dense one-hot "
          f"(x{sc['dense_blowup_x']:.0f}); train "
          f"{sc['train_rows_per_s']:.0f} rows/s, "
          f"test acc {sc['test_acc']:.3f}")
    ok = (par["abs_diff"] <= 1e-3
          and sc["dense_onehot_bytes"] >= 2 ** 32
          and sc["dense_onehot_bytes"] >= 32 * sc["packed_bytes"]
          and sc["test_acc"] >= 0.8)
    print("acceptance: " + ("PASS" if ok else "FAIL"))
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
