"""Distributed-path tests in an 8-device subprocess (keeps the main test
process at 1 device, per the dry-run isolation rule)."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

assert len(jax.devices()) == 8

# --- 1) MoE shard_map parity vs single-device routing ---
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("data", "model"))
from repro.parallel.sharding import ShardingRules
from repro.models.moe import MoEConfig, moe, moe_param_specs
from repro.models.nn import init_params

c = MoEConfig(d_model=32, n_experts=8, n_per_token=2, d_ff=16,
              capacity_factor=8.0)
params = init_params(moe_param_specs(c), seed=0)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32), jnp.bfloat16)
rules = ShardingRules(mesh)
out_sharded, aux_s = jax.jit(lambda p, xx: moe(p, xx, c, rules))(params, x)
out_local, aux_l = jax.jit(lambda p, xx: moe(p, xx, c, None))(params, x)
err = float(jnp.max(jnp.abs(out_sharded.astype(jnp.float32)
                            - out_local.astype(jnp.float32))))
print("moe parity err:", err)
assert err < 0.05, err

# --- 2) GSPMD train step on a (2,4) mesh: loss finite and decreases ---
from repro.models import lm as L
from repro.optim import AdamWConfig, init_opt_state
from repro.train import make_train_step
from repro.data import DataConfig, TokenPipeline

cfg = L.ModelConfig(name="tiny", n_layers=2, d_model=32, n_heads=4,
                    n_kv_heads=2, d_ff=64, vocab_size=64, loss_chunk=16,
                    chunk_kv=16, chunk_q=16)
opt_cfg = AdamWConfig(lr_peak=3e-3, warmup_steps=2, decay_steps=40,
                      weight_decay=0.0)
step_fn = make_train_step(cfg, opt_cfg, rules)
params = init_params(L.model_param_specs(cfg), seed=0)
opt = init_opt_state(params, opt_cfg)
pipe = TokenPipeline(DataConfig(vocab_size=64, seq_len=32, global_batch=8))
losses = []
for i in range(20):
    params, opt, m = step_fn(params, opt, pipe.batch_at(i))
    losses.append(float(m["loss"]))
print("gspmd losses:", losses[0], "->", losses[-1])
assert np.isfinite(losses).all() and losses[-1] < losses[0]

# --- 3) compressed-gradient DP training on 8 devices ---
from repro.core.gradient_compression import GradCompressionConfig, GradCompressor
from repro.train import make_compressed_train_step
from repro.launch.mesh import make_dp_mesh

dp_mesh = make_dp_mesh(8)
params = init_params(L.model_param_specs(cfg), seed=0)
opt = init_opt_state(params, opt_cfg)
gtpl = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
comp = GradCompressor(GradCompressionConfig(scheme="2bit", rate=2, chunk=512),
                      gtpl)
ef = comp.init_ef(gtpl)
cstep = make_compressed_train_step(cfg, opt_cfg, dp_mesh, comp)
closs = []
for i in range(40):
    params, opt, ef, m = cstep(params, opt, ef, pipe.batch_at(i))
    closs.append(float(m["loss"]))
print("compressed losses:", closs[0], "->", closs[-1])
# EF at rate=2 transmits half the gradient energy per step: allow a
# slightly longer window before demanding net progress
assert np.isfinite(closs).all() and min(closs[-10:]) < closs[0]

# --- 4) elastic checkpoint restore across meshes ---
from repro.checkpoint import save_checkpoint, restore_checkpoint
from repro.models.nn import param_shardings
import tempfile
d = tempfile.mkdtemp()
save_checkpoint(d, 1, params)
specs = L.model_param_specs(cfg)
sh = param_shardings(specs, ShardingRules(make_mesh_compat((8,), ("data",))))
restored = restore_checkpoint(d, 1, params, shardings=None)
for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(params)):
    np.testing.assert_allclose(np.asarray(a, dtype=np.float32),
                               np.asarray(b, dtype=np.float32))
print("ALL DISTRIBUTED OK")
"""


@pytest.mark.slow
def test_distributed_paths():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _SCRIPT], cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), env=env,
        capture_output=True, text=True, timeout=1200)
    assert "ALL DISTRIBUTED OK" in res.stdout, \
        f"STDOUT:\n{res.stdout[-3000:]}\nSTDERR:\n{res.stderr[-3000:]}"
