"""Unified LM assembly for all assigned architectures.

One ``ModelConfig`` drives dense / MoE / hybrid(Mamba2+shared-attn) /
SSM(RWKV6) / VLM / audio decoders. Layers execute as a lax.scan over
repeating *pattern groups* (stacked params passed as scan xs, so FSDP
gathers one group per step and the HLO stays small), with any
non-divisible tail applied unscanned.

Pattern characters: 'G' global attention block, 'L' sliding-window block,
'M' Mamba2 block, 'R' RWKV6 block, 'A' shared attention block (zamba2 —
single weight copy + per-invocation LoRA on W_q).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import ffn as F
from repro.models import mamba2 as M
from repro.models import moe as MOE
from repro.models import rwkv6 as R
from repro.models.nn import ParamSpec, rms_norm
from repro.models import unroll as U

__all__ = ["ModelConfig", "model_param_specs", "forward", "lm_loss",
           "init_caches", "decode_step", "layer_kinds"]


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"        # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: Optional[int] = None
    # attention
    qkv_bias: bool = False
    qk_norm: bool = False
    logit_softcap: Optional[float] = None
    attn_softcap: Optional[float] = None
    rope_theta: float = 10000.0
    rope_theta_local: Optional[float] = None
    window: Optional[int] = None
    layer_pattern: str = "G"     # cycled over layers; tail unscanned
    query_scale: Optional[float] = None
    # ffn
    activation: str = "silu"
    # moe
    n_experts: int = 0
    n_experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    renorm_gates: bool = True
    aux_loss_coef: float = 0.01
    # ssm / hybrid
    ssm_state: int = 64
    ssm_chunk: int = 64
    shared_attn_every: int = 6   # zamba2: shared block every N mamba layers
    lora_rank: int = 64
    rwkv_chunk: int = 16
    # embeddings / output
    n_codebooks: int = 1
    tie_embeddings: bool = True
    embed_scale: bool = False    # gemma: x *= sqrt(d)
    post_norms: bool = False     # gemma2/3 sandwich norms
    norm_eps: float = 1e-6
    # execution
    dtype: str = "bfloat16"
    remat: bool = True
    probs_bf16: bool = False
    chunk_kv: int = 1024
    chunk_q: int = 512
    loss_chunk: int = 512

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    def attn_cfg(self, local: bool) -> A.AttnConfig:
        return A.AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.hd,
            rope_theta=(self.rope_theta_local if (local and self.rope_theta_local)
                        else self.rope_theta),
            window=self.window if local else None,
            attn_softcap=self.attn_softcap, qk_norm=self.qk_norm,
            qkv_bias=self.qkv_bias, query_scale=self.query_scale,
            norm_eps=self.norm_eps, chunk_kv=self.chunk_kv,
            chunk_q=self.chunk_q, probs_bf16=self.probs_bf16,
            dtype=self.dtype)

    def mamba_cfg(self) -> M.Mamba2Config:
        return M.Mamba2Config(d_model=self.d_model, d_state=self.ssm_state,
                              chunk=self.ssm_chunk, norm_eps=self.norm_eps,
                              dtype=self.dtype)

    def rwkv_cfg(self) -> R.RWKV6Config:
        return R.RWKV6Config(d_model=self.d_model, d_ff=self.d_ff,
                             chunk=self.rwkv_chunk, norm_eps=self.norm_eps,
                             dtype=self.dtype)

    def moe_cfg(self) -> MOE.MoEConfig:
        return MOE.MoEConfig(d_model=self.d_model, n_experts=self.n_experts,
                             n_per_token=self.n_experts_per_token,
                             d_ff=self.moe_d_ff,
                             capacity_factor=self.capacity_factor,
                             renorm_gates=self.renorm_gates,
                             activation=self.activation, dtype=self.dtype)

    def ffn_cfg(self) -> F.FFNConfig:
        return F.FFNConfig(d_model=self.d_model, d_ff=self.d_ff,
                           activation=self.activation, dtype=self.dtype)


# ---------------------------------------------------------------------------
# layer layout


def layer_kinds(cfg: ModelConfig):
    """Per-layer kind chars, full length (pattern cycled)."""
    if cfg.family == "hybrid":
        # groups of (A + every*M); 'A' is an *insertion*, not a counted layer
        pat = "A" + "M" * cfg.shared_attn_every
        n_groups = cfg.n_layers // cfg.shared_attn_every
        tail = cfg.n_layers - n_groups * cfg.shared_attn_every
        return pat, n_groups, "M" * tail
    if cfg.family == "ssm":
        return "R", cfg.n_layers, ""
    pat = cfg.layer_pattern
    n_groups = cfg.n_layers // len(pat)
    tail = pat[:cfg.n_layers - n_groups * len(pat)]
    return pat, n_groups, tail


def _norm_spec(cfg):
    return ParamSpec((cfg.d_model,), ("embed",), cfg.dtype,
                     init="zeros" if cfg.post_norms else "ones")


def _block_param_specs(cfg: ModelConfig, kind: str) -> dict:
    if kind in ("G", "L"):
        specs = {
            "ln1": _norm_spec(cfg),
            "attn": A.attn_param_specs(cfg.attn_cfg(kind == "L")),
            "ln2": _norm_spec(cfg),
        }
        if cfg.post_norms:
            specs["ln1_post"] = _norm_spec(cfg)
            specs["ln2_post"] = _norm_spec(cfg)
        if cfg.family == "moe" or (cfg.n_experts > 0):
            specs["moe"] = MOE.moe_param_specs(cfg.moe_cfg())
        else:
            specs["ffn"] = F.ffn_param_specs(cfg.ffn_cfg())
        return specs
    if kind == "M":
        return {"ln": _norm_spec(cfg), "mamba": M.mamba2_param_specs(cfg.mamba_cfg())}
    if kind == "R":
        rs = R.rwkv6_param_specs(cfg.rwkv_cfg())
        return {"ln1": _norm_spec(cfg), "time": rs["time"],
                "ln2": _norm_spec(cfg), "channel": rs["channel"]}
    if kind == "A":
        # per-invocation LoRA on W_q only; shared weights live outside scan
        h, hd, r = cfg.n_heads, cfg.hd, cfg.lora_rank
        return {
            "lora_a": ParamSpec((cfg.d_model, r), ("embed", None), cfg.dtype),
            "lora_b": ParamSpec((r, h * hd), (None, "heads"), cfg.dtype,
                                init="zeros"),
        }
    raise ValueError(kind)


def _stack_specs(specs, n: int):
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.dtype,
                            s.init, s.scale),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def model_param_specs(cfg: ModelConfig) -> dict:
    pat, n_groups, tail = layer_kinds(cfg)
    group = {f"p{i}": _block_param_specs(cfg, k) for i, k in enumerate(pat)}
    specs = {
        "embed": ParamSpec(
            ((cfg.n_codebooks,) if cfg.n_codebooks > 1 else ())
            + (cfg.vocab_size, cfg.d_model),
            (("codebooks",) if cfg.n_codebooks > 1 else ())
            + ("vocab", "embed"),
            cfg.dtype, init="embed", scale=cfg.d_model ** -0.5),
        "blocks": _stack_specs(group, n_groups),
        "ln_f": _norm_spec(cfg),
    }
    if tail:
        specs["tail"] = {f"t{i}": _block_param_specs(cfg, k)
                         for i, k in enumerate(tail)}
    if cfg.family == "hybrid":
        specs["shared_attn"] = {
            "ln": _norm_spec(cfg),
            "attn": A.attn_param_specs(cfg.attn_cfg(False)),
        }
    if not cfg.tie_embeddings:
        head_shape = ((cfg.n_codebooks,) if cfg.n_codebooks > 1 else ()) + \
            (cfg.d_model, cfg.vocab_size)
        head_axes = (("codebooks",) if cfg.n_codebooks > 1 else ()) + \
            ("embed", "vocab")
        specs["head"] = ParamSpec(head_shape, head_axes, cfg.dtype)
    return specs


# ---------------------------------------------------------------------------
# block application


def _apply_block(kind: str, bp, x, cfg: ModelConfig, rules, positions,
                 mode: str, cache, pos, shared=None):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("G", "L"):
        ac = cfg.attn_cfg(kind == "L")
        h = rms_norm(x, bp["ln1"], cfg.norm_eps, plus_one=cfg.post_norms)
        attn_out, new_kv = A.attention(bp["attn"], h, ac, positions, rules,
                                       cache=None if cache is None else cache["kv"],
                                       pos=pos, mode=mode)
        if cfg.post_norms:
            attn_out = rms_norm(attn_out, bp["ln1_post"], cfg.norm_eps, plus_one=True)
        x = x + attn_out
        h = rms_norm(x, bp["ln2"], cfg.norm_eps, plus_one=cfg.post_norms)
        if "moe" in bp:
            f_out, aux = MOE.moe(bp["moe"], h, cfg.moe_cfg(), rules)
        else:
            f_out = F.ffn(bp["ffn"], h, cfg.ffn_cfg(), rules)
        if cfg.post_norms:
            f_out = rms_norm(f_out, bp["ln2_post"], cfg.norm_eps, plus_one=True)
        x = x + f_out
        new_cache = None if cache is None else {"kv": new_kv}
        return x, new_cache, aux
    if kind == "M":
        h = rms_norm(x, bp["ln"], cfg.norm_eps)
        if mode == "train":
            out, _ = M.mamba2(bp["mamba"], h, cfg.mamba_cfg(), rules)
            return x + out, None, aux
        out, new = M.mamba2(bp["mamba"], h, cfg.mamba_cfg(), rules,
                            state=cache["ssm"], conv_state=cache["conv"],
                            mode=mode)
        return x + out, new, aux
    if kind == "R":
        rc = cfg.rwkv_cfg()
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        if mode == "train":
            out, _ = R.rwkv6_timemix(bp["time"], h, rc, rules)
            x = x + out
            h = rms_norm(x, bp["ln2"], cfg.norm_eps)
            out, _ = R.rwkv6_channelmix(bp["channel"], h, rc, rules)
            return x + out, None, aux
        out, tnew = R.rwkv6_timemix(bp["time"], h, rc, rules,
                                    state=cache["state"],
                                    shift=cache["shift_t"], mode=mode)
        x = x + out
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        out, cnew = R.rwkv6_channelmix(bp["channel"], h, rc, rules,
                                       shift=cache["shift_c"], mode=mode)
        new = {"state": tnew["state"], "shift_t": tnew["shift"],
               "shift_c": cnew["shift"]}
        return x + out, new, aux
    if kind == "A":
        # zamba2 shared attention: shared weights + this invocation's LoRA
        ac = cfg.attn_cfg(False)
        sp = dict(shared["attn"])
        delta = (bp["lora_a"] @ bp["lora_b"]).reshape(
            cfg.d_model, cfg.n_heads, cfg.hd)
        sp["wq"] = sp["wq"] + delta
        h = rms_norm(x, shared["ln"], cfg.norm_eps)
        out, new_kv = A.attention(sp, h, ac, positions, rules,
                                  cache=None if cache is None else cache["kv"],
                                  pos=pos, mode=mode)
        new_cache = None if cache is None else {"kv": new_kv}
        return x + out, new_cache, aux
    raise ValueError(kind)


def _group_body(cfg: ModelConfig, rules, pat: str, mode: str):
    """Scan body over pattern groups: carry (x, aux), xs (params, caches)."""
    def body(carry, xs):
        x, aux = carry
        gp, gc, positions, pos = xs
        new_caches = {}
        for i, kind in enumerate(pat):
            cache_i = None if gc is None else gc.get(f"p{i}")
            x, nc, a = _apply_block(kind, gp[f"p{i}"], x, cfg, rules,
                                    positions, mode, cache_i, pos,
                                    shared=gp.get("__shared__"))
            if nc is not None:
                new_caches[f"p{i}"] = nc
            aux = aux + a
        return (x, aux), (new_caches if new_caches else None)
    return body


def forward(params, tokens, cfg: ModelConfig, rules=None, mode: str = "train",
            caches=None, pos=None):
    """tokens [B,S] (or [B,S,C] multi-codebook) -> (hidden [B,S,d],
    new_caches, aux). Call lm_head/lm_loss on the hidden states."""
    pat, n_groups, tail = layer_kinds(cfg)
    s = tokens.shape[1]
    if pos is None:
        positions = jnp.arange(s)
    else:
        pos = jnp.asarray(pos)
        positions = jnp.reshape(pos, (1,)) if pos.ndim == 0 else pos

    emb = params["embed"]
    if cfg.n_codebooks > 1:
        x = jnp.zeros(tokens.shape[:2] + (cfg.d_model,), jnp.dtype(cfg.dtype))
        for cb in range(cfg.n_codebooks):
            x = x + jnp.take(emb[cb], tokens[..., cb], axis=0)
    else:
        x = jnp.take(emb, tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if rules is not None:
        x = rules.shard(x, "batch", "seq_res", "embed")

    blocks = params["blocks"]
    if cfg.family == "hybrid":
        blocks = dict(blocks)
        blocks["__shared__"] = jax.tree.map(
            lambda p: jnp.broadcast_to(p, (n_groups,) + p.shape),
            params["shared_attn"])

    group_caches = None if caches is None else caches["groups"]
    pos_b = jnp.broadcast_to(positions, (n_groups,) + positions.shape)
    pos_s = (jnp.broadcast_to(pos, (n_groups,))
             if pos is not None else jnp.zeros((n_groups,), jnp.int32))

    body = _group_body(cfg, rules, pat, mode)
    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux), new_group_caches = U.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (blocks, group_caches, pos_b, pos_s))

    new_caches = None
    if caches is not None:
        new_caches = {"groups": new_group_caches}

    if tail:
        new_tail = {}
        for i, kind in enumerate(tail):
            cache_i = None if caches is None else caches["tail"].get(f"t{i}")
            x, nc, a = _apply_block(kind, params["tail"][f"t{i}"], x, cfg,
                                    rules, positions, mode, cache_i, pos,
                                    shared=params.get("shared_attn"))
            if nc is not None:
                new_tail[f"t{i}"] = nc
            aux = aux + a
        if new_caches is not None:
            new_caches["tail"] = new_tail

    x = rms_norm(x, params["ln_f"], cfg.norm_eps, plus_one=cfg.post_norms)
    return x, new_caches, aux


# ---------------------------------------------------------------------------
# heads & loss


def _head_weight(params, cfg: ModelConfig):
    if not cfg.tie_embeddings:
        return params["head"]
    emb = params["embed"]
    if cfg.n_codebooks > 1:
        return jnp.swapaxes(emb, -1, -2)
    return emb.T


def lm_logits(x, params, cfg: ModelConfig, rules=None):
    """x [B,S,d] -> logits [B,S,(C,)V] (decode-sized inputs only)."""
    w = _head_weight(params, cfg)
    if cfg.n_codebooks > 1:
        logits = jnp.einsum("bsd,cdv->bscv", x, w)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, w)
    logits = logits.astype(jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits


def lm_loss(params, tokens, cfg: ModelConfig, rules=None):
    """Causal LM loss with seq-chunked, rematerialized CE (never holds the
    full [B,S,V] logits). Returns (loss, metrics)."""
    x, _, aux = forward(params, tokens, cfg, rules, mode="train")
    b, s = tokens.shape[:2]
    # shift: predict token t+1 from position t
    x_in = x[:, :-1]
    labels = tokens[:, 1:]
    w = _head_weight(params, cfg)

    chunk = min(cfg.loss_chunk, s - 1)
    n_full = (s - 1) // chunk

    def chunk_loss(args):
        xc, lc = args
        if cfg.n_codebooks > 1:
            logits = jnp.einsum("bsd,cdv->bscv", xc, w).astype(jnp.float32)
        else:
            logits = jnp.einsum("bsd,dv->bsv", xc, w).astype(jnp.float32)
        if cfg.logit_softcap:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        if rules is not None:
            spec = (("batch", "seq", "codebooks", "vocab")
                    if cfg.n_codebooks > 1 else ("batch", "seq", "vocab"))
            logits = rules.shard(logits, *spec)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via masked sum (SPMD-friendly on the sharded vocab dim:
        # take_along_axis would all-gather the logits chunk)
        vocab_ids = jnp.arange(logits.shape[-1])
        onehot = (lc[..., None] == vocab_ids)
        gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
        return jnp.sum(lse - gold)

    chunk_loss = jax.checkpoint(chunk_loss)
    xc = x_in[:, :n_full * chunk].reshape(b, n_full, chunk, cfg.d_model)
    lc = labels[:, :n_full * chunk].reshape((b, n_full, chunk)
                                            + labels.shape[2:])
    total = jnp.zeros((), jnp.float32)

    def scan_body(tot, args):
        return tot + chunk_loss(args), None
    total, _ = U.scan(scan_body, total,
                      (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(lc, 1, 0)))
    rem = (s - 1) - n_full * chunk
    if rem:
        total = total + chunk_loss((x_in[:, -rem:], labels[:, -rem:]))

    n_tok = b * (s - 1) * (cfg.n_codebooks if cfg.n_codebooks > 1 else 1)
    loss = total / n_tok
    metrics = {"ce": loss}
    if cfg.n_experts:
        loss = loss + cfg.aux_loss_coef * aux / max(cfg.n_layers, 1)
        metrics["aux"] = aux
    return loss, metrics


# ---------------------------------------------------------------------------
# caches / decode


def _block_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int, rules):
    if kind in ("G", "L", "A"):
        ac = cfg.attn_cfg(kind == "L")
        length = min(cfg.window, max_len) if kind == "L" else max_len
        return {"kv": A.init_kv_cache(batch, length, ac, rules)}
    if kind == "M":
        return M.init_mamba_cache(batch, cfg.mamba_cfg(), rules)
    if kind == "R":
        return R.init_rwkv_cache(batch, cfg.rwkv_cfg(), rules)
    raise ValueError(kind)


def init_caches(cfg: ModelConfig, batch: int, max_len: int, rules=None):
    pat, n_groups, tail = layer_kinds(cfg)

    def stack(tree):
        return jax.tree.map(
            lambda c: jnp.broadcast_to(c, (n_groups,) + c.shape).copy()
            if n_groups else c, tree)

    groups = {f"p{i}": stack(_block_cache(k, cfg, batch, max_len, rules))
              for i, k in enumerate(pat)}
    caches = {"groups": groups}
    if tail:
        caches["tail"] = {f"t{i}": _block_cache(k, cfg, batch, max_len, rules)
                          for i, k in enumerate(tail)}
    return caches


def decode_step(params, caches, tokens, pos, cfg: ModelConfig, rules=None):
    """One decode step: tokens [B,1(,C)], pos scalar int32 (current position).
    Returns (logits [B,1,(C,)V], new_caches)."""
    x, new_caches, _ = forward(params, tokens, cfg, rules, mode="decode",
                               caches=caches, pos=pos)
    return lm_logits(x, params, cfg, rules), new_caches


def prefill(params, tokens, cfg: ModelConfig, rules=None, max_len=None):
    """Prefill: run the full prompt, returning (last_logits, caches)."""
    b, s = tokens.shape[:2]
    caches = init_caches(cfg, b, max_len or s, rules)
    x, new_caches, _ = forward(params, tokens, cfg, rules, mode="prefill",
                               caches=caches)
    return lm_logits(x[:, -1:], params, cfg, rules), new_caches
