"""Assigned architecture configs (exact) + reduced smoke variants.

``get_config(arch_id)`` returns the full assignment config;
``get_smoke_config(arch_id)`` a same-family reduced config runnable on one
CPU device. ``ARCHS`` lists all assigned ids.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "qwen2_0_5b", "gemma2_9b", "phi3_mini_3_8b", "gemma3_27b",
    "olmoe_1b_7b", "qwen3_moe_235b_a22b", "zamba2_1_2b", "chameleon_34b",
    "musicgen_medium", "rwkv6_7b",
]

# canonical assignment ids -> module names
ALIASES = {
    "qwen2-0.5b": "qwen2_0_5b",
    "gemma2-9b": "gemma2_9b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "gemma3-27b": "gemma3_27b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "zamba2-1.2b": "zamba2_1_2b",
    "chameleon-34b": "chameleon_34b",
    "musicgen-medium": "musicgen_medium",
    "rwkv6-7b": "rwkv6_7b",
}


def _module(arch: str):
    name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch: str):
    return _module(arch).config()


def get_smoke_config(arch: str):
    return _module(arch).smoke_config()


def shapes_for(arch: str):
    """Applicable (shape_name, kind) cells for this arch (long_500k only
    for sub-quadratic archs; see DESIGN.md)."""
    mod = _module(arch)
    return getattr(mod, "SHAPES", ["train_4k", "prefill_32k", "decode_32k"])
