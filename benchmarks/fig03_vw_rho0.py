"""Fig 3: V_w at rho=0 versus w — paper: minimum pi^2/4 attained w->inf."""
import numpy as np
import jax.numpy as jnp

from repro.core import variance as V
from benchmarks._util import timed, write_csv


def run(quick: bool = True):
    ws = np.geomspace(0.2, 20.0, 200)

    def curve():
        return np.asarray([float(V.variance_factor_uniform(jnp.asarray(0.0), w))
                           for w in ws])

    vals, us = timed(curve, repeat=1)
    write_csv("fig03_vw_rho0", ["w", "V_w_rho0"], list(zip(ws, vals)))
    return [("fig03_limit", us,
             f"V_w(0,w=20)={vals[-1]:.6f};pi2_4={np.pi**2/4:.6f}")]
