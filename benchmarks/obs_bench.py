"""Observability overhead benchmark: what the measuring layer costs.

An observability layer that taxes the hot path gets turned off, and an
unmeasured system drifts; this bench keeps ``repro.obs`` honest on both
counts. Measured:

  * end-to-end QPS of the exact-search serving hot path
    (``serve.AnnService`` submit→flush, cache disabled so every query
    does device work) with metrics ENABLED vs DISABLED — the acceptance
    contract is <= 3% QPS overhead enabled;
  * microbenchmarks of the primitives: counter ``inc``, histogram
    ``observe`` (log-bucket math), disabled-registry no-op metrics, and
    a ``span(...)`` enter/exit with no tracer installed;
  * a real trace artifact: one ingest → search → delete → compact cycle
    over the mutable engine recorded under a ``Tracer`` and dumped as
    Chrome-trace/Perfetto JSON next to the BENCH files (load it at
    https://ui.perfetto.dev).

Wall-clock numbers are median-of-N with ``block_until_ready`` (the
serving flush syncs via its own host transfer).

``BENCH_obs.json`` (repo root) records the QPS pair, the overhead
fraction, the primitive costs and the trace path. ``--quick`` runs the
same acceptance gate on a small corpus without rewriting the JSON —
the mode CI uses on every push.
"""
import json
import os
import sys
import time

import numpy as np
import jax

if __package__ in (None, ""):            # direct `python benchmarks/obs_bench.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

from benchmarks._util import write_csv
from repro.ann import AnnEngine, BandSpec
from repro.core.sketch import CodedRandomProjection, SketchConfig
from repro.index import MutableAnnEngine
from repro.obs import (MetricsRegistry, Tracer, no_tracing,
                       set_default_registry, span)
from repro.serve import AnnService, AnnServiceConfig

K = 64


def _median_qps(svc, queries, repeat):
    """Median submit-all+flush QPS over ``repeat`` rounds (the flush's
    host transfer of results is the device sync)."""
    nq = queries.shape[0]
    for x in queries:                     # warm every jit + bucket
        svc.submit(x)
    svc.flush()
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        for x in queries:
            svc.submit(x)
        svc.flush()
        ts.append(time.perf_counter() - t0)
    return nq / float(np.median(ts))


def _ns_per(fn, n=100_000):
    fn()                                  # touch once outside the timer
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return 1e9 * (time.perf_counter() - t0) / n


def _trace_cycle(d, rows, path):
    """Record one ingest → search → delete → compact cycle and dump the
    Chrome trace; returns (path, n_events)."""
    crp = CodedRandomProjection(SketchConfig(k=K, scheme="2bit", w=0.75), d)
    eng = MutableAnnEngine(crp, tail_rows=256)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((rows, d)).astype(np.float32)
    with Tracer() as tr:
        ids = eng.ingest(x, chunk_rows=256)
        eng.search(x[:32], 10, mode="exact", chunk_q=32)
        eng.delete(ids[: rows // 3])
        eng.compact()
    tr.dump(path)
    return path, len(tr.events)


def _bench(d, n, nq, repeat):
    rng = np.random.default_rng(0)
    corpus = rng.standard_normal((n, d)).astype(np.float32)
    queries = corpus[:nq] + 0.1 * rng.standard_normal(
        (nq, d)).astype(np.float32)
    crp = CodedRandomProjection(SketchConfig(k=K, scheme="2bit", w=0.75), d)
    engine = AnnEngine.build(crp, corpus, BandSpec(n_tables=8, band_width=4))
    cfg = AnnServiceConfig(top_k=10, mode="exact", cache_size=0,
                           buckets=(nq,))

    # the enabled-vs-disabled pair isolates the *metrics* cost: span
    # recording is a separate knob, so any tracer the harness installed
    # (run.py --profile) is suspended for both measurements
    prev = set_default_registry(MetricsRegistry(enabled=True))
    try:
        with no_tracing():
            svc_on = AnnService(engine, cfg,
                                registry=MetricsRegistry(enabled=True))
            qps_on = _median_qps(svc_on, queries, repeat)
            set_default_registry(MetricsRegistry(enabled=False))
            svc_off = AnnService(engine, cfg,
                                 registry=MetricsRegistry(enabled=False))
            qps_off = _median_qps(svc_off, queries, repeat)
    finally:
        set_default_registry(prev)

    reg_on = MetricsRegistry(enabled=True)
    reg_off = MetricsRegistry(enabled=False)
    c_on, c_off = reg_on.counter("bench.c"), reg_off.counter("bench.c")
    h_on, h_off = reg_on.histogram("bench.h"), reg_off.histogram("bench.h")

    def _span_noop():
        with span("bench.span"):
            pass

    trace_path, trace_events = _trace_cycle(
        d, 1024, os.path.join(_ROOT, "TRACE_obs_cycle.json"))

    # the span microbench measures the NO-tracer cost — suspend any
    # tracer the harness (run.py --profile) may have installed
    with no_tracing():
        ns_span = _ns_per(_span_noop)

    overhead = 1.0 - qps_on / qps_off
    return {
        "corpus": n, "queries": nq, "k": K, "bits": 2,
        "qps_metrics_enabled": qps_on,
        "qps_metrics_disabled": qps_off,
        "overhead_frac": overhead,
        "ns_counter_inc": _ns_per(lambda: c_on.inc()),
        "ns_counter_inc_disabled": _ns_per(lambda: c_off.inc()),
        "ns_histogram_observe": _ns_per(lambda: h_on.observe(3e-4)),
        "ns_histogram_observe_disabled": _ns_per(
            lambda: h_off.observe(3e-4)),
        "ns_span_no_tracer": ns_span,
        "trace_file": os.path.basename(trace_path),
        "trace_events": trace_events,
        "timing": "median-of-%d, device-synced flush" % repeat,
    }


def _rows(r):
    return [
        ("obs_serve_enabled", 1e6 / r["qps_metrics_enabled"],
         f"qps={r['qps_metrics_enabled']:.0f}"),
        ("obs_serve_disabled", 1e6 / r["qps_metrics_disabled"],
         f"qps={r['qps_metrics_disabled']:.0f} "
         f"overhead={100 * r['overhead_frac']:.2f}%"),
        ("obs_counter_inc", 1e-3 * r["ns_counter_inc"],
         f"disabled_ns={r['ns_counter_inc_disabled']:.0f}"),
        ("obs_histogram_observe", 1e-3 * r["ns_histogram_observe"],
         f"disabled_ns={r['ns_histogram_observe_disabled']:.0f}"),
        ("obs_span_no_tracer", 1e-3 * r["ns_span_no_tracer"],
         f"trace_events={r['trace_events']}"),
    ]


def run(quick: bool = True):
    """run.py contract: (name, us_per_call, derived) rows."""
    r = _bench(d=64, n=4096 if quick else 65536, nq=64,
               repeat=5 if quick else 9)
    rows = _rows(r)
    write_csv("obs_bench", ["name", "us_per_call", "derived"], rows)
    return rows


def main():
    quick = "--quick" in sys.argv[1:]
    if quick:
        # CI gate mode: small corpus, same acceptance check, no
        # BENCH_obs.json overwrite (full-size numbers stay canonical)
        r = _bench(d=64, n=8192, nq=64, repeat=5)
    else:
        r = _bench(d=64, n=65536, nq=64, repeat=9)
    write_csv("obs_bench", ["name", "us_per_call", "derived"], _rows(r))
    if not quick:
        with open(os.path.join(_ROOT, "BENCH_obs.json"), "w") as f:
            json.dump(r, f, indent=1)
    print("BENCH " + json.dumps(r))
    print(f"\nmetrics-enabled hot path: {r['qps_metrics_enabled']:.0f} qps "
          f"vs disabled {r['qps_metrics_disabled']:.0f} qps "
          f"({100 * r['overhead_frac']:.2f}% overhead)")
    print(f"primitives: counter {r['ns_counter_inc']:.0f} ns, histogram "
          f"{r['ns_histogram_observe']:.0f} ns, span(no tracer) "
          f"{r['ns_span_no_tracer']:.0f} ns")
    ok = r["overhead_frac"] <= 0.03
    print("acceptance: " + ("PASS" if ok else "FAIL"))
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
