"""Two-stage scored search benchmark: recall and re-rank economics.

Workload: clustered unit vectors (each query has ~``per`` true
neighbors at rho ~0.92) scored against float32 cosine ground truth —
the quality bar the packed-code search is approximating.

Measured:
  * recall@10 of collision-count-only exact search (the coarse ranking)
  * recall@10 of the two-stage path: coarse packed-collision top-m ->
    fused LUT re-rank (``repro.rank`` non-linear 2-bit scores)
  * latency split at m = 4096 from ``repro.obs`` tracing spans: the
    engine runs each stage as its own device-synced span
    (``search.coarse`` / ``search.rerank``), so the re-rank overhead is
    the re-rank stage's *measured* execution time — not a subtraction
    of two independently-noisy totals, which is how an earlier version
    of this bench produced a negative (clamped-to-zero) overhead out of
    jax's async dispatch.

All wall-clock numbers are median-of-N with ``block_until_ready``
inside the timed region.

The acceptance contract recorded into ``BENCH_rank.json`` (repo root):
two-stage recall@10 strictly above collision-only recall@10 at equal k,
with re-rank overhead <= 25% of the coarse-pass latency at m=4k (and
strictly positive — a zero overhead means the measurement is broken).
Collision counts cap at k+1 distinct values, so the tail of a top-10 is
decided inside large count-ties essentially at random; the LUT scores
split those ties with the full contingency table's evidence — that is
where the recall comes back.
"""
import json
import os
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

if __package__ in (None, ""):            # direct `python benchmarks/rank_bench.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

from benchmarks._util import write_csv
from repro.ann import AnnEngine, BandSpec
from repro.ann.engine import SearchConfig
from repro.core.sketch import CodedRandomProjection, SketchConfig
from repro.obs import Tracer

K, TOP_K, RERANK_M = 64, 10, 4096


def _unit(x):
    return x / jnp.linalg.norm(x, axis=-1, keepdims=True)


def make_workload(key, d, n_clusters, per, nq, rho_m=0.92, rho_q=0.92):
    """Clustered corpus [n_clusters*per, d] + queries near nq centers."""
    kc, km, kq = jax.random.split(key, 3)
    centers = _unit(jax.random.normal(kc, (n_clusters, d)))
    noise = _unit(jax.random.normal(km, (n_clusters, per, d)))
    corpus = _unit(rho_m * centers[:, None, :]
                   + np.sqrt(1 - rho_m ** 2) * noise).reshape(-1, d)
    qn = _unit(jax.random.normal(jax.random.fold_in(kq, 1), (nq, d)))
    queries = _unit(rho_q * centers[:nq] + np.sqrt(1 - rho_q ** 2) * qn)
    return corpus, queries


def _timed(fn, repeat=5):
    jax.block_until_ready(fn())            # warm the jit caches
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _span_split(engine, q_codes, cfg, repeat=5):
    """Median (coarse_s, rerank_s) of a scored search's two stages,
    each measured as its own device-synced ``repro.obs`` span."""
    with Tracer():
        engine.search_codes(q_codes, cfg)  # warm the stage-pair jits
    coarse, rerank = [], []
    for _ in range(repeat):
        with Tracer() as tr:
            engine.search_codes(q_codes, cfg)
        coarse.append(tr.total("search.coarse"))
        rerank.append(tr.total("search.rerank"))
    return float(np.median(coarse)), float(np.median(rerank))


def _recall(ids, gt):
    return float(np.mean([len(set(np.asarray(a)) & set(b)) / gt.shape[1]
                          for a, b in zip(ids, gt)]))


def _bench(d, n_clusters, per, nq, rerank_m):
    key = jax.random.PRNGKey(0)
    corpus, queries = make_workload(key, d, n_clusters, per, nq)
    n = corpus.shape[0]
    crp = CodedRandomProjection(SketchConfig(k=K, scheme="2bit", w=0.75), d)
    engine = AnnEngine.build(crp, corpus, BandSpec(n_tables=8, band_width=4))
    m = min(rerank_m, n)

    # float32 cosine ground truth (the quality bar)
    gt = np.asarray(jax.lax.top_k(queries @ corpus.T, TOP_K)[1])

    ids_plain, _ = engine.search(queries, TOP_K, mode="exact", chunk_q=nq)
    ids_scored, _ = engine.search(queries, TOP_K, mode="exact", scored=True,
                                  rerank_m=m, chunk_q=nq)
    recall_plain = _recall(np.asarray(ids_plain), gt)
    recall_scored = _recall(np.asarray(ids_scored), gt)

    # latency split at top-m: each stage measured as its own
    # device-synced span (search.coarse / search.rerank)
    q_codes = engine.encode_queries(queries)
    cfg = SearchConfig(top_k=TOP_K, mode="exact", scored=True, rerank_m=m,
                       chunk_q=nq)
    t_coarse, t_rerank = _span_split(engine, q_codes, cfg)
    two_stage = engine._chunk_fn(cfg)
    t_two = _timed(lambda: two_stage(q_codes))
    cfg_p = SearchConfig(top_k=TOP_K, mode="exact", chunk_q=nq)
    t_plain = _timed(lambda: engine._chunk_fn(cfg_p)(q_codes))

    return {
        "corpus": n, "queries": nq, "k": K, "bits": 2, "top_k": TOP_K,
        "rerank_m": m,
        "recall_at_10_collision": recall_plain,
        "recall_at_10_two_stage": recall_scored,
        "recall_gain": recall_scored - recall_plain,
        "t_coarse_topm_s": t_coarse, "t_two_stage_s": t_two,
        "t_collision_top10_s": t_plain,
        "rerank_overhead_s": t_rerank,
        "rerank_overhead_frac": t_rerank / t_coarse,
        "qps_two_stage": nq / t_two,
        "qps_collision_only": nq / t_plain,
        "timing": "span-derived, device-synced, median-of-5",
    }


def _rows(r):
    return [
        ("rank_two_stage", 1e6 * r["t_two_stage_s"] / r["queries"],
         f"recall@10={r['recall_at_10_two_stage']:.3f} "
         f"m={r['rerank_m']}"),
        ("rank_collision_only", 1e6 * r["t_collision_top10_s"] / r["queries"],
         f"recall@10={r['recall_at_10_collision']:.3f}"),
        ("rank_rerank_overhead", 1e6 * r["rerank_overhead_s"] / r["queries"],
         f"frac_of_coarse={r['rerank_overhead_frac']:.3f}"),
    ]


def run(quick: bool = True):
    """run.py contract: (name, us_per_query, derived) rows."""
    r = _bench(d=64, n_clusters=1000 if quick else 16384, per=8,
               nq=32 if quick else 64, rerank_m=512 if quick else RERANK_M)
    rows = _rows(r)
    write_csv("rank_bench", ["name", "us_per_query", "derived"], rows)
    return rows


def main():
    r = _bench(d=64, n_clusters=16384, per=8, nq=64, rerank_m=RERANK_M)
    write_csv("rank_bench", ["name", "us_per_query", "derived"], _rows(r))
    with open(os.path.join(_ROOT, "BENCH_rank.json"), "w") as f:
        json.dump(r, f, indent=1)
    print("BENCH " + json.dumps(r))
    print(f"\ntwo-stage recall@10 {r['recall_at_10_two_stage']:.3f} vs "
          f"collision-only {r['recall_at_10_collision']:.3f} "
          f"(+{r['recall_gain']:.3f}) on {r['corpus']} rows")
    print(f"re-rank overhead at m={r['rerank_m']}: "
          f"{100 * r['rerank_overhead_frac']:.1f}% of the coarse pass "
          f"({1e3 * r['rerank_overhead_s']:.1f} ms vs "
          f"{1e3 * r['t_coarse_topm_s']:.1f} ms)")
    ok = (r["recall_at_10_two_stage"] > r["recall_at_10_collision"]
          and 0.0 < r["rerank_overhead_frac"] <= 0.25)
    print("acceptance: " + ("PASS" if ok else "FAIL"))
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
