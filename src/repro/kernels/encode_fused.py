"""Fused projection → coding → bit-packing Pallas kernels (the ingest path).

The paper's storage economy only pays off end-to-end if *producing* the
codes is as lean as storing them.  ``kernels/proj_code.py`` fuses the
GEMM with the coding scheme but still writes int32 codes (4 bytes per
projection) to HBM before a separate packing pass; these kernels take
the epilogue one stage further, so the ONLY HBM write-back of an encode
is the final packed uint32 words — b bits per projection, a 16x traffic
cut at b=2 versus f32 projections and 16x versus int32 codes.

Two entry points share the epilogue:

``encode_fused_pallas``   x [M, D] @ r [D, K] → uint32 words [M, W]:
    grid (M/bm, D/bd), f32 VMEM accumulator over the reduction axis
    (minor-most = sequential on TPU), code + pack applied in-register on
    the final reduction step.  K is held whole per tile (acc [bm, K]
    f32 ≈ 128 KB at K=256) because packing mixes all K fields of a row.
``code_pack_pallas``      z [M, K] → uint32 words [M, W]:
    the epilogue alone, for pre-projected values — the finalize stage of
    the matrix-free streaming path (``repro.encode.encoder``), whose
    GEMM accumulates across host-loop steps with a donated slab.

Both are bit-exact (packed words included) against the jnp oracles
``kernels.ref.encode_fused_ref`` / ``code_pack_ref`` for all four
schemes; padded K fields are forced to code 0 in-register, matching the
zero-padding of ``core.packing.pack_codes``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import codes_per_word
from repro.core.schemes import CodeSpec
from repro.kernels.proj_code import _apply_code, _pad_to

__all__ = ["encode_fused_pallas", "code_pack_pallas"]


def _code_and_pack(z, q_row, spec: CodeSpec, k: int):
    """In-register epilogue: f32 tile z [bm, kp] -> uint32 [bm, kp*b/32].

    Fields past the real ``k`` are forced to code 0 (the pack oracle's
    zero padding); fields are disjoint so the bitwise-or is an integer
    dot with the shift vector (VPU multiply-accumulate).
    """
    bits = spec.bits
    cpw = codes_per_word(bits)
    bm, kp = z.shape
    codes = _apply_code(z, q_row, spec)
    col = jax.lax.broadcasted_iota(jnp.int32, (bm, kp), 1)
    codes = jnp.where(col < k, codes, 0).astype(jnp.uint32)
    codes = codes.reshape(bm, kp // cpw, cpw)
    shifts = (jnp.arange(cpw, dtype=jnp.uint32) * jnp.uint32(bits))
    return jnp.sum(codes << shifts, axis=-1, dtype=jnp.uint32)


def _fused_kernel(x_ref, r_ref, q_ref, o_ref, acc_ref, *,
                  spec: CodeSpec, k: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], r_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _finalize():
        o_ref[...] = _code_and_pack(acc_ref[...], q_ref[...], spec, k)


@functools.partial(
    jax.jit,
    static_argnames=("spec", "block_m", "block_d", "interpret"))
def encode_fused_pallas(x, r, spec: CodeSpec, q: Optional[jax.Array] = None,
                        *, block_m: int = 128, block_d: int = 512,
                        interpret: bool = False):
    """x [M, D] (f32/bf16) @ r [D, K] -> packed uint32 [M, ceil(K·b/32)].

    Fuses GEMM-accumulate, the coding scheme under ``spec`` and b-bit
    packing; neither f32 projections nor int32 codes ever reach HBM.
    ``q`` (offset scheme) is a [K] vector; ignored (zeros) otherwise.
    """
    m, d = x.shape
    d2, k = r.shape
    assert d == d2, (x.shape, r.shape)
    if q is None:
        q = jnp.zeros((k,), jnp.float32)
    cpw = codes_per_word(spec.bits)
    lane = 128 if 128 % cpw == 0 else cpw      # cpw divides 128 for b<=16
    xp = _pad_to(_pad_to(x, block_m, 0), block_d, 1)
    rp = _pad_to(_pad_to(r, lane, 1), block_d, 0)
    qp = _pad_to(q.astype(jnp.float32)[None, :], lane, 1)
    mp, dp = xp.shape
    kp = rp.shape[1]
    nw = kp // cpw
    grid = (mp // block_m, dp // block_d)

    out = pl.pallas_call(
        functools.partial(_fused_kernel, spec=spec, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_d), lambda i, s: (i, s)),
            pl.BlockSpec((block_d, kp), lambda i, s: (s, 0)),
            pl.BlockSpec((1, kp), lambda i, s: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, nw), lambda i, s: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, nw), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((block_m, kp), jnp.float32)],
        interpret=interpret,
    )(xp, rp, qp)
    # lane padding beyond the real packed width holds all-zero fields
    return out[:m, :(k + cpw - 1) // cpw]


def _pack_kernel(z_ref, q_ref, o_ref, *, spec: CodeSpec, k: int):
    o_ref[...] = _code_and_pack(z_ref[...].astype(jnp.float32),
                                q_ref[...], spec, k)


@functools.partial(
    jax.jit, static_argnames=("spec", "block_m", "interpret"))
def code_pack_pallas(z, spec: CodeSpec, q: Optional[jax.Array] = None,
                     *, block_m: int = 256, interpret: bool = False):
    """Projected z [M, K] float -> packed uint32 [M, ceil(K·b/32)].

    The fused epilogue alone: coding scheme + b-bit pack in one VMEM
    pass (row-blocked), int32 codes never materialized.
    """
    m, k = z.shape
    if q is None:
        q = jnp.zeros((k,), jnp.float32)
    cpw = codes_per_word(spec.bits)
    lane = 128 if 128 % cpw == 0 else cpw
    zp = _pad_to(_pad_to(z, block_m, 0), lane, 1)
    qp = _pad_to(q.astype(jnp.float32)[None, :], lane, 1)
    mp, kp = zp.shape
    nw = kp // cpw
    out = pl.pallas_call(
        functools.partial(_pack_kernel, spec=spec, k=k),
        grid=(mp // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, kp), lambda i: (i, 0)),
            pl.BlockSpec((1, kp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, nw), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, nw), jnp.uint32),
        interpret=interpret,
    )(zp, qp)
    return out[:m, :(k + cpw - 1) // cpw]
