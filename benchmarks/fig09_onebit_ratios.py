"""Figs 9+10: variance ratios Var(rho_1)/Var(rho_w) and /Var(rho_{w,2}) —
how much accuracy 1-bit coding loses, at optimal and at fixed w."""
import numpy as np
import jax.numpy as jnp

from repro.core import variance as V
from repro.core.optimal import optimal_w
from benchmarks._util import timed, write_csv


def run(quick: bool = True):
    rhos = np.concatenate([np.linspace(0.01, 0.9, 20),
                           1 - np.geomspace(0.1, 0.005, 12)])
    rho = jnp.asarray(rhos)

    def compute():
        v1 = np.asarray(V.variance_factor_sign(rho))
        _, vu = optimal_w(rho, "uniform")
        _, v2 = optimal_w(rho, "2bit")
        fixed = {w: (np.asarray(V.variance_factor_uniform(rho, w)),
                     np.asarray(V.variance_factor_2bit(rho, w)))
                 for w in (0.5, 0.75, 1.0, 2.0)}
        return v1, np.asarray(vu), np.asarray(v2), fixed

    (v1, vu, v2, fixed), us = timed(compute, repeat=1)
    rows = [[r, v1[i] / vu[i], v1[i] / v2[i]] for i, r in enumerate(rhos)]
    write_csv("fig09_max_ratios", ["rho", "V1_over_Vw_opt", "V1_over_Vw2_opt"],
              rows)
    rows10 = []
    for w, (vw_f, v2_f) in fixed.items():
        for i, r in enumerate(rhos):
            rows10.append([w, r, v1[i] / vw_f[i], v1[i] / v2_f[i]])
    write_csv("fig10_fixed_ratios", ["w", "rho", "V1_over_Vw", "V1_over_Vw2"],
              rows10)
    # paper: at w=0.75, high-similarity ratio V1/V_{w,2} is between 2 and 3
    hi = np.argmin(np.abs(rhos - 0.95))
    r_hi = v1[hi] / fixed[0.75][1][hi]
    return [("fig09_10", us, f"V1_over_Vw2@rho0.95_w0.75={r_hi:.2f};paper:2-3")]
