"""repro.index lifecycle contract: masked-kernel parity, segment-log
mutation semantics, randomized add/delete/upsert/compact vs a fresh-build
oracle, snapshot/restore equivalence, and the serving-layer cache."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.ann import AnnEngine, BandSpec
from repro.ann.engine import SearchConfig, merge_topk
from repro.core import packing as PK
from repro.core.sketch import CodedRandomProjection, SketchConfig
from repro.index import (CompactionPolicy, MutableAnnEngine, SegmentLogStore,
                         compact, plan_compaction, restore_index, save_index)
from repro.index.segment_log import _np_pack_bitmask, _np_unpack_bitmask
from repro.kernels import ref
from repro.kernels.packed_collision import packed_topk_masked_pallas
from repro.serve.ann_service import AnnService, AnnServiceConfig

D, K, BITS = 16, 64, 2
BAND = BandSpec(n_tables=16, band_width=4)


def _crp():
    return CodedRandomProjection(
        SketchConfig(k=K, scheme="2bit", w=0.75), D)


def _codes(rng, m, k=K, bits=BITS):
    return jnp.asarray(rng.integers(0, 1 << bits, (m, k)), jnp.int32)


# -- packed validity bitmask --------------------------------------------------

@pytest.mark.parametrize("n", [1, 31, 32, 33, 100])
def test_bitmask_roundtrip(n):
    rng = np.random.default_rng(n)
    flags = rng.random(n) < 0.5
    words = PK.pack_bitmask(jnp.asarray(flags))
    assert words.shape == (PK.bitmask_width(n),)
    np.testing.assert_array_equal(
        np.asarray(PK.unpack_bitmask(words, n)), flags)
    # host-side twin used by the segment log agrees bit for bit
    np.testing.assert_array_equal(_np_pack_bitmask(flags),
                                  np.asarray(words))
    np.testing.assert_array_equal(
        _np_unpack_bitmask(np.asarray(words), n), flags)


# -- masked streaming top-k kernel vs oracle ----------------------------------

@pytest.mark.parametrize("bits,k", [(1, 33), (2, 128), (4, 30)])
@pytest.mark.parametrize("top_k", [1, 7])
def test_packed_topk_masked_matches_oracle(bits, k, top_k):
    """Kernel == masked ref == dense mask-then-topk oracle, and dead rows
    never surface."""
    rng = np.random.default_rng(bits * 10 + top_k)
    wq = PK.pack_codes(_codes(rng, 9, k, bits), bits)
    wdb = PK.pack_codes(_codes(rng, 70, k, bits), bits)
    live = rng.random(70) < 0.6
    vw = PK.pack_bitmask(jnp.asarray(live))
    rv, ri = ref.packed_topk_masked_ref(wq, wdb, vw, bits, k, top_k)
    gv, gi = packed_topk_masked_pallas(wq, wdb, vw, bits, k, top_k,
                                       block_q=8, block_n=32,
                                       interpret=True)
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(ri))
    counts = np.asarray(ref.packed_collision_ref(wq, wdb, bits, k)).copy()
    counts[:, ~live] = -1
    ov, oi = ref.topk_stable_ref(jnp.asarray(counts), top_k)
    np.testing.assert_array_equal(np.asarray(rv), np.asarray(ov))
    np.testing.assert_array_equal(np.asarray(ri), np.asarray(oi))
    surfaced = set(np.asarray(ri)[np.asarray(rv) >= 0].ravel().tolist())
    assert not surfaced & set(np.flatnonzero(~live).tolist())


def test_packed_topk_masked_overflow_and_all_dead():
    """top_k beyond the live count fills (-1, -1); an all-dead mask
    returns nothing at all."""
    rng = np.random.default_rng(0)
    wq = PK.pack_codes(_codes(rng, 3, 20, 2), 2)
    wdb = PK.pack_codes(_codes(rng, 10, 20, 2), 2)
    live = np.zeros(10, bool)
    live[[2, 5]] = True
    for vw in [PK.pack_bitmask(jnp.asarray(live)),
               PK.pack_bitmask(jnp.zeros(10, bool))]:
        n_live = int(np.asarray(PK.unpack_bitmask(vw, 10)).sum())
        for fn in [
            lambda: ref.packed_topk_masked_ref(wq, wdb, vw, 2, 20, 6),
            lambda: packed_topk_masked_pallas(wq, wdb, vw, 2, 20, 6,
                                              block_q=8, block_n=32,
                                              interpret=True),
        ]:
            v, i = fn()
            assert (np.asarray(v[:, n_live:]) == -1).all()
            assert (np.asarray(i[:, n_live:]) == -1).all()


def test_merge_topk_tie_break_matches_single_store():
    """Cross-segment merge == one top-k over the concatenated scores."""
    rng = np.random.default_rng(3)
    parts = [jnp.asarray(rng.integers(0, 6, (4, 50)), jnp.int32)
             for _ in range(3)]
    full = jnp.concatenate(parts, axis=1)
    want_v, want_i = ref.topk_stable_ref(full, 5)
    vals_l, ids_l, off = [], [], 0
    for p in parts:
        v, i = ref.topk_stable_ref(p, 5)
        vals_l.append(v)
        ids_l.append(jnp.where(v < 0, -1, i + off))
        off += p.shape[1]
    got_v, got_i = merge_topk(vals_l, ids_l, 5)
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))


# -- segment log: mutation semantics ------------------------------------------

def test_segment_log_add_seal_delete_upsert():
    rng = np.random.default_rng(7)
    store = SegmentLogStore(K, BITS, band_spec=BAND, tail_rows=32)
    ids = store.add_codes(_codes(rng, 70))
    assert store.n_segments == 3 and store.tail.length == 6
    assert store.n_live == 70 and list(store.live_ids()) == list(range(70))
    # tombstones drop rows everywhere, including sealed segments
    assert store.delete(ids[:5]) == 5
    assert store.n_live == 65 and 0 not in store
    with pytest.raises(KeyError):
        store.delete([0])
    assert store.delete([0], strict=False) == 0
    # upsert keeps the external id, moves the row to the tail
    old_codes = np.asarray(store.live_codes())
    store.upsert_codes(ids[10:12], _codes(rng, 2))
    assert store.n_live == 65 and int(ids[10]) in store
    # iteration order: surviving originals first, upserted versions last
    assert list(store.live_ids()[-2:]) == [int(ids[10]), int(ids[11])]
    # explicit-id add collides with a live id
    with pytest.raises(ValueError):
        store.add_codes(_codes(rng, 1), ids=np.asarray([int(ids[11])]))
    del old_codes


def test_mutation_failures_are_atomic():
    """A raising mutator must leave the store untouched: strict deletes
    validate before tombstoning, upserts validate before deleting, and
    duplicate ids within one batch are rejected up front."""
    rng = np.random.default_rng(37)
    store = SegmentLogStore(K, BITS, tail_rows=32)
    ids = store.add_codes(_codes(rng, 10))
    gen = store.generation
    # strict delete with one unknown id: nothing dies, generation frozen
    with pytest.raises(KeyError):
        store.delete([int(ids[1]), 999])
    assert int(ids[1]) in store and store.generation == gen
    assert store.n_live == 10
    # bad upsert (wrong code width): old rows must survive
    with pytest.raises(ValueError):
        store.upsert_codes([int(ids[2])], jnp.zeros((1, 5), jnp.int32))
    assert int(ids[2]) in store and store.n_live == 10
    # duplicate ids in one batch: rejected before any mutation
    with pytest.raises(ValueError):
        store.add_codes(_codes(rng, 2), ids=np.asarray([50, 50]))
    with pytest.raises(ValueError):
        store.upsert_codes(np.asarray([int(ids[3])] * 2), _codes(rng, 2))
    # out-of-int32-range id in an upsert batch: validated before the
    # tombstone, so the in-range id's old version survives
    with pytest.raises(ValueError):
        store.upsert_codes(np.asarray([int(ids[4]), 2 ** 40]),
                           _codes(rng, 2))
    assert int(ids[4]) in store
    assert store.n_live == 10 and store.generation == gen
    np.testing.assert_array_equal(store.live_ids(), ids)


def test_segment_log_add_is_o_batch():
    """The donated tail write never reallocates the buffer: the tail
    array keeps its shape, and sealed segment buffers are reused as-is
    (object identity), so ingest copies O(batch), not O(corpus)."""
    rng = np.random.default_rng(8)
    store = SegmentLogStore(K, BITS, tail_rows=32)
    store.add_codes(_codes(rng, 32))          # exactly one sealed segment
    sealed_words = store.sealed[0].words
    store.add_codes(_codes(rng, 48))
    assert store.sealed[0].words is sealed_words
    assert store.tail.words.shape == (32, store.n_words)


def test_live_words_match_fresh_pack():
    rng = np.random.default_rng(9)
    store = SegmentLogStore(K, BITS, tail_rows=32)
    codes = _codes(rng, 50)
    ids = store.add_codes(codes)
    store.delete(ids[::4])
    keep = np.ones(50, bool)
    keep[::4] = False
    np.testing.assert_array_equal(np.asarray(store.live_codes()),
                                  np.asarray(codes)[keep])
    np.testing.assert_array_equal(store.live_ids(), ids[keep])


# -- lifecycle contract vs fresh-build oracle ---------------------------------

def _oracle_search(eng, q_codes, cfg):
    """Fresh immutable store built from the surviving rows (the
    acceptance-criteria oracle), results mapped back to external ids."""
    live_ids = eng.store.live_ids()
    fresh = AnnEngine.from_codes(eng.sketcher, eng.store.live_codes(),
                                 eng.band_spec or BAND)
    rows, rho = fresh.search_codes(q_codes, cfg)
    rows = np.asarray(rows)
    safe = np.clip(rows, 0, max(len(live_ids) - 1, 0))
    ids = np.where(rows < 0, -1,
                   live_ids[safe] if len(live_ids) else -1)
    return ids, np.asarray(rho)


def _check_vs_oracle(eng, q_codes, modes=("exact", "lsh")):
    for mode in modes:
        cfg = SearchConfig(top_k=7, mode=mode, n_probes=1, chunk_q=8)
        got_i, got_r = eng.search_codes(q_codes, cfg)
        want_i, want_r = _oracle_search(eng, q_codes, cfg)
        np.testing.assert_array_equal(np.asarray(got_i), want_i)
        np.testing.assert_allclose(np.asarray(got_r), want_r, rtol=1e-6)


def _random_lifecycle(seed, n_ops, tail_rows=32):
    rng = np.random.default_rng(seed)
    eng = MutableAnnEngine(_crp(), band_spec=BAND, tail_rows=tail_rows)
    live = []
    for _ in range(n_ops):
        op = rng.choice(["add", "delete", "upsert", "compact"],
                        p=[0.5, 0.25, 0.15, 0.1])
        if op == "add" or not live:
            ids = eng.add_codes(_codes(rng, int(rng.integers(1, 40))))
            live.extend(int(i) for i in ids)
        elif op == "delete":
            kill = rng.choice(len(live),
                              size=min(len(live),
                                       int(rng.integers(1, 10))),
                              replace=False)
            eng.delete([live[i] for i in kill])
            live = [x for i, x in enumerate(live)
                    if i not in set(kill.tolist())]
        elif op == "upsert":
            pick = [live[i] for i in
                    rng.choice(len(live), size=min(len(live), 3),
                               replace=False)]
            eng.upsert_codes(np.asarray(pick, np.int64),
                             _codes(rng, len(pick)))
        else:
            eng.compact(CompactionPolicy(target_rows=4 * tail_rows))
    return eng, rng


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_lifecycle_matches_fresh_build(seed):
    """Randomized add/delete/upsert/compact sequences: engine results ==
    fresh immutable store of the surviving rows, both search modes."""
    eng, rng = _random_lifecycle(seed, n_ops=25)
    assert eng.store.n_live > 0
    _check_vs_oracle(eng, _codes(rng, 9))


@pytest.mark.slow
def test_lifecycle_hypothesis_sequences():
    """Property-based op sequences (real hypothesis when installed,
    else the seeded shim in ``_hypothesis_compat`` — never skipped)."""
    from _hypothesis_compat import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=0, max_value=10 ** 6),
           st.integers(min_value=5, max_value=15))
    def prop(seed, n_ops):
        eng, rng = _random_lifecycle(seed, n_ops)
        _check_vs_oracle(eng, _codes(rng, 4), modes=("exact",))

    prop()


def test_mutable_engine_matches_immutable_when_append_only():
    """No deletes: the mutable engine is just a sharded immutable store;
    ids coincide with row numbers and results with AnnEngine."""
    rng = np.random.default_rng(11)
    codes = _codes(rng, 90)
    eng = MutableAnnEngine(_crp(), band_spec=BAND, tail_rows=32)
    eng.add_codes(codes)
    base = AnnEngine.from_codes(_crp(), codes, BAND)
    q = _codes(rng, 6)
    for mode in ("exact", "lsh"):
        cfg = SearchConfig(top_k=5, mode=mode, n_probes=1, chunk_q=8)
        gi, gr = eng.search_codes(q, cfg)
        wi, wr = base.search_codes(q, cfg)
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
        np.testing.assert_allclose(np.asarray(gr), np.asarray(wr),
                                   rtol=1e-6)


# -- compaction ---------------------------------------------------------------

def test_compaction_drops_dead_rows_and_preserves_results():
    rng = np.random.default_rng(13)
    eng = MutableAnnEngine(_crp(), band_spec=BAND, tail_rows=32)
    ids = eng.add_codes(_codes(rng, 128))       # 4 sealed segments
    eng.delete(ids[::2])
    q = _codes(rng, 5)
    before = eng.search_codes(q, SearchConfig(top_k=9, chunk_q=8))
    st = compact(eng.store, CompactionPolicy(target_rows=128))
    assert st["segments_after"] < st["segments_before"]
    assert st["rows_dropped"] > 0
    assert eng.store.n_rows == eng.store.n_live  # sealed dead rows gone
    after = eng.search_codes(q, SearchConfig(top_k=9, chunk_q=8))
    np.testing.assert_array_equal(np.asarray(before[0]),
                                  np.asarray(after[0]))
    np.testing.assert_allclose(np.asarray(before[1]),
                               np.asarray(after[1]))
    _check_vs_oracle(eng, q)


def test_compaction_plan_respects_target_and_tiering():
    rng = np.random.default_rng(14)
    store = SegmentLogStore(K, BITS, tail_rows=32)
    store.add_codes(_codes(rng, 96))            # 3 sealed, fully live
    # nothing to gain: single full segments below the dead threshold
    assert plan_compaction(store, CompactionPolicy(
        target_rows=32, max_dead_fraction=0.25)) == []
    # room to merge: adjacent runs group under the target
    runs = plan_compaction(store, CompactionPolicy(target_rows=64))
    assert runs == [[0, 1]]
    stats = compact(store, CompactionPolicy(target_rows=64))
    assert stats["segments_after"] == 2
    assert [s.length for s in store.sealed] == [64, 32]


# -- snapshot / restore -------------------------------------------------------

def test_snapshot_restore_roundtrip(tmp_path):
    eng, rng = _random_lifecycle(17, n_ops=20)
    q = _codes(rng, 6)
    cfg = SearchConfig(top_k=7, chunk_q=8)
    want = eng.search_codes(q, cfg)
    eng.save(str(tmp_path), 3)
    eng2 = MutableAnnEngine.restore(_crp(), str(tmp_path))
    assert eng2.store.n_live == eng.store.n_live
    assert eng2.store.next_id == eng.store.next_id
    got = eng2.search_codes(q, cfg)
    np.testing.assert_array_equal(np.asarray(want[0]), np.asarray(got[0]))
    np.testing.assert_allclose(np.asarray(want[1]), np.asarray(got[1]))
    _check_vs_oracle(eng2, q)
    # ingestion resumes: fresh ids, tail picks up where it stopped
    tail_len = eng2.store.tail.length
    new_ids = eng2.add_codes(_codes(rng, 3))
    assert new_ids.min() >= eng.store.next_id
    assert eng2.store.tail.length == (tail_len + 3) % eng2.store.tail_rows


def test_snapshot_restore_no_band_spec(tmp_path):
    rng = np.random.default_rng(19)
    store = SegmentLogStore(K, BITS, tail_rows=32)
    ids = store.add_codes(_codes(rng, 40))
    store.delete(ids[:7])
    save_index(store, str(tmp_path), 1)
    back = restore_index(str(tmp_path), 1)
    assert back.band_spec is None and back.tail.hashes is None
    np.testing.assert_array_equal(back.live_ids(), store.live_ids())
    np.testing.assert_array_equal(np.asarray(back.live_words()),
                                  np.asarray(store.live_words()))


def test_restore_missing_snapshot_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_index(str(tmp_path))


# -- serving layer ------------------------------------------------------------

def test_service_mutation_endpoints_and_cache():
    rng = np.random.default_rng(23)
    eng = MutableAnnEngine(_crp(), band_spec=BAND, tail_rows=64)
    svc = AnnService(eng, AnnServiceConfig(top_k=3, buckets=(1, 4, 8),
                                           cache_size=16))
    svc.add(jnp.asarray(rng.normal(size=(40, D)), jnp.float32))
    q = jnp.asarray(rng.normal(size=(D,)), jnp.float32)
    t1 = svc.submit(q)
    svc.flush()
    t2 = svc.submit(q)
    svc.flush()
    assert svc.stats["cache_hits"] == 1 and svc.stats["cache_misses"] == 1
    i1, _ = svc.result(t1)
    i2, _ = svc.result(t2)
    np.testing.assert_array_equal(i1, i2)
    # a delete invalidates: the old top hit must disappear
    top = int(i1[0])
    assert svc.delete([top]) == 1
    t3 = svc.submit(q)
    svc.flush()
    i3, _ = svc.result(t3)
    assert svc.stats["cache_misses"] == 2
    assert top not in set(i3.tolist())
    # interleaved adds keep serving
    svc.add(jnp.asarray(rng.normal(size=(8, D)), jnp.float32))
    t4 = svc.submit(q)
    out = svc.flush()
    assert t4 in out
    # partial-hit batch: cached query + fresh query in one flush
    q2 = jnp.asarray(rng.normal(size=(D,)), jnp.float32)
    t5, t6 = svc.submit(q), svc.submit(q2)
    hits_before = svc.stats["cache_hits"]
    out = svc.flush()
    assert svc.stats["cache_hits"] == hits_before + 1
    np.testing.assert_array_equal(svc.result(t5)[0], svc.result(t4)[0])
    assert t6 in out


def test_service_cache_eviction_and_capacity():
    rng = np.random.default_rng(29)
    eng = MutableAnnEngine(_crp(), band_spec=BAND, tail_rows=64)
    svc = AnnService(eng, AnnServiceConfig(top_k=3, buckets=(1, 4, 8),
                                           cache_size=4))
    svc.add(jnp.asarray(rng.normal(size=(20, D)), jnp.float32))
    for i in range(8):
        svc.submit(jnp.asarray(rng.normal(size=(D,)), jnp.float32))
    svc.flush()
    assert len(svc._cache) <= 4


def test_service_immutable_engine_rejects_mutation(small_ann_engine=None):
    rng = np.random.default_rng(31)
    codes = _codes(rng, 30)
    base = AnnEngine.from_codes(_crp(), codes, BAND)
    svc = AnnService(base, AnnServiceConfig(top_k=3, buckets=(1, 4)))
    with pytest.raises(TypeError):
        svc.add(jnp.zeros((1, D)))
    with pytest.raises(TypeError):
        svc.delete([0])
    # read path still works, cache included
    q = jnp.asarray(rng.normal(size=(D,)), jnp.float32)
    t1 = svc.submit(q)
    svc.flush()
    t2 = svc.submit(q)
    svc.flush()
    assert svc.stats["cache_hits"] == 1
    np.testing.assert_array_equal(svc.result(t1)[0], svc.result(t2)[0])
