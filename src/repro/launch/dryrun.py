import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- multi-pod dry-run: lower + compile every (arch x shape x mesh) cell ---
# The two lines above MUST precede any jax-importing module: jax locks the
# device count at first init, and only the dry-run wants 512 host devices.

import argparse   # noqa: E402
import gc         # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
from functools import partial  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs as C                      # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import roofline as R              # noqa: E402
from repro.models import lm as L                    # noqa: E402
from repro.models.nn import abstract_params, param_shardings  # noqa: E402
from repro.optim import AdamWConfig, init_opt_state  # noqa: E402
from repro.parallel.sharding import ShardingRules   # noqa: E402
from repro.train import make_train_step, make_state_shardings  # noqa: E402

SHAPES = {
    # name: (kind, seq_len, global_batch)
    "train_4k": ("train", 4096, 256),
    "prefill_32k": ("prefill", 32768, 32),
    "decode_32k": ("decode", 32768, 128),
    "long_500k": ("decode", 524288, 1),
}

# per-shape logical-rule overrides (the long-context decode shards the KV
# sequence over the data axis: context parallelism)
SHAPE_RULES = {
    "long_500k": {"seq_kv": "data", "batch": None},
}


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _token_shape(cfg, batch, seq):
    return (batch, seq, cfg.n_codebooks) if cfg.n_codebooks > 1 else (batch, seq)


def input_specs(arch: str, shape_name: str = "train_4k", rules=None):
    """ShapeDtypeStruct stand-ins for every model input of a cell
    (weak-type-correct, shardable, no device allocation).

    train shapes -> {"tokens"}; decode shapes -> {"tokens", "pos"}
    (+ caches are built abstractly inside lower_cell). The [audio]/[vlm]
    modality frontends are stubs per the assignment: tokens already are
    codebook/VQ ids.
    """
    cfg = C.get_config(arch)
    kind, seq, batch = SHAPES[shape_name]
    tshape = _token_shape(cfg, batch, seq if kind != "decode" else 1)
    names = ("batch", "seq", "codebooks")[:len(tshape)]
    sh = rules.sharding_for(tshape, names) if rules is not None else None
    specs = {"tokens": _sds(tshape, jnp.int32, sh)}
    if kind == "decode":
        specs["pos"] = _sds((), jnp.int32)
    return specs


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               rules_overrides=None, opt_cfg=None, cfg=None):
    """Returns (lowered, meta) for one dry-run cell."""
    cfg = cfg or C.get_config(arch)
    kind, seq, batch = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    overrides = dict(SHAPE_RULES.get(shape_name, {}))
    overrides.update(rules_overrides or {})
    rules = ShardingRules(mesh).with_overrides(**overrides)
    specs = L.model_param_specs(cfg)
    p_shard = param_shardings(specs, rules)
    aparams = jax.tree.map(
        lambda s, sh: _sds(s.shape, jnp.dtype(s.dtype), sh),
        specs, p_shard,
        is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "init"))
    meta = {"arch": arch, "shape": shape_name, "kind": kind,
            "mesh": "multi" if multi_pod else "single",
            "devices": mesh.size, "seq": seq, "batch": batch}

    if kind == "train":
        opt_cfg = opt_cfg or AdamWConfig()
        _, opt_shard = make_state_shardings(cfg, rules, opt_cfg.master_fp32)
        aopt = jax.eval_shape(partial(init_opt_state, cfg=opt_cfg), aparams)
        aopt = jax.tree.map(lambda s, sh: _sds(s.shape, s.dtype, sh),
                            aopt, opt_shard)
        tok_sh = rules.sharding_for(_token_shape(cfg, batch, seq),
                                    ("batch", "seq", "codebooks")[
                                        :len(_token_shape(cfg, batch, seq))])
        atok = _sds(_token_shape(cfg, batch, seq), jnp.int32, tok_sh)
        step = make_train_step(cfg, opt_cfg, rules, donate=True)
        lowered = step.lower(aparams, aopt, atok)
        return lowered, meta

    if kind == "prefill":
        tshape = _token_shape(cfg, batch, seq)
        tok_sh = rules.sharding_for(tshape, ("batch", "seq", "codebooks")[:len(tshape)])
        atok = _sds(tshape, jnp.int32, tok_sh)
        fn = jax.jit(lambda p, t: L.prefill(p, t, cfg, rules, max_len=seq))
        lowered = fn.lower(aparams, atok)
        return lowered, meta

    # decode: one new token against a seq-long cache
    cache_builder = jax.jit(partial(L.init_caches, cfg, batch, seq, rules))
    cache_sh = cache_builder.lower().compile().output_shardings
    acache = jax.eval_shape(cache_builder)
    acache = jax.tree.map(lambda s, sh: _sds(s.shape, s.dtype, sh),
                          acache, cache_sh)
    tshape = _token_shape(cfg, batch, 1)
    tok_sh = rules.sharding_for(tshape, ("batch", "seq", "codebooks")[:len(tshape)])
    atok = _sds(tshape, jnp.int32, tok_sh)
    apos = _sds((), jnp.int32)
    fn = jax.jit(lambda p, c, t, pos: L.decode_step(p, c, t, pos, cfg, rules),
                 donate_argnums=(1,))
    lowered = fn.lower(aparams, acache, atok, apos)
    return lowered, meta


def analyze(lowered, meta, keep_hlo: bool = False):
    t0 = time.monotonic()
    compiled = lowered.compile()
    compile_s = time.monotonic() - t0

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            if hasattr(ma, k):
                mem[k] = int(getattr(ma, k))
        print(f"[dryrun] memory_analysis: {mem}")
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}

    hlo = compiled.as_text()
    coll = R.collective_bytes(hlo)

    cfg = C.get_config(meta["arch"])
    mf = R.model_flops(cfg, meta["kind"], meta["batch"], meta["seq"])
    n_dev = meta["devices"]
    terms = R.roofline_terms(flops, bytes_acc, coll["total"])
    useful = mf / max(flops * n_dev, 1.0)

    rec = dict(meta)
    rec.update({
        "compile_s": round(compile_s, 2),
        "flops_per_dev": flops, "bytes_per_dev": bytes_acc,
        "collective_bytes_per_dev": coll["total"],
        "collectives": {k: v for k, v in coll.items() if k != "total"},
        "model_flops": mf, "useful_flop_ratio": useful,
        "memory": mem,
        **terms,
    })
    out = (rec, hlo) if keep_hlo else (rec, None)
    del compiled
    gc.collect()
    return out


def probe_config(cfg, groups: int):
    """Same arch with `groups` pattern-groups of layers (tail preserved)."""
    from dataclasses import replace
    pat, _, tail = L.layer_kinds(cfg)
    if cfg.family == "hybrid":
        return replace(cfg, n_layers=groups * cfg.shared_attn_every + len(tail))
    return replace(cfg, n_layers=groups * len(pat) + len(tail))


def _probe_measure(arch, shape_name, multi_pod, overrides, cfg):
    from repro.models import unroll as UN
    with UN.force_unroll():
        lowered, _ = lower_cell(arch, shape_name, multi_pod,
                                rules_overrides=overrides, cfg=cfg)
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = R.collective_bytes(compiled.as_text())
    out = {"flops": float(cost.get("flops", 0.0)),
           "bytes": float(cost.get("bytes accessed", 0.0)),
           "coll": coll}
    del compiled, lowered
    gc.collect()
    return out


def loop_corrected_metrics(arch, shape_name, multi_pod=False, overrides=None,
                           cfg_sets=None):
    """XLA counts while bodies once; measure 1- and 2-group probes with all
    scans unrolled, then total = M1 + (G-1) * (M2 - M1)."""
    cfg = C.get_config(arch)
    if cfg_sets:
        from dataclasses import replace as _rep
        cfg = _rep(cfg, **cfg_sets)
    _, n_groups, _ = L.layer_kinds(cfg)
    m1 = _probe_measure(arch, shape_name, multi_pod, overrides, probe_config(cfg, 1))
    m2 = _probe_measure(arch, shape_name, multi_pod, overrides, probe_config(cfg, 2))

    def extrap(a, b):
        return a + (n_groups - 1) * (b - a)

    coll = {k: max(0.0, extrap(m1["coll"][k], m2["coll"][k]))
            for k in m1["coll"]}
    return {
        "flops_per_dev": max(0.0, extrap(m1["flops"], m2["flops"])),
        "bytes_per_dev": max(0.0, extrap(m1["bytes"], m2["bytes"])),
        "coll": coll,
        "probe": {"g1": m1, "g2": m2, "n_groups": n_groups},
    }


def run_cells(archs, shapes, meshes, json_path, overrides=None, force=False,
              probes=True, cfg_sets=None):
    results = {}
    if json_path and os.path.exists(json_path):
        with open(json_path) as f:
            results = json.load(f)
    for arch in archs:
        applicable = C.shapes_for(arch)
        for shape in shapes:
            if shape not in applicable:
                print(f"[dryrun] SKIP {arch} x {shape} (see DESIGN.md)")
                continue
            for mesh_kind in meshes:
                key = f"{arch}|{shape}|{mesh_kind}"
                if key in results and not force:
                    print(f"[dryrun] cached {key}")
                    continue
                print(f"[dryrun] lowering {key} ...", flush=True)
                t0 = time.monotonic()
                cfg_cell = None
                if cfg_sets:
                    from dataclasses import replace as _rep
                    cfg_cell = _rep(C.get_config(arch), **cfg_sets)
                try:
                    lowered, meta = lower_cell(arch, shape,
                                               mesh_kind == "multi",
                                               rules_overrides=overrides,
                                               cfg=cfg_cell)
                    rec, _ = analyze(lowered, meta)
                    rec["lower_s"] = round(time.monotonic() - t0 - rec["compile_s"], 2)
                    if probes and mesh_kind == "single":
                        corr = loop_corrected_metrics(arch, shape,
                                                      overrides=overrides,
                                                      cfg_sets=cfg_sets)
                        rec["raw_flops_per_dev"] = rec["flops_per_dev"]
                        rec["raw_bytes_per_dev"] = rec["bytes_per_dev"]
                        rec["raw_collective_bytes_per_dev"] = rec["collective_bytes_per_dev"]
                        rec["flops_per_dev"] = corr["flops_per_dev"]
                        rec["bytes_per_dev"] = corr["bytes_per_dev"]
                        rec["collective_bytes_per_dev"] = corr["coll"]["total"]
                        rec["collectives"] = {k: v for k, v in corr["coll"].items()
                                              if k != "total"}
                        rec["probe"] = corr["probe"]
                        rec.update(R.roofline_terms(rec["flops_per_dev"],
                                                    rec["bytes_per_dev"],
                                                    rec["collective_bytes_per_dev"]))
                        cfg2 = C.get_config(arch)
                        rec["useful_flop_ratio"] = (
                            rec["model_flops"] / max(rec["flops_per_dev"]
                                                     * rec["devices"], 1.0))
                    rec["status"] = "ok"
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "status": "FAIL", "error": f"{type(e).__name__}: {e}"}
                    print(f"[dryrun] FAIL {key}: {rec['error'][:500]}")
                results[key] = rec
                lowered = None
                if json_path:
                    with open(json_path, "w") as f:
                        json.dump(results, f, indent=1)
                if rec.get("status") == "ok":
                    print(f"[dryrun] OK {key}: compile={rec['compile_s']}s "
                          f"flops/dev={rec['flops_per_dev']:.3e} "
                          f"coll/dev={rec['collective_bytes_per_dev']:.3e} "
                          f"dominant={rec['dominant']}", flush=True)
                gc.collect()
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default=",".join(C.ARCHS))
    ap.add_argument("--shapes", default="train_4k,prefill_32k,decode_32k,long_500k")
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--json", default="launch_dryrun_results.json")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="ModelConfig field override, e.g. rwkv_chunk=32")
    ap.add_argument("--override", action="append", default=[],
                    help="logical=physical rule override (hillclimb knob)")
    args = ap.parse_args()
    overrides = {}
    for ov in args.override:
        k, _, v = ov.partition("=")
        overrides[k] = None if v in ("", "None") else v
    cfg_sets = {}
    for sv in args.set:
        k, _, v = sv.partition("=")
        cfg_sets[k] = int(v) if v.lstrip("-").isdigit() else (
            float(v) if v.replace(".", "", 1).lstrip("-").isdigit() else v)
    results = run_cells([a.strip() for a in args.archs.split(",") if a.strip()],
                        [s.strip() for s in args.shapes.split(",") if s.strip()],
                        [m.strip() for m in args.meshes.split(",") if m.strip()],
                        args.json, overrides=overrides or None,
                        force=args.force, probes=not args.no_probes,
                        cfg_sets=cfg_sets or None)
    ok = sum(1 for r in results.values() if r.get("status") == "ok")
    fail = sum(1 for r in results.values() if r.get("status") == "FAIL")
    print(f"[dryrun] done: {ok} ok, {fail} failed")
    return 0 if fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
