"""Collision probabilities for the four coding schemes (paper §2, §4, §5).

All functions are vectorized over ``rho`` (array) with a static Python
float ``w`` (bin width), so bin counts are compile-time constants. They
are jittable and differentiable.

Schemes / notation (paper):
  h_w     uniform quantization  code = floor(x / w)           -> P_w   (Thm 1)
  h_{w,q} window + random offset code = floor((x + q) / w)    -> P_wq  (Eq. 7)
  h_{w,2} 2-bit non-uniform, regions (-inf,-w),[-w,0),[0,w),[w,inf)
                                                              -> P_w2  (Thm 4)
  h_1     1-bit sign                                          -> P_1   (Eq. 19)
"""
from __future__ import annotations

import math

import jax.numpy as jnp
from jax.scipy.special import ndtr  # standard normal CDF, accurate tails

from repro.core._quad import interval_nodes

__all__ = [
    "phi", "Phi", "q_region", "collision_prob_uniform",
    "collision_prob_offset", "collision_prob_2bit", "collision_prob_sign",
    "collision_prob", "SCHEMES",
]

# Beyond |z| = ZMAX the N(0,1) mass is < 1e-18; integrals are truncated here.
ZMAX = 9.0
_DEFAULT_ORDER = 48

SCHEMES = ("uniform", "offset", "2bit", "sign")


def phi(x):
    """Standard normal pdf."""
    x = jnp.asarray(x)
    return jnp.exp(-0.5 * x * x) / jnp.sqrt(jnp.asarray(2.0 * math.pi, x.dtype))


def Phi(x):
    """Standard normal cdf."""
    return ndtr(jnp.asarray(x))


def _clip_rho(rho):
    rho = jnp.asarray(rho, jnp.result_type(float))
    return jnp.clip(rho, 0.0, 1.0 - 1e-9)


def q_region(rho, s, t, order: int = _DEFAULT_ORDER):
    """Lemma 1: Q_{s,t}(rho) = Pr(x in [s,t], y in [s,t]) for bivariate
    N(0, [[1, rho], [rho, 1]]).

    rho: array; s, t: static floats with s < t.
    """
    rho = _clip_rho(rho)[..., None]
    sd = jnp.sqrt(1.0 - rho * rho)
    lo = max(s, -ZMAX)
    hi = min(t, ZMAX)
    if hi <= lo:
        return jnp.zeros(rho.shape[:-1], rho.dtype)
    z, wz = interval_nodes(lo, hi, order)  # [order]
    inner = Phi((t - rho * z) / sd) - Phi((s - rho * z) / sd)
    return jnp.sum(phi(z) * inner * wz, axis=-1)


def collision_prob_uniform(rho, w: float, order: int = _DEFAULT_ORDER):
    """P_w (Thm 1): collision probability of h_w(x) = floor(x/w).

    P_w = 2 sum_{i>=0} Q_{iw,(i+1)w}(rho), truncated at ZMAX.
    """
    w = float(w)
    if w <= 0:
        raise ValueError("bin width w must be positive")
    n_bins = max(1, int(math.ceil(ZMAX / w)))
    rho = _clip_rho(rho)
    r = rho[..., None, None]  # [..., bin, node]
    sd = jnp.sqrt(1.0 - r * r)
    lo = jnp.asarray([i * w for i in range(n_bins)])
    hi = jnp.asarray([min((i + 1) * w, ZMAX + w) for i in range(n_bins)])
    z, wz = interval_nodes(lo, hi, order)  # [bin, node]
    upper = jnp.asarray([(i + 1) * w for i in range(n_bins)])[:, None]
    lower = jnp.asarray([i * w for i in range(n_bins)])[:, None]
    inner = Phi((upper - r * z) / sd) - Phi((lower - r * z) / sd)
    return 2.0 * jnp.sum(phi(z) * inner * wz, axis=(-1, -2))


def collision_prob_offset(rho, w: float):
    """P_{w,q} (Eq. 7), the Datar et al. window+offset scheme, closed form.

    P = 2 Phi(r) - 1 + (2 / (sqrt(2 pi) r)) (exp(-r^2/2) - 1),  r = w / sqrt(d),
    d = 2 (1 - rho).
    """
    w = float(w)
    rho = _clip_rho(rho)
    d = jnp.maximum(2.0 * (1.0 - rho), 1e-24)
    r = w / jnp.sqrt(d)
    return (2.0 * Phi(r) - 1.0
            + 2.0 / (math.sqrt(2.0 * math.pi) * r) * (jnp.exp(-0.5 * r * r) - 1.0))


def collision_prob_2bit(rho, w: float, order: int = _DEFAULT_ORDER):
    """P_{w,2} (Thm 4) for the non-uniform 2-bit scheme.

    P = 1 - acos(rho)/pi - 4 \\int_0^w phi(z) Phi((-w + rho z)/sqrt(1-rho^2)) dz
    """
    w = float(w)
    rho = _clip_rho(rho)
    base = 1.0 - jnp.arccos(rho) / math.pi
    hi = min(w, ZMAX)
    if hi <= 0.0:
        return base
    r = rho[..., None]
    sd = jnp.sqrt(1.0 - r * r)
    z, wz = interval_nodes(0.0, hi, order)
    integral = jnp.sum(phi(z) * Phi((-w + r * z) / sd) * wz, axis=-1)
    return base - 4.0 * integral


def collision_prob_sign(rho, w: float = 0.0):
    """P_1 (Eq. 19): 1-bit sign scheme, 1 - acos(rho)/pi. ``w`` ignored."""
    rho = _clip_rho(rho)
    return 1.0 - jnp.arccos(rho) / math.pi


_PROB = {
    "uniform": collision_prob_uniform,
    "offset": collision_prob_offset,
    "2bit": collision_prob_2bit,
    "sign": collision_prob_sign,
}


def collision_prob(rho, w: float, scheme: str):
    """Dispatch to the scheme's collision probability P(rho; w)."""
    try:
        fn = _PROB[scheme]
    except KeyError:
        raise ValueError(f"unknown scheme {scheme!r}; one of {SCHEMES}") from None
    return fn(rho, w)
