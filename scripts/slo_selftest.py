"""CI self-test for the closed-loop health layer (``repro.obs.slo``).

Synthetic drill protocol, mirroring ``check_perf --selftest``: drive an
``SloEngine`` on a fake clock through three scripted scenarios and
demand the *correct* alert (or none) each time:

1. **stationary** — seeded jittered traffic well inside every budget
   for 400 virtual seconds must produce ZERO alerts (the false-alarm
   gate; a pager that cries wolf gets muted and then misses the real
   incident);
2. **latency step** — an injected 2x latency step (all requests late)
   must trip ``slo.search.latency`` within the fast (60 s) window, and
   nothing else;
3. **recall drop** — an injected quality collapse (shadow recall 0.1
   against a 0.8 floor) must trip ``slo.search.quality`` within the
   fast window, and no latency alert.

Exit 0 only when all three behave. Optionally writes the ops dashboard
of the final drill state with ``--dashboard out.html`` so CI archives a
rendered artifact every run.
"""
import argparse
import math
import os
import sys

import numpy as np

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.obs import MetricsRegistry, SloEngine, SloSpec  # noqa: E402


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _engine(clock):
    reg = MetricsRegistry()
    slo = SloEngine(registry=reg, clock=clock, resolution=1.0)
    slo.add(SloSpec("search", latency_hist="serve.flush_s",
                    latency_target_s=0.050,
                    error_counter="serve.flush_errors",
                    quality_min=0.8))
    fired = []
    slo.subscribe(lambda series, value, det: fired.append(series))
    return reg, slo, fired


def _stationary(reg, slo, clock, rng, seconds, qps=40):
    h = reg.histogram("serve.flush_s")
    for _ in range(seconds):
        for v in rng.lognormal(math.log(0.025), 0.25, size=qps):
            h.observe(float(v))
        if rng.random() < 0.3:
            slo.observe_quality("search", float(rng.uniform(0.85, 1.0)))
        clock.t += 1.0
        slo.tick()


def drill_stationary(seed=0):
    clock = _Clock()
    reg, slo, fired = _engine(clock)
    _stationary(reg, slo, clock, np.random.default_rng(seed), 400)
    ok = not fired and slo.health()["status"] == "ok"
    return ok, fired, slo, ("stationary 400 s: "
                            + ("no alerts" if ok else f"ALERTS {fired}"))


def drill_latency_step(seed=0):
    clock = _Clock()
    reg, slo, fired = _engine(clock)
    _stationary(reg, slo, clock, np.random.default_rng(seed), 90)
    h = reg.histogram("serve.flush_s")
    t0, t_alert = clock.t, math.nan
    for _ in range(120):                  # 2x step: every request late
        for _ in range(40):
            h.observe(0.100)
        clock.t += 1.0
        slo.tick()
        if fired and math.isnan(t_alert):
            t_alert = clock.t
            break
    ok = (fired[:1] == ["slo.search.latency"]
          and t_alert - t0 <= 60.0
          and slo.health()["status"] == "degraded")
    return ok, fired, slo, (f"latency 2x step: alert {fired} after "
                            f"{t_alert - t0:.0f} s (fast window 60 s)")


def drill_recall_drop(seed=0):
    clock = _Clock()
    reg, slo, fired = _engine(clock)
    _stationary(reg, slo, clock, np.random.default_rng(seed), 90)
    t0, t_alert = clock.t, math.nan
    h = reg.histogram("serve.flush_s")
    for _ in range(120):                  # latency stays healthy...
        for _ in range(40):
            h.observe(0.025)
        for _ in range(3):                # ...but recall collapses
            slo.observe_quality("search", 0.1)
        clock.t += 1.0
        slo.tick()
        if fired and math.isnan(t_alert):
            t_alert = clock.t
            break
    ok = (fired[:1] == ["slo.search.quality"]
          and t_alert - t0 <= 60.0
          and "slo.search.latency" not in fired)
    return ok, fired, slo, (f"recall drop: alert {fired} after "
                            f"{t_alert - t0:.0f} s (fast window 60 s)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dashboard", default="",
                    help="also write the final drill's dashboard HTML")
    ap.add_argument("--seeds", type=int, default=3,
                    help="seeds per scenario (default 3)")
    args = ap.parse_args(argv)
    bad = 0
    last_slo = None
    for seed in range(args.seeds):
        for drill in (drill_stationary, drill_latency_step,
                      drill_recall_drop):
            ok, fired, slo, msg = drill(seed)
            last_slo = slo
            print(f"  seed {seed} {drill.__name__}: "
                  f"{'PASS' if ok else 'FAIL'} — {msg}")
            if not ok:
                bad += 1
    if args.dashboard and last_slo is not None:
        from repro.obs import gather, write_dashboard
        write_dashboard(args.dashboard,
                        gather(registry=last_slo.registry, slo=last_slo))
        print(f"  dashboard -> {args.dashboard}")
    print(f"slo selftest: {'FAIL' if bad else 'PASS'} "
          f"({args.seeds} seeds x stationary/latency-step/recall-drop)")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
