"""AdamW with decoupled weight decay, global-norm clipping, and ZeRO-1
sharded moments (the sharding lives in the train-step's out_shardings —
this module is pure math on pytrees).

Moments are fp32 regardless of param dtype; an optional fp32 master copy
is kept when params are bf16 (configurable, default on).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update", "global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    master_fp32: bool = True


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to lr_min."""
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / jnp.maximum(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def init_opt_state(params, cfg: AdamWConfig):
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
    }
    if cfg.master_fp32:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    if cfg.clip_norm:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
                     state["m"], grads)
    v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2)
                     * jnp.square(g.astype(jnp.float32)), state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    base = state.get("master", params)

    def upd(p, mm, vv):
        pf = p.astype(jnp.float32)
        u = (mm / bc1) / (jnp.sqrt(vv / bc2) + cfg.eps)
        return pf - lr * (u + cfg.weight_decay * pf)

    new_master = jax.tree.map(upd, base, m, v)
    new_params = jax.tree.map(
        lambda nm, p: nm.astype(p.dtype), new_master, params)
    new_state = {"step": step, "m": m, "v": v}
    if cfg.master_fp32:
        new_state["master"] = new_master
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
