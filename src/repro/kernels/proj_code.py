"""Fused projection + coding Pallas kernel (the paper's hot spot).

Computes codes = encode(X @ R) without ever writing the f32 projections
to HBM: the GEMM accumulates in a VMEM f32 scratch tile on the MXU and the
coding scheme is applied in-register on the final reduction step, so the
HBM write-back is int8-scale (int32 codes here; packing kernel takes it
to b bits). For D = 3.2M (paper's URL set) this saves 4·k bytes/vector of
traffic versus project-then-encode.

Tiling: grid (M/bm, K/bk, D/bd), accumulation over the last grid axis
(minor-most = sequential on TPU). Block shapes default to MXU-aligned
(128, 128) output tiles with bd=512 reduction slabs:
VMEM use = bm·bd (x) + bd·bk (r) + bm·bk (acc f32 + out i32) ≈ 0.6 MB.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.schemes import CodeSpec

__all__ = ["coded_project_pallas"]


def _apply_code(z, q_row, spec: CodeSpec):
    """In-register coding of an f32 tile z [bm, bk]; q_row [1, bk]."""
    if spec.scheme == "sign":
        return (z >= 0.0).astype(jnp.int32)
    if spec.scheme == "2bit":
        w = spec.w
        return ((z >= -w).astype(jnp.int32) + (z >= 0.0).astype(jnp.int32)
                + (z >= w).astype(jnp.int32))
    if spec.scheme == "uniform":
        n_side = spec.n_bins_side
        c = jnp.floor(z * (1.0 / spec.w))
        c = jnp.clip(c, -n_side, n_side - 1)
        return (c + n_side).astype(jnp.int32)
    if spec.scheme == "offset":
        n_side = spec.n_bins_side
        c = jnp.floor((z + q_row) * (1.0 / spec.w))
        c = jnp.clip(c, -n_side, n_side - 1)
        return (c + n_side).astype(jnp.int32)
    raise ValueError(spec.scheme)


def _kernel(x_ref, r_ref, q_ref, o_ref, acc_ref, *, spec: CodeSpec):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], r_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[...] = _apply_code(acc_ref[...], q_ref[...], spec)


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("spec", "block_m", "block_k", "block_d", "interpret"))
def coded_project_pallas(x, r, spec: CodeSpec, q: Optional[jax.Array] = None,
                         *, block_m: int = 128, block_k: int = 128,
                         block_d: int = 512, interpret: bool = False):
    """x [M, D] (f32/bf16) @ r [D, K] -> int32 codes [M, K] under ``spec``.

    ``q`` (offset scheme) is a [K] vector; ignored (zeros) otherwise.
    """
    m, d = x.shape
    d2, k = r.shape
    assert d == d2, (x.shape, r.shape)
    if q is None:
        q = jnp.zeros((k,), jnp.float32)
    xp = _pad_to(_pad_to(x, block_m, 0), block_d, 1)
    rp = _pad_to(_pad_to(r, block_d, 0), block_k, 1)
    qp = _pad_to(q.astype(jnp.float32)[None, :], block_k, 1)
    mp, dp = xp.shape
    kp = rp.shape[1]
    grid = (mp // block_m, kp // block_k, dp // block_d)

    out = pl.pallas_call(
        functools.partial(_kernel, spec=spec),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_d), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_d, block_k), lambda i, j, s: (s, j)),
            pl.BlockSpec((1, block_k), lambda i, j, s: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_k), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, kp), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_m, block_k), jnp.float32)],
        interpret=interpret,
    )(xp, rp, qp)
    return out[:m, :k]
