"""Inject rendered dry-run/roofline/hillclimb tables into EXPERIMENTS.md."""
import io
import json
import os
import sys
from contextlib import redirect_stdout

sys.path.insert(0, os.path.dirname(__file__))
import render_experiments  # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")


def render_dryrun():
    buf = io.StringIO()
    with redirect_stdout(buf):
        render_experiments.main(os.path.join(ROOT, "launch_dryrun_results.json"))
    text = buf.getvalue()
    dry, _, roof = text.partition("### Roofline table")
    return dry.strip(), ("### Roofline table" + roof).strip()


def render_hillclimb():
    path = os.path.join(ROOT, "hillclimb_results.json")
    if not os.path.exists(path):
        return "(hillclimb results pending)"
    res = json.load(open(path))
    lines = ["| variant | flops/dev | bytes/dev | coll bytes/dev | t_compute | "
             "t_memory | t_collective | dominant | useful |",
             "|---|---|---|---|---|---|---|---|---|"]
    for name in sorted(res):
        r = res[name]
        if r.get("status") != "ok":
            lines.append(f"| {name} | FAIL: {r.get('error', '')[:80]} | | | | | | | |")
            continue
        def f(k, scale=1.0, fmt="{:.3e}"):
            v = r.get(k)
            return fmt.format(v * scale) if v is not None else "-"
        lines.append(
            f"| {name} | {f('flops_per_dev')} | {f('bytes_per_dev')} "
            f"| {f('collective_bytes_per_dev')} "
            f"| {f('t_compute_s', 1e3, '{:.0f}ms')} "
            f"| {f('t_memory_s', 1e3, '{:.0f}ms')} "
            f"| {f('t_collective_s', 1e3, '{:.0f}ms')} "
            f"| {r.get('dominant', '-')} "
            f"| {f('useful_flop_ratio', 1.0, '{:.3f}')} |")
    return "\n".join(lines)


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()
    dry, roof = render_dryrun()
    text = text.replace("<!-- DRYRUN_TABLE -->", dry)
    text = text.replace("<!-- ROOFLINE_TABLE -->", roof)
    text = text.replace("<!-- PERF_LOG -->", render_hillclimb())
    open(path, "w").write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
