"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; kernels must match them bit-for-bit (integer
outputs) across the shared scheme x shape x dtype x mask grid in
tests/test_kernel_conformance.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing as _packing
from repro.core import schemes as _schemes
from repro.core.schemes import CodeSpec

__all__ = ["coded_project_ref", "pack_codes_ref", "code_pack_ref",
           "encode_fused_ref", "collision_counts_ref",
           "packed_collision_ref", "packed_topk_ref",
           "packed_topk_masked_ref", "topk_blocked_ref", "topk_stable_ref",
           "lut_scores_ref", "lut_scores_rowwise_ref",
           "lut_scores_rowwise_int8_ref", "topk_scored_ref",
           "packed_lut_topk_ref", "packed_lut_topk_masked_ref",
           "packed_lut_rerank_ref", "packed_linear_fwd_ref",
           "packed_linear_fwd_masked_ref", "packed_linear_bwd_ref",
           "packed_linear_bwd_masked_ref", "coarse_survivor_mask_ref",
           "fused_scored_topk_ref", "fused_scored_topk_masked_ref",
           "two_stage_scored_ref", "two_stage_scored_masked_ref"]


def coded_project_ref(x, r, spec: CodeSpec, q=None):
    """x [M, D] @ r [D, K] -> int32 codes [M, K] under ``spec``.

    The matmul accumulates in float32 regardless of input dtype (matches
    the kernel's MXU accumulator).
    """
    z = jnp.dot(x, r, preferred_element_type=jnp.float32)
    return _schemes.encode(z, spec, q)


def pack_codes_ref(codes, bits: int):
    """codes int [M, K] -> uint32 words [M, ceil(K/(32/bits))]."""
    return _packing.pack_codes(codes, bits)


def code_pack_ref(z, spec: CodeSpec, q=None):
    """Projected z [M, K] float -> packed uint32 [M, ceil(K·b/32)].

    Coding scheme + b-bit pack (the fused-encode epilogue); the oracle
    for ``encode_fused.code_pack_pallas``, bit-exact including the
    zero-padded fields past K."""
    return _packing.pack_codes(
        _schemes.encode(jnp.asarray(z, jnp.float32), spec, q), spec.bits)


def encode_fused_ref(x, r, spec: CodeSpec, q=None):
    """x [M, D] @ r [D, K] -> packed uint32 [M, ceil(K·b/32)].

    Full fused-ingest oracle: f32-accumulated projection, coding under
    ``spec``, bit-pack — the semantics contract of
    ``encode_fused.encode_fused_pallas`` (packed words bit-exact)."""
    return code_pack_ref(
        jnp.dot(x, r, preferred_element_type=jnp.float32), spec, q)


def collision_counts_ref(codes_q, codes_db):
    """codes_q [Q, K], codes_db [N, K] -> int32 [Q, N] match counts."""
    eq = (codes_q[:, None, :] == codes_db[None, :, :])
    return jnp.sum(eq, axis=-1).astype(jnp.int32)


def packed_collision_ref(words_q, words_db, bits: int, k: int):
    """words_q uint32 [Q, W], words_db uint32 [N, W] -> int32 [Q, N].

    All-pairs b-bit collision counts computed directly on packed words
    (XOR + field fold + popcount; semantics in ``packing.match_count_packed``).
    Accumulates word-by-word so the [Q, N] temporaries stay 2-D — the
    broadcast [Q, N, W] intermediate never materializes (W is small and
    static, so the unrolled loop fuses under jit).
    """
    q, w = words_q.shape
    n = words_db.shape[0]
    mism = jnp.zeros((q, n), jnp.int32)
    for j in range(w):
        xor = jnp.bitwise_xor(words_q[:, None, j], words_db[None, :, j])
        mism = mism + _packing.mismatch_count_words(xor, bits).astype(jnp.int32)
    return k - mism


def topk_blocked_ref(m, top_k: int, block: int = 4096):
    """Stable descending top-k over the last axis of int matrix [c, n].

    Bit-identical to ``jax.lax.top_k`` (ties -> lowest index) but built
    for small top_k on large n: one block-max pass over the matrix, then
    per-pick work touches only the winning block, so the cost is
    O(c*n + top_k * c * block) instead of XLA's full per-row sort.
    ~30x faster than ``lax.top_k`` on CPU at [256, 100k], top_k=10.

    Unlike ``lax.top_k``, top_k > n is allowed: overflow slots return the
    dtype-min sentinel as value (ids point past n) — callers mask on
    value < real-minimum.
    """
    c, n = m.shape
    sent = jnp.iinfo(m.dtype).min
    pad = (-max(n, top_k)) % block + (max(n, top_k) - n)
    if pad:
        m = jnp.pad(m, ((0, 0), (0, pad)), constant_values=sent)
    nb = m.shape[1] // block
    mb = m.reshape(c, nb, block)
    bmax = jnp.max(mb, axis=2)                       # [c, nb]
    rows = jnp.arange(c)
    vals, ids = [], []
    for _ in range(top_k):
        b = jnp.argmax(bmax, axis=1)                 # lowest block on ties
        blk = mb[rows, b]                            # [c, block]
        inner = jnp.argmax(blk, axis=1)
        vals.append(blk[rows, inner])
        ids.append((b * block + inner).astype(jnp.int32))
        mb = mb.at[rows, b, inner].set(sent)
        bmax = bmax.at[rows, b].set(jnp.max(mb[rows, b], axis=1))
    return jnp.stack(vals, axis=1), jnp.stack(ids, axis=1)


def topk_stable_ref(m, top_k: int):
    """Stable descending top-k of int scores [c, n] with -1-fill overflow.

    The shared selection for search paths: blocked picking for small
    top_k (fast on CPU), one lax.top_k call beyond that (the unrolled
    pick loop would trace top_k scatter steps). top_k > n is allowed —
    overflow slots come back as (-1, -1); negative scores also surface
    ids of -1 (search paths use negatives to mark non-candidates).
    """
    if top_k > m.shape[1]:
        m = jnp.pad(m, ((0, 0), (0, top_k - m.shape[1])),
                    constant_values=-1)
    if top_k <= 64:
        vals, ids = topk_blocked_ref(m, top_k)
    else:
        vals, ids = jax.lax.top_k(m, top_k)
        ids = ids.astype(jnp.int32)
    return vals, jnp.where(vals < 0, -1, ids)


def packed_topk_ref(words_q, words_db, bits: int, k: int, top_k: int):
    """-> (counts [Q, top_k], ids [Q, top_k]): full packed collision matrix
    followed by a stable descending top-k (lowest corpus id wins ties).

    top_k > N yields (-1, -1) in the overflow slots, matching the
    streaming kernel's scratch-fill semantics.
    """
    counts = packed_collision_ref(words_q, words_db, bits, k)
    return topk_stable_ref(counts, top_k)


# -- LUT-scored ranking (repro.rank) ------------------------------------------

def lut_scores_ref(q_tables, words_db, bits: int):
    """LUT scores on packed words: [Q, F*P] x [N, W] -> float32 [Q, N].

    q_tables is the flat per-query table of ``rank.RankTables
    .query_tables`` (any float dtype; F = W * 32/bits field slots, P =
    2**bits entries each); entry (w*cpw + f)*P + c scores corpus code
    value c at field f of word w. Scores accumulate in float32 field by
    field in (word, field) order — the exact accumulation order of the
    fused kernel, so kernel outputs match bit-for-bit. Padded field
    slots hold zeros, so rows with k < F real codes score correctly.
    """
    p = 1 << bits
    cpw = 32 // bits
    n_words = words_db.shape[-1]
    assert q_tables.shape[-1] == n_words * cpw * p, (
        q_tables.shape, words_db.shape, bits)
    tab = q_tables.astype(jnp.float32)
    score = jnp.zeros((q_tables.shape[0], words_db.shape[0]), jnp.float32)
    for w in range(n_words):
        word = words_db[:, w]
        for f in range(cpw):
            c = (word >> jnp.uint32(f * bits)) & jnp.uint32(p - 1)
            col = (w * cpw + f) * p
            score = score + jnp.take(tab[:, col:col + p],
                                     c.astype(jnp.int32), axis=1)
    return score


def lut_scores_rowwise_ref(q_tables, cand_words, bits: int):
    """Row-wise LUT scores: [Q, F*P] x per-query candidates [Q, M, W]
    -> float32 [Q, M] (same table layout and float32 accumulation order
    as ``lut_scores_ref``; query i scores only its own candidate rows).
    """
    p = 1 << bits
    cpw = 32 // bits
    n_words = cand_words.shape[-1]
    assert q_tables.shape[-1] == n_words * cpw * p, (
        q_tables.shape, cand_words.shape, bits)
    tab = q_tables.astype(jnp.float32)
    score = jnp.zeros(cand_words.shape[:-1], jnp.float32)
    for w in range(n_words):
        word = cand_words[..., w]
        for f in range(cpw):
            c = (word >> jnp.uint32(f * bits)) & jnp.uint32(p - 1)
            col = (w * cpw + f) * p
            score = score + jnp.take_along_axis(
                tab[:, col:col + p], c.astype(jnp.int32), axis=1)
    return score


def topk_scored_ref(scores, top_k: int):
    """Stable descending top-k of float scores [c, n] -> (float32
    [c, top_k], int32 ids [c, top_k]).

    -inf marks non-candidates/empty slots; such slots (and overflow when
    top_k > n) surface as (-inf, -1). Ties resolve to the lowest index
    (``lax.top_k`` is stable), matching the streaming kernels.
    """
    scores = jnp.asarray(scores, jnp.float32)
    if top_k > scores.shape[1]:
        scores = jnp.pad(scores, ((0, 0), (0, top_k - scores.shape[1])),
                         constant_values=-jnp.inf)
    vals, ids = jax.lax.top_k(scores, top_k)
    return vals, jnp.where(jnp.isneginf(vals), -1, ids.astype(jnp.int32))


def packed_lut_topk_ref(q_tables, words_db, bits: int, top_k: int):
    """Full-corpus LUT-scored search: -> (scores f32 [Q, top_k], ids
    int32 [Q, top_k]); the oracle for the fused streaming kernel
    (``packed_lut.packed_lut_topk_pallas``), bit-exact including float
    accumulation order and lowest-id tie-breaks."""
    return topk_scored_ref(lut_scores_ref(q_tables, words_db, bits), top_k)


def packed_lut_topk_masked_ref(q_tables, words_db, valid_words, bits: int,
                               top_k: int):
    """``packed_lut_topk_ref`` over live rows only: ``valid_words`` is
    the packed row-validity bitmask (``packing.pack_bitmask`` layout).
    Dead rows score -inf and never surface; empty slots are (-inf, -1).
    """
    scores = lut_scores_ref(q_tables, words_db, bits)
    live = _packing.unpack_bitmask(valid_words, words_db.shape[0])
    return topk_scored_ref(jnp.where(live[None, :], scores, -jnp.inf),
                           top_k)


def packed_lut_rerank_ref(q_tables, cand_words, cand_valid, bits: int,
                          top_k: int):
    """Per-query candidate re-rank: q_tables [Q, F*P], gathered
    candidate rows [Q, M, W] uint32, cand_valid bool/int [Q, M] ->
    (scores f32 [Q, top_k], positions int32 [Q, top_k]).

    Positions index the candidate axis (0..M-1), NOT corpus rows —
    callers map them through their candidate id list. Invalid candidates
    (coarse-stage -1 slots) score -inf; empty slots are (-inf, -1).
    """
    scores = lut_scores_rowwise_ref(q_tables, cand_words, bits)
    scores = jnp.where(jnp.asarray(cand_valid) != 0, scores, -jnp.inf)
    return topk_scored_ref(scores, top_k)


# -- packed linear classifier (repro.learn) -----------------------------------

def packed_linear_fwd_ref(tables, words_db, bits: int):
    """Margins of a packed linear model: class weight tables float
    [C, F*P] (flat ``learn.features`` layout) × packed words uint32
    [N, W] -> float32 [C, N].

    Identical semantics to ``lut_scores_ref`` with the per-query tables
    replaced by per-class weight tables: margin[c, n] sums, in (word,
    field) order, the table entry each b-bit field of row n selects.
    The oracle for ``packed_linear.packed_linear_fwd_pallas``
    (bit-exact, including float accumulation order).
    """
    return lut_scores_ref(tables, words_db, bits)


def packed_linear_fwd_masked_ref(tables, words_db, valid_words, bits: int):
    """``packed_linear_fwd_ref`` over live rows only: ``valid_words`` is
    the packed row-validity bitmask (``packing.pack_bitmask`` layout);
    dead rows emit margin 0.0 (callers also drop them from the loss)."""
    scores = lut_scores_ref(tables, words_db, bits)
    live = _packing.unpack_bitmask(valid_words, words_db.shape[0])
    return jnp.where(live[None, :], scores, 0.0)


def _onehot_rows(words, bits: int):
    """Dense one-hot of every field slot: uint32 [n, W] -> float32
    [n, F*P] in the flat table layout (phantom field slots included)."""
    p = 1 << bits
    f = words.shape[-1] * (32 // bits)
    codes = _packing.unpack_codes(words, bits, f)          # [n, F]
    hot = codes[..., None] == jnp.arange(p, dtype=jnp.int32)
    return hot.reshape(words.shape[0], f * p).astype(jnp.float32)


def packed_linear_bwd_ref(g, words_db, bits: int, *, block_c: int = 8,
                          block_n: int = 512):
    """Weight-table gradients: upstream margin gradients g float32
    [C, N] × packed words [N, W] -> float32 [C, F*P].

    dTables[c, f*P + v] = sum over rows n whose field f holds code v of
    g[c, n]. The accumulation order is the contract with the fused
    kernel (``packed_linear.packed_linear_bwd_pallas``): rows are
    processed in ``block_n`` chunks (g zero-padded, classes padded to
    ``block_c``, matching the kernel's tile shapes exactly), each chunk
    enters through one one-hot matmul, and chunk results add
    sequentially — bit-exact at equal block sizes.
    """
    c, n = g.shape
    g = jnp.asarray(g, jnp.float32)
    pad_c, pad_n = (-c) % block_c, (-n) % block_n
    if pad_c or pad_n:
        g = jnp.pad(g, ((0, pad_c), (0, pad_n)))
    if pad_n:
        words_db = jnp.pad(words_db, ((0, pad_n), (0, 0)))
    p = 1 << bits
    fp = words_db.shape[1] * (32 // bits) * p
    acc = jnp.zeros((g.shape[0], fp), jnp.float32)
    for lo in range(0, g.shape[1], block_n):
        hot = _onehot_rows(words_db[lo:lo + block_n], bits)
        acc = acc + jnp.dot(g[:, lo:lo + block_n], hot,
                            preferred_element_type=jnp.float32)
    return acc[:c]


def packed_linear_bwd_masked_ref(g, words_db, valid_words, bits: int, *,
                                 block_c: int = 8, block_n: int = 512):
    """``packed_linear_bwd_ref`` over live rows only: gradient columns
    of rows whose validity bit is clear are zeroed before the scatter,
    so tombstoned examples contribute exactly nothing."""
    live = _packing.unpack_bitmask(valid_words, words_db.shape[0])
    g = jnp.where(live[None, :], jnp.asarray(g, jnp.float32), 0.0)
    return packed_linear_bwd_ref(g, words_db, bits, block_c=block_c,
                                 block_n=block_n)


def packed_topk_masked_ref(words_q, words_db, valid_words, bits: int, k: int,
                           top_k: int):
    """``packed_topk_ref`` over live rows only: ``valid_words`` is the
    packed row-validity bitmask (``packing.pack_bitmask`` layout, bit
    r%32 of word r//32 = row r live). Dead rows never surface — slots
    beyond the live count come back as (-1, -1), exactly as if the store
    held just the live rows (tie order among survivors is unchanged).
    """
    counts = packed_collision_ref(words_q, words_db, bits, k)
    live = _packing.unpack_bitmask(valid_words, words_db.shape[0])
    return topk_stable_ref(jnp.where(live[None, :], counts, -1), top_k)


# -- single-pass fused scored search ------------------------------------------

def lut_scores_rowwise_int8_ref(q_tables, scales, cand_words, bits: int):
    """Row-wise LUT scores from int8 tables with per-(query, word)
    scales: q_tables int8 [Q, F*P], scales float32 [Q, W], cand_words
    [Q, M, W] -> float32 [Q, M].

    The int8 accumulation contract (shared with the fused kernel): the
    32/b selected entries of one packed word sum exactly in int32, then
    each word's integer sum joins the float32 total as
    ``score += scale[q, w] * float(isum)`` in word order. Scales must be
    powers of two (``rank.RankTables.query_tables_int8`` produces them
    that way): the multiply is then exact, so whether a compiler
    contracts it into an FMA or not cannot change a single bit — kernel
    and oracle agree bit-for-bit.
    """
    p = 1 << bits
    cpw = 32 // bits
    n_words = cand_words.shape[-1]
    assert q_tables.shape[-1] == n_words * cpw * p, (
        q_tables.shape, cand_words.shape, bits)
    assert scales.shape == (q_tables.shape[0], n_words), (
        scales.shape, q_tables.shape, cand_words.shape)
    tab = q_tables.astype(jnp.int32)
    score = jnp.zeros(cand_words.shape[:-1], jnp.float32)
    for w in range(n_words):
        word = cand_words[..., w]
        isum = jnp.zeros(cand_words.shape[:-1], jnp.int32)
        for f in range(cpw):
            c = (word >> jnp.uint32(f * bits)) & jnp.uint32(p - 1)
            col = (w * cpw + f) * p
            isum = isum + jnp.take_along_axis(
                tab[:, col:col + p], c.astype(jnp.int32), axis=1)
        score = score + scales[:, w][:, None] * isum.astype(jnp.float32)
    return score


def coarse_survivor_mask_ref(counts, k: int, rerank_m: int):
    """Membership mask [Q, N] bool of the stable coarse top-``rerank_m``
    by collision count (ties -> lowest corpus id), without sorting.

    This is the survivor rule the fused kernel evaluates in-VMEM: with
    t(q) the smallest threshold in [0, k] such that fewer than rerank_m
    rows satisfy count > t (found by binary search — counts live in
    [-1, k]), row n survives iff count > t, or count == t and its
    id-ascending rank among the count == t ties fits the remaining
    quota. Rows with count < 0 (tombstoned / padded) never survive.
    The surviving id set equals ``topk_stable_ref(counts, rerank_m)``'s
    non-sentinel ids exactly.
    """
    q, n = counts.shape
    m = jnp.int32(rerank_m)
    lo = jnp.zeros((q, 1), jnp.int32)
    hi = jnp.full((q, 1), k, jnp.int32)
    for _ in range(max(1, (k + 1).bit_length())):
        mid = (lo + hi) >> 1
        above = jnp.sum((counts > mid).astype(jnp.int32), axis=1,
                        keepdims=True)
        done = above < m
        lo = jnp.where(done, lo, mid + 1)
        hi = jnp.where(done, mid, hi)
    t = lo                                                     # [Q, 1]
    above_t = jnp.sum((counts > t).astype(jnp.int32), axis=1,
                      keepdims=True)
    quota = m - above_t
    is_tie = counts == t
    tie_rank = jnp.cumsum(is_tie.astype(jnp.int32), axis=1)
    return (counts > t) | (is_tie & (tie_rank <= quota))


def _compact_survivors(sm, rerank_m: int):
    """Survivor mask [Q, N] -> id-ascending candidate ids [Q, rerank_m]
    (-1 padded) — no per-row sort. The j-th survivor of a row is the
    first index where the mask's running cumsum reaches j+1, i.e. a
    per-row ``searchsorted`` into the (non-decreasing) cumsum: O(m log
    n) gathers instead of the O(n) scatter this used to be (XLA lowers
    row scatters catastrophically on CPU — ~60x slower than the binary
    searches at the bench shape)."""
    q, n = sm.shape
    csum = jnp.cumsum(sm.astype(jnp.int32), axis=1)            # [Q, N]
    targets = jnp.arange(1, rerank_m + 1, dtype=jnp.int32)     # [m]
    pos = jax.vmap(
        lambda c: jnp.searchsorted(c, targets, side="left"))(csum)
    found = targets[None, :] <= csum[:, -1:]                   # [Q, m]
    return jnp.where(found, pos.astype(jnp.int32), -1)


def _score_candidates(q_tables, words_db, cand, bits: int, top_k: int,
                      scales):
    """Gather candidate rows, LUT-score them (f32 or int8 path), top-k by
    score; -1 candidate slots score -inf. Returns (scores, corpus ids)."""
    n = words_db.shape[0]
    m = cand.shape[1]
    cand_words = jnp.take(words_db, jnp.clip(cand, 0, n - 1), axis=0)
    if scales is None:
        s = lut_scores_rowwise_ref(q_tables, cand_words, bits)
    else:
        s = lut_scores_rowwise_int8_ref(q_tables, scales, cand_words, bits)
    s = jnp.where(cand >= 0, s, -jnp.inf)
    vals, pos = topk_scored_ref(s, top_k)
    ids = jnp.take_along_axis(cand, jnp.clip(pos, 0, m - 1), axis=1)
    return vals, jnp.where(pos < 0, -1, ids)


def _empty_scored(q: int, top_k: int):
    return (jnp.full((q, top_k), -jnp.inf, jnp.float32),
            jnp.full((q, top_k), -1, jnp.int32))


def fused_scored_topk_ref(q_words, q_tables, words_db, bits: int, k: int,
                          rerank_m: int, top_k: int, scales=None):
    """Single-pass scored search oracle: q_words uint32 [Q, W], q_tables
    float/int8 [Q, F*P], words_db uint32 [N, W] -> (scores f32
    [Q, top_k], corpus ids int32 [Q, top_k]).

    Semantics (the contract with ``fused_scored.fused_scored_topk_
    pallas``): survivors are the exact stable coarse top-``rerank_m`` by
    collision count; the result is the top-``top_k`` of the survivors'
    LUT scores, ties -> lowest corpus id. ``scales`` float32 [Q, W]
    selects the int8 path (``lut_scores_rowwise_int8_ref``); otherwise
    tables upcast to float32. Sentinel padding: slots beyond the
    survivor count are (-inf, -1), so rerank_m or top_k larger than the
    corpus degrade exactly like the two-stage path.

    Unlike the two-stage composition this never sorts the [Q, N] count
    matrix — the threshold binary search plus a cumsum compaction is
    O(N log k) per query, which is where the CPU-path speedup over the
    old O(N·rerank_m) coarse ``lax.top_k`` comes from.
    """
    if words_db.shape[0] == 0:
        return _empty_scored(q_words.shape[0], top_k)
    counts = packed_collision_ref(q_words, words_db, bits, k)
    sm = coarse_survivor_mask_ref(counts, k, rerank_m)
    cand = _compact_survivors(sm, rerank_m)
    return _score_candidates(q_tables, words_db, cand, bits, top_k, scales)


def fused_scored_topk_masked_ref(q_words, q_tables, words_db, valid_words,
                                 bits: int, k: int, rerank_m: int,
                                 top_k: int, scales=None):
    """``fused_scored_topk_ref`` over live rows only (``valid_words``:
    packed bitmask, ``packing.pack_bitmask`` layout). Tombstoned rows
    take count -1 before the coarse threshold, so they can neither
    survive nor displace a live tie — all-dead segments return pure
    sentinels."""
    if words_db.shape[0] == 0:
        return _empty_scored(q_words.shape[0], top_k)
    counts = packed_collision_ref(q_words, words_db, bits, k)
    live = _packing.unpack_bitmask(valid_words, words_db.shape[0])
    counts = jnp.where(live[None, :], counts, -1)
    sm = coarse_survivor_mask_ref(counts, k, rerank_m)
    cand = _compact_survivors(sm, rerank_m)
    return _score_candidates(q_tables, words_db, cand, bits, top_k, scales)


def two_stage_scored_ref(q_words, q_tables, words_db, bits: int, k: int,
                         rerank_m: int, top_k: int):
    """The literal two-stage composition (coarse ``packed_topk_ref`` ->
    gather -> ``packed_lut_rerank_ref``), as the engines ran it before
    fusion. The differential oracle for the fused path: identical
    results whenever LUT scores don't tie across different collision
    counts (always true for monotone sign-scheme tables; a measure-zero
    event for generic float tables)."""
    n = words_db.shape[0]
    if n == 0:
        return _empty_scored(q_words.shape[0], top_k)
    cv, ci = packed_topk_ref(q_words, words_db, bits, k, rerank_m)
    vals, pos = packed_lut_rerank_ref(
        q_tables, jnp.take(words_db, jnp.clip(ci, 0, n - 1), axis=0),
        ci >= 0, bits, top_k)
    ids = jnp.take_along_axis(ci, jnp.clip(pos, 0, rerank_m - 1), axis=1)
    return vals, jnp.where(pos < 0, -1, ids)


def two_stage_scored_masked_ref(q_words, q_tables, words_db, valid_words,
                                bits: int, k: int, rerank_m: int,
                                top_k: int):
    """Masked two-stage composition (coarse ``packed_topk_masked_ref``
    -> gather -> re-rank) — the differential oracle for
    ``fused_scored_topk_masked_ref`` under random tombstone masks."""
    n = words_db.shape[0]
    if n == 0:
        return _empty_scored(q_words.shape[0], top_k)
    cv, ci = packed_topk_masked_ref(q_words, words_db, valid_words, bits,
                                    k, rerank_m)
    vals, pos = packed_lut_rerank_ref(
        q_tables, jnp.take(words_db, jnp.clip(ci, 0, n - 1), axis=0),
        ci >= 0, bits, top_k)
    ids = jnp.take_along_axis(ci, jnp.clip(pos, 0, rerank_m - 1), axis=1)
    return vals, jnp.where(pos < 0, -1, ids)
