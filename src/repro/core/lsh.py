"""LSH tables over coded random projections (paper §1.1).

"Using k projections and a bin width w, we can naturally build a hash
table with (2 ceil(6/w))^k buckets." We band the k codes into L tables of
m codes each (standard LSH amplification), hash each band to a 64-bit
bucket id, and re-rank candidates by full collision count.

The index is a host-side structure (serving-layer component); probing and
re-ranking are batched jnp computations (re-ranking uses the collision
kernel in ``repro.kernels.collision`` on TPU).
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

from repro.core.sketch import CodedRandomProjection

__all__ = ["LSHIndex"]

_MIX = np.uint64(0x9E3779B97F4A7C15)


def _band_hash(codes: np.ndarray) -> np.ndarray:
    """codes [n, m] -> uint64 bucket ids (splitmix-style polynomial hash)."""
    h = np.zeros(codes.shape[0], dtype=np.uint64)
    for j in range(codes.shape[1]):
        h = (h ^ (codes[:, j].astype(np.uint64) + _MIX)) * np.uint64(0xBF58476D1CE4E5B9)
        h ^= h >> np.uint64(31)
    return h


@dataclass
class LSHIndex:
    """L banded hash tables over coded projections."""
    sketcher: CodedRandomProjection
    n_tables: int = 8
    band_width: int = 8

    def __post_init__(self):
        need = self.n_tables * self.band_width
        if need > self.sketcher.cfg.k:
            raise ValueError(f"need n_tables*band_width <= k, {need} > {self.sketcher.cfg.k}")
        self._tables = [defaultdict(list) for _ in range(self.n_tables)]
        self._codes = None  # [n, k] corpus codes for re-ranking

    def build(self, x):
        """Index a corpus x [n, D]."""
        codes = np.asarray(self.sketcher.encode(x))
        self._codes = jnp.asarray(codes)
        for t in range(self.n_tables):
            band = codes[:, t * self.band_width:(t + 1) * self.band_width]
            for i, h in enumerate(_band_hash(band)):
                self._tables[t][int(h)].append(i)
        return self

    def candidates(self, q_codes: np.ndarray):
        """Union of bucket members across tables for one query code row."""
        out = set()
        for t in range(self.n_tables):
            band = q_codes[None, t * self.band_width:(t + 1) * self.band_width]
            out.update(self._tables[t].get(int(_band_hash(band)[0]), ()))
        return sorted(out)

    def query(self, x_query, top: int = 10):
        """x_query [D] -> list[(corpus_idx, rho_hat)] sorted by similarity."""
        q_codes = np.asarray(self.sketcher.encode(x_query[None, :]))[0]
        cand = self.candidates(q_codes)
        if not cand:
            return []
        cand_idx = jnp.asarray(cand)
        cand_codes = self._codes[cand_idx]  # [c, k]
        rho = self.sketcher.estimate_rho(cand_codes, jnp.asarray(q_codes)[None, :])
        order = jnp.argsort(-rho)[:top]
        return [(int(cand_idx[i]), float(rho[i])) for i in order]
