"""Quickstart: code random projections, estimate similarity, check theory.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (CodedRandomProjection, SketchConfig, collision_prob,
                        variance_factor)


def main():
    d, k = 4096, 1024
    rho_true = 0.85

    # two unit vectors with inner product rho_true
    key = jax.random.PRNGKey(0)
    u = jax.random.normal(key, (d,))
    u = u / jnp.linalg.norm(u)
    z = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    z = z - jnp.dot(z, u) * u
    z = z / jnp.linalg.norm(z)
    v = rho_true * u + np.sqrt(1 - rho_true ** 2) * z

    print(f"true rho = {rho_true}\n")
    print(f"{'scheme':10s} {'w':>5s} {'rho_hat':>8s} {'pred_std':>9s} "
          f"{'bits/code':>9s} {'bytes/vec':>9s}")
    for scheme, w in (("sign", 0.0), ("2bit", 0.75), ("uniform", 0.75),
                      ("uniform", 2.0), ("offset", 0.75)):
        crp = CodedRandomProjection(
            SketchConfig(k=k, scheme=scheme, w=max(w, 1e-3), seed=42), d)
        codes = crp.encode(jnp.stack([u, v]))
        rho_hat = float(crp.estimate_rho(codes[0], codes[1]))
        std = float(crp.asymptotic_std(rho_true))
        print(f"{scheme:10s} {w:5.2f} {rho_hat:8.4f} {std:9.4f} "
              f"{crp.spec.bits:9d} {crp.bytes_per_vector():9d}")

    # the paper's headline: empirical collision matches P(rho) and the
    # estimator variance matches V/k
    p_theory = float(collision_prob(jnp.asarray(rho_true), 0.75, "2bit"))
    v_theory = float(variance_factor(jnp.asarray(rho_true), 0.75, "2bit"))
    print(f"\nP_w2(rho={rho_true}, w=0.75) = {p_theory:.4f}; "
          f"Var(rho_hat) ~ {v_theory:.3f}/k = {v_theory / k:.2e}")
    print(f"storage: fp32 projections = {4 * k} B/vec; "
          f"2-bit codes = {2 * k // 8} B/vec (16x smaller)")


if __name__ == "__main__":
    main()
