"""Training drivers: streaming batches from the stores, sharded grads.

``learn.linear`` owns one gradient evaluation; this module owns where
the rows come from and how steps are paced:

``fit_words``
    The workhorse: full-batch (one donated jit around the whole Adam
    scan) or streaming minibatch (``cfg.batch > 0``: a per-step donated
    update executable — weight and optimizer buffers update in place,
    one compile for every step — fed by host-side index sampling and a
    device gather, so only O(batch) rows are ever touched per step).
    With a ``mesh``, every gradient runs data-parallel under
    ``shard_map`` (``packed_grads_sharded``).

``fit_store``
    Batches straight off an ``ann.CodeStore`` — the packed corpus that
    serves search doubles as the training set, zero extra copies.

``fit_log``
    Training over a *live mutable index* (``index.SegmentLogStore``):
    per-segment masked forward/backward (tombstoned and unwritten rows
    contribute exactly nothing), per-segment data grads summed in log
    order, the L2 term added once. Labels are keyed by *external* id,
    so churn (deletes, upserts, seals, compaction) never invalidates
    the label map. Matches training on a fresh store of the live rows
    up to float summation order (``tests/test_learn.py``).

``packed_grads_sharded``
    One data-parallel gradient: rows row-sharded over ``mesh[axis]``
    (padding carried as dead validity bits, never as shapes), per-shard
    data grads all-reduced with ``psum``, regularizer added once on the
    replicated result — the same ``parallel.sharding`` machinery the LM
    stack trains with.
"""
from __future__ import annotations

import functools
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import packing as _packing
from repro.learn.features import PackedFeatureSpec, feature_spec_for
from repro.learn.linear import (LearnConfig, PackedLinearModel,
                                adam_cosine_train, adam_update,
                                full_batch_fit, packed_data_grads,
                                packed_loss_and_grads, targets_pm)
from repro.obs import (deep_tracing_active, default_flight_recorder,
                       default_registry, span)
from repro.parallel.sharding import shard_map_unchecked

__all__ = ["fit_words", "fit_store", "fit_log", "packed_grads_sharded"]


def _as_fspec(spec, k: int = None,
              normalize: bool = True) -> PackedFeatureSpec:
    """Accept a PackedFeatureSpec, a CodeSpec (+ k), or a sketcher."""
    if isinstance(spec, PackedFeatureSpec):
        return spec
    return feature_spec_for(spec, k, normalize=normalize)


def _zeros_params(fspec: PackedFeatureSpec, n_outputs: int):
    return (jnp.zeros((n_outputs, fspec.table_width), jnp.float32),
            jnp.zeros((n_outputs,), jnp.float32))


# -- sharded gradients --------------------------------------------------------

def packed_grads_sharded(params, words, y_pm, fspec: PackedFeatureSpec,
                         mesh: Mesh, axis: str = "data", *, c: float = 1.0,
                         loss: str = "sq_hinge", valid_words=None,
                         impl: str = "auto"):
    """One data-parallel full objective + gradient evaluation.

    Rows of ``words`` uint32 [n, W] (and target columns of ``y_pm``
    [C, n]) are sharded over ``mesh[axis]``; each shard runs the masked
    fused kernels on its local block, data grads are ``psum``-reduced,
    and the L2 term is added once to the replicated result. Row padding
    up to 32 * mesh-size is carried as dead validity bits (data, not
    shape). Returns (loss, (dTables, dBias)), numerically equal to the
    unsharded ``packed_loss_and_grads`` up to float summation order.
    """
    n = words.shape[0]
    n_sh = mesh.shape[axis]
    live = (jnp.ones((n,), bool) if valid_words is None
            else _packing.unpack_bitmask(valid_words, n))
    pad = (-n) % (32 * n_sh)
    if pad:
        words = jnp.pad(words, ((0, pad), (0, 0)))
        y_pm = jnp.pad(y_pm, ((0, 0), (0, pad)), constant_values=1.0)
        live = jnp.pad(live, (0, pad))
    vw = _packing.pack_bitmask(live)

    def local(tab, b, w_, y_, v_):
        dl, (dt, db) = packed_data_grads((tab, b), w_, y_, fspec, c=c,
                                         loss=loss, valid_words=v_,
                                         impl=impl)
        return (jax.lax.psum(dl, axis), jax.lax.psum(dt, axis),
                jax.lax.psum(db, axis))

    fn = shard_map_unchecked(
        local, mesh,
        in_specs=(P(None, None), P(None), P(axis, None), P(None, axis),
                  P(axis)),
        out_specs=(P(), P(None, None), P(None)))
    tables, bias = params
    data_loss, dt, db = fn(tables, bias, words, y_pm, vw)
    return (0.5 * jnp.sum(tables * tables) + data_loss,
            (dt + tables, db))


# -- fitting ------------------------------------------------------------------

def _fit_full_batch(words, y_pm, fspec, cfg, valid_words, mesh, axis):
    grad_fn = None
    if mesh is not None:
        def grad_fn(p):
            return packed_grads_sharded(p, words, y_pm, fspec, mesh, axis,
                                        c=cfg.c, loss=cfg.loss,
                                        valid_words=valid_words,
                                        impl=cfg.impl)[1]
    return full_batch_fit(words, y_pm, fspec, cfg,
                          valid_words=valid_words, grad_fn=grad_fn)


def _fit_minibatch(words, y_pm, fspec, cfg, mesh, axis):
    n = words.shape[0]
    if cfg.batch > n:
        raise ValueError(f"batch {cfg.batch} > rows {n}")
    init = _zeros_params(fspec, y_pm.shape[0])

    @functools.partial(jax.jit, donate_argnums=(0, 1, 2))
    def step(params, m, v, i, bw, by):
        if mesh is not None:
            g = packed_grads_sharded(params, bw, by, fspec, mesh, axis,
                                     c=cfg.c, loss=cfg.loss,
                                     impl=cfg.impl)[1]
        else:
            g = packed_loss_and_grads(params, bw, by, fspec, c=cfg.c,
                                      loss=cfg.loss, impl=cfg.impl)[1]
        return adam_update(params, m, v, g, i, cfg.steps, cfg.lr)

    rng = np.random.default_rng(cfg.seed)
    params = init
    m = jax.tree.map(jnp.zeros_like, init)
    v = jax.tree.map(jnp.zeros_like, init)
    # per-step device-true timing only while a *deep* tracer is
    # installed: the span sync would otherwise serialize the
    # donated-update pipeline (a shallow RequestTrace never blocks)
    h_step = default_registry().histogram("learn.step_s")
    traced = deep_tracing_active()
    for i in range(cfg.steps):
        idx = jnp.asarray(rng.choice(n, size=cfg.batch, replace=False))
        t0 = time.perf_counter()
        with span("learn.step", step=i) as sp:
            params, m, v = step(params, m, v, jnp.float32(i),
                                jnp.take(words, idx, axis=0),
                                jnp.take(y_pm, idx, axis=1))
            sp.sync(params)
        if traced:
            h_step.observe(time.perf_counter() - t0)
    return params


def _observe_fit_margins(model, words, quality, seed: int):
    """Feed a trained model's margins over a sampled row subset to an
    ``obs.quality.QualityMonitors`` bundle — the post-fit calibration
    snapshot its ``margin_mean`` drift series baselines against."""
    if quality is None or not quality.enabled:
        return
    n = int(np.shape(words)[0])
    if n == 0:
        return
    cap = quality.cfg.margin_sample
    if n > cap:
        idx = np.random.default_rng(seed).choice(n, size=cap,
                                                 replace=False)
        words = jnp.take(jnp.asarray(words), jnp.asarray(np.sort(idx)),
                         axis=0)
    quality.observe_margins(model.margins(words))


def fit_words(words, y, spec, cfg: LearnConfig = LearnConfig(), *,
              k: int = None, valid_words=None, n_outputs: int = 1,
              normalize: bool = True, mesh: Mesh = None,
              axis: str = "data", quality=None) -> PackedLinearModel:
    """Train a packed linear model on uint32 words [n, W].

    ``spec``: PackedFeatureSpec, CodeSpec (+ ``k``), or a sketcher. y:
    ±1 [n] (binary) or int class ids (``n_outputs`` > 1). ``cfg.batch``
    0 trains full-batch under one donated jit'd Adam scan; > 0 streams
    minibatches through a per-step donated update executable (weights
    update in place, one compile total). ``valid_words`` masks
    tombstoned rows (full-batch only); ``mesh`` runs every gradient
    data-parallel over ``mesh[axis]``. ``quality`` (an
    ``obs.quality.QualityMonitors``) receives the trained model's
    margin distribution over a sampled row subset — the calibration
    baseline for its drift trigger.
    """
    fspec = _as_fspec(spec, k, normalize=normalize)
    y_pm = targets_pm(y, n_outputs)
    n = int(np.shape(words)[0])
    t0 = time.perf_counter()
    with span("learn.fit", rows=n, steps=cfg.steps) as sp:
        if cfg.batch:
            if valid_words is not None:
                raise ValueError("minibatch + validity mask unsupported; "
                                 "train full-batch or drop dead rows")
            tables, bias = _fit_minibatch(words, y_pm, fspec, cfg, mesh,
                                          axis)
        else:
            tables, bias = _fit_full_batch(words, y_pm, fspec, cfg,
                                           valid_words, mesh, axis)
        # the fit is over either way: blocking here makes learn.fit_s an
        # execution time, not a dispatch time
        jax.block_until_ready(sp.sync((tables, bias)))
    reg = default_registry()
    reg.counter("learn.rows").inc(n)
    reg.counter("learn.steps").inc(cfg.steps)
    t1 = time.perf_counter()
    reg.histogram("learn.fit_s").observe(t1 - t0)
    # the block_until_ready above makes this an execution-true event
    default_flight_recorder().record("learn.fit", t0, t1, batch=n,
                                     synced=True)
    model = PackedLinearModel(fspec=fspec, tables=tables, bias=bias,
                              loss=cfg.loss)
    _observe_fit_margins(model, words, quality, cfg.seed)
    return model


def fit_store(store, y, spec, cfg: LearnConfig = LearnConfig(), *,
              n_outputs: int = 1, normalize: bool = True,
              mesh: Mesh = None, axis: str = "data") -> PackedLinearModel:
    """Train straight off an ``ann.CodeStore``: the packed corpus that
    serves search is the training set — no unpack, no copy. ``spec``
    supplies n_codes (a CodeSpec or sketcher; k/bits are checked
    against the store)."""
    fspec = _as_fspec(spec, getattr(store, "k", None),
                      normalize=normalize)
    if (fspec.k, fspec.bits) != (store.k, store.bits):
        raise ValueError(f"spec k/bits {(fspec.k, fspec.bits)} != store "
                         f"{(store.k, store.bits)}")
    return fit_words(store.words, y, fspec, cfg, n_outputs=n_outputs,
                     mesh=mesh, axis=axis)


def _segment_targets(seg, labels, n_outputs: int):
    """Per-segment ±1 targets [C, cap] from an external-id label map.

    ``labels``: mapping id -> label, or callable(ids int64 [m]) ->
    labels [m]. Only live rows are looked up (KeyError on a live id
    missing from a mapping); dead and unwritten slots get a +1 filler
    the validity mask zeroes out of loss and gradient anyway.
    """
    fill = 1
    y = np.full(seg.cap, fill, np.int64)
    rows = seg.live_rows()
    if rows.size:
        ids = seg.ids[rows]
        if callable(labels):
            y[rows] = np.asarray(labels(ids), np.int64)
        else:
            y[rows] = [int(labels[int(i)]) for i in ids]
    return targets_pm(jnp.asarray(y), n_outputs)


def fit_log(store, labels, spec, cfg: LearnConfig = LearnConfig(), *,
            n_outputs: int = 1, normalize: bool = True,
            quality=None) -> PackedLinearModel:
    """Train over a live mutable index (``index.SegmentLogStore``).

    Each step runs the masked fused kernels per segment — tombstoned
    and unwritten tail rows contribute exactly nothing — sums the
    per-segment data grads in log order and adds the L2 term once.
    ``labels`` maps *external* ids to labels (dict-like or
    callable(ids) -> labels), so deletes/upserts/compaction between
    calls never invalidate it. The segment snapshot is taken at call
    time; mutate-then-refit to pick up churn — subscribe the refit to a
    ``quality`` bundle's drift alarms (``on_drift``) and pass the same
    bundle here so each refit re-baselines the margin series.
    """
    if cfg.batch:
        raise ValueError("fit_log trains full-batch over the segment "
                         "snapshot; cfg.batch is unsupported (stream "
                         "minibatches with fit_words over live_words())")
    fspec = _as_fspec(spec, store.k, normalize=normalize)
    if (fspec.k, fspec.bits) != (store.k, store.bits):
        raise ValueError(f"spec k/bits {(fspec.k, fspec.bits)} != store "
                         f"{(store.k, store.bits)}")
    if store.n_live == 0:
        raise ValueError("store has no live rows")
    parts = tuple(
        (seg.words, seg.valid_dev(), _segment_targets(seg, labels,
                                                      n_outputs))
        for seg in store.segments() if seg.live)
    init = _zeros_params(fspec, n_outputs)

    def run(params, parts):
        def grad_fn(p):
            tables, _ = p
            dt = jnp.zeros_like(tables)
            db = jnp.zeros_like(p[1])
            for words, vw, y_pm in parts:
                _, (dt_s, db_s) = packed_data_grads(
                    p, words, y_pm, fspec, c=cfg.c, loss=cfg.loss,
                    valid_words=vw, impl=cfg.impl)
                dt = dt + dt_s
                db = db + db_s
            return (dt + tables, db)

        return adam_cosine_train(params, grad_fn, cfg.steps, cfg.lr)

    t0 = time.perf_counter()
    with span("learn.fit", rows=store.n_live, steps=cfg.steps) as sp:
        tables, bias = jax.jit(run, donate_argnums=(0,))(init, parts)
        jax.block_until_ready(sp.sync((tables, bias)))
    reg = default_registry()
    reg.counter("learn.rows").inc(store.n_live)
    reg.counter("learn.steps").inc(cfg.steps)
    reg.histogram("learn.fit_s").observe(time.perf_counter() - t0)
    model = PackedLinearModel(fspec=fspec, tables=tables, bias=bias,
                              loss=cfg.loss)
    if quality is not None and quality.enabled:
        _observe_fit_margins(model, store.live_words(), quality, cfg.seed)
    return model
