"""Fused LUT scoring on bit-packed codes (the re-rank hot loop).

Where ``packed_collision`` ranks by the *diagonal* of the code
contingency table (collision counts), these kernels rank by an arbitrary
per-cell score table (``repro.rank.RankTables``): each b-bit corpus
field selects one of 2^b per-query float entries and the selections
accumulate in float32 — a product-quantization-style asymmetric
distance computation fused with streaming top-k.

Three kernels, all sharing the field loop and the running-top-k merge of
``packed_collision``:

``packed_lut_topk_pallas``
    Full-corpus scored search: streams corpus words per query tile,
    accumulates LUT scores in-register (the [Q, N] score matrix never
    reaches HBM), keeps a running (scores, ids) top-k in VMEM scratch.

``packed_lut_topk_masked_pallas``
    Same with a packed row-validity bitmask (tombstoned rows score -inf
    on device; the mask is data, not shape — zero recompiles).

``packed_lut_rerank_pallas``
    The two-stage second pass: per-query *gathered* candidate rows
    [Q, M, W] (from a coarse packed-collision top-m) plus a validity
    matrix, streaming top-k over the candidate axis. Returned ids are
    candidate positions; callers map them through the coarse id list.

Table lookups are branchless: the 2^b entries of a field's table column
are combined through a depth-b select tree keyed on the field's bits
(``_lut_select``), so the gather is b vectorized selects — no dynamic
indexing in the kernel. Tables may be stored bf16 (``RankTables
.quantize``); they are upcast to float32 at tile load, so accumulation
is float32 either way and matches the jnp oracle bit-for-bit.

Padding: query rows pad with zero tables, corpus rows are masked to -inf
past ``n_valid`` (or via the bitmask), candidate slots pad with validity
0 — so padded entries can never beat the running list's -inf/-1 init
(stable ties keep the earlier -1 entries, exactly like the count
kernels).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import bitmask_width
from repro.kernels.packed_collision import _merge_running_topk, _pad

__all__ = ["packed_lut_topk_pallas", "packed_lut_topk_masked_pallas",
           "packed_lut_rerank_pallas"]

_NEG_INF = float("-inf")


def _lut_select(c, entries):
    """Branchless 2^b-way table lookup: pick entries[c] per lane.

    c: uint32 field values (any broadcastable shape); entries: list of
    2^b arrays (the field's table column, broadcastable against c).
    A depth-b binary select tree on c's bits; returns entries[c]
    element-wise with no gather.
    """
    level = list(entries)
    bit = 0
    while len(level) > 1:
        b = ((c >> jnp.uint32(bit)) & jnp.uint32(1)) != 0
        level = [jnp.where(b, level[2 * i + 1], level[2 * i])
                 for i in range(len(level) // 2)]
        bit += 1
    return level[0]


def _accum_lut_scores(tab, words, bits: int, shape):
    """Accumulate LUT scores over every (word, field) position.

    tab: float32 [bq, F*P]; words: uint32 [bn, W] (corpus tile; fields
    broadcast as [1, bn]) or [bq, bm, W] (candidate tile; fields are
    [bq, bm]). Returns float32 ``shape`` scores, accumulated in (word,
    field) order — the oracle's order, so sums are bit-identical.
    """
    p = 1 << bits
    cpw = 32 // bits
    n_words = words.shape[-1]
    score = jnp.zeros(shape, jnp.float32)
    for w in range(n_words):
        if words.ndim == 2:
            word = words[:, w][None, :]          # [1, bn]
        else:
            word = words[:, :, w]                # [bq, bm]
        for f in range(cpw):
            c = (word >> jnp.uint32(f * bits)) & jnp.uint32(p - 1)
            col = (w * cpw + f) * p
            entries = [tab[:, col + i][:, None] for i in range(p)]
            score = score + _lut_select(c, entries)
    return score


def _init_running(vals_ref, ids_ref):
    vals_ref[...] = jnp.full_like(vals_ref, _NEG_INF)
    ids_ref[...] = jnp.full_like(ids_ref, -1)


# -- full-corpus scored top-k -------------------------------------------------

def _lut_topk_kernel(tab_ref, db_ref, ov_ref, oi_ref, vals_ref, ids_ref, *,
                     bits: int, top_k: int, n_valid: int, block_n: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        _init_running(vals_ref, ids_ref)

    tab = tab_ref[...].astype(jnp.float32)
    db = db_ref[...]
    score = _accum_lut_scores(tab, db, bits,
                              (tab.shape[0], block_n))
    local = jax.lax.broadcasted_iota(jnp.int32, score.shape, 1)
    gids = local + j * block_n
    score = jnp.where(gids < n_valid, score, _NEG_INF)
    _merge_running_topk(vals_ref, ids_ref, score, gids, top_k)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finalize():
        ov_ref[...] = vals_ref[...]
        oi_ref[...] = ids_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("bits", "top_k", "block_q", "block_n", "interpret"))
def packed_lut_topk_pallas(q_tables, words_db, bits: int, top_k: int, *,
                           block_q: int = 128, block_n: int = 512,
                           interpret: bool = False):
    """q_tables float [Q, F*P] (``rank.RankTables.query_tables``),
    words_db uint32 [N, W] -> (scores f32 [Q, top_k], ids int32
    [Q, top_k]), streaming the corpus axis (HBM traffic O(Q*F*P + N*W +
    Q*top_k), never O(Q*N)).

    Bit-exact (scores and lowest-id tie-breaks) vs
    ``ref.packed_lut_topk_ref``; empty slots surface as (-inf, -1).
    """
    qn, fp = q_tables.shape
    n, w = words_db.shape
    assert fp == w * (32 // bits) * (1 << bits), (q_tables.shape,
                                                  words_db.shape, bits)
    tp = _pad(q_tables, block_q, 0)
    dbp = _pad(words_db, block_n, 0)
    qm, nm = tp.shape[0], dbp.shape[0]
    grid = (qm // block_q, nm // block_n)
    kernel = functools.partial(_lut_topk_kernel, bits=bits, top_k=top_k,
                               n_valid=n, block_n=block_n)
    vals, ids = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, fp), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, w), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, top_k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, top_k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qm, top_k), jnp.float32),
            jax.ShapeDtypeStruct((qm, top_k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, top_k), jnp.float32),
            pltpu.VMEM((block_q, top_k), jnp.int32),
        ],
        interpret=interpret,
    )(tp, dbp)
    return vals[:qn], ids[:qn]


# -- scored top-k over live rows only -----------------------------------------

def _lut_topk_masked_kernel(tab_ref, db_ref, valid_ref, ov_ref, oi_ref,
                            vals_ref, ids_ref, *, bits: int, top_k: int,
                            block_n: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        _init_running(vals_ref, ids_ref)

    tab = tab_ref[...].astype(jnp.float32)
    db = db_ref[...]
    score = _accum_lut_scores(tab, db, bits, (tab.shape[0], block_n))
    local = jax.lax.broadcasted_iota(jnp.int32, score.shape, 1)
    gids = local + j * block_n
    # packed validity tile -> row mask, as in packed_collision's masked
    # kernel: bit r%32 of word r//32 is row r (wrapper zeroes bits > N)
    v = valid_ref[...]                                  # [bn/32, 1]
    bitpos = jax.lax.broadcasted_iota(jnp.uint32, (block_n // 32, 32), 1)
    live = ((v >> bitpos) & jnp.uint32(1)).reshape(1, block_n)
    score = jnp.where(live != 0, score, _NEG_INF)
    _merge_running_topk(vals_ref, ids_ref, score, gids, top_k)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finalize():
        ov_ref[...] = vals_ref[...]
        oi_ref[...] = ids_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("bits", "top_k", "block_q", "block_n", "interpret"))
def packed_lut_topk_masked_pallas(q_tables, words_db, valid_words,
                                  bits: int, top_k: int, *,
                                  block_q: int = 128, block_n: int = 512,
                                  interpret: bool = False):
    """Scored streaming top-k over rows whose validity bit is set.

    ``valid_words``: uint32 [ceil(N/32)] bitmask (``packing
    .pack_bitmask`` layout). Dead rows score -inf on device and never
    enter the running list; slots beyond the live count surface as
    (-inf, -1). Bit-exact vs ``ref.packed_lut_topk_masked_ref``. The
    mask is data — tombstone patterns never trigger a recompile.
    """
    qn, fp = q_tables.shape
    n, w = words_db.shape
    assert fp == w * (32 // bits) * (1 << bits), (q_tables.shape,
                                                  words_db.shape, bits)
    assert block_n % 32 == 0, block_n
    nw = bitmask_width(n)
    assert valid_words.shape == (nw,), (valid_words.shape, nw)
    tp = _pad(q_tables, block_q, 0)
    dbp = _pad(words_db, block_n, 0)
    qm, nm = tp.shape[0], dbp.shape[0]
    vw = valid_words.astype(jnp.uint32)
    if n % 32:
        vw = vw.at[-1].set(vw[-1] & jnp.uint32((1 << (n % 32)) - 1))
    vw = jnp.pad(vw, (0, nm // 32 - nw)).reshape(nm // 32, 1)
    grid = (qm // block_q, nm // block_n)
    kernel = functools.partial(_lut_topk_masked_kernel, bits=bits,
                               top_k=top_k, block_n=block_n)
    vals, ids = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, fp), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, w), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n // 32, 1), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, top_k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, top_k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qm, top_k), jnp.float32),
            jax.ShapeDtypeStruct((qm, top_k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, top_k), jnp.float32),
            pltpu.VMEM((block_q, top_k), jnp.int32),
        ],
        interpret=interpret,
    )(tp, dbp, vw)
    return vals[:qn], ids[:qn]


# -- per-query candidate re-rank (two-stage second pass) ----------------------

def _lut_rerank_kernel(tab_ref, cand_ref, valid_ref, ov_ref, oi_ref,
                       vals_ref, ids_ref, *, bits: int, top_k: int,
                       block_m: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        _init_running(vals_ref, ids_ref)

    tab = tab_ref[...].astype(jnp.float32)
    cand = cand_ref[...]                                # [bq, bm, W]
    score = _accum_lut_scores(tab, cand, bits,
                              (tab.shape[0], block_m))
    score = jnp.where(valid_ref[...] != 0, score, _NEG_INF)
    local = jax.lax.broadcasted_iota(jnp.int32, score.shape, 1)
    _merge_running_topk(vals_ref, ids_ref, score, local + j * block_m,
                        top_k)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finalize():
        ov_ref[...] = vals_ref[...]
        oi_ref[...] = ids_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("bits", "top_k", "block_q", "block_m", "interpret"))
def packed_lut_rerank_pallas(q_tables, cand_words, cand_valid, bits: int,
                             top_k: int, *, block_q: int = 128,
                             block_m: int = 512, interpret: bool = False):
    """Re-rank per-query candidates: q_tables [Q, F*P], cand_words
    uint32 [Q, M, W] (coarse-stage gather), cand_valid int32/bool
    [Q, M] -> (scores f32 [Q, top_k], positions int32 [Q, top_k]).

    Positions index the candidate axis; invalid candidates score -inf
    and surface as (-inf, -1). Streams the M axis with the running
    top-k in VMEM — the [Q, M] score matrix never reaches HBM.
    Bit-exact vs ``ref.packed_lut_rerank_ref``.
    """
    qn, fp = q_tables.shape
    n_q, m, w = cand_words.shape
    assert n_q == qn and cand_valid.shape == (qn, m), (
        q_tables.shape, cand_words.shape, cand_valid.shape)
    assert fp == w * (32 // bits) * (1 << bits), (q_tables.shape,
                                                  cand_words.shape, bits)
    tp = _pad(q_tables, block_q, 0)
    cw = _pad(_pad(cand_words, block_q, 0), block_m, 1)
    cv = _pad(_pad(cand_valid.astype(jnp.int32), block_q, 0), block_m, 1)
    qm, mm = cw.shape[0], cw.shape[1]
    grid = (qm // block_q, mm // block_m)
    kernel = functools.partial(_lut_rerank_kernel, bits=bits, top_k=top_k,
                               block_m=block_m)
    vals, ids = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, fp), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, block_m, w), lambda i, j: (i, j, 0)),
            pl.BlockSpec((block_q, block_m), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, top_k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, top_k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qm, top_k), jnp.float32),
            jax.ShapeDtypeStruct((qm, top_k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, top_k), jnp.float32),
            pltpu.VMEM((block_q, top_k), jnp.int32),
        ],
        interpret=interpret,
    )(tp, cw, cv)
    return vals[:qn], ids[:qn]
