"""Coded random-projection sketches — the paper's end-to-end pipeline.

    X [n, D]  --(Gaussian projection R in blocks)-->  [n, k]
              --(b-bit coding scheme)-->              codes [n, k]
              --(bit packing)-->                      uint32 [n, k*b/32]

The projection matrix is never materialized for large D: it is generated
block-by-block from a counter-based PRNG key (``fold_in``), so sketching a
D = 3.2M-dim corpus (the paper's URL dataset) streams R in O(block) memory
and the sketch is reproducible from the seed alone — on a cluster every
host regenerates the same R without any broadcast.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import schemes as _schemes
from repro.core import packing as _packing
from repro.core.estimators import CollisionEstimator
from repro.core.schemes import CodeSpec

__all__ = ["SketchConfig", "CodedRandomProjection"]


@dataclass(frozen=True)
class SketchConfig:
    k: int = 256                    # number of projections
    scheme: str = "2bit"            # paper-recommended default (§8)
    w: float = 0.75                 # paper-recommended first bin width (§8)
    cutoff: float = 6.0
    seed: int = 0
    block_d: int = 4096             # streaming block size over input dim
    dtype: str = "float32"

    @property
    def code_spec(self) -> CodeSpec:
        return CodeSpec(scheme=self.scheme, w=self.w, cutoff=self.cutoff)


class CodedRandomProjection:
    """Sketching engine for a fixed input dimensionality D."""

    def __init__(self, cfg: SketchConfig, d: int):
        self.cfg = cfg
        self.d = int(d)
        self.spec = cfg.code_spec
        self._key = jax.random.PRNGKey(cfg.seed)
        self._offsets = None
        if cfg.scheme == "offset":
            self._offsets = _schemes.sample_offsets(
                jax.random.fold_in(self._key, 0xFFFF), cfg.k, cfg.w,
                dtype=jnp.dtype(cfg.dtype))
        self._estimator = CollisionEstimator(cfg.scheme, cfg.w)

    # -- projection ---------------------------------------------------------
    def _block_r(self, b: int, width: int):
        """Regenerable Gaussian block R[b*block : b*block+width, :k]."""
        key = jax.random.fold_in(self._key, b)
        return jax.random.normal(key, (width, self.cfg.k),
                                 dtype=jnp.dtype(self.cfg.dtype))

    @functools.partial(jax.jit, static_argnums=0)
    def project(self, x):
        """x [n, D] -> [n, k], streaming over D in blocks."""
        n = x.shape[0]
        bd = self.cfg.block_d
        n_blocks = (self.d + bd - 1) // bd
        acc = jnp.zeros((n, self.cfg.k), dtype=jnp.dtype(self.cfg.dtype))
        for b in range(n_blocks):
            lo = b * bd
            hi = min(lo + bd, self.d)
            acc = acc + x[:, lo:hi].astype(acc.dtype) @ self._block_r(b, hi - lo)
        return acc

    # -- coding -------------------------------------------------------------
    def encode(self, x):
        """x [n, D] -> int32 codes [n, k]."""
        return _schemes.encode(self.project(x), self.spec, self._offsets)

    def encode_projected(self, z):
        """Pre-projected z [n, k] -> codes."""
        return _schemes.encode(z, self.spec, self._offsets)

    def pack(self, codes):
        return _packing.pack_codes(codes, self.spec.bits)

    def sketch(self, x):
        """x [n, D] -> packed uint32 sketch [n, k*bits/32]."""
        return self.pack(self.encode(x))

    # -- estimation ---------------------------------------------------------
    def estimate_rho(self, codes_a, codes_b):
        """rho_hat from code arrays [..., k] (table inversion, §3)."""
        return self._estimator.estimate(codes_a, codes_b)

    def estimate_rho_packed(self, words_a, words_b):
        ca = _packing.unpack_codes(words_a, self.spec.bits, self.cfg.k)
        cb = _packing.unpack_codes(words_b, self.spec.bits, self.cfg.k)
        return self.estimate_rho(ca, cb)

    def asymptotic_std(self, rho):
        return self._estimator.asymptotic_std(rho, self.cfg.k)

    # -- storage accounting (the paper's headline economy) -------------------
    def bytes_per_vector(self) -> int:
        return 4 * _packing.packed_width(self.cfg.k, self.spec.bits)

    def fp32_bytes_per_vector(self) -> int:
        return 4 * self.cfg.k

    def with_scheme(self, scheme: str, w: Optional[float] = None):
        cfg = replace(self.cfg, scheme=scheme, w=self.cfg.w if w is None else w)
        return CodedRandomProjection(cfg, self.d)
