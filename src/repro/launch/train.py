"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 1000 --ckpt-dir /ckpts/qwen2 [--mesh single|multi|host]

On a real cluster this process runs per host under the cluster scheduler
(jax.distributed.initialize picks up coordinator env vars); SIGTERM
triggers checkpoint-and-exit so preemptions are lossless, and --resume
auto restarts from the newest complete checkpoint (any mesh: checkpoints
store logical arrays). In this container --mesh host uses the single CPU
device and a reduced config smoke-sizes the run.
"""
from __future__ import annotations

import argparse

import jax

from repro import configs as C
from repro.data import DataConfig, TokenPipeline
from repro.launch.mesh import make_production_mesh
from repro.models import lm as L
from repro.models.nn import count_params, init_params
from repro.optim import AdamWConfig, init_opt_state
from repro.parallel.sharding import ShardingRules
from repro.train import Trainer, TrainState, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--mesh", default="host", choices=["host", "single", "multi"])
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    try:  # multi-host: no-op in single-process environments
        jax.distributed.initialize()
    except Exception:
        pass

    cfg = C.get_smoke_config(args.arch) if args.smoke else C.get_config(args.arch)
    if args.mesh == "host":
        rules = ShardingRules(None)
    else:
        rules = ShardingRules(make_production_mesh(multi_pod=args.mesh == "multi"))

    seq = args.seq or (64 if args.smoke else 4096)
    batch = args.batch or (8 if args.smoke else 256)
    specs = L.model_param_specs(cfg)
    print(f"[train] {cfg.name}: {count_params(specs) / 1e6:.1f}M params, "
          f"seq={seq} batch={batch} mesh={args.mesh}")

    opt_cfg = AdamWConfig(lr_peak=args.lr, decay_steps=max(args.steps, 1000))
    pipe = TokenPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch,
        n_codebooks=cfg.n_codebooks))
    params = init_params(specs, seed=0)
    opt = init_opt_state(params, opt_cfg)
    step_fn = make_train_step(cfg, opt_cfg, rules)
    trainer = Trainer(step_fn, TrainState(params, opt), pipe,
                      ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    trainer.maybe_resume()
    hist = trainer.run(args.steps)
    if hist:
        print(f"[train] final loss {float(hist[-1]['loss']):.4f}")


if __name__ == "__main__":
    main()
