"""Online statistical-health monitors: empirical vs. theory, live.

PR 6 made the system *observable* (latencies, counters, rooflines);
this module makes it *auditable*: the paper's entire argument is
statistical — coded collision rates match the closed-form curves of
``core.probabilities`` and the contingency-cell model of
``core.estimators`` — and a served index can silently stop satisfying
those contracts (input distribution drift, a mis-seeded R, a packing
bug, stale rank tables) while every latency gauge stays green.

``CollisionMonitor``
    Streams *sampled* query-candidate code pairs into two accumulators:
    a per-cell count vector over the scheme's code contingency table
    (the batch reduction runs on device — one ``bincount`` per sampled
    batch, only the O(n_codes^2) count vector ever crosses to host,
    where it pools in exact int64) and Welford moments of the per-pair
    collision fraction. ``report()`` re-estimates rho by maximum
    likelihood over the pooled counts (grid inversion, the
    ``MleRhoEstimator`` table) and compares empirical cell frequencies
    against ``core.estimators.cell_probs`` at that rho-hat: per-cell
    z-scores, a chi-square divergence, and the diagonal empirical
    collision fraction vs. ``core.probabilities.collision_prob`` — all
    as registry gauges. Schemes without a shared cell table (``offset``
    draws per-projection regions) degrade to the match/mismatch
    diagonal, same gauges.

    Caveat (documented, by design): live traffic pools pairs of
    *different* rho, so the pooled table is a mixture and a nonzero
    baseline divergence is expected — the gauges are health *series*
    whose level is tracked by ``obs.drift``, and their absolute
    calibration holds on fixed-rho streams (the property tests pin
    convergence to ``cell_probs`` at known synthetic rho per scheme).

``MarginMonitor``
    Welford moments over classifier decision margins (binary margin, or
    the top-minus-second gap one-vs-rest) — the calibration series the
    ROADMAP's warm-start-refit drift trigger subscribes to.

``QualityMonitors``
    The bundle the serving layer threads through everything: one
    sampling budget (``QualityConfig.sample_rate``, default 1% of
    requests), one seeded RNG, a ``CollisionMonitor`` on the engine's
    scheme, a shadow ground-truth recall monitor (``obs.shadow``), a
    ``MarginMonitor``, and an ``obs.drift.DriftMonitor`` fed with the
    monitored series (per-batch collision fraction, pooled chi-square
    divergence, shadow recall, margin mean). Everything no-ops when the
    registry is disabled; all sampling decisions come from one seeded
    stream so a replayed workload samples identically.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.estimators import cell_probs, region_bounds
from repro.core.probabilities import collision_prob
from repro.core.schemes import CodeSpec, encode
from repro.obs.drift import DriftMonitor
from repro.obs.registry import MetricsRegistry, default_registry

__all__ = ["QualityConfig", "Welford", "CollisionMonitor", "MarginMonitor",
           "QualityMonitors", "synthetic_code_pairs"]


@dataclass(frozen=True)
class QualityConfig:
    """Knobs of the quality-monitoring layer (one sampling budget)."""
    sample_rate: float = 0.01      # fraction of requests monitored
    pairs_per_query: int = 8       # code pairs fed per sampled search
    min_pairs: int = 256           # pooled pairs before z/chi2 gauges report
    reservoir_rows: int = 1024     # shadow reservoir cap (raw f32 rows)
    shadow_top_k: int = 10         # recall@k of the shadow ground truth
    margin_sample: int = 512       # margins monitored per observed batch
    grid_size: int = 512           # rho grid of the MLE/cell-prob table
    seed: int = 0                  # one seeded stream for every decision
    drift_delta: float = 0.002     # Page-Hinkley slack of the series
    drift_threshold: float = 0.25  # Page-Hinkley evidence to fire


class Welford:
    """Streaming mean/variance (Welford's online moments), O(1) state."""

    __slots__ = ("n", "mean", "_m2")

    def __init__(self):
        self.n = 0
        self.mean = 0.0
        self._m2 = 0.0

    def push(self, x: float):
        """Fold one observation into the moments."""
        x = float(x)
        self.n += 1
        d = x - self.mean
        self.mean += d / self.n
        self._m2 += d * (x - self.mean)

    def push_many(self, xs):
        """Fold an iterable of observations."""
        for x in np.asarray(xs, np.float64).ravel():
            self.push(x)

    @property
    def var(self) -> float:
        """Sample variance (ddof=1); nan below two observations."""
        return self._m2 / (self.n - 1) if self.n > 1 else math.nan

    @property
    def std(self) -> float:
        """Sample standard deviation; nan below two observations."""
        v = self.var
        return math.sqrt(v) if v == v else math.nan


def synthetic_code_pairs(spec: CodeSpec, k: int, rho: float, m: int,
                         seed: int = 0, q=None):
    """``m`` code pairs [m, k] at exact correlation ``rho`` — the
    bivariate-normal construction behind Lemma 1, for tests/benches:
    x = z1, y = rho z1 + sqrt(1-rho^2) z2 with z1, z2 iid N(0,1), both
    encoded under ``spec`` (``q`` passes offsets for the offset
    scheme). Returns (codes_x, codes_y) int32 np arrays.
    """
    rng = np.random.default_rng(seed)
    z1 = rng.standard_normal((m, k)).astype(np.float32)
    z2 = rng.standard_normal((m, k)).astype(np.float32)
    y = rho * z1 + math.sqrt(max(0.0, 1.0 - rho * rho)) * z2
    return (np.asarray(encode(jnp.asarray(z1), spec, q)),
            np.asarray(encode(jnp.asarray(y), spec, q)))


class CollisionMonitor:
    """Empirical collision/cell frequencies vs. theory at the MLE rho.

    Feed sampled code pairs via ``observe_pairs``; read pooled health
    via ``report()`` (also mirrored into registry gauges under
    ``<name>.*``). See the module docstring for the statistical model
    and the mixture caveat on pooled live traffic.
    """

    def __init__(self, spec: CodeSpec, k: int, *,
                 registry: MetricsRegistry = None,
                 name: str = "quality.collision", grid_size: int = 512,
                 min_pairs: int = 256, rho_max: float = 0.99995):
        self.spec = spec
        self.k = int(k)
        self.name = name
        self.min_pairs = int(min_pairs)
        self.registry = registry if registry is not None \
            else default_registry()
        self._rho_grid = np.linspace(0.0, rho_max, grid_size)
        try:
            bounds = region_bounds(spec)
            self.n_codes = len(bounds)
            # [G, C] cell-probability table, C = n_codes^2 (row-major)
            self._probs = np.asarray(
                cell_probs(jnp.asarray(self._rho_grid), spec),
                np.float64).reshape(grid_size, -1)
            self.diag_only = False
        except ValueError:
            # offset scheme: regions are per-projection — fall back to
            # the 2-cell match/mismatch table (diagonal-only audit)
            self.n_codes = 0
            p = np.asarray(collision_prob(jnp.asarray(self._rho_grid),
                                          spec.w, spec.scheme), np.float64)
            self._probs = np.stack([p, 1.0 - p], axis=1)
            self.diag_only = True
        self._logp = np.log(np.maximum(self._probs, 1e-30))
        self._diag_idx = (None if self.diag_only else
                          np.arange(self.n_codes) * (self.n_codes + 1))
        self.counts = np.zeros(self._probs.shape[1], np.int64)
        self.pairs = 0
        self.frac = Welford()
        n = self.n_codes

        if self.diag_only:
            def batch(a, b):
                eq = (a == b)
                match = jnp.sum(eq)
                return (jnp.stack([match, a.size - match]),
                        jnp.mean(eq, axis=-1))
        else:
            def batch(a, b):
                return (jnp.bincount((a * n + b).reshape(-1),
                                     length=n * n),
                        jnp.mean(a == b, axis=-1))
        # device-side batch reduction: only the O(cells) count vector
        # and the [m] per-pair fractions ever reach the host
        self._batch_fn = jax.jit(batch)
        reg = self.registry
        self._c_pairs = reg.counter(f"{name}.pairs")
        self._c_batches = reg.counter(f"{name}.batches")

    def observe_pairs(self, codes_a, codes_b) -> dict:
        """Fold one batch of code pairs [m, k] (int arrays, device or
        host) into the pooled accumulators; returns the *batch-local*
        stats {p_batch, rho_batch} (the per-batch series the drift
        detectors watch — pooled stats live in ``report()``)."""
        a = jnp.asarray(codes_a, jnp.int32)
        b = jnp.asarray(codes_b, jnp.int32)
        counts, frac = self._batch_fn(a, b)
        counts = np.asarray(counts, np.int64)
        frac = np.asarray(frac, np.float64)
        self.counts += counts
        self.pairs += frac.size
        self.frac.push_many(frac)
        self._c_pairs.inc(frac.size)
        self._c_batches.inc()
        return {"p_batch": float(frac.mean()),
                "rho_batch": self._mle(counts)}

    def _mle(self, counts: np.ndarray) -> float:
        """Grid MLE over a count vector (host matvec on the log table)."""
        return float(self._rho_grid[int(np.argmax(counts @ self._logp.T))])

    def report(self) -> dict:
        """Pooled empirical-vs-theory health, mirrored into gauges.

        Keys: pairs, rho_hat (pooled MLE), p_hat / p_theory (diagonal
        collision fraction, empirical vs. curve at rho_hat), z_diag,
        z_max (worst cell), chi2 / chi2_per_cell, phat_std /
        phat_std_theory (per-pair collision-fraction spread vs. the
        binomial sqrt(p(1-p)/k)), cell_freq (empirical [C]). Gauges
        only update once ``min_pairs`` pairs pooled.
        """
        n_obs = int(self.counts.sum())
        out = {"pairs": self.pairs, "scheme": self.spec.scheme}
        if n_obs == 0:
            out.update(rho_hat=math.nan, p_hat=math.nan, chi2=math.nan)
            return out
        rho_hat = self._mle(self.counts)
        gi = int(np.searchsorted(self._rho_grid, rho_hat))
        gi = min(gi, len(self._rho_grid) - 1)
        exp_p = self._probs[gi]
        obs_f = self.counts / n_obs
        if self.diag_only:
            p_hat, p_theory = obs_f[0], exp_p[0]
        else:
            p_hat = float(obs_f[self._diag_idx].sum())
            p_theory = float(exp_p[self._diag_idx].sum())
        sd_diag = math.sqrt(max(p_theory * (1 - p_theory), 1e-30) / n_obs)
        live = exp_p > 1e-12
        z = (obs_f[live] - exp_p[live]) / np.sqrt(
            exp_p[live] * (1 - exp_p[live]) / n_obs)
        chi2 = float(np.sum(
            (self.counts[live] - n_obs * exp_p[live]) ** 2
            / (n_obs * exp_p[live])))
        n_cells = int(live.sum())
        out.update(
            rho_hat=rho_hat, p_hat=float(p_hat), p_theory=float(p_theory),
            z_diag=float((p_hat - p_theory) / sd_diag),
            z_max=float(np.abs(z).max()), chi2=chi2,
            chi2_per_cell=chi2 / max(n_cells, 1), n_cells=n_cells,
            phat_std=self.frac.std,
            phat_std_theory=math.sqrt(
                max(p_theory * (1 - p_theory), 0.0) / self.k),
            cell_freq=obs_f)
        if self.pairs >= self.min_pairs:
            reg = self.registry
            for key in ("rho_hat", "p_hat", "p_theory", "z_diag", "z_max",
                        "chi2", "chi2_per_cell", "phat_std",
                        "phat_std_theory"):
                v = out[key]
                if v == v:              # skip nan (empty Welford)
                    reg.gauge(f"{self.name}.{key}").set(v)
        return out

    def reset(self):
        """Drop the pooled accumulators (counts, pairs, moments)."""
        self.counts[:] = 0
        self.pairs = 0
        self.frac = Welford()


class MarginMonitor:
    """Welford moments over classifier decision margins.

    Binary models contribute the signed margin; one-vs-rest models the
    top-minus-second gap (prediction confidence). Mirrors
    ``<name>.mean`` / ``.std`` / ``.n`` gauges; the per-batch mean is
    the drift series (``QualityMonitors`` feeds it).
    """

    def __init__(self, registry: MetricsRegistry = None,
                 name: str = "quality.margin", max_rows: int = 512):
        self.registry = registry if registry is not None \
            else default_registry()
        self.name = name
        self.max_rows = int(max_rows)
        self.moments = Welford()

    def observe(self, margins) -> float:
        """Fold one margin batch [C, m] (np/device); returns the batch
        mean (nan on an empty batch)."""
        m = np.asarray(margins, np.float64)
        if m.ndim == 1:
            m = m[None, :]
        vals = (m[0] if m.shape[0] == 1
                else np.sort(m, axis=0)[-1] - np.sort(m, axis=0)[-2])
        vals = vals[: self.max_rows]
        if vals.size == 0:
            return math.nan
        self.moments.push_many(vals)
        reg = self.registry
        reg.gauge(f"{self.name}.mean").set(self.moments.mean)
        if self.moments.n > 1:
            reg.gauge(f"{self.name}.std").set(self.moments.std)
        reg.gauge(f"{self.name}.n").set(self.moments.n)
        return float(vals.mean())


class QualityMonitors:
    """The quality bundle the serving layer threads through the system.

    One ``sample()`` budget gates every monitor (default 1% of
    requests); the sub-monitors share the registry and one seeded RNG.
    ``observe_search`` is the engines' hook, ``maybe_shadow`` the
    serving flush hook, ``observe_margins`` the classify/trainer hook,
    ``on_store_event`` the segment-log listener (tombstone-aware
    reservoir). ``on_drift(cb)`` registers the drift-alarm callback —
    the contract ``repro.learn``'s warm-start refit subscribes to.
    Everything (sampling included) no-ops while the registry is
    disabled.
    """

    #: drift series names fed by this bundle
    SERIES = ("collision_p", "collision_chi2", "shadow_recall",
              "margin_mean")

    def __init__(self, sketcher, cfg: QualityConfig = QualityConfig(), *,
                 registry: MetricsRegistry = None,
                 drift: DriftMonitor = None):
        from repro.obs.shadow import RecallMonitor, ShadowReservoir

        self.cfg = cfg
        self.sketcher = sketcher
        self.registry = registry if registry is not None \
            else default_registry()
        self.rng = np.random.default_rng(cfg.seed)
        self.collision = CollisionMonitor(
            sketcher.spec, sketcher.cfg.k, registry=self.registry,
            grid_size=cfg.grid_size, min_pairs=cfg.min_pairs)
        self.reservoir = ShadowReservoir(cap=cfg.reservoir_rows,
                                         seed=cfg.seed,
                                         registry=self.registry)
        self.recall = RecallMonitor(self.reservoir, top_k=cfg.shadow_top_k,
                                    registry=self.registry)
        self.margins = MarginMonitor(registry=self.registry,
                                     max_rows=cfg.margin_sample)
        if drift is None:
            from repro.obs.drift import PageHinkley
            drift = DriftMonitor(
                registry=self.registry,
                detector_factory=lambda: PageHinkley(
                    delta=cfg.drift_delta, threshold=cfg.drift_threshold))
        self.drift = drift
        for series in self.SERIES:
            self.drift.detector(series)
        self._c_sampled = self.registry.counter("quality.sampled")
        self._c_skipped_sparse = self.registry.counter(
            "quality.reservoir_skipped_sparse")

    @property
    def enabled(self) -> bool:
        """Whether the monitors do anything (tracks the registry)."""
        return self.registry.enabled

    def sample(self) -> bool:
        """One budgeted coin flip from the seeded stream; always False
        while the registry is disabled."""
        if not self.registry.enabled:
            return False
        if self.rng.random() >= self.cfg.sample_rate:
            return False
        self._c_sampled.inc()
        return True

    def on_drift(self, callback) -> "QualityMonitors":
        """Subscribe ``callback(series, value, detector)`` to drift
        alarms on any monitored series; returns self."""
        self.drift.subscribe(callback)
        return self

    # -- engine hook ---------------------------------------------------------
    def observe_search(self, q_codes, ids, codes_for_ids):
        """Engine hook: budgeted audit of one search batch.

        Samples one query row, gathers the codes of its top
        ``pairs_per_query`` live result ids via ``codes_for_ids(ids_np)
        -> [m, k]``, feeds the collision monitor and the drift series.
        Cost when the sample does not fire: one RNG draw.
        """
        if not self.sample():
            return
        qi = int(self.rng.integers(q_codes.shape[0]))
        row = np.asarray(ids[qi])
        row = row[row >= 0][: self.cfg.pairs_per_query]
        if row.size == 0:
            return
        cand = jnp.asarray(codes_for_ids(row))
        qc = jnp.broadcast_to(jnp.asarray(q_codes)[qi][None, :], cand.shape)
        batch = self.collision.observe_pairs(qc, cand)
        rep = self.collision.report()
        self.drift.update("collision_p", batch["p_batch"])
        if self.collision.pairs >= self.cfg.min_pairs:
            self.drift.update("collision_chi2", rep["chi2_per_cell"])

    # -- serving hooks -------------------------------------------------------
    def shadow_check(self, q_raw, encode_fn, q_codes=None):
        """Ungated shadow ground-truth check of one raw query vector
        (see ``obs.shadow.RecallMonitor``); feeds the ``shadow_recall``
        drift series. Hot paths gate with ``sample()`` first (or call
        ``maybe_shadow``). Returns the query's recall@k or None."""
        if not self.registry.enabled:
            return None
        r = self.recall.observe_query(
            np.asarray(q_raw, np.float32), encode_fn,
            self.sketcher._estimator, q_codes=q_codes)
        if r is not None:
            self.drift.update("shadow_recall", r)
        return r

    def maybe_shadow(self, q_raw, encode_fn, q_codes=None):
        """Serving flush hook: one budgeted coin flip, then
        ``shadow_check`` (no-op when the sample does not fire)."""
        if not self.sample():
            return None
        return self.shadow_check(q_raw, encode_fn, q_codes=q_codes)

    def observe_margins(self, margins):
        """Classify/trainer hook (callers gate with ``sample()`` on hot
        paths): fold a margin batch, feed the ``margin_mean`` series."""
        if not self.registry.enabled:
            return
        m = self.margins.observe(margins)
        if m == m:
            self.drift.update("margin_mean", m)

    # -- reservoir upkeep ----------------------------------------------------
    def offer_rows(self, ids, x):
        """Ingest hook: offer raw rows to the shadow reservoir (sparse
        inputs are skipped — tracked by a counter, never an error)."""
        if not self.registry.enabled:
            return
        if not hasattr(x, "ndim") and not isinstance(x, np.ndarray):
            x = np.asarray(x)
        if getattr(x, "ndim", None) != 2:     # CsrMatrix etc.
            self._c_skipped_sparse.inc()
            return
        self.reservoir.offer(np.asarray(ids, np.int64),
                             np.asarray(x, np.float32))

    def on_store_event(self, event: str, ids):
        """Segment-log listener: keeps the reservoir tombstone-aware
        (deletes drop their rows; compaction changes nothing — external
        ids are stable)."""
        if event == "delete" and ids is not None:
            self.reservoir.remove(ids)

    # -- one-call view -------------------------------------------------------
    def report(self) -> dict:
        """Pooled health of every monitor as one plain dict (the gauges'
        source of truth; also exported via ``obs.export.snapshot``)."""
        rep = self.collision.report()
        rep.pop("cell_freq", None)
        return {"collision": rep,
                "shadow": self.recall.report(),
                "margin": {"mean": self.margins.moments.mean,
                           "std": self.margins.moments.std,
                           "n": self.margins.moments.n},
                "drift": {s: {"stat": self.drift.detector(s).stat,
                              "alarms": self.drift.alarms(s)}
                          for s in self.SERIES}}
