"""Pallas TPU kernels for the paper's compute hot-spots.

proj_code        — fused projection GEMM + in-register coding (MXU + epilogue)
pack_codes       — b-bit field packing into uint32 words (VPU)
collision        — all-pairs code-match counting on int32 codes (VPU)
packed_collision — collision counts + fused streaming top-k directly on
                   packed uint32 words (XOR/fold/popcount; ANN hot loop)

Each has a pure-jnp oracle in ref.py and a dispatching wrapper in ops.py;
tests sweep shapes/dtypes in interpret mode against the oracles.
"""
from repro.kernels.ops import (  # noqa: F401
    coded_project, pack_codes, collision_counts, packed_collision_counts,
    packed_topk,
)
