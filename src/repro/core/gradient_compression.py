"""Coded-sketch gradient compression for data-parallel training.

The paper's economics applied to the collective-bound regime: instead of
all-reducing fp32 gradients, each DP rank

    1. adds its error-feedback residual (EF-SGD),
    2. splits the flat gradient into `chunk`-sized blocks and rotates each
       into a random orthonormal basis R [chunk, k] (column-orthonormal,
       derived once from the seed; k = chunk/rate). Orthonormality makes
       decode an exact subspace projection — a CONTRACTION, which EF-SGD
       needs (a plain Gaussian sketch has reconstruction rel-err ~sqrt(rate)
       >= 1 and diverges; found by test_grad_compression). Rotated unit
       blocks scaled by sqrt(chunk) have ~N(0,1) coords — exactly the
       paper's setting,
    3. **codes** each rotated value with one of the paper's schemes
       (sign / 2-bit non-uniform / uniform / dithered offset),
    4. all-gathers the packed codes + per-block scales (tiny vs fp32
       grads),
    5. dequantizes with the N(0,1) conditional-mean centroid of each code
       cell, averages over ranks, and back-projects  ĝ = R ẑ / k.

For *similarity* the paper shows the offset (dither) is unnecessary; for
*mean estimation* dithering restores unbiasedness at the cost of higher
variance — both are selectable and compared in EXPERIMENTS.md. Error
feedback makes the iteration contract either way.

Bytes on the wire per rank: G/rate values at `bits` bits vs 32-bit
all-reduce -> wire ratio = 32 * rate / bits (e.g. rate=8, 2-bit: 128x
smaller payload; with a P-way gather the net collective-term win is
32*rate/(bits*P) vs ring all-reduce).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import schemes as _schemes
from repro.core.schemes import CodeSpec

__all__ = ["GradCompressionConfig", "GradCompressor", "code_centroids"]


@dataclass(frozen=True)
class GradCompressionConfig:
    scheme: str = "2bit"        # sign | 2bit | uniform | offset
    w: float = 0.75             # paper-recommended bin width (§8)
    rate: int = 1               # subspace compression: k = chunk / rate
    chunk: int = 1024           # rotation block (QR'd once at init)
    error_feedback: bool = True
    seed: int = 17
    cutoff: float = 6.0

    @property
    def k(self) -> int:
        return self.chunk // self.rate

    @property
    def spec(self) -> CodeSpec:
        return CodeSpec(scheme=self.scheme, w=self.w, cutoff=self.cutoff)


def code_centroids(spec: CodeSpec, offsets=None) -> np.ndarray:
    """E[z | code] under z ~ N(0,1): the MMSE dequantizer per code cell.

    For the offset scheme the cells shift by the (known) per-projection
    offset; we return the zero-offset table and apply the shift at decode
    (the offset enters the cell boundaries, E[z|cell] uses the same
    truncated-normal formula).
    """
    from scipy import stats

    def trunc_mean(a, b):
        pa, pb = stats.norm.cdf(a), stats.norm.cdf(b)
        if pb - pa < 1e-12:
            return 0.5 * (max(a, -spec.cutoff) + min(b, spec.cutoff))
        return (stats.norm.pdf(a) - stats.norm.pdf(b)) / (pb - pa)

    if spec.scheme == "sign":
        return np.asarray([trunc_mean(-np.inf, 0.0), trunc_mean(0.0, np.inf)],
                          np.float32)
    if spec.scheme == "2bit":
        w = spec.w
        return np.asarray([trunc_mean(-np.inf, -w), trunc_mean(-w, 0.0),
                           trunc_mean(0.0, w), trunc_mean(w, np.inf)],
                          np.float32)
    if spec.scheme in ("uniform", "offset"):
        n = spec.n_bins_side
        edges = (np.arange(-n, n + 1)) * spec.w
        return np.asarray([trunc_mean(edges[i], edges[i + 1])
                           for i in range(2 * n)], np.float32)
    raise ValueError(spec.scheme)


class GradCompressor:
    """Stateless-math compressor bound to a gradient pytree template."""

    def __init__(self, cfg: GradCompressionConfig, grad_template):
        self.cfg = cfg
        leaves = jax.tree.leaves(grad_template)
        self.sizes = [int(np.prod(x.shape)) for x in leaves]
        self.total = sum(self.sizes)
        self.n_chunks = (self.total + cfg.chunk - 1) // cfg.chunk
        self.padded = self.n_chunks * cfg.chunk
        self.treedef = jax.tree.structure(grad_template)
        self.shapes = [x.shape for x in leaves]
        self._centroids = jnp.asarray(code_centroids(cfg.spec))
        key = jax.random.PRNGKey(cfg.seed)
        self._rkey = jax.random.fold_in(key, 0)
        # computed EAGERLY: a lazily-cached jnp value created inside a
        # traced context would leak a tracer into later calls
        g = jax.random.normal(self._rkey, (cfg.chunk, cfg.chunk), jnp.float32)
        q, _ = jnp.linalg.qr(g)
        self._r_np = np.asarray(q[:, :cfg.k])
        if cfg.scheme == "offset":
            self._offsets = _schemes.sample_offsets(
                jax.random.fold_in(key, 1), cfg.k, cfg.w)
        else:
            self._offsets = None

    # -- layout ---------------------------------------------------------------
    def _flatten(self, tree):
        flat = jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                                for x in jax.tree.leaves(tree)])
        return jnp.pad(flat, (0, self.padded - self.total))

    def _unflatten(self, vec):
        out, off = [], 0
        leaves = []
        for shape, size in zip(self.shapes, self.sizes):
            leaves.append(vec[off:off + size].reshape(shape))
            off += size
        return jax.tree.unflatten(self.treedef, leaves)

    def _r(self):
        # column-orthonormal basis derived from the seed (never
        # communicated: every rank regenerates the same R). QR is a
        # one-time O(chunk^3) init cost; a subsampled randomized Hadamard
        # transform is the O(n log n) production alternative.
        return jnp.asarray(self._r_np)

    def _signs(self, step):
        """Per-step Rademacher re-randomization: with a FIXED subspace the
        EF residual's orthogonal component would never be transmitted and
        EF diverges (found by test_grad_compression); sign-flipping the
        input re-orients the subspace every step at O(n) cost."""
        key = jax.random.fold_in(self._rkey, jnp.asarray(step, jnp.uint32))
        return jax.random.rademacher(key, (self.cfg.chunk,),
                                     jnp.float32)

    # -- encode / decode --------------------------------------------------------
    def encode(self, g_vec, step=0):
        """[padded] -> (codes int32 [nc, k], scales [nc])."""
        c = self.cfg
        blocks = g_vec.reshape(self.n_chunks, c.chunk)
        scales = jnp.linalg.norm(blocks, axis=1) + 1e-12
        # sign-flip + rotate the unit block; sqrt(chunk) -> ~N(0,1) coords
        blocks = blocks * self._signs(step)
        z = (blocks / scales[:, None]) @ self._r() * math.sqrt(c.chunk)
        codes = _schemes.encode(z, c.spec, self._offsets)
        return codes, scales

    def decode(self, codes, scales, step=0):
        """Inverse map: codes -> ẑ -> ĝ blocks -> flat vector."""
        c = self.cfg
        z_hat = self._centroids[codes]
        g_blocks = (z_hat @ self._r().T) / math.sqrt(c.chunk) * scales[:, None]
        return (g_blocks * self._signs(step)).reshape(-1)

    # -- distributed sync -------------------------------------------------------
    def sync(self, grads, ef, axis_name, step=0):
        """Inside shard_map over the DP axis: returns (synced_grads, new_ef).

        grads: local (per-shard) gradient pytree. ef: error-feedback pytree
        (or None). axis_name: DP axis (string or tuple). Codes travel
        bit-packed (b bits per projection on the wire, plus one f32 scale
        per chunk) — the paper's storage economy, applied to the link.
        """
        from repro.core import packing as _pk

        g = self._flatten(grads)
        if ef is not None:
            g = g + self._flatten(ef)
        codes, scales = self.encode(g, step)
        g_local_hat = self.decode(codes, scales, step)
        new_ef = self._unflatten(g - g_local_hat) if ef is not None else None

        bits = self.cfg.spec.bits
        packed = _pk.pack_codes(codes, bits)                 # [nc, k*b/32]
        all_packed = jax.lax.all_gather(packed, axis_name)   # [P, nc, words]
        all_scales = jax.lax.all_gather(scales, axis_name)   # [P, nc]
        p = all_packed.shape[0]
        all_codes = _pk.unpack_codes(all_packed, bits, self.cfg.k)
        z_hat = self._centroids[all_codes]                   # [P, nc, k]
        z_mean = jnp.einsum("pnk,pn->nk", z_hat, all_scales) / p
        g_hat = (z_mean @ self._r().T) / math.sqrt(self.cfg.chunk)
        g_hat = g_hat * self._signs(step)[None, :]
        return self._unflatten(g_hat.reshape(-1)), new_ef

    def sync_local(self, grads, ef, step=0):
        """Single-rank path (no collective): compress -> decode, with error
        feedback. Semantically identical to sync() at world size 1."""
        g = self._flatten(grads)
        if ef is not None:
            g = g + self._flatten(ef)
        codes, scales = self.encode(g, step)
        g_hat = self.decode(codes, scales, step)
        new_ef = self._unflatten(g - g_hat) if ef is not None else None
        return self._unflatten(g_hat), new_ef

    def init_ef(self, grad_template):
        return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                            grad_template) if self.cfg.error_feedback else None

    # -- accounting --------------------------------------------------------------
    def wire_bytes(self) -> int:
        """Payload bytes per rank per sync (codes packed + scales)."""
        bits = self.cfg.spec.bits
        return self.n_chunks * (self.cfg.k * bits // 8 + 4)

    def fp32_bytes(self) -> int:
        return self.total * 4
