"""Bit-packing of codes into uint32 words (pure-jnp reference layer).

The storage argument of the paper: a b-bit code should occupy b bits.
``pack_codes``/``unpack_codes`` lay out 32/b codes per uint32 word along
the last axis. The Pallas kernel in ``repro.kernels.pack_codes`` targets
the same layout; this module is its oracle and the CPU fallback.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["codes_per_word", "packed_width", "pack_codes", "unpack_codes",
           "hamming_packed", "match_count_packed_1bit", "field_lsb_mask",
           "fold_nonzero_fields", "mismatch_count_words",
           "match_count_packed", "bitmask_width", "pack_bitmask",
           "unpack_bitmask"]


def codes_per_word(bits: int) -> int:
    if bits not in (1, 2, 4, 8, 16):
        raise ValueError(f"bits must divide 32 and be <=16, got {bits}")
    return 32 // bits


def packed_width(k: int, bits: int) -> int:
    cpw = codes_per_word(bits)
    return (k + cpw - 1) // cpw


def pack_codes(codes, bits: int):
    """Pack int codes in [0, 2^bits) along the last axis into uint32 words.

    codes: int array [..., k]. Returns uint32 [..., ceil(k/(32/bits))].
    k is zero-padded to a multiple of 32/bits.
    """
    cpw = codes_per_word(bits)
    k = codes.shape[-1]
    pad = (-k) % cpw
    if pad:
        codes = jnp.pad(codes, [(0, 0)] * (codes.ndim - 1) + [(0, pad)])
    c = codes.astype(jnp.uint32).reshape(codes.shape[:-1] + (-1, cpw))
    shifts = (jnp.arange(cpw, dtype=jnp.uint32) * jnp.uint32(bits))
    # fields are disjoint, so an integer sum equals the bitwise-or
    return jnp.sum(c << shifts, axis=-1, dtype=jnp.uint32)


def unpack_codes(words, bits: int, k: int):
    """Inverse of pack_codes. Returns int32 [..., k]."""
    cpw = codes_per_word(bits)
    shifts = (jnp.arange(cpw, dtype=jnp.uint32) * jnp.uint32(bits))
    mask = jnp.uint32((1 << bits) - 1)
    c = (words[..., None] >> shifts) & mask
    c = c.reshape(words.shape[:-1] + (-1,))
    return c[..., :k].astype(jnp.int32)


def _popcount32(x):
    """Vectorized popcount on uint32."""
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (x * jnp.uint32(0x01010101)) >> 24


def hamming_packed(a, b):
    """Hamming distance between packed 1-bit code rows: sum popcount(a^b)."""
    return jnp.sum(_popcount32(jnp.bitwise_xor(a, b)), axis=-1).astype(jnp.int32)


def match_count_packed_1bit(a, b, k: int):
    """Number of colliding 1-bit codes = k - hamming (padding bits cancel
    in xor since both padded with zeros)."""
    return k - hamming_packed(a, b)


def field_lsb_mask(bits: int) -> int:
    """uint32 mask with a 1 at the least-significant bit of every b-bit
    field: 0xFFFFFFFF (b=1), 0x55555555 (b=2), 0x11111111 (b=4), ..."""
    cpw = codes_per_word(bits)
    return sum(1 << (i * bits) for i in range(cpw))


def fold_nonzero_fields(x, bits: int):
    """OR-fold each b-bit field of uint32 ``x`` onto its LSB.

    After the fold, bit i*b of the result is 1 iff field i of ``x`` is
    nonzero (higher bits of each field hold garbage; mask with
    ``field_lsb_mask``). Shift amounts stay < b, so cross-field
    contamination never reaches a field's LSB.
    """
    s = 1
    while s < bits:
        x = x | (x >> jnp.uint32(s))
        s *= 2
    return x


def mismatch_count_words(xor_words, bits: int):
    """Per-word count of differing b-bit fields from XORed packed words."""
    folded = fold_nonzero_fields(xor_words, bits)
    return _popcount32(folded & jnp.uint32(field_lsb_mask(bits)))


def bitmask_width(n: int) -> int:
    """Words in a packed 1-bit-per-row validity mask over n rows."""
    return (n + 31) // 32


def pack_bitmask(flags):
    """Bool/int flags [..., n] -> uint32 words [..., ceil(n/32)].

    Bit ``r % 32`` of word ``r // 32`` is flag r (LSB-first, same
    convention as ``pack_codes`` with bits=1); any nonzero flag counts
    as set. Rows are zero-padded, so bits past n are always 0 — kernels
    rely on that to mask row padding.
    """
    return pack_codes((jnp.asarray(flags) != 0).astype(jnp.int32), 1)


def unpack_bitmask(words, n: int):
    """Inverse of ``pack_bitmask``: uint32 [..., W] -> bool [..., n]."""
    return unpack_codes(words, 1, n).astype(bool)


def match_count_packed(a, b, bits: int, k: int):
    """Number of colliding b-bit codes between packed rows a, b [..., W].

    The oracle for ``kernels.packed_collision``: XOR, OR-fold each field
    to its LSB, popcount the mismatch bits. Zero-padded fields (k not a
    multiple of 32/b) XOR to zero in both operands and so never count as
    mismatches; matches over the k real fields = k - mismatches.
    """
    xor = jnp.bitwise_xor(a, b)
    mism = jnp.sum(mismatch_count_words(xor, bits), axis=-1).astype(jnp.int32)
    return k - mism
