"""Device/host resource accounting: live bytes, watermarks, recompiles.

The serving tier's capacity questions — "does the next shard fit?",
"is something leaking device memory?", "did the hot path silently start
recompiling?" — need numbers, not vibes. This module is the accounting
layer:

* **tracked live bytes** — ``track(name, obj)`` registers anything with
  an ``nbytes`` attribute/property (``CodeStore``, ``SegmentLogStore``,
  a ``PackedLinearModel``'s tables) or a zero-arg callable; ``collect``
  mirrors each into a ``resources.bytes.<name>`` gauge. These are the
  *modeled* byte counts the stores already maintain, aggregated in one
  place.
* **device memory** — total bytes of every live jax array
  (``jax.live_arrays``) plus the per-device allocator watermarks from
  ``device.memory_stats()`` where the backend provides them (TPU/GPU;
  CPU returns none — gauges simply stay absent, never raise).
* **host RSS** — current resident set from ``/proc/self/status`` (zero
  dependencies; NaN on platforms without procfs) and the peak RSS from
  ``resource.getrusage``.
* **jit recompiles** — a process-wide compile counter fed by a
  ``jax.monitoring`` duration listener on backend compiles. The
  ARCHITECTURE "never-recompile" invariant (serving traffic must reuse
  the warmed executables) becomes a runtime-enforced number:
  ``mark()`` pins a baseline after warmup, ``compiles_since_mark``
  must stay 0, and ``SloEngine.attach_resources`` turns any excursion
  into a budget burn + alert. The listener is installed process-wide
  exactly once (``install_compile_counter`` is idempotent) and counts
  into a module global, so monitors on any registry read one truth.
"""
from __future__ import annotations

import math
import os

import jax

from repro.obs.registry import MetricsRegistry, default_registry

__all__ = ["ResourceMonitor", "install_compile_counter", "jit_compiles"]

_COMPILES = 0
_LISTENER_INSTALLED = False

#: the jax.monitoring duration event emitted once per backend compile
_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


def _on_compile_duration(event: str, duration: float, **_kw):
    global _COMPILES
    if event == _COMPILE_EVENT:
        _COMPILES += 1


def install_compile_counter() -> bool:
    """Install the process-wide compile listener (idempotent; returns
    whether it is installed). Safe on any jax backend — if the
    monitoring hook is unavailable the counter simply stays at 0."""
    global _LISTENER_INSTALLED
    if _LISTENER_INSTALLED:
        return True
    try:
        from jax import monitoring as _monitoring
        _monitoring.register_event_duration_secs_listener(
            _on_compile_duration)
        _LISTENER_INSTALLED = True
    except Exception:
        return False
    return True


def jit_compiles() -> int:
    """Process-wide backend compiles seen since the listener was
    installed (0 until ``install_compile_counter`` ran)."""
    return _COMPILES


def _host_rss_bytes() -> float:
    """Current resident set size from procfs; NaN when unavailable."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmRSS:"):
                    return float(line.split()[1]) * 1024.0
    except OSError:
        pass
    return math.nan


def _host_peak_rss_bytes() -> float:
    """Peak RSS via getrusage (ru_maxrss is KiB on linux)."""
    try:
        import resource
        return float(resource.getrusage(
            resource.RUSAGE_SELF).ru_maxrss) * 1024.0
    except Exception:
        return math.nan


class ResourceMonitor:
    """One scope of resource gauges (see module docstring).

    ``collect()`` is the slow-path refresh (dashboard render, SLO tick
    at resolution, incident capture) — it walks tracked objects, live
    arrays, and procfs; nothing here belongs on a per-request path.
    Every gauge lands in the registry under ``resources.*`` and the
    same values come back as the return dict.
    """

    def __init__(self, registry: MetricsRegistry = None,
                 live_arrays: bool = True):
        self.registry = registry if registry is not None \
            else default_registry()
        self.live_arrays = bool(live_arrays)
        self._tracked: dict = {}
        self._mark = 0
        install_compile_counter()

    def track(self, name: str, obj) -> "ResourceMonitor":
        """Register ``obj`` under ``name``: anything with an ``nbytes``
        attribute (stores, models) or a zero-arg callable returning
        bytes; returns self for chaining."""
        self._tracked[str(name)] = obj
        return self

    def untrack(self, name: str):
        """Forget a tracked object (missing name is a no-op)."""
        self._tracked.pop(str(name), None)

    @staticmethod
    def _bytes_of(obj) -> float:
        if callable(obj) and not hasattr(obj, "nbytes"):
            return float(obj())
        v = getattr(obj, "nbytes", math.nan)
        return float(v() if callable(v) else v)

    # -- recompile accounting ------------------------------------------------
    def jit_compiles(self) -> int:
        """Process-wide compile count (module-global truth)."""
        return jit_compiles()

    def mark(self) -> int:
        """Pin the compile baseline (call after warmup/autotune);
        returns the baseline count."""
        self._mark = jit_compiles()
        return self._mark

    @property
    def compiles_since_mark(self) -> int:
        """Compiles since ``mark()`` — the never-recompile invariant
        says this stays 0 on a warmed serving path."""
        return jit_compiles() - self._mark

    # -- the one-call refresh ------------------------------------------------
    def collect(self) -> dict:
        """Refresh every gauge; returns the resource dict."""
        reg = self.registry
        out = {"tracked": {}, "device": {}, "host": {}}
        total_tracked = 0.0
        for name, obj in self._tracked.items():
            try:
                b = self._bytes_of(obj)
            except Exception:
                b = math.nan
            out["tracked"][name] = b
            if b == b:
                total_tracked += b
                reg.gauge(f"resources.bytes.{name}").set(b)
        out["tracked_total"] = total_tracked
        reg.gauge("resources.bytes.tracked_total").set(total_tracked)

        if self.live_arrays:
            try:
                live = sum(a.nbytes for a in jax.live_arrays())
                out["device"]["live_bytes"] = int(live)
                reg.gauge("resources.device.live_bytes").set(live)
            except Exception:
                out["device"]["live_bytes"] = math.nan
        for d in jax.devices():
            try:
                stats = d.memory_stats()
            except Exception:
                stats = None
            if not stats:
                continue
            did = f"{d.platform}{d.id}"
            used = stats.get("bytes_in_use")
            peak = stats.get("peak_bytes_in_use")
            if used is not None:
                out["device"][f"{did}.bytes_in_use"] = int(used)
                reg.gauge(f"resources.device.{did}.bytes_in_use").set(used)
            if peak is not None:
                out["device"][f"{did}.peak_bytes"] = int(peak)
                reg.gauge(f"resources.device.{did}.peak_bytes").set(peak)

        rss = _host_rss_bytes()
        peak = _host_peak_rss_bytes()
        out["host"]["rss_bytes"] = rss
        out["host"]["peak_rss_bytes"] = peak
        if rss == rss:
            reg.gauge("resources.host.rss_bytes").set(rss)
        if peak == peak:
            reg.gauge("resources.host.peak_rss_bytes").set(peak)

        out["jit_compiles"] = jit_compiles()
        out["compiles_since_mark"] = self.compiles_since_mark
        reg.gauge("resources.jit_compiles").set(out["jit_compiles"])
        reg.gauge("resources.compiles_since_mark").set(
            out["compiles_since_mark"])
        return out
