"""Linear SVM on coded random projections (paper §6).

The paper trains L2-regularized linear SVMs (LIBLINEAR) on a one-hot
expansion of the codes: with k projections and a b-bit scheme the feature
vector has length k * 2^b with exactly k ones. We reproduce the pipeline
with a JAX solver for the (smooth) squared-hinge L2 SVM:

    min_W  0.5 ||W||^2 + C sum_i max(0, 1 - y_i w.x_i)^2

solved by full-batch Adam with cosine decay (deterministic; LIBLINEAR is
not available offline — objective family is identical to its L2R_L2LOSS
primal). Inputs are row-normalized to unit norm as the paper recommends.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.schemes import CodeSpec

__all__ = ["expand_codes", "SVMConfig", "train_linear_svm", "svm_accuracy"]


def expand_codes(codes, spec: CodeSpec, normalize: bool = True):
    """One-hot expand codes [n, k] -> features [n, k * n_codes] (§6).

    Each projection contributes one 1 in its n_codes-wide slot; rows are
    scaled to unit norm (1/sqrt(k)) per the paper's recommended practice.
    """
    n, k = codes.shape
    one_hot = jax.nn.one_hot(codes, spec.n_codes, dtype=jnp.float32)
    feats = one_hot.reshape(n, k * spec.n_codes)
    if normalize:
        feats = feats / jnp.sqrt(jnp.asarray(float(k)))
    return feats


@dataclass(frozen=True)
class SVMConfig:
    c: float = 1.0           # L2 regularization tradeoff (LIBLINEAR's C)
    steps: int = 400
    lr: float = 0.1
    seed: int = 0


def _objective(params, x, y, c):
    w, b = params
    margin = y * (x @ w + b)
    hinge = jnp.maximum(0.0, 1.0 - margin)
    return 0.5 * jnp.sum(w * w) + c * jnp.sum(hinge * hinge)


def train_linear_svm(x, y, cfg: SVMConfig = SVMConfig(),
                     x_val: Optional[jnp.ndarray] = None,
                     y_val: Optional[jnp.ndarray] = None):
    """Train binary squared-hinge SVM. y in {-1, +1}. Returns (w, b)."""
    n, d = x.shape
    w = jnp.zeros((d,), jnp.float32)
    b = jnp.zeros((), jnp.float32)
    m = (jnp.zeros_like(w), jnp.zeros_like(b))
    v = (jnp.zeros_like(w), jnp.zeros_like(b))
    grad_fn = jax.grad(_objective)

    def step(carry, i):
        (w, b), m, v = carry
        g = grad_fn((w, b), x, y, cfg.c)
        lr = cfg.lr * 0.5 * (1.0 + jnp.cos(jnp.pi * i / cfg.steps))
        b1, b2, eps = 0.9, 0.999, 1e-8
        m = jax.tree.map(lambda mm, gg: b1 * mm + (1 - b1) * gg, m, g)
        v = jax.tree.map(lambda vv, gg: b2 * vv + (1 - b2) * gg * gg, v, g)
        t = i + 1.0
        def upd(p, mm, vv):
            mh = mm / (1 - b1 ** t)
            vh = vv / (1 - b2 ** t)
            return p - lr * mh / (jnp.sqrt(vh) + eps)
        w2, b2_ = jax.tree.map(upd, (w, b), m, v)
        return ((w2, b2_), m, v), None

    ((w, b), _, _), _ = jax.lax.scan(
        step, ((w, b), m, v), jnp.arange(cfg.steps, dtype=jnp.float32))
    return w, b


def svm_accuracy(w, b, x, y):
    pred = jnp.sign(x @ w + b)
    pred = jnp.where(pred == 0, 1.0, pred)
    return jnp.mean((pred == y).astype(jnp.float32))
