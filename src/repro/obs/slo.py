"""Closed-loop SLO engine: error budgets, burn rates, health verdicts.

PRs 6-9 made the system observable (metrics, quality series, flight
forensics); nothing yet turned those signals into *decisions*. This
module is the decision layer: declarative per-endpoint ``SloSpec``s,
rolling multi-window error-budget accounting, Google-SRE-style
multi-window multi-burn-rate alerting, and a machine-readable
``health()`` verdict — the admission-control input the ROADMAP's async
serving tier consumes.

Health here is inherently two-dimensional. The paper's claim is that a
few coded bits preserve similarity, so a served index can fail on
*latency* (the classic SLO) or on *estimation quality* (recall/margin
drift that every latency gauge is blind to). An ``SloSpec`` therefore
names up to three objectives over one endpoint:

* **latency** — fraction of requests finishing within
  ``latency_target_s`` (the serving layer passes ``cfg.deadline_s``).
  Lateness counts are derived from the *existing* registry histogram's
  bucket counts (everything in buckets above the target's bucket is
  late) — no per-request state, no stored samples; resolution is one
  histogram bucket (~19% with the default spec).
* **availability** — fraction of requests that did not raise, from the
  endpoint's error counter against the same histogram's total.
* **quality** — fraction of quality observations (shadow recall from
  ``obs.shadow``, canary-probe verdicts from ``obs.probe``) at or above
  ``quality_min``. These are *push* events (``observe_quality`` /
  ``observe_probe``) because quality truth only exists when a sampled
  shadow check or probe ran.

Error budgets follow the SRE book: an objective of 0.99 grants a 1%
budget of bad events; the **burn rate** over a window is
``bad_fraction / (1 - objective)`` — 1.0 consumes exactly the budget
over that window, 14.4 exhausts a 30-day budget in 2 days. Windowed
fractions come from a ring of periodic cumulative-counter snapshots
(one ``(t, total, bad)`` tuple per ``resolution`` seconds, O(window /
resolution) memory — the "sliding counters, no stored samples"
invariant). An alert fires only when BOTH windows of a ``BurnPolicy``
pair exceed its threshold — the long window supplies significance, the
short window confirms the problem is still happening (so a fixed
regression stops paging without waiting out the long window).

Alert callbacks use the ``DriftMonitor`` contract ``callback(series,
value, detector)`` with ``series = "slo.<ledger>"`` and a detector-like
``AlertState`` (``side``/``alarms``/``stat``) — so the serving layer's
existing drift wiring (flag the in-flight trace, dump an
``IncidentManager`` bundle) works on SLO alarms unchanged.

``health()`` returns the machine verdict: overall ``status`` ("ok" |
"degraded"), the active alert series, per-ledger burn rates and budget
remaining, and an advisory ``shed_fraction`` (how much traffic
admission control would need to reject for the worst fast-window burn
to drop back to its threshold) — deliberately shaped as the input for
the upcoming async admission controller.
"""
from __future__ import annotations

import math
import time
from collections import deque
from dataclasses import dataclass

from repro.obs.registry import MetricsRegistry, default_registry

__all__ = ["SloSpec", "BurnPolicy", "AlertState", "SloEngine",
           "DEFAULT_POLICIES"]


@dataclass(frozen=True)
class BurnPolicy:
    """One multi-window burn-rate alert rule: fire when the budget burn
    rate exceeds ``threshold`` over BOTH the ``long_s`` and ``short_s``
    windows (SRE book ch. 5: the long window is significance, the short
    window is "still happening"). ``min_events`` additionally requires
    that many events inside the long window — two bad requests during a
    cold start must not page."""
    long_s: float = 60.0
    short_s: float = 5.0
    threshold: float = 14.4
    severity: str = "page"
    min_events: int = 20


#: default pair: a fast page (budget gone in ~4% of the long horizon)
#: and a slow ticket (sustained 6x burn). Horizons are scaled to an
#: in-process server's lifetime, not a 30-day fleet — override per
#: deployment.
DEFAULT_POLICIES = (BurnPolicy(60.0, 5.0, 14.4, "page"),
                    BurnPolicy(600.0, 60.0, 6.0, "ticket"))


@dataclass(frozen=True)
class SloSpec:
    """Declarative objectives for one endpoint (see module docstring).

    ``latency_hist`` / ``error_counter`` name *existing* registry
    metrics (the serving layer's ``serve.flush_s`` etc.) — the spec
    never creates its own per-request instrumentation. Empty names
    disable that dimension. ``quality_min`` is the floor under which a
    quality observation (shadow recall, probe verdict) counts against
    the quality budget; NaN disables the dimension until the first
    ``observe_quality`` call with an explicit floor.
    """
    name: str                            # "search", "classify", ...
    latency_hist: str = ""               # registry histogram of request s
    latency_target_s: float = 0.050      # objective threshold (deadline)
    latency_objective: float = 0.99      # fraction within target
    error_counter: str = ""              # registry counter of errors
    availability_objective: float = 0.999
    quality_min: float = math.nan        # floor for quality observations
    quality_objective: float = 0.95      # fraction of obs >= floor


class AlertState:
    """Detector-shaped state of one ledger's burn alert (the object
    passed as ``detector`` to subscribed callbacks — same attribute
    surface as ``obs.drift``'s detectors: ``side``/``alarms``/``stat``).
    """

    __slots__ = ("series", "active", "alarms", "side", "stat", "policy",
                 "since")

    def __init__(self, series: str):
        self.series = series
        self.active = False
        self.alarms = 0          # rising edges so far
        self.side = ""           # always "up" once fired (budget burn)
        self.stat = 0.0          # worst burn/threshold ratio last eval
        self.policy = None       # the BurnPolicy that fired
        self.since = math.nan    # clock time the alert went active


class _Ledger:
    """One error-budget stream: cumulative (total, bad) counters plus a
    ring of timestamped snapshots for windowed rates.

    Pull ledgers (latency/availability) read their cumulative totals
    from the registry at tick time; push ledgers (quality/probe/
    recompile) accumulate via ``push``. Memory is O(max_window /
    resolution) snapshot tuples — never samples.
    """

    __slots__ = ("name", "objective", "pull", "total", "bad", "ring",
                 "spark", "alert")

    def __init__(self, name: str, objective: float, pull=None,
                 spark_len: int = 64):
        if not 0.0 < objective < 1.0:
            raise ValueError(f"objective must be in (0,1), got {objective}")
        self.name = name
        self.objective = float(objective)
        self.pull = pull                 # () -> (total, bad) cumulative
        self.total = 0
        self.bad = 0
        self.ring: deque = deque()       # (t, total, bad) snapshots
        self.spark: deque = deque(maxlen=spark_len)  # fast-burn series
        self.alert = AlertState(f"slo.{name}")

    def push(self, ok: bool, n: int = 1):
        """Record ``n`` events (push ledgers only)."""
        self.total += n
        if not ok:
            self.bad += n

    def totals(self):
        """Current cumulative (total, bad)."""
        return self.pull() if self.pull is not None else (self.total,
                                                          self.bad)

    def snap(self, now: float, max_window: float):
        """Append one (t, total, bad) snapshot; evict beyond the
        longest window (+1 entry kept as the baseline just outside)."""
        t, b = self.totals()
        self.ring.append((now, t, b))
        while len(self.ring) > 2 and self.ring[1][0] <= now - max_window:
            self.ring.popleft()

    def window_rate(self, now: float, window: float):
        """(bad_fraction, n_events) over the trailing ``window``
        seconds: the delta between now and the newest snapshot at or
        before ``now - window`` (the oldest snapshot when the ring is
        still younger than the window)."""
        t, b = self.totals()
        base_t, base_b = 0, 0
        for st, stot, sbad in reversed(self.ring):
            base_t, base_b = stot, sbad
            if st <= now - window:
                break
        n = t - base_t
        if n <= 0:
            return 0.0, 0
        return (b - base_b) / n, n

    def burn(self, now: float, window: float) -> float:
        """Budget burn rate over ``window``: bad_fraction / budget."""
        frac, _ = self.window_rate(now, window)
        return frac / (1.0 - self.objective)


def _latency_pull(registry: MetricsRegistry, hist: str, target: float):
    """Cumulative (total, late) derived from an existing histogram's
    bucket counts: everything in buckets strictly above the bucket
    holding ``target`` is late (bucket-resolution conservative — values
    sharing the target's bucket count as on-time)."""
    def pull():
        h = registry.histograms.get(hist)
        if h is None:
            return 0, 0
        i = h.spec.bucket_index(target)
        counts = h.counts
        return sum(counts), sum(counts[i + 1:])
    return pull


def _availability_pull(registry: MetricsRegistry, hist: str, errs: str):
    """Cumulative (total, errors): the error counter against the
    latency histogram's count (errors never observe the histogram, so
    total requests = observed + errored)."""
    def pull():
        h = registry.histograms.get(hist)
        c = registry.counters.get(errs)
        e = c.value if c is not None else 0
        n = (h.count if h is not None else 0) + e
        return n, e
    return pull


class SloEngine:
    """Error budgets, burn-rate alerts, and the ``health()`` verdict.

    ``add(spec)`` registers an endpoint's objectives; ``tick()`` (call
    it once per request batch, or on any cadence — it self-limits to
    ``resolution`` seconds) snapshots every ledger, evaluates the burn
    policies, mirrors gauges, and fires callbacks on rising edges.
    ``clock`` is injectable (tests/drills drive a fake clock; serving
    uses the default monotonic clock).

    Gauges per ledger: ``slo.<name>.burn_fast`` / ``.burn_slow``
    (burn over the fastest policy's long/short windows),
    ``slo.<name>.budget_remaining`` (fraction of the longest-window
    budget left), and an ``slo.<name>.alerts`` counter.
    """

    def __init__(self, registry: MetricsRegistry = None,
                 policies=DEFAULT_POLICIES, resolution: float = 1.0,
                 clock=time.monotonic, spark_len: int = 64):
        self.registry = registry if registry is not None \
            else default_registry()
        self.policies = tuple(policies)
        if not self.policies:
            raise ValueError("need at least one BurnPolicy")
        self.resolution = float(resolution)
        self.clock = clock
        self.spark_len = int(spark_len)
        self.specs: dict[str, SloSpec] = {}
        self.ledgers: dict[str, _Ledger] = {}
        self._callbacks: list = []
        self._resources = None
        self._compile_mark = None
        self._last_tick = -math.inf
        self._max_window = max(p.long_s for p in self.policies)
        self._fast = min(self.policies, key=lambda p: p.short_s)

    # -- registration --------------------------------------------------------
    def ledger(self, name: str, objective: float, pull=None) -> _Ledger:
        """Get-or-create the ledger ``name`` (objective fixed at
        birth); ``pull`` makes it read cumulative totals instead of
        accepting pushes."""
        led = self.ledgers.get(name)
        if led is None:
            led = self.ledgers[name] = _Ledger(
                name, objective, pull, spark_len=self.spark_len)
        return led

    def add(self, spec: SloSpec) -> "SloEngine":
        """Register one endpoint's objectives; returns self."""
        self.specs[spec.name] = spec
        reg = self.registry
        if spec.latency_hist:
            self.ledger(f"{spec.name}.latency", spec.latency_objective,
                        _latency_pull(reg, spec.latency_hist,
                                      spec.latency_target_s))
            if spec.error_counter:
                self.ledger(f"{spec.name}.availability",
                            spec.availability_objective,
                            _availability_pull(reg, spec.latency_hist,
                                               spec.error_counter))
        if spec.quality_min == spec.quality_min:    # not NaN
            self.ledger(f"{spec.name}.quality", spec.quality_objective)
        return self

    def attach_resources(self, resources,
                         objective: float = 0.99) -> "SloEngine":
        """Watch a ``ResourceMonitor``'s jit-compile counter: after
        ``mark_steady()``, every tick contributes one trial to the
        ``runtime.recompile`` ledger — bad when any compile happened
        since the previous tick. This turns the ARCHITECTURE
        "never-recompile" invariant into a budgeted runtime gauge: a
        recompiling hot path burns the budget every tick and trips the
        fast-window alert."""
        self._resources = resources
        self.ledger("runtime.recompile", objective)
        return self

    def mark_steady(self):
        """Arm the recompile ledger: compiles before this call (warmup,
        autotune) are free; compiles after it burn budget."""
        if self._resources is not None:
            self._compile_mark = self._resources.jit_compiles()

    def subscribe(self, callback) -> "SloEngine":
        """Add an alert callback ``callback(series, value, detector)``
        (the ``DriftMonitor`` contract); returns self."""
        self._callbacks.append(callback)
        return self

    # -- event pushes --------------------------------------------------------
    def observe_quality(self, slo_name: str, value: float,
                        floor: float = None):
        """Feed one quality observation (shadow recall, probe recall)
        for ``slo_name``; bad when below the spec's ``quality_min``
        (or an explicit ``floor``). No-op without a floor."""
        spec = self.specs.get(slo_name)
        if floor is None:
            floor = spec.quality_min if spec is not None else math.nan
        if floor != floor or value != value:
            return
        obj = spec.quality_objective if spec is not None else 0.95
        self.ledger(f"{slo_name}.quality", obj).push(value >= floor)

    def observe_probe(self, slo_name: str, ok: bool):
        """Feed one canary-probe verdict into ``<slo>.quality`` — a
        failed known-answer probe is a quality budget event exactly
        like a bad shadow-recall sample."""
        spec = self.specs.get(slo_name)
        obj = spec.quality_objective if spec is not None else 0.95
        self.ledger(f"{slo_name}.quality", obj).push(bool(ok))

    # -- the closed loop -----------------------------------------------------
    def tick(self, force: bool = False) -> bool:
        """One engine step: snapshot ledgers, evaluate burn policies,
        mirror gauges, fire callbacks on rising edges. Self-limits to
        one evaluation per ``resolution`` seconds unless ``force``;
        returns whether an evaluation ran."""
        now = self.clock()
        if not force and now - self._last_tick < self.resolution:
            return False
        self._last_tick = now
        if self._resources is not None and self._compile_mark is not None:
            cur = self._resources.jit_compiles()
            delta = cur - self._compile_mark
            self._compile_mark = cur
            led = self.ledgers["runtime.recompile"]
            led.push(delta == 0)
            if delta > 1:                # each compile burns separately
                led.push(False, n=delta - 1)
        reg = self.registry
        for led in self.ledgers.values():
            led.snap(now, self._max_window)
            burn_fast = led.burn(now, self._fast.long_s)
            burn_short = led.burn(now, self._fast.short_s)
            led.spark.append(burn_fast)
            worst = 0.0
            fired_policy = None
            active = False
            for pol in self.policies:
                fl, nl = led.window_rate(now, pol.long_s)
                fs, _ = led.window_rate(now, pol.short_s)
                budget = 1.0 - led.objective
                bl, bs = fl / budget, fs / budget
                ratio = min(bl, bs) / pol.threshold
                if ratio > worst:
                    worst = ratio
                if (bl >= pol.threshold and bs >= pol.threshold
                        and nl >= pol.min_events):
                    active = True
                    if fired_policy is None:
                        fired_policy = pol
            st = led.alert
            st.stat = worst
            frac, _ = led.window_rate(now, self._max_window)
            budget_left = max(0.0, 1.0 - frac / (1.0 - led.objective))
            name = led.name
            reg.gauge(f"slo.{name}.burn_fast").set(burn_fast)
            reg.gauge(f"slo.{name}.burn_short").set(burn_short)
            reg.gauge(f"slo.{name}.budget_remaining").set(budget_left)
            if active and not st.active:
                st.active = True
                st.alarms += 1
                st.side = "up"
                st.policy = fired_policy
                st.since = now
                reg.counter(f"slo.{name}.alerts").inc()
                for cb in self._callbacks:
                    cb(st.series, burn_fast, st)
            elif not active and st.active:
                st.active = False
        return True

    # -- verdicts ------------------------------------------------------------
    def budgets(self) -> dict:
        """Per-ledger budget view: {name: {objective, burn_fast,
        burn_short, budget_remaining, alerting, alarms, spark}}."""
        now = self.clock()
        out = {}
        for name, led in self.ledgers.items():
            frac, n = led.window_rate(now, self._max_window)
            out[name] = {
                "objective": led.objective,
                "events": n,
                "burn_fast": led.burn(now, self._fast.long_s),
                "burn_short": led.burn(now, self._fast.short_s),
                "budget_remaining": max(
                    0.0, 1.0 - frac / (1.0 - led.objective)),
                "alerting": led.alert.active,
                "alarms": led.alert.alarms,
                "spark": list(led.spark),
            }
        return out

    def health(self) -> dict:
        """The machine-readable verdict (admission-control input).

        ``status`` is "degraded" while any alert is active, else "ok".
        ``shed_fraction`` is advisory: the traffic fraction admission
        control would need to reject for the worst active fast burn to
        fall back to its policy threshold (`1 - threshold/burn`,
        clamped to [0, 1]); 0.0 when healthy.
        """
        alerts = [led.alert.series for led in self.ledgers.values()
                  if led.alert.active]
        now = self.clock()
        shed = 0.0
        for led in self.ledgers.values():
            if not led.alert.active:
                continue
            pol = led.alert.policy or self._fast
            b = led.burn(now, pol.long_s)
            if b > pol.threshold:
                shed = max(shed, 1.0 - pol.threshold / b)
        return {
            "status": "degraded" if alerts else "ok",
            "alerts": alerts,
            "shed_fraction": min(1.0, shed),
            "slos": self.budgets(),
        }
