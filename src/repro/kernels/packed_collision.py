"""Collision counts directly on bit-packed codes (ANN engine hot loop).

Two kernels over uint32 word arrays (layout of ``kernels.pack_codes``):

``packed_collision_counts_pallas``
    counts[q, n] = #{ fields j < k : code_q[q, j] == code_db[n, j] },
    computed as k - popcount(fold(xor)) entirely in-register — the codes
    are never unpacked to int32 in HBM. Versus ``kernels.collision`` this
    reads 32/b x fewer bytes per pair and replaces the b-bit equality
    compare with one XOR + OR-fold + popcount per word. Tiled
    (bq, bn, bw) with an int32 VMEM accumulator streaming the word axis
    on the minor grid dimension, exactly like a matmul reduction.

``packed_topk_pallas``
    The fused search kernel: streams the corpus axis per query tile,
    keeping a running (values, ids) top-k in VMEM scratch and merging
    each fresh (bq, bn) count tile with ``jax.lax.top_k`` over the
    concatenation. The running list is kept sorted and precedes the new
    tile in the concat, so ties resolve to the lowest corpus id — ids
    match a full-matrix ``lax.top_k`` bit-for-bit. Only the [Q, top_k]
    result ever reaches HBM; the [Q, N] count matrix is never written.

``packed_topk_masked_pallas``
    The streaming top-k kernel with a packed row-validity bitmask (the
    mutable-index tombstone path, ``repro.index``): one uint32 word
    covers 32 corpus rows, the per-tile mask slice is expanded to a row
    mask in-register, and dead rows are forced to -1 before the top-k
    merge — deletes cost one bit of HBM per row and zero recompiles,
    because the mask is data, not shape.

Padding: the wrappers zero-pad every axis. Zero-padded words XOR to zero
and contribute no mismatches, so counts stay exact; zero-padded corpus
*rows* would alias a real all-zero code row, so the top-k kernel masks
rows past the static ``n_valid`` count to -1 before merging — that mask
is load-bearing, not belt-and-braces. (The masked kernel folds row
padding into the bitmask itself: bits past N are zeroed by the wrapper.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import bitmask_width, mismatch_count_words

__all__ = ["packed_collision_counts_pallas", "packed_topk_pallas",
           "packed_topk_masked_pallas"]


def _mismatch_bits(xor, bits: int):
    """Per-word count of differing b-bit fields, in-register (the shared
    OR-fold + SWAR popcount from the ``core.packing`` oracle — one
    implementation, kernel and oracle can't drift)."""
    return mismatch_count_words(xor, bits).astype(jnp.int32)


def _pad(x, mult, axis, fill=0):
    p = (-x.shape[axis]) % mult
    if p == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, p)
    return jnp.pad(x, widths, constant_values=fill)


# -- all-pairs counts ---------------------------------------------------------

def _counts_kernel(q_ref, db_ref, o_ref, acc_ref, *, bits: int, k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]           # [bq, bw] uint32
    db = db_ref[...]         # [bn, bw] uint32
    xor = jnp.bitwise_xor(q[:, None, :], db[None, :, :])
    acc_ref[...] += jnp.sum(_mismatch_bits(xor, bits), axis=-1)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[...] = k - acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("bits", "k", "block_q", "block_n", "block_w",
                     "interpret"))
def packed_collision_counts_pallas(words_q, words_db, bits: int, k: int, *,
                                   block_q: int = 128, block_n: int = 128,
                                   block_w: int = 64,
                                   interpret: bool = False):
    """words_q uint32 [Q, W], words_db uint32 [N, W] -> int32 counts [Q, N].

    Matches ``ref.packed_collision_ref`` bit-exactly, including rows whose
    last word carries zero-padded fields (k < W * 32/bits).
    """
    qn, w = words_q.shape
    n, w2 = words_db.shape
    assert w == w2, (words_q.shape, words_db.shape)
    bw = min(block_w, w)
    qp = _pad(_pad(words_q, block_q, 0), bw, 1)
    dbp = _pad(_pad(words_db, block_n, 0), bw, 1)
    qm, wp = qp.shape
    nm = dbp.shape[0]
    grid = (qm // block_q, nm // block_n, wp // bw)
    out = pl.pallas_call(
        functools.partial(_counts_kernel, bits=bits, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, bw), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_n, bw), lambda i, j, s: (j, s)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qm, nm), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_q, block_n), jnp.int32)],
        interpret=interpret,
    )(qp, dbp)
    return out[:qn, :n]


# -- fused streaming top-k ----------------------------------------------------

def _tile_counts_gids(q_ref, db_ref, j, *, bits: int, k: int, block_n: int):
    """One (bq, bn) count tile + its global corpus ids."""
    q = q_ref[...]           # [bq, W]
    db = db_ref[...]         # [bn, W]
    xor = jnp.bitwise_xor(q[:, None, :], db[None, :, :])
    counts = k - jnp.sum(_mismatch_bits(xor, bits), axis=-1)   # [bq, bn]
    local = jax.lax.broadcasted_iota(jnp.int32, (counts.shape[0], block_n), 1)
    return counts, local + j * block_n


def _merge_running_topk(vals_ref, ids_ref, counts, gids, top_k: int):
    # merge running top-k with the fresh tile; running entries come first,
    # and lax.top_k is stable, so ties keep the lowest corpus id
    cat_v = jnp.concatenate([vals_ref[...], counts], axis=1)
    cat_i = jnp.concatenate([ids_ref[...], gids], axis=1)
    best_v, pos = jax.lax.top_k(cat_v, top_k)
    vals_ref[...] = best_v
    ids_ref[...] = jnp.take_along_axis(cat_i, pos, axis=1)


def _topk_kernel(q_ref, db_ref, ov_ref, oi_ref, vals_ref, ids_ref, *,
                 bits: int, k: int, top_k: int, n_valid: int,
                 block_n: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, -1)
        ids_ref[...] = jnp.full_like(ids_ref, -1)

    counts, gids = _tile_counts_gids(q_ref, db_ref, j, bits=bits, k=k,
                                     block_n=block_n)
    counts = jnp.where(gids < n_valid, counts, -1)
    _merge_running_topk(vals_ref, ids_ref, counts, gids, top_k)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finalize():
        ov_ref[...] = vals_ref[...]
        oi_ref[...] = ids_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("bits", "k", "top_k", "block_q", "block_n",
                     "interpret"))
def packed_topk_pallas(words_q, words_db, bits: int, k: int, top_k: int, *,
                       block_q: int = 128, block_n: int = 512,
                       interpret: bool = False):
    """-> (counts [Q, top_k] int32, ids [Q, top_k] int32), streaming the
    corpus axis: HBM traffic is O(Q*W + N*W + Q*top_k), never O(Q*N).

    Rows beyond N (block padding) surface as (-1, -1) only when
    top_k > N. Tie-breaking matches ``ref.packed_topk_ref`` exactly.
    """
    qn, w = words_q.shape
    n = words_db.shape[0]
    assert w == words_db.shape[1], (words_q.shape, words_db.shape)
    qp = _pad(words_q, block_q, 0)
    dbp = _pad(words_db, block_n, 0)
    qm = qp.shape[0]
    nm = dbp.shape[0]
    grid = (qm // block_q, nm // block_n)
    kernel = functools.partial(_topk_kernel, bits=bits, k=k, top_k=top_k,
                               n_valid=n, block_n=block_n)
    vals, ids = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, w), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, w), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, top_k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, top_k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qm, top_k), jnp.int32),
            jax.ShapeDtypeStruct((qm, top_k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, top_k), jnp.int32),
            pltpu.VMEM((block_q, top_k), jnp.int32),
        ],
        interpret=interpret,
    )(qp, dbp)
    return vals[:qn], ids[:qn]


# -- fused streaming top-k over live rows only --------------------------------

def _topk_masked_kernel(q_ref, db_ref, valid_ref, ov_ref, oi_ref, vals_ref,
                        ids_ref, *, bits: int, k: int, top_k: int,
                        block_n: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, -1)
        ids_ref[...] = jnp.full_like(ids_ref, -1)

    counts, gids = _tile_counts_gids(q_ref, db_ref, j, bits=bits, k=k,
                                     block_n=block_n)
    # expand the packed validity tile in-register: [bn/32, 1] uint32 words
    # -> bit matrix [bn/32, 32] -> row mask [1, bn]. Bit r%32 of word
    # r//32 is row r, so the row-major reshape IS the row order. The
    # wrapper zeroes bits past N, so block row-padding is dead too.
    v = valid_ref[...]                                      # [bn/32, 1]
    bitpos = jax.lax.broadcasted_iota(jnp.uint32, (block_n // 32, 32), 1)
    live = ((v >> bitpos) & jnp.uint32(1)).reshape(1, block_n)
    counts = jnp.where(live != 0, counts, -1)
    _merge_running_topk(vals_ref, ids_ref, counts, gids, top_k)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finalize():
        ov_ref[...] = vals_ref[...]
        oi_ref[...] = ids_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("bits", "k", "top_k", "block_q", "block_n",
                     "interpret"))
def packed_topk_masked_pallas(words_q, words_db, valid_words, bits: int,
                              k: int, top_k: int, *, block_q: int = 128,
                              block_n: int = 512, interpret: bool = False):
    """Streaming top-k over rows whose validity bit is set.

    ``valid_words``: uint32 [ceil(N/32)] packed bitmask in the
    ``packing.pack_bitmask`` layout. Dead rows are masked to -1 before
    every merge, so they can never enter the running list; slots beyond
    the live count surface as (-1, -1). Bit-exact (values, tie-broken
    ids) vs ``ref.packed_topk_masked_ref``. The mask is *data* — deletes
    never change any traced shape, so the jit cache entry survives any
    tombstone pattern.
    """
    qn, w = words_q.shape
    n = words_db.shape[0]
    assert w == words_db.shape[1], (words_q.shape, words_db.shape)
    assert block_n % 32 == 0, block_n
    nw = bitmask_width(n)
    assert valid_words.shape == (nw,), (valid_words.shape, nw)
    qp = _pad(words_q, block_q, 0)
    dbp = _pad(words_db, block_n, 0)
    qm = qp.shape[0]
    nm = dbp.shape[0]
    vw = valid_words.astype(jnp.uint32)
    if n % 32:      # zero mask bits past N inside the last partial word
        vw = vw.at[-1].set(vw[-1] & jnp.uint32((1 << (n % 32)) - 1))
    vw = jnp.pad(vw, (0, nm // 32 - nw)).reshape(nm // 32, 1)
    grid = (qm // block_q, nm // block_n)
    kernel = functools.partial(_topk_masked_kernel, bits=bits, k=k,
                               top_k=top_k, block_n=block_n)
    vals, ids = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, w), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, w), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n // 32, 1), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, top_k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, top_k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qm, top_k), jnp.int32),
            jax.ShapeDtypeStruct((qm, top_k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, top_k), jnp.int32),
            pltpu.VMEM((block_q, top_k), jnp.int32),
        ],
        interpret=interpret,
    )(qp, dbp, vw)
    return vals[:qn], ids[:qn]
