"""Observability overhead benchmark: what the measuring layer costs.

An observability layer that taxes the hot path gets turned off, and an
unmeasured system drifts; this bench keeps ``repro.obs`` honest on both
counts. Measured:

  * end-to-end QPS of the exact-search serving hot path
    (``serve.AnnService`` submit→flush, cache disabled so every query
    does device work) in three configurations: everything off, metrics
    only, and the production default (metrics + flight recorder + tail
    sampler). Acceptance: metrics <= 3% QPS overhead, the flight layer
    <= 1% on top of metrics;
  * microbenchmarks of the primitives: counter ``inc``, histogram
    ``observe`` (precomputed-edge bisect — the <= ~400 ns fast path),
    disabled-registry no-op metrics, a ``span(...)`` enter/exit with no
    tracer installed, and the flight-recorder ring append (the
    <= ~500 ns O(1) slot write);
  * a real trace artifact: one full service cycle — bulk_load ingest →
    batched search → classify → delete → compact — recorded under a
    ``Tracer`` and dumped as Chrome-trace/Perfetto JSON next to the
    BENCH files (load it at https://ui.perfetto.dev).

Wall-clock numbers are median-of-N with ``block_until_ready`` (the
serving flush syncs via its own host transfer).

``BENCH_obs.json`` (repo root) records the QPS triple, both overhead
fractions, the primitive costs and the trace path. ``--quick`` runs the
same acceptance gates on a small corpus without rewriting the JSON —
the mode CI uses on every push.
"""
import json
import os
import sys
import time

import numpy as np
import jax

if __package__ in (None, ""):            # direct `python benchmarks/obs_bench.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

from benchmarks._util import write_csv
from repro.ann import AnnEngine, BandSpec
from repro.core.sketch import CodedRandomProjection, SketchConfig
from repro.index import MutableAnnEngine
from repro.learn import LearnConfig, fit_log
from repro.obs import (FlightRecorder, MetricsRegistry, TailSampler,
                       Tracer, no_tracing, set_default_registry,
                       set_flight_recorder, span)
from repro.serve import AnnService, AnnServiceConfig

K = 64


def _interleaved_qps(setups, queries, repeat):
    """Median submit-all+flush QPS per configuration, with rounds
    interleaved A,B,C,A,B,C,... instead of AAA,BBB,CCC — slow machine
    drift (thermal, cache, background load) then lands on every config
    equally instead of biasing whichever ran last. Each setup is
    (service, registry, flight_recorder); the globals are swapped in
    before each round so engine/kernel-level instrumentation follows
    the config under test. The flush's host transfer of results is the
    device sync."""
    nq = queries.shape[0]
    ts = [[] for _ in setups]
    for svc, reg, fr in setups:           # warm every jit + bucket
        set_default_registry(reg)
        set_flight_recorder(fr)
        for x in queries:
            svc.submit(x)
        svc.flush()
    k = len(setups)
    for r in range(repeat):
        # rotate the within-cycle order each cycle: no config always
        # runs first (or last), so position effects — cache state left
        # by the previous config, periodic background work — average
        # out instead of biasing one config
        for j in range(k):
            i = (j + r) % k
            svc, reg, fr = setups[i]
            set_default_registry(reg)
            set_flight_recorder(fr)
            t0 = time.perf_counter()
            for x in queries:
                svc.submit(x)
            svc.flush()
            ts[i].append(time.perf_counter() - t0)
    return [nq / float(np.median(t)) for t in ts], ts


def _paired_overhead(t_slow, t_fast):
    """Fractional slowdown of config ``t_slow`` over ``t_fast`` as the
    median of per-cycle ratios — each pair ran back-to-back inside one
    interleave cycle, so machine-level drift common to the cycle
    cancels out of the ratio."""
    return float(np.median([a / b for a, b in zip(t_slow, t_fast)])) - 1.0


def _ns_per(fn, n=50_000, best_of=3):
    """Best-of-``best_of`` ns/call: the minimum over repeated timed
    loops is the standard noise-robust microbench estimator (anything
    above the minimum is scheduler/cache interference, not the code)."""
    fn()                                  # touch once outside the timer
    best = float("inf")
    for _ in range(best_of):
        t0 = time.perf_counter()
        for _ in range(n):
            fn()
        best = min(best, time.perf_counter() - t0)
    return 1e9 * best / n


def _trace_cycle(d, rows, path):
    """Record one full service cycle — bulk_load → two search rounds →
    upsert → classify → delete → compact → post-compact search, all
    through ``serve.AnnService`` — and dump the Chrome trace; returns
    (path, n_events)."""
    crp = CodedRandomProjection(SketchConfig(k=K, scheme="2bit", w=0.75), d)
    eng = MutableAnnEngine(crp, tail_rows=256)
    svc = AnnService(eng, AnnServiceConfig(top_k=10, mode="exact",
                                           cache_size=16, buckets=(32,)))
    rng = np.random.default_rng(0)
    x = rng.standard_normal((rows, d)).astype(np.float32)
    with Tracer() as tr:
        ids = svc.bulk_load(x, chunk_rows=256)
        for q in x[:32]:
            svc.submit(q)
        svc.flush()
        for q in x[32:64]:                # distinct round: no cache hits
            svc.submit(q)
        svc.flush()
        svc.upsert(ids[:16], x[:16] + 0.01)
        model = fit_log(eng.store,
                        lambda i: np.where(np.asarray(i) % 2 == 0, 1, -1),
                        crp, LearnConfig(steps=4))
        svc.set_classifier(model)
        svc.classify(x[:32])
        svc.classify(x[64:96])
        svc.delete(ids[: rows // 3])
        svc.compact()
        for q in x[64:80]:                # search the compacted store
            svc.submit(q)
        svc.flush()
    tr.dump(path)
    return path, len(tr.events)


def _bench(d, n, nq, repeat):
    rng = np.random.default_rng(0)
    corpus = rng.standard_normal((n, d)).astype(np.float32)
    queries = corpus[:nq] + 0.1 * rng.standard_normal(
        (nq, d)).astype(np.float32)
    crp = CodedRandomProjection(SketchConfig(k=K, scheme="2bit", w=0.75), d)
    engine = AnnEngine.build(crp, corpus, BandSpec(n_tables=8, band_width=4))
    cfg = AnnServiceConfig(top_k=10, mode="exact", cache_size=0,
                           buckets=(nq,))

    def _off_service(reg):
        return AnnService(engine, cfg, registry=reg,
                          flight=FlightRecorder(enabled=False),
                          sampler=TailSampler(enabled=False))

    # three-point ladder, rounds interleaved across configs: any tracer
    # the harness installed (run.py --profile) is suspended so the
    # pairs isolate exactly one knob
    prev_reg = set_default_registry(MetricsRegistry(enabled=True))
    prev_fr = set_flight_recorder(FlightRecorder(enabled=True))
    try:
        with no_tracing():
            reg_flight = MetricsRegistry(enabled=True)
            reg_metrics = MetricsRegistry(enabled=True)
            reg_none = MetricsRegistry(enabled=False)
            setups = [
                # production default: metrics + flight ring + sampler
                (AnnService(engine, cfg, registry=reg_flight),
                 reg_flight, FlightRecorder(enabled=True)),
                # metrics only (flight off): the pre-flight baseline
                (_off_service(reg_metrics), reg_metrics,
                 FlightRecorder(enabled=False)),
                # everything off
                (_off_service(reg_none), reg_none,
                 FlightRecorder(enabled=False)),
            ]
            (qps_flight, qps_on, qps_off), (t_fl, t_on, t_off) = \
                _interleaved_qps(setups, queries, repeat)
    finally:
        set_default_registry(prev_reg)
        set_flight_recorder(prev_fr)

    reg_on = MetricsRegistry(enabled=True)
    reg_off = MetricsRegistry(enabled=False)
    c_on, c_off = reg_on.counter("bench.c"), reg_off.counter("bench.c")
    h_on, h_off = reg_on.histogram("bench.h"), reg_off.histogram("bench.h")
    fr_on = FlightRecorder(capacity=4096, enabled=True)
    fr_off = FlightRecorder(capacity=4096, enabled=False)

    def _span_noop():
        with span("bench.span"):
            pass

    trace_path, trace_events = _trace_cycle(
        d, 1024, os.path.join(_ROOT, "TRACE_obs_cycle.json"))

    # the span microbench measures the NO-tracer cost — suspend any
    # tracer the harness (run.py --profile) may have installed
    with no_tracing():
        ns_span = _ns_per(_span_noop)

    overhead = _paired_overhead(t_on, t_off)
    flight_overhead = _paired_overhead(t_fl, t_on)
    return {
        "corpus": n, "queries": nq, "k": K, "bits": 2,
        "qps_flight_enabled": qps_flight,
        "qps_metrics_enabled": qps_on,
        "qps_metrics_disabled": qps_off,
        "overhead_frac": overhead,
        "flight_overhead_frac": flight_overhead,
        "ns_counter_inc": _ns_per(lambda: c_on.inc()),
        "ns_counter_inc_disabled": _ns_per(lambda: c_off.inc()),
        "ns_histogram_observe": _ns_per(lambda: h_on.observe(3e-4)),
        "ns_histogram_observe_disabled": _ns_per(
            lambda: h_off.observe(3e-4)),
        "ns_flight_record": _ns_per(
            lambda: fr_on.record("bench", 0.0, 1.0, batch=64,
                                 generation=1, synced=True)),
        "ns_flight_record_disabled": _ns_per(
            lambda: fr_off.record("bench", 0.0, 1.0)),
        "ns_span_no_tracer": ns_span,
        "trace_file": os.path.basename(trace_path),
        "trace_events": trace_events,
        "timing": "median-of-%d, device-synced flush" % repeat,
    }


def _rows(r):
    return [
        ("obs_serve_flight", 1e6 / r["qps_flight_enabled"],
         f"qps={r['qps_flight_enabled']:.0f} "
         f"flight_overhead={100 * r['flight_overhead_frac']:.2f}%"),
        ("obs_serve_enabled", 1e6 / r["qps_metrics_enabled"],
         f"qps={r['qps_metrics_enabled']:.0f}"),
        ("obs_serve_disabled", 1e6 / r["qps_metrics_disabled"],
         f"qps={r['qps_metrics_disabled']:.0f} "
         f"overhead={100 * r['overhead_frac']:.2f}%"),
        ("obs_counter_inc", 1e-3 * r["ns_counter_inc"],
         f"disabled_ns={r['ns_counter_inc_disabled']:.0f}"),
        ("obs_histogram_observe", 1e-3 * r["ns_histogram_observe"],
         f"disabled_ns={r['ns_histogram_observe_disabled']:.0f}"),
        ("obs_flight_record", 1e-3 * r["ns_flight_record"],
         f"disabled_ns={r['ns_flight_record_disabled']:.0f}"),
        ("obs_span_no_tracer", 1e-3 * r["ns_span_no_tracer"],
         f"trace_events={r['trace_events']}"),
    ]


def run(quick: bool = True):
    """run.py contract: (name, us_per_call, derived) rows."""
    r = _bench(d=64, n=4096 if quick else 65536, nq=64,
               repeat=9 if quick else 21)
    rows = _rows(r)
    write_csv("obs_bench", ["name", "us_per_call", "derived"], rows)
    return rows


def _acceptance(r) -> bool:
    """The CI gates: metrics <= 3% QPS, flight layer <= 1% QPS on top,
    ring append <= 500 ns, histogram observe <= 400 ns."""
    checks = [
        ("metrics overhead <= 3%", r["overhead_frac"] <= 0.03),
        ("flight overhead <= 1%", r["flight_overhead_frac"] <= 0.01),
        ("ring append <= 500 ns", r["ns_flight_record"] <= 500.0),
        ("histogram observe <= 400 ns",
         r["ns_histogram_observe"] <= 400.0),
    ]
    ok = True
    for name, passed in checks:
        print(f"  {name}: {'PASS' if passed else 'FAIL'}")
        ok = ok and passed
    return ok


def main():
    quick = "--quick" in sys.argv[1:]
    if quick:
        # CI gate mode: small corpus, same acceptance checks, no
        # BENCH_obs.json overwrite (full-size numbers stay canonical)
        r = _bench(d=64, n=8192, nq=64, repeat=15)
    else:
        r = _bench(d=64, n=65536, nq=64, repeat=21)
    write_csv("obs_bench", ["name", "us_per_call", "derived"], _rows(r))
    if not quick:
        with open(os.path.join(_ROOT, "BENCH_obs.json"), "w") as f:
            json.dump(r, f, indent=1)
    print("BENCH " + json.dumps(r))
    print(f"\nflight+metrics hot path: {r['qps_flight_enabled']:.0f} qps "
          f"vs metrics-only {r['qps_metrics_enabled']:.0f} qps "
          f"({100 * r['flight_overhead_frac']:.2f}% flight overhead) "
          f"vs all-off {r['qps_metrics_disabled']:.0f} qps "
          f"({100 * r['overhead_frac']:.2f}% metrics overhead)")
    print(f"primitives: counter {r['ns_counter_inc']:.0f} ns, histogram "
          f"{r['ns_histogram_observe']:.0f} ns, flight record "
          f"{r['ns_flight_record']:.0f} ns, span(no tracer) "
          f"{r['ns_span_no_tracer']:.0f} ns")
    ok = _acceptance(r)
    print("acceptance: " + ("PASS" if ok else "FAIL"))
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
