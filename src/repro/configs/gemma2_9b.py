"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 — local(4096)+global alternating, logit softcap.
[arXiv:2408.00118; hf]"""
from dataclasses import replace

from repro.models.lm import ModelConfig

# local layers are sub-quadratic but global layers keep full 500k KV;
# not sub-quadratic end-to-end -> long_500k skipped (DESIGN.md).
SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b", family="dense",
        n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, d_ff=14336,
        vocab_size=256000, head_dim=256,
        layer_pattern="LG", window=4096,
        attn_softcap=50.0, logit_softcap=30.0,
        activation="gelu", post_norms=True, embed_scale=True,
        query_scale=256 ** -0.5,
        tie_embeddings=True, norm_eps=1e-6,
    )


def smoke_config() -> ModelConfig:
    return replace(config(), n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                   head_dim=16, d_ff=128, vocab_size=256, window=8,
                   query_scale=16 ** -0.5, loss_chunk=16, chunk_kv=32,
                   chunk_q=16)
