"""Scored-search benchmark: fused vs two-stage vs collision-only.

Workload: clustered unit vectors (each query has ~``per`` true
neighbors at rho ~0.92) scored against float32 cosine ground truth —
the quality bar the packed-code search is approximating.

Measured, three-way:
  * recall@10 of collision-count-only exact search (the coarse ranking)
  * recall@10 of the scored path (``repro.rank`` non-linear 2-bit
    scores). Fused single-pass and two-stage produce bit-identical
    results — the bench asserts it — so this is one recall number with
    two latencies:
  * latency of the fused single-pass kernel path (one corpus stream,
    coarse selection + LUT scoring in-VMEM, no candidate-id round-trip)
    vs the legacy two-stage path (coarse packed-collision top-m ->
    gather -> LUT re-rank) vs collision-only top-10.
  * recall deltas of the quantized query-table variants: bf16 tables
    on the same path, int8 tables (per-word power-of-two scales) on the
    fused path.

Stage timings come from ``repro.obs`` tracing spans: the engine runs
each stage as its own device-synced span (``search.fused`` for the
single-pass path, ``search.coarse``/``search.rerank`` for two-stage),
so a stage's cost is its *measured* execution time — not a subtraction
of two independently-noisy totals. End-to-end wall-clock numbers are
median-of-N with ``block_until_ready`` inside the timed region.

The acceptance contract recorded into ``BENCH_rank.json`` (repo root):
scored recall@10 strictly above collision-only recall@10 at equal k,
fused and two-stage bit-identical, and the fused scored search costing
at most 2x the collision-only search (the two-stage path pays the full
coarse top-m sort — at m=4k that made scored search ~68x collision-only
in the previous revision of this bench; the fused kernel's survivor
rule replaces the sort with a histogram threshold, which is where the
gap closes).

The 2x bound is a memory-traffic property of the compiled kernel: the
fused op streams the packed corpus twice (exceedance histogram, then
score+select) where collision-only streams it once, so on a
memory-bound accelerator the ratio converges to 2 from above. The
bench computes that modeled HBM ratio from the ``repro.obs`` byte
models and gates the *measured* ratio only on tpu/gpu backends, where
the Pallas kernel actually compiles. On CPU the engine runs the jnp
oracle path — collision-only there is a single fused XLA reduction
while the scored oracle materializes counts, a survivor mask, and a
candidate compaction as separate passes — so the measured ratio
(recorded, not gated) sits well above 2 for reasons that have nothing
to do with the kernel; the CPU gate is instead recall, bit-exactness,
and the fused path being strictly the fastest scored path.
"""
import json
import os
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

if __package__ in (None, ""):            # direct `python benchmarks/rank_bench.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

from benchmarks._util import write_csv
from repro.ann import AnnEngine, BandSpec
from repro.ann.engine import SearchConfig
from repro.core.sketch import CodedRandomProjection, SketchConfig
from repro.obs import Tracer
from repro.obs.kernelstats import model as _kernel_model

K, TOP_K, RERANK_M = 64, 10, 4096


def _unit(x):
    return x / jnp.linalg.norm(x, axis=-1, keepdims=True)


def make_workload(key, d, n_clusters, per, nq, rho_m=0.92, rho_q=0.92):
    """Clustered corpus [n_clusters*per, d] + queries near nq centers."""
    kc, km, kq = jax.random.split(key, 3)
    centers = _unit(jax.random.normal(kc, (n_clusters, d)))
    noise = _unit(jax.random.normal(km, (n_clusters, per, d)))
    corpus = _unit(rho_m * centers[:, None, :]
                   + np.sqrt(1 - rho_m ** 2) * noise).reshape(-1, d)
    qn = _unit(jax.random.normal(jax.random.fold_in(kq, 1), (nq, d)))
    queries = _unit(rho_q * centers[:nq] + np.sqrt(1 - rho_q ** 2) * qn)
    return corpus, queries


def _timed(fn, repeat=5):
    jax.block_until_ready(fn())            # warm the jit caches
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _span_totals(engine, q_codes, cfg, names, repeat=5):
    """Median device-synced span totals {name: s} of one scored search
    (``search.fused`` for the fused path, ``search.coarse``/
    ``search.rerank`` for two-stage)."""
    with Tracer():
        engine.search_codes(q_codes, cfg)  # warm the per-stage jits
    acc = {nm: [] for nm in names}
    for _ in range(repeat):
        with Tracer() as tr:
            engine.search_codes(q_codes, cfg)
        for nm in names:
            acc[nm].append(tr.total(nm))
    return {nm: float(np.median(v)) for nm, v in acc.items()}


def _recall(ids, gt):
    return float(np.mean([len(set(np.asarray(a)) & set(b)) / gt.shape[1]
                          for a, b in zip(ids, gt)]))


def _bench(d, n_clusters, per, nq, rerank_m):
    key = jax.random.PRNGKey(0)
    corpus, queries = make_workload(key, d, n_clusters, per, nq)
    n = corpus.shape[0]
    crp = CodedRandomProjection(SketchConfig(k=K, scheme="2bit", w=0.75), d)
    engine = AnnEngine.build(crp, corpus, BandSpec(n_tables=8, band_width=4))
    m = min(rerank_m, n)

    # float32 cosine ground truth (the quality bar)
    gt = np.asarray(jax.lax.top_k(queries @ corpus.T, TOP_K)[1])

    ids_plain, _ = engine.search(queries, TOP_K, mode="exact", chunk_q=nq)
    ids_fused, _ = engine.search(queries, TOP_K, mode="exact", scored=True,
                                 rerank_m=m, chunk_q=nq, fused=True)
    ids_two, _ = engine.search(queries, TOP_K, mode="exact", scored=True,
                               rerank_m=m, chunk_q=nq, fused=False)
    fused_bit_exact = bool(np.array_equal(np.asarray(ids_fused),
                                          np.asarray(ids_two)))
    recall_plain = _recall(np.asarray(ids_plain), gt)
    recall_scored = _recall(np.asarray(ids_fused), gt)

    # quantized query tables: same path, cheaper VMEM traffic
    ids_bf16, _ = engine.search(queries, TOP_K, mode="exact", scored=True,
                                rerank_m=m, chunk_q=nq,
                                table_dtype="bf16")
    ids_int8, _ = engine.search(queries, TOP_K, mode="exact", scored=True,
                                rerank_m=m, chunk_q=nq,
                                table_dtype="int8")
    recall_bf16 = _recall(np.asarray(ids_bf16), gt)
    recall_int8 = _recall(np.asarray(ids_int8), gt)

    # latency: fused vs two-stage vs collision-only, each end-to-end
    # (whole chunk fn, device-synced) plus per-stage span totals
    q_codes = engine.encode_queries(queries)
    cfg_f = SearchConfig(top_k=TOP_K, mode="exact", scored=True,
                         rerank_m=m, chunk_q=nq, fused=True)
    cfg_t = SearchConfig(top_k=TOP_K, mode="exact", scored=True,
                         rerank_m=m, chunk_q=nq, fused=False)
    cfg_p = SearchConfig(top_k=TOP_K, mode="exact", chunk_q=nq)
    t_fused = _timed(lambda: engine._chunk_fn(cfg_f)(q_codes))
    t_two = _timed(lambda: engine._chunk_fn(cfg_t)(q_codes))
    t_plain = _timed(lambda: engine._chunk_fn(cfg_p)(q_codes))
    sp_f = _span_totals(engine, q_codes, cfg_f, ("search.fused",))
    sp_t = _span_totals(engine, q_codes, cfg_t,
                        ("search.coarse", "search.rerank"))

    # modeled HBM bytes of the compiled kernels (repro.obs roofline
    # models): the contract the measured ratio is gated against on
    # accelerator backends
    w = int(q_codes.shape[1])
    t = w * (32 // 2) * (1 << 2)
    _, _, b_fused = _kernel_model("fused_scored_topk", q=nq, n=n, w=w,
                                  t=t, k=K, top_k=TOP_K)
    _, _, b_plain = _kernel_model("packed_topk", q=nq, n=n, w=w,
                                  top_k=TOP_K)

    return {
        "corpus": n, "queries": nq, "k": K, "bits": 2, "top_k": TOP_K,
        "rerank_m": m, "backend": jax.default_backend(),
        "recall_at_10_collision": recall_plain,
        "recall_at_10_two_stage": recall_scored,
        "recall_at_10_bf16": recall_bf16,
        "recall_at_10_int8": recall_int8,
        "recall_gain": recall_scored - recall_plain,
        "recall_delta_bf16": recall_bf16 - recall_scored,
        "recall_delta_int8": recall_int8 - recall_scored,
        "fused_bit_exact_vs_two_stage": fused_bit_exact,
        "t_fused_s": t_fused, "t_two_stage_s": t_two,
        "t_collision_top10_s": t_plain,
        "t_fused_span_s": sp_f["search.fused"],
        "t_coarse_topm_s": sp_t["search.coarse"],
        "t_rerank_span_s": sp_t["search.rerank"],
        "fused_vs_collision_ratio": t_fused / t_plain,
        "two_stage_vs_collision_ratio": t_two / t_plain,
        "modeled_hbm_ratio_fused_vs_collision": b_fused / b_plain,
        "fused_speedup_vs_two_stage": t_two / t_fused,
        "qps_fused": nq / t_fused,
        "qps_two_stage": nq / t_two,
        "qps_collision_only": nq / t_plain,
        "timing": "span-derived, device-synced, median-of-5",
    }


def _rows(r):
    return [
        ("rank_fused_scored", 1e6 * r["t_fused_s"] / r["queries"],
         f"recall@10={r['recall_at_10_two_stage']:.3f} "
         f"m={r['rerank_m']} "
         f"x_collision={r['fused_vs_collision_ratio']:.2f}"),
        ("rank_two_stage", 1e6 * r["t_two_stage_s"] / r["queries"],
         f"recall@10={r['recall_at_10_two_stage']:.3f} "
         f"m={r['rerank_m']}"),
        ("rank_collision_only", 1e6 * r["t_collision_top10_s"] / r["queries"],
         f"recall@10={r['recall_at_10_collision']:.3f}"),
        ("rank_fused_int8", 1e6 * r["t_fused_s"] / r["queries"],
         f"recall@10={r['recall_at_10_int8']:.3f} "
         f"delta={r['recall_delta_int8']:+.4f}"),
        ("rank_fused_bf16", 1e6 * r["t_fused_s"] / r["queries"],
         f"recall@10={r['recall_at_10_bf16']:.3f} "
         f"delta={r['recall_delta_bf16']:+.4f}"),
    ]


def run(quick: bool = True):
    """run.py contract: (name, us_per_query, derived) rows."""
    r = _bench(d=64, n_clusters=1000 if quick else 16384, per=8,
               nq=32 if quick else 64, rerank_m=512 if quick else RERANK_M)
    rows = _rows(r)
    write_csv("rank_bench", ["name", "us_per_query", "derived"], rows)
    return rows


def main():
    r = _bench(d=64, n_clusters=16384, per=8, nq=64, rerank_m=RERANK_M)
    write_csv("rank_bench", ["name", "us_per_query", "derived"], _rows(r))
    with open(os.path.join(_ROOT, "BENCH_rank.json"), "w") as f:
        json.dump(r, f, indent=1)
    print("BENCH " + json.dumps(r))
    print(f"\nscored recall@10 {r['recall_at_10_two_stage']:.3f} vs "
          f"collision-only {r['recall_at_10_collision']:.3f} "
          f"(+{r['recall_gain']:.3f}) on {r['corpus']} rows; "
          f"int8 delta {r['recall_delta_int8']:+.4f}, "
          f"bf16 delta {r['recall_delta_bf16']:+.4f}")
    print(f"fused {1e3 * r['t_fused_s']:.1f} ms vs two-stage "
          f"{1e3 * r['t_two_stage_s']:.1f} ms vs collision-only "
          f"{1e3 * r['t_collision_top10_s']:.1f} ms "
          f"(fused = {r['fused_vs_collision_ratio']:.2f}x collision, "
          f"{r['fused_speedup_vs_two_stage']:.1f}x faster than "
          f"two-stage at m={r['rerank_m']})")
    # the measured <=2x gate is a compiled-kernel property; on CPU the
    # oracle path runs instead, so gate on recall + bit-exactness +
    # fused being strictly the fastest scored path, and track the
    # measured ratio against the modeled one (see module docstring)
    if r["backend"] in ("tpu", "gpu"):
        ratio_ok = r["fused_vs_collision_ratio"] <= 2.0
    else:
        ratio_ok = (r["fused_speedup_vs_two_stage"] >= 1.0
                    and r["modeled_hbm_ratio_fused_vs_collision"] <= 2.1)
        print(f"[cpu] measured ratio {r['fused_vs_collision_ratio']:.2f} "
              f"is the jnp oracle path; modeled kernel HBM ratio "
              f"{r['modeled_hbm_ratio_fused_vs_collision']:.2f}")
    ok = (r["recall_at_10_two_stage"] > r["recall_at_10_collision"]
          and r["recall_at_10_two_stage"] >= 0.806
          and r["fused_bit_exact_vs_two_stage"]
          and ratio_ok)
    print("acceptance: " + ("PASS" if ok else "FAIL"))
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
