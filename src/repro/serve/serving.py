"""Batched serving: prefill + greedy/temperature decode loop.

``make_serve_step`` builds the jit'd one-token step used by the dry-run's
decode cells; ``generate`` is the host-side loop (examples + tests).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import lm as L

__all__ = ["make_serve_step", "generate"]


def make_serve_step(cfg, rules=None):
    """jit'd (params, caches, tokens [B,1(,C)], pos) -> (logits, caches)."""

    def step(params, caches, tokens, pos):
        if rules is not None:
            tokens = rules.shard(tokens, *("batch", "seq", "codebooks")
                                 [:tokens.ndim])
        return L.decode_step(params, caches, tokens, pos, cfg, rules)

    return jax.jit(step, donate_argnums=(1,))


def generate(params, prompt, cfg, n_tokens: int, rules=None,
             temperature: float = 0.0, seed: int = 0, max_len: int = 0):
    """prompt [B, S(,C)] -> tokens [B, S + n_tokens(, C)] (greedy if
    temperature == 0)."""
    b, s = prompt.shape[:2]
    max_len = max_len or (s + n_tokens)
    last_logits, caches = jax.jit(
        lambda p, t: L.prefill(p, t, cfg, rules, max_len=max_len)
    )(params, prompt)
    serve_step = make_serve_step(cfg, rules)
    key = jax.random.PRNGKey(seed)
    out = [prompt]
    logits = last_logits

    def pick(logits, key):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature).astype(jnp.int32)

    for i in range(n_tokens):
        key, k = jax.random.split(key)
        nxt = pick(logits[:, -1] if logits.ndim == 3 else logits[:, -1], k)
        nxt = nxt.reshape((b, 1) + ((cfg.n_codebooks,) if cfg.n_codebooks > 1
                                    else ()))
        out.append(nxt)
        if i + 1 < n_tokens:
            logits, caches = serve_step(params, caches, nxt,
                                        jnp.int32(s + i))
    return jnp.concatenate(out, axis=1)
