"""Similarity estimators from empirical collision fractions (paper §3).

The collision probability P(rho; scheme, w) is strictly increasing in rho
for every scheme, so rho_hat = P^{-1}(P_hat). Following the paper we
tabulate P on a dense rho grid and invert by monotone interpolation
("we can tabulate P_w for each rho, for example at a precision of 1e-3").

Also provides the closed-form inversion for the sign scheme and a
batched maximum-likelihood refinement (paper §7 'future work' — included
as a beyond-paper extension) that uses the full contingency table of the
2-bit scheme rather than only the diagonal collision count.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.probabilities import collision_prob, q_region
from repro.core.variance import variance_factor

__all__ = ["CollisionEstimator", "rho_from_sign_collision", "mle_rho_2bit"]


def rho_from_sign_collision(p_hat):
    """Closed-form inverse of P_1 = 1 - acos(rho)/pi."""
    p = jnp.clip(p_hat, 0.5, 1.0)
    return jnp.cos(math.pi * (1.0 - p))


@dataclass
class CollisionEstimator:
    """rho_hat = P^{-1}(P_hat) by table inversion.

    Builds a (rho, P) table once (host side, float64-safe under x64) and
    estimates with jnp.interp — fully jittable / vmappable.
    """
    scheme: str
    w: float = 1.0
    grid_size: int = 4096
    rho_max: float = 0.99995
    _rho_grid: np.ndarray = field(init=False, repr=False)
    _p_grid: np.ndarray = field(init=False, repr=False)

    def __post_init__(self):
        rho = np.linspace(0.0, self.rho_max, self.grid_size)
        p = np.asarray(collision_prob(jnp.asarray(rho), self.w, self.scheme))
        # enforce strict monotonicity for interp (numerics can plateau at tails)
        p = np.maximum.accumulate(p)
        eps = 1e-12 * np.arange(self.grid_size)
        self._rho_grid = rho
        self._p_grid = p + eps

    def __call__(self, p_hat):
        """Map empirical collision fraction(s) to rho_hat(s)."""
        p_hat = jnp.asarray(p_hat)
        return jnp.interp(p_hat, jnp.asarray(self._p_grid),
                          jnp.asarray(self._rho_grid))

    def estimate(self, codes_a, codes_b):
        """Estimate rho from two code arrays [..., k]."""
        p_hat = jnp.mean((codes_a == codes_b).astype(jnp.float32), axis=-1)
        return self(p_hat)

    def asymptotic_std(self, rho, k: int):
        """Predicted std of rho_hat: sqrt(V/k) (Thms 2-4)."""
        return jnp.sqrt(variance_factor(jnp.asarray(rho), self.w, self.scheme) / k)


def _cell_probs_2bit(rho, w: float):
    """4x4 contingency-cell probabilities of (h_{w,2}(x), h_{w,2}(y)).

    Cells are intersections of the regions R0=(-inf,-w), R1=[-w,0),
    R2=[0,w), R3=[w,inf). By symmetry of the bivariate normal we compute
    the upper triangle with Lemma 1-style quadrature over generalized
    rectangles Pr(x in [a,b], y in [c,d]).
    """
    from repro.core.probabilities import ZMAX, Phi, phi
    from repro.core._quad import interval_nodes

    bounds = [(-ZMAX, -w), (-w, 0.0), (0.0, w), (w, ZMAX)]
    rho = jnp.clip(jnp.asarray(rho), 0.0, 1.0 - 1e-7)
    r = rho[..., None]
    sd = jnp.sqrt(1.0 - r * r)
    rows = []
    for (a, b) in bounds:
        row = []
        z, wz = interval_nodes(a, b, 64)
        for (c, d) in bounds:
            inner = Phi((d - r * z) / sd) - Phi((c - r * z) / sd)
            row.append(jnp.sum(phi(z) * inner * wz, axis=-1))
        rows.append(jnp.stack(row, axis=-1))
    return jnp.stack(rows, axis=-2)  # [..., 4, 4]


def mle_rho_2bit(codes_a, codes_b, w: float, grid_size: int = 512):
    """Beyond-paper MLE (paper §7): maximize the 4x4 contingency-table
    likelihood of the 2-bit codes over a rho grid.

    codes_a/b: int32 [..., k] in {0,1,2,3}. Returns rho_hat [...].
    """
    k = codes_a.shape[-1]
    # empirical 4x4 counts
    cell = codes_a * 4 + codes_b  # [..., k] in [0,16)
    counts = jax.vmap(lambda c: jnp.bincount(c, length=16), in_axes=0)(
        cell.reshape(-1, k)).reshape(codes_a.shape[:-1] + (16,))
    rho_grid = jnp.linspace(0.0, 0.99995, grid_size)
    probs = _cell_probs_2bit(rho_grid, w).reshape(grid_size, 16)  # [G, 16]
    logp = jnp.log(jnp.maximum(probs, 1e-30))
    ll = counts @ logp.T  # [..., G]
    return rho_grid[jnp.argmax(ll, axis=-1)]
