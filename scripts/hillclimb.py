import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimbing driver (EXPERIMENTS.md section Perf).

Measures named variants of the three chosen cells with the same
loop-corrected probe methodology as the dry-run, plus the coded-sketch
gradient-compression comparison (the paper's technique applied to the
collective term). Results -> hillclimb_results.json.

    PYTHONPATH=src python scripts/hillclimb.py [variant ...]
"""
import gc        # noqa: E402
import json      # noqa: E402
import sys       # noqa: E402
import time      # noqa: E402
from dataclasses import replace  # noqa: E402
from functools import partial    # noqa: E402

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import configs as C                                   # noqa: E402
from repro.launch import roofline as R                           # noqa: E402
from repro.launch.dryrun import (_probe_measure, analyze,        # noqa: E402
                                 lower_cell, probe_config)
from repro.launch.mesh import make_dp_mesh                       # noqa: E402
from repro.models import lm as L                                 # noqa: E402
from repro.models.nn import abstract_params                      # noqa: E402
from repro.optim import AdamWConfig, init_opt_state              # noqa: E402
from repro.train import make_compressed_train_step               # noqa: E402
from repro.core.gradient_compression import (                    # noqa: E402
    GradCompressionConfig, GradCompressor)

OUT = os.path.join(os.path.dirname(__file__), "..", "hillclimb_results.json")


def measure(arch, shape, overrides=None, cfg_tf=None, mesh_devices=256):
    """Full-compile memory + loop-corrected probe metrics for one variant."""
    cfg0 = C.get_config(arch)
    cfg_full = cfg_tf(cfg0) if cfg_tf else cfg0
    t0 = time.monotonic()
    lowered, meta = lower_cell(arch, shape, False, rules_overrides=overrides,
                               cfg=cfg_full)
    rec, _ = analyze(lowered, meta)
    del lowered
    gc.collect()
    _, n_groups, _ = L.layer_kinds(cfg_full)
    m1 = _probe_measure(arch, shape, False, overrides,
                        cfg_tf(probe_config(cfg0, 1)) if cfg_tf else probe_config(cfg0, 1))
    m2 = _probe_measure(arch, shape, False, overrides,
                        cfg_tf(probe_config(cfg0, 2)) if cfg_tf else probe_config(cfg0, 2))

    def ex(a, b):
        return max(0.0, a + (n_groups - 1) * (b - a))

    flops = ex(m1["flops"], m2["flops"])
    bts = ex(m1["bytes"], m2["bytes"])
    coll = {k: ex(m1["coll"][k], m2["coll"][k]) for k in m1["coll"]}
    rec.update({"flops_per_dev": flops, "bytes_per_dev": bts,
                "collective_bytes_per_dev": coll["total"],
                "collectives": {k: v for k, v in coll.items() if k != "total"}})
    rec.update(R.roofline_terms(flops, bts, coll["total"]))
    rec["useful_flop_ratio"] = rec["model_flops"] / max(flops * 256, 1.0)
    rec["wall_s"] = round(time.monotonic() - t0, 1)
    return rec


def measure_dp16(arch, compress):
    """Pure-DP (16-rank node) train step: plain psum vs coded-sketch sync."""
    cfg = C.get_config(arch)
    cfg = replace(cfg, n_layers=4)  # one-node study: 4 layers is enough to
    # expose the gradient-sync collective vs compute balance per layer
    mesh = make_dp_mesh(16)
    opt_cfg = AdamWConfig()
    specs = L.model_param_specs(cfg)
    aparams = abstract_params(specs)
    aopt = jax.eval_shape(partial(init_opt_state, cfg=opt_cfg), aparams)
    gtpl = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                        aparams)
    comp = None
    ef = None
    if compress:
        comp_real = GradCompressor(
            GradCompressionConfig(scheme="2bit", w=0.75, rate=8, chunk=4096),
            gtpl)
        comp = comp_real
        ef = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                          aparams)
    else:
        ef = jax.tree.map(lambda p: jax.ShapeDtypeStruct((1,), jnp.float32),
                          aparams)  # dummy ef (unused by plain path)
    step = make_compressed_train_step(cfg, opt_cfg, mesh, comp)
    atok = jax.ShapeDtypeStruct((256, 4096), jnp.int32)
    lowered = step.lower(aparams, aopt, ef, atok)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = R.collective_bytes(compiled.as_text())
    rec = {"arch": arch, "variant": "dp16_" + ("2bit" if compress else "psum"),
           "flops_per_dev": float(cost.get("flops", 0)),
           "bytes_per_dev": float(cost.get("bytes accessed", 0)),
           "collective_bytes_per_dev": coll["total"],
           "collectives": {k: v for k, v in coll.items() if k != "total"}}
    if compress:
        rec["wire_bytes_per_rank"] = comp.wire_bytes()
        rec["fp32_bytes"] = comp.fp32_bytes()
    del compiled, lowered
    gc.collect()
    return rec


VARIANTS = {
    # cell A: qwen2 train — worst roofline fraction (head replication)
    "A0_qwen2_base": lambda: measure("qwen2-0.5b", "train_4k"),
    "A1_qwen2_puredp": lambda: measure("qwen2-0.5b", "train_4k",
                                       overrides={"batch": "dpm"}),
    "A2_qwen2_dp16_psum": lambda: measure_dp16("qwen2-0.5b", False),
    "A3_qwen2_dp16_coded": lambda: measure_dp16("qwen2-0.5b", True),
    # cell B: qwen3-moe train — most collective-bound
    "B0_qwen3_base": lambda: measure("qwen3-moe-235b-a22b", "train_4k"),
    "B1_qwen3_seqres": lambda: measure("qwen3-moe-235b-a22b", "train_4k",
                                       overrides={"seq_res": "model"}),
    # B2: SP with an explicit post-norm gather point (one AG per layer
    # instead of GSPMD resharding every elementwise consumer)
    "B2_qwen3_seqres_gatherpoint": lambda: measure(
        "qwen3-moe-235b-a22b", "train_4k", overrides={"seq_res": "model"}),

    # cell C: gemma3 train — biggest memory term
    "C0_gemma3_base": lambda: measure("gemma3-27b", "train_4k"),
    "C1_gemma3_bf16probs": lambda: measure(
        "gemma3-27b", "train_4k",
        cfg_tf=lambda c: replace(c, probs_bf16=True, loss_chunk=1024)),
    "C2_gemma3_bf16_seqres": lambda: measure(
        "gemma3-27b", "train_4k", overrides={"seq_res": "model"},
        cfg_tf=lambda c: replace(c, probs_bf16=True, loss_chunk=1024)),
}


def main():
    names = [a for a in sys.argv[1:] if not a.startswith("-")] or list(VARIANTS)
    results = {}
    if os.path.exists(OUT):
        results = json.load(open(OUT))
    for name in names:
        if name in results and "--force" not in sys.argv:
            print(f"[hillclimb] cached {name}")
            continue
        print(f"[hillclimb] measuring {name} ...", flush=True)
        try:
            rec = VARIANTS[name]()
            rec["status"] = "ok"
        except Exception as e:
            import traceback
            traceback.print_exc()
            rec = {"status": "FAIL", "error": str(e)[:500]}
        results[name] = rec
        json.dump(results, open(OUT, "w"), indent=1)
        if rec.get("status") == "ok":
            print(f"[hillclimb] {name}: flops/dev={rec.get('flops_per_dev', 0):.3e} "
                  f"bytes/dev={rec.get('bytes_per_dev', 0):.3e} "
                  f"coll/dev={rec.get('collective_bytes_per_dev', 0):.3e}",
                  flush=True)


if __name__ == "__main__":
    main()
