"""repro.learn: dense-path parity, masked training over a churned
segment log, sharded gradients, serving. Packed-linear kernel-vs-oracle
bit-exactness lives in test_kernel_conformance.py."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import packing as PK
from repro.core.schemes import CodeSpec, encode
from repro.core.svm import SVMConfig, expand_codes, svm_accuracy, \
    train_linear_svm
from repro.index import SegmentLogStore
from repro.kernels import ref
from repro.kernels.packed_linear import onehot_tile
from repro.learn import (LearnConfig, PackedLinearModel, feature_spec_for,
                         fit_log, fit_store, fit_words,
                         packed_grads_sharded, train_dense_linear,
                         train_packed_linear)
from repro.learn.linear import (packed_loss_and_grads, packed_margins,
                                targets_pm, _dense_objective)

SPECS = [("2bit", 0.75), ("sign", 1.0), ("uniform", 1.0)]


def _rand_problem(key, scheme, w, k, n_cls, n):
    """Random tables/words/grads covering the full 2^bits code range."""
    spec = CodeSpec(scheme, w)
    p = 1 << spec.bits
    fp = PK.packed_width(k, spec.bits) * (32 // spec.bits) * p
    kc, kt, kg = jax.random.split(key, 3)
    words = PK.pack_codes(jax.random.randint(kc, (n, k), 0, p), spec.bits)
    tab = jax.random.normal(kt, (n_cls, fp))
    g = jax.random.normal(kg, (n_cls, n))
    return spec, tab, words, g


def test_onehot_tile_matches_dense_expansion():
    """The kernel's in-register one-hot equals expand_codes on the real
    columns and is zero-free on phantom entries only where expected."""
    spec = CodeSpec("2bit", 0.75)
    k, n = 30, 50
    fspec = feature_spec_for(spec, k)
    codes = jax.random.randint(jax.random.PRNGKey(0), (n, k), 0,
                               spec.n_codes)
    words = PK.pack_codes(codes, spec.bits)
    hot = onehot_tile(words, spec.bits)
    dense = expand_codes(codes, spec, normalize=False)
    np.testing.assert_array_equal(
        np.asarray(fspec.dense_from_tables(hot)), np.asarray(dense))
    # each row sets exactly n_fields entries (phantom fields hit code 0)
    assert (np.asarray(hot).sum(axis=1) == fspec.n_fields).all()


# -- feature geometry ---------------------------------------------------------

def test_feature_spec_layout_and_converters():
    fspec = feature_spec_for(CodeSpec("uniform", 1.0), 30)
    assert fspec.n_codes == 12 and fspec.bits == 4
    assert fspec.n_fields >= fspec.k and fspec.n_entries >= fspec.n_codes
    assert fspec.table_width == fspec.n_fields * fspec.n_entries
    w = jax.random.normal(jax.random.PRNGKey(1), (2, fspec.dense_dim))
    t = fspec.tables_from_dense(w)
    assert t.shape == (2, fspec.table_width)
    np.testing.assert_array_equal(np.asarray(fspec.dense_from_tables(t)),
                                  np.asarray(w))
    # phantom columns land exactly where entry_mask is zero
    mask = np.asarray(fspec.entry_mask())
    assert (np.asarray(t)[:, mask == 0.0] == 0.0).all()
    assert mask.sum() == fspec.dense_dim


def test_feature_spec_rejects_overflow():
    with pytest.raises(ValueError):
        from repro.learn import PackedFeatureSpec
        PackedFeatureSpec(k=8, bits=1, n_codes=4)


# -- gradients and margins vs the dense path ----------------------------------

def _planted(key, spec, k, n, sep=0.4):
    y = jnp.where(jax.random.uniform(key, (n,)) < 0.5, 1.0, -1.0)
    mu = jax.random.normal(jax.random.fold_in(key, 1), (k,)) * sep
    z = jax.random.normal(jax.random.fold_in(key, 2), (n, k)) \
        + y[:, None] * mu
    codes = encode(z, spec)
    return codes, PK.pack_codes(codes, spec.bits), y


@pytest.mark.parametrize("loss", ["sq_hinge", "logistic"])
def test_packed_grads_match_dense_autodiff(loss):
    """The fused analytic gradient equals jax.grad through the explicit
    one-hot feature matrix (same objective, float tolerance)."""
    spec = CodeSpec("2bit", 0.75)
    k, n = 48, 300
    fspec = feature_spec_for(spec, k)
    codes, words, y = _planted(jax.random.PRNGKey(0), spec, k, n)
    wt = jax.random.normal(jax.random.PRNGKey(5),
                           (1, fspec.table_width)) * fspec.entry_mask()
    b = jnp.asarray([0.3])
    lp, (dt, db) = packed_loss_and_grads((wt, b), words, targets_pm(y, 1),
                                         fspec, c=1.0, loss=loss)
    x = expand_codes(codes, spec)
    wd = fspec.dense_from_tables(wt)[0]
    ld = _dense_objective((wd, b[0]), x, y, 1.0, loss)
    gd = jax.grad(_dense_objective)((wd, b[0]), x, y, 1.0, loss)
    np.testing.assert_allclose(float(lp), float(ld), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(fspec.dense_from_tables(dt)[0]),
                               np.asarray(gd[0]), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(db[0]), float(gd[1]), rtol=1e-4,
                               atol=1e-5)
    # phantom columns never receive gradient
    mask = np.asarray(fspec.entry_mask())
    assert (np.asarray(dt)[:, mask == 0.0] == 0.0).all()


def test_packed_margins_equal_dense_matmul():
    spec = CodeSpec("uniform", 1.0)
    k, n = 40, 200
    fspec = feature_spec_for(spec, k)
    codes, words, _ = _planted(jax.random.PRNGKey(2), spec, k, n)
    wt = jax.random.normal(jax.random.PRNGKey(3),
                           (2, fspec.table_width)) * fspec.entry_mask()
    b = jnp.asarray([0.1, -0.2])
    m = packed_margins(wt, b, words, fspec)
    x = expand_codes(codes, spec)           # includes the 1/sqrt(k) norm
    md = x @ fspec.dense_from_tables(wt).T + b[None, :]
    np.testing.assert_allclose(np.asarray(m), np.asarray(md).T, rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("scheme,w", SPECS)
def test_training_parity_dense_vs_packed(scheme, w):
    """Acceptance contract: packed-code training reaches accuracy within
    1e-3 of the dense expand_codes path (same objective/optimizer)."""
    spec = CodeSpec(scheme, w)
    k, n = 32, 500
    fspec = feature_spec_for(spec, k)
    codes, words, y = _planted(jax.random.PRNGKey(7), spec, k, n, sep=0.3)
    cfg = LearnConfig(c=1.0, steps=120)
    model = train_packed_linear(words[:400], y[:400], fspec, cfg)
    x = expand_codes(codes, spec)
    w_, b_ = train_dense_linear(x[:400], y[:400], cfg)
    acc_p = model.accuracy(words[400:], np.asarray(y[400:]))
    acc_d = float(svm_accuracy(w_, b_, x[400:], y[400:]))
    assert abs(acc_p - acc_d) <= 1e-3, (acc_p, acc_d)
    assert acc_p >= acc_d - 1e-3
    # trained weights live on the same trajectory up to float rounding
    # (accumulated over cfg.steps Adam steps, hence the loose atol)
    np.testing.assert_allclose(np.asarray(model.margins(words)[0]),
                               np.asarray(x @ w_ + b_), atol=5e-3)


def test_compat_svm_wrapper_unchanged():
    """core.svm keeps the historical API and solver behavior."""
    x = jax.random.normal(jax.random.PRNGKey(0), (80, 12))
    y = jnp.where(x[:, 0] > 0, 1.0, -1.0)
    w_, b_ = train_linear_svm(x, y, SVMConfig(c=1.0, steps=80))
    assert float(svm_accuracy(w_, b_, x, y)) > 0.9


# -- training over stores -----------------------------------------------------

def test_fit_store_trains_off_code_store():
    from repro.ann import CodeStore
    spec = CodeSpec("2bit", 0.75)
    k = 32
    codes, _, y = _planted(jax.random.PRNGKey(11), spec, k, 400)
    store = CodeStore.from_codes(codes, k, spec.bits)
    model = fit_store(store, y, spec, LearnConfig(steps=80))
    assert model.accuracy(store.words, np.asarray(y)) > 0.9
    with pytest.raises(ValueError):
        fit_store(store, y, CodeSpec("sign", 1.0), LearnConfig(steps=2))


def test_fit_store_accepts_sketcher():
    """fit_store/fit_log docstrings promise 'a CodeSpec or sketcher' —
    the sketcher path must survive an explicit k (regression)."""
    from repro.ann import CodeStore
    from repro.core.sketch import CodedRandomProjection, SketchConfig
    k = 16
    crp = CodedRandomProjection(SketchConfig(k=k, scheme="2bit", w=0.75),
                                32)
    codes, _, y = _planted(jax.random.PRNGKey(61), crp.spec, k, 96)
    store = CodeStore.from_codes(codes, k, crp.spec.bits)
    model = fit_store(store, y, crp, LearnConfig(steps=10))
    assert model.fspec.k == k
    log = SegmentLogStore(k, crp.spec.bits, tail_rows=32)
    ids = log.add_codes(codes)
    labels = dict(zip((int(i) for i in ids),
                      np.where(np.asarray(y) > 0, 1, -1)))
    assert fit_log(log, labels, crp, LearnConfig(steps=5)).fspec.k == k


def test_masked_training_on_churned_log_matches_fresh_store():
    """fit_log over a store full of tombstones/upserts == fit_words on a
    fresh store holding only the live rows (float-order tolerance,
    identical predictions)."""
    spec = CodeSpec("2bit", 0.75)
    k = 32
    fspec = feature_spec_for(spec, k)
    codes, _, y = _planted(jax.random.PRNGKey(13), spec, k, 700)
    store = SegmentLogStore(k, spec.bits, tail_rows=256)
    ids = store.add_codes(codes)
    labels = {int(i): (1 if float(y[j]) > 0 else -1)
              for j, i in enumerate(ids)}
    # churn: delete a stripe, upsert another with fresh codes + labels
    dead = [int(i) for i in ids[::5]]
    store.delete(dead)
    for i in dead:
        labels.pop(i)
    up_ids = ids[3::50]
    new_codes = encode(jax.random.normal(jax.random.PRNGKey(17),
                                         (len(up_ids), k)), spec)
    store.upsert_codes(up_ids, new_codes)
    for i in up_ids:
        labels[int(i)] = -1
    cfg = LearnConfig(steps=60)
    m_log = fit_log(store, labels, spec, cfg)

    live_words = store.live_words()
    y_live = jnp.asarray([labels[int(i)] for i in store.live_ids()],
                         jnp.float32)
    m_fresh = fit_words(live_words, y_live, fspec, cfg)
    np.testing.assert_allclose(np.asarray(m_log.tables),
                               np.asarray(m_fresh.tables), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(m_log.predict(live_words)),
                                  np.asarray(m_fresh.predict(live_words)))
    assert m_log.accuracy(live_words, np.asarray(y_live)) > 0.9


def test_fit_log_callable_labels_and_empty_store():
    spec = CodeSpec("2bit", 0.75)
    store = SegmentLogStore(16, spec.bits, tail_rows=32)
    with pytest.raises(ValueError):
        fit_log(store, {}, spec, LearnConfig(steps=2))
    codes, _, y = _planted(jax.random.PRNGKey(19), spec, 16, 48)
    ids = store.add_codes(codes)
    by_id = dict(zip((int(i) for i in ids),
                     np.where(np.asarray(y) > 0, 1, -1)))
    m1 = fit_log(store, by_id, spec, LearnConfig(steps=20))
    m2 = fit_log(store, lambda q: [by_id[int(i)] for i in q], spec,
                 LearnConfig(steps=20))
    np.testing.assert_array_equal(np.asarray(m1.tables),
                                  np.asarray(m2.tables))


def test_sharded_grads_match_unsharded():
    spec = CodeSpec("2bit", 0.75)
    k, n = 32, 257          # deliberately not a multiple of 32
    fspec = feature_spec_for(spec, k)
    _, words, y = _planted(jax.random.PRNGKey(23), spec, k, n)
    wt = jax.random.normal(jax.random.PRNGKey(29),
                           (1, fspec.table_width)) * fspec.entry_mask()
    b = jnp.zeros((1,))
    y_pm = targets_pm(y, 1)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    ls, (dts, dbs) = packed_grads_sharded((wt, b), words, y_pm, fspec,
                                          mesh)
    lu, (dtu, dbu) = packed_loss_and_grads((wt, b), words, y_pm, fspec)
    np.testing.assert_allclose(float(ls), float(lu), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(dts), np.asarray(dtu),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(dbs), np.asarray(dbu),
                               rtol=1e-5, atol=1e-6)


def test_sharded_training_runs():
    spec = CodeSpec("2bit", 0.75)
    k = 32
    _, words, y = _planted(jax.random.PRNGKey(31), spec, k, 320)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    model = fit_words(words, y, feature_spec_for(spec, k),
                      LearnConfig(steps=40), mesh=mesh)
    assert model.accuracy(words, np.asarray(y)) > 0.9


def test_multiclass_one_vs_rest():
    spec = CodeSpec("2bit", 0.75)
    k, n, n_cls = 32, 600, 3
    key = jax.random.PRNGKey(37)
    y = jax.random.randint(key, (n,), 0, n_cls)
    mu = jax.random.normal(jax.random.fold_in(key, 1), (n_cls, k)) * 0.6
    z = jax.random.normal(jax.random.fold_in(key, 2), (n, k)) + mu[y]
    words = PK.pack_codes(encode(z, spec), spec.bits)
    model = fit_words(words, y, feature_spec_for(spec, k),
                      LearnConfig(steps=80), n_outputs=n_cls)
    assert model.n_outputs == n_cls
    assert model.accuracy(words, np.asarray(y)) > 0.8
    with pytest.raises(ValueError):
        model.decision(words)


def test_learn_config_validation():
    with pytest.raises(ValueError):
        LearnConfig(loss="hinge")
    spec = CodeSpec("2bit", 0.75)
    _, words, y = _planted(jax.random.PRNGKey(41), spec, 16, 64)
    with pytest.raises(ValueError):
        fit_words(words, y, feature_spec_for(spec, 16),
                  LearnConfig(steps=2, batch=32),
                  valid_words=PK.pack_bitmask(jnp.ones(64, bool)))
    with pytest.raises(ValueError):
        fit_words(words, y, feature_spec_for(spec, 16),
                  LearnConfig(steps=2, batch=128))
    store = SegmentLogStore(16, spec.bits, tail_rows=32)
    store.add_codes(encode(jax.random.normal(jax.random.PRNGKey(1),
                                             (8, 16)), spec))
    with pytest.raises(ValueError):
        fit_log(store, lambda ids: [1] * len(ids), spec,
                LearnConfig(steps=2, batch=4))


@pytest.mark.slow
def test_streaming_minibatch_training_long():
    """Long haul: streaming minibatch training over a corpus two orders
    larger than any batch, donated per-step updates, held-out accuracy."""
    spec = CodeSpec("2bit", 0.75)
    k, n = 64, 40960
    fspec = feature_spec_for(spec, k)
    _, words, y = _planted(jax.random.PRNGKey(43), spec, k, n + 2048,
                           sep=0.25)
    model = fit_words(words[:n], y[:n], fspec,
                      LearnConfig(steps=120, batch=1024))
    assert model.accuracy(words[n:], np.asarray(y[n:])) > 0.95


def test_minibatch_quick():
    spec = CodeSpec("2bit", 0.75)
    k = 32
    _, words, y = _planted(jax.random.PRNGKey(47), spec, k, 512)
    model = fit_words(words, y, feature_spec_for(spec, k),
                      LearnConfig(steps=50, batch=128))
    assert model.accuracy(words, np.asarray(y)) > 0.9


def test_logistic_loss_trains():
    spec = CodeSpec("2bit", 0.75)
    k = 32
    _, words, y = _planted(jax.random.PRNGKey(53), spec, k, 400)
    model = train_packed_linear(words, y, feature_spec_for(spec, k),
                                LearnConfig(loss="logistic", steps=80))
    assert model.loss == "logistic"
    assert model.accuracy(words, np.asarray(y)) > 0.9


# -- serving ------------------------------------------------------------------

def test_service_classify_endpoint():
    from repro.ann import AnnEngine, BandSpec
    from repro.core.sketch import CodedRandomProjection, SketchConfig
    from repro.serve.ann_service import AnnService

    d, k, n = 64, 32, 300
    key = jax.random.PRNGKey(59)
    crp = CodedRandomProjection(SketchConfig(k=k, scheme="2bit", w=0.75), d)
    x = jax.random.normal(key, (n, d))
    y = jnp.where(x[:, 0] > 0, 1.0, -1.0)
    engine = AnnEngine.build(crp, x, BandSpec(n_tables=4, band_width=4))
    svc = AnnService(engine)
    with pytest.raises(TypeError):
        svc.classify(x[:4])
    codes = crp.encode(x)
    words = crp.pack(codes)
    model = fit_words(words, y, feature_spec_for(crp.spec, k),
                      LearnConfig(steps=60))
    svc.set_classifier(model)
    pred, margins = svc.classify(x[:32])
    assert pred.shape == (32,) and margins.shape == (1, 32)
    np.testing.assert_array_equal(pred,
                                  np.asarray(model.predict(words[:32])))
    # batches beyond the largest bucket split into bucket-shaped slices
    pred_all, marg_all = svc.classify(x)
    assert pred_all.shape == (n,) and marg_all.shape == (1, n)
    np.testing.assert_array_equal(pred_all,
                                  np.asarray(model.predict(words)))
    with pytest.raises(ValueError):
        svc.classify(x[0])
    # k/bits mismatch rejected
    other = PackedLinearModel.zeros(feature_spec_for(CodeSpec("sign", 1.0),
                                                     k))
    with pytest.raises(ValueError):
        svc.set_classifier(other)
