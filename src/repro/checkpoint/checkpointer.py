"""Atomic, sharding-aware, elastic checkpointing.

Design (scaled mentally to 1000+ nodes, implemented for this container):

* Arrays are stored at their *logical* (global) shapes, one ``.npy`` per
  pytree leaf plus a msgpack-free JSON manifest. On a multi-host cluster
  each host writes only the shards it owns into a per-leaf directory and
  host 0 writes the manifest; here (single process) fully-addressable
  arrays are written directly. Restore re-shards to *any* mesh — the
  elastic-rescale path: load global array, device_put with the new
  sharding.
* Atomicity: write to ``step_N.tmp/``, fsync, rename to ``step_N/``. A
  crash mid-write never corrupts the latest complete checkpoint.
* Retention: keep the newest ``keep`` checkpoints (the scheduler may
  restart the job against any of them).
* ``latest_step`` scans for complete checkpoints only.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Optional

import numpy as np
import jax

__all__ = ["save_checkpoint", "restore_checkpoint", "read_manifest",
           "latest_step", "available_steps"]

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree):
    # jax.tree.flatten_with_path only exists on jax >= 0.4.38; the
    # tree_util spelling works on every version we target
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), x) for p, x in leaves], treedef


def save_checkpoint(directory: str, step: int, tree, keep: int = 3) -> str:
    """Write pytree ``tree`` at ``directory/step_<step>``. Returns path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    named, _ = _flatten(tree)
    manifest = {"step": step, "leaves": []}
    for i, (name, x) in enumerate(named):
        arr = np.asarray(jax.device_get(x))
        shape = arr.shape  # before ascontiguousarray (it promotes 0-d to 1-d)
        arr = np.ascontiguousarray(arr)
        fn = f"leaf_{i}.npy"
        # store the raw byte view: ml_dtypes (bfloat16) do not roundtrip
        # through npy dtype descriptors on plain numpy loads
        np.save(os.path.join(tmp, fn), arr.reshape(-1).view(np.uint8))
        manifest["leaves"].append({"name": name, "file": fn,
                                   "shape": list(shape),
                                   "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _retain(directory, keep)
    return final


def _retain(directory: str, keep: int):
    steps = sorted(available_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)


def available_steps(directory: str):
    """Complete checkpoints only (manifest present)."""
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        m = _STEP_RE.match(d)
        if m and os.path.exists(os.path.join(directory, d, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = available_steps(directory)
    return steps[-1] if steps else None


def read_manifest(directory: str, step: int) -> dict:
    """The step's manifest (leaf names/shapes/dtypes). Lets a caller
    reconstruct the ``like`` pytree for ``restore_checkpoint`` without
    knowing the saved structure a priori — the self-describing-restore
    path (``repro.index.snapshot`` rebuilds whole indexes from it)."""
    path = os.path.join(directory, f"step_{step}", "manifest.json")
    with open(path) as f:
        return json.load(f)


def restore_checkpoint(directory: str, step: int, like, shardings=None):
    """Restore into the structure of ``like`` (pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching pytree of
    NamedShardings — the elastic-rescale path (any mesh works).
    """
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    named_like, treedef = _flatten(like)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(named_like))
    if shardings is not None:
        assert len(shard_leaves) == len(named_like)
    out = []
    for (name, proto), shd in zip(named_like, shard_leaves):
        entry = by_name.get(name)
        if entry is None:
            raise KeyError(f"checkpoint at {path} missing leaf {name}")
        raw = np.load(os.path.join(path, entry["file"]))
        stored_dtype = np.dtype(jax.numpy.dtype(entry["dtype"]))
        stored_shape = tuple(entry["shape"])
        arr = raw.reshape(-1).view(stored_dtype).reshape(stored_shape)
        want_shape = tuple(proto.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{name}: checkpoint shape {arr.shape} != {want_shape}")
        arr = arr.astype(proto.dtype)
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out)
