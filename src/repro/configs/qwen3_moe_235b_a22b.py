"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128e top-8 (renormalized gates), qk-norm.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from dataclasses import replace

from repro.models.lm import ModelConfig

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe",
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_ff=1536,
        vocab_size=151936, head_dim=128, rope_theta=1e6, qk_norm=True,
        n_experts=128, n_experts_per_token=8, moe_d_ff=1536,
        renorm_gates=True, tie_embeddings=False, norm_eps=1e-6,
    )


def smoke_config() -> ModelConfig:
    # capacity_factor=8 -> no token dropping, so prefill/decode agree exactly
    return replace(config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                   head_dim=16, d_ff=64, vocab_size=256, n_experts=8,
                   n_experts_per_token=2, moe_d_ff=64, capacity_factor=8.0,
                   loss_chunk=16, chunk_kv=32, chunk_q=16)
