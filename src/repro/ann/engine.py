"""Batched device-resident ANN search over packed codes.

The serving-side payoff of the paper's coding schemes (and of the
follow-ups 1403.8144 / 1602.06577): queries are fused-projected to b-bit
codes, bit-packed, and matched against a ``CodeStore`` without the codes
ever existing as int32 in HBM. Two candidate modes:

``exact``   — brute-force: streaming packed-collision top-k over the whole
              corpus (``kernels.packed_collision``; jnp oracle off-TPU).
``lsh``     — banded candidates: batched multi-probe band-hash matching
              (``ann.bands``) scores every corpus row by matching-band
              count; only rows sharing >= ``min_bands`` buckets with the
              query are eligible (classic LSH retrieval semantics), and
              eligible rows are re-ranked by full packed collision count.
              Packed counts are so cheap (32/b codes per uint32 XOR) that
              re-ranking is a masked brute pass rather than a gather —
              the candidate *set* is exact, never truncated to a fixed C,
              and grows monotonically with ``n_probes``.

Both modes also run **two-stage scored** (``scored=True``): the coarse
pass above selects top-``rerank_m`` candidates by collision count, then
a fused LUT kernel re-ranks them with the non-linear per-code-pair
scores of ``repro.rank`` (contingency-table log-likelihood ratios, the
1602.06577 estimator family) and returns calibrated rho_hat from the
scores. Collision counts only see the table's diagonal, so equal counts
hide real similarity differences; the re-rank breaks exactly those ties
and recovers recall the coarse pass leaves on the floor.

Both modes process queries in fixed-size chunks (padded to one shape, so
each mode compiles exactly twice: chunk shape + remainder-free path) and
return (ids [Q, top_k], rho_hat [Q, top_k]) with rho_hat from the paper's
collision estimator (table inversion of P(rho), or the LUT calibration
curve when scored). ``search_sharded`` runs the exact mode under
``shard_map`` with the corpus row-sharded across a mesh axis, merging
per-shard top-k by all-gather + re-top-k.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.ann.bands import BandSpec, band_hashes, probe_hashes
from repro.ann.store import CodeStore
from repro.core import packing as _packing
from repro.core.sketch import CodedRandomProjection
from repro.kernels import ops as _ops
from repro.kernels import ref as _ref
from repro.obs import default_flight_recorder, deep_tracing_active, span
from repro.rank.tables import RankTables, build_rank_tables

__all__ = ["SearchConfig", "AnnEngine", "QueryCoder", "merge_topk",
           "run_chunked", "lut_rerank_stage", "rho_scored",
           "resolve_query_tables"]


@dataclass(frozen=True)
class SearchConfig:
    """Static knobs of one search variant (one jit cache entry each)."""
    top_k: int = 10
    mode: str = "exact"          # exact | lsh
    min_bands: int = 1           # lsh: matching bands required to be a candidate
    n_probes: int = 0            # lsh: multi-probe expansions per band
    chunk_q: int = 256           # query rows per device step
    impl: str = "auto"           # kernel dispatch (see kernels.ops)
    scored: bool = False         # scored search: LUT scores, calibrated rho
    rerank_m: int = 0            # scored: coarse candidates (0 = auto)
    fused: bool = True           # scored exact: single-pass kernel (False =
    #                              the literal two-stage coarse -> re-rank)
    table_dtype: str = "auto"    # LUT storage: auto | f32 | bf16 | int8

    def resolve_m(self, n: int) -> int:
        """Coarse candidate count for one part with ``n`` rows: the
        configured ``rerank_m`` (default 4*top_k, floor 64), never below
        ``top_k`` and never above ``n`` (all static => one jit entry)."""
        m = self.rerank_m or max(64, 4 * self.top_k)
        return max(1, min(max(m, self.top_k), n))

    def use_fused(self) -> bool:
        """Whether this config takes the single-pass fused scored kernel:
        scored exact search only (lsh's band filter runs in the coarse
        stage, so lsh scored stays two-stage)."""
        return self.scored and self.fused and self.mode == "exact"


def resolve_query_tables(tables: RankTables, q_codes, table_dtype: str):
    """Build per-query LUTs in the configured storage dtype ->
    (q_tables [Q, F*P], scales [Q, W] or None).

    ``auto`` takes the table bundle's own dtype (f32, or bf16 after
    ``quantize``); ``f32``/``bf16`` force it; ``int8`` returns
    power-of-two-scaled int8 tables (``RankTables.query_tables_int8``),
    which only the fused scored kernel accepts.
    """
    if table_dtype == "int8":
        return tables.query_tables_int8(q_codes)
    named = {"auto": None, "f32": jnp.float32, "bf16": jnp.bfloat16}
    if table_dtype not in named:
        raise ValueError(f"unknown table_dtype {table_dtype!r}")
    return tables.query_tables(q_codes, dtype=named[table_dtype]), None


class QueryCoder:
    """Fused query encoder shared by the immutable engine and the mutable
    segment-log engine (``repro.index``): a thin facade over
    ``repro.encode.StreamingEncoder`` — fused proj+code kernel over the
    cached R below the residency cap, matrix-free unit streaming above
    it, so a D = 3.2M index never materializes [D, k] for queries
    either."""

    def __init__(self, sketcher: CodedRandomProjection):
        self.sketcher = sketcher
        self._encoder = sketcher.stream_encoder()   # shared per-sketcher

    def r_matrix(self):
        """Materialized projection [D, k] (cached), regenerated from the
        seed unit by unit.  Raises above the encoder's residency cap —
        large-D callers must stream (``encode`` does, transparently)."""
        return self._encoder.r_matrix()

    def encode(self, x, impl: str = "auto"):
        """x [Q, D] (dense or ``encode.CsrMatrix``) -> int32 codes
        [Q, k]: fused proj+code kernel when R is resident, streaming
        projection + scheme encode otherwise."""
        return self._encoder.encode_codes(x, impl=impl)

    def encode_packed(self, x, impl: str = "auto"):
        """x [Q, D] (dense or ``encode.CsrMatrix``) -> packed uint32
        [Q, W] via the fused project→code→pack ingest path."""
        return self._encoder.encode_packed(x, impl=impl)


def merge_topk(vals_list, ids_list, top_k: int):
    """Merge per-part (segment/shard) top-k lists into a global top-k.

    vals_list: per-part values, each int32 collision counts or float32
    LUT scores [Q, k_part]; ids_list: matching int32 ids [Q, k_part]
    (-1 = empty slot). Returns (vals [Q, top_k], ids int32 [Q, top_k]).

    Tie-break order: parts are concatenated in list order and
    ``lax.top_k`` is stable, so equal values resolve to the earliest
    part and, within a part, to the part's own list order (the kernels
    emit ties lowest-row-first). With parts ordered by row offset this
    reproduces the single-store tie-break exactly. Empty slots keep ids
    of -1: the sentinel value is -1 for integer counts and -inf for
    float scores (real float scores may be negative).
    """
    cat_v = jnp.concatenate(vals_list, axis=1)
    cat_i = jnp.concatenate(ids_list, axis=1)
    best_v, pos = jax.lax.top_k(cat_v, top_k)
    best_i = jnp.take_along_axis(cat_i, pos, axis=1)
    if jnp.issubdtype(cat_v.dtype, jnp.floating):
        empty = jnp.isneginf(best_v)
    else:
        empty = best_v < 0
    return best_v, jnp.where(empty, -1, best_i)


def run_chunked(q_codes, cfg: SearchConfig, chunk_fn):
    """Shared query chunking: pad Q up to a power-of-two chunk (jit cache
    stays <= log2(chunk_q) shapes however callers vary Q), run
    ``chunk_fn(q_codes[lo:hi], cfg)`` per chunk, unpad."""
    q = q_codes.shape[0]
    chunk = min(cfg.chunk_q, 1 << (q - 1).bit_length())
    cfg = replace(cfg, chunk_q=chunk)
    pad = (-q) % chunk
    if pad:
        q_codes = jnp.pad(q_codes, ((0, pad), (0, 0)))
    ids, rho = [], []
    for lo in range(0, q + pad, chunk):
        i, r = chunk_fn(q_codes[lo:lo + chunk], cfg)
        ids.append(i)
        rho.append(r)
    return jnp.concatenate(ids)[:q], jnp.concatenate(rho)[:q]


def lut_rerank_stage(tables: RankTables, q_codes, cand_ids, words_src,
                     top_k: int, impl: str = "auto", q_tables=None):
    """Second stage of a two-stage scored search (shared by the
    immutable, mutable and sharded paths).

    q_codes int32 [c, k]; cand_ids int32 [c, M] rows into ``words_src``
    uint32 [n, W] from a coarse pass (-1 = empty slot); returns
    (rows int32 [c, top_k] into words_src, -1 empty; scores f32
    [c, top_k], -inf empty). Gathers candidate rows, builds the
    query-specialized LUTs (pass prebuilt ``q_tables`` [c, F*P] to
    reuse them across calls, e.g. per-segment loops) and runs the fused
    re-rank kernel; fully jittable (one XLA gather + one kernel call).
    """
    n = words_src.shape[0]
    cand = jnp.take(words_src, jnp.clip(cand_ids, 0, n - 1), axis=0)
    if q_tables is None:
        q_tables = tables.query_tables(q_codes)
    scores, pos = _ops.packed_lut_rerank(q_tables, cand, cand_ids >= 0,
                                         tables.bits, top_k, impl=impl)
    rows = jnp.take_along_axis(cand_ids,
                               jnp.clip(pos, 0, cand_ids.shape[1] - 1),
                               axis=1)
    return jnp.where(pos < 0, -1, rows), scores


def rho_scored(tables: RankTables, ids, scores):
    """LUT scores [...] -> calibrated rho_hat float32 [...] via the
    tables' inversion curve; empty slots (id < 0) surface as rho = -1
    (the scored twin of the engines' count-based ``_rho``)."""
    rho = tables.rho_from_scores(scores)
    return jnp.where(ids < 0, -1.0, rho)


def _packed_counts_rowwise(q_words, cand_words, bits: int, k: int):
    """q_words [c, W] vs per-query candidates [c, C, W] -> int32 [c, C]."""
    w = q_words.shape[-1]
    mism = jnp.zeros(cand_words.shape[:-1], jnp.int32)
    for j in range(w):
        xor = jnp.bitwise_xor(q_words[:, None, j], cand_words[..., j])
        mism = mism + _packing.mismatch_count_words(xor, bits).astype(jnp.int32)
    return k - mism


def _coarse_band_scores(q_probe_hashes, db_hashes):
    """Matching-band counts: [c, P, L] vs [N, L] -> int32 [c, N].

    A band matches when *any* probe hits its bucket; looping the small
    static (P, L) axes keeps temporaries at [c, N].
    """
    c, p_n, l_n = q_probe_hashes.shape
    score = jnp.zeros((c, db_hashes.shape[0]), jnp.int32)
    for l in range(l_n):
        hit = jnp.zeros((c, db_hashes.shape[0]), bool)
        for p in range(p_n):
            hit = hit | (q_probe_hashes[:, p, l][:, None]
                         == db_hashes[None, :, l])
        score = score + hit.astype(jnp.int32)
    return score


class AnnEngine:
    """Immutable search engine: sketcher + packed corpus + band hashes."""

    def __init__(self, sketcher: CodedRandomProjection, store: CodeStore,
                 band_spec: BandSpec = BandSpec(), db_band_hashes=None,
                 rank_tables: RankTables = None):
        self.sketcher = sketcher
        self.store = store
        self.band_spec = band_spec.validate(sketcher.cfg.k)
        if db_band_hashes is None:
            db_band_hashes = band_hashes(store.unpack(), band_spec)
        self.db_band_hashes = db_band_hashes      # uint32 [n, L]
        self._coder = QueryCoder(sketcher)
        self._rank_tables = rank_tables
        self._search_fns = {}
        self._stage_fns = {}      # cfg -> (jit coarse, jit rerank)
        self.quality = None       # obs.quality.QualityMonitors, if attached

    # -- construction / ingestion -------------------------------------------
    @classmethod
    def build(cls, sketcher: CodedRandomProjection, corpus,
              band_spec: BandSpec = BandSpec(), impl: str = "auto"):
        """Index a corpus [n, D]: fused project+code, pack, band-hash —
        through the sketcher's shared ``repro.encode`` encoder, the
        same numerics queries use."""
        codes = sketcher.stream_encoder().encode_codes(corpus, impl=impl)
        return cls.from_codes(sketcher, codes, band_spec, impl=impl)

    @classmethod
    def from_codes(cls, sketcher: CodedRandomProjection, codes,
                   band_spec: BandSpec = BandSpec(), impl: str = "auto"):
        """Index pre-encoded int32 codes [n, k]: pack + band-hash."""
        store = CodeStore.from_codes(codes, sketcher.cfg.k,
                                     sketcher.spec.bits, impl=impl)
        return cls(sketcher, store, band_spec,
                   db_band_hashes=band_hashes(codes, band_spec))

    def add(self, x, impl: str = "auto") -> "AnnEngine":
        """New engine with corpus rows appended (ids continue from n);
        encoded through the shared query coder's fused path."""
        codes = self._coder.encode(x, impl=impl)
        store = self.store.add(codes, impl=impl)
        hashes = jnp.concatenate(
            [self.db_band_hashes, band_hashes(codes, self.band_spec)])
        new = AnnEngine(self.sketcher, store, self.band_spec,
                        db_band_hashes=hashes,
                        rank_tables=self._rank_tables)
        new.quality = self.quality
        return new

    @property
    def n(self) -> int:
        """Corpus rows resident in the store."""
        return self.store.n

    @property
    def rank_tables(self) -> RankTables:
        """LUT scoring tables for scored search, built lazily from the
        sketcher's (scheme, k) on first use (pass ``rank_tables`` to
        ``__init__`` to override, e.g. for bf16-quantized tables)."""
        if self._rank_tables is None:
            self._rank_tables = build_rank_tables(self.sketcher)
        return self._rank_tables

    # -- query encoding ------------------------------------------------------
    def _r_matrix(self):
        return self._coder.r_matrix()

    def encode_queries(self, x, impl: str = "auto"):
        """x [Q, D] -> int32 codes [Q, k] via the fused proj+code kernel."""
        return self._coder.encode(x, impl=impl)

    # -- quality audit hooks -------------------------------------------------
    def attach_quality(self, monitors) -> "AnnEngine":
        """Attach an ``obs.quality.QualityMonitors`` bundle: every search
        gets a budgeted chance (its ``sample_rate``) of feeding one
        query-candidate batch to the collision monitor. Returns self."""
        self.quality = monitors
        return self

    def codes_for_ids(self, ids):
        """int32 codes [m, k] of store rows ``ids`` (row positions) —
        the small gather the quality audit re-scores against."""
        words = self.store.take(jnp.asarray(ids, jnp.int32))
        return _packing.unpack_codes(words, self.sketcher.spec.bits,
                                     self.sketcher.cfg.k)

    # -- search --------------------------------------------------------------
    def search(self, queries, top_k: int = 10, *, mode: str = "exact",
               min_bands: int = 1, n_probes: int = 0,
               chunk_q: int = 256, impl: str = "auto",
               scored: bool = False, rerank_m: int = 0,
               fused: bool = True, table_dtype: str = "auto"):
        """queries float [Q, D] -> (ids int32 [Q, top_k], rho_hat
        float32 [Q, top_k]).

        ids of -1 mark empty slots (top_k exceeding corpus/candidates).
        ``scored=True`` runs the two-stage path — coarse collision top-m
        (m = ``rerank_m``, 0 = auto) then fused LUT re-rank — and
        returns rho_hat calibrated from the non-linear scores.
        """
        cfg = SearchConfig(top_k=top_k, mode=mode, min_bands=min_bands,
                           n_probes=n_probes, chunk_q=chunk_q, impl=impl,
                           scored=scored, rerank_m=rerank_m, fused=fused,
                           table_dtype=table_dtype)
        return self.search_codes(self.encode_queries(queries, impl=impl), cfg)

    def search_codes(self, q_codes, cfg: SearchConfig):
        """Search pre-encoded queries [Q, k] (chunked, padded to one shape).

        When a *deep* ``repro.obs.Tracer`` is installed (profiling),
        every chunk runs under device-synced spans — two-stage scored
        searches as a ``search.coarse`` / ``search.rerank`` pair (the
        two stages jit separately at a chunk boundary; same kernels,
        same results), so a trace attributes coarse and re-rank wall
        time honestly. Under a shallow per-request trace
        (``obs.RequestTrace``) the chunks keep their async fast path —
        one submission-timed ``search.chunks`` span carries the trace
        id instead, and a flight-recorder event marks the call.
        """
        if cfg.mode not in ("exact", "lsh"):
            raise ValueError(f"unknown mode {cfg.mode!r}")
        if cfg.table_dtype == "int8" and not cfg.use_fused():
            raise ValueError("int8 tables require the fused scored exact "
                             "path (scored=True, fused=True, mode='exact')")
        q = q_codes.shape[0]
        if q == 0 or self.store.n == 0:
            return (jnp.full((q, cfg.top_k), -1, jnp.int32),
                    jnp.full((q, cfg.top_k), -1.0, jnp.float32))
        t0 = time.perf_counter()
        if deep_tracing_active():
            out = run_chunked(q_codes, cfg, self._traced_chunk)
        else:
            with span("search.chunks", sync=False, mode=cfg.mode,
                      q=int(q), scored=cfg.scored):
                out = run_chunked(
                    q_codes, cfg,
                    lambda chunk, c: self._chunk_fn(c)(chunk))
        default_flight_recorder().record(
            "ann.search", t0, time.perf_counter(), batch=int(q),
            outcome=cfg.mode, synced=deep_tracing_active())
        if self.quality is not None:
            self.quality.observe_search(q_codes, out[0], self.codes_for_ids)
        return out

    def _chunk_fn(self, cfg: SearchConfig):
        """jit'd one-chunk search; cached per SearchConfig (warm cache)."""
        fn = self._search_fns.get(cfg)
        if fn is None:
            if cfg.scored:
                self.rank_tables        # host-side build, outside the trace
            body = (self._exact_chunk if cfg.mode == "exact"
                    else self._lsh_chunk)
            fn = jax.jit(functools.partial(body, cfg=cfg))
            self._search_fns[cfg] = fn
        return fn

    def _stage_fn_pair(self, cfg: SearchConfig):
        """jit'd (coarse, rerank) stage pair for span-split scored
        search; cached per SearchConfig like ``_chunk_fn``."""
        fns = self._stage_fns.get(cfg)
        if fns is None:
            self.rank_tables            # host-side build, outside the trace
            body = (self._exact_coarse if cfg.mode == "exact"
                    else self._lsh_coarse)
            coarse = jax.jit(functools.partial(body, cfg=cfg))
            rerank = jax.jit(lambda qc, ids: self._rerank(qc, ids, cfg))
            fns = self._stage_fns[cfg] = (coarse, rerank)
        return fns

    def _traced_chunk(self, chunk, cfg: SearchConfig):
        """One chunk under spans (tracer installed). Non-scored chunks
        get one ``search.chunk`` span; scored chunks split into
        device-synced ``search.coarse`` + ``search.rerank``."""
        if not cfg.scored:
            with span("search.chunk", mode=cfg.mode,
                      q=int(chunk.shape[0])) as sp:
                out = sp.sync(self._chunk_fn(cfg)(chunk))
            return out
        if cfg.use_fused():
            with span("search.fused", mode=cfg.mode,
                      q=int(chunk.shape[0]),
                      m=cfg.resolve_m(self.store.n),
                      top_k=cfg.top_k) as sp:
                out = sp.sync(self._chunk_fn(cfg)(chunk))
            return out
        coarse, rerank = self._stage_fn_pair(cfg)
        with span("search.coarse", mode=cfg.mode,
                  q=int(chunk.shape[0]),
                  m=cfg.resolve_m(self.store.n)) as sp:
            _, cand_ids = sp.sync(coarse(chunk))
        with span("search.rerank", top_k=cfg.top_k) as sp:
            out = sp.sync(rerank(chunk, cand_ids))
        return out

    def _rho(self, counts):
        """Collision counts -> rho_hat via the paper's estimator; empty
        slots (count < 0) surface as rho = -1."""
        k = self.sketcher.cfg.k
        rho = self.sketcher._estimator(counts / k)
        return jnp.where(counts < 0, -1.0, rho)

    def _rerank(self, q_codes, cand_ids, cfg: SearchConfig):
        """Coarse candidate rows -> (ids, rho) by fused LUT re-rank."""
        ids, scores = lut_rerank_stage(self.rank_tables, q_codes, cand_ids,
                                       self.store.words, cfg.top_k,
                                       impl=cfg.impl)
        return ids, rho_scored(self.rank_tables, ids, scores)

    def _exact_coarse(self, q_codes, *, cfg: SearchConfig):
        """Coarse pass of one exact chunk -> (vals, ids) at top-m (scored)
        or top-k (counts-only)."""
        q_words = _ops.pack_codes(q_codes, self.store.bits, impl=cfg.impl)
        top = cfg.resolve_m(self.store.n) if cfg.scored else cfg.top_k
        vals, ids = _ops.packed_topk(
            q_words, self.store.words, self.store.bits, self.sketcher.cfg.k,
            top, impl=cfg.impl)
        return vals, jnp.where(vals < 0, -1, ids)

    def _fused_chunk(self, q_codes, *, cfg: SearchConfig):
        """One scored exact chunk through the single-pass fused kernel:
        coarse top-m selection and LUT re-rank in one corpus stream —
        bit-identical results to the two-stage pair wherever LUT scores
        don\'t tie across different collision counts."""
        q_words = _ops.pack_codes(q_codes, self.store.bits, impl=cfg.impl)
        q_tables, scales = resolve_query_tables(self.rank_tables, q_codes,
                                                cfg.table_dtype)
        scores, ids = _ops.fused_scored_topk(
            q_words, q_tables, self.store.words, self.store.bits,
            self.sketcher.cfg.k, cfg.resolve_m(self.store.n), cfg.top_k,
            scales=scales, impl=cfg.impl)
        return ids, rho_scored(self.rank_tables, ids, scores)

    def _exact_chunk(self, q_codes, *, cfg: SearchConfig):
        if cfg.use_fused():
            return self._fused_chunk(q_codes, cfg=cfg)
        vals, ids = self._exact_coarse(q_codes, cfg=cfg)
        if cfg.scored:
            return self._rerank(q_codes, ids, cfg)
        return ids, self._rho(vals)

    def _lsh_coarse(self, q_codes, *, cfg: SearchConfig):
        """Coarse pass of one lsh chunk -> (vals, ids), band-filtered."""
        q_words = _ops.pack_codes(q_codes, self.store.bits, impl=cfg.impl)
        qh = probe_hashes(q_codes, self.band_spec, cfg.n_probes)
        coarse = _coarse_band_scores(qh, self.db_band_hashes)
        counts = _ops.packed_collision_counts(
            q_words, self.store.words, self.store.bits, self.sketcher.cfg.k,
            impl=cfg.impl)
        # non-candidates (too few matching bands) are unretrievable
        counts = jnp.where(coarse >= cfg.min_bands, counts, -1)
        top = cfg.resolve_m(self.store.n) if cfg.scored else cfg.top_k
        return _ref.topk_stable_ref(counts, top)

    def _lsh_chunk(self, q_codes, *, cfg: SearchConfig):
        vals, ids = self._lsh_coarse(q_codes, cfg=cfg)
        if cfg.scored:
            return self._rerank(q_codes, ids, cfg)
        return ids, self._rho(vals)

    # -- candidate introspection (compat wrapper + tests) --------------------
    def band_match_counts(self, q_codes, n_probes: int = 0):
        """[Q, k] codes -> int32 [Q, n] matching-band counts (coarse
        scores; a row is a candidate iff its count > 0). Monotone
        non-decreasing in ``n_probes`` (prefix-nested probes)."""
        qh = probe_hashes(q_codes, self.band_spec, n_probes)
        return _coarse_band_scores(qh, self.db_band_hashes)

    def rerank(self, q_codes, cand_ids):
        """Full packed collision counts of one query row's candidate list
        -> (counts [c], rho_hat [c])."""
        q_words = _ops.pack_codes(q_codes[None, :], self.store.bits,
                                  impl="ref")
        counts = _packed_counts_rowwise(
            q_words, self.store.take(jnp.asarray(cand_ids))[None, ...],
            self.store.bits, self.sketcher.cfg.k)[0]
        return counts, self._rho(counts)

    # -- multi-device path ---------------------------------------------------
    def search_sharded(self, queries, mesh: Mesh, axis: str = "data",
                       top_k: int = 10, impl: str = "auto",
                       scored: bool = False, rerank_m: int = 0,
                       fused: bool = True, table_dtype: str = "auto"):
        """Exact search with the corpus row-sharded over ``mesh[axis]``.

        queries float [Q, D] -> (ids int32 [Q, top_k], rho_hat float32
        [Q, top_k]). Each shard computes a local streaming top-k over
        its rows (local ids offset to global by the shard index), then
        the per-shard lists are all-gathered and re-top-k'd — the
        classic distributed top-k merge; every step stays on device.
        With ``scored=True`` each shard additionally LUT-scores its
        local coarse top-m before the merge (single-pass fused kernel
        by default, two-stage rerank with ``fused=False``), so the
        cross-shard merge compares calibrated scores, not counts.
        Query tables are built once on host side and replicated;
        ``table_dtype`` selects their storage (see ``SearchConfig``).
        """
        from jax.experimental.shard_map import shard_map

        store = self.store.shard(mesh, axis)
        q_codes = self.encode_queries(queries, impl=impl)
        q_words = _ops.pack_codes(q_codes, store.bits, impl=impl)
        k = self.sketcher.cfg.k
        bits = store.bits
        n_local = store.n // mesh.shape[axis]
        tables = self.rank_tables if scored else None
        cfg = SearchConfig(top_k=top_k, scored=scored, rerank_m=rerank_m,
                           fused=fused, table_dtype=table_dtype)
        if cfg.table_dtype == "int8" and not cfg.use_fused():
            raise ValueError("table_dtype='int8' requires the fused "
                             "scored path (scored=True, fused=True)")

        def merge_gathered(vals, ids, offset):
            ids = jnp.where(ids < 0, -1, ids + offset)
            vg = jax.lax.all_gather(vals, axis)       # [n_sh, Q, top_k]
            ig = jax.lax.all_gather(ids, axis)
            vg = jnp.moveaxis(vg, 0, 1).reshape(vals.shape[0], -1)
            ig = jnp.moveaxis(ig, 0, 1).reshape(vals.shape[0], -1)
            best, pos = jax.lax.top_k(vg, top_k)
            return best, jnp.take_along_axis(ig, pos, axis=1)

        def local(qw, dbw):
            vals, ids = _ops.packed_topk(qw, dbw, bits, k, top_k, impl=impl)
            return merge_gathered(vals, ids,
                                  jax.lax.axis_index(axis) * dbw.shape[0])

        def local_scored(qw, qc, dbw):
            m = cfg.resolve_m(n_local)
            cvals, cids = _ops.packed_topk(qw, dbw, bits, k, m, impl=impl)
            cids = jnp.where(cvals < 0, -1, cids)
            rows, scores = lut_rerank_stage(tables, qc, cids, dbw, top_k,
                                            impl=impl)
            return merge_gathered(scores, rows,
                                  jax.lax.axis_index(axis) * dbw.shape[0])

        def local_fused(qw, tabs, dbw, scl=None):
            m = cfg.resolve_m(n_local)
            scores, rows = _ops.fused_scored_topk(
                qw, tabs, dbw, bits, k, m, top_k, scales=scl, impl=impl)
            return merge_gathered(scores, rows,
                                  jax.lax.axis_index(axis) * dbw.shape[0])

        if scored and cfg.use_fused():
            q_tables, scales = resolve_query_tables(tables, q_codes,
                                                    cfg.table_dtype)
            rep = P(None, None)
            if scales is None:
                fn = shard_map(local_fused, mesh=mesh,
                               in_specs=(rep, rep, P(axis, None)),
                               out_specs=(rep, rep), check_rep=False)
                scores, ids = jax.jit(fn)(q_words, q_tables, store.words)
            else:
                fn = shard_map(local_fused, mesh=mesh,
                               in_specs=(rep, rep, P(axis, None), rep),
                               out_specs=(rep, rep), check_rep=False)
                scores, ids = jax.jit(fn)(q_words, q_tables, store.words,
                                          scales)
            ids = jnp.where(jnp.isneginf(scores), -1, ids)
            return ids, rho_scored(tables, ids, scores)
        if scored:
            fn = shard_map(local_scored, mesh=mesh,
                           in_specs=(P(None, None), P(None, None),
                                     P(axis, None)),
                           out_specs=(P(None, None), P(None, None)),
                           check_rep=False)
            scores, ids = jax.jit(fn)(q_words, q_codes, store.words)
            ids = jnp.where(jnp.isneginf(scores), -1, ids)
            return ids, rho_scored(tables, ids, scores)
        fn = shard_map(local, mesh=mesh,
                       in_specs=(P(None, None), P(axis, None)),
                       out_specs=(P(None, None), P(None, None)),
                       check_rep=False)
        vals, ids = jax.jit(fn)(q_words, store.words)
        return jnp.where(vals < 0, -1, ids), self._rho(vals)
