"""Low-overhead metrics registry: counters, gauges, log-bucket histograms.

The substrate every subsystem reports through (``repro.obs``). Three
metric kinds, all host-side and allocation-free on the hot path:

* ``Counter`` — monotone int/float accumulator (``inc``).
* ``Gauge``   — last-write-wins float (``set``).
* ``Histogram`` — fixed log-spaced buckets: ``observe(v)`` is one
  C-level bisect over precomputed bucket edges + one list increment
  (no ``math.log`` on the hot path), and p50/p95/p99 are derivable from
  the bucket counts alone — no samples are ever stored, so memory is
  O(buckets) whatever the traffic. ``exemplar(v, trace_id)`` pins a
  retained flight-recorder trace to the bucket holding ``v``.

A ``MetricsRegistry`` owns one namespace of metrics. There is a
process-global default (``default_registry``) for code that doesn't
thread a registry through, and any component can take an injected
instance instead (the serving layer does). A registry built with
``enabled=False`` hands out shared null metrics whose methods are empty
— the disabled mode costs one method call per site and nothing else
(``tests/test_obs.py`` pins this).
"""
from __future__ import annotations

import math
from bisect import bisect_left

__all__ = ["Counter", "Gauge", "Histogram", "HistogramSpec",
           "MetricsRegistry", "NULL_COUNTER", "NULL_GAUGE",
           "NULL_HISTOGRAM", "default_registry", "set_default_registry"]


class Counter:
    """Monotone accumulator; read ``value`` directly."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n=1):
        """Add ``n`` (default 1) to the counter."""
        self.value += n


class Gauge:
    """Last-write-wins scalar; read ``value`` directly."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v):
        """Overwrite the gauge with ``v``."""
        self.value = float(v)


class HistogramSpec:
    """Fixed log-bucket layout: ``n_buckets`` edges at ``lo * growth^i``.

    Values below ``lo`` land in bucket 0, values at or above ``hi`` in
    the last bucket — the range is clamped, never resized, so two
    histograms with the same spec are always mergeable bucket-by-bucket.
    The default (1 us .. 1000 s, growth 2^1/4) brackets any latency this
    system produces within a ~19% relative error per bucket.
    """

    __slots__ = ("lo", "hi", "growth", "n_buckets", "_log_lo", "_log_g",
                 "_edges")

    def __init__(self, lo: float = 1e-6, hi: float = 1e3,
                 growth: float = 2.0 ** 0.25):
        if not (lo > 0 and hi > lo and growth > 1.0):
            raise ValueError(f"bad histogram spec lo={lo} hi={hi} "
                             f"growth={growth}")
        self.lo = float(lo)
        self.hi = float(hi)
        self.growth = float(growth)
        self._log_lo = math.log(lo)
        self._log_g = math.log(growth)
        self.n_buckets = int(math.ceil(
            (math.log(hi) - self._log_lo) / self._log_g)) + 1
        # precomputed upper edges of buckets 0..n-2: the hot-path lookup
        # is a C-level bisect instead of a math.log per observe. The
        # edge list is one short of n_buckets so any v past the last
        # edge clamps into the final bucket for free.
        self._edges = [math.exp(self._log_lo + self._log_g * (i + 1))
                       for i in range(self.n_buckets - 1)]

    def bucket_index(self, v: float) -> int:
        """Bucket holding ``v`` (clamped to [0, n_buckets))."""
        return bisect_left(self._edges, v)

    def bucket_bounds(self, i: int):
        """(lower, upper) value edges of bucket ``i``; bucket 0's lower
        edge is 0 (it absorbs every underflow)."""
        lower = 0.0 if i == 0 else self.lo * self.growth ** i
        return lower, self.lo * self.growth ** (i + 1)


DEFAULT_SPEC = HistogramSpec()


class Histogram:
    """Log-bucket histogram: O(1) observe, percentiles from counts.

    ``percentile(q)`` returns the upper edge of the bucket where the
    cumulative count first reaches ``q`` — an upper bound on the true
    quantile that is tight to one bucket (a ``growth`` factor);
    ``percentile_bounds(q)`` returns both edges.
    """

    __slots__ = ("name", "spec", "counts", "total", "vmin",
                 "vmax", "_edges", "exemplars")

    def __init__(self, name: str, spec: HistogramSpec = DEFAULT_SPEC):
        self.name = name
        self.spec = spec
        self.counts = [0] * spec.n_buckets
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self._edges = spec._edges         # skip one attr hop per observe
        self.exemplars: dict = {}         # bucket index -> (value, trace_id)

    def observe(self, v: float, _bisect=bisect_left):
        """Record one value: one C-level bisect, one list increment,
        one float add — the whole hot path. The total observation count
        is derived from the buckets at read time (``count``), not
        tracked per observe."""
        self.counts[_bisect(self._edges, v)] += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    @property
    def count(self) -> int:
        """Total observations (bucket sum; O(buckets), read-time only)."""
        return sum(self.counts)

    def exemplar(self, v: float, trace_id):
        """Attach an exemplar: remember ``trace_id`` as *the* retained
        trace for the bucket holding ``v`` (last writer wins). Exported
        as an OpenMetrics ``# {trace_id="..."}`` bucket annotation —
        the link from a histogram tail to a concrete flight-recorder
        trace. Call after ``observe(v)``; off the hot path (only
        tail-retained requests pay it)."""
        self.exemplars[bisect_left(self._edges, v)] = (v, trace_id)

    def percentile_bounds(self, q: float):
        """(lower, upper) edges of the bucket containing quantile ``q``
        in (0, 1]; (nan, nan) when empty."""
        if self.count == 0:
            return math.nan, math.nan
        need = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= need:
                return self.spec.bucket_bounds(i)
        return self.spec.bucket_bounds(self.spec.n_buckets - 1)

    def percentile(self, q: float) -> float:
        """Upper-bound estimate of quantile ``q`` (see class docstring)."""
        return self.percentile_bounds(q)[1]

    @property
    def mean(self) -> float:
        """Exact mean of every observed value (sum is tracked exactly)."""
        return self.total / self.count if self.count else math.nan

    def summary(self) -> dict:
        """count / sum / min / max / mean / p50 / p95 / p99 as a dict."""
        return {"count": self.count, "sum": self.total,
                "min": self.vmin if self.count else math.nan,
                "max": self.vmax if self.count else math.nan,
                "mean": self.mean,
                "p50": self.percentile(0.50),
                "p95": self.percentile(0.95),
                "p99": self.percentile(0.99)}


class _NullCounter(Counter):
    """Shared no-op counter handed out by disabled registries."""

    __slots__ = ()

    def inc(self, n=1):
        """No-op."""


class _NullGauge(Gauge):
    """Shared no-op gauge handed out by disabled registries."""

    __slots__ = ()

    def set(self, v):
        """No-op."""


class _NullHistogram(Histogram):
    """Shared no-op histogram handed out by disabled registries."""

    __slots__ = ()

    def observe(self, v):
        """No-op."""

    def exemplar(self, v, trace_id):
        """No-op."""


NULL_COUNTER = _NullCounter("null")
NULL_GAUGE = _NullGauge("null")
NULL_HISTOGRAM = _NullHistogram("null")


class MetricsRegistry:
    """One namespace of metrics; get-or-create by dotted name.

    ``enabled=False`` makes every accessor return the shared null
    metrics (their mutators are empty methods), so an instrumented
    call site costs one attribute lookup + one no-op call — cheap
    enough to leave in the hottest host loops.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        """Get-or-create the counter ``name``."""
        if not self.enabled:
            return NULL_COUNTER
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        """Get-or-create the gauge ``name``."""
        if not self.enabled:
            return NULL_GAUGE
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str,
                  spec: HistogramSpec = DEFAULT_SPEC) -> Histogram:
        """Get-or-create the histogram ``name`` (spec fixed at birth)."""
        if not self.enabled:
            return NULL_HISTOGRAM
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name, spec)
        return h

    def reset(self):
        """Drop every metric (counts and registrations)."""
        self.counters.clear()
        self.gauges.clear()
        self.histograms.clear()

    def snapshot(self) -> dict:
        """Plain-dict view: {counters, gauges, histograms(summaries)}."""
        return {
            "counters": {n: c.value for n, c in self.counters.items()},
            "gauges": {n: g.value for n, g in self.gauges.items()},
            "histograms": {n: h.summary()
                           for n, h in self.histograms.items()},
        }


_DEFAULT = MetricsRegistry(enabled=True)


def default_registry() -> MetricsRegistry:
    """The process-global registry (enabled by default)."""
    return _DEFAULT


def set_default_registry(reg: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-global registry; returns the previous one."""
    global _DEFAULT
    prev = _DEFAULT
    _DEFAULT = reg
    return prev
