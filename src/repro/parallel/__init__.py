from repro.parallel.sharding import ShardingRules, DEFAULT_RULES, zero_shard_spec  # noqa: F401
