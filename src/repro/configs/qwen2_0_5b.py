"""qwen2-0.5b [dense]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936 — GQA, QKV bias. [arXiv:2407.10671; hf]"""
from dataclasses import replace

from repro.models.lm import ModelConfig

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]  # pure full attention


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b", family="dense",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
        vocab_size=151936, qkv_bias=True, rope_theta=1e6,
        tie_embeddings=True, norm_eps=1e-6,
    )


def smoke_config() -> ModelConfig:
    return replace(config(), n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                   head_dim=16, d_ff=128, vocab_size=256, loss_chunk=16,
                   chunk_kv=32, chunk_q=16)
