"""Nestable tracing spans with device-sync-correct timing.

The timing trap this module exists to close: jax dispatch is async, so
``t1 - t0`` around a device call measures *submission*, not execution —
exactly the bug that produced a negative (clamped-to-zero) re-rank
overhead in ``BENCH_rank.json``. A span therefore closes in one of two
explicitly-labelled states:

* **device-synced** — the code inside called ``sp.sync(value)`` (a
  ``jax.block_until_ready`` that returns its argument), so the span's
  duration covers the device work that produced ``value``;
* **async** — no sync happened before close (either ``sync=False`` was
  requested, or the caller simply never synced). The span is marked
  ``"sync": "async"`` in the trace.

That labelling is the sync-boundary invariant documented in
``docs/ARCHITECTURE.md``: a span that closes without a device sync is
*always* marked async — there is no state in which an unsynced duration
masquerades as an execution time.

Tracing is globally opt-in: ``with Tracer() as tr`` installs the tracer,
and while none is installed ``span(...)`` returns a shared no-op context
manager (near-zero cost — the hot path keeps its spans). Finished traces
export to Chrome-trace / Perfetto JSON (``Tracer.dump``): load the file
in ``chrome://tracing`` or https://ui.perfetto.dev to see a whole
ingest→search→compact run as a flame view.
"""
from __future__ import annotations

import json
import threading
import time

import jax

__all__ = ["Span", "Tracer", "span", "tracing_active", "active_tracer",
           "no_tracing"]

_ACTIVE: "Tracer | None" = None


def tracing_active() -> bool:
    """Whether a tracer is currently installed (spans are recording)."""
    return _ACTIVE is not None


def active_tracer() -> "Tracer | None":
    """The installed tracer, or None."""
    return _ACTIVE


class Span:
    """One live span; use via ``with span("name") as sp``.

    Call ``sp.sync(value)`` on the device results produced inside the
    span — it blocks until they are ready (so the closing timestamp is
    execution-true) and returns them. Extra attributes land in the
    Chrome-trace ``args`` via ``sp.set(key=...)`` or the ``span(...)``
    kwargs.
    """

    __slots__ = ("tracer", "name", "args", "sync_wanted", "t0", "_synced")

    def __init__(self, tracer: "Tracer", name: str, sync_wanted: bool,
                 args: dict):
        self.tracer = tracer
        self.name = name
        self.args = args
        self.sync_wanted = sync_wanted
        self.t0 = 0.0
        self._synced = False

    def sync(self, value):
        """Block until ``value`` (any pytree of arrays) is ready; marks
        the span device-synced and returns ``value``."""
        jax.block_until_ready(value)
        self._synced = True
        return value

    def set(self, **attrs):
        """Attach attributes to the span's trace ``args``."""
        self.args.update(attrs)

    def __enter__(self) -> "Span":
        self.tracer._push(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter()
        self.args["sync"] = "device" if self._synced else "async"
        if exc_type is not None:
            self.args["error"] = exc_type.__name__
        self.tracer._pop(self, t1)
        return False                      # never swallow exceptions


class _NullSpan:
    """Shared no-op span returned while no tracer is installed; its
    ``sync`` is a passthrough (no block), so disabled-mode tracing adds
    neither time nor device barriers."""

    __slots__ = ()

    def sync(self, value):
        """Passthrough: no block, no recording."""
        return value

    def set(self, **attrs):
        """No-op."""

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_SPAN = _NullSpan()


def span(name: str, sync: bool = True, **attrs):
    """Open a span on the installed tracer (no-op when none is active).

    ``sync=True`` declares the span *should* close device-synced — the
    body is expected to route its device results through ``sp.sync``;
    if it never does, the span is recorded but labelled async.
    ``sync=False`` declares an async span up front (e.g. enqueue-only
    work). Returns a context manager either way.
    """
    tr = _ACTIVE
    if tr is None:
        return _NULL_SPAN
    return Span(tr, name, sync, dict(attrs))


class _NoTracing:
    """Suspends the installed tracer for the duration of a block."""

    __slots__ = ("_prev",)

    def __enter__(self):
        global _ACTIVE
        self._prev = _ACTIVE
        _ACTIVE = None
        return self

    def __exit__(self, exc_type, exc, tb):
        global _ACTIVE
        _ACTIVE = self._prev
        return False


def no_tracing() -> _NoTracing:
    """Context manager suspending span recording inside its block —
    for sections too hot to trace, or for measuring the no-tracer span
    cost itself while a tracer happens to be installed."""
    return _NoTracing()


class Tracer:
    """Span collector + Chrome-trace exporter; ``with Tracer() as tr``
    installs it globally for the duration of the block.

    Spans nest per-thread (a stack keyed on thread id); nesting in the
    exported trace is carried by timestamp containment on one track,
    which is exactly how chrome://tracing / Perfetto build flames.
    """

    def __init__(self):
        self.events: list[dict] = []      # finished spans, close order
        self._stacks: dict[int, list] = {}
        self._tids: dict[int, int] = {}
        self._t0 = time.perf_counter()
        self._prev = None

    # -- span bookkeeping (called by Span) -----------------------------------
    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            tid = self._tids[ident] = len(self._tids)
        return tid

    def _push(self, sp: Span):
        self._stacks.setdefault(threading.get_ident(), []).append(sp)

    def _pop(self, sp: Span, t1: float):
        stack = self._stacks[threading.get_ident()]
        # exception-safe: unwind past any inner spans abandoned by a raise
        while stack and stack[-1] is not sp:
            stack.pop()
        if stack:
            stack.pop()
        self.events.append({
            "name": sp.name, "ts": sp.t0 - self._t0,
            "dur": t1 - sp.t0, "tid": self._tid(), "depth": len(stack),
            "args": sp.args})

    def depth(self) -> int:
        """Current nesting depth on the calling thread."""
        return len(self._stacks.get(threading.get_ident(), ()))

    # -- install / uninstall -------------------------------------------------
    def __enter__(self) -> "Tracer":
        global _ACTIVE
        self._prev = _ACTIVE
        _ACTIVE = self
        return self

    def __exit__(self, exc_type, exc, tb):
        global _ACTIVE
        _ACTIVE = self._prev
        return False

    # -- queries -------------------------------------------------------------
    def durations(self, name: str) -> list:
        """Seconds of every finished span called ``name``."""
        return [e["dur"] for e in self.events if e["name"] == name]

    def total(self, name: str) -> float:
        """Summed seconds across every finished span called ``name``."""
        return sum(self.durations(name))

    # -- export --------------------------------------------------------------
    def to_chrome(self) -> dict:
        """Chrome-trace JSON object (``traceEvents`` complete events,
        timestamps in microseconds) — loadable by chrome://tracing and
        Perfetto."""
        events = [{
            "name": e["name"], "ph": "X", "pid": 0, "tid": e["tid"],
            "ts": round(e["ts"] * 1e6, 3),
            "dur": round(e["dur"] * 1e6, 3),
            "args": e["args"],
        } for e in self.events]
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def dump(self, path: str) -> str:
        """Write the Chrome-trace JSON to ``path``; returns ``path``."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path
