"""ANN engine benchmark: batched packed-code search vs host-side loops.

Workload: clustered unit vectors (the paper §1.1 near-duplicate regime —
each query has ~10 true neighbors at rho ~0.9) at 1k queries x 100k
corpus when run directly (``python benchmarks/ann_bench.py``); smaller
via the run.py harness' quick mode.

Measured:
  * engine exact     — batched streaming packed-collision top-k
  * engine lsh       — batched banded-candidate search with multi-probe
  * host wrapper     — ``LSHIndex.query`` loop (the repo's one-query-at-
                       a-time compat path; subsampled and extrapolated)
  * host dict        — numpy re-creation of the seed's Python-dict LSH
                       index (band-hash dicts + per-query re-rank), the
                       architecture the engine replaces

Reports QPS for each, recall@10 of lsh vs exact re-rank at matched
settings, and emits one ``BENCH {json}`` line plus a CSV.
"""
import json
import os
import sys
import time
from collections import defaultdict

import numpy as np
import jax
import jax.numpy as jnp

if __package__ in (None, ""):              # direct `python benchmarks/ann_bench.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

from benchmarks._util import write_csv
from repro.ann import AnnEngine, BandSpec
from repro.core.lsh import LSHIndex
from repro.core.sketch import CodedRandomProjection, SketchConfig

N_TABLES, BAND_WIDTH, N_PROBES, TOP_K = 32, 4, 1, 10


def _unit(x):
    return x / jnp.linalg.norm(x, axis=-1, keepdims=True)


def make_workload(key, d, n_clusters, per, nq, rho_m=0.95, rho_q=0.95):
    """Clustered corpus [n_clusters*per, d] + queries near nq centers."""
    kc, km, kq = jax.random.split(key, 3)
    centers = _unit(jax.random.normal(kc, (n_clusters, d)))
    noise = _unit(jax.random.normal(km, (n_clusters, per, d)))
    corpus = _unit(rho_m * centers[:, None, :]
                   + np.sqrt(1 - rho_m ** 2) * noise).reshape(-1, d)
    qidx = jax.random.permutation(kq, n_clusters)[:nq]
    qn = _unit(jax.random.normal(jax.random.fold_in(kq, 1), (nq, d)))
    queries = _unit(rho_q * centers[qidx] + np.sqrt(1 - rho_q ** 2) * qn)
    return corpus, queries


_MIX = np.uint64(0x9E3779B97F4A7C15)


class SeedDictIndex:
    """The seed repo's host-side LSH index, re-created as the baseline:
    numpy band hashes into Python dicts, one query at a time, candidate
    union re-ranked on unpacked codes (numpy re-rank — at least as fast
    as the seed's per-query jnp dispatch)."""

    def __init__(self, sketcher, codes, n_tables, band_width):
        self.sketcher = sketcher
        self.n_tables, self.band_width = n_tables, band_width
        self.codes = np.asarray(codes)
        self.tables = [defaultdict(list) for _ in range(n_tables)]
        for t in range(n_tables):
            band = self.codes[:, t * band_width:(t + 1) * band_width]
            for i, h in enumerate(self._hash(band)):
                self.tables[t][int(h)].append(i)

    @staticmethod
    def _hash(codes):
        h = np.zeros(codes.shape[0], dtype=np.uint64)
        for j in range(codes.shape[1]):
            h = (h ^ (codes[:, j].astype(np.uint64) + _MIX)) \
                * np.uint64(0xBF58476D1CE4E5B9)
            h ^= h >> np.uint64(31)
        return h

    def query(self, q_codes, top):
        cand = set()
        bw = self.band_width
        for t in range(self.n_tables):
            band = q_codes[None, t * bw:(t + 1) * bw]
            cand.update(self.tables[t].get(int(self._hash(band)[0]), ()))
        if not cand:
            return []
        idx = np.fromiter(cand, dtype=np.int64, count=len(cand))
        counts = (self.codes[idx] == q_codes[None, :]).sum(axis=1)
        order = np.argsort(-counts)[:top]
        return idx[order]


def _timed_batch(fn, repeat=2):
    fn()                                   # warm the jit caches
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return out, best


def _bench(d, n_clusters, per, nq, host_queries):
    key = jax.random.PRNGKey(0)
    corpus, queries = make_workload(key, d, n_clusters, per, nq)
    n = corpus.shape[0]
    crp = CodedRandomProjection(SketchConfig(k=128, scheme="2bit", w=0.75), d)
    engine = AnnEngine.build(
        crp, corpus, BandSpec(n_tables=N_TABLES, band_width=BAND_WIDTH))

    (ids_e, _), t_exact = _timed_batch(
        lambda: engine.search(queries, TOP_K, mode="exact"))
    (ids_l, _), t_lsh = _timed_batch(
        lambda: engine.search(queries, TOP_K, mode="lsh", n_probes=N_PROBES))
    ids_e, ids_l = np.asarray(ids_e), np.asarray(ids_l)
    recall = float(np.mean([len(set(a) & set(b)) / TOP_K
                            for a, b in zip(ids_l, ids_e)]))

    # host-side one-query-at-a-time baselines (subsampled + extrapolated)
    hq = min(host_queries, nq)
    wrapper = LSHIndex(crp, n_tables=N_TABLES, band_width=BAND_WIDTH)
    wrapper._engine = engine               # share the already-built index
    wrapper.query(np.asarray(queries[0]), top=TOP_K)       # warm
    t0 = time.perf_counter()
    for i in range(hq):
        wrapper.query(np.asarray(queries[i]), top=TOP_K)
    t_wrap = (time.perf_counter() - t0) / hq

    q_codes = np.asarray(engine.encode_queries(queries[:hq]))
    dict_index = SeedDictIndex(crp, engine.store.unpack(),
                               N_TABLES, BAND_WIDTH)
    dict_index.query(q_codes[0], TOP_K)                     # warm
    t0 = time.perf_counter()
    for i in range(hq):
        dict_index.query(q_codes[i], TOP_K)
    t_dict = (time.perf_counter() - t0) / hq

    return {
        "corpus": n, "queries": nq, "k": 128, "bits": 2,
        "qps_exact": nq / t_exact, "qps_lsh": nq / t_lsh,
        "qps_host_wrapper": 1.0 / t_wrap, "qps_host_dict": 1.0 / t_dict,
        "recall_at_10": recall,
        "speedup_exact_vs_wrapper": (nq / t_exact) * t_wrap,
        "speedup_lsh_vs_wrapper": (nq / t_lsh) * t_wrap,
        "speedup_exact_vs_dict": (nq / t_exact) * t_dict,
    }


def _rows(r):
    return [
        ("ann_exact_batched", 1e6 * r["queries"] / r["qps_exact"] / r["queries"],
         f"qps={r['qps_exact']:.0f}"),
        ("ann_lsh_batched", 1e6 / r["qps_lsh"],
         f"qps={r['qps_lsh']:.0f} recall@10={r['recall_at_10']:.3f}"),
        ("ann_host_wrapper_loop", 1e6 / r["qps_host_wrapper"],
         f"qps={r['qps_host_wrapper']:.1f}"),
        ("ann_host_dict_loop", 1e6 / r["qps_host_dict"],
         f"qps={r['qps_host_dict']:.1f}"),
    ]


def run(quick: bool = True):
    """run.py contract: (name, us_per_query, derived) rows."""
    r = _bench(d=64, n_clusters=2000 if quick else 10_000, per=10,
               nq=200 if quick else 1000, host_queries=8)
    rows = _rows(r)
    write_csv("ann_bench", ["name", "us_per_query", "derived"], rows)
    return rows


def main():
    r = _bench(d=64, n_clusters=10_000, per=10, nq=1000, host_queries=8)
    write_csv("ann_bench", ["name", "us_per_query", "derived"], _rows(r))
    print("BENCH " + json.dumps(r))
    print(f"\nbatched packed search: exact {r['qps_exact']:.0f} qps, "
          f"lsh {r['qps_lsh']:.0f} qps (recall@10 {r['recall_at_10']:.3f} "
          f"vs exact re-rank)")
    print(f"host LSHIndex.query loop: {r['qps_host_wrapper']:.1f} qps -> "
          f"{r['speedup_exact_vs_wrapper']:.0f}x (exact) / "
          f"{r['speedup_lsh_vs_wrapper']:.0f}x (lsh) speedup")
    print(f"seed-style dict index:    {r['qps_host_dict']:.1f} qps -> "
          f"{r['speedup_exact_vs_dict']:.1f}x (exact); at k=128 the packed "
          f"brute pass is the CPU-fast path, banding pays off at larger k/N")


if __name__ == "__main__":
    main()
