"""Batched collision-count Pallas kernel (similarity-search inner loop).

counts[q, n] = #{ j : codes_q[q, j] == codes_db[n, j] } — the sufficient
statistic for the paper's rho estimator, computed for all (query, corpus)
pairs. Equality-compare + accumulate is VPU work; we tile (bq, bn, bk)
with an int32 VMEM accumulator, streaming the K axis on the minor grid
dimension exactly like a matmul reduction.

Padded K entries are sentinel-masked by the wrapper (-1 vs -2) so they
never collide.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["collision_counts_pallas"]


def _kernel(q_ref, db_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]          # [bq, bk]
    db = db_ref[...]        # [bn, bk]
    eq = (q[:, None, :] == db[None, :, :]).astype(jnp.int32)
    acc_ref[...] += jnp.sum(eq, axis=-1)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_n", "block_k", "interpret"))
def collision_counts_pallas(codes_q, codes_db, *, block_q: int = 128,
                            block_n: int = 128, block_k: int = 512,
                            interpret: bool = False):
    """codes_q int32 [Q, K], codes_db int32 [N, K] -> int32 [Q, N]."""
    qn, k = codes_q.shape
    n, k2 = codes_db.shape
    assert k == k2, (codes_q.shape, codes_db.shape)

    def pad(x, mult, axis, fill):
        p = (-x.shape[axis]) % mult
        if p == 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, p)
        return jnp.pad(x, widths, constant_values=fill)

    # sentinels differ so padded K positions never match
    qp = pad(pad(codes_q, block_q, 0, -2), block_k, 1, -2)
    dbp = pad(pad(codes_db, block_n, 0, -1), block_k, 1, -1)
    qm, kp = qp.shape
    nm = dbp.shape[0]
    grid = (qm // block_q, nm // block_n, kp // block_k)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, block_k), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_n, block_k), lambda i, j, s: (j, s)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qm, nm), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_q, block_n), jnp.int32)],
        interpret=interpret,
    )(qp, dbp)
    return out[:qn, :n]
