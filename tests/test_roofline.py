"""Roofline helpers: HLO collective parsing + term math."""
from repro.launch.roofline import (HW, collective_bytes, roofline_terms,
                                   _shape_bytes)

HLO = """
ENTRY %main {
  %ag = bf16[8,128,256]{2,1,0} all-gather(%x), replica_groups=...
  %ar = f32[1024,1024]{1,0} all-reduce(%y), to_apply=%add
  %ars = f32[64,64]{1,0} all-reduce-start(%z)
  %rs = bf16[2,4]{1,0} reduce-scatter(%w)
  %a2a = bf16[16,8,320,4096]{3,2,1,0} all-to-all(%v)
  %cp = u32[128]{0} collective-permute(%u)
  %dot = f32[128,128]{1,0} dot(%a, %b)
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[8,128,256]") == 8 * 128 * 256 * 2
    assert _shape_bytes("f32[1024,1024]") == 1024 * 1024 * 4
    assert _shape_bytes("(f32[2,2], s32[3])") == 16 + 12


def test_collective_bytes_parses_all_kinds():
    out = collective_bytes(HLO)
    assert out["all-gather"] == 8 * 128 * 256 * 2
    # all-reduce counted twice (ring reduce+broadcast), includes -start
    assert out["all-reduce"] == 2 * (1024 * 1024 * 4 + 64 * 64 * 4)
    assert out["reduce-scatter"] == 2 * 4 * 2
    assert out["all-to-all"] == 16 * 8 * 320 * 4096 * 2
    assert out["collective-permute"] == 128 * 4
    assert out["count"] == 6
    assert out["total"] == sum(out[k] for k in (
        "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
        "collective-permute"))


def test_roofline_terms_dominance():
    hw = HW()
    t = roofline_terms(197e12, 0.0, 0.0, hw)   # 1s compute, nothing else
    assert t["dominant"] == "compute" and abs(t["t_compute_s"] - 1.0) < 1e-9
    assert t["roofline_fraction"] == 1.0
    t = roofline_terms(197e10, 819e9, 0.0, hw)  # memory 1s vs compute 10ms
    assert t["dominant"] == "memory"
    assert abs(t["roofline_fraction"] - 0.01) < 1e-6
    t = roofline_terms(0.0, 0.0, 50e9, hw)
    assert t["dominant"] == "collective"


def test_model_flops_conventions():
    from repro.launch.roofline import model_flops, active_params
    from repro import configs as C
    cfg = C.get_config("olmoe-1b-7b")
    act, tot = active_params(cfg)
    assert act < tot  # MoE: only top-k experts active
    assert model_flops(cfg, "train", 2, 128) == 6.0 * act * 256
    assert model_flops(cfg, "decode", 4, 999) == 2.0 * act * 4


def test_kernelstats_roofline_agrees_with_roofline_terms():
    """The live roofline repro.obs.kernelstats builds must use the same
    compute/memory term math as the static launch-planning model."""
    from repro.obs import KernelStats
    ks = KernelStats()
    ks.record("coded_project", m=256, d=64, k=64)
    hw = HW()
    row = ks.roofline_table(hw)["coded_project"]
    terms = roofline_terms(row["flops"], row["hbm_bytes"], 0.0, hw)
    assert row["t_compute_s"] == terms["t_compute_s"]
    assert row["t_memory_s"] == terms["t_memory_s"]
    assert row["bound"] == terms["dominant"]
