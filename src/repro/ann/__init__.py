"""Device-resident batched approximate-near-neighbor engine.

store   — ``CodeStore``: immutable bit-packed corpus in HBM (add/merge,
          row-shardable across a mesh)
bands   — batched LSH band hashing with prefix-nested multi-probe
engine  — ``AnnEngine``: fused project→code→pack queries, exact and
          LSH-banded candidate search, multi-device top-k merge;
          ``QueryCoder``/``merge_topk`` shared with the mutable layer;
          ``scored=True`` adds the two-stage LUT re-rank (``repro.rank``)
(mutable lifecycle over this layer: ``repro.index``; serving
front-end: ``repro.serve.ann_service``; the packed corpus also feeds
classifier training directly — ``repro.learn.fit_store`` batches off a
``CodeStore`` without unpacking a single code)
"""
from repro.ann.bands import BandSpec, band_hashes, probe_hashes  # noqa: F401
from repro.ann.engine import AnnEngine, SearchConfig  # noqa: F401
from repro.ann.store import CodeStore  # noqa: F401
