"""Core library: the paper's contribution — coding schemes for random
projections, their collision probabilities / estimator variances, sketch
pipeline, LSH, SVM-on-codes, and the coded-sketch gradient compressor.
"""
from repro.core.schemes import (  # noqa: F401
    CodeSpec, spec_for, encode, encode_uniform, encode_offset, encode_2bit,
    encode_sign, sample_offsets, collision_fraction,
)
from repro.core.probabilities import (  # noqa: F401
    collision_prob, collision_prob_uniform, collision_prob_offset,
    collision_prob_2bit, collision_prob_sign, q_region, SCHEMES,
)
from repro.core.variance import (  # noqa: F401
    variance_factor, dP_drho,
)
from repro.core.estimators import (  # noqa: F401
    CollisionEstimator, MleRhoEstimator, cell_probs, mle_rho_2bit,
    region_bounds, rho_from_sign_collision,
)
from repro.core.optimal import optimal_w  # noqa: F401
from repro.core.packing import pack_codes, unpack_codes  # noqa: F401
from repro.core.sketch import SketchConfig, CodedRandomProjection  # noqa: F401
