"""Size-tiered compaction for the segment log.

Steady-state churn leaves the log as many tail-sized sealed segments with
a growing tombstone fraction: each query pays one kernel launch per
segment, and dead rows still burn XOR/popcount bandwidth. Compaction
rewrites *adjacent runs* of sealed segments into one dense segment —
adjacency preserves the log's iteration order, which is the search
tie-break order, so compaction is invisible to results (the bit-exactness
contract ``tests/test_index.py`` enforces).

Policy (size-tiered, greedy over the log):

* accumulate adjacent sealed segments while the merged output stays under
  ``target_rows`` live rows;
* rewrite a run when it has more than one segment (merge small segments)
  or when its single segment carries more than ``max_dead_fraction``
  tombstones (reclaim space);
* the mutable tail is never touched.

The rewrite gathers live rows on device (O(run) copy — the cost is
proportional to what is rewritten, never the whole corpus) and emits a
fully-live segment, so compaction both caps segment count and drops
tombstoned rows. ``compact`` mutates the store in place and returns a
stats dict.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

from repro.index.segment_log import Segment, SegmentLogStore, \
    _np_pack_bitmask
from repro.obs import span

__all__ = ["CompactionPolicy", "plan_compaction", "compact"]


@dataclass(frozen=True)
class CompactionPolicy:
    target_rows: int = 4096        # max live rows in a merged segment
    max_dead_fraction: float = 0.25  # lone segment rewritten above this


def _wants_rewrite(run: list[Segment], policy: CompactionPolicy) -> bool:
    if len(run) > 1:
        return True
    seg = run[0]
    dead = seg.length - seg.live
    return seg.length > 0 and dead / seg.length > policy.max_dead_fraction


def plan_compaction(store: SegmentLogStore,
                    policy: CompactionPolicy = CompactionPolicy()):
    """Greedy adjacent runs of sealed-segment indices worth rewriting."""
    runs, cur, cur_live = [], [], 0
    for i, seg in enumerate(store.sealed):
        if cur and cur_live + seg.live > policy.target_rows:
            if _wants_rewrite([store.sealed[j] for j in cur], policy):
                runs.append(cur)
            cur, cur_live = [], 0
        cur.append(i)
        cur_live += seg.live
    if cur and _wants_rewrite([store.sealed[j] for j in cur], policy):
        runs.append(cur)
    return runs


def _rewrite_run(store: SegmentLogStore, run: list[Segment]) -> Segment:
    """Gather the run's live rows into one dense, fully-live segment."""
    rows_per = [seg.live_rows() for seg in run]
    n_new = int(sum(r.size for r in rows_per))
    words = jnp.concatenate(
        [jnp.take(seg.words, jnp.asarray(rows), axis=0)
         for seg, rows in zip(run, rows_per) if rows.size]) \
        if n_new else jnp.zeros((0, store.n_words), jnp.uint32)
    hashes = None
    if store.band_spec is not None:
        hashes = jnp.concatenate(
            [jnp.take(seg.hashes, jnp.asarray(rows), axis=0)
             for seg, rows in zip(run, rows_per) if rows.size]) \
            if n_new else jnp.zeros((0, store.band_spec.n_tables),
                                    jnp.uint32)
    ids = (np.concatenate([seg.ids[rows]
                           for seg, rows in zip(run, rows_per)])
           if n_new else np.zeros(0, np.int64))
    valid = _np_pack_bitmask(np.ones(n_new, bool)) if n_new \
        else np.zeros(0, np.uint32)
    return Segment(words=words, hashes=hashes, ids=ids, valid=valid,
                   live=n_new, length=n_new)


def compact(store: SegmentLogStore,
            policy: CompactionPolicy = CompactionPolicy()) -> dict:
    """Rewrite planned runs in place. Iteration order of live rows — and
    therefore every search result — is unchanged. Reports through the
    store's ``repro.obs`` registry (``index.compactions`` /
    ``index.compact_rows_dropped`` / ``index.compact_bytes_copied``) and
    opens an ``index.compact`` span when tracing."""
    with span("index.compact") as sp:
        runs = plan_compaction(store, policy)
        before = len(store.sealed)
        dropped = 0
        copied_bytes = 0
        run_at = {run[0]: run for run in runs}
        in_run = {i for run in runs for i in run}
        new_sealed: list[Segment] = []
        for i, seg in enumerate(store.sealed):
            if i not in in_run:
                new_sealed.append(seg)
                continue
            if i not in run_at:
                continue            # consumed by the run starting earlier
            run = [store.sealed[j] for j in run_at[i]]
            merged = _rewrite_run(store, run)
            sp.sync(merged.words)     # Segment is not a pytree
            dropped += sum(s.length for s in run) - merged.length
            copied_bytes += merged.words.size * 4
            for row in range(merged.length):
                store._by_id[int(merged.ids[row])] = (merged, row)
            if merged.length:       # an all-dead run just vanishes
                new_sealed.append(merged)
        store.sealed = new_sealed
        if runs:
            store.generation += 1
            # external ids survive a rewrite, so listeners (e.g. the
            # shadow reservoir) only need to know membership was churned
            store._notify("compact", None)
        reg = store.registry
        reg.counter("index.compactions").inc()
        reg.counter("index.compact_rows_dropped").inc(dropped)
        reg.counter("index.compact_bytes_copied").inc(copied_bytes)
        store._update_gauges()
        sp.set(runs=len(runs), rows_dropped=dropped)
    return {"runs": len(runs), "segments_before": before,
            "segments_after": len(store.sealed),
            "rows_dropped": dropped, "bytes_copied": copied_bytes}
