"""Docs lint: public-API docstrings + no dead paths in the docs.

Three checks, each tripping a nonzero exit:

1. every public symbol (module, class, function, method, property) in
   the ``PACKAGES`` list (``repro.ann`` through ``repro.kernels``)
   carries a docstring — the subsystems' shape/dtype contracts live
   there;
2. every repo path referenced from ``README.md`` and ``docs/*.md``
   (markdown links and backticked tokens that look like paths) exists;
3. every module of the packages in ``MENTION_PACKAGES`` (``repro.obs``
   — the layer whose whole job is being visible — and
   ``repro.kernels`` — where every hot loop lives) is mentioned by
   name somewhere in the docs, so a new monitor or kernel family
   cannot land documentation-silent.

Run as ``python benchmarks/run.py lint``, ``python
scripts/check_docs.py``, or through ``tests/test_docs_lint.py``.
"""
from __future__ import annotations

import importlib
import inspect
import os
import pkgutil
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
PACKAGES = ("repro.ann", "repro.index", "repro.rank", "repro.learn",
            "repro.encode", "repro.obs", "repro.kernels")
MENTION_PACKAGES = ("repro.obs", "repro.kernels")
DOC_FILES = ["README.md"]
DOC_DIRS = ["docs"]

_PATH_EXTS = (".py", ".md", ".json", ".ini", ".csv", ".txt")
_PATH_ROOTS = ("src", "docs", "benchmarks", "tests", "scripts", "examples")
_TOKEN = re.compile(r"`([A-Za-z0-9_\-./]+)`")
_LINK = re.compile(r"\[[^\]]*\]\(([^)#]+)\)")


def _iter_public_symbols(mod):
    """Yield (qualname, object) for the module's public API: __all__ if
    declared, else module-level defs; plus public methods/properties
    declared directly on public classes."""
    names = getattr(mod, "__all__", None)
    if names is None:
        names = [n for n in vars(mod) if not n.startswith("_")
                 and getattr(getattr(mod, n), "__module__", None)
                 == mod.__name__]
    for name in names:
        obj = getattr(mod, name)
        if not (inspect.isclass(obj) or inspect.isfunction(obj)):
            continue
        yield f"{mod.__name__}.{name}", obj
        if inspect.isclass(obj):
            for mname, member in vars(obj).items():
                if mname.startswith("_"):
                    continue
                if isinstance(member, property):
                    yield f"{mod.__name__}.{name}.{mname}", member.fget
                elif inspect.isfunction(member):
                    yield f"{mod.__name__}.{name}.{mname}", member
                elif isinstance(member, (classmethod, staticmethod)):
                    yield f"{mod.__name__}.{name}.{mname}", member.__func__


def check_docstrings() -> list:
    """Missing-docstring report: list of offending qualnames."""
    missing = []
    for pkg_name in PACKAGES:
        pkg = importlib.import_module(pkg_name)
        if not (pkg.__doc__ or "").strip():
            missing.append(pkg_name)
        mods = [pkg] + [
            importlib.import_module(f"{pkg_name}.{m.name}")
            for m in pkgutil.iter_modules(pkg.__path__)]
        for mod in mods:
            if not (mod.__doc__ or "").strip():
                missing.append(mod.__name__)
            for qualname, obj in _iter_public_symbols(mod):
                if not (getattr(obj, "__doc__", None) or "").strip():
                    missing.append(qualname)
    return sorted(set(missing))


def _looks_like_path(token: str) -> bool:
    if token.startswith(("http://", "https://")):
        return False
    if token.endswith(_PATH_EXTS):
        return True
    head = token.split("/", 1)[0]
    return "/" in token and head in _PATH_ROOTS


def _repo_basenames() -> set:
    """All file basenames under the repo (for bare-filename refs)."""
    names = set()
    for dirpath, dirnames, filenames in os.walk(ROOT):
        dirnames[:] = [d for d in dirnames
                       if d not in (".git", "__pycache__", ".pytest_cache")]
        names.update(filenames)
    return names


def check_doc_paths() -> list:
    """Dead-path report: list of '<doc>: <path>' strings.

    A reference resolves if it exists relative to the repo root, the
    doc's own directory (how markdown links render), ``src/`` or
    ``src/repro/`` (how module-relative prose reads). Bare filenames
    (no '/') resolve if any file in the repo has that basename.
    """
    docs = [f for f in DOC_FILES
            if os.path.exists(os.path.join(ROOT, f))]
    for d in DOC_DIRS:
        dpath = os.path.join(ROOT, d)
        if os.path.isdir(dpath):
            docs += [os.path.join(d, f) for f in sorted(os.listdir(dpath))
                     if f.endswith(".md")]
    basenames = _repo_basenames()
    dead = []
    for doc in docs:
        text = open(os.path.join(ROOT, doc)).read()
        doc_dir = os.path.dirname(os.path.join(ROOT, doc))
        bases = [ROOT, doc_dir, os.path.join(ROOT, "src"),
                 os.path.join(ROOT, "src", "repro")]
        refs = set(_TOKEN.findall(text)) | set(_LINK.findall(text))
        for token in sorted(refs):
            token = token.strip()
            if not _looks_like_path(token):
                continue
            if "/" not in token and token in basenames:
                continue
            if any(os.path.exists(os.path.join(b, token.rstrip("/")))
                   for b in bases):
                continue
            dead.append(f"{doc}: {token}")
    return dead


def _doc_texts() -> str:
    """README + docs/*.md concatenated (the mention corpus)."""
    docs = [f for f in DOC_FILES if os.path.exists(os.path.join(ROOT, f))]
    for d in DOC_DIRS:
        dpath = os.path.join(ROOT, d)
        if os.path.isdir(dpath):
            docs += [os.path.join(d, f) for f in sorted(os.listdir(dpath))
                     if f.endswith(".md")]
    return "\n".join(open(os.path.join(ROOT, doc)).read() for doc in docs)


def check_module_mentions() -> list:
    """Unmentioned-module report for MENTION_PACKAGES: each module must
    appear in the docs as ``pkg.mod``, ``pkg/mod.py`` or a backticked
    ``mod.py`` — a subsystem file nobody can find is dead weight."""
    text = _doc_texts()
    unmentioned = []
    for pkg_name in MENTION_PACKAGES:
        pkg = importlib.import_module(pkg_name)
        short = pkg_name.rsplit(".", 1)[-1]
        for m in pkgutil.iter_modules(pkg.__path__):
            forms = (f"{pkg_name}.{m.name}", f"{short}/{m.name}.py",
                     f"`{m.name}.py`")
            if not any(f in text for f in forms):
                unmentioned.append(f"{pkg_name}.{m.name}")
    return sorted(unmentioned)


def main() -> int:
    """Run all three checks; print a report and return the exit code."""
    sys.path.insert(0, os.path.join(ROOT, "src"))
    missing = check_docstrings()
    dead = check_doc_paths()
    silent = check_module_mentions()
    for name in missing:
        print(f"MISSING DOCSTRING  {name}")
    for ref in dead:
        print(f"DEAD PATH          {ref}")
    for name in silent:
        print(f"UNDOCUMENTED MODULE  {name}")
    print(f"check_docs: {len(missing)} missing docstrings, "
          f"{len(dead)} dead doc paths, {len(silent)} unmentioned "
          f"modules across {PACKAGES}")
    return 1 if (missing or dead or silent) else 0


if __name__ == "__main__":
    raise SystemExit(main())
