"""Microbatching front-end for the ANN engine (serving-layer component).

Mirrors ``serve.serving``'s split between jit'd device steps and a thin
host loop: individual queries arrive via ``submit`` (a ticket comes
back), ``flush`` pads the pending queue up to the next bucket size and
runs ONE batched ``AnnEngine`` search per bucket-shaped batch. Bucketed
padding keeps the jit cache to a handful of entries regardless of
traffic shape — ``warmup`` pre-compiles every bucket so the first real
query never pays compile latency.

This is the single-process skeleton of the production front-end: the
queue becomes a real async queue and ``flush`` a deadline-driven loop,
but the device contract (pad-to-bucket, warm cache, one search per
batch) is exactly what a high-QPS deployment needs.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp

from repro.ann.engine import AnnEngine

__all__ = ["AnnServiceConfig", "AnnService"]


@dataclass(frozen=True)
class AnnServiceConfig:
    top_k: int = 10
    mode: str = "exact"            # exact | lsh
    min_bands: int = 1
    n_probes: int = 0
    buckets: tuple = (1, 8, 64, 256)   # padded batch shapes (ascending)
    impl: str = "auto"


@dataclass
class AnnService:
    """Queue + pad-to-bucket batching over a shared ``AnnEngine``."""
    engine: AnnEngine
    cfg: AnnServiceConfig = field(default_factory=AnnServiceConfig)

    def __post_init__(self):
        self._queue = []          # [(ticket, vector [D])]
        self._results = {}        # ticket -> (ids [top_k], rho [top_k])
        self._next_ticket = 0
        self.stats = {"queries": 0, "batches": 0, "padded_rows": 0}

    # -- request path --------------------------------------------------------
    def submit(self, x) -> int:
        """Enqueue one query vector [D]; returns a ticket for ``result``."""
        x = jnp.asarray(x)
        if x.ndim != 1:
            raise ValueError(f"submit takes a single vector, got {x.shape}")
        t = self._next_ticket
        self._next_ticket += 1
        self._queue.append((t, x))
        return t

    def result(self, ticket: int):
        """(ids, rho) for a flushed ticket; KeyError if not flushed yet."""
        return self._results[ticket]

    def pending(self) -> int:
        return len(self._queue)

    # -- batch execution -----------------------------------------------------
    def _bucket_for(self, n: int) -> int:
        for b in self.cfg.buckets:
            if n <= b:
                return b
        return self.cfg.buckets[-1]

    def flush(self):
        """Run every pending query; returns {ticket: (ids, rho)}.

        Queries are taken in arrival order, in slices of at most the
        largest bucket; each slice is padded up to its bucket shape.
        """
        out = {}
        cfg = self.cfg
        max_b = cfg.buckets[-1]
        while self._queue:
            batch = self._queue[:max_b]
            self._queue = self._queue[max_b:]
            n = len(batch)
            b = self._bucket_for(n)
            x = jnp.stack([v for _, v in batch])
            if b > n:
                x = jnp.pad(x, ((0, b - n), (0, 0)))
            ids, rho = self.engine.search(
                x, cfg.top_k, mode=cfg.mode, min_bands=cfg.min_bands,
                n_probes=cfg.n_probes, chunk_q=b, impl=cfg.impl)
            for i, (t, _) in enumerate(batch):
                self._results[t] = (ids[i], rho[i])
                out[t] = (ids[i], rho[i])
            self.stats["queries"] += n
            self.stats["batches"] += 1
            self.stats["padded_rows"] += b - n
        return out

    def warmup(self, d: int):
        """Pre-compile every bucket shape (cold-start insurance)."""
        for b in self.cfg.buckets:
            self.engine.search(
                jnp.zeros((b, d)), self.cfg.top_k, mode=self.cfg.mode,
                min_bands=self.cfg.min_bands,
                n_probes=self.cfg.n_probes, chunk_q=b, impl=self.cfg.impl)
        return self
