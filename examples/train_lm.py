"""End-to-end training driver: train an LM with the full substrate
(pipeline -> train step -> checkpoints -> resume), optionally with the
paper's coded-sketch gradient compression.

    # CPU-sized run (default): ~5M params, 200 steps
    PYTHONPATH=src python examples/train_lm.py --steps 200

    # ~100M-parameter preset (cluster-sized; runs on this CPU but slowly)
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

    # coded-sketch compressed gradients (paper integration)
    PYTHONPATH=src python examples/train_lm.py --compress 2bit --steps 200
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core.gradient_compression import (GradCompressionConfig,
                                             GradCompressor)
from repro.data import DataConfig, TokenPipeline
from repro.launch.mesh import make_dp_mesh
from repro.models import lm as L
from repro.models.nn import count_params, init_params
from repro.optim import AdamWConfig, init_opt_state
from repro.parallel.sharding import ShardingRules
from repro.train import (Trainer, TrainState, make_compressed_train_step,
                         make_train_step)

PRESETS = {
    "cpu-tiny": L.ModelConfig(name="cpu-tiny", n_layers=4, d_model=128,
                              n_heads=4, n_kv_heads=2, d_ff=512,
                              vocab_size=2048, loss_chunk=64, chunk_kv=64,
                              chunk_q=64, remat=False),
    "100m": L.ModelConfig(name="repro-100m", n_layers=12, d_model=768,
                          n_heads=12, n_kv_heads=4, d_ff=2048,
                          vocab_size=32768, loss_chunk=128),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu-tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--compress", default="none",
                    choices=["none", "sign", "2bit", "uniform", "offset"])
    ap.add_argument("--compress-rate", type=int, default=8)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    specs = L.model_param_specs(cfg)
    print(f"[train_lm] {cfg.name}: {count_params(specs) / 1e6:.1f}M params")
    opt_cfg = AdamWConfig(lr_peak=args.lr, warmup_steps=20,
                          decay_steps=args.steps, weight_decay=0.01)
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq,
                                    global_batch=args.batch))
    params = init_params(specs, seed=0)
    opt = init_opt_state(params, opt_cfg)

    if args.compress != "none":
        mesh = make_dp_mesh()
        gtpl = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        comp = GradCompressor(
            GradCompressionConfig(scheme=args.compress,
                                  rate=args.compress_rate), gtpl)
        print(f"[train_lm] coded-sketch gradient sync: "
              f"{comp.wire_bytes()} wire bytes/rank vs "
              f"{comp.fp32_bytes()} fp32 ({comp.fp32_bytes() / comp.wire_bytes():.0f}x)")
        step_fn = make_compressed_train_step(cfg, opt_cfg, mesh, comp)
        state = TrainState(params, opt, ef=comp.init_ef(gtpl))
    else:
        step_fn = make_train_step(cfg, opt_cfg, ShardingRules(None))
        state = TrainState(params, opt)

    trainer = Trainer(step_fn, state, pipe, ckpt_dir=args.ckpt_dir,
                      ckpt_every=max(args.steps // 4, 25), log_every=10)
    trainer.maybe_resume()
    hist = trainer.run(args.steps)
    if hist:
        print(f"[train_lm] loss {float(hist[0]['loss']):.4f} -> "
              f"{float(hist[-1]['loss']):.4f} over {len(hist)} steps")


if __name__ == "__main__":
    main()
