"""End-to-end observability: metrics, traces, kernel stats, exporters.

Every budget the paper trades in — bits per projection vs. estimation
accuracy, HBM bytes vs. recall, coarse-pass vs. re-rank compute — is
only governable if it is *measured*; this subsystem is the measuring
layer the other six report through.

registry    — ``MetricsRegistry``: counters, gauges, fixed-log-bucket
              histograms (p50/p95/p99 without storing samples);
              process-global default + injectable instances; a disabled
              registry hands out no-op metrics
trace       — ``Tracer``/``span``: nestable spans with device-sync-
              correct timing (``sp.sync`` = ``block_until_ready`` at
              the boundary; unsynced spans are *marked* async — the
              sync-boundary invariant) and Chrome-trace/Perfetto export
kernelstats — per-kernel-family dispatch counts + modeled FLOPs/HBM
              bytes recorded at the ``kernels/ops.py`` chokepoint; live
              roofline table against ``launch.roofline.HW``
export      — one-call JSON snapshot + Prometheus text format
quality     — online statistical health: sampled empirical collision/
              cell frequencies vs. the paper's theory curves at the MLE
              rho (z-scores, chi-square divergence) + classifier-margin
              moments, all budgeted by one sampling rate
shadow      — seeded reservoir of raw rows (capped, tombstone-aware) +
              shadow queries re-scored by exact cosine: unbiased online
              recall@k and rho-estimation error with Wilson intervals
drift       — Page-Hinkley/CUSUM detectors over the monitored series;
              registered callbacks fire on alarm (the warm-start-refit
              trigger hook); detectors report the alarm direction
events      — ``FlightRecorder`` (``obs/events.py``): always-on
              preallocated ring buffer of structured per-request
              events (op, queue/start/sync timestamps, batch, cache
              hits, generation, outcome, trace id); O(1) append cheap
              enough for the serving hot path
incident    — ``IncidentManager`` (``obs/incident.py``): on a drift
              alarm, burn-rate alarm, or endpoint error, dump a
              self-contained bundle (flight tail, retained traces,
              registry snapshot, quality state, SLO health, store
              generation) through ``repro.checkpoint``; restores to a
              readable dict
slo         — ``SloEngine`` (``obs/slo.py``): declarative per-endpoint
              ``SloSpec``s (latency/availability/quality), rolling
              multi-window error budgets from cumulative-counter
              snapshots (no stored samples), Google-SRE multi-window
              multi-burn-rate alerts on the ``DriftMonitor`` callback
              contract, and the machine-readable ``health()`` verdict
              (admission-control input)
probe       — ``CanaryProber`` (``obs/probe.py``): deterministic
              known-answer canaries drawn from the shadow reservoir,
              replayed through the real serving endpoints
              (``probe_search``/``probe_classify``) with telemetry
              segregated; verdicts feed the SLO quality budgets
resources   — ``ResourceMonitor`` (``obs/resources.py``): live-bytes
              gauges per tracked store/model, device memory watermarks,
              host RSS, and the process-wide jit-recompile counter that
              turns the never-recompile invariant into a budgeted gauge
dashboard   — zero-dependency ops view (``obs/dashboard.py``): one
              ``gather`` snapshot rendered as terminal text or a static
              self-contained HTML page (SLO budgets + burn sparklines,
              latency, resources, roofline, quality, flight tail),
              written atomically for CI artifacts

The flight layer adds retain-on-tail tracing: ``RequestTrace`` gives
every request a shallow span chain (no device barriers) and
``TailSampler`` retains full traces only for slowest-quantile /
errored / quality-flagged requests, with exemplar links
(``Histogram.exemplar``) exported on Prometheus buckets.

Instrumented layers: ``serve.ann_service`` (endpoint latencies, ticket
age, cache + padding economics, per-request flight events + tail
sampling), ``encode.pipeline`` (chunk spans, rows/bytes),
``index.segment_log``/``index.compaction`` (churn counters,
live-fraction gauge), ``ann.engine``/``index.engine`` (coarse vs.
re-rank span split), ``learn.trainer`` (step time, rows/s). Overhead is
benchmarked by ``benchmarks/obs_bench.py`` (``BENCH_obs.json``); any
bench target exports a flame view via ``benchmarks/run.py --profile``;
cross-run headline numbers accumulate in ``BENCH_history.jsonl``
(``benchmarks/history.py``) and are regression-gated by
``scripts/check_perf.py``.
"""
from repro.obs.registry import (Counter, Gauge, Histogram,  # noqa: F401
                                HistogramSpec, MetricsRegistry,
                                default_registry, set_default_registry)
from repro.obs.trace import (RequestTrace, Span,  # noqa: F401
                             TailSampler, Tracer, active_tracer,
                             deep_tracing_active, no_tracing, span,
                             tracing_active)
from repro.obs.events import (EVENT_FIELDS,  # noqa: F401
                              FlightRecorder, default_flight_recorder,
                              set_flight_recorder)
from repro.obs.incident import IncidentManager  # noqa: F401
from repro.obs.kernelstats import (KernelStats,  # noqa: F401
                                   get_kernel_stats, roofline_table,
                                   set_kernel_stats)
from repro.obs.export import dump_json, snapshot, to_prometheus  # noqa: F401
from repro.obs.quality import (CollisionMonitor, MarginMonitor,  # noqa: F401
                               QualityConfig, QualityMonitors, Welford,
                               synthetic_code_pairs)
from repro.obs.shadow import (RecallMonitor, ShadowReservoir,  # noqa: F401
                              wilson_interval)
from repro.obs.drift import Cusum, DriftMonitor, PageHinkley  # noqa: F401
from repro.obs.slo import (AlertState, BurnPolicy,  # noqa: F401
                           DEFAULT_POLICIES, SloEngine, SloSpec)
from repro.obs.probe import CanaryProber, ProbeConfig  # noqa: F401
from repro.obs.resources import (ResourceMonitor,  # noqa: F401
                                 install_compile_counter, jit_compiles)
from repro.obs.dashboard import (gather, render_html,  # noqa: F401
                                 render_text, write_dashboard)
