"""Device-resident corpus of bit-packed codes (the ANN engine's HBM side).

A ``CodeStore`` is an immutable array of uint32 words in the layout of
``repro.core.packing`` / ``kernels.pack_codes``: row i holds item i's k
b-bit codes in ceil(k / (32/b)) words. Immutability keeps every search
jit-cache entry valid forever; ingestion produces *new* stores
(``add``/``merge``) by copying the concatenation — O(corpus) per batch.
The mutable ingestion path that amortizes this away is
``repro.index.SegmentLogStore``, a log of content-immutable segments
with the same row layout.

The row axis is the shard axis: ``shard``/``row_sharding`` place the
store across a mesh's data axis for the multi-device search path.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import packing as _packing
from repro.kernels import ops as _ops

__all__ = ["CodeStore"]


@dataclass(frozen=True)
class CodeStore:
    """Immutable packed-code corpus: ``words`` uint32 [n, n_words]."""
    words: jax.Array
    k: int
    bits: int

    def __post_init__(self):
        want = _packing.packed_width(self.k, self.bits)
        if self.words.ndim != 2 or self.words.shape[1] != want:
            raise ValueError(
                f"words {self.words.shape} != [n, {want}] for k={self.k}, "
                f"bits={self.bits}")

    # -- construction / ingestion -------------------------------------------
    @classmethod
    def from_codes(cls, codes, k: int, bits: int, impl: str = "auto"):
        """Pack int32 codes [n, k] (Pallas kernel on TPU, jnp oracle off)."""
        assert codes.shape[-1] == k, (codes.shape, k)
        words = _ops.pack_codes(codes, bits, impl=impl)
        return cls(words=words, k=k, bits=bits)

    @classmethod
    def from_words(cls, words, k: int, bits: int):
        """Wrap already-packed uint32 words [n, ceil(k/(32/bits))]."""
        return cls(words=jnp.asarray(words, jnp.uint32), k=k, bits=bits)

    def add(self, codes, impl: str = "auto") -> "CodeStore":
        """New store with packed ``codes`` [m, k] appended (ids n..n+m)."""
        return self.merge(CodeStore.from_codes(codes, self.k, self.bits,
                                               impl=impl))

    def add_words(self, words) -> "CodeStore":
        """New store with already-packed rows [m, W] appended — the
        fused-ingest path (``repro.encode``): int32 codes never exist."""
        return self.merge(CodeStore.from_words(words, self.k, self.bits))

    def merge(self, other: "CodeStore") -> "CodeStore":
        """New store: self's rows then other's (same k/bits required)."""
        if (self.k, self.bits) != (other.k, other.bits):
            raise ValueError(f"incompatible stores: k/bits "
                             f"{(self.k, self.bits)} vs {(other.k, other.bits)}")
        return CodeStore(words=jnp.concatenate([self.words, other.words]),
                         k=self.k, bits=self.bits)

    # -- geometry ------------------------------------------------------------
    @property
    def n(self) -> int:
        """Corpus rows."""
        return self.words.shape[0]

    @property
    def n_words(self) -> int:
        """uint32 words per row: ceil(k / (32/bits))."""
        return self.words.shape[1]

    @property
    def nbytes(self) -> int:
        """Device bytes of the packed corpus (4 per word)."""
        return self.n * self.n_words * 4

    def unpack(self):
        """int32 codes [n, k] (debug / compat path only)."""
        return _packing.unpack_codes(self.words, self.bits, self.k)

    def take(self, ids):
        """Gather rows -> uint32 [..., n_words] (candidate re-ranking)."""
        return jnp.take(self.words, ids, axis=0)

    # -- device placement ----------------------------------------------------
    def row_sharding(self, mesh: Mesh, axis: str = "data") -> NamedSharding:
        """The store's canonical sharding: rows split over mesh[axis]."""
        return NamedSharding(mesh, P(axis, None))

    def shard(self, mesh: Mesh, axis: str = "data") -> "CodeStore":
        """Store with rows laid out across ``mesh[axis]`` (n must divide).

        The multi-device search path (``AnnEngine.search_sharded``) maps
        over exactly this layout.
        """
        if self.n % mesh.shape[axis] != 0:
            raise ValueError(
                f"n={self.n} not divisible by mesh axis {axis} "
                f"({mesh.shape[axis]})")
        words = jax.device_put(self.words, self.row_sharding(mesh, axis))
        return CodeStore(words=words, k=self.k, bits=self.bits)
