"""Ingest benchmark: segment-log mutation path vs the concat-copy baseline.

Three measurements over the same packed-code workload:

* **ingest throughput + copy bytes** — stream ``total`` rows in batches
  into (a) the PR-1 immutable ``CodeStore`` (every ``add`` concatenates
  the whole corpus: O(corpus) bytes per batch, O(N^2/B) total) and
  (b) the ``SegmentLogStore`` (donated tail write: O(batch) bytes per
  batch, O(N) total). Copy-byte counts are the exact analytic traffic of
  each path's device ops; wall times are measured.
* **query QPS under churn** — interleave add / delete / periodic compact
  with batched searches on a ``MutableAnnEngine`` and report sustained
  query QPS while the corpus turns over, plus the same batched searches
  on a quiescent index as the no-churn reference.
* **snapshot round-trip** — save + restore wall time at final size.

Emits run.py CSV rows, a detailed CSV, and ``BENCH_ingest.json`` (repo
root) with every number.
"""
import json
import os
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

if __package__ in (None, ""):        # direct `python benchmarks/ingest_bench.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

from benchmarks._util import write_csv
from repro.ann import AnnEngine, BandSpec, CodeStore
from repro.ann.engine import SearchConfig
from repro.core.sketch import CodedRandomProjection, SketchConfig
from repro.index import CompactionPolicy, MutableAnnEngine, SegmentLogStore

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
K, BITS, D, TOP_K = 64, 2, 32, 10


def _codes(rng, m):
    return jnp.asarray(rng.integers(0, 1 << BITS, (m, K)), jnp.int32)


def _bench_concat_add(rng, total, batch):
    """Immutable-store ingestion: O(corpus) concat copy per batch."""
    store = CodeStore.from_codes(_codes(rng, batch), K, BITS)
    w = store.n_words
    copied = store.nbytes
    t0 = time.perf_counter()
    for _ in range(total // batch - 1):
        store = store.add(_codes(rng, batch))
        copied += store.nbytes          # concat writes the full new array
    jax.block_until_ready(store.words)
    dt = time.perf_counter() - t0
    return {"rows_per_s": (total - batch) / dt, "bytes_copied": copied,
            "bytes_per_row": copied / total, "seconds": dt,
            "final_rows": store.n, "word_bytes_per_row": 4 * w}


def _bench_segment_add(rng, total, batch, tail_rows):
    """Segment-log ingestion: donated tail write, O(batch) copy."""
    store = SegmentLogStore(K, BITS, tail_rows=tail_rows)
    copied = 0
    t0 = time.perf_counter()
    for _ in range(total // batch):
        store.add_codes(_codes(rng, batch))
        copied += batch * store.n_words * 4     # dynamic_update_slice slab
    jax.block_until_ready(store.tail.words)
    dt = time.perf_counter() - t0
    return {"rows_per_s": total / dt, "bytes_copied": copied,
            "bytes_per_row": copied / total, "seconds": dt,
            "final_rows": store.n_live, "n_segments": store.n_segments}


def _bench_churn(rng, steps, batch, n_queries, tail_rows):
    """Interleaved add/delete/compact/search on the mutable engine."""
    crp = CodedRandomProjection(
        SketchConfig(k=K, scheme="2bit", w=0.75), D)
    eng = MutableAnnEngine(crp, band_spec=BandSpec(16, 4),
                           tail_rows=tail_rows)
    cfg = SearchConfig(top_k=TOP_K, chunk_q=n_queries)
    q_codes = _codes(rng, n_queries)
    eng.add_codes(_codes(rng, batch))
    jax.block_until_ready(eng.search_codes(q_codes, cfg))   # warm cache
    live = list(eng.store.live_ids())
    t_search = 0.0
    t0 = time.perf_counter()
    for step in range(steps):
        live.extend(eng.add_codes(_codes(rng, batch)))
        kill = rng.choice(len(live), size=batch // 2, replace=False)
        eng.delete([live[i] for i in kill])
        ks = set(kill.tolist())
        live = [x for i, x in enumerate(live) if i not in ks]
        if step % 8 == 7:
            eng.compact(CompactionPolicy(target_rows=4 * tail_rows))
        ts = time.perf_counter()
        jax.block_until_ready(eng.search_codes(q_codes, cfg)[0])
        t_search += time.perf_counter() - ts
    dt = time.perf_counter() - t0
    # quiescent reference: same searches, no interleaved mutation
    reps = max(steps // 2, 1)
    t1 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(eng.search_codes(q_codes, cfg)[0])
    t_quiet = (time.perf_counter() - t1) / reps
    return {"steps": steps, "rows_added": steps * batch,
            "rows_deleted": steps * (batch // 2),
            "final_live": eng.store.n_live,
            "final_segments": eng.store.n_segments,
            "qps_under_churn": steps * n_queries / t_search,
            "qps_quiescent": n_queries / t_quiet,
            "ingest_rows_per_s": steps * batch / dt,
            "seconds": dt}, eng


def _bench_snapshot(eng, tmpdir):
    t0 = time.perf_counter()
    eng.save(tmpdir, 0)
    t_save = time.perf_counter() - t0
    t0 = time.perf_counter()
    eng2 = MutableAnnEngine.restore(eng.sketcher, tmpdir)
    t_restore = time.perf_counter() - t0
    assert eng2.store.n_live == eng.store.n_live
    return {"save_s": t_save, "restore_s": t_restore,
            "rows": eng.store.n_live}


def _bench(total, batch, tail_rows, steps, n_queries):
    rng = np.random.default_rng(0)
    seg = _bench_segment_add(rng, total, batch, tail_rows)
    cat = _bench_concat_add(rng, total, batch)
    churn, eng = _bench_churn(rng, steps, batch, n_queries, tail_rows)
    import tempfile
    with tempfile.TemporaryDirectory() as tmp:
        snap = _bench_snapshot(eng, tmp)
    r = {"total_rows": total, "batch": batch, "tail_rows": tail_rows,
         "k": K, "bits": BITS,
         "segment_log": seg, "concat_baseline": cat, "churn": churn,
         "snapshot": snap,
         "copy_bytes_ratio": cat["bytes_copied"] / seg["bytes_copied"],
         "ingest_speedup": seg["rows_per_s"] / cat["rows_per_s"]}
    with open(os.path.join(_ROOT, "BENCH_ingest.json"), "w") as f:
        json.dump(r, f, indent=1)
    return r


def _rows(r):
    seg, cat, churn = r["segment_log"], r["concat_baseline"], r["churn"]
    return [
        ("ingest_segment_log", 1e6 / seg["rows_per_s"],
         f"rows/s={seg['rows_per_s']:.0f} bytes/row={seg['bytes_per_row']:.0f}"),
        ("ingest_concat_copy", 1e6 / cat["rows_per_s"],
         f"rows/s={cat['rows_per_s']:.0f} bytes/row={cat['bytes_per_row']:.0f}"),
        ("churn_query", 1e6 / churn["qps_under_churn"],
         f"qps={churn['qps_under_churn']:.0f} "
         f"quiet_qps={churn['qps_quiescent']:.0f}"),
        ("snapshot_roundtrip", 1e6 * (r["snapshot"]["save_s"]
                                      + r["snapshot"]["restore_s"]),
         f"rows={r['snapshot']['rows']}"),
    ]


def run(quick: bool = True):
    """run.py contract: (name, us_per_op, derived) rows."""
    r = _bench(total=4096 if quick else 65536, batch=256,
               tail_rows=1024, steps=8 if quick else 32, n_queries=64)
    rows = _rows(r)
    write_csv("ingest_bench", ["name", "us_per_op", "derived"], rows)
    return rows


def main():
    r = _bench(total=65536, batch=256, tail_rows=2048, steps=32,
               n_queries=128)
    write_csv("ingest_bench", ["name", "us_per_op", "derived"], _rows(r))
    print("BENCH " + json.dumps(r))
    seg, cat = r["segment_log"], r["concat_baseline"]
    print(f"\nsegment-log add: {seg['rows_per_s']:.0f} rows/s at "
          f"{seg['bytes_per_row']:.0f} copied bytes/row (O(batch)); "
          f"concat-copy baseline: {cat['rows_per_s']:.0f} rows/s at "
          f"{cat['bytes_per_row']:.0f} bytes/row (O(corpus)) -> "
          f"{r['copy_bytes_ratio']:.0f}x less copy traffic, "
          f"{r['ingest_speedup']:.1f}x ingest speedup")
    print(f"churn: {r['churn']['qps_under_churn']:.0f} qps interleaved with "
          f"ingest+deletes+compaction (quiescent {r['churn']['qps_quiescent']:.0f})")


if __name__ == "__main__":
    main()
