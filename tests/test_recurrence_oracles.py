"""Chunked Mamba2-SSD and RWKV6-WKV vs naive step-by-step recurrences."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.models.mamba2 import _ssd_chunked
from repro.models.rwkv6 import _wkv_chunked


def test_ssd_chunked_matches_naive():
    key = jax.random.PRNGKey(0)
    b, s, h, p, n = 2, 37, 3, 4, 5
    ks = jax.random.split(key, 4)
    xdt = jax.random.normal(ks[0], (b, s, h, p))
    a = -jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.3
    bm = jax.random.normal(ks[2], (b, s, n))
    cm = jax.random.normal(ks[3], (b, s, n))
    s0 = jnp.zeros((b, h, n, p))

    for chunk in (1, 4, 8, 37, 64):
        y, sf = _ssd_chunked(xdt, a, bm, cm, s0, chunk)
        # naive recurrence: S_t = exp(a_t) S_{t-1} + B_t (xdt_t)^T; y = C_t.S_t
        S = np.zeros((b, h, n, p))
        ys = []
        for t in range(s):
            S = np.exp(np.asarray(a[:, t]))[:, :, None, None] * S + \
                np.einsum("bn,bhp->bhnp", np.asarray(bm[:, t]), np.asarray(xdt[:, t]))
            ys.append(np.einsum("bn,bhnp->bhp", np.asarray(cm[:, t]), S))
        y_ref = np.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(sf), S, rtol=2e-4, atol=2e-4)


def test_wkv_chunked_matches_naive():
    key = jax.random.PRNGKey(1)
    b, s, h, k = 2, 29, 2, 4
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, s, h, k))
    kk = jax.random.normal(ks[1], (b, s, h, k))
    v = jax.random.normal(ks[2], (b, s, h, k))
    lw = -jnp.abs(jax.random.normal(ks[3], (b, s, h, k))) * 0.5
    u = jax.random.normal(ks[4], (h, k))
    s0 = jnp.zeros((b, h, k, k))

    for chunk in (1, 4, 16, 29):
        o, sf = _wkv_chunked(r, kk, v, lw, u, s0, chunk)
        # naive: o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T); S_t = diag(w_t) S_{t-1} + k_t v_t^T
        S = np.zeros((b, h, k, k))
        os_ = []
        for t in range(s):
            rt = np.asarray(r[:, t]); kt = np.asarray(kk[:, t]); vt = np.asarray(v[:, t])
            bonus = np.einsum("bhk,hk,bhk,bhv->bhv", rt, np.asarray(u), kt, vt)
            os_.append(np.einsum("bhk,bhkv->bhv", rt, S) + bonus)
            S = np.exp(np.asarray(lw[:, t]))[..., None] * S + \
                np.einsum("bhk,bhv->bhkv", kt, vt)
        o_ref = np.stack(os_, axis=1)
        np.testing.assert_allclose(np.asarray(o), o_ref, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(sf), S, rtol=2e-4, atol=2e-4)


def test_ssd_state_carry_composes():
    # running two segments with carried state == one long segment
    key = jax.random.PRNGKey(2)
    b, s, h, p, n = 1, 16, 2, 4, 3
    ks = jax.random.split(key, 4)
    xdt = jax.random.normal(ks[0], (b, s, h, p))
    a = -jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.3
    bm = jax.random.normal(ks[2], (b, s, n))
    cm = jax.random.normal(ks[3], (b, s, n))
    s0 = jnp.zeros((b, h, n, p))
    y_full, sf_full = _ssd_chunked(xdt, a, bm, cm, s0, 4)
    y1, s1 = _ssd_chunked(xdt[:, :8], a[:, :8], bm[:, :8], cm[:, :8], s0, 4)
    y2, s2 = _ssd_chunked(xdt[:, 8:], a[:, 8:], bm[:, 8:], cm[:, 8:], s1, 4)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(sf_full),
                               rtol=1e-4, atol=1e-4)
