"""Closed-loop health layer: SLO error budgets and burn-rate alerting
under injected-fault drills (latency step, recall degradation, forced
recompile — each must alarm within the fast window with zero false
alarms on stationary traffic), known-answer canary probing through the
real service endpoints with probe-exclusion invariants, resource
accounting, dashboard rendering, OpenMetrics label escaping, and the
histogram edge cases the budget math leans on."""
import math
import os
import sys
import threading

import numpy as np
import pytest
import jax.numpy as jnp

from repro.ann import BandSpec
from repro.core.sketch import CodedRandomProjection, SketchConfig
from repro.index import MutableAnnEngine
from repro.obs import (BurnPolicy, CanaryProber, FlightRecorder,
                       Histogram, HistogramSpec, MetricsRegistry,
                       ProbeConfig, ResourceMonitor, SloEngine, SloSpec,
                       TailSampler, gather, render_html, render_text,
                       to_prometheus, write_dashboard)
from repro.obs.quality import QualityConfig

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)                      # benchmarks/
sys.path.insert(0, os.path.join(_ROOT, "scripts"))   # check_perf

D, K = 16, 16
BAND = BandSpec(n_tables=4, band_width=4)


def _crp():
    return CodedRandomProjection(SketchConfig(k=K, scheme="2bit", w=0.75),
                                 D)


class _Clock:
    """Injectable fake clock driving deterministic drills."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def step(self, dt=1.0):
        self.t += dt
        return self.t


class _FakeResources:
    """ResourceMonitor stand-in exposing only the compile counter."""

    def __init__(self):
        self.compiles = 0

    def jit_compiles(self):
        return self.compiles


def _engine_with_spec(reg, clock, **spec_kw):
    eng = SloEngine(registry=reg, clock=clock, resolution=1.0)
    kw = dict(latency_hist="serve.flush_s", latency_target_s=0.050,
              error_counter="serve.flush_errors", quality_min=0.8)
    kw.update(spec_kw)
    eng.add(SloSpec("search", **kw))
    return eng


# -- drill 1: forced 2x latency step ------------------------------------------

def test_latency_step_trips_fast_burn_alert_and_health_degrades():
    reg = MetricsRegistry()
    clock = _Clock()
    slo = _engine_with_spec(reg, clock)
    fired = []
    slo.subscribe(lambda series, value, det: fired.append((series, det)))
    h = reg.histogram("serve.flush_s")
    for _ in range(90):                   # stationary: 40 ms < deadline
        for _ in range(50):
            h.observe(0.040)
        clock.step()
        slo.tick()
    assert fired == [] and slo.health()["status"] == "ok"
    t_step = clock.t
    for _ in range(60):                   # 2x step: 80 ms, all late
        for _ in range(50):
            h.observe(0.080)
        clock.step()
        slo.tick()
        if fired:
            break
    assert fired, "latency step never tripped the burn alert"
    series, det = fired[0]
    assert series == "slo.search.latency"
    # DriftMonitor detector contract: side/alarms/stat
    assert det.side == "up" and det.alarms == 1 and det.stat >= 1.0
    assert clock.t - t_step <= 60.0, "alert fired outside the fast window"
    health = slo.health()
    assert health["status"] == "degraded"
    assert "slo.search.latency" in health["alerts"]
    assert 0.0 < health["shed_fraction"] <= 1.0
    # budgets view mirrors the ledger state
    b = slo.budgets()["search.latency"]
    # the slow-ticket policy (6x burn) legitimately fires first
    assert b["alerting"] and b["burn_fast"] >= 6.0 and b["spark"]
    # recovery: back on time -> the alert clears once the short window
    # drains (multi-window: a fixed regression stops paging)
    for _ in range(120):
        for _ in range(50):
            h.observe(0.040)
        clock.step()
        slo.tick()
    assert slo.health()["status"] == "ok"
    assert len(fired) == 1, "recovery must not re-fire the callback"


def test_stationary_jittered_run_never_alarms():
    reg = MetricsRegistry()
    clock = _Clock()
    slo = _engine_with_spec(reg, clock)
    fired = []
    slo.subscribe(lambda *a: fired.append(a))
    h = reg.histogram("serve.flush_s")
    rng = np.random.default_rng(7)
    for _ in range(400):
        # seeded lognormal jitter around 25 ms; rare excursions stay
        # far under the 1% lateness budget
        for v in rng.lognormal(math.log(0.025), 0.25, size=40):
            h.observe(float(v))
        if rng.random() < 0.2:
            slo.observe_quality("search", float(rng.uniform(0.85, 1.0)))
        clock.step()
        slo.tick()
    assert fired == []
    assert slo.health()["status"] == "ok"
    assert slo.health()["shed_fraction"] == 0.0


# -- drill 2: forced recall degradation ---------------------------------------

def test_recall_drop_trips_quality_alert():
    reg = MetricsRegistry()
    clock = _Clock()
    slo = _engine_with_spec(reg, clock)
    fired = []
    slo.subscribe(lambda series, value, det: fired.append(series))
    for _ in range(90):                   # healthy shadow recall
        for _ in range(3):
            slo.observe_quality("search", 1.0)
        clock.step()
        slo.tick()
    assert fired == []
    t_step = clock.t
    for _ in range(60):                   # corrupted ranking: recall 0
        for _ in range(3):
            slo.observe_quality("search", 0.1)
        clock.step()
        slo.tick()
        if fired:
            break
    assert fired == ["slo.search.quality"]
    assert clock.t - t_step <= 60.0, "alert fired outside the fast window"
    assert "slo.search.quality" in slo.health()["alerts"]


# -- drill 3: forced recompile on the hot path --------------------------------

def test_recompile_after_steady_mark_trips_runtime_alert():
    reg = MetricsRegistry()
    clock = _Clock()
    slo = SloEngine(registry=reg, clock=clock, resolution=1.0)
    res = _FakeResources()
    slo.attach_resources(res)
    fired = []
    slo.subscribe(lambda series, value, det: fired.append(series))
    res.compiles = 17                     # warmup/autotune compiles...
    slo.mark_steady()                     # ...are free after the mark
    for _ in range(90):
        clock.step()
        slo.tick()
    assert fired == [] and slo.health()["status"] == "ok"
    t_step = clock.t
    for _ in range(60):                   # hot path starts recompiling
        res.compiles += 1
        clock.step()
        slo.tick()
        if fired:
            break
    assert fired == ["slo.runtime.recompile"]
    assert clock.t - t_step <= 60.0, "alert fired outside the fast window"
    assert slo.health()["status"] == "degraded"


def test_quality_obs_without_floor_is_noop_and_bad_probe_burns():
    slo = SloEngine(registry=MetricsRegistry(), clock=_Clock())
    slo.add(SloSpec("classify", latency_hist="serve.classify_s"))
    slo.observe_quality("classify", 0.1)  # spec has NaN floor -> no-op
    assert "classify.quality" not in slo.ledgers
    slo.observe_probe("classify", False)  # probe verdicts always land
    led = slo.ledgers["classify.quality"]
    assert (led.total, led.bad) == (1, 1)


def test_ledger_windows_use_snapshots_not_samples():
    clock = _Clock()
    slo = SloEngine(registry=MetricsRegistry(), clock=clock,
                    resolution=1.0)
    led = slo.ledger("x", 0.99)
    for i in range(5000):
        led.push(i % 10 != 0)             # 10% bad forever
        if i % 10 == 9:
            clock.step()
            slo.tick(force=True)
    # ring stays bounded by the longest policy window / resolution
    assert len(led.ring) <= 600 + 2
    frac, n = led.window_rate(clock.t, 60.0)
    assert n > 0 and abs(frac - 0.1) < 0.02


# -- end-to-end drill through the service -------------------------------------

def _service(tmp_path, cache_size=16, **kw):
    eng = MutableAnnEngine(_crp(), band_spec=BAND, tail_rows=64)
    from repro.serve import AnnService, AnnServiceConfig
    reg = MetricsRegistry()
    defaults = dict(
        registry=reg, flight=FlightRecorder(capacity=256),
        sampler=TailSampler(min_count=2, quantile=0.5, registry=reg),
        quality=QualityConfig(sample_rate=0.5, reservoir_rows=64),
        incidents=str(tmp_path / "incidents"),
        slo=True, resources=True)
    defaults.update(kw)
    svc = AnnService(eng, AnnServiceConfig(top_k=5, buckets=(1, 4),
                                           cache_size=cache_size,
                                           deadline_s=30.0),
                     **defaults)
    rng = np.random.default_rng(3)
    X = np.asarray(rng.normal(size=(48, D)), np.float32)
    svc.add(jnp.asarray(X))
    return svc, rng


def test_service_corrupted_ranking_probe_alert_incident_bundle(tmp_path):
    clock = _Clock()
    from repro.obs.slo import SloEngine as _SE
    reg = MetricsRegistry()
    slo = _SE(registry=reg, clock=clock, resolution=1.0)
    # cache_size=0: the result cache would otherwise serve pre-fault
    # answers for repeated canaries and mask the corruption
    svc, rng = _service(tmp_path, cache_size=0, slo=slo, registry=reg,
                        quality=None)
    resv_rows = np.asarray(rng.normal(size=(48, D)), np.float32)
    from repro.obs import ShadowReservoir
    resv = ShadowReservoir(cap=64)
    ids = svc.add(jnp.asarray(resv_rows))
    resv.offer(np.asarray(ids), resv_rows)
    prober = CanaryProber(svc, slo=svc.slo, reservoir=resv,
                          cfg=ProbeConfig(n_probes=4, classify=False,
                                          latency_budget_s=math.inf))
    assert svc.incidents.slo is svc.slo
    # healthy: canaries retrieve themselves, no alerts
    for _ in range(8):
        rep = prober.run_once()
        assert rep["ok"] and rep["recall"] == 1.0
        clock.step()
    assert svc.slo.health()["status"] == "ok"
    captured_before = svc.incidents.captured
    # corrupt the ranking: every search returns wrong ids (the effect
    # of a corrupted rank table) — per-layer monitors can't see this,
    # the known-answer probe must
    real = svc.engine.search_codes
    svc.engine.search_codes = lambda q, cfg: (
        jnp.full((q.shape[0], 5), 99999, jnp.int32),
        jnp.zeros((q.shape[0], 5), jnp.float32))
    try:
        tripped = False
        for _ in range(40):
            rep = prober.run_once()
            assert not rep["ok"] and rep["recall"] == 0.0
            clock.step()
            if svc.slo.health()["status"] == "degraded":
                tripped = True
                break
        assert tripped, "probe failures never tripped the quality alert"
    finally:
        svc.engine.search_codes = real
    health = svc.slo.health()
    assert "slo.search.quality" in health["alerts"]
    # the alarm produced an incident bundle carrying the SLO state
    assert svc.incidents.captured > captured_before
    bundle = svc.incidents.load()
    assert bundle["kind"] == "drift"
    assert bundle["context"]["series"] == "slo.search.quality"
    assert bundle["slo"]["status"] == "degraded"
    assert "slo.search.quality" in bundle["slo"]["alerts"]


def test_probe_traffic_excluded_from_user_metrics_and_sampler(tmp_path):
    svc, rng = _service(tmp_path)
    reg = svc.registry
    for _ in range(4):
        svc.submit(jnp.asarray(rng.normal(size=(D,)), np.float32))
        svc.flush()
    user_flush = reg.histograms["serve.flush_s"].count
    user_q = reg.counters["serve.queries"].value
    retained = dict(svc.sampler.retained)
    qm_state = svc.quality.report()
    prober = CanaryProber(svc, slo=svc.slo,
                          cfg=ProbeConfig(n_probes=5, classify=False,
                                          latency_budget_s=math.inf))
    rep = prober.run_once()
    assert rep["probes"] == 5 and rep["recall"] == 1.0
    # user-facing series untouched; probe twins carry the traffic
    assert reg.histograms["serve.flush_s"].count == user_flush
    assert reg.counters["serve.queries"].value == user_q
    assert reg.histograms["serve.probe.flush_s"].count == 5
    assert reg.counters["serve.probe.queries"].value == 5
    # tail sampler never saw the probes; quality sampling streams
    # unperturbed (seeded replay invariant)
    assert dict(svc.sampler.retained) == retained
    assert svc.quality.report() == qm_state
    # probe context restores user wiring
    assert svc.quality is not None and not svc._probing
    svc.submit(jnp.asarray(rng.normal(size=(D,)), np.float32))
    svc.flush()
    assert reg.histograms["serve.flush_s"].count == user_flush + 1


def test_probe_uses_result_cache_and_detects_stale_reservoir(tmp_path):
    svc, rng = _service(tmp_path)
    prober = CanaryProber(svc, slo=svc.slo,
                          cfg=ProbeConfig(n_probes=4, seed=5,
                                          classify=False,
                                          latency_budget_s=math.inf))
    assert prober.run_once()["ok"]
    # deleting the probed rows makes the reservoir stale ONLY if it is
    # not wired to store events — the service reservoir is, so canaries
    # keep passing across churn (tombstoned rows leave the reservoir)
    ids = svc.quality.reservoir.ids()
    svc.delete(ids[: len(ids) // 2])
    rep = prober.run_once()
    assert rep["ok"], "reservoir failed to track deletions"


def test_resource_monitor_tracks_bytes_and_compiles():
    reg = MetricsRegistry()
    rm = ResourceMonitor(registry=reg)
    rm.track("model", type("T", (), {"nbytes": 4096})())
    rm.track("fn", lambda: 1024.0)
    out = rm.collect()
    assert out["tracked"]["model"] == 4096.0
    assert out["tracked"]["fn"] == 1024.0
    assert out["tracked_total"] == 5120.0
    assert reg.gauges["resources.bytes.tracked_total"].value == 5120.0
    assert out["jit_compiles"] >= 0
    assert np.isfinite(out["host"]["rss_bytes"])
    rm.untrack("fn")
    assert rm.collect()["tracked_total"] == 4096.0
    base = rm.mark()
    assert rm.compiles_since_mark == 0 and base == rm.jit_compiles()


def test_service_resources_track_engine_store(tmp_path):
    svc, _ = _service(tmp_path)
    out = svc.resources.collect()
    assert out["tracked"]["engine.store"] > 0
    # warmup arms the never-recompile ledger via mark_steady
    svc.warmup(D)
    assert svc.slo._compile_mark is not None


# -- dashboard ----------------------------------------------------------------

def test_dashboard_renders_and_writes_atomically(tmp_path):
    svc, rng = _service(tmp_path)
    for _ in range(3):
        svc.submit(jnp.asarray(rng.normal(size=(D,)), np.float32))
        svc.flush()
    snap = gather(registry=svc.registry, slo=svc.slo, flight=svc.flight,
                  quality=svc.quality, resources=svc.resources)
    txt = render_text(snap)
    assert "== health: OK" in txt and "serve.flush_s" in txt
    page = render_html(snap)
    assert page.startswith("<!doctype html>")
    assert "SLO budgets" in page and "flight tail" in page
    assert "<script" not in page          # static artifact: no scripts
    path = tmp_path / "dash.html"
    out = write_dashboard(str(path), snap)
    assert out == str(path) and path.read_text() == page
    # atomic: no temp droppings next to the artifact
    assert [p.name for p in tmp_path.glob("*.tmp")] == []


def test_dashboard_gather_sections_optional():
    reg = MetricsRegistry()
    reg.histogram("h").observe(0.01)
    snap = gather(registry=reg)
    assert "health" not in snap and "resources" not in snap
    assert render_text(snap)              # renders without SLO wiring
    assert "<html>" in render_html(snap)


# -- OpenMetrics escaping (satellite) -----------------------------------------

def test_prometheus_exemplar_escapes_hostile_trace_id():
    reg = MetricsRegistry()
    h = reg.histogram("serve.flush_s")
    h.observe(0.01)
    hostile = 'id"} 1\nfake_metric 99 # {x="\\'
    h.exemplar(0.01, hostile)
    text = to_prometheus(reg)
    # the injection never becomes its own exposition line
    assert not any(ln.startswith("fake_metric")
                   for ln in text.splitlines())
    line = next(ln for ln in text.splitlines() if "trace_id" in ln)
    # backslash, quote, newline all escaped per the OpenMetrics spec
    assert '\\"' in line and "\\n" in line and "\\\\" in line
    # the line stays a single well-formed sample ending in its value
    assert line.rstrip().endswith(tuple("0123456789"))


# -- histogram edge cases the budget math leans on (satellite) ----------------

def test_histogram_percentile_empty_is_nan():
    h = Histogram("h")
    assert math.isnan(h.percentile(0.5))
    assert h.percentile_bounds(0.99) == (pytest.approx(math.nan, nan_ok=True),) * 2 \
        or all(math.isnan(v) for v in h.percentile_bounds(0.99))
    assert math.isnan(h.mean)
    s = h.summary()
    assert s["count"] == 0 and math.isnan(s["p99"])


def test_histogram_all_mass_in_overflow_bucket():
    spec = HistogramSpec(lo=1e-3, hi=1.0)
    h = Histogram("h", spec)
    for _ in range(7):
        h.observe(5e4)                    # far past hi: clamps, never grows
    assert h.count == 7
    assert h.counts[-1] == 7 and sum(h.counts[:-1]) == 0
    p = h.percentile(0.5)
    assert math.isfinite(p) and p >= spec.hi
    lo_b, hi_b = h.percentile_bounds(0.99)
    assert lo_b < hi_b and math.isfinite(hi_b)
    # lateness derivation stays sane: everything above any target bucket
    i = spec.bucket_index(0.05)
    assert sum(h.counts[i + 1:]) == 7


def test_histogram_snapshot_races_concurrent_observe():
    reg = MetricsRegistry()
    h = reg.histogram("h")
    n, stop = 50_000, threading.Event()

    def writer():
        for i in range(n):
            h.observe(1e-5 * (1 + i % 1000))
        stop.set()

    t = threading.Thread(target=writer)
    t.start()
    snaps = 0
    while not stop.is_set():
        s = h.summary()                   # must never raise mid-write
        assert 0 <= s["count"] <= n
        reg.snapshot()
        snaps += 1
    t.join()
    assert snaps > 0
    assert h.count == n                   # nothing lost to the race
    assert h.summary()["count"] == n


# -- check_perf --explain (satellite) -----------------------------------------

def test_check_perf_explain_reports_points_until_armed(tmp_path):
    import io
    import json as _json
    import check_perf
    hist = tmp_path / "hist.jsonl"
    rec = {"ts": "t", "git": "g", "module": "obs_bench", "quick": True,
           "metrics": {"obs_serve_flight": 100.0}}
    hist.write_text("\n".join([_json.dumps(rec)] * 2) + "\n")
    buf = io.StringIO()
    assert check_perf.explain(str(hist), min_points=5, out=buf) == 0
    assert "3 more point(s) until armed" in buf.getvalue()
    hist.write_text("\n".join([_json.dumps(rec)] * 5) + "\n")
    buf = io.StringIO()
    assert check_perf.explain(str(hist), min_points=5, out=buf) == 0
    assert "ARMED" in buf.getvalue()
    # no history at all: still exits clean with the arming hint
    buf = io.StringIO()
    assert check_perf.explain(str(tmp_path / "none.jsonl"), out=buf) == 0
    assert "needs 5 points to arm" in buf.getvalue()
