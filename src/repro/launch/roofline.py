"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs_per_device / 197e12      (bf16 MXU peak)
    memory     = HLO_bytes_per_device / 819e9       (HBM bandwidth)
    collective = collective_bytes_per_device / 50e9 (ICI link)

FLOPs/bytes come from ``compiled.cost_analysis()`` (the post-SPMD module
is per-partition, so these are already per-device). Collective bytes are
parsed from the optimized HLO text: we sum the *result-shape* bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (all-reduce counted twice for the ring's
reduce+broadcast phases). Shapes in the partitioned module are
per-device, so this approximates per-device link traffic.

MODEL_FLOPS uses the 6*N*D (train) / 2*N*D (inference) convention with
N = active non-embedding params, so the ratio MODEL_FLOPS / HLO_FLOPs
exposes remat recompute, causal-mask waste, routing overhead, and
padding.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

__all__ = ["HW", "collective_bytes", "roofline_terms", "model_flops",
           "active_params"]


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12        # bf16 / chip
    hbm_bw: float = 819e9             # bytes/s
    link_bw: float = 50e9             # bytes/s ICI per link


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

# NOTE: the op-result signature may be a combiner-fused TUPLE whose
# elements are separated by /*index=N*/ comments — '=' must be in the
# class or the match silently truncates to the tuple's tail.
_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],{}\s/_:#*\.=]*?\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes by collective kind (result-shape accounting;
    all-reduce x2). '-start' variants counted, '-done' skipped."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "count": 0}
    for m in _COLL_RE.finditer(hlo_text):
        sig, kind = m.group(1), m.group(2).lower()
        nbytes = _shape_bytes(sig)
        mult = 2 if kind == "all-reduce" else 1
        out[kind] += nbytes * mult
        out["count"] += 1
    out["total"] = sum(out[k] for k in
                       ("all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "collective-permute"))
    return out


def active_params(cfg) -> tuple:
    """(n_active, n_total) non-embedding params; MoE counts top-k experts."""
    from repro.models.lm import model_param_specs
    from repro.models.nn import np_prod
    import jax

    specs = model_param_specs(cfg)
    total = active = 0
    emb = np_prod(specs["embed"].shape)
    leaves = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "axes"))[0]
    for path, s in leaves:
        name = jax.tree_util.keystr(path)
        n = np_prod(s.shape)
        if "embed'" in name and "blocks" not in name:
            continue
        if "head" in name and "blocks" not in name and "tail" not in name:
            continue
        total += n
        if "experts" in s.axes:
            frac = cfg.n_experts_per_token / max(cfg.n_experts, 1)
            active += int(n * frac)
        else:
            active += n
    del emb
    return active, total


def model_flops(cfg, shape_kind: str, batch: int, seq: int) -> float:
    """6*N*D (train) / 2*N*D (inference forward) with N=active params."""
    n_active, _ = active_params(cfg)
    if shape_kind == "train":
        tokens = batch * seq
        return 6.0 * n_active * tokens
    if shape_kind == "prefill":
        tokens = batch * seq
        return 2.0 * n_active * tokens
    # decode: one token per sequence per step
    return 2.0 * n_active * batch


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float, hw: HW = HW()) -> dict:
    t_c = flops_per_dev / hw.peak_flops
    t_m = bytes_per_dev / hw.hbm_bw
    t_l = coll_bytes_per_dev / hw.link_bw
    dom = max((t_c, "compute"), (t_m, "memory"), (t_l, "collective"))[1]
    bound = max(t_c, t_m, t_l)
    return {
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_l,
        "dominant": dom,
        "roofline_fraction": (t_c / bound if bound > 0 else 0.0),
    }
